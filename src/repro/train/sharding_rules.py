"""Per-architecture logical sharding rules for params / optimizer state /
inputs (consumed by launch/dryrun and the train driver).

Returns pytrees of *logical axis tuples* structurally matching
``models.init_params(cfg)``; ``repro.sharding.logical_spec`` translates
them to PartitionSpecs for a concrete mesh.
"""

from __future__ import annotations

from typing import Any
from typing import Tuple

import jax

from repro.configs import ArchConfig
from repro.configs import DENSE
from repro.configs import HYBRID
from repro.configs import MOE
from repro.configs import SSM


def _attn_axes(stacked: bool, qk_norm: bool):
    L = (None,) if stacked else ()
    p = {
        "ln": L + (None,),
        "wq": L + (None, "tp", None),
        "wk": L + (None, "tp", None),
        "wv": L + (None, "tp", None),
        "wo": L + ("tp", None, None),
    }
    if qk_norm:
        p["q_norm"] = L + (None,)
        p["k_norm"] = L + (None,)
    return p


def _mlp_axes(stacked: bool):
    L = (None,) if stacked else ()
    return {
        "ln": L + (None,),
        "w_gate": L + (None, "tp"),
        "w_up": L + (None, "tp"),
        "w_down": L + ("tp", None),
    }


def _moe_axes():
    # experts sharded over the model axis (EP); shared experts TP-sharded
    return {
        "ln": (None, None),
        "w_gate": (None, None, None),
        "w1": (None, "ep", None, None),
        "w3": (None, "ep", None, None),
        "w2": (None, "ep", None, None),
        "sh_gate": (None, None, "tp"),
        "sh_up": (None, None, "tp"),
        "sh_down": (None, "tp", None),
    }


def _mamba_axes(extra_lead: Tuple = (None,)):
    L = extra_lead
    return {
        "ln": L + (None,),
        "w_z": L + (None, "tp"),
        "w_x": L + (None, "tp"),
        "w_bc": L + (None, None),
        "w_dt": L + (None, "tp"),
        "conv_x_w": L + (None, "tp"),
        "conv_x_b": L + ("tp",),
        "conv_bc_w": L + (None, None),
        "conv_bc_b": L + (None,),
        "dt_bias": L + ("tp",),
        "a_log": L + ("tp",),
        "d_skip": L + ("tp",),
        "out_ln": L + ("tp",),
        "w_out": L + ("tp", None),
    }


def param_logical_axes(cfg: ArchConfig) -> Any:
    axes: dict = {
        "embed": (None, "tp"),       # D-sharded: row gather stays local
        "ln_f": (None,),
        "lm_head": (None, "tp"),     # vocab-sharded logits
    }
    if cfg.family == DENSE:
        axes["layers"] = {"attn": _attn_axes(True, cfg.qk_norm),
                          "mlp": _mlp_axes(True)}
    elif cfg.family == MOE:
        if cfg.moe.first_dense:
            axes["dense_layers"] = {"attn": _attn_axes(True, cfg.qk_norm),
                                    "mlp": _mlp_axes(True)}
        moe_axes = _moe_axes()
        if not cfg.moe.n_shared:
            for k in ("sh_gate", "sh_up", "sh_down"):
                moe_axes.pop(k)
        axes["moe_layers"] = {"attn": _attn_axes(True, cfg.qk_norm),
                              "moe": moe_axes}
    elif cfg.family == SSM:
        axes["layers"] = _mamba_axes((None,))
    elif cfg.family == HYBRID:
        axes["mamba_groups"] = _mamba_axes((None, None))
        if cfg.n_layers % cfg.hybrid_period:
            axes["mamba_tail"] = _mamba_axes((None,))
        axes["shared_attn"] = _attn_axes(False, cfg.qk_norm)
        axes["shared_mlp"] = _mlp_axes(False)
    return axes


def opt_logical_axes(cfg: ArchConfig) -> Any:
    """ZeRO-1: moments get an extra 'zero' (data-axis) sharding on the
    first axis that the param rules leave unsharded and whose size is
    large (the leading stacked-layer axis)."""
    p_axes = param_logical_axes(cfg)

    def zero_ify(axes):
        axes = tuple(axes)
        if len(axes) >= 2 and axes[0] is None:
            return ("zero",) + axes[1:]
        return axes

    return jax.tree.map(zero_ify, p_axes,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_logical_axes() -> Tuple:
    return ("dp", None)              # (batch, seq)

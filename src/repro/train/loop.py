"""Training loop: train-step factory (grad accumulation, bf16 + fp32
moments, remat), step watchdog for straggler mitigation."""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import field
import time
from typing import Any
from typing import Callable
from typing import List
from typing import NamedTuple
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import forward
from repro.models import lm_loss

from .optimizer import AdamWConfig
from .optimizer import OptState
from .optimizer import adamw_update
from .optimizer import init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt=init_opt_state(params))


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(state, tokens) -> (state, metrics).

    ``microbatches`` > 1 splits the per-step batch and accumulates grads
    with a lax.scan (sequential microbatching — the standard way to fit
    the global batch when activations dominate memory)."""

    def loss_fn(params, tokens):
        logits = forward(params, tokens, cfg)
        return lm_loss(logits, tokens)

    def train_step(state: TrainState, tokens: jnp.ndarray):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, tokens)
        else:
            b = tokens.shape[0]
            mb = tokens.reshape(microbatches, b // microbatches,
                                tokens.shape[1])

            def acc(carry, batch):
                loss_i, g_i = jax.value_and_grad(loss_fn)(state.params,
                                                          batch)
                return jax.tree.map(jnp.add, carry[0], g_i), \
                    carry[1] + loss_i

            # scan keeps one gradient buffer live instead of `microbatches`
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def body(carry, batch):
                return acc(carry, batch), None

            (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches

        new_params, new_opt, om = adamw_update(opt_cfg, state.params,
                                               grads, state.opt)
        metrics = {"loss": loss, **om}
        return TrainState(new_params, new_opt), metrics

    return train_step


# ---------------------------------------------------------------------------
# Straggler mitigation: per-step timing watchdog
# ---------------------------------------------------------------------------
@dataclass
class StepWatchdog:
    """Tracks step wall-times and flags stragglers.

    On a real multi-pod deployment each host reports its step time into
    the coordination service; a host exceeding ``threshold ×`` the rolling
    median marks itself a straggler, and the documented policy is:
    (1) log + alert, (2) after ``evict_after`` consecutive flags the
    launcher removes the pod from the mesh and restarts from the latest
    checkpoint with a shrunk data axis (elastic restore,
    checkpoint.manager).  On this single-host build the watchdog is fully
    functional for detection; eviction is exercised in tests via the
    callback hook.
    """

    threshold: float = 3.0
    window: int = 32
    evict_after: int = 3
    on_straggler: Optional[Callable[[int, float], None]] = None
    _times: List[float] = field(default_factory=list)
    _consecutive: int = 0
    flagged_steps: List[int] = field(default_factory=list)

    def record(self, step: int, duration_s: float) -> bool:
        self._times.append(duration_s)
        if len(self._times) > self.window:
            self._times.pop(0)
        med = sorted(self._times)[len(self._times) // 2]
        is_straggler = (len(self._times) >= 5
                        and duration_s > self.threshold * med)
        if is_straggler:
            self._consecutive += 1
            self.flagged_steps.append(step)
            if self.on_straggler and self._consecutive >= self.evict_after:
                self.on_straggler(step, duration_s)
        else:
            self._consecutive = 0
        return is_straggler


class StepTimer:
    def __init__(self):
        self.t0 = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0

from .compression import (compressed_psum_mean, init_error_feedback)
from .loop import (StepTimer, StepWatchdog, TrainState, init_train_state,
                   make_train_step)
from .optimizer import (AdamWConfig, OptState, adamw_update, global_norm,
                        init_opt_state, lr_schedule)
from .sharding_rules import (batch_logical_axes, opt_logical_axes,
                             param_logical_axes)

__all__ = [
    "compressed_psum_mean", "init_error_feedback",
    "StepTimer", "StepWatchdog", "TrainState", "init_train_state",
    "make_train_step",
    "AdamWConfig", "OptState", "adamw_update", "global_norm",
    "init_opt_state", "lr_schedule",
    "batch_logical_axes", "opt_logical_axes", "param_logical_axes",
]

from .compression import compressed_psum_mean
from .compression import init_error_feedback
from .loop import StepTimer
from .loop import StepWatchdog
from .loop import TrainState
from .loop import init_train_state
from .loop import make_train_step
from .optimizer import AdamWConfig
from .optimizer import OptState
from .optimizer import adamw_update
from .optimizer import global_norm
from .optimizer import init_opt_state
from .optimizer import lr_schedule
from .sharding_rules import batch_logical_axes
from .sharding_rules import opt_logical_axes
from .sharding_rules import param_logical_axes

__all__ = [
    "compressed_psum_mean", "init_error_feedback",
    "StepTimer", "StepWatchdog", "TrainState", "init_train_state",
    "make_train_step",
    "AdamWConfig", "OptState", "adamw_update", "global_norm",
    "init_opt_state", "lr_schedule",
    "batch_logical_axes", "opt_logical_axes", "param_logical_axes",
]

"""int8 gradient compression with error feedback for cross-pod sync.

Distributed-optimization trick for the DCN-connected ``pod`` axis: the
inter-pod gradient all-reduce is the slowest collective in the multi-pod
mesh (~25 GB/s DCN vs ~50 GB/s/link ICI), so we quantize the payload to
int8 with a shared per-tensor scale and carry the quantization error into
the next step (error feedback keeps convergence unbiased in expectation).

Wire protocol per tensor:
  1. ``scale = psum_max(|g+e|) / 127``      (scalar, fp32)
  2. ``q = round((g+e)/scale)``             (int8 payload)
  3. ``sum = psum(q.int32)``                (int32 on the wire; a real DCN
     transport would reduce-scatter int8 + all-gather int8 — we keep the
     jax-native psum and count payload bytes as 4·n in the roofline, still
     2× less than fp32 all-reduce + no fp32 master copy exchange)
  4. ``g' = sum · scale / n_pods``; ``e' = (g+e) - dequant(own share)``
"""

from __future__ import annotations

from functools import partial
from typing import Any
from typing import Tuple

import jax
import jax.numpy as jnp


def _compress_one(g: jnp.ndarray, e: jnp.ndarray, axis: str
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32) + e
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    local_dq = q * scale
    new_e = gf - local_dq
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    summed = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32)
    mean = (summed * scale / n).astype(g.dtype)
    return mean, new_e


def compressed_psum_mean(grads: Any, err: Any, axis: str
                         ) -> Tuple[Any, Any]:
    """Mean-reduce a gradient pytree across ``axis`` with int8 quantization
    and error feedback.  Must run inside shard_map over ``axis``."""
    out = jax.tree.map(partial(_compress_one, axis=axis), grads, err)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return mean, new_err


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

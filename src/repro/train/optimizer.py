"""AdamW in pure JAX pytrees (no optax dependency).

Moments are fp32 regardless of param dtype; the dry-run shards them with
ZeRO-1 rules (``train.sharding_rules.opt_logical_axes``) so the optimizer
state never replicates across the data axis.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any
from typing import NamedTuple
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState
                 ) -> Tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(new_m, new_v, step), metrics

"""Version-compatibility shims for the JAX APIs this repo relies on.

The codebase targets the modern JAX surface (``jax.shard_map``,
``jax.sharding.AbstractMesh(axis_sizes, axis_names)``, ``check_vma``),
but must also run on JAX 0.4.x where

* ``shard_map`` still lives in ``jax.experimental.shard_map`` and takes
  ``check_rep`` instead of ``check_vma``;
* ``AbstractMesh`` takes a single ``((name, size), ...)`` shape tuple.

Import :func:`shard_map` / :func:`abstract_mesh` from here instead of
touching ``jax`` directly so every call site is version-proof.
"""

from __future__ import annotations

from typing import Any
from typing import Sequence
from typing import Tuple

import jax

__all__ = ["shard_map", "abstract_mesh"]


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, /, *, mesh, in_specs, out_specs, **kwargs):
        """``jax.shard_map`` signature adapter over the experimental API.

        Accepts the modern ``check_vma`` keyword and forwards it as the
        pre-0.5 ``check_rep``; all other keywords pass through.
        """
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _legacy_shard_map(f, mesh, in_specs, out_specs, **kwargs)


def abstract_mesh(shape: Sequence[int], names: Sequence[str]) -> Any:
    """Build ``jax.sharding.AbstractMesh`` across JAX versions.

    Modern JAX: ``AbstractMesh(axis_sizes, axis_names)``.
    JAX 0.4.x:  ``AbstractMesh(((name, size), ...))``.
    """
    AbstractMesh = jax.sharding.AbstractMesh
    shape_t: Tuple[int, ...] = tuple(shape)
    names_t: Tuple[str, ...] = tuple(names)
    if len(shape_t) != len(names_t):
        raise ValueError("shape and names must have the same length")
    try:
        return AbstractMesh(shape_t, names_t)
    except TypeError:
        return AbstractMesh(tuple(zip(names_t, shape_t)))

"""Fault-tolerant checkpointing: atomic writes, CRC manifest, keep-N,
resume-latest-valid, elastic mesh restore.

Layout::

    <dir>/step_<N>/
        arrays.npz          # flattened pytree leaves ("a/b/0" keys)
        manifest.json       # step, tree structure, crc32 per leaf, marker

Writes go to ``step_<N>.tmp`` and are renamed into place only after the
manifest (written last) is fsynced — a crash at any point leaves either a
complete checkpoint or an ignorable ``.tmp``.  ``restore_latest`` walks
checkpoints newest-first and skips any with a missing/corrupt manifest or
CRC mismatch (the node-failure / torn-write case).

Elastic restore: leaves are stored as *full logical arrays* (gathered via
``jax.device_get``); on load they are plain numpy and can be re-placed on
any mesh shape via ``jax.device_put`` with the new sharding — restarting
2×16×16 → 16×16 (pod loss) needs no resharding pass.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any
from typing import Dict
from typing import List
from typing import Optional
from typing import Tuple
import zlib

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz cannot round-trip ml_dtypes; widen exactly to fp32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        flat = _flatten(tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "crc32": {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                      for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "complete": True,
        }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        return final

    # ------------------------------------------------------------------
    def _steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _prune(self) -> None:
        steps = self._steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def _validate(self, path: str) -> Optional[Dict[str, np.ndarray]]:
        mpath = os.path.join(path, "manifest.json")
        npath = os.path.join(path, "arrays.npz")
        try:
            with open(mpath) as f:
                manifest = json.load(f)
            if not manifest.get("complete"):
                return None
            with np.load(npath) as z:
                arrays = {k: z[k] for k in manifest["keys"]}
            for k, v in arrays.items():
                if zlib.crc32(np.ascontiguousarray(v).tobytes()) \
                        != manifest["crc32"][k]:
                    return None
            return arrays
        except Exception:
            return None

    def restore_latest(self, like: Any) -> Optional[Tuple[int, Any]]:
        """Restore the newest valid checkpoint into the structure of
        ``like`` (a template pytree).  Returns (step, tree) or None."""
        for step in reversed(self._steps()):
            path = os.path.join(self.dir, f"step_{step:08d}")
            arrays = self._validate(path)
            if arrays is None:
                continue
            flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
            leaves = []
            ok = True
            for p, leaf in flat_like:
                key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                               for q in p)
                if key not in arrays:
                    ok = False
                    break
                arr = arrays[key]
                target = np.asarray(leaf)
                if tuple(arr.shape) != tuple(target.shape):
                    ok = False
                    break
                leaf_out = arr.astype(target.dtype)
                if hasattr(leaf, "sharding"):     # elastic re-placement
                    leaf_out = jax.device_put(leaf_out, leaf.sharding)
                leaves.append(leaf_out)
            if ok:
                return step, jax.tree_util.tree_unflatten(
                    treedef, leaves)
        return None

"""Fine-grained Mixture-of-Experts FFN (DeepSeekMoE-style).

Shared experts (always active) + routed experts with top-k gating and
capacity-based token dropping.  Distribution: expert parallelism over the
``model`` mesh axis via ``shard_map`` — tokens stay on their data shard
(no cross-data traffic); every model shard routes the *same* local tokens
to *its* slice of experts and a single ``psum`` over ``model`` combines
routed and shared-expert partial outputs.  This is the EP pattern whose
collective cost equals one TP all-reduce, chosen over dispatch all-to-all
because the paper-assigned MoE configs (64 experts, top-6) are
fine-grained: every token activates ~6/64 experts, so expert-local gather
+ psum moves strictly less data than a full token exchange.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.sharding import current_mesh
from repro.sharding import logical_spec

from .layers import rms_norm


def _route(xt: jnp.ndarray, w_gate: jnp.ndarray, top_k: int):
    """Top-k routing with renormalized weights. xt (T, D) → (w, idx)."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        w_gate.astype(jnp.float32))
    w, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx


def _routed_experts(xt, w, idx, w1, w3, w2, e0: int, capacity: int,
                    act):
    """Compute routed-expert outputs for local experts [e0, e0+E_loc).

    xt (T, D); w/idx (T, k); expert weights (E_loc, D, F)/(E_loc, F, D).
    Returns (T, D) partial output covering only local experts.
    """
    T = xt.shape[0]
    e_loc = w1.shape[0]
    eids = e0 + jnp.arange(e_loc)
    onehot = idx[None, :, :] == eids[:, None, None]          # (E,T,k)
    w_e = jnp.einsum("etk,tk->et", onehot.astype(w.dtype), w)  # (E,T)
    selected = w_e > 0
    # first-come-first-served capacity: earlier tokens win slots
    prio = jnp.where(selected, (T - jnp.arange(T))[None, :].astype(
        jnp.float32), -jnp.inf)
    cap = min(capacity, T)
    top_prio, tok_ids = jax.lax.top_k(prio, cap)              # (E, C)
    valid = jnp.isfinite(top_prio)
    tok_ids = jnp.where(valid, tok_ids, 0)
    gw = jnp.take_along_axis(w_e, tok_ids, axis=1) * valid    # (E, C)

    xg = xt[tok_ids]                                          # (E, C, D)
    h = act(jnp.einsum("ecd,edf->ecf", xg, w1)) \
        * jnp.einsum("ecd,edf->ecf", xg, w3)
    y = jnp.einsum("ecf,efd->ecd", h, w2)                     # (E, C, D)
    y = y * gw[..., None].astype(y.dtype)
    out = jnp.zeros_like(xt)
    out = out.at[tok_ids.reshape(-1)].add(y.reshape(-1, xt.shape[1]))
    return out


def _shared_experts(xt, p, act):
    h = act(jnp.einsum("td,df->tf", xt, p["sh_gate"])) \
        * jnp.einsum("td,df->tf", xt, p["sh_up"])
    return jnp.einsum("tf,fd->td", h, p["sh_down"])


def _moe_shard(x, p, *, spec, act, axis: Optional[str]):
    """Per-shard body (also the single-device path with axis=None)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    w, idx = _route(xt, p["w_gate"], spec.top_k)
    e_loc = p["w1"].shape[0]
    e0 = jax.lax.axis_index(axis) * e_loc if axis else 0
    capacity = max(int(spec.capacity_factor * xt.shape[0] * spec.top_k
                       / spec.n_experts), 4)
    out = _routed_experts(xt, w, idx, p["w1"], p["w3"], p["w2"], e0,
                          capacity, act)
    if spec.n_shared:
        out = out + _shared_experts(xt, p, act)
    if axis:
        out = jax.lax.psum(out, axis)
    return out.reshape(b, s, d).astype(x.dtype)


def moe_ffn(params, x, cfg, spec):
    """MoE FFN block (includes its pre-norm).  x (B, S, D)."""
    act = (partial(jax.nn.gelu, approximate=True) if cfg.act == "gelu"
           else jax.nn.silu)
    h = rms_norm(x, params["ln"], plus_one=cfg.gemma_norm)
    mesh = current_mesh()
    body = {k: v for k, v in params.items() if k != "ln"}
    if mesh is not None and mesh.shape.get("model", 1) > 1:
        dp_spec = logical_spec(("dp", None, None), mesh, shape=h.shape)
        pspecs = {
            "w_gate": P(), "w1": P("model"), "w3": P("model"),
            "w2": P("model"),
            "sh_gate": P(None, "model"), "sh_up": P(None, "model"),
            "sh_down": P("model", None),
        }
        in_specs = (dp_spec, {k: pspecs[k] for k in body})
        fn = shard_map(
            partial(_moe_shard, spec=spec, act=act, axis="model"),
            mesh=mesh, in_specs=in_specs, out_specs=dp_spec,
            check_vma=False)
        return fn(h, body)
    return _moe_shard(h, body, spec=spec, act=act, axis=None)


def init_moe_params(key, d_model: int, spec, dtype=jnp.bfloat16):
    e, f = spec.n_experts, spec.d_ff_expert
    fs = spec.n_shared * spec.d_ff_expert
    ks = jax.random.split(key, 7)
    s_in = d_model ** -0.5
    s_out = f ** -0.5
    p = {
        "ln": jnp.ones((d_model,), dtype),
        "w_gate": (jax.random.normal(ks[0], (d_model, e)) * s_in
                   ).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d_model, f)) * s_in
               ).astype(dtype),
        "w3": (jax.random.normal(ks[2], (e, d_model, f)) * s_in
               ).astype(dtype),
        "w2": (jax.random.normal(ks[3], (e, f, d_model)) * s_out
               ).astype(dtype),
    }
    if spec.n_shared:
        p["sh_gate"] = (jax.random.normal(ks[4], (d_model, fs)) * s_in
                        ).astype(dtype)
        p["sh_up"] = (jax.random.normal(ks[5], (d_model, fs)) * s_in
                      ).astype(dtype)
        p["sh_down"] = (jax.random.normal(ks[6], (fs, d_model))
                        * fs ** -0.5).astype(dtype)
    return p

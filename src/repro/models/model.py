"""Model assembly: init / forward / prefill / decode for every assigned
architecture family (dense, MoE, SSM, hybrid).

Design notes
------------
* Layers are stacked along a leading axis and iterated with
  ``jax.lax.scan`` so the lowered HLO stays small for 28–81-layer models
  (critical for the 40-cell dry-run compile budget).
* Per-layer heterogeneity (Gemma2 local/global alternation) is expressed
  as scanned flag arrays, not Python branches.
* ``tie_embeddings`` is honored as *intent only*: the lm_head is always a
  separate parameter so that the embedding can be D-sharded (cheap
  gather) while the head stays vocab-sharded (sharded logits/loss).
  Recorded in DESIGN.md §7.
* Modality archs (musicgen [audio], qwen2-vl [vlm]) take optional
  ``input_embeds`` (precomputed frame/patch embeddings — the frontend is
  a stub per spec) and, for M-RoPE, 3-plane ``positions``.
"""

from __future__ import annotations

import math
import os
from typing import Any
from typing import Dict
from typing import NamedTuple
from typing import Optional
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.configs import DENSE
from repro.configs import HYBRID
from repro.configs import MOE
from repro.configs import SSM
from repro.sharding import act_axes
from repro.sharding import constrain

from .layers import attention_block
from .layers import mlp_block
from .layers import rms_norm
from .moe import init_moe_params
from .moe import moe_ffn
from .ssm import Mamba2Cache
from .ssm import init_mamba2_cache
from .ssm import init_mamba2_params
from .ssm import mamba2_block

DTYPE = jnp.bfloat16

# Dry-run roofline accounting: XLA's HloCostAnalysis counts a while-loop
# body ONCE (trip count unknown to it), so scanned layer stacks under-
# report FLOPs/bytes by ~n_layers×.  launch/dryrun traces a second,
# fully-unrolled lowering (flag below) purely for cost analysis, while
# the scanned form is what compiles/ships.
UNROLL_SCANS = [os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"]


def _scan(f, init, xs, **kw):
    return jax.lax.scan(f, init, xs,
                        unroll=True if UNROLL_SCANS[0] else 1, **kw)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------
def _init_attn(key, cfg: ArchConfig, n_layers: int):
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    ln_init = jnp.zeros if cfg.gemma_norm else jnp.ones
    p = {
        "ln": ln_init((n_layers, d), DTYPE),
        "wq": (jax.random.normal(ks[0], (n_layers, d, h, hd)) * s
               ).astype(DTYPE),
        "wk": (jax.random.normal(ks[1], (n_layers, d, g, hd)) * s
               ).astype(DTYPE),
        "wv": (jax.random.normal(ks[2], (n_layers, d, g, hd)) * s
               ).astype(DTYPE),
        "wo": (jax.random.normal(ks[3], (n_layers, h, hd, d))
               * (h * hd) ** -0.5).astype(DTYPE),
    }
    if cfg.qk_norm:
        p["q_norm"] = ln_init((n_layers, hd), DTYPE)
        p["k_norm"] = ln_init((n_layers, hd), DTYPE)
    return p


def _init_mlp(key, cfg: ArchConfig, n_layers: int, d_ff: int):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    ln_init = jnp.zeros if cfg.gemma_norm else jnp.ones
    return {
        "ln": ln_init((n_layers, d), DTYPE),
        "w_gate": (jax.random.normal(ks[0], (n_layers, d, d_ff))
                   * d ** -0.5).astype(DTYPE),
        "w_up": (jax.random.normal(ks[1], (n_layers, d, d_ff))
                 * d ** -0.5).astype(DTYPE),
        "w_down": (jax.random.normal(ks[2], (n_layers, d_ff, d))
                   * d_ff ** -0.5).astype(DTYPE),
    }


def _squeeze0(tree):
    return jax.tree.map(lambda a: a[0] if a.ndim > 0 else a, tree)


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (v, d)) * d ** -0.5
                  ).astype(DTYPE),
        "ln_f": (jnp.zeros if cfg.gemma_norm else jnp.ones)((d,), DTYPE),
        "lm_head": (jax.random.normal(keys[1], (d, v)) * d ** -0.5
                    ).astype(DTYPE),
    }
    if cfg.family == DENSE:
        params["layers"] = {
            "attn": _init_attn(keys[2], cfg, cfg.n_layers),
            "mlp": _init_mlp(keys[3], cfg, cfg.n_layers, cfg.d_ff),
        }
    elif cfg.family == MOE:
        nd = cfg.moe.first_dense
        nm = cfg.n_layers - nd
        if nd:
            params["dense_layers"] = {
                "attn": _init_attn(keys[2], cfg, nd),
                "mlp": _init_mlp(keys[3], cfg, nd, cfg.d_ff),
            }
        moe_keys = jax.random.split(keys[4], nm)
        params["moe_layers"] = {
            "attn": _init_attn(keys[5], cfg, nm),
            "moe": jax.vmap(lambda k: init_moe_params(k, d, cfg.moe, DTYPE)
                            )(moe_keys),
        }
    elif cfg.family == SSM:
        lk = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: init_mamba2_params(k, d, cfg.ssm, DTYPE))(lk)
    elif cfg.family == HYBRID:
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        gk = jax.random.split(keys[2], n_groups * period)
        stacked = jax.vmap(
            lambda k: init_mamba2_params(k, d, cfg.ssm, DTYPE))(gk)
        params["mamba_groups"] = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]), stacked)
        if tail:
            tk = jax.random.split(keys[3], tail)
            params["mamba_tail"] = jax.vmap(
                lambda k: init_mamba2_params(k, d, cfg.ssm, DTYPE))(tk)
        params["shared_attn"] = _squeeze0(_init_attn(keys[4], cfg, 1))
        params["shared_mlp"] = _squeeze0(_init_mlp(keys[5], cfg, 1,
                                                   cfg.d_ff))
    else:
        raise ValueError(cfg.family)
    return params


def local_flags(cfg: ArchConfig, n_layers: Optional[int] = None
                ) -> jnp.ndarray:
    n = n_layers if n_layers is not None else cfg.n_layers
    if cfg.local_global_period is None or cfg.window is None:
        return jnp.zeros((n,), dtype=bool)
    idx = jnp.arange(n)
    # every `period`-th layer is global; the rest use the sliding window
    return (idx % cfg.local_global_period) != (cfg.local_global_period - 1)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed_tokens(params, tokens, cfg: ArchConfig,
                 input_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    if input_embeds is not None:
        x = input_embeds.astype(DTYPE)
    else:
        x = params["embed"][tokens]
    if cfg.gemma_norm:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), DTYPE)
    return constrain(x, act_axes())


def lm_logits(params, x, cfg: ArchConfig) -> jnp.ndarray:
    x = rms_norm(x, params["ln_f"], plus_one=cfg.gemma_norm)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = constrain(logits, ("dp", None, "tp"))
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


# ---------------------------------------------------------------------------
# Forward (training / prefill-style full-sequence)
# ---------------------------------------------------------------------------
def forward(params, tokens, cfg: ArchConfig, *,
            positions: Optional[jnp.ndarray] = None,
            input_embeds: Optional[jnp.ndarray] = None,
            remat: bool = True) -> jnp.ndarray:
    x = embed_tokens(params, tokens, cfg, input_embeds)

    if cfg.family == DENSE:
        x = _dense_stack(params["layers"], x, cfg, positions, remat,
                         local_flags(cfg))
    elif cfg.family == MOE:
        nd = cfg.moe.first_dense
        if nd:
            x = _dense_stack(params["dense_layers"], x, cfg, positions,
                             remat, local_flags(cfg, nd))
        x = _moe_stack(params["moe_layers"], x, cfg, positions, remat)
    elif cfg.family == SSM:
        x = _ssm_stack(params["layers"], x, cfg, remat)
    elif cfg.family == HYBRID:
        x = _hybrid_stack(params, x, cfg, positions, remat)
    return lm_logits(params, x, cfg)


def _maybe_remat(fn, remat):
    return jax.checkpoint(fn) if remat else fn


def _dense_stack(layers, x, cfg, positions, remat, flags):
    def block(h, sc):
        pa, pm, fl = sc
        a, _ = attention_block(pa, h, cfg, layer_is_local=fl,
                               positions=positions)
        h = h + a
        h = h + mlp_block(pm, h, cfg)
        return h, None

    xs = (layers["attn"], layers["mlp"], flags)
    x, _ = _scan(_maybe_remat(block, remat), x, xs)
    return x


def _moe_stack(layers, x, cfg, positions, remat):
    def block(h, sc):
        pa, pm = sc
        a, _ = attention_block(pa, h, cfg, positions=positions)
        h = h + a
        h = h + moe_ffn(pm, h, cfg, cfg.moe)
        return h, None

    x, _ = _scan(_maybe_remat(block, remat), x,
                        (layers["attn"], layers["moe"]))
    return x


def _ssm_stack(layers, x, cfg, remat):
    def block(h, p):
        y, _ = mamba2_block(p, h, cfg.ssm)
        return h + y, None

    x, _ = _scan(_maybe_remat(block, remat), x, layers)
    return x


def _hybrid_stack(params, x, cfg, positions, remat):
    shared_attn = params["shared_attn"]
    shared_mlp = params["shared_mlp"]

    def mamba_layer(h, p):
        y, _ = mamba2_block(p, h, cfg.ssm)
        return h + y, None

    def group(h, gp):
        h, _ = _scan(mamba_layer, h, gp)
        a, _ = attention_block(shared_attn, h, cfg, positions=positions)
        h = h + a
        h = h + mlp_block(shared_mlp, h, cfg)
        return h, None

    x, _ = _scan(_maybe_remat(group, remat), x,
                        params["mamba_groups"])
    if "mamba_tail" in params:
        x, _ = _scan(_maybe_remat(mamba_layer, remat), x,
                            params["mamba_tail"])
    return x


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy, vocab-sharding-friendly (one-hot einsum +
    logsumexp keep the vocab axis sharded end-to-end)."""
    lg = logits[:, :-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    logz = jax.nn.logsumexp(lg, axis=-1)
    onehot = jax.nn.one_hot(tg, lg.shape[-1], dtype=lg.dtype)
    gold = jnp.einsum("bsv,bsv->bs", lg, onehot)
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------
class Cache(NamedTuple):
    """Union cache: attention K/V (stacked over layers/apps) and/or SSM
    states (stacked over layers)."""
    k: Optional[jnp.ndarray] = None          # (L, B, S, G, hd)
    v: Optional[jnp.ndarray] = None
    conv_x: Optional[jnp.ndarray] = None     # (L, B, K-1, d_inner)
    conv_bc: Optional[jnp.ndarray] = None    # (L, B, K-1, 2GN)
    ssm: Optional[jnp.ndarray] = None        # (L, B, H, P, N)
    pos: Optional[jnp.ndarray] = None        # scalar int32: next position


def _n_attn_apps(cfg: ArchConfig) -> int:
    if cfg.family == HYBRID:
        return cfg.n_layers // cfg.hybrid_period
    if cfg.family == SSM:
        return 0
    return cfg.n_layers


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Cache:
    k = v = conv_x = conv_bc = ssm = None
    n_attn = _n_attn_apps(cfg)
    if n_attn:
        shape = (n_attn, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        k = jnp.zeros(shape, DTYPE)
        v = jnp.zeros(shape, DTYPE)
    if cfg.family in (SSM, HYBRID):
        proto = init_mamba2_cache(batch, cfg.d_model, cfg.ssm, DTYPE)
        n = cfg.n_layers
        conv_x = jnp.zeros((n,) + proto.conv_x.shape, proto.conv_x.dtype)
        conv_bc = jnp.zeros((n,) + proto.conv_bc.shape, proto.conv_bc.dtype)
        ssm = jnp.zeros((n,) + proto.ssm.shape, proto.ssm.dtype)
    return Cache(k=k, v=v, conv_x=conv_x, conv_bc=conv_bc, ssm=ssm,
                 pos=jnp.zeros((), jnp.int32))


def cache_logical_axes(cfg: ArchConfig) -> Cache:
    """Logical sharding for the cache (used by launch/dryrun)."""
    has_ssm = cfg.family in (SSM, HYBRID)
    has_attn = bool(_n_attn_apps(cfg))
    return Cache(
        k=(None, "dp", "kvseq", None, None) if has_attn else None,
        v=(None, "dp", "kvseq", None, None) if has_attn else None,
        conv_x=(None, "dp", None, "tp") if has_ssm else None,
        conv_bc=(None, "dp", None, None) if has_ssm else None,
        ssm=(None, "dp", "tp", None, None) if has_ssm else None,
        pos=(),
    )


# ---------------------------------------------------------------------------
# Decode step (one new token against the cache)
# ---------------------------------------------------------------------------
def decode_step(params, tokens, cache: Cache, cfg: ArchConfig, *,
                input_embeds: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Cache]:
    """tokens (B, 1) → (logits (B, 1, V), updated cache)."""
    b = tokens.shape[0]
    pos = cache.pos
    positions = jnp.broadcast_to(pos, (b, 1))
    x = embed_tokens(params, tokens, cfg, input_embeds)

    if cfg.family == DENSE:
        x, nk, nv = _dense_decode(params["layers"], x, cfg, positions,
                                  cache.k, cache.v, pos, local_flags(cfg))
        new = Cache(k=nk, v=nv, pos=pos + 1)
    elif cfg.family == MOE:
        nd = cfg.moe.first_dense
        ks, vs = [], []
        if nd:
            x, nk, nv = _dense_decode(params["dense_layers"], x, cfg,
                                      positions, cache.k[:nd], cache.v[:nd],
                                      pos, local_flags(cfg, nd))
            ks.append(nk)
            vs.append(nv)
        x, nk, nv = _moe_decode(params["moe_layers"], x, cfg, positions,
                                cache.k[nd:], cache.v[nd:], pos)
        ks.append(nk)
        vs.append(nv)
        new = Cache(k=jnp.concatenate(ks), v=jnp.concatenate(vs),
                    pos=pos + 1)
    elif cfg.family == SSM:
        def block(h, sc):
            p, cx, cbc, st = sc
            y, nc = mamba2_block(p, h, cfg.ssm,
                                 cache=Mamba2Cache(conv_x=cx, conv_bc=cbc,
                                                   ssm=st))
            return h + y, (nc.conv_x, nc.conv_bc, nc.ssm)
        x, (ncx, ncbc, nssm) = _scan(
            block, x, (params["layers"], cache.conv_x, cache.conv_bc,
                       cache.ssm))
        new = Cache(conv_x=ncx, conv_bc=ncbc, ssm=nssm, pos=pos + 1)
    elif cfg.family == HYBRID:
        x, new = _hybrid_decode(params, x, cfg, positions, cache)
    return lm_logits(params, x, cfg), new


def _dense_decode(layers, x, cfg, positions, ck, cv, pos, flags):
    # The stacked KV cache rides in the scan CARRY (per-layer
    # dynamic_update_index) rather than as xs/ys: while-loop carries can
    # be updated in place by XLA, so the multi-GB cache is not
    # double-buffered (§Perf iteration 3).
    def block(carry, sc):
        h, ck, cv, li = carry
        pa, pm, fl = sc
        k_l = jax.lax.dynamic_index_in_dim(ck, li, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(cv, li, 0, keepdims=False)
        a, (nk, nv) = attention_block(pa, h, cfg, layer_is_local=fl,
                                      positions=positions,
                                      kv_cache=(k_l, v_l), cache_pos=pos)
        h = h + a
        h = h + mlp_block(pm, h, cfg)
        ck = jax.lax.dynamic_update_index_in_dim(ck, nk, li, 0)
        cv = jax.lax.dynamic_update_index_in_dim(cv, nv, li, 0)
        return (h, ck, cv, li + 1), None

    (x, nk, nv, _), _ = _scan(
        block, (x, ck, cv, jnp.zeros((), jnp.int32)),
        (layers["attn"], layers["mlp"], flags))
    return x, nk, nv


def _moe_decode(layers, x, cfg, positions, ck, cv, pos):
    def block(carry, sc):
        h, ck, cv, li = carry
        pa, pm = sc
        k_l = jax.lax.dynamic_index_in_dim(ck, li, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(cv, li, 0, keepdims=False)
        a, (nk, nv) = attention_block(pa, h, cfg, positions=positions,
                                      kv_cache=(k_l, v_l), cache_pos=pos)
        h = h + a
        h = h + moe_ffn(pm, h, cfg, cfg.moe)
        ck = jax.lax.dynamic_update_index_in_dim(ck, nk, li, 0)
        cv = jax.lax.dynamic_update_index_in_dim(cv, nv, li, 0)
        return (h, ck, cv, li + 1), None

    (x, nk, nv, _), _ = _scan(
        block, (x, ck, cv, jnp.zeros((), jnp.int32)),
        (layers["attn"], layers["moe"]))
    return x, nk, nv


def _hybrid_decode(params, x, cfg, positions, cache: Cache):
    period = cfg.hybrid_period
    n_groups = cfg.n_layers // period
    pos = cache.pos
    shared_attn, shared_mlp = params["shared_attn"], params["shared_mlp"]

    def mamba_layer(h, sc):
        p, cx, cbc, st = sc
        y, nc = mamba2_block(p, h, cfg.ssm,
                             cache=Mamba2Cache(conv_x=cx, conv_bc=cbc,
                                               ssm=st))
        return h + y, (nc.conv_x, nc.conv_bc, nc.ssm)

    n_main = n_groups * period

    def grp_view(a):
        return a[:n_main].reshape((n_groups, period) + a.shape[1:])

    def group(h, sc):
        gp, gcx, gcbc, gssm, k_a, v_a = sc
        h, (ncx, ncbc, nssm) = _scan(mamba_layer, h,
                                            (gp, gcx, gcbc, gssm))
        a, (nk, nv) = attention_block(shared_attn, h, cfg,
                                      positions=positions,
                                      kv_cache=(k_a, v_a), cache_pos=pos)
        h = h + a
        h = h + mlp_block(shared_mlp, h, cfg)
        return h, (ncx, ncbc, nssm, nk, nv)

    x, (ncx, ncbc, nssm, nk, nv) = _scan(
        group, x, (params["mamba_groups"], grp_view(cache.conv_x),
                   grp_view(cache.conv_bc), grp_view(cache.ssm),
                   cache.k, cache.v))
    ncx = ncx.reshape((n_main,) + ncx.shape[2:])
    ncbc = ncbc.reshape((n_main,) + ncbc.shape[2:])
    nssm = nssm.reshape((n_main,) + nssm.shape[2:])
    if "mamba_tail" in params:
        x, (tcx, tcbc, tssm) = _scan(
            mamba_layer, x,
            (params["mamba_tail"], cache.conv_x[n_main:],
             cache.conv_bc[n_main:], cache.ssm[n_main:]))
        ncx = jnp.concatenate([ncx, tcx])
        ncbc = jnp.concatenate([ncbc, tcbc])
        nssm = jnp.concatenate([nssm, tssm])
    return x, Cache(k=nk, v=nv, conv_x=ncx, conv_bc=ncbc, ssm=nssm,
                    pos=pos + 1)


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward that also fills the cache
# ---------------------------------------------------------------------------
def prefill(params, tokens, cfg: ArchConfig, *,
            positions: Optional[jnp.ndarray] = None,
            input_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Cache]:
    """Returns (last-token logits (B, V), cache filled to S)."""
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg, input_embeds)

    if cfg.family == DENSE:
        ck, cv = _proto_kv(cfg, cfg.n_layers, b, s)
        x, nk, nv = _dense_prefill(params["layers"], x, cfg, positions,
                                   ck, cv, local_flags(cfg))
        cache = Cache(k=nk, v=nv, pos=jnp.asarray(s, jnp.int32))
    elif cfg.family == MOE:
        nd = cfg.moe.first_dense
        ks, vs = [], []
        if nd:
            ck, cv = _proto_kv(cfg, nd, b, s)
            x, nk, nv = _dense_prefill(params["dense_layers"], x, cfg,
                                       positions, ck, cv,
                                       local_flags(cfg, nd))
            ks.append(nk)
            vs.append(nv)
        ck, cv = _proto_kv(cfg, cfg.n_layers - nd, b, s)
        x, nk, nv = _moe_prefill(params["moe_layers"], x, cfg, positions,
                                 ck, cv)
        ks.append(nk)
        vs.append(nv)
        cache = Cache(k=jnp.concatenate(ks), v=jnp.concatenate(vs),
                      pos=jnp.asarray(s, jnp.int32))
    elif cfg.family == SSM:
        def block(h, sc):
            p, cx, cbc, st = sc
            y, nc = mamba2_block(p, h, cfg.ssm,
                                 cache=Mamba2Cache(conv_x=cx, conv_bc=cbc,
                                                   ssm=st))
            return h + y, (nc.conv_x, nc.conv_bc, nc.ssm)
        init = init_cache(cfg, b, 0)
        x, (ncx, ncbc, nssm) = _scan(
            block, x, (params["layers"], init.conv_x, init.conv_bc,
                       init.ssm))
        cache = Cache(conv_x=ncx, conv_bc=ncbc, ssm=nssm,
                      pos=jnp.asarray(s, jnp.int32))
    elif cfg.family == HYBRID:
        x, cache = _hybrid_prefill(params, x, cfg, positions, b, s)
    logits = lm_logits(params, x[:, -1:], cfg)[:, 0]
    return logits, cache


def _proto_kv(cfg, n, b, s):
    shape = (n, b, s, cfg.n_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, DTYPE), jnp.zeros(shape, DTYPE)


def _dense_prefill(layers, x, cfg, positions, ck, cv, flags):
    zero = jnp.zeros((), jnp.int32)

    def block(h, sc):
        pa, pm, fl, k_l, v_l = sc
        a, (nk, nv) = attention_block(pa, h, cfg, layer_is_local=fl,
                                      positions=positions,
                                      kv_cache=(k_l, v_l), cache_pos=zero)
        h = h + a
        h = h + mlp_block(pm, h, cfg)
        return h, (nk, nv)

    x, (nk, nv) = _scan(
        block, x, (layers["attn"], layers["mlp"], flags, ck, cv))
    return x, nk, nv


def _moe_prefill(layers, x, cfg, positions, ck, cv):
    zero = jnp.zeros((), jnp.int32)

    def block(h, sc):
        pa, pm, k_l, v_l = sc
        a, (nk, nv) = attention_block(pa, h, cfg, positions=positions,
                                      kv_cache=(k_l, v_l), cache_pos=zero)
        h = h + a
        h = h + moe_ffn(pm, h, cfg, cfg.moe)
        return h, (nk, nv)

    x, (nk, nv) = _scan(
        block, x, (layers["attn"], layers["moe"], ck, cv))
    return x, nk, nv


def _hybrid_prefill(params, x, cfg, positions, b, s):
    period = cfg.hybrid_period
    n_groups = cfg.n_layers // period
    zero = jnp.zeros((), jnp.int32)
    shared_attn, shared_mlp = params["shared_attn"], params["shared_mlp"]
    proto = init_mamba2_cache(b, cfg.d_model, cfg.ssm, DTYPE)

    def mamba_layer(h, p):
        y, nc = mamba2_block(p, h, cfg.ssm,
                             cache=Mamba2Cache(conv_x=proto.conv_x,
                                               conv_bc=proto.conv_bc,
                                               ssm=proto.ssm))
        return h + y, (nc.conv_x, nc.conv_bc, nc.ssm)

    ck, cv = _proto_kv(cfg, n_groups, b, s)

    def group(h, sc):
        gp, k_a, v_a = sc
        h, (ncx, ncbc, nssm) = _scan(mamba_layer, h, gp)
        a, (nk, nv) = attention_block(shared_attn, h, cfg,
                                      positions=positions,
                                      kv_cache=(k_a, v_a), cache_pos=zero)
        h = h + a
        h = h + mlp_block(shared_mlp, h, cfg)
        return h, (ncx, ncbc, nssm, nk, nv)

    x, (ncx, ncbc, nssm, nk, nv) = _scan(
        group, x, (params["mamba_groups"], ck, cv))
    n_main = n_groups * period
    ncx = ncx.reshape((n_main,) + ncx.shape[2:])
    ncbc = ncbc.reshape((n_main,) + ncbc.shape[2:])
    nssm = nssm.reshape((n_main,) + nssm.shape[2:])
    if "mamba_tail" in params:
        x, (tcx, tcbc, tssm) = _scan(mamba_layer, x,
                                            params["mamba_tail"])
        ncx = jnp.concatenate([ncx, tcx])
        ncbc = jnp.concatenate([ncbc, tcbc])
        nssm = jnp.concatenate([nssm, tssm])
    return x, Cache(k=nk, v=nv, conv_x=ncx, conv_bc=ncbc, ssm=nssm,
                    pos=jnp.asarray(s, jnp.int32))

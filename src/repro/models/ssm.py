"""Mamba2 (SSD — state-space duality) blocks in pure JAX.

Implements the chunked SSD algorithm (Dao & Gu, 2024): intra-chunk
quadratic attention-like term + inter-chunk linear state recurrence.  The
same math is mirrored by the Pallas kernel in
``repro.kernels.ssd_scan`` (validated against :func:`ssd_chunked`).

Shapes: x (B, S, H, P); dt (B, S, H) [post-softplus]; A (H,) negative;
B/C (B, S, G, N) with H % G == 0.
"""

from __future__ import annotations

from typing import NamedTuple
from typing import Optional
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.sharding import act_axes
from repro.sharding import constrain

from .layers import rms_norm
from .layers import row_parallel_out


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum(x[..., j+1:i+1]) for i ≥ j,
    -inf above the diagonal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                initial_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, "sequence must be chunk-aligned"
    nc = s // chunk
    rep = h // g

    xd = (x * dt[..., None]).reshape(b, nc, chunk, h, p)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)
    dA = (dt * A).reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    dA_cs = jnp.cumsum(dA, axis=-1)

    # 1. intra-chunk (quadratic within chunk)
    L = jnp.exp(segsum(dA))                                # (b,h,c,l,l)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        Cc, Bc, L, xd)

    # 2. per-chunk final states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)        # (b,h,c,l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bc, decay_states, xd)

    # 3. inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), dtype=states.dtype)
    states = jnp.concatenate([initial_state[:, None], states], axis=1)
    chunk_decay = dA_cs[..., -1]                           # (b,h,c)
    dc = jnp.exp(segsum(jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))))
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dc, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # 4. inter-chunk contribution to outputs
    state_decay = jnp.exp(dA_cs)                           # (b,h,c,l)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Cc, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def ssd_decode_step(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    A: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token recurrence.  state (B,H,P,N); x (B,H,P); dt (B,H);
    B/C (B,G,N).  Returns (y (B,H,P), new_state)."""
    h = x.shape[1]
    g = B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)                        # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1)
    dA = jnp.exp(dt * A)                                   # (b,h)
    upd = jnp.einsum("bhp,bhn->bhpn", x * dt[..., None], Bh)
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y, new_state


class Mamba2Cache(NamedTuple):
    conv_x: jnp.ndarray   # (B, d_conv-1, d_inner)     — TP-sharded dim
    conv_bc: jnp.ndarray  # (B, d_conv-1, 2·G·N)       — replicated
    ssm: jnp.ndarray      # (B, H, P, N)               — heads TP-sharded


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv1d. x (B,S,C); w (K,C); b (C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :],                     # (K, 1, C) kernel
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return jax.nn.silu(out + b)


def mamba2_block(params, x, spec, cache: Optional[Mamba2Cache] = None
                 ) -> Tuple[jnp.ndarray, Optional[Mamba2Cache]]:
    """One Mamba2 block: projections → conv → SSD → gated norm → out-proj.

    The z/x/dt projections are head-sharded (TP over ``model``) while the
    small B/C projections stay replicated — this keeps every downstream
    split aligned with shard boundaries (DESIGN.md §9).

    Train/prefill mode (cache is None or full-seq with returned cache) and
    single-token decode mode (S == 1 with cache) share parameters.
    """
    b, s, d = x.shape
    d_inner = spec.expand * d
    h = d_inner // spec.head_dim
    p, n, g = spec.head_dim, spec.d_state, spec.n_groups

    res = rms_norm(x, params["ln"])
    # gather the residual once (bf16) so the four column-parallel
    # projections contract over a replicated dim — without this, each
    # projection all-reduces an fp32 partial sum (§Perf iteration 1,
    # zamba2 train cell: 4 AR/layer → 1 AG/layer)
    res = constrain(res, ("dp", None, None))
    z = constrain(jnp.einsum("bsd,de->bse", res, params["w_z"]),
                  ("dp", None, "tp"))
    xr = constrain(jnp.einsum("bsd,de->bse", res, params["w_x"]),
                   ("dp", None, "tp"))
    bc = jnp.einsum("bsd,de->bse", res, params["w_bc"])
    dt_raw = constrain(jnp.einsum("bsd,dh->bsh", res, params["w_dt"]),
                       ("dp", None, "tp"))
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])

    A = -jnp.exp(params["a_log"].astype(jnp.float32))

    if cache is not None and s == 1:
        hist_x = jnp.concatenate([cache.conv_x, xr], axis=1)   # (b,K,d_in)
        hist_bc = jnp.concatenate([cache.conv_bc, bc], axis=1)
        cx = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist_x,
                                    params["conv_x_w"])
                         + params["conv_x_b"])
        cbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist_bc,
                                     params["conv_bc_w"])
                          + params["conv_bc_b"])
        xs = cx.reshape(b, h, p)
        Bv = cbc[..., :g * n].reshape(b, g, n)
        Cv = cbc[..., g * n:].reshape(b, g, n)
        y, new_ssm = ssd_decode_step(cache.ssm, xs.astype(jnp.float32),
                                     dt[:, 0].astype(jnp.float32), A,
                                     Bv.astype(jnp.float32),
                                     Cv.astype(jnp.float32))
        y = y[:, None]                                          # (b,1,h,p)
        xs = xs[:, None]                                        # (b,1,h,p)
        new_cache = Mamba2Cache(conv_x=hist_x[:, 1:],
                                conv_bc=hist_bc[:, 1:], ssm=new_ssm)
    else:
        cx = _causal_conv(xr, params["conv_x_w"], params["conv_x_b"])
        cbc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"])
        xs = cx.reshape(b, s, h, p)
        Bv = cbc[..., :g * n].reshape(b, s, g, n)
        Cv = cbc[..., g * n:].reshape(b, s, g, n)
        init = cache.ssm if cache is not None else None
        y, final_state = ssd_chunked(xs.astype(jnp.float32),
                                     dt.astype(jnp.float32), A,
                                     Bv.astype(jnp.float32),
                                     Cv.astype(jnp.float32),
                                     chunk=min(spec.chunk, s),
                                     initial_state=init)
        new_cache = None
        if cache is not None:
            new_cache = Mamba2Cache(
                conv_x=xr[:, -(spec.d_conv - 1):],
                conv_bc=bc[:, -(spec.d_conv - 1):],
                ssm=final_state)

    y = y + xs.astype(y.dtype) * params["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_ln"])
    rp = row_parallel_out(y, params["w_out"])
    if rp is not None:
        return rp, new_cache
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return constrain(out, act_axes()), new_cache


def init_mamba2_params(key, d_model: int, spec, dtype=jnp.bfloat16):
    d_inner = spec.expand * d_model
    h = d_inner // spec.head_dim
    g, n = spec.n_groups, spec.d_state
    bc_dim = 2 * g * n
    ks = jax.random.split(key, 6)
    scale = d_model ** -0.5
    return {
        "ln": jnp.ones((d_model,), dtype),
        "w_z": (jax.random.normal(ks[0], (d_model, d_inner)) * scale
                ).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d_model, d_inner)) * scale
                ).astype(dtype),
        "w_bc": (jax.random.normal(ks[2], (d_model, bc_dim)) * scale
                 ).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (d_model, h)) * scale
                 ).astype(dtype),
        "conv_x_w": (jax.random.normal(ks[4], (spec.d_conv, d_inner))
                     * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_w": (jax.random.normal(ks[5], (spec.d_conv, bc_dim))
                      * 0.1).astype(dtype),
        "conv_bc_b": jnp.zeros((bc_dim,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(0.001, 0.1, h))).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_ln": jnp.ones((d_inner,), dtype),
        "w_out": (jax.random.normal(jax.random.fold_in(key, 7),
                                    (d_inner, d_model)) * d_inner ** -0.5
                  ).astype(dtype),
    }


def init_mamba2_cache(batch: int, d_model: int, spec, dtype=jnp.bfloat16
                      ) -> Mamba2Cache:
    d_inner = spec.expand * d_model
    h = d_inner // spec.head_dim
    bc_dim = 2 * spec.n_groups * spec.d_state
    return Mamba2Cache(
        conv_x=jnp.zeros((batch, spec.d_conv - 1, d_inner), dtype),
        conv_bc=jnp.zeros((batch, spec.d_conv - 1, bc_dim), dtype),
        ssm=jnp.zeros((batch, h, spec.head_dim, spec.d_state), jnp.float32),
    )

"""Model zoo: pure-functional JAX implementations of the 10 assigned
architectures (dense GQA / MoE / Mamba2-SSD / hybrid)."""

from .model import Cache
from .model import cache_logical_axes
from .model import decode_step
from .model import forward
from .model import init_cache
from .model import init_params
from .model import lm_loss
from .model import local_flags
from .model import prefill

__all__ = ["Cache", "cache_logical_axes", "decode_step", "forward",
           "init_cache", "init_params", "lm_loss", "local_flags", "prefill"]

"""Model zoo: pure-functional JAX implementations of the 10 assigned
architectures (dense GQA / MoE / Mamba2-SSD / hybrid)."""

from .model import (Cache, cache_logical_axes, decode_step, forward,
                    init_cache, init_params, lm_loss, local_flags, prefill)

__all__ = ["Cache", "cache_logical_axes", "decode_step", "forward",
           "init_cache", "init_params", "lm_loss", "local_flags", "prefill"]

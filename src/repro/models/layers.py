"""Transformer building blocks shared by all assigned architectures.

Pure-functional JAX: params are pytrees of jnp arrays; every function takes
explicit config arguments.  Sharding is expressed through
``repro.sharding.constrain`` logical-axis hints so the same code runs on a
single CPU device (smoke tests) and on the production mesh (dry-run).
"""

from __future__ import annotations

import math
from typing import Optional
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.sharding import act_axes
from repro.sharding import constrain
from repro.sharding import current_mesh
from repro.sharding.api import ACT_SEQ


def row_parallel_out(y: jnp.ndarray, w: jnp.ndarray) -> Optional[jnp.ndarray]:
    """Megatron-SP row-parallel output projection (§Perf lever).

    y (B, S, F) with F sharded over ``model``; w (F, D) sharded on dim 0.
    Computes the partial matmul per shard and **reduce-scatters over the
    sequence** (psum_scatter) so the residual stream leaves the block
    sequence-sharded — replacing the all-reduce the plain lowering emits
    (wire bytes: (g-1)/g×N vs 2·(g-1)/g×N).  Returns None when the layout
    prerequisites don't hold (caller falls back to the einsum+constraint
    path).
    """
    mesh = current_mesh()
    if not ACT_SEQ[0] or mesh is None:
        return None
    mdl = mesh.shape.get("model", 1)
    if mdl <= 1 or y.shape[1] % mdl or y.shape[2] % mdl or \
            w.shape[0] % mdl:
        return None
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    if y.shape[0] % max(mesh.shape.get("data", 1)
                        * mesh.shape.get("pod", 1), 1):
        dp = None

    def f(y_loc, w_loc):
        part = jnp.einsum("bsf,fd->bsd", y_loc, w_loc)
        return jax.lax.psum_scatter(part, "model", scatter_dimension=1,
                                    tiled=True)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(dp, None, "model"), P("model", None)),
        out_specs=P(dp, "model", None), check_vma=False)(y, w)

# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm; ``plus_one`` selects the Gemma convention ((1+w)·x̂)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale) if plus_one else scale
    return (x * w).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Optional[Tuple[int, ...]] = None
               ) -> jnp.ndarray:
    """Rotate ``x`` (..., S, H, D) by position-dependent angles.

    ``positions``: (B, S) for standard RoPE, or (3, B, S) for Qwen2-VL
    M-RoPE, where the three planes carry temporal/height/width positions
    and ``mrope_sections`` gives the per-plane frequency-section sizes
    (in half-dims, summing to D/2).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    if mrope_sections is None:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    else:
        if positions.ndim == 2:                        # text-only fallback
            positions = jnp.broadcast_to(positions[None],
                                         (3,) + positions.shape)
        parts = []
        start = 0
        for plane, sec in enumerate(mrope_sections):
            f = freqs[start:start + sec]
            parts.append(positions[plane][..., None].astype(jnp.float32) * f)
            start += sec
        angles = jnp.concatenate(parts, axis=-1)       # (B,S,D/2)
    cos = jnp.cos(angles)[..., None, :]                # (B,S,1,D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; optional logit softcap and sliding window)
# ---------------------------------------------------------------------------
def _soft_cap(scores: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return scores
    return jnp.tanh(scores / cap) * cap


def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  q_positions: Optional[jnp.ndarray] = None,
                  kv_positions: Optional[jnp.ndarray] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Grouped-query attention.

    q: (B, Sq, H, D); k/v: (B, Sk, G, D) with H % G == 0.
    ``q_positions``/``kv_positions``: (B, Sq)/(B, Sk) absolute positions for
    masking (required when Sq != Sk, i.e. decode); default = aranges.
    """
    b, sq, h, d = q.shape
    _, sk, g, _ = k.shape
    group = h // g
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, g, group, d)
    scores = jnp.einsum("bsgqd,btgd->bgqst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = _soft_cap(scores, softcap)

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    pos_q = q_positions[:, None, None, :, None]        # (b,1,1,sq,1)
    pos_k = kv_positions[:, None, None, None, :]       # (b,1,1,1,sk)
    mask = jnp.ones((b, 1, 1, sq, sk), dtype=bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgqst,btgd->bsgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention_block(params, x, cfg, *, layer_is_local=None, positions=None,
                    kv_cache=None, cache_pos=None):
    """Full attention sub-block: norm → qkv → rope → attn → out-proj.

    With ``kv_cache=(k, v)`` (B, S_max, G, D) and scalar ``cache_pos``,
    runs in decode mode: writes the new K/V at ``cache_pos`` and attends
    over the cache.  Returns (out, new_kv_cache_or_None).
    """
    b, s, _ = x.shape
    h = rms_norm(x, params["ln"], plus_one=cfg.gemma_norm)
    h = constrain(h, ("dp", None, None))
    q = jnp.einsum("bsd,dhe->bshe", h, params["wq"])
    k = jnp.einsum("bsd,dge->bsge", h, params["wk"])
    v = jnp.einsum("bsd,dge->bsge", h, params["wv"])
    q = constrain(q, ("dp", None, "tp", None))
    k = constrain(k, ("dp", None, "tp", None))
    v = constrain(v, ("dp", None, "tp", None))

    if positions is None:
        base = jnp.arange(s) if cache_pos is None else cache_pos + jnp.arange(s)
        positions = jnp.broadcast_to(base, (b, s))
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], plus_one=cfg.gemma_norm)
        k = rms_norm(k, params["k_norm"], plus_one=cfg.gemma_norm)

    window = None
    if layer_is_local is not None and cfg.window is not None:
        # per-layer local/global alternation (Gemma2); layer_is_local is a
        # traced scalar → select the window mask arithmetically
        window_arr = jnp.where(layer_is_local, cfg.window, jnp.int32(2**30))
        window = window_arr
    scale = cfg.attn_scale or (1.0 / math.sqrt(cfg.head_dim))

    if kv_cache is None:
        out = gqa_attention(q, k, v, causal=True, window=window,
                            softcap=cfg.attn_softcap, scale=scale,
                            q_positions=positions, kv_positions=positions)
        new_cache = None
    else:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, axis=1)
        s_max = ck.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(s_max), (b, s_max))
        # mask out unwritten slots via position comparison (kv_pos > current)
        out = gqa_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                            causal=True, window=window,
                            softcap=cfg.attn_softcap, scale=scale,
                            q_positions=positions, kv_positions=kv_pos)
        new_cache = (ck, cv)

    b2, s2, hh, ee = out.shape
    wo2 = params["wo"].reshape(hh * ee, -1)
    rp = row_parallel_out(out.reshape(b2, s2, hh * ee), wo2)
    if rp is not None:
        return rp, new_cache
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    out = constrain(out, act_axes())
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def mlp_block(params, x, cfg):
    h = rms_norm(x, params["ln"], plus_one=cfg.gemma_norm)
    h = constrain(h, ("dp", None, None))
    gate = jnp.einsum("bsd,df->bsf", h, params["w_gate"])
    up = jnp.einsum("bsd,df->bsf", h, params["w_up"])
    gate = constrain(gate, ("dp", None, "tp"))
    act = jax.nn.gelu(gate, approximate=True) if cfg.act == "gelu" \
        else jax.nn.silu(gate)
    rp = row_parallel_out(act * up, params["w_down"])
    if rp is not None:
        return rp
    out = jnp.einsum("bsf,fd->bsd", act * up, params["w_down"])
    return constrain(out, act_axes())

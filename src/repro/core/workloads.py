"""Paper workloads (§VI-C): attention units of Gemma3-27B, Llama3-70B,
Llama3-405B, Qwen3-8B, evaluated as FlashAttention-2 GQA dataflows.

"In each attention unit, these models mainly differ in the number of Q
heads and KV heads."  Group allocation (paper Fig. 4, §VI-C):

* **temporal group allocation** — the Group dimension (Q heads sharing a
  KV head) is mapped entirely to the time domain *on the same core*; no
  inter-core KV sharing (used for Gemma3-27B in the paper).
* **spatial group allocation** — the Group dimension is (at least
  partially) spread across cores; cores share KV streams through the LLC
  and its MSHRs (used for Qwen3-8B / Llama3 in the paper).
"""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import replace
from typing import Dict

TEMPORAL = "temporal"
SPATIAL = "spatial"


@dataclass(frozen=True)
class AttnWorkload:
    """One attention unit of a model, in FlashAttention-2 form."""

    name: str
    n_q_heads: int
    n_kv_heads: int
    head_dim: int
    seq_len: int
    group_alloc: str = TEMPORAL       # temporal | spatial
    n_batches: int = 1                # >1 → the multi-batch DBP scenario (§VI-F)
    # int8/fp8 activations: with 1-byte K/V the Gemma3-27B 2K active
    # working set is 16 heads × 512 KB = 8 MB — exactly the paper's §VI-D2
    # statement ("8MB, which is exactly the size of the active working
    # set of the Gemma3-27B 2K case").
    dtype_bytes: int = 1
    q_block: int = 128                # Br (rows of Q per tile)
    kv_block: int = 128               # Bc (rows of K/V per tile)
    causal: bool = False              # the paper's dataflow streams full K/V

    def __post_init__(self) -> None:
        if self.n_q_heads % self.n_kv_heads:
            raise ValueError("GQA requires n_q_heads % n_kv_heads == 0")
        if self.seq_len % self.q_block or self.seq_len % self.kv_block:
            raise ValueError("seq_len must be tile-aligned")
        if self.group_alloc not in (TEMPORAL, SPATIAL):
            raise ValueError(f"bad group_alloc {self.group_alloc!r}")

    # -- derived quantities ------------------------------------------------
    @property
    def group_size(self) -> int:
        return self.n_q_heads // self.n_kv_heads

    @property
    def n_q_tiles(self) -> int:
        return self.seq_len // self.q_block

    @property
    def n_kv_tiles(self) -> int:
        return self.seq_len // self.kv_block

    @property
    def kv_head_bytes(self) -> int:
        """K + V bytes for one KV head."""
        return 2 * self.seq_len * self.head_dim * self.dtype_bytes

    @property
    def kv_tile_bytes(self) -> int:
        return self.kv_block * self.head_dim * self.dtype_bytes

    @property
    def q_tile_bytes(self) -> int:
        return self.q_block * self.head_dim * self.dtype_bytes

    def flops_per_inner_step(self) -> float:
        """QK^T + softmax update + PV for one (q_tile, kv_tile) pair."""
        qk = 2.0 * self.q_block * self.kv_block * self.head_dim
        pv = 2.0 * self.q_block * self.kv_block * self.head_dim
        softmax = 6.0 * self.q_block * self.kv_block
        return qk + pv + softmax

    def with_seq(self, seq_len: int) -> "AttnWorkload":
        return replace(self, seq_len=seq_len)

    def with_batches(self, n: int) -> "AttnWorkload":
        return replace(self, n_batches=n)


@dataclass(frozen=True)
class DecodeWorkload:
    """Decode-time attention over paged KV (the §VI-F multi-batch scenario
    generalized to serving): every decode step streams the full live KV
    history of each sequence; sequences finish at different steps, so
    their pages become dead mid-run and pollute the LLC until retired.

    ``n_short`` of the ``n_seqs`` sequences end after ``retire_step``
    decode steps; the rest run all ``n_steps``.  Each K/V line is read
    once per decode step its sequence is alive, so ``nAcc`` equals the
    sequence's lifetime in steps — the dataflow knowledge DBP retires
    pages with.
    """

    name: str = "decode-paged"
    n_seqs: int = 16
    seq_len: int = 2048               # KV history rows per sequence
    head_dim: int = 128
    n_kv_heads: int = 1
    page_rows: int = 128
    dtype_bytes: int = 1
    n_steps: int = 8                  # decode steps simulated
    retire_step: int = 4              # short sequences end after this step
    n_short: int = 8

    def __post_init__(self) -> None:
        if self.seq_len % self.page_rows:
            raise ValueError("seq_len must be page-aligned")
        if not (0 < self.retire_step <= self.n_steps):
            raise ValueError("retire_step must lie in (0, n_steps]")
        if not (0 <= self.n_short <= self.n_seqs):
            raise ValueError("n_short out of range")

    @property
    def page_bytes(self) -> int:
        return (self.page_rows * self.head_dim * self.n_kv_heads
                * self.dtype_bytes)

    @property
    def n_pages(self) -> int:
        return self.seq_len // self.page_rows

    @property
    def kv_bytes_per_seq(self) -> int:
        """K + V bytes of one sequence's history."""
        return 2 * self.n_pages * self.page_bytes

    def steps_alive(self, seq: int) -> int:
        return self.retire_step if seq < self.n_short else self.n_steps


@dataclass(frozen=True)
class SpecDecodeWorkload:
    """Speculative decoding: per verification cycle, a small draft model
    autoregressively proposes ``gamma`` tokens (streaming its own
    speculation-window KV ``gamma`` times), then the target model
    verifies them in one pass over its full KV history.

    The draft KV of one speculation round has a *short, known lifetime*:
    it dies at verification (accepted tokens re-enter through the target
    KV, rejected ones are discarded), so each round's draft KV is its
    own liveness epoch — the §VI-F two-epoch retirement pattern
    interleaved with a persistent reuse carrier.  ``nAcc`` of a draft
    page is ``gamma + 1`` (γ autoregressive draft passes plus the one
    verification read, matching ``spec_decode_spec``); DBP retires the
    whole speculation window on exactly that verification read, while
    LRU drags every retired window through the LLC as dead pollution.
    """

    name: str = "spec-decode"
    n_seqs: int = 16
    target_len: int = 512             # target-model KV history rows/seq
    draft_len: int = 256              # draft speculation-window rows/seq
    head_dim: int = 128
    n_kv_heads: int = 1
    page_rows: int = 128
    dtype_bytes: int = 1
    gamma: int = 4                    # draft tokens per verification
    n_verify: int = 4                 # draft→verify cycles simulated

    def __post_init__(self) -> None:
        if self.target_len % self.page_rows or self.draft_len % self.page_rows:
            raise ValueError("KV lengths must be page-aligned")
        if self.gamma < 1 or self.n_verify < 1:
            raise ValueError("gamma and n_verify must be >= 1")

    @property
    def page_bytes(self) -> int:
        return (self.page_rows * self.head_dim * self.n_kv_heads
                * self.dtype_bytes)

    @property
    def n_target_pages(self) -> int:
        return self.target_len // self.page_rows

    @property
    def n_draft_pages(self) -> int:
        return self.draft_len // self.page_rows

    @property
    def token_bytes(self) -> int:
        """One decode token's activation row (Q or logit output)."""
        return self.head_dim * self.n_kv_heads * self.dtype_bytes


@dataclass(frozen=True)
class SSDScanWorkload:
    """Mamba2 SSD chunked scan (``models/ssm.py::ssd_chunked``) as a
    cache dataflow — the dead-block insight on an attention-free
    architecture (DESIGN.md §4).

    Per chunk, the intra-chunk quadratic pass streams the chunk's
    x/B/C inputs (bursty, bypass class), then the inter-chunk recurrence
    reads the *previous* chunk's running state and materializes this
    chunk's: each head's (P × N) state tile is stored once and read
    exactly once by the next chunk's recurrence, so its ``nAcc`` ends at
    the next chunk's materialization and the TMU retires it there.
    Consumed states are the most-recently-read mass in the LLC — under
    LRU they shadow the freshly materialized generation (the §VI-F
    pollution at chunk cadence), DBP frees them on the spot.  States are
    *dirty* reuse carriers (produced by stores), so the scenario also
    stresses the dirty-lifetime write-back model: every state writes
    back once it ages out, whether or not its read hit.
    """

    name: str = "ssd-scan"
    n_seqs: int = 16
    n_chunks: int = 6
    n_heads: int = 6
    d_head: int = 128                 # P
    d_state: int = 128                # N
    chunk_len: int = 128              # rows per chunk (x/B/C stream)
    dtype_bytes: int = 1

    def __post_init__(self) -> None:
        if self.n_chunks < 2:
            raise ValueError("need >= 2 chunks for a state recurrence")
        if self.n_heads < 1 or self.n_seqs < 1:
            raise ValueError("n_heads and n_seqs must be >= 1")

    @property
    def head_state_bytes(self) -> int:
        """One head's (P × N) running-state tile."""
        return self.d_head * self.d_state * self.dtype_bytes

    @property
    def state_bytes(self) -> int:
        """One sequence's full running state (all heads) for one chunk."""
        return self.n_heads * self.head_state_bytes

    @property
    def head_slab_bytes(self) -> int:
        """All sequences' head-``h`` state tiles of one chunk — the unit
        that dies in a single lockstep round (every core's recurrence
        reads its sequence's tile in the same round), sized so it tiles
        the TMU's ``tag``-slice dead-id regions cleanly."""
        return self.n_seqs * self.head_state_bytes

    @property
    def chunk_in_bytes(self) -> int:
        """x + B + C rows of one chunk (the bursty input stream)."""
        return self.chunk_len * (self.n_heads * self.d_head
                                 + 2 * self.d_state) * self.dtype_bytes

    @property
    def chunk_out_bytes(self) -> int:
        """y rows of one chunk."""
        return self.chunk_len * self.n_heads * self.d_head * self.dtype_bytes

    @property
    def intra_flops(self) -> float:
        """Intra-chunk quadratic term per (seq, chunk), all heads."""
        return 4.0 * self.n_heads * self.chunk_len ** 2 * self.d_head

    @property
    def inter_flops(self) -> float:
        """State materialization + inter-chunk contribution per
        (seq, chunk), all heads."""
        return 4.0 * self.n_heads * self.chunk_len * self.d_head \
            * self.d_state


@dataclass(frozen=True)
class PrefixShareWorkload:
    """Prefix-cache sharing: a batch of requests whose prompts share one
    common prefix (system prompt / few-shot header) while each request
    appends a private suffix.

    Every decode step streams the shared prefix KV on *all* cores at
    once — a high-``sharers`` co-stream whose same-round requests merge
    in the MSHRs while the lagging rank's reuses ride LLC storage — plus
    each request's private suffix KV (``sharers == 1``).  The private
    streams thrash; blind bypassing that caught them would also kill the
    shared prefix's inter-core reuse, which is exactly the §IV-E failure
    mode the conservative ``gqa_bypass`` variant exists to avoid — the
    suite runs this scenario with that variant.
    """

    name: str = "prefix-share"
    n_reqs: int = 16
    prefix_len: int = 2048            # shared-prompt KV rows
    suffix_len: int = 512             # private KV rows per request
    head_dim: int = 128
    n_kv_heads: int = 1
    page_rows: int = 128
    dtype_bytes: int = 1
    n_steps: int = 4                  # decode steps simulated

    def __post_init__(self) -> None:
        if self.prefix_len % self.page_rows or \
                self.suffix_len % self.page_rows:
            raise ValueError("KV lengths must be page-aligned")
        if self.n_reqs < 2:
            raise ValueError("prefix sharing needs >= 2 requests")
        if self.n_steps < 1:
            raise ValueError("n_steps must be >= 1")

    @property
    def page_bytes(self) -> int:
        return (self.page_rows * self.head_dim * self.n_kv_heads
                * self.dtype_bytes)

    @property
    def n_prefix_pages(self) -> int:
        return self.prefix_len // self.page_rows

    @property
    def n_suffix_pages(self) -> int:
        return self.suffix_len // self.page_rows

    @property
    def token_bytes(self) -> int:
        """One decode token's activation row (Q or logit output)."""
        return self.head_dim * self.n_kv_heads * self.dtype_bytes


@dataclass(frozen=True)
class MoEWorkload:
    """Expert-FFN of a Mixture-of-Experts layer with skewed routing:
    ``n_hot`` experts stay active for the whole run and are co-streamed by
    several cores (inter-core expert-weight sharing through the LLC),
    while the remaining cold experts serve traffic only during the first
    ``warm_steps`` token waves and then retire — dead expert weights that
    pollute the cache exactly like finished batches do in §VI-F.
    """

    name: str = "moe-ffn"
    n_experts: int = 16
    n_hot: int = 8
    d_model: int = 512
    d_ff: int = 512
    tile_bytes: int = 16 * 1024
    token_block: int = 32             # tokens per routed activation tile
    dtype_bytes: int = 1
    n_steps: int = 8                  # token waves
    warm_steps: int = 2               # waves during which cold experts route

    def __post_init__(self) -> None:
        if not (0 < self.n_hot <= self.n_experts):
            raise ValueError("n_hot out of range")
        if self.expert_bytes % self.tile_bytes:
            raise ValueError("expert weights must be tile-aligned")
        if not (0 < self.warm_steps <= self.n_steps):
            raise ValueError("warm_steps must lie in (0, n_steps]")

    @property
    def expert_bytes(self) -> int:
        """W_up + W_down bytes of one expert."""
        return 2 * self.d_model * self.d_ff * self.dtype_bytes

    @property
    def n_cold(self) -> int:
        return self.n_experts - self.n_hot

    @property
    def act_tile_bytes(self) -> int:
        return self.token_block * self.d_model * self.dtype_bytes

    @property
    def flops_per_use(self) -> float:
        """One routed token block through W_up and W_down."""
        return 4.0 * self.token_block * self.d_model * self.d_ff


# Paper benchmark models (attention-unit shapes; GQA head counts are the
# models' published configurations, head_dim 128 across all four).
PAPER_WORKLOADS: Dict[str, AttnWorkload] = {
    "gemma3-27b": AttnWorkload("gemma3-27b", n_q_heads=32, n_kv_heads=16,
                               head_dim=128, seq_len=2048,
                               group_alloc=TEMPORAL),
    "qwen3-8b": AttnWorkload("qwen3-8b", n_q_heads=32, n_kv_heads=8,
                             head_dim=128, seq_len=2048,
                             group_alloc=SPATIAL),
    "llama3-70b": AttnWorkload("llama3-70b", n_q_heads=64, n_kv_heads=8,
                               head_dim=128, seq_len=2048,
                               group_alloc=SPATIAL),
    "llama3-405b": AttnWorkload("llama3-405b", n_q_heads=128, n_kv_heads=8,
                                head_dim=128, seq_len=2048,
                                group_alloc=SPATIAL),
}


def get_workload(name: str, seq_len: int | None = None,
                 n_batches: int = 1) -> AttnWorkload:
    wl = PAPER_WORKLOADS[name]
    if seq_len is not None:
        wl = wl.with_seq(seq_len)
    if n_batches != 1:
        wl = wl.with_batches(n_batches)
    return wl

"""Paper workloads (§VI-C): attention units of Gemma3-27B, Llama3-70B,
Llama3-405B, Qwen3-8B, evaluated as FlashAttention-2 GQA dataflows.

"In each attention unit, these models mainly differ in the number of Q
heads and KV heads."  Group allocation (paper Fig. 4, §VI-C):

* **temporal group allocation** — the Group dimension (Q heads sharing a
  KV head) is mapped entirely to the time domain *on the same core*; no
  inter-core KV sharing (used for Gemma3-27B in the paper).
* **spatial group allocation** — the Group dimension is (at least
  partially) spread across cores; cores share KV streams through the LLC
  and its MSHRs (used for Qwen3-8B / Llama3 in the paper).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict

TEMPORAL = "temporal"
SPATIAL = "spatial"


@dataclass(frozen=True)
class AttnWorkload:
    """One attention unit of a model, in FlashAttention-2 form."""

    name: str
    n_q_heads: int
    n_kv_heads: int
    head_dim: int
    seq_len: int
    group_alloc: str = TEMPORAL       # temporal | spatial
    n_batches: int = 1                # >1 → the multi-batch DBP scenario (§VI-F)
    # int8/fp8 activations: with 1-byte K/V the Gemma3-27B 2K active
    # working set is 16 heads × 512 KB = 8 MB — exactly the paper's §VI-D2
    # statement ("8MB, which is exactly the size of the active working
    # set of the Gemma3-27B 2K case").
    dtype_bytes: int = 1
    q_block: int = 128                # Br (rows of Q per tile)
    kv_block: int = 128               # Bc (rows of K/V per tile)
    causal: bool = False              # the paper's dataflow streams full K/V

    def __post_init__(self) -> None:
        if self.n_q_heads % self.n_kv_heads:
            raise ValueError("GQA requires n_q_heads % n_kv_heads == 0")
        if self.seq_len % self.q_block or self.seq_len % self.kv_block:
            raise ValueError("seq_len must be tile-aligned")
        if self.group_alloc not in (TEMPORAL, SPATIAL):
            raise ValueError(f"bad group_alloc {self.group_alloc!r}")

    # -- derived quantities ------------------------------------------------
    @property
    def group_size(self) -> int:
        return self.n_q_heads // self.n_kv_heads

    @property
    def n_q_tiles(self) -> int:
        return self.seq_len // self.q_block

    @property
    def n_kv_tiles(self) -> int:
        return self.seq_len // self.kv_block

    @property
    def kv_head_bytes(self) -> int:
        """K + V bytes for one KV head."""
        return 2 * self.seq_len * self.head_dim * self.dtype_bytes

    @property
    def kv_tile_bytes(self) -> int:
        return self.kv_block * self.head_dim * self.dtype_bytes

    @property
    def q_tile_bytes(self) -> int:
        return self.q_block * self.head_dim * self.dtype_bytes

    def flops_per_inner_step(self) -> float:
        """QK^T + softmax update + PV for one (q_tile, kv_tile) pair."""
        qk = 2.0 * self.q_block * self.kv_block * self.head_dim
        pv = 2.0 * self.q_block * self.kv_block * self.head_dim
        softmax = 6.0 * self.q_block * self.kv_block
        return qk + pv + softmax

    def with_seq(self, seq_len: int) -> "AttnWorkload":
        return replace(self, seq_len=seq_len)

    def with_batches(self, n: int) -> "AttnWorkload":
        return replace(self, n_batches=n)


# Paper benchmark models (attention-unit shapes; GQA head counts are the
# models' published configurations, head_dim 128 across all four).
PAPER_WORKLOADS: Dict[str, AttnWorkload] = {
    "gemma3-27b": AttnWorkload("gemma3-27b", n_q_heads=32, n_kv_heads=16,
                               head_dim=128, seq_len=2048,
                               group_alloc=TEMPORAL),
    "qwen3-8b": AttnWorkload("qwen3-8b", n_q_heads=32, n_kv_heads=8,
                             head_dim=128, seq_len=2048,
                             group_alloc=SPATIAL),
    "llama3-70b": AttnWorkload("llama3-70b", n_q_heads=64, n_kv_heads=8,
                               head_dim=128, seq_len=2048,
                               group_alloc=SPATIAL),
    "llama3-405b": AttnWorkload("llama3-405b", n_q_heads=128, n_kv_heads=8,
                                head_dim=128, seq_len=2048,
                                group_alloc=SPATIAL),
}


def get_workload(name: str, seq_len: int | None = None,
                 n_batches: int = 1) -> AttnWorkload:
    wl = PAPER_WORKLOADS[name]
    if seq_len is not None:
        wl = wl.with_seq(seq_len)
    if n_batches != 1:
        wl = wl.with_batches(n_batches)
    return wl

"""Dataflow → memory-trace generation for the DCO simulator.

The paper evaluates trace-driven: "directly using memory traces generated
from given dataflows" (§VI-B).  Traces are produced by lowering
declarative dataflow specs (``repro.dataflows``, DESIGN.md §8); this
module keeps the trace data model (:class:`Step`/:class:`Trace`), the
compiled-trace IR, the closed-form :class:`DataflowCounts` record, and
the historical entry points (``build_fa2_trace`` for FlashAttention-2 GQA
with temporal/spatial group allocation §VI-C, optionally multi-batch
§VI-F; ``build_matmul_trace`` for the tiled MatMul of Fig. 2(a)), which
are now thin wrappers over the IR.

A trace is a list of per-core *steps*; each step is one inner iteration of
the dataflow: a set of bulk tile transfers plus the compute executed on
the tiles while they sit in the core's private SPM.  Cores run steps in
lockstep rounds (burst-synchronous simulation, DESIGN.md §7.2).

Spatial group allocation staggers the KV-loop start of the cores inside a
sharing group by one tile per rank ("scheduling skew"), so inter-core
reuses appear as short-reuse-distance LLC hits rather than same-cycle MSHR
merges — this reproduces the paper's observation that blind bypassing
destroys inter-core reuse (§IV-E) while LRU and ``at`` keep it.

Policy sweeps (one trace, many policies — every figure of the paper) go
through :class:`CompiledTrace`: the per-core ``Step`` lists are lowered
*once* into flat round-indexed numpy arrays (line addresses, dense seen
indices, merged write flags, TLL feed, CSR-style round offsets).  The
compiled form is built lazily by :meth:`Trace.compiled` and cached on the
``Trace`` so the lowering cost is shared across all policies of a sweep;
``Simulator.run`` slices these arrays per round instead of re-walking the
Python step lists.
"""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import field
from typing import Dict
from typing import List
from typing import Optional
from typing import Tuple

import numpy as np

from .tmu import TensorMeta
from .workloads import AttnWorkload

LINE_BYTES = 128


@dataclass
class Step:
    """One dataflow inner iteration on one core."""

    loads: List[Tuple[int, int]] = field(default_factory=list)   # (tensor_id, tile)
    stores: List[Tuple[int, int]] = field(default_factory=list)
    flops: float = 0.0


@dataclass
class Trace:
    name: str
    tensors: Dict[int, TensorMeta]
    core_steps: List[List[Step]]
    core_group: List[int]            # sharing-group id per core (-1: none)
    core_is_leader: List[bool]       # leader of its sharing group?
    line_bytes: int = LINE_BYTES
    workload: Optional[AttnWorkload] = None
    # multi-tenant composites (DESIGN.md §8.4): tensor_id → tenant index
    # plus tenant display names; the simulator attributes counters by
    # the tenants' (disjoint, region-aligned) address ranges
    tenant_of_tensor: Optional[Dict[int, int]] = None
    tenant_names: Optional[List[str]] = None
    # deterministic content hash of the DataflowSpec this trace was
    # lowered from (repro.dataflows.artifacts); None for hand-built
    # traces.  Keys the on-disk artifact cache for compiled lowerings.
    fingerprint: Optional[str] = None
    _compiled: Dict[int, "CompiledTrace"] = field(
        default_factory=dict, init=False, repr=False, compare=False)
    _line_counts: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False)

    @property
    def n_cores(self) -> int:
        return len(self.core_steps)

    @property
    def n_tenants(self) -> int:
        return len(self.tenant_names) if self.tenant_names else 1

    def tenant_region_starts(self) -> Optional[Tuple[np.ndarray,
                                                     np.ndarray]]:
        """Sorted ``(region_start_addrs, tenant_ids)`` for per-tenant
        attribution: a byte address belongs to the tenant whose region
        start is the greatest one <= it (regions are disjoint and
        contiguous per tenant, so the map is exact)."""
        if self.tenant_of_tensor is None:
            return None
        base: Dict[int, int] = {}
        for tid, m in self.tensors.items():
            ten = self.tenant_of_tensor[tid]
            base[ten] = min(base.get(ten, m.base_addr), m.base_addr)
        tens = sorted(base, key=lambda t: base[t])
        return (np.asarray([base[t] for t in tens], dtype=np.int64),
                np.asarray(tens, dtype=np.int64))

    @property
    def n_rounds(self) -> int:
        return max(len(s) for s in self.core_steps)

    def tile_lines(self, tensor_id: int, tile: int) -> np.ndarray:
        meta = self.tensors[tensor_id]
        start = meta.base_addr + tile * meta.tile_bytes
        n = meta.tile_bytes // self.line_bytes
        return start + np.arange(n, dtype=np.int64) * self.line_bytes

    def total_bytes_touched(self) -> int:
        return sum(m.size_bytes for m in self.tensors.values())

    def compiled(self, line_bytes: int = 0) -> "CompiledTrace":
        """Lower to flat round-indexed arrays; built once, cached here.

        ``line_bytes`` is validation only: the simulator passes its
        cache-line size and anything other than the trace's own line
        granularity is rejected (the addresses bake it in).  The single
        cached lowering is shared by every policy and every cache
        geometry of a sweep.
        """
        lb = line_bytes or self.line_bytes
        if lb != self.line_bytes:
            # the trace bakes its line granularity into every address;
            # lowering at another line size would silently corrupt the
            # seen-bitmap layout and the TLL feed
            raise ValueError(
                f"cannot compile a {self.line_bytes}-byte-line trace at "
                f"line_bytes={lb}")
        ct = self._compiled.get(lb)
        if ct is None:
            key = None
            if self.fingerprint is not None:
                from repro.dataflows import artifacts
                if artifacts.artifacts_enabled():
                    key = artifacts.compiled_trace_key(self.fingerprint, lb)
                    ct = artifacts.load_compiled_trace(key)
            if ct is None:
                ct = CompiledTrace.build(self, lb)
                if key is not None:
                    from repro.dataflows import artifacts
                    artifacts.store_compiled_trace(key, ct)
            ct.cache_key = key
            self._compiled[lb] = ct
        return ct

    # ------------------------------------------------------------------
    def _round_line_counts(self) -> np.ndarray:
        """Pre-merge line-request count per round (== the compiled
        ``n_acc_round``), computed from the step lists alone so segment
        boundaries can be chosen without materializing the full
        lowering."""
        if self._line_counts is None:
            lb = self.line_bytes
            counts = np.zeros(self.n_rounds, dtype=np.int64)
            tensors = self.tensors
            for steps in self.core_steps:
                for r, step in enumerate(steps):
                    for tid, _ in step.loads:
                        counts[r] += tensors[tid].tile_bytes // lb
                    for tid, _ in step.stores:
                        counts[r] += tensors[tid].tile_bytes // lb
            self._line_counts = counts
        return self._line_counts

    def compiled_segments(self, line_bytes: int = 0,
                          chunk_lines: int = 1 << 20):
        """Chunked mode of :meth:`compiled`: lower the rounds into
        fixed-size CSR segments and yield them incrementally.

        Segments pack whole rounds greedily up to ``chunk_lines``
        pre-merge line requests each; rounds are atomic (the MSHR merge
        and same-set pass splitting never cross a round boundary), so a
        single round larger than the budget becomes its own segment and
        the concatenation of the segment arrays is exactly the
        monolithic lowering.  When the full lowering is already cached
        the segments are zero-copy slices of it; otherwise each window
        is built directly from its round range, so streaming consumers
        (the serving-replay path) never hold more than one window of
        per-line arrays.
        """
        lb = line_bytes or self.line_bytes
        if lb != self.line_bytes:
            raise ValueError(
                f"cannot compile a {self.line_bytes}-byte-line trace at "
                f"line_bytes={lb}")
        if chunk_lines <= 0:
            raise ValueError("chunk_lines must be positive")
        bounds = _segment_bounds(self._round_line_counts(), chunk_lines)
        full = self._compiled.get(lb)
        for r0, r1 in zip(bounds[:-1], bounds[1:]):
            if full is not None:
                yield full.slice_rounds(r0, r1)
            else:
                yield CompiledTrace.build(self, lb, r0, r1)


def _segment_bounds(line_counts: np.ndarray, chunk_lines: int) -> List[int]:
    """Round indices cutting a trace into whole-round segments of at most
    ``chunk_lines`` pre-merge line requests (always >= 1 round each)."""
    bounds = [0]
    acc = 0
    for r, c in enumerate(line_counts.tolist()):
        if acc and acc + c > chunk_lines:
            bounds.append(r)
            acc = 0
        acc += c
    bounds.append(int(line_counts.shape[0]))
    return bounds


class CompiledTrace:
    """Flat, round-indexed lowering of a :class:`Trace` (compiled-trace IR).

    One build replaces the per-policy Python walk over ``core_steps``:
    every round's accesses are pre-merged (MSHR semantics: same-line
    requests of one round collapse to the first occurrence, write intents
    OR-ed across duplicates) and stored in CSR layout — ``round_off[r] :
    round_off[r+1]`` slices the per-line arrays of round ``r``.

    Per unique line and round (arrays of length ``U``):

    * ``u_addrs``      byte address of the line (ascending within a round)
    * ``u_dense``      index into the run's global "seen" bitmap
    * ``u_write``      OR of the write intents of all merged duplicates
    * ``u_force``      tensor-level ``bypass_all``
    * ``u_nonleader``  issuing core (first occurrence) is a gqa non-leader
    * ``u_core``       issuing core of the first occurrence (event-trace
                       attribution; the MSHR merge keeps the first
                       requester, matching the step engine's unique())
    * ``u_tid``        tensor id of the line (event-trace attribution
                       that stays exact when a pooled allocator recycles
                       addresses across generations; same-round
                       duplicates of one line always belong to one
                       tensor, so the first occurrence is exact)
    * ``u_dups``       duplicates merged away into this line (MSHR-hit
                       accounting, attributable per tenant)

    Per round: ``n_acc_round`` (pre-merge request count, for MSHR-hit
    accounting) and ``flops_round``.  The TLL feed for the TMU is a second
    CSR block (``tll_*``) holding pre-resolved (tensor, tile, nAcc) per
    tile-last-line access, in issue order.

    Cache-geometry-dependent state (set indices, same-set pass splitting)
    is *not* baked in; :meth:`plans_for` computes it per geometry and
    caches it so every policy of a sweep reuses it.
    """

    def __init__(self, line_bytes: int, n_rounds: int, n_seen_lines: int,
                 u_addrs, u_dense, u_write, u_force, u_nonleader, u_core,
                 u_tid, u_dups, round_off, n_acc_round, flops_round,
                 tll_addrs, tll_tids, tll_tiles, tll_nacc, tll_off):
        self.line_bytes = line_bytes
        self.n_rounds = n_rounds
        self.n_seen_lines = n_seen_lines
        self.u_addrs = u_addrs
        self.u_dense = u_dense
        self.u_write = u_write
        self.u_force = u_force
        self.u_nonleader = u_nonleader
        self.u_core = u_core          # first requester (event attribution)
        self.u_tid = u_tid            # owning tensor (exact under reuse)
        self.u_dups = u_dups          # merged-away duplicates per line
        self.round_off = round_off
        self.n_acc_round = n_acc_round
        self.flops_round = flops_round
        self.tll_addrs = tll_addrs
        self.tll_tids = tll_tids
        self.tll_tiles = tll_tiles
        self.tll_nacc = tll_nacc
        self.tll_off = tll_off
        # artifact-cache key ("<spec-fingerprint>-lb<N>") when this
        # lowering came from a fingerprinted trace; lets plans_for
        # persist its geometry plans too
        self.cache_key: Optional[str] = None
        self._plans: Dict[Tuple[int, bool], list] = {}
        self._tll_tags: Dict[int, np.ndarray] = {}

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, trace: Trace, line_bytes: int, round_start: int = 0,
              round_stop: Optional[int] = None,
              dense_map: Optional[Dict[int, int]] = None,
              n_seen_lines: Optional[int] = None) -> "CompiledTrace":
        """Lower ``trace`` (or the round window ``[round_start,
        round_stop)`` of it) to the flat CSR arrays.

        A window build touches only the step records of its own rounds —
        the streaming path — and is bit-identical to the same rounds of
        the monolithic lowering: the MSHR merge and the lexsort both
        group by round, so no array element ever crosses a round
        boundary.  The dense seen-bitmap layout stays global
        (``n_seen_lines`` covers every tensor) so one bitmap spans all
        segments of a run.

        ``dense_map``/``n_seen_lines`` override the per-tensor dense
        offsets: the generator-driven replay lowering
        (``repro.dataflows.stream``) recycles retired tensors' bitmap
        ranges, so its offsets come from an external allocator instead
        of the cumulative layout below (which would grow with every
        tensor ever declared).
        """
        if round_stop is None:
            round_stop = trace.n_rounds
        n_rounds = round_stop - round_start
        tensors = trace.tensors
        tr_lb = trace.line_bytes

        # dense "seen"-bitmap layout: one contiguous range per tensor
        if dense_map is not None:
            dense_off = dense_map
            n_seen = int(n_seen_lines)
        else:
            dense_off = {}
            n_seen = 0
            for tid, m in tensors.items():
                dense_off[tid] = n_seen
                n_seen += m.size_bytes // line_bytes

        # one record per bulk tile transfer (expanded to lines vectorized)
        p_round: List[int] = []
        p_start: List[int] = []      # first line's byte address
        p_k: List[int] = []          # lines in the tile
        p_dense0: List[int] = []     # first line's dense seen index
        p_write: List[bool] = []
        p_force: List[bool] = []
        p_nonlead: List[bool] = []
        p_core: List[int] = []
        p_tid: List[int] = []
        t_round: List[int] = []      # TLL feed, in issue order
        t_addr: List[int] = []
        t_tid: List[int] = []
        t_tile: List[int] = []
        t_nacc: List[int] = []
        flops_round = np.zeros(n_rounds, dtype=np.float64)

        nonleader = [not ldr for ldr in trace.core_is_leader]
        for r in range(round_start, round_stop):
            rloc = r - round_start          # window-relative round index
            for c, steps in enumerate(trace.core_steps):
                if r >= len(steps):
                    continue
                step = steps[r]
                flops_round[rloc] += step.flops
                for (tid, tile), is_store in (
                        [(ld, False) for ld in step.loads]
                        + [(s, True) for s in step.stores]):
                    meta = tensors[tid]
                    start = meta.base_addr + tile * meta.tile_bytes
                    p_round.append(rloc)
                    p_start.append(start)
                    p_k.append(meta.tile_bytes // tr_lb)
                    p_dense0.append(dense_off[tid]
                                    + (start - meta.base_addr) // line_bytes)
                    p_write.append(is_store)
                    p_force.append(meta.bypass_all)
                    p_nonlead.append(nonleader[c])
                    p_core.append(c)
                    p_tid.append(tid)
                    if not is_store and not meta.bypass_all:
                        t_round.append(rloc)
                        t_addr.append(meta.tile_last_line(tile, line_bytes))
                        t_tid.append(tid)
                        t_tile.append(tile)
                        t_nacc.append(meta.n_acc)

        k = np.asarray(p_k, dtype=np.int64)
        n_acc_total = int(k.sum()) if k.size else 0
        if n_acc_total:
            # expand tile records to per-line arrays (CSR expansion)
            rep = np.repeat(np.arange(k.size), k)
            within = np.arange(n_acc_total) - np.repeat(
                np.concatenate(([0], np.cumsum(k)[:-1])), k)
            a_round = np.asarray(p_round, dtype=np.int64)[rep]
            a_addr = (np.asarray(p_start, dtype=np.int64)[rep]
                      + within * tr_lb)
            a_dense = np.asarray(p_dense0, dtype=np.int64)[rep] + within
            a_write = np.asarray(p_write, dtype=bool)[rep]
            a_force = np.asarray(p_force, dtype=bool)[rep]
            a_nonlead = np.asarray(p_nonlead, dtype=bool)[rep]
            a_core = np.asarray(p_core, dtype=np.int64)[rep]
            a_tid = np.asarray(p_tid, dtype=np.int64)[rep]

            # per-round MSHR merge: stable sort by (round, addr); the first
            # element of each (round, addr) run is the first occurrence in
            # issue order, so seen/force/nonleader take its values while
            # write intent ORs over the whole run.
            order = np.lexsort((a_addr, a_round))
            s_round = a_round[order]
            s_addr = a_addr[order]
            starts = np.ones(n_acc_total, dtype=bool)
            starts[1:] = (s_round[1:] != s_round[:-1]) \
                | (s_addr[1:] != s_addr[:-1])
            start_idx = np.nonzero(starts)[0]
            u_addrs = s_addr[start_idx]
            u_round = s_round[start_idx]
            u_dense = a_dense[order][start_idx]
            u_force = a_force[order][start_idx]
            u_nonleader = a_nonlead[order][start_idx]
            u_core = a_core[order][start_idx]
            u_tid = a_tid[order][start_idx]
            u_write = np.maximum.reduceat(
                a_write[order].astype(np.int8), start_idx).astype(bool)
            u_dups = np.diff(np.append(start_idx, n_acc_total)) - 1
            round_off = np.searchsorted(u_round,
                                        np.arange(n_rounds + 1))
            n_acc_round = np.bincount(a_round, minlength=n_rounds)
        else:
            u_addrs = u_dense = np.empty(0, dtype=np.int64)
            u_write = u_force = u_nonleader = np.empty(0, dtype=bool)
            u_core = np.empty(0, dtype=np.int64)
            u_tid = np.empty(0, dtype=np.int64)
            u_dups = np.empty(0, dtype=np.int64)
            round_off = np.zeros(n_rounds + 1, dtype=np.int64)
            n_acc_round = np.zeros(n_rounds, dtype=np.int64)

        tll_off = np.concatenate((
            [0], np.cumsum(np.bincount(np.asarray(t_round, dtype=np.int64),
                                       minlength=n_rounds))
        )).astype(np.int64)
        return cls(
            line_bytes, n_rounds, n_seen,
            u_addrs, u_dense, u_write, u_force, u_nonleader, u_core,
            u_tid, u_dups,
            round_off.astype(np.int64), n_acc_round.astype(np.int64),
            flops_round,
            np.asarray(t_addr, dtype=np.int64),
            np.asarray(t_tid, dtype=np.int64),
            np.asarray(t_tile, dtype=np.int64),
            np.asarray(t_nacc, dtype=np.int64),
            tll_off,
        )

    # ------------------------------------------------------------------
    def slice_rounds(self, round_start: int,
                     round_stop: int) -> "CompiledTrace":
        """Zero-copy round-window view: every per-line array is grouped
        by round, so a segment is literally a slice of the monolithic
        arrays with the CSR offsets rebased.  Used by
        :meth:`Trace.compiled_segments` when the full lowering is
        already cached."""
        a0 = int(self.round_off[round_start])
        a1 = int(self.round_off[round_stop])
        t0 = int(self.tll_off[round_start])
        t1 = int(self.tll_off[round_stop])
        return CompiledTrace(
            self.line_bytes, round_stop - round_start, self.n_seen_lines,
            self.u_addrs[a0:a1], self.u_dense[a0:a1], self.u_write[a0:a1],
            self.u_force[a0:a1], self.u_nonleader[a0:a1],
            self.u_core[a0:a1], self.u_tid[a0:a1], self.u_dups[a0:a1],
            self.round_off[round_start:round_stop + 1] - a0,
            self.n_acc_round[round_start:round_stop],
            self.flops_round[round_start:round_stop],
            self.tll_addrs[t0:t1], self.tll_tids[t0:t1],
            self.tll_tiles[t0:t1], self.tll_nacc[t0:t1],
            self.tll_off[round_start:round_stop + 1] - t0,
        )

    # ------------------------------------------------------------------
    def tll_tags_for(self, geom) -> np.ndarray:
        """Cache tags of the TLL feed for one geometry, cached like
        :meth:`plans_for` so a policy sweep computes them once."""
        tags = self._tll_tags.get(geom.num_sets)
        if tags is None:
            tags = (self.tll_addrs // self.line_bytes) // geom.num_sets
            self._tll_tags[geom.num_sets] = tags
        return tags

    # ------------------------------------------------------------------
    def plans_for(self, geom) -> list:
        """Per-round :class:`~repro.core.cache.AccessPlan` list for one
        cache geometry (set mapping + same-set pass splitting), cached so
        every policy of a sweep shares it.  Entries are ``None`` for empty
        rounds."""
        key = (geom.num_sets, geom.hash_sets)
        plans = self._plans.get(key)
        if plans is not None:
            return plans
        from .cache import AccessPlan

        sets_all = geom.set_of(self.u_addrs)
        tags_all = geom.tag_of(self.u_addrs)
        n = self.u_addrs.shape[0]
        pk = None
        pass_idx = None
        if self.cache_key is not None:
            from repro.dataflows import artifacts
            pk = artifacts.plan_key(self.cache_key, geom.num_sets,
                                    geom.hash_sets)
            pass_idx = artifacts.load_plan_pass_idx(pk)
            if pass_idx is not None and pass_idx.shape[0] != n:
                pass_idx = None
        if pass_idx is None:
            u_round = np.repeat(np.arange(self.n_rounds),
                                np.diff(self.round_off))
            # occurrence rank of each line's set within its round
            # (stable): rank k goes into same-set pass k, replicating
            # access_burst
            order = np.lexsort((sets_all, u_round))
            s_round = u_round[order]
            s_sets = sets_all[order]
            starts = np.ones(n, dtype=bool)
            if n:
                starts[1:] = (s_round[1:] != s_round[:-1]) \
                    | (s_sets[1:] != s_sets[:-1])
            run_start = np.maximum.accumulate(
                np.where(starts, np.arange(n), 0))
            pass_sorted = np.arange(n) - run_start
            pass_idx = np.empty(n, dtype=np.int64)
            pass_idx[order] = pass_sorted
            if pk is not None:
                from repro.dataflows import artifacts
                artifacts.store_plan_pass_idx(pk, pass_idx)

        plans = []
        for r in range(self.n_rounds):
            a0, a1 = self.round_off[r], self.round_off[r + 1]
            if a0 == a1:
                plans.append(None)
                continue
            pi = pass_idx[a0:a1]
            mp = int(pi.max())
            passes = None if mp == 0 else [
                np.nonzero(pi == p)[0] for p in range(mp + 1)]
            plans.append(AccessPlan(self.u_addrs[a0:a1], sets_all[a0:a1],
                                    passes, tags_all[a0:a1]))
        self._plans[key] = plans
        return plans


# ---------------------------------------------------------------------------
# Dataflow builders: thin wrappers over the declarative IR (DESIGN.md §8).
# The hand-written builders these entry points used to contain live on as
# IR spec builders in ``repro.dataflows``; tests/test_dataflow_ir.py pins
# the lowered traces bit-identical to the pre-refactor implementations.
# ---------------------------------------------------------------------------
def build_fa2_trace(wl: AttnWorkload, n_cores: int = 16) -> Trace:
    """FlashAttention-2 GQA trace (temporal or spatial group allocation,
    §VI-C; multi-batch for the §VI-F DBP scenario)."""
    from repro.dataflows import fa2_spec, lower_to_trace
    return lower_to_trace(fa2_spec(wl, n_cores))


def build_matmul_trace(m: int, n: int, k: int, tile: int = 128,
                       n_cores: int = 16, dtype_bytes: int = 1) -> Trace:
    """Tiled MatMul trace of Fig. 2(a), C-tiles round-robin over cores."""
    from repro.dataflows import lower_to_trace, matmul_spec
    return lower_to_trace(matmul_spec(m, n, k, tile=tile, n_cores=n_cores,
                                      dtype_bytes=dtype_bytes))


# ---------------------------------------------------------------------------
# Closed-form dataflow counts (consumed by the analytical model, §V)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DataflowCounts:
    """All quantities of Eq. 1–3 that derive from the dataflow alone."""

    name: str
    line_bytes: int
    # line-granular request counts, totalled over all cores:
    n_kv_accesses: int          # total K/V line requests (reuse carriers)
    n_kv_distinct: int          # distinct K/V lines (cold misses)
    n_bypass_lines: int         # Q/O traffic (always DRAM, bursty)
    n_intercore_reuse: int      # K/V requests that are inter-core reuses
    s_work_active: int          # active working set, bytes (KV of live groups)
    s_work_total: int           # all K/V bytes of one batch
    flops_total: float
    n_batches: int
    n_rounds: int               # lockstep rounds (scheduling overhead term)
    # IR-derived reuse-distance profile (repro.dataflows.reuse), consumed
    # by the analytical model's ``model="profile"`` path.  Excluded from
    # equality so counts stay pinnable against the frozen closed-form
    # oracles; None when the producer skipped the schedule walk (the
    # model then falls back to the §V-C closed forms).
    reuse_profile: Optional[object] = field(default=None, compare=False,
                                            repr=False)

    @property
    def n_temporal_reuse(self) -> int:
        """K/V requests that revisit a line already streamed this pass."""
        return self.n_kv_accesses - self.n_kv_distinct - self.n_intercore_reuse


def fa2_counts(wl: AttnWorkload, n_cores: int = 16,
               with_profile: bool = False) -> DataflowCounts:
    """Closed-form FA2 request counts, derived from the same IR spec the
    trace is lowered from (pinned bit-identical to the former hand-kept
    formula by tests/test_dataflow_ir.py).

    ``with_profile`` additionally attaches the reuse-distance profile
    (``model="profile"`` input).  Off by default here: this historical
    entry point feeds the closed-form figure sweeps, some at
    long-context shapes where the schedule walk is not free — the IR
    path (``repro.dataflows.lower_to_counts``) attaches it by default.
    """
    from repro.dataflows import fa2_spec, lower_to_counts
    return lower_to_counts(fa2_spec(wl, n_cores), with_profile=with_profile)

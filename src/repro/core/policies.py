"""Replacement / bypass policy configuration for the DCO shared LLC.

The paper composes three mechanisms (§IV):

* ``dbp``      dead-block prediction (victimize TMU-predicted dead lines first)
* ``at``       self-adaptive anti-thrashing (evict the lowest
               ``tag[B_BITS-1:0]`` tier first; ties → LRU)
* bypassing    on a miss, lines with ``tag[B_BITS-1:0] < B_GEAR`` are not
               allocated.  Variants: static gear (fix1/fix2/fix3), dynamic
               (per-slice eviction-rate feedback), and ``gqa_bypass`` (only
               the slower core of a sharing pair bypasses, and only under
               high contention).

Named policies used throughout the paper's figures are exposed through
:func:`named_policy` (``lru``, ``at``, ``dbp``, ``at+dbp``, ``lru+bypass``,
``at+bypass``, ``all``, ``fix1`` …).
"""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import replace
from typing import Optional

import numpy as np

BYPASS_NONE = "none"
BYPASS_STATIC = "static"
BYPASS_DYNAMIC = "dynamic"


@dataclass(frozen=True)
class PolicyConfig:
    """A full replacement+bypass policy configuration."""

    dbp: bool = False
    at: bool = False
    bypass: str = BYPASS_NONE           # none | static | dynamic
    gqa_variant: bool = False           # conservative inter-core-sharing variant
    b_bits: int = 3                     # priority tiers = 2**b_bits
    b_gear: int = 0                     # initial (static: fixed) gear
    # dynamic-gear feedback (evictions per window per slice):
    window_cycles: int = 4096
    bypass_ub: float = 0.12             # eviction rate upper bound → gear++
    bypass_lb: float = 0.05             # eviction rate lower bound → gear--
    # gear decrease only after this many consecutive low-rate windows
    # (fast-up / slow-down hysteresis: over-bypassing shows up as a rate
    # cliff at the optimal gear, so probing down must be gentle)
    down_streak: int = 4
    # gqa_bypass: contention level (eviction rate) above which the slower
    # core of a sharing pair starts bypassing.
    gqa_contention_threshold: float = 0.30
    # multi-tenant composites only (DESIGN.md §8.4): run one gear
    # feedback loop per tenant's address region instead of one global
    # law — each tenant's eviction rate moves only that tenant's gear,
    # and a line's bypass decision consults its own tenant's gear.
    # Ignored (bit-identical to the global controller) on traces that
    # carry no tenant map.
    per_tenant_gears: bool = False

    def __post_init__(self) -> None:
        if self.bypass not in (BYPASS_NONE, BYPASS_STATIC, BYPASS_DYNAMIC):
            raise ValueError(f"unknown bypass mode {self.bypass!r}")
        if not (0 <= self.b_gear <= (1 << self.b_bits)):
            raise ValueError("B_GEAR must lie in [0, 2**B_BITS]")

    @property
    def name(self) -> str:
        parts = []
        parts.append("at" if self.at else "lru")
        if self.bypass != BYPASS_NONE:
            suffix = "gqa_bypass" if self.gqa_variant else "bypass"
            if self.bypass == BYPASS_STATIC:
                suffix += f"[gear={self.b_gear}]"
            parts.append(suffix)
        if self.dbp:
            parts.append("dbp")
        return "+".join(parts)


def named_policy(name: str, *, b_bits: int = 3, gqa: bool = False,
                 **overrides) -> PolicyConfig:
    """Resolve the policy names used in the paper's figures.

    ``gqa=True`` selects the conservative gqa_bypass variant for any policy
    that bypasses (the paper always uses it for spatial group allocation).
    """
    base = dict(b_bits=b_bits, gqa_variant=gqa)
    presets = {
        "lru": dict(),
        "at": dict(at=True),
        "dbp": dict(dbp=True),
        "at+dbp": dict(at=True, dbp=True),
        "lru+bypass": dict(bypass=BYPASS_DYNAMIC),
        "at+bypass": dict(at=True, bypass=BYPASS_DYNAMIC),
        "bypass+dbp": dict(bypass=BYPASS_DYNAMIC, dbp=True),
        "all": dict(at=True, bypass=BYPASS_DYNAMIC, dbp=True),
    }
    if name in presets:
        cfg = dict(base, **presets[name])
    elif name.startswith("fix"):
        # fixN: static gear, ascending aggressiveness; at always enabled
        # (the paper evaluates bypassing with at on, §VI-E).
        gear = int(name[3:])
        cfg = dict(base, at=True, bypass=BYPASS_STATIC, b_gear=gear)
    else:
        raise KeyError(f"unknown policy {name!r}")
    cfg.update(overrides)
    return PolicyConfig(**cfg)


class GearController:
    """Per-slice dynamic ``B_GEAR`` controller (paper §IV-D).

    Each LLC slice tracks its eviction count over a sliding window of
    cycles.  When the window closes, the eviction *rate* (evictions per
    LLC-access) is compared against ``bypass_ub`` / ``bypass_lb`` and the
    slice's gear moves one step up / down.

    ``n_tenants > 1`` (the opt-in multi-tenant mode, DESIGN.md §8.4)
    runs the identical feedback law independently per tenant: state
    arrays grow a leading tenant axis and ``record`` attributes each
    access to the tenant of the line that issued it, so one tenant's
    thrashing ramps only that tenant's gear.  With one tenant every
    array collapses to the original per-slice shape — bit-identical to
    the single-controller behavior.
    """

    def __init__(self, n_slices: int, cfg: PolicyConfig,
                 n_tenants: int = 1):
        self.cfg = cfg
        self.n_slices = n_slices
        self.n_tenants = n_tenants
        shape = (n_tenants, n_slices) if n_tenants > 1 else (n_slices,)
        self.gear = np.full(shape, cfg.b_gear, dtype=np.int64)
        self._evictions = np.zeros(shape, dtype=np.int64)
        self._accesses = np.zeros(shape, dtype=np.int64)
        self._low_streak = np.zeros(shape, dtype=np.int64)
        self._window_start = 0.0
        self.max_gear = 1 << cfg.b_bits
        # last observed eviction rate (for gqa_bypass contention)
        self.last_rate = np.zeros(shape, dtype=np.float64)
        # opt-in event telemetry (repro.core.events.EventSink): gear
        # transitions are emitted per (tenant, slice) when attached
        self.sink = None

    def _flat(self, slice_ids: np.ndarray,
              tenant_ids: Optional[np.ndarray]) -> np.ndarray:
        if self.n_tenants == 1:
            return slice_ids
        return tenant_ids * self.n_slices + slice_ids

    def record(self, slice_ids: np.ndarray, evicted: np.ndarray,
               tenant_ids: Optional[np.ndarray] = None) -> None:
        flat = self._flat(slice_ids, tenant_ids)
        n = self.gear.size
        self._accesses += np.bincount(flat, minlength=n).reshape(
            self._accesses.shape)
        if evicted.any():
            self._evictions += np.bincount(
                flat[evicted], minlength=n).reshape(self._evictions.shape)

    def gears_at(self, slice_ids: np.ndarray,
                 tenant_ids: Optional[np.ndarray] = None) -> np.ndarray:
        if self.n_tenants == 1 or tenant_ids is None:
            gear = self.gear if self.gear.ndim == 1 else self.gear[0]
            return gear[slice_ids]
        return self.gear[tenant_ids, slice_ids]

    def tick(self, now_cycles: float) -> None:
        elapsed = now_cycles - self._window_start
        if elapsed < self.cfg.window_cycles:
            return
        acc = np.maximum(self._accesses, 1)
        rate = self._evictions / acc
        self.last_rate = rate
        if self.cfg.bypass == BYPASS_DYNAMIC:
            up = rate > self.cfg.bypass_ub
            low = rate < self.cfg.bypass_lb
            self._low_streak = np.where(low, self._low_streak + 1, 0)
            down = self._low_streak >= self.cfg.down_streak
            self._low_streak[down] = 0
            old = self.gear if self.sink is not None else None
            self.gear = np.clip(self.gear + up.astype(np.int64)
                                - down.astype(np.int64), 0, self.max_gear)
            if self.sink is not None:
                changed = np.nonzero(old != self.gear)
                if changed[0].shape[0]:
                    if self.gear.ndim == 1:
                        sl = changed[0]
                        ten = np.zeros_like(sl)
                    else:
                        ten, sl = changed
                    self.sink.emit_gear(sl, ten, self.gear[changed])
        self._evictions[:] = 0
        self._accesses[:] = 0
        # advance in whole window multiples: snapping to now_cycles would
        # let a late tick stretch the next feedback window by the
        # overshoot, skewing the eviction *rate* the gear law compares
        # against its fixed thresholds
        self._window_start += (elapsed // self.cfg.window_cycles) \
            * self.cfg.window_cycles

    def contended(self) -> np.ndarray:
        """Per-slice contention flag used by the gqa_bypass variant."""
        return self.last_rate > self.cfg.gqa_contention_threshold


def make_controller(n_slices: int, cfg: PolicyConfig,
                    n_tenants: int = 1) -> Optional[GearController]:
    if cfg.bypass == BYPASS_NONE:
        return None
    return GearController(
        n_slices, cfg, n_tenants if cfg.per_tenant_gears else 1)


def with_gear(cfg: PolicyConfig, gear: int) -> PolicyConfig:
    return replace(cfg, b_gear=gear)

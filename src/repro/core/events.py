"""Structured event-trace telemetry for the DCO simulator (DESIGN.md §10).

Every engine (step reference, compiled, compiled-streaming) can emit a
canonical per-round event stream — fills, hits, MSHR merges, bypasses,
evictions (victim tag + dead/live verdict), write-backs, gear
transitions, TMU tile retirements — into an :class:`EventSink`.  The
stream is flat int64 columns::

    (round, core, tenant, tensor, set, way, kind, aux)

chosen so that a whole run is a single ``(N, 8)`` matrix: cheap to
append block-wise, to export as npz, to diff, and to hash.  ``-1``
marks "not applicable" (e.g. ``way`` of a bypassed line, ``core`` of a
gear transition).  ``aux`` is kind-specific (see the ``EV_*`` constants
below and the schema table in DESIGN.md §10).

Contracts the conformance harness (``repro.conformance``) builds on:

* **Determinism** — emission is a pure function of the simulated
  state machine; two runs of the same (trace, policy, geometry) produce
  byte-identical streams.
* **Segment concatenation** — the streaming compiled engine emits
  segment by segment into one persistent sink; the raw stream is
  bit-identical to a monolithic compiled run (rounds are atomic and the
  round index is global).
* **Engine agreement** — the step and compiled engines produce the
  same event *multiset* per round; :meth:`EventSink.canonical` imposes
  a total order (lexsort over all columns, round-major) so equality is
  byte-comparable and :meth:`EventSink.digest` is engine-independent.
* **Zero cost when disabled** — every emission site is guarded by a
  ``sink is not None`` check; with tracing off (the default) no event
  work, not even argument marshalling, happens on the hot path
  (``benchmarks/sweep_perf.py`` carries the overhead probe).

``SCHEMA_VERSION`` governs both the digest domain and the golden files
under ``tests/golden/``: any change to the column layout, kind codes,
or aux packing must bump it (and refresh the goldens via
``scripts/conformance.py --update-golden``).
"""

from __future__ import annotations

import hashlib
from typing import Dict
from typing import List
from typing import Optional
from typing import Tuple

import numpy as np

#: bump on any change to columns, kind codes, or aux packing
SCHEMA_VERSION = 1

#: column layout of the event matrix (one row per event)
COLUMNS: Tuple[str, ...] = ("round", "core", "tenant", "tensor", "set",
                            "way", "kind", "aux")

# Event kinds.  aux packing per kind:
#   FILL     aux = 2*tag + seen          (allocated fill; seen => conflict)
#   HIT      aux = 0                     (LLC tag hit)
#   MSHR     aux = merged duplicates     (same-line requests of the round)
#   BYPASS   aux = seen                  (miss not allocated; seen => conflict)
#   EVICT    aux = 2*victim_tag + dead   (dead: TMU dead-FIFO verdict)
#   WB       aux = victim_tag            (dirty victim written back)
#   GEAR     aux = new gear              (set column holds the slice id)
#   RETIRE   aux = tile index            (TMU accCnt reached nAcc)
EV_FILL = 0
EV_HIT = 1
EV_MSHR = 2
EV_BYPASS = 3
EV_EVICT = 4
EV_WB = 5
EV_GEAR = 6
EV_RETIRE = 7

KIND_NAMES: Tuple[str, ...] = ("FILL", "HIT", "MSHR", "BYPASS", "EVICT",
                               "WB", "GEAR", "RETIRE")

_EMPTY = np.empty((0, len(COLUMNS)), dtype=np.int64)


class EventSink:
    """Collects one run's event stream as flat int64 blocks.

    A sink serves exactly one simulation run: ``Simulator`` binds it to
    the run's trace + cache geometry (address → tensor/tenant
    resolution tables), every emission site appends ``(k, 8)`` blocks,
    and the matrix/canonical/digest views concatenate lazily.  Pass a
    fresh sink per run (``Simulator.run(..., events=EventSink())``) or
    set ``SimConfig.trace_events=True`` to have the run create and
    attach one to ``SimResult.events``.
    """

    def __init__(self) -> None:
        self._blocks: List[np.ndarray] = []
        self._round = -1
        self._geom = None
        self._t_starts: Optional[np.ndarray] = None   # tensor base addrs
        self._t_ids: Optional[np.ndarray] = None
        self._ten_starts: Optional[np.ndarray] = None  # tenant region addrs
        self._ten_ids: Optional[np.ndarray] = None
        self._tenant_by_tid: Dict[int, int] = {}
        self._live_regions: Dict[int, Tuple[int, int]] = {}  # tid -> [s, e)
        self._matrix: Optional[np.ndarray] = None

    # -- binding --------------------------------------------------------
    def bind(self, trace, geom) -> None:
        """Attach the run's address-resolution tables (idempotent for
        the same trace; the streaming engine binds once per run)."""
        self._geom = geom
        starts = sorted((m.base_addr, tid)
                        for tid, m in trace.tensors.items())
        self._t_starts = np.asarray([s for s, _ in starts], dtype=np.int64)
        self._t_ids = np.asarray([t for _, t in starts], dtype=np.int64)
        regions = trace.tenant_region_starts()
        if regions is not None:
            self._ten_starts, self._ten_ids = regions
            self._tenant_by_tid = dict(trace.tenant_of_tensor)
        else:
            self._ten_starts = None
            self._tenant_by_tid = {}

    def register_tensors(self, metas, *, retiring_tids=None) -> None:
        """Register tensors that join the run mid-stream (the serving
        replay registers at request admission).

        Allocator-aware liveness check: a new tensor's ``[base, end)``
        must not overlap any *live* region — addresses may recur across
        generations (a pooled allocator recycles retired regions), but
        never while the previous owner is still live.  The error names
        the offending tensor, its base, and the live region it collides
        with.  ``retiring_tids`` lists tensors this same segment also
        clears (declared *and* retired within one window): their regions
        may already have been recycled in-window, so they are exempt as
        overlap targets.  ``release_tensors`` removes regions when the
        engine clears them.

        The address-resolution fallback table (used only by emissions
        that do not carry explicit tensor ids) is kept sorted: the
        monotone bump case appends; recycled bases re-sort, with the
        newest generation winning a base collision.
        """
        new = sorted((m.base_addr, m.tensor_id, m.size_bytes)
                     for m in metas)
        if not new:
            return
        exempt = set(retiring_tids) if retiring_tids else set()
        for base, tid, size in new:
            end = base + size
            for lt, (ls, le) in self._live_regions.items():
                if lt == tid or lt in exempt:
                    continue
                if base < le and ls < end:
                    raise ValueError(
                        f"register_tensors: tensor {tid} at base "
                        f"0x{base:x} ([0x{base:x}, 0x{end:x})) overlaps "
                        f"the live region [0x{ls:x}, 0x{le:x}) of tensor "
                        f"{lt} — the allocator handed out an address "
                        f"range whose previous owner has not been "
                        f"released")
            self._live_regions[tid] = (base, end)
        starts = np.asarray([s for s, _, _ in new], dtype=np.int64)
        tids = np.asarray([t for _, t, _ in new], dtype=np.int64)
        if self._t_starts is None or self._t_starts.shape[0] == 0:
            self._t_starts, self._t_ids = starts, tids
            return
        if starts[0] > self._t_starts[-1]:
            self._t_starts = np.concatenate([self._t_starts, starts])
            self._t_ids = np.concatenate([self._t_ids, tids])
            return
        merged = dict(zip(self._t_starts.tolist(), self._t_ids.tolist()))
        merged.update(zip(starts.tolist(), tids.tolist()))
        pairs = sorted(merged.items())
        self._t_starts = np.asarray([s for s, _ in pairs], dtype=np.int64)
        self._t_ids = np.asarray([t for _, t in pairs], dtype=np.int64)

    def release_tensors(self, tids) -> None:
        """Drop cleared tensors from the live-region map so a recycling
        allocator may hand their addresses out again."""
        for tid in tids:
            self._live_regions.pop(int(tid), None)

    def begin_round(self, round_idx: int) -> None:
        self._round = round_idx

    # -- address resolution ---------------------------------------------
    def _tensor_of(self, addrs: np.ndarray) -> np.ndarray:
        idx = np.searchsorted(self._t_starts, addrs, side="right") - 1
        return self._t_ids[np.maximum(idx, 0)]

    def _tenant_of(self, addrs: np.ndarray) -> np.ndarray:
        if self._ten_starts is None:
            return np.zeros(addrs.shape[0], dtype=np.int64)
        idx = np.searchsorted(self._ten_starts, addrs, side="right") - 1
        return self._ten_ids[np.maximum(idx, 0)]

    # -- emission -------------------------------------------------------
    def emit_lines(self, kind: int, addrs: np.ndarray, sets=None,
                   ways=None, cores=None, aux=None, tensors=None) -> None:
        """Append one block of per-line events.  ``sets=None`` derives
        the set index from the bound geometry; ``ways``/``cores``/``aux``
        default to -1 / -1 / 0.  ``tensors`` carries exact per-line
        tensor ids from the engine (required for correct attribution
        when a pooled allocator recycles addresses across generations);
        ``None`` falls back to address resolution, which is exact for
        unique-address (bump) layouts."""
        k = addrs.shape[0]
        if k == 0:
            return
        mat = np.empty((k, len(COLUMNS)), dtype=np.int64)
        mat[:, 0] = self._round
        mat[:, 1] = -1 if cores is None else cores
        mat[:, 2] = self._tenant_of(addrs)
        mat[:, 3] = self._tensor_of(addrs) if tensors is None else tensors
        mat[:, 4] = self._geom.set_of(addrs) if sets is None else sets
        mat[:, 5] = -1 if ways is None else ways
        mat[:, 6] = kind
        mat[:, 7] = 0 if aux is None else aux
        self._blocks.append(mat)
        self._matrix = None

    def emit_gear(self, slice_ids: np.ndarray, tenant_ids: np.ndarray,
                  gears: np.ndarray) -> None:
        k = slice_ids.shape[0]
        if k == 0:
            return
        mat = np.full((k, len(COLUMNS)), -1, dtype=np.int64)
        mat[:, 0] = self._round
        mat[:, 2] = tenant_ids
        mat[:, 4] = slice_ids
        mat[:, 6] = EV_GEAR
        mat[:, 7] = gears
        self._blocks.append(mat)
        self._matrix = None

    def emit_retire(self, tensor_ids, tile_idxs) -> None:
        tensor_ids = np.asarray(tensor_ids, dtype=np.int64)
        k = tensor_ids.shape[0]
        if k == 0:
            return
        mat = np.full((k, len(COLUMNS)), -1, dtype=np.int64)
        mat[:, 0] = self._round
        if self._tenant_by_tid:
            mat[:, 2] = [self._tenant_by_tid.get(int(t), 0)
                         for t in tensor_ids]
        else:
            mat[:, 2] = 0
        mat[:, 3] = tensor_ids
        mat[:, 6] = EV_RETIRE
        mat[:, 7] = np.asarray(tile_idxs, dtype=np.int64)
        self._blocks.append(mat)
        self._matrix = None

    # -- views ----------------------------------------------------------
    def matrix(self) -> np.ndarray:
        """The raw event stream in emission order, shape ``(N, 8)``.
        This is the view the streaming-concatenation contract is stated
        over (segments append in round order)."""
        if self._matrix is None:
            self._matrix = (np.concatenate(self._blocks)
                            if self._blocks else _EMPTY.copy())
        return self._matrix

    def canonical(self) -> np.ndarray:
        """Engine-independent total order: lexsort over every column,
        round-major — two engines that agree on the per-round event
        multiset produce byte-identical canonical matrices."""
        return canonical_order(self.matrix())

    def digest(self) -> str:
        """SHA-256 of the canonical stream under the schema version —
        the value frozen in the golden files."""
        return stream_digest(self.canonical())

    def counts_by_kind(self) -> Dict[str, int]:
        m = self.matrix()
        c = np.bincount(m[:, 6], minlength=len(KIND_NAMES))
        return {KIND_NAMES[i]: int(c[i]) for i in range(len(KIND_NAMES))}

    def __len__(self) -> int:
        return int(self.matrix().shape[0])

    def to_npz(self, path) -> None:
        """Export the raw stream (one array per column + schema tag)."""
        m = self.matrix()
        arrays = {name: m[:, i] for i, name in enumerate(COLUMNS)}
        arrays["schema_version"] = np.asarray([SCHEMA_VERSION],
                                              dtype=np.int64)
        np.savez(path, **arrays)


# ---------------------------------------------------------------------------
# free functions shared with the conformance harness
# ---------------------------------------------------------------------------
def canonical_order(mat: np.ndarray) -> np.ndarray:
    """Sort an event matrix into the canonical total order (round-major,
    then kind, set, way, tensor, tenant, core, aux)."""
    if mat.shape[0] == 0:
        return mat
    order = np.lexsort((mat[:, 7], mat[:, 1], mat[:, 2], mat[:, 3],
                        mat[:, 5], mat[:, 4], mat[:, 6], mat[:, 0]))
    return mat[order]


def stream_digest(mat: np.ndarray) -> str:
    """Deterministic digest of an event matrix (callers pass the
    canonical order for the engine-independent value)."""
    h = hashlib.sha256()
    h.update(b"dco-events-v%d;" % SCHEMA_VERSION)
    h.update(np.ascontiguousarray(mat, dtype=np.int64).tobytes())
    return h.hexdigest()


def decode_event(row) -> str:
    """One event as a human-readable line (trace_dump / divergence
    reports)."""
    r, core, tenant, tensor, set_, way, kind, aux = (int(x) for x in row)
    name = KIND_NAMES[kind] if 0 <= kind < len(KIND_NAMES) else f"?{kind}"
    base = f"round={r:<6d} {name:7s}"
    if kind in (EV_FILL, EV_EVICT):
        extra = (f"tag={aux >> 1} "
                 + ("conflict" if aux & 1 else "cold")
                 if kind == EV_FILL else
                 f"victim_tag={aux >> 1} {'dead' if aux & 1 else 'live'}")
        return (f"{base} set={set_} way={way} core={core} tenant={tenant} "
                f"tensor={tensor} {extra}")
    if kind == EV_WB:
        return (f"{base} set={set_} way={way} core={core} tenant={tenant} "
                f"tensor={tensor} victim_tag={aux}")
    if kind == EV_HIT:
        return (f"{base} set={set_} way={way} core={core} tenant={tenant} "
                f"tensor={tensor}")
    if kind == EV_MSHR:
        return (f"{base} set={set_} core={core} tenant={tenant} "
                f"tensor={tensor} merged_dups={aux}")
    if kind == EV_BYPASS:
        return (f"{base} set={set_} core={core} tenant={tenant} "
                f"tensor={tensor} {'conflict' if aux else 'cold'}")
    if kind == EV_GEAR:
        return f"{base} slice={set_} tenant={tenant} gear={aux}"
    if kind == EV_RETIRE:
        return f"{base} tensor={tensor} tenant={tenant} tile={aux}"
    return (f"{base} core={core} tenant={tenant} tensor={tensor} "
            f"set={set_} way={way} aux={aux}")


def timeline_digest(timeline: Dict[str, np.ndarray]) -> str:
    """Deterministic digest of a ``SimResult.timeline`` dict (key-sorted
    dtype/shape/bytes) — the per-scenario value suite_bench records."""
    h = hashlib.sha256()
    h.update(b"dco-timeline-v%d;" % SCHEMA_VERSION)
    for key in sorted(timeline):
        a = np.ascontiguousarray(timeline[key])
        h.update(key.encode())
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()

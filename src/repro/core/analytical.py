"""Cache-integrated analytical model (paper §V + DESIGN.md §5).

Predicts execution time for a dataflow with no simulation in the loop.
Two hit-estimation engines share the paper's Eq. 1–5 time machinery:

* ``model="profile"`` (default) — evaluates the IR-derived
  reuse-distance profile (``repro.dataflows.reuse``,
  ``DataflowCounts.reuse_profile``).  Every cache mechanism is a small
  *transform of the profile* and the hit mass is the reuse mass whose
  transformed distance fits the effective capacity — one evaluation
  path for all policies (DESIGN.md §5):

  - **DBP** removes dead-epoch pollution: distance drops from
    ``d_live + d_dead`` to ``d_live``.
  - **Anti-thrashing** partitions reuse mass into the hardware's
    ``2^B_BITS`` ``tag``-derived priority tiers and protects the top
    tiers whose footprint fits; unprotected mass competes for the
    remaining capacity with correspondingly shrunk distances.
  - **Bypass gear g** deletes the lowest ``g`` tiers' mass (their
    reuses miss — including inter-core reuses, the §IV-E failure mode)
    and shrinks everyone else's distances by the deleted fraction;
    dynamic bypassing replays the §IV-D feedback law window by window
    (:func:`gear_trajectory`) and charges each round at its transient
    gear instead of assuming the converged one.
  - **Dirty lifetimes**: a stored tile writes back when it is evicted
    while dirty.  P(dirty) chains along each tile's access sequence
    (store → dirty; miss → the eviction wrote it back and reloads
    clean; hit → dirty persists) and still-dirty tiles age out via
    their tail distance — the same distance-vs-capacity rule as hits,
    so every mechanism's effect on write-back volume falls out of its
    profile transform.
  - MSHR-merge mass (distance 0) always hits, under every policy.

* ``model="closed"`` — the original §V-C scalar step functions
  (``kept_fraction``), kept bit-identical as the fallback for counts
  that carry no profile and as the frozen-oracle baseline.

Shared time structure (both engines):

* Eq. 1: each request class is bottlenecked by the slowest of
  {core LSU issue, LLC throughput, DRAM bandwidth}.
* Eq. 2: ``t = t_hit + t_cold + max(t_comp, t_cf)`` — cold misses are
  bursty and exposed; conflict misses are dispersed and overlap with
  compute.  The profile engine applies Eq. 2 at the simulator's own
  time quantum (per lockstep round, DESIGN.md §7.2); the closed engine
  applies it once globally.
* Eq. 3–5: conflict-miss bandwidth from the demand rate ``v_cf,dmd``
  with fitted constants θ1, θ2, θ3, λ (per hardware/policy family,
  §V-D).
"""

from __future__ import annotations

from dataclasses import dataclass
from itertools import product
import math
from typing import Dict
from typing import List
from typing import Optional
from typing import Sequence
from typing import Tuple

import numpy as np

from .simulator import SimConfig
from .traces import DataflowCounts

MODEL_POLICIES = ("lru", "dbp", "at+dbp", "bypass+dbp", "all")
BYPASS_VARIANTS = ("fix1", "fix3", "optimal")
#: every policy name either hit engine resolves (superset of the paper's
#: figure set; the simulator's named_policy uses the same vocabulary)
_KNOWN_POLICIES = ("lru", "at", "dbp", "at+dbp", "lru+bypass", "at+bypass",
                   "bypass+dbp", "all")


@dataclass(frozen=True)
class ModelParams:
    """Fitted constants of Eq. 4–5 (+ per-round scheduling overhead)."""

    theta1: float = 0.90      # cold-burst DRAM efficiency
    theta2: float = 0.25      # conflict-miss bandwidth floor (×BW)
    theta3: float = 0.65      # conflict-miss bandwidth ceiling (×BW)
    lam: float = 1.00         # demand-rate scale λ
    round_overhead: float = 8.0


@dataclass(frozen=True)
class Prediction:
    cycles: float
    t_hit: float
    t_cold: float
    t_cf: float
    t_comp: float
    n_hit: float
    n_cold: float
    n_cf: float
    kept_fraction: float
    #: predicted dirty-eviction (write-back) line volume; the profile
    #: engine's dirty-lifetime model fills it, the closed forms carry no
    #: write-back term and leave it 0
    n_wb: float = 0.0
    #: per-tenant breakdowns on multi-tenant composite profiles
    #: (DESIGN.md §8.4), ordered like the profile's ``tenant_names``:
    #: hit / miss (cold + conflict, incl. bypass traffic) / write-back
    #: line masses.  ``None`` on single-tenant predictions and the
    #: closed forms.
    n_hit_tenant: Optional[Tuple[float, ...]] = None
    n_miss_tenant: Optional[Tuple[float, ...]] = None
    n_wb_tenant: Optional[Tuple[float, ...]] = None


# ---------------------------------------------------------------------------
# §V-C: kept-fraction closed forms
# ---------------------------------------------------------------------------
def kept_fraction(policy: str, s_work: float, s_llc: float, assoc: int,
                  b_bits: int = 3, bypass_variant: str = "optimal",
                  gqa: bool = False, pollution: float = 1.0) -> float:
    """Fraction of the streaming working set whose reuses hit.

    ``pollution`` scales the effective cache size down (dead data from
    retired batches, §VI-F) — 1.0 with DBP, 1/n_batches without.
    """
    if s_work <= 0:
        return 1.0
    s_eff_at = s_llc * (assoc - 1) / assoc * pollution
    s_eff_full = s_llc * pollution
    tiers = 1 << b_bits

    def at_fraction(work: float, cap: float) -> float:
        if work <= cap:
            return 1.0
        m = int(cap / (work / tiers))
        return min(m, tiers) / tiers

    if policy == "lru":
        return 1.0 if s_work <= s_eff_at else 0.0
    if policy == "dbp":
        # clean separation between adjacent working sets → full cache usable
        return 1.0 if s_work <= s_eff_full else 0.0
    if policy == "at+dbp" or policy == "at":
        return at_fraction(s_work, s_eff_at)
    if policy in ("bypass+dbp", "lru+bypass", "at+bypass", "all"):
        if gqa:
            # conservative gqa_bypass pins nothing beyond LRU behavior
            # (paper Fig. 10 d–f: bypass+dbp ≈ 1.0 under inter-core sharing)
            extra = 1.0 if s_work <= s_eff_full else 0.0
            if policy == "all":
                return max(extra, at_fraction(s_work, s_eff_at))
            return extra
        if bypass_variant == "optimal" or policy == "all":
            return min(1.0, s_eff_full / s_work)
        gear = int(bypass_variant[3:])        # fix1 / fix3 …
        protected = (tiers - gear) / tiers
        s_prot = protected * s_work
        if s_prot <= s_eff_full:
            return protected
        # at (always on with static gears) keeps top tiers of the
        # protected stream
        return at_fraction(s_prot, s_eff_at) * protected
    raise KeyError(f"unknown model policy {policy!r}")


# ---------------------------------------------------------------------------
# Profile engine: policy transforms over the reuse-distance profile
# (DESIGN.md §5; the profile itself is lowered in repro.dataflows.reuse)
# ---------------------------------------------------------------------------
def parse_model_policy(policy: str) -> Tuple[bool, bool, bool]:
    """Resolve a policy name to its mechanism flags ``(at, dbp, bypass)``."""
    if policy not in _KNOWN_POLICIES:
        raise KeyError(f"unknown model policy {policy!r}")
    return (policy in ("at", "at+dbp", "at+bypass", "all"),
            "dbp" in policy or policy == "all",
            "bypass" in policy or policy == "all")


def _static_gear(bypass: bool, variant: str, gqa: bool) -> int:
    """Gear for the non-emulated paths: none → gear 0; static fixN →
    that gear; the conservative gqa variant bypasses nothing the model
    credits (§IV-E).  Dynamic bypassing does not reduce to one gear —
    it runs the window-by-window trajectory (:func:`_gear_trajectory`)."""
    if not bypass or gqa:
        return 0
    return int(variant[3:])


def _hit_prob(d: np.ndarray, lo, hi) -> np.ndarray:
    """Set-associative capacity ramp: certain hit up to ``lo`` =
    ``C·(A-1)/A`` stack lines, certain miss past ``hi`` = ``C·(A+1)/A``,
    linear in between (hashed set mapping spreads a burst binomially
    over sets, so the all-or-nothing step of the closed forms becomes a
    band around the capacity).  ``lo``/``hi`` may be per-element arrays
    (the gear-trajectory path evaluates each access under the band of
    its own round's gear)."""
    lo = np.asarray(lo, dtype=float)
    hi = np.asarray(hi, dtype=float)
    span = hi - lo
    safe = np.where(span > 0, span, 1.0)
    p = np.clip((hi - d) / safe, 0.0, 1.0)
    return np.where(span > 0, p, (d <= lo).astype(float))


def _profile_outcome(prof, llc_bytes: int, assoc: int, at: bool, dbp: bool,
                     gear, b_bits: int) -> dict:
    """Per-round request-class masses under one transformed profile.

    The single evaluation rule: a reuse entry hits with the probability
    that its transformed distance fits the effective capacity left to
    its mass class.  All mechanism effects are transforms applied before
    that comparison.  Cached on the profile per (geometry, mechanism)
    key — θ/λ only enter the time aggregation, so calibration reuses
    these aggregates.

    ``gear`` is either a scalar (one gear everywhere — the static and
    converged cases), a per-round int array from the §IV-D trajectory
    emulation, or an ``(n_rounds, n_tenants)`` matrix from the
    per-tenant ("per-slice") trajectory mode — each access is then
    evaluated under its own tenant's transient gear.  The per-round
    forms are *residency-aware*: bypass decisions happen at fill time,
    so an access to a currently-bypassed tier still hits if the gear
    **at its previous access** admitted the fill — exactly the
    transient population a gear ramp leaves resident (and the reason a
    converged-gear model overstates bypass losses).
    """
    nr = prof.n_rounds
    if np.ndim(gear) == 0:
        g_r = np.full(nr, int(gear), dtype=np.int64)
        key = (llc_bytes, assoc, at, dbp, int(gear), b_bits)
    else:
        g_r = np.asarray(gear, dtype=np.int64)
        key = (llc_bytes, assoc, at, dbp, g_r.ndim, g_r.tobytes(), b_bits)
    out = prof._eval_cache.get(key)
    if out is not None:
        return out

    e_ten = prof.e_tenant
    t_ten = prof.t_tenant
    n_ten = prof.n_tenants

    def g_at(rounds, tenants):
        return g_r[rounds] if g_r.ndim == 1 else g_r[rounds, tenants]

    cap_lines = llc_bytes // prof.line_bytes
    c_lo = cap_lines * (assoc - 1) / assoc
    c_hi = cap_lines * (assoc + 1) / assoc
    num_sets = max(cap_lines // assoc, 1)
    n_tiers = 1 << b_bits

    # hardware priority tier = tag[B_BITS-1:0]; tag = line // num_sets
    t_prio = (prof.t_line // num_sets) % n_tiers
    e_prio = (prof.e_line // num_sets) % n_tiers
    fp = np.bincount(t_prio, weights=prof.t_mass.astype(float),
                     minlength=n_tiers)
    total_fp = float(fp.sum())
    if dbp and total_fp > 0:
        # dead generations retire on the fly: only the peak live stack
        # competes for capacity, spread over the tiers proportionally
        fp = fp * (prof.max_live_lines / total_fp)
    stack_total = float(fp.sum())

    # per-gear transform tables (bypass survivors, anti-thrashing
    # eviction-order stratification, distance shrink); a trajectory
    # indexes them per access
    max_g = 1 << b_bits
    # at: the victim is always the lowest tier *present in the set*
    # (§IV-A), so a tier-t line survives through two regimes and hits if
    # either keeps it resident:
    #
    # * **stratified** — higher-tier lines are never victimized while a
    #   lower tier is present, so their *standing* occupancy (their
    #   share of the distinct mass touched so far in the run —
    #   time-aware: early accesses see an empty cache, late ones the
    #   accumulated high-tier residue dead tiles pin there without DBP)
    #   shrinks the capacity left to tier t, inside which the line
    #   competes in LRU order against its own tier's window mass.
    #   Tiers below the gear are not refilled, but their *resident*
    #   lines sit at the very bottom of this order: every surviving
    #   allocation victimizes them first, so their competing mass is
    #   the whole surviving stream under the capacity the whole
    #   surviving standing occupancy leaves over (the ROADMAP
    #   "resident bypassed-tier" coupling).
    # * **churn** — alloc-on-fill keeps ~one way per set of streaming
    #   churn even when the standing tiers saturate capacity: a
    #   *just-used* line of any tier survives until its set's next
    #   allocation — a recency window of one line per set
    #   (capacity/assoc) against the allocation stream between its
    #   accesses.
    dscale_tab = np.zeros((max_g + 1, n_tiers))
    above_tab = np.zeros((max_g + 1, n_tiers))   # standing mass, tiers > t
    shrink_tab = np.ones(max_g + 1)     # no-at: deleted-fraction scale
    for g in np.unique(g_r).tolist():
        surv = np.arange(n_tiers) >= g
        fp_surv = np.where(surv, fp, 0.0)
        W = float(fp_surv.sum())
        shrink_tab[g] = (W / stack_total) if stack_total else 1.0
        if at:
            dscale_tab[g] = np.where(
                surv, fp_surv / stack_total if stack_total else 0.0,
                shrink_tab[g])
            above_tab[g] = np.where(
                surv,
                np.concatenate((np.cumsum(fp_surv[::-1])[::-1][1:], [0.0])),
                W)

    # fraction of the run's distinct footprint touched by each round —
    # the ramp of the standing higher-tier occupancy above
    if at:
        touched = np.cumsum(prof.cold_round.astype(float))
        touched_frac = touched / total_fp if total_fp else touched

    e_gear = g_at(prof.e_round, e_ten)
    e_prev_gear = g_at(prof.e_prev_round, e_ten)
    # residency: the line's last fill allocated iff its tier survived
    # the gear active *then* (with one gear everywhere this reduces to
    # the plain "bypassed tiers never hit" transform)
    not_resident = (e_prio < e_prev_gear) & ~prof.e_mshr

    # --- dbp transform: dead-epoch pollution leaves the stack ----------
    d = (prof.e_dlive if dbp else prof.e_dlive + prof.e_ddead).astype(float)
    w = prof.e_mass.astype(float)
    alloc_now = e_prio >= e_gear          # this access's fill allocates
    t_cold_gear = g_at(prof.t_cold_round, t_ten)
    cold_alloc_r = np.bincount(
        prof.t_cold_round,
        weights=prof.t_mass * (t_prio >= t_cold_gear), minlength=nr)

    def _finalize(p):
        p = np.where(not_resident, 0.0, p)
        return np.where(prof.e_mshr, 1.0, p)

    if at:
        occ = touched_frac[prof.e_round] * above_tab[e_gear, e_prio]
        p_strat = _hit_prob(d * dscale_tab[e_gear, e_prio],
                            c_lo - occ, c_hi - occ)
        p_hit = _finalize(p_strat)
        # churn term, as a short fixed point: the eviction threat to a
        # just-used line is the *allocation* stream between its two
        # accesses (hits evict nothing), which itself depends on the hit
        # probabilities — two rounds of alternation starting from the
        # strat-only (allocation-heaviest) estimate converge from below
        for _ in range(2):
            ar = (np.bincount(prof.e_round,
                              weights=w * (1.0 - p_hit) * alloc_now,
                              minlength=nr) + cold_alloc_r)
            cum_a = np.concatenate(([0.0], np.cumsum(ar)))
            a_win = cum_a[prof.e_round + 1] - cum_a[prof.e_prev_round + 1]
            p_churn = _hit_prob(a_win, c_lo / assoc, c_hi / assoc)
            p_hit = _finalize(np.maximum(p_strat, p_churn))
    else:
        p_hit = _finalize(_hit_prob(d * shrink_tab[e_gear], c_lo, c_hi))
    h_r = np.bincount(prof.e_round, weights=w * p_hit, minlength=nr)
    cf_reuse_r = np.bincount(prof.e_round, weights=w * (1.0 - p_hit),
                             minlength=nr)
    cold_r = (prof.cold_round + prof.byp_cold_round).astype(float)
    cf_r = cf_reuse_r + prof.byp_rep_round
    total_reuse = float(w.sum())

    # --- dirty-lifetime write-back model (DESIGN.md §5) ----------------
    # Chain each tile's accesses and propagate P(dirty): a store dirties
    # the line (write-allocate, unless its fill is bypassed); a later
    # access that *misses* under the profile's own hit rule means the
    # line aged past capacity in between — if it was dirty, that
    # eviction wrote it back (and the reload is clean).  A hit leaves
    # the dirty bit in place.
    t_last_gear = g_at(prof.t_last_round, t_ten)
    dirty0 = prof.t_cold_store & (t_prio >= t_cold_gear)
    wb_list = [0.0] * nr
    chain_w = [0.0] * prof.t_mass.shape[0]   # per-tile, tenant breakdown
    dl = dirty0.astype(float).tolist()
    for t, r, m, s, p, a in zip(
            prof.e_tile.tolist(), prof.e_round.tolist(),
            prof.e_mass.tolist(), prof.e_store.tolist(),
            p_hit.tolist(), alloc_now.tolist()):
        dcur = dl[t]
        if dcur > 0.0 and p < 1.0:
            amt = dcur * (1.0 - p) * m
            wb_list[r] += amt
            chain_w[t] += amt
        # store: hit keeps residency (dirtied either way), miss
        # re-allocates dirty only if the fill is admitted
        dl[t] = (p + (1.0 - p) * a) if s else dcur * p
    # tail: tiles still dirty at their last access write back iff the
    # remaining schedule ages them past capacity — same transformed
    # distance-vs-capacity rule as hits, under the gear of their final
    # round.
    dirty = np.asarray(dl)
    d_tail_full = (prof.t_tail_dlive + prof.t_tail_ddead).astype(float)
    d_tail = prof.t_tail_dlive.astype(float) if dbp else d_tail_full
    if at:
        # survival to the end of the schedule faces the *final* standing
        # occupancy of the tiers ranked above (touched_frac = 1: by then
        # every high-tier line that will ever stand does), against the
        # tile's own tier's share of the remaining traffic
        occ_t = above_tab[t_last_gear, t_prio]
        p_surv = _hit_prob(d_tail * dscale_tab[t_last_gear, t_prio],
                           c_lo - occ_t, c_hi - occ_t)
    else:
        p_surv = _hit_prob(d_tail * shrink_tab[t_last_gear], c_lo, c_hi)
    if dbp:
        # retired tiles lose both stack recency and tier protection (the
        # dead FIFO victimizes them first): their dirty lines survive
        # only if the remaining schedule's raw traffic never fills the
        # cache — the untransformed full distance against the plain band
        p_surv = np.where(prof.t_dies,
                          _hit_prob(d_tail_full, c_lo, c_hi), p_surv)
    wb_tail = dirty * (1.0 - p_surv) * prof.t_mass
    wb_r = np.asarray(wb_list)
    np.add.at(wb_r, prof.t_last_round, wb_tail)

    # feedback observables for the dynamic-gear controller emulation:
    # per-round allocations (misses beyond bypass; the trajectory
    # credits the first cap_lines fills as warm-up, which land in
    # invalid ways and evict nothing) and per-round request totals
    alloc_ew = w * (1.0 - p_hit) * alloc_now
    cold_aw = prof.t_mass * (t_prio >= t_cold_gear)
    alloc_r = (np.bincount(prof.e_round, weights=alloc_ew, minlength=nr)
               + np.bincount(prof.t_cold_round, weights=cold_aw,
                             minlength=nr))
    req_r = h_r + cold_r + cf_r

    out = {
        "h_r": h_r, "cold_r": cold_r, "cf_r": cf_r, "wb_r": wb_r,
        "alloc_r": alloc_r, "req_r": req_r, "cap_lines": cap_lines,
        "n_hit": float(h_r.sum()), "n_cold": float(cold_r.sum()),
        "n_cf": float(cf_r.sum()), "n_wb": float(wb_r.sum()),
        "kept": float((w * p_hit).sum() / total_reuse)
        if total_reuse else 1.0,
    }

    if n_ten > 1:
        # per-tenant attribution (DESIGN.md §8.4): entry masses key by
        # the accessing tenant, tile masses (cold fills, write-backs) by
        # the owning tenant — regions are disjoint so they coincide
        flat_e = prof.e_round * n_ten + e_ten
        flat_t = prof.t_cold_round * n_ten + t_ten
        h_rt = np.bincount(flat_e, weights=w * p_hit,
                           minlength=nr * n_ten).reshape(nr, n_ten)
        cf_rt = (np.bincount(flat_e, weights=w * (1.0 - p_hit),
                             minlength=nr * n_ten).reshape(nr, n_ten)
                 + prof.byp_rep_rt)
        cold_rt = (prof.cold_rt + prof.byp_cold_rt).astype(float)
        alloc_rt = (np.bincount(flat_e, weights=alloc_ew,
                                minlength=nr * n_ten).reshape(nr, n_ten)
                    + np.bincount(flat_t, weights=cold_aw,
                                  minlength=nr * n_ten).reshape(nr, n_ten))
        wb_t = (np.bincount(t_ten, weights=wb_tail, minlength=n_ten)
                + np.bincount(t_ten, weights=chain_w, minlength=n_ten))
        out.update({
            "alloc_rt": alloc_rt, "req_rt": h_rt + cold_rt + cf_rt,
            "n_hit_t": h_rt.sum(axis=0),
            "n_miss_t": (cold_rt + cf_rt).sum(axis=0),
            "n_wb_t": wb_t,
        })

    prof._eval_cache[key] = out
    return out


def _round_time_components(prof, outcome: dict, hw: SimConfig,
                           params: ModelParams
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                      np.ndarray]:
    """Per-round (t_hit, t_cold, t_cf, t_comp) arrays — Eq. 1–5 at the
    simulator's round granularity, shared by the aggregate prediction and
    the window-by-window gear-trajectory emulation."""
    issue = hw.n_cores * hw.ipc_mem
    v = hw.v_llc
    bw = hw.dram_lines_per_cycle
    h_r, cold_r = outcome["h_r"], outcome["cold_r"]
    cf_r, wb_r = outcome["cf_r"], outcome["wb_r"]
    flops_r = prof.flops_round

    t_hit = np.maximum(h_r / issue, h_r / v)
    t_cold = np.maximum(np.maximum(cold_r / issue, cold_r / v),
                        cold_r / (params.theta1 * bw))
    # Eq. 3 per round: conflict-demand density over the round's stream.
    # Dirty evictions are dispersed DRAM traffic exactly like conflict
    # misses (§V-B), so write-back volume counts toward the demand rate
    # — without it a store-heavy round with few conflict misses would
    # drain its write-backs at the θ2 floor.
    n_mem = h_r + cold_r + cf_r
    denom = n_mem / hw.ipc_mem + flops_r / hw.core_flops_per_cycle
    eta = np.divide((cf_r + wb_r) / hw.ipc_mem, denom,
                    out=np.zeros_like(cf_r), where=denom > 0)
    v_dmd = np.minimum(eta * issue, v)
    bw_cf = np.clip(params.lam * v_dmd, params.theta2 * bw,
                    params.theta3 * bw)
    t_cf = np.maximum(np.maximum(cf_r / issue, cf_r / v),
                      (cf_r + wb_r) / bw_cf)
    t_comp = flops_r / (hw.n_cores * hw.core_flops_per_cycle)
    return t_hit, t_cold, t_cf, t_comp


def _profile_prediction(prof, outcome: dict, hw: SimConfig,
                        params: ModelParams,
                        n_rounds: Optional[int] = None) -> Prediction:
    """Eq. 1–5 applied at the simulator's round granularity (§7.2).

    ``n_rounds`` overrides the scheduling-overhead round count like the
    closed path's parameter does; by default the profile's own round
    count is charged.
    """
    t_hit, t_cold, t_cf, t_comp = _round_time_components(prof, outcome,
                                                         hw, params)
    overhead_rounds = prof.n_rounds if n_rounds is None else n_rounds
    cycles = float((t_hit + t_cold + np.maximum(t_comp, t_cf)).sum()) \
        + params.round_overhead * overhead_rounds
    def tup(key):
        return tuple(float(x) for x in outcome[key]) \
            if key in outcome else None

    return Prediction(
        cycles=cycles, t_hit=float(t_hit.sum()), t_cold=float(t_cold.sum()),
        t_cf=float(t_cf.sum()), t_comp=float(t_comp.sum()),
        n_hit=outcome["n_hit"], n_cold=outcome["n_cold"],
        n_cf=outcome["n_cf"], kept_fraction=outcome["kept"],
        n_wb=outcome.get("n_wb", 0.0),
        n_hit_tenant=tup("n_hit_t"), n_miss_tenant=tup("n_miss_t"),
        n_wb_tenant=tup("n_wb_t"))


def _gear_trajectory(prof, llc_bytes: int, hw: SimConfig,
                     params: ModelParams, at: bool, dbp: bool,
                     b_bits: int, pcfg=None
                     ) -> Tuple[np.ndarray, dict]:
    """Window-by-window emulation of the §IV-D dynamic-gear feedback law.

    Instead of assuming the converged gear everywhere, the trajectory
    replays the controller: per feedback window (``window_cycles`` of
    *modeled* time), the predicted eviction rate — allocations beyond
    the warm-up fill credit over requests — moves the gear one step up
    when it exceeds ``bypass_ub``, and one step down only after
    ``down_streak`` consecutive low-rate windows (the fast-up /
    slow-down hysteresis).  Each round's request classes are charged at
    the gear active *during that round*, so the ramp-up transient before
    equilibrium (the residual error on ``at+bypass`` rows the converged
    pick left) is part of the prediction.  The emulated trajectory is
    validated against ``SimResult.history["gear"]``.

    Returns ``(gear_per_round, composite_outcome)`` where the composite
    outcome mixes each round's masses from the per-gear steady-state
    outcomes along the trajectory.
    """
    g_rt, outcome = _replay_gear_law(prof, llc_bytes, hw, params, at,
                                     dbp, b_bits, pcfg, n_ten=1)
    return g_rt[:, 0], outcome


def _gear_trajectory_tenant(prof, llc_bytes: int, hw: SimConfig,
                            params: ModelParams, at: bool, dbp: bool,
                            b_bits: int, pcfg=None
                            ) -> Tuple[np.ndarray, dict]:
    """Per-slice (per-tenant) mode of the §IV-D emulation (DESIGN.md
    §8.4): one independent feedback loop per tenant, mirroring the
    simulator's opt-in ``per_tenant_gears`` controller.

    The loops share modeled *time* (windows close on the composite
    clock) and the one physical cache (the warm-up fill credit is one
    shared pool, split over a chunk by each tenant's allocation share),
    but each tenant's eviction mass moves only that tenant's gear and
    every access is evaluated under its own tenant's transient gear —
    the per-tenant divergence a single mean-field controller emulation
    cannot express.  Returns ``(gear_matrix[n_rounds, n_tenants],
    composite_outcome)``.
    """
    return _replay_gear_law(prof, llc_bytes, hw, params, at, dbp, b_bits,
                            pcfg, n_ten=prof.n_tenants)


def _replay_gear_law(prof, llc_bytes: int, hw: SimConfig,
                     params: ModelParams, at: bool, dbp: bool,
                     b_bits: int, pcfg, n_ten: int
                     ) -> Tuple[np.ndarray, dict]:
    """One implementation of the window replay for both modes — the
    single-controller case is exactly ``n_ten=1`` (scalar gears are
    passed through to ``_profile_outcome`` so its cache keys and the
    composite 1-D trajectory path are unchanged)."""
    if pcfg is None:
        from .policies import PolicyConfig
        pcfg = PolicyConfig()
    nr = prof.n_rounds
    assoc = hw.llc_assoc
    max_gear = 1 << b_bits
    outs: Dict[tuple, dict] = {}
    cum: Dict[tuple, tuple] = {}

    def gear_arg(gt: tuple):
        """What _profile_outcome sees for one constant gear state."""
        if n_ten == 1:
            return int(gt[0])
        return np.broadcast_to(np.asarray(gt, dtype=np.int64),
                               (nr, n_ten)).copy()

    def outcome(gt: tuple) -> dict:
        o = outs.get(gt)
        if o is None:
            o = outs[gt] = _profile_outcome(prof, llc_bytes, assoc, at,
                                            dbp, gear_arg(gt), b_bits)
            th, tc, tcf, tcomp = _round_time_components(prof, o, hw,
                                                        params)
            if n_ten == 1:
                ca = np.cumsum(o["alloc_r"])[:, None]
                cq = np.cumsum(o["req_r"])[:, None]
            else:
                ca = np.cumsum(o["alloc_rt"], axis=0)
                cq = np.cumsum(o["req_rt"], axis=0)
            cum[gt] = (np.cumsum(th + tc + np.maximum(tcomp, tcf)
                                 + params.round_overhead), ca, cq)
        return o

    gears = tuple(pcfg.b_gear for _ in range(n_ten))
    cap = float(outcome(gears)["cap_lines"])
    clock = win_start = 0.0
    ev = np.zeros(n_ten)
    acc = np.zeros(n_ten)
    cum_alloc = 0.0
    streak = np.zeros(n_ten, dtype=np.int64)
    g_rt = np.zeros((nr, n_ten), dtype=np.int64)
    r = 0
    while r < nr:
        outcome(gears)
        ct, ca, cq = cum[gears]
        base_t = ct[r - 1] if r else 0.0
        # first round whose end crosses the current window boundary
        j = int(np.searchsorted(ct, win_start + pcfg.window_cycles
                                - clock + base_t))
        j = min(j, nr - 1)
        g_rt[r:j + 1] = gears
        base = r - 1
        chunk_t = ca[j] - (ca[base] if r else 0.0)       # (n_tenants,)
        total = float(chunk_t.sum())
        # warm-up fill credit: the first cap allocations land in invalid
        # ways and evict nothing (mirrors the simulator's cold start);
        # one shared pool, split by each tenant's share of the chunk
        evictable = max(cum_alloc + total - max(cap, cum_alloc), 0.0)
        if total > 0:
            ev += chunk_t * (evictable / total)
        cum_alloc += total
        acc += cq[j] - (cq[base] if r else 0.0)
        clock += ct[j] - base_t
        r = j + 1
        elapsed = clock - win_start
        if elapsed >= pcfg.window_cycles:
            # one gear step per crossing, then advance in whole window
            # multiples — GearController.tick is invoked once per round
            # and moves one step at most, so a round spanning several
            # windows ramps exactly one step there too
            rate = ev / np.maximum(acc, 1.0)
            g = np.asarray(gears, dtype=np.int64)
            up = rate > pcfg.bypass_ub
            low = rate < pcfg.bypass_lb
            streak = np.where(low, streak + 1, 0)
            down = streak >= pcfg.down_streak
            streak[down] = 0
            g = np.clip(g + up.astype(np.int64) - down.astype(np.int64),
                        0, max_gear)
            gears = tuple(int(x) for x in g)
            ev[:] = 0.0
            acc[:] = 0.0
            win_start += (elapsed // pcfg.window_cycles) \
                * pcfg.window_cycles

    # composite outcome: every access re-evaluated under the gear of its
    # own round, residency-aware across gear changes (an access whose
    # tier the *current* gear bypasses still hits if its last fill was
    # admitted under a lower transient gear) — cached per trajectory
    segments = {tuple(row) for row in g_rt.tolist()}
    if len(segments) == 1:
        return g_rt, outcome(next(iter(segments)))
    traj = g_rt[:, 0] if n_ten == 1 else g_rt
    return g_rt, _profile_outcome(prof, llc_bytes, assoc, at, dbp, traj,
                                  b_bits)


def _predict_profile(counts: DataflowCounts, llc_bytes: int, policy: str,
                     hw: SimConfig, params: ModelParams,
                     bypass_variant: str, gqa: bool, b_bits: int,
                     n_rounds: Optional[int] = None,
                     per_tenant_gears: bool = False) -> Prediction:
    prof = counts.reuse_profile
    at, dbp, bypass = parse_model_policy(policy)
    if bypass and bypass_variant.startswith("fix"):
        at = True          # static gears run with at enabled (§VI-E)
    if bypass and not gqa and not bypass_variant.startswith("fix"):
        # dynamic bypassing: replay the per-window feedback law (§IV-D)
        # round by round — the controller ramps the gear until the
        # eviction rate drops under its upper bound, and the pre-
        # equilibrium windows run (and are charged) at their lower
        # transient gears, even when the converged gear over-bypasses
        # and destroys inter-core reuse (the §IV-E failure the gqa
        # variant exists to avoid).
        traj = (_gear_trajectory_tenant
                if per_tenant_gears and prof.n_tenants > 1
                else _gear_trajectory)
        _, outcome = traj(prof, llc_bytes, hw, params, at, dbp, b_bits)
        return _profile_prediction(prof, outcome, hw, params, n_rounds)
    gear = _static_gear(bypass, bypass_variant, gqa)
    outcome = _profile_outcome(prof, llc_bytes, hw.llc_assoc, at, dbp,
                               gear, b_bits)
    return _profile_prediction(prof, outcome, hw, params, n_rounds)


def gear_trajectory(counts: DataflowCounts, llc_bytes: int,
                    policy: str = "at+bypass",
                    hw: Optional[SimConfig] = None,
                    params: Optional[ModelParams] = None,
                    b_bits: int = 3, policy_cfg=None,
                    per_tenant: bool = False) -> np.ndarray:
    """Emulated per-round gear trajectory of the §IV-D feedback law.

    The validation-facing entry point: rounds with no requests keep the
    gear of the preceding window, matching where the simulator skips
    its controller tick.  Compare against the per-round mean gear the
    simulator records in ``SimResult.history["gear"]`` (which omits the
    empty rounds).

    ``per_tenant=True`` (multi-tenant composite profiles, DESIGN.md
    §8.4) runs one feedback loop per tenant and returns an
    ``(n_rounds, n_tenants)`` matrix — compare column ``i`` against
    ``SimResult.history["tenant_gear"][:, i]`` recorded under the
    simulator's ``per_tenant_gears`` policy flag."""
    hw = hw or SimConfig()
    params = params or ModelParams()
    prof = counts.reuse_profile
    if prof is None:
        raise ValueError("counts carry no reuse profile "
                         "(lower_to_counts(with_profile=True))")
    at, dbp, bypass = parse_model_policy(policy)
    if not bypass:
        raise ValueError(f"policy {policy!r} does not bypass")
    if per_tenant:
        if prof.n_tenants < 2:
            raise ValueError("per_tenant gear trajectory needs a "
                             "multi-tenant composite profile")
        g_rt, _ = _gear_trajectory_tenant(prof, llc_bytes, hw, params,
                                          at, dbp, b_bits, policy_cfg)
        return g_rt
    g_r, _ = _gear_trajectory(prof, llc_bytes, hw, params, at, dbp,
                              b_bits, policy_cfg)
    return g_r


# ---------------------------------------------------------------------------
# Eq. 1–5
# ---------------------------------------------------------------------------
def predict(counts: DataflowCounts, llc_bytes: int, policy: str,
            hw: Optional[SimConfig] = None,
            params: Optional[ModelParams] = None,
            bypass_variant: str = "optimal",
            gqa: bool = False,
            b_bits: int = 3,
            n_rounds: Optional[int] = None,
            model: str = "profile",
            per_tenant_gears: bool = False) -> Prediction:
    """Predict cycles for one (dataflow, cache size, policy) point.

    ``model="profile"`` (default) evaluates the reuse-distance profile
    attached to ``counts`` and falls back to the closed forms when the
    producer skipped the profile lowering; ``model="closed"`` forces the
    original §V-C scalar step functions.  ``per_tenant_gears`` mirrors
    the simulator's opt-in policy flag on multi-tenant composites: the
    dynamic-bypass emulation runs one feedback loop per tenant
    (DESIGN.md §8.4) instead of the single mean-field controller.
    """
    hw = hw or SimConfig()
    params = params or ModelParams()
    if model not in ("profile", "closed"):
        raise KeyError(f"unknown model {model!r}")
    if model == "profile" and counts.reuse_profile is not None:
        return _predict_profile(counts, llc_bytes, policy, hw, params,
                                bypass_variant, gqa, b_bits, n_rounds,
                                per_tenant_gears)

    # dead data of retired batches pollutes every policy that does not
    # predict dead blocks (§VI-F); "all" names its mechanisms implicitly
    # but its closed-form treatment keeps the polluted stack, so the
    # substring test is the behavior-defining check for every policy in
    # ``_KNOWN_POLICIES`` (pinned by tests/test_analytical.py)
    pollution = 1.0
    if counts.n_batches > 1 and "dbp" not in policy:
        pollution = 1.0 / counts.n_batches

    f = kept_fraction(policy, counts.s_work_active, llc_bytes,
                      hw.llc_assoc, b_bits, bypass_variant, gqa, pollution)

    temporal_hits = f * counts.n_temporal_reuse
    intercore_hits = float(counts.n_intercore_reuse)
    lost_intercore = 0.0
    if (not gqa and counts.n_intercore_reuse
            and policy in ("bypass+dbp", "all", "lru+bypass", "at+bypass")):
        # blind bypassing in sharing dataflows loses the bypassed share of
        # inter-core reuses and pays extra DRAM fetches (paper §IV-E)
        if bypass_variant.startswith("fix"):
            gear_frac = int(bypass_variant[3:]) / (1 << b_bits)
        else:
            gear_frac = max(0.0, 1.0 - f)
        lost_intercore = gear_frac * intercore_hits
        intercore_hits -= lost_intercore

    n_hit = temporal_hits + intercore_hits
    n_cold = counts.n_kv_distinct + counts.n_bypass_lines
    n_cf = (counts.n_temporal_reuse - temporal_hits) + lost_intercore
    n_mem = counts.n_kv_accesses + counts.n_bypass_lines

    N, ipc = hw.n_cores, hw.ipc_mem
    v_llc = hw.v_llc
    bw = hw.dram_lines_per_cycle

    t_comp = counts.flops_total / (N * hw.core_flops_per_cycle)
    t_hit = max(n_hit / (N * ipc), n_hit / v_llc)
    bw_cold = params.theta1 * bw
    t_cold = max(n_cold / (N * ipc), n_cold / v_llc, n_cold / bw_cold)

    # Eq. 3: conflict-miss demand density over the instruction stream
    ipc_comp = hw.core_flops_per_cycle
    denom = n_mem / ipc + counts.flops_total / ipc_comp
    eta_cf = (n_cf / ipc) / denom if denom > 0 else 0.0
    v_cf_dmd = min(eta_cf * N * ipc, v_llc)
    bw_cf = float(np.clip(params.lam * v_cf_dmd,
                          params.theta2 * bw, params.theta3 * bw))
    t_cf = max(n_cf / (N * ipc), n_cf / v_llc, n_cf / bw_cf) if n_cf else 0.0

    cycles = t_hit + t_cold + max(t_comp, t_cf)
    if n_rounds:
        cycles += params.round_overhead * n_rounds

    return Prediction(cycles=cycles, t_hit=t_hit, t_cold=t_cold, t_cf=t_cf,
                      t_comp=t_comp, n_hit=n_hit, n_cold=n_cold, n_cf=n_cf,
                      kept_fraction=f)


# ---------------------------------------------------------------------------
# Batched prediction: one shared reuse histogram, many policies
# ---------------------------------------------------------------------------
def predict_batch(counts: DataflowCounts, llc_bytes: int,
                  policies: Sequence[str],
                  hw: Optional[SimConfig] = None,
                  params: Optional[ModelParams] = None,
                  bypass_variant: str = "optimal",
                  gqa: bool = False,
                  b_bits: int = 3,
                  n_rounds: Optional[int] = None,
                  model: str = "profile",
                  per_tenant_gears: bool = False) -> List[Prediction]:
    """Predict one (dataflow, cache size) point for a whole policy set.

    Each policy's request classes are a reweighting of the *same* reuse
    histogram (``_profile_outcome``, cached per (policy-flags, gear) on
    the profile), and the Eq. 1–5 time aggregation runs once on the
    stacked ``(n_policies, n_rounds)`` class matrix instead of per
    policy.  Every returned :class:`Prediction` is bit-identical to the
    corresponding scalar :func:`predict` call — the stacked arithmetic
    is elementwise and the per-policy sums reduce contiguous rows
    exactly like the 1-D path (pinned by tests/test_fit_batched.py).
    """
    hw = hw or SimConfig()
    params = params or ModelParams()
    if model not in ("profile", "closed"):
        raise KeyError(f"unknown model {model!r}")
    prof = counts.reuse_profile
    if model != "profile" or prof is None:
        # closed forms are scalar arithmetic — nothing to batch
        return [predict(counts, llc_bytes, p, hw, params, bypass_variant,
                        gqa, b_bits, n_rounds, model=model,
                        per_tenant_gears=per_tenant_gears)
                for p in policies]

    outcomes = []
    for policy in policies:
        at, dbp, bypass = parse_model_policy(policy)
        if bypass and bypass_variant.startswith("fix"):
            at = True
        if bypass and not gqa and not bypass_variant.startswith("fix"):
            traj = (_gear_trajectory_tenant
                    if per_tenant_gears and prof.n_tenants > 1
                    else _gear_trajectory)
            _, o = traj(prof, llc_bytes, hw, params, at, dbp, b_bits)
        else:
            o = _profile_outcome(prof, llc_bytes, hw.llc_assoc, at, dbp,
                                 _static_gear(bypass, bypass_variant, gqa),
                                 b_bits)
        outcomes.append(o)

    # one stacked Eq. 1–5 evaluation: _round_time_components is purely
    # elementwise over the class arrays, so feeding it (P, nr) stacks
    # yields each policy's rows bit-identical to its own 1-D call
    stacked = {k: np.stack([o[k] for o in outcomes])
               for k in ("h_r", "cold_r", "cf_r", "wb_r")}
    t_hit, t_cold, t_cf, t_comp = _round_time_components(prof, stacked,
                                                         hw, params)
    overhead_rounds = prof.n_rounds if n_rounds is None else n_rounds
    preds = []
    for i, o in enumerate(outcomes):
        th, tc, tcf = t_hit[i], t_cold[i], t_cf[i]
        cycles = float((th + tc + np.maximum(t_comp, tcf)).sum()) \
            + params.round_overhead * overhead_rounds

        def tup(key):
            return tuple(float(x) for x in o[key]) if key in o else None

        preds.append(Prediction(
            cycles=cycles, t_hit=float(th.sum()), t_cold=float(tc.sum()),
            t_cf=float(tcf.sum()), t_comp=float(t_comp.sum()),
            n_hit=o["n_hit"], n_cold=o["n_cold"], n_cf=o["n_cf"],
            kept_fraction=o["kept"], n_wb=o.get("n_wb", 0.0),
            n_hit_tenant=tup("n_hit_t"), n_miss_tenant=tup("n_miss_t"),
            n_wb_tenant=tup("n_wb_t")))
    return preds


# ---------------------------------------------------------------------------
# Calibration (§V-D: θ, λ fitted per hardware/policy combination)
# ---------------------------------------------------------------------------
class _ThetaGrid:
    """A candidate batch masquerading as :class:`ModelParams`.

    The θ fields are ``(K, 1)`` column arrays, so feeding a grid through
    ``_round_time_components`` broadcasts the round arrays to ``(K,
    n_rounds)`` — one row per candidate, each bit-identical to the
    scalar call (every op is elementwise, and scalar products like
    ``theta1 * bw`` happen in the same order)."""

    __slots__ = ("params", "k", "key", "theta1", "theta2", "theta3",
                 "lam", "round_overhead")

    def __init__(self, cands: Sequence[ModelParams]):
        self.params = list(cands)
        self.k = len(self.params)
        self.key = tuple((p.theta1, p.theta2, p.theta3, p.lam,
                          p.round_overhead) for p in self.params)
        col = np.asarray(self.key, dtype=np.float64).reshape(self.k, 5)
        self.theta1 = col[:, 0:1]
        self.theta2 = col[:, 1:2]
        self.theta3 = col[:, 2:3]
        self.lam = col[:, 3:4]
        self.round_overhead = col[:, 4:5]

    def subset(self, idx: Sequence[int]) -> "_ThetaGrid":
        return _ThetaGrid([self.params[i] for i in idx])


class _FitPointEval:
    """One calibration point, evaluated for a whole candidate batch.

    Splits ``predict``'s three regimes — closed form, static-gear
    profile, dynamic-gear profile — and batches each across the θ axis:
    the θ-independent work (class reweighting, per-gear cumulative
    observables) runs once, the θ-dependent Eq. 1–5 rows vectorize via
    :class:`_ThetaGrid`, and only the inherently sequential feedback-law
    replay stays a per-candidate scalar loop (over its cheap cumulative
    tables).  Batch results are cached on the profile keyed by the
    candidate set, so repeated fits over shared points (the LOSO loop)
    evaluate each grid once."""

    def __init__(self, point, hw: SimConfig, model: str):
        counts, llc, pol, variant, gqa, rounds, target = point
        self.log_target = math.log(max(target, 1.0))
        self.hw = hw
        self.llc = llc
        self.b_bits = 3                      # predict()'s default
        prof = counts.reuse_profile
        self.prof = None
        if model == "profile" and prof is not None:
            self.prof = prof
            self.overhead = prof.n_rounds if rounds is None else rounds
            at, dbp, bypass = parse_model_policy(pol)
            if bypass and variant.startswith("fix"):
                at = True
            self.at, self.dbp = at, dbp
            self.dynamic = (bypass and not gqa
                            and not variant.startswith("fix"))
            self.gear = (None if self.dynamic
                         else _static_gear(bypass, variant, gqa))
            hwk = (hw.n_cores, hw.ipc_mem, hw.v_llc,
                   hw.core_flops_per_cycle, hw.dram_bw_bytes_per_cycle,
                   hw.dram_eff_seq, hw.dram_eff_rand, hw.llc_assoc,
                   hw.line_bytes)
            self._key = ("fit_cyc", llc, at, dbp,
                         "dyn" if self.dynamic else int(self.gear),
                         self.b_bits, self.overhead, hwk)
        else:
            self._closed_setup(counts, llc, pol, variant, gqa, rounds)

    # -- closed form (§V-C): θ-independent scalars precomputed once ------
    def _closed_setup(self, counts, llc, pol, variant, gqa, rounds):
        hw = self.hw
        pollution = 1.0
        if counts.n_batches > 1 and "dbp" not in pol:
            pollution = 1.0 / counts.n_batches
        f = kept_fraction(pol, counts.s_work_active, llc, hw.llc_assoc,
                          self.b_bits, variant, gqa, pollution)
        temporal_hits = f * counts.n_temporal_reuse
        intercore_hits = float(counts.n_intercore_reuse)
        lost = 0.0
        if (not gqa and counts.n_intercore_reuse
                and pol in ("bypass+dbp", "all", "lru+bypass",
                            "at+bypass")):
            if variant.startswith("fix"):
                gear_frac = int(variant[3:]) / (1 << self.b_bits)
            else:
                gear_frac = max(0.0, 1.0 - f)
            lost = gear_frac * intercore_hits
            intercore_hits -= lost
        n_hit = temporal_hits + intercore_hits
        n_cold = counts.n_kv_distinct + counts.n_bypass_lines
        n_cf = (counts.n_temporal_reuse - temporal_hits) + lost
        n_mem = counts.n_kv_accesses + counts.n_bypass_lines
        N, ipc = hw.n_cores, hw.ipc_mem
        v_llc = hw.v_llc
        self._bw = hw.dram_lines_per_cycle
        self._t_comp = counts.flops_total / (N * hw.core_flops_per_cycle)
        self._t_hit = max(n_hit / (N * ipc), n_hit / v_llc)
        self._m_cold = max(n_cold / (N * ipc), n_cold / v_llc)
        self._n_cold = n_cold
        denom = n_mem / ipc + counts.flops_total / hw.core_flops_per_cycle
        eta_cf = (n_cf / ipc) / denom if denom > 0 else 0.0
        self._v_cf_dmd = min(eta_cf * N * ipc, v_llc)
        self._m_cf = max(n_cf / (N * ipc), n_cf / v_llc)
        self._n_cf = n_cf
        self._rounds = rounds

    def _closed_cycles(self, grid: _ThetaGrid) -> np.ndarray:
        t1 = grid.theta1[:, 0]
        t2 = grid.theta2[:, 0]
        t3 = grid.theta3[:, 0]
        lam = grid.lam[:, 0]
        ro = grid.round_overhead[:, 0]
        bw = self._bw
        t_cold = np.maximum(self._m_cold, self._n_cold / (t1 * bw))
        if self._n_cf:
            bw_cf = np.clip(lam * self._v_cf_dmd, t2 * bw, t3 * bw)
            t_cf = np.maximum(self._m_cf, self._n_cf / bw_cf)
        else:
            t_cf = 0.0
        cycles = self._t_hit + t_cold + np.maximum(self._t_comp, t_cf)
        if self._rounds:
            cycles = cycles + ro * self._rounds
        return np.asarray(cycles, dtype=np.float64)

    # -- shared Eq. 1–5 row aggregation ----------------------------------
    def _rows_cycles(self, outcome: dict, grid: _ThetaGrid) -> np.ndarray:
        t_hit, t_cold, t_cf, t_comp = _round_time_components(
            self.prof, outcome, self.hw, grid)
        body = t_hit + t_cold + np.maximum(t_comp, t_cf)   # (K, nr)
        sums = np.empty(grid.k)
        for i in range(grid.k):
            # contiguous row views reduce exactly like the 1-D arrays of
            # the scalar path (same pairwise-summation blocking)
            sums[i] = body[i].sum()
        return sums + grid.round_overhead[:, 0] * self.overhead

    def _static_cycles(self, grid: _ThetaGrid) -> np.ndarray:
        o = _profile_outcome(self.prof, self.llc, self.hw.llc_assoc,
                             self.at, self.dbp, self.gear, self.b_bits)
        return self._rows_cycles(o, grid)

    # -- dynamic gears: scalar replay per candidate over batched tables --
    def _dynamic_cycles(self, grid: _ThetaGrid) -> np.ndarray:
        from .policies import PolicyConfig
        prof, hw = self.prof, self.hw
        pcfg = PolicyConfig()
        nr = prof.n_rounds
        max_gear = 1 << self.b_bits
        W = pcfg.window_cycles
        gear_data: Dict[int, dict] = {}

        def entry(g: int) -> dict:
            e = gear_data.get(g)
            if e is None:
                o = _profile_outcome(prof, self.llc, hw.llc_assoc,
                                     self.at, self.dbp, int(g),
                                     self.b_bits)
                th, tc, tcf, tcomp = _round_time_components(prof, o, hw,
                                                            grid)
                e = gear_data[g] = {
                    "o": o,
                    "ct": np.cumsum(th + tc + np.maximum(tcomp, tcf)
                                    + grid.round_overhead, axis=-1),
                    "ca": np.cumsum(o["alloc_r"]),
                    "cq": np.cumsum(o["req_r"]),
                    "cap": float(o["cap_lines"]),
                }
            return e

        trajs = []
        for k in range(grid.k):
            g = pcfg.b_gear
            cap = entry(g)["cap"]
            clock = win_start = 0.0
            ev = acc = cum_alloc = 0.0
            streak = 0
            traj: List[int] = []
            r = 0
            while r < nr:
                e = entry(g)
                ct = e["ct"][k]
                ca, cq = e["ca"], e["cq"]
                base_t = ct[r - 1] if r else 0.0
                j = int(np.searchsorted(ct, win_start + W - clock
                                        + base_t))
                if j > nr - 1:
                    j = nr - 1
                traj.extend([g] * (j + 1 - r))
                base = r - 1
                total = float(ca[j] - (ca[base] if r else 0.0))
                evictable = max(cum_alloc + total - max(cap, cum_alloc),
                                0.0)
                if total > 0:
                    ev += total * (evictable / total)
                cum_alloc += total
                acc += float(cq[j] - (cq[base] if r else 0.0))
                clock += float(ct[j] - base_t)
                r = j + 1
                elapsed = clock - win_start
                if elapsed >= W:
                    rate = ev / (acc if acc > 1.0 else 1.0)
                    streak = streak + 1 if rate < pcfg.bypass_lb else 0
                    down = streak >= pcfg.down_streak
                    if down:
                        streak = 0
                    g = (g + (1 if rate > pcfg.bypass_ub else 0)
                         - (1 if down else 0))
                    g = min(max(g, 0), max_gear)
                    ev = acc = 0.0
                    win_start += (elapsed // W) * W
            trajs.append(tuple(traj))

        # candidates sharing a trajectory share its composite outcome
        out = np.empty(grid.k)
        groups: Dict[tuple, List[int]] = {}
        for k, t in enumerate(trajs):
            groups.setdefault(t, []).append(k)
        for t, ks in groups.items():
            if len(set(t)) == 1:
                o = entry(t[0])["o"]
            else:
                o = _profile_outcome(prof, self.llc, hw.llc_assoc,
                                     self.at, self.dbp,
                                     np.asarray(t, dtype=np.int64),
                                     self.b_bits)
            out[np.asarray(ks)] = self._rows_cycles(o, grid.subset(ks))
        return out

    # -- entry point -----------------------------------------------------
    def cycles(self, grid: _ThetaGrid) -> np.ndarray:
        """Predicted cycles per candidate, cached per candidate set on
        the profile so repeated fits over shared points (LOSO) evaluate
        each grid once."""
        if self.prof is None:
            return self._closed_cycles(grid)
        key = self._key + (grid.key,)
        hit = self.prof._eval_cache.get(key)
        if hit is not None:
            return hit
        out = (self._dynamic_cycles(grid) if self.dynamic
               else self._static_cycles(grid))
        self.prof._eval_cache[key] = out
        return out


def fit_params(points: Sequence[Tuple[DataflowCounts, int, str, str, bool,
                                      Optional[int], float]],
               hw: Optional[SimConfig] = None,
               model: str = "profile") -> ModelParams:
    """Fit (θ1, θ2, θ3, λ) to simulator measurements.

    ``points``: (counts, llc_bytes, policy, bypass_variant, gqa, n_rounds,
    simulated_cycles) tuples.  Coarse grid search + refinement on mean
    squared log error, mirroring the paper's empirical fitting.  ``model``
    selects the hit engine the constants are fitted for.

    The search is batched across the candidate axis
    (:class:`_FitPointEval`): each point's θ-independent aggregates are
    computed once and the Eq. 1–5 rows for a whole candidate grid
    evaluate in one broadcast, with per-(point, grid) results cached on
    the reuse profiles — the stage the suite leans on for its LOSO
    loop.  The selected parameters are bit-identical to the sequential
    reference scan (``_fit_params_reference``, pinned by
    tests/test_fit_batched.py): elementwise float ops, first-occurrence
    ``argmin`` (ties keep the earlier candidate, exactly like the strict
    ``<`` scan), and NaN losses dropped the way the scan skips them.
    """
    hw = hw or SimConfig()
    evals = [_FitPointEval(p, hw, model) for p in points]
    inv = max(len(points), 1)

    def losses(cands: List[ModelParams]) -> np.ndarray:
        grid = _ThetaGrid(cands)
        err = np.zeros(grid.k)
        for ev in evals:
            lt = ev.log_target
            err += np.asarray(
                [(math.log(max(c, 1.0)) - lt) ** 2
                 for c in ev.cycles(grid).tolist()])
        return err / inv

    default = ModelParams()
    cands = [default]
    for t1, t2, t3, lam in product(
            (0.7, 0.8, 0.9, 1.0),          # theta1
            (0.1, 0.2, 0.3),               # theta2
            (0.45, 0.6, 0.75, 0.9),        # theta3
            (0.6, 0.8, 1.0, 1.25)):        # lambda
        if t2 >= t3:
            continue
        cands.append(ModelParams(t1, t2, t3, lam))
    L = losses(cands)
    if math.isnan(float(L[0])):
        # a NaN baseline loss beats nothing in the strict-< scan
        return default
    L = np.where(np.isnan(L), np.inf, L)
    bi = int(np.argmin(L))
    best, best_loss = cands[bi], float(L[bi])

    # local refinement around the best point
    for _ in range(2):
        t1, t2, t3, lam = best.theta1, best.theta2, best.theta3, best.lam
        cands = []
        for d1, d2, d3, dl in product((-0.05, 0.0, 0.05), repeat=4):
            p = ModelParams(
                float(np.clip(t1 + d1, 0.3, 1.0)),
                float(np.clip(t2 + d2, 0.05, 0.5)),
                float(np.clip(t3 + d3, 0.2, 1.0)),
                float(np.clip(lam + dl, 0.2, 2.0)))
            if p.theta2 >= p.theta3:
                continue
            cands.append(p)
        if not cands:
            continue
        L = losses(cands)
        L = np.where(np.isnan(L), np.inf, L)
        bi = int(np.argmin(L))
        if float(L[bi]) < best_loss:
            best, best_loss = cands[bi], float(L[bi])
    return best


def _fit_params_reference(points: Sequence[Tuple[DataflowCounts, int, str,
                                                 str, bool, Optional[int],
                                                 float]],
                          hw: Optional[SimConfig] = None,
                          model: str = "profile") -> ModelParams:
    """Pre-batching sequential fit — one ``predict`` per (candidate,
    point).  Kept as the oracle for the batched :func:`fit_params`
    (tests/test_fit_batched.py asserts the selected parameters are
    bit-identical); not used on any hot path."""
    hw = hw or SimConfig()

    def loss(p: ModelParams) -> float:
        err = 0.0
        for counts, llc, pol, variant, gqa, rounds, target in points:
            pred = predict(counts, llc, pol, hw, p, variant, gqa,
                           n_rounds=rounds, model=model).cycles
            err += (math.log(max(pred, 1.0)) - math.log(max(target, 1.0))) ** 2
        return err / max(len(points), 1)

    best = ModelParams()
    best_loss = loss(best)
    grid = product(
        (0.7, 0.8, 0.9, 1.0),          # theta1
        (0.1, 0.2, 0.3),               # theta2
        (0.45, 0.6, 0.75, 0.9),        # theta3
        (0.6, 0.8, 1.0, 1.25),         # lambda
    )
    for t1, t2, t3, lam in grid:
        if t2 >= t3:
            continue
        p = ModelParams(t1, t2, t3, lam)
        cur = loss(p)
        if cur < best_loss:
            best, best_loss = p, cur
    # local refinement around the best point
    for _ in range(2):
        t1, t2, t3, lam = best.theta1, best.theta2, best.theta3, best.lam
        for d1, d2, d3, dl in product((-0.05, 0.0, 0.05), repeat=4):
            p = ModelParams(
                float(np.clip(t1 + d1, 0.3, 1.0)),
                float(np.clip(t2 + d2, 0.05, 0.5)),
                float(np.clip(t3 + d3, 0.2, 1.0)),
                float(np.clip(lam + dl, 0.2, 2.0)))
            if p.theta2 >= p.theta3:
                continue
            cur = loss(p)
            if cur < best_loss:
                best, best_loss = p, cur
    return best


# ---------------------------------------------------------------------------
# Validation metrics (paper §VI-G1: R² = 0.997, Kendall τ = 0.934)
# ---------------------------------------------------------------------------
def r_squared(pred: np.ndarray, target: np.ndarray) -> float:
    target = np.asarray(target, dtype=float)
    pred = np.asarray(pred, dtype=float)
    ss_res = float(((target - pred) ** 2).sum())
    ss_tot = float(((target - target.mean()) ** 2).sum())
    return 1.0 - ss_res / ss_tot if ss_tot else 1.0


def kendall_tau(pred: np.ndarray, target: np.ndarray) -> float:
    """Kendall's τ-b (tie-adjusted): tied pairs leave the numerator but
    also shrink the denominator, ``sqrt((n0-n1)(n0-n2))`` with ``n1``/
    ``n2`` the pairs tied in each input — the paper's §VI-G1 τ = 0.934
    is a τ-b figure.  (The τ-a denominator ``n(n-1)/2`` biases τ low
    whenever predictions tie, e.g. two policies collapsing to the same
    predicted cycles.)"""
    pred = np.asarray(pred, dtype=float)
    target = np.asarray(target, dtype=float)
    n = pred.shape[0]
    if n < 2:
        return 1.0
    dp = np.sign(pred[:, None] - pred[None, :])
    dt = np.sign(target[:, None] - target[None, :])
    iu = np.triu_indices(n, k=1)
    s = dp[iu] * dt[iu]
    concordant = float((s > 0).sum())
    discordant = float((s < 0).sum())
    n0 = n * (n - 1) / 2
    n1 = float((dp[iu] == 0).sum())
    n2 = float((dt[iu] == 0).sum())
    denom = math.sqrt((n0 - n1) * (n0 - n2))
    if denom == 0.0:
        # at least one input is constant: perfect agreement only if both
        # are (no orderable pair disagrees), else no rank information
        return 1.0 if n1 == n2 == n0 else 0.0
    return (concordant - discordant) / denom

"""Cache-integrated analytical model (paper §V + DESIGN.md §5).

Predicts execution time for a dataflow with no simulation in the loop.
Two hit-estimation engines share the paper's Eq. 1–5 time machinery:

* ``model="profile"`` (default) — evaluates the IR-derived
  reuse-distance profile (``repro.dataflows.reuse``,
  ``DataflowCounts.reuse_profile``).  Every cache mechanism is a small
  *transform of the profile* and the hit mass is the reuse mass whose
  transformed distance fits the effective capacity — one evaluation
  path for all policies (DESIGN.md §5):

  - **DBP** removes dead-epoch pollution: distance drops from
    ``d_live + d_dead`` to ``d_live``.
  - **Anti-thrashing** partitions reuse mass into the hardware's
    ``2^B_BITS`` ``tag``-derived priority tiers and protects the top
    tiers whose footprint fits; unprotected mass competes for the
    remaining capacity with correspondingly shrunk distances.
  - **Bypass gear g** deletes the lowest ``g`` tiers' mass (their
    reuses miss — including inter-core reuses, the §IV-E failure mode)
    and shrinks everyone else's distances by the deleted fraction;
    dynamic bypassing is its upper bound, the best static gear (§V-A).
  - MSHR-merge mass (distance 0) always hits, under every policy.

* ``model="closed"`` — the original §V-C scalar step functions
  (``kept_fraction``), kept bit-identical as the fallback for counts
  that carry no profile and as the frozen-oracle baseline.

Shared time structure (both engines):

* Eq. 1: each request class is bottlenecked by the slowest of
  {core LSU issue, LLC throughput, DRAM bandwidth}.
* Eq. 2: ``t = t_hit + t_cold + max(t_comp, t_cf)`` — cold misses are
  bursty and exposed; conflict misses are dispersed and overlap with
  compute.  The profile engine applies Eq. 2 at the simulator's own
  time quantum (per lockstep round, DESIGN.md §7.2); the closed engine
  applies it once globally.
* Eq. 3–5: conflict-miss bandwidth from the demand rate ``v_cf,dmd``
  with fitted constants θ1, θ2, θ3, λ (per hardware/policy family,
  §V-D).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from itertools import product
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .simulator import SimConfig
from .traces import DataflowCounts

MODEL_POLICIES = ("lru", "dbp", "at+dbp", "bypass+dbp", "all")
BYPASS_VARIANTS = ("fix1", "fix3", "optimal")
#: every policy name either hit engine resolves (superset of the paper's
#: figure set; the simulator's named_policy uses the same vocabulary)
_KNOWN_POLICIES = ("lru", "at", "dbp", "at+dbp", "lru+bypass", "at+bypass",
                   "bypass+dbp", "all")


@dataclass(frozen=True)
class ModelParams:
    """Fitted constants of Eq. 4–5 (+ per-round scheduling overhead)."""

    theta1: float = 0.90      # cold-burst DRAM efficiency
    theta2: float = 0.25      # conflict-miss bandwidth floor (×BW)
    theta3: float = 0.65      # conflict-miss bandwidth ceiling (×BW)
    lam: float = 1.00         # demand-rate scale λ
    round_overhead: float = 8.0


@dataclass(frozen=True)
class Prediction:
    cycles: float
    t_hit: float
    t_cold: float
    t_cf: float
    t_comp: float
    n_hit: float
    n_cold: float
    n_cf: float
    kept_fraction: float


# ---------------------------------------------------------------------------
# §V-C: kept-fraction closed forms
# ---------------------------------------------------------------------------
def kept_fraction(policy: str, s_work: float, s_llc: float, assoc: int,
                  b_bits: int = 3, bypass_variant: str = "optimal",
                  gqa: bool = False, pollution: float = 1.0) -> float:
    """Fraction of the streaming working set whose reuses hit.

    ``pollution`` scales the effective cache size down (dead data from
    retired batches, §VI-F) — 1.0 with DBP, 1/n_batches without.
    """
    if s_work <= 0:
        return 1.0
    s_eff_at = s_llc * (assoc - 1) / assoc * pollution
    s_eff_full = s_llc * pollution
    tiers = 1 << b_bits

    def at_fraction(work: float, cap: float) -> float:
        if work <= cap:
            return 1.0
        m = int(cap / (work / tiers))
        return min(m, tiers) / tiers

    if policy == "lru":
        return 1.0 if s_work <= s_eff_at else 0.0
    if policy == "dbp":
        # clean separation between adjacent working sets → full cache usable
        return 1.0 if s_work <= s_eff_full else 0.0
    if policy == "at+dbp" or policy == "at":
        return at_fraction(s_work, s_eff_at)
    if policy in ("bypass+dbp", "lru+bypass", "at+bypass", "all"):
        if gqa:
            # conservative gqa_bypass pins nothing beyond LRU behavior
            # (paper Fig. 10 d–f: bypass+dbp ≈ 1.0 under inter-core sharing)
            extra = 1.0 if s_work <= s_eff_full else 0.0
            if policy == "all":
                return max(extra, at_fraction(s_work, s_eff_at))
            return extra
        if bypass_variant == "optimal" or policy == "all":
            return min(1.0, s_eff_full / s_work)
        gear = int(bypass_variant[3:])        # fix1 / fix3 …
        protected = (tiers - gear) / tiers
        s_prot = protected * s_work
        if s_prot <= s_eff_full:
            return protected
        # at (always on with static gears) keeps top tiers of the
        # protected stream
        return at_fraction(s_prot, s_eff_at) * protected
    raise KeyError(f"unknown model policy {policy!r}")


# ---------------------------------------------------------------------------
# Profile engine: policy transforms over the reuse-distance profile
# (DESIGN.md §5; the profile itself is lowered in repro.dataflows.reuse)
# ---------------------------------------------------------------------------
def parse_model_policy(policy: str) -> Tuple[bool, bool, bool]:
    """Resolve a policy name to its mechanism flags ``(at, dbp, bypass)``."""
    if policy not in _KNOWN_POLICIES:
        raise KeyError(f"unknown model policy {policy!r}")
    return (policy in ("at", "at+dbp", "at+bypass", "all"),
            "dbp" in policy or policy == "all",
            "bypass" in policy or policy == "all")


def _gear_candidates(bypass: bool, variant: str, gqa: bool,
                     b_bits: int) -> Tuple[int, ...]:
    """Gears to evaluate: none → gear 0; static fixN → that gear; the
    conservative gqa variant bypasses nothing the model credits (§IV-E);
    dynamic ("optimal") → every gear, the paper's upper-bound treatment."""
    if not bypass or gqa:
        return (0,)
    if variant.startswith("fix"):
        return (int(variant[3:]),)
    return tuple(range((1 << b_bits) + 1))


def _hit_prob(d: np.ndarray, lo: float, hi: float) -> np.ndarray:
    """Set-associative capacity ramp: certain hit up to ``lo`` =
    ``C·(A-1)/A`` stack lines, certain miss past ``hi`` = ``C·(A+1)/A``,
    linear in between (hashed set mapping spreads a burst binomially
    over sets, so the all-or-nothing step of the closed forms becomes a
    band around the capacity)."""
    if hi <= lo:
        return (d <= lo).astype(float)
    return np.clip((hi - d) / (hi - lo), 0.0, 1.0)


def _profile_outcome(prof, llc_bytes: int, assoc: int, at: bool, dbp: bool,
                     gear: int, b_bits: int) -> dict:
    """Per-round request-class masses under one transformed profile.

    The single evaluation rule: a reuse entry hits with the probability
    that its transformed distance fits the effective capacity left to
    its mass class.  All mechanism effects are transforms applied before
    that comparison.  Cached on the profile per (geometry, mechanism)
    key — θ/λ only enter the time aggregation, so calibration reuses
    these aggregates.
    """
    key = (llc_bytes, assoc, at, dbp, gear, b_bits)
    out = prof._eval_cache.get(key)
    if out is not None:
        return out

    cap_lines = llc_bytes // prof.line_bytes
    c_lo = cap_lines * (assoc - 1) / assoc
    c_hi = cap_lines * (assoc + 1) / assoc
    num_sets = max(cap_lines // assoc, 1)
    n_tiers = 1 << b_bits

    # hardware priority tier = tag[B_BITS-1:0]; tag = line // num_sets
    t_prio = (prof.t_line // num_sets) % n_tiers
    e_prio = (prof.e_line // num_sets) % n_tiers
    fp = np.bincount(t_prio, weights=prof.t_mass.astype(float),
                     minlength=n_tiers)
    total_fp = float(fp.sum())
    if dbp and total_fp > 0:
        # dead generations retire on the fly: only the peak live stack
        # competes for capacity, spread over the tiers proportionally
        fp = fp * (prof.max_live_lines / total_fp)

    # --- bypass transform: lowest `gear` tiers never allocate ----------
    surv_tier = np.arange(n_tiers) >= gear
    fp_surv = np.where(surv_tier, fp, 0.0)
    W = float(fp_surv.sum())
    stack_total = float(fp.sum())
    bypassed = (e_prio < gear) & ~prof.e_mshr

    # --- dbp transform: dead-epoch pollution leaves the stack ----------
    d = (prof.e_dlive if dbp else prof.e_dlive + prof.e_ddead).astype(float)

    if at:
        # --- anti-thrashing transform: protect top tiers that fit -----
        order = np.arange(n_tiers - 1, -1, -1)
        cum = np.cumsum(fp_surv[order])
        prot_tier = np.zeros(n_tiers, dtype=bool)
        prot_tier[order[cum <= c_lo]] = True
        prot_mass = float(fp_surv[prot_tier].sum())
        frac_u = ((W - prot_mass) / stack_total) if stack_total else 0.0
        protected = prot_tier[e_prio] & surv_tier[e_prio]
        p_hit = np.where(protected, 1.0,
                         _hit_prob(d * frac_u, max(c_lo - prot_mass, 0.0),
                                   max(c_hi - prot_mass, 1.0)))
    else:
        shrink = (W / stack_total) if stack_total else 1.0
        p_hit = _hit_prob(d * shrink, c_lo, c_hi)

    p_hit = np.where(bypassed, 0.0, p_hit)
    p_hit = np.where(prof.e_mshr, 1.0, p_hit)

    nr = prof.n_rounds
    w = prof.e_mass.astype(float)
    h_r = np.bincount(prof.e_round, weights=w * p_hit, minlength=nr)
    cf_reuse_r = np.bincount(prof.e_round, weights=w * (1.0 - p_hit),
                             minlength=nr)
    cold_r = (prof.cold_round + prof.byp_cold_round).astype(float)
    cf_r = cf_reuse_r + prof.byp_rep_round
    # dirtied reuse-carrier lines write back when evicted: scale the
    # dirty volume by the reuse-miss fraction (fits → stays resident)
    total_reuse = float(w.sum())
    miss_frac = float(cf_reuse_r.sum()) / total_reuse if total_reuse else 0.0
    wb_r = prof.wb_round * miss_frac

    # feedback observable for the dynamic-gear controller emulation:
    # evictions ≈ allocating misses beyond the warm-up fills (the first
    # cap_lines allocations land in invalid ways and evict nothing;
    # bypassed fills never allocate).  Fraction against the *current*
    # (possibly dbp-rescaled) footprint — the rescale is uniform, so
    # this is the true bypassed-tier share.
    byp_fp_frac = (float(fp[:gear].sum()) / stack_total) if stack_total \
        else 0.0
    allocations = float((w * (1.0 - p_hit) * ~bypassed).sum()) \
        + float(prof.cold_round.sum()) * (1.0 - byp_fp_frac)
    evictions = max(allocations - cap_lines, 0.0)
    requests = float(h_r.sum() + cold_r.sum() + cf_r.sum())

    out = {
        "h_r": h_r, "cold_r": cold_r, "cf_r": cf_r, "wb_r": wb_r,
        "n_hit": float(h_r.sum()), "n_cold": float(cold_r.sum()),
        "n_cf": float(cf_r.sum()),
        "evict_rate": evictions / requests if requests else 0.0,
        "kept": float((w * p_hit).sum() / total_reuse)
        if total_reuse else 1.0,
    }
    prof._eval_cache[key] = out
    return out


def _profile_prediction(prof, outcome: dict, hw: SimConfig,
                        params: ModelParams,
                        n_rounds: Optional[int] = None) -> Prediction:
    """Eq. 1–5 applied at the simulator's round granularity (§7.2).

    ``n_rounds`` overrides the scheduling-overhead round count like the
    closed path's parameter does; by default the profile's own round
    count is charged.
    """
    issue = hw.n_cores * hw.ipc_mem
    v = hw.v_llc
    bw = hw.dram_lines_per_cycle
    h_r, cold_r = outcome["h_r"], outcome["cold_r"]
    cf_r, wb_r = outcome["cf_r"], outcome["wb_r"]
    flops_r = prof.flops_round

    t_hit = np.maximum(h_r / issue, h_r / v)
    t_cold = np.maximum(np.maximum(cold_r / issue, cold_r / v),
                        cold_r / (params.theta1 * bw))
    # Eq. 3 per round: conflict-demand density over the round's stream
    n_mem = h_r + cold_r + cf_r
    denom = n_mem / hw.ipc_mem + flops_r / hw.core_flops_per_cycle
    eta = np.divide(cf_r / hw.ipc_mem, denom,
                    out=np.zeros_like(cf_r), where=denom > 0)
    v_dmd = np.minimum(eta * issue, v)
    bw_cf = np.clip(params.lam * v_dmd, params.theta2 * bw,
                    params.theta3 * bw)
    t_cf = np.maximum(np.maximum(cf_r / issue, cf_r / v),
                      (cf_r + wb_r) / bw_cf)
    t_comp = flops_r / (hw.n_cores * hw.core_flops_per_cycle)

    overhead_rounds = prof.n_rounds if n_rounds is None else n_rounds
    cycles = float((t_hit + t_cold + np.maximum(t_comp, t_cf)).sum()) \
        + params.round_overhead * overhead_rounds
    return Prediction(
        cycles=cycles, t_hit=float(t_hit.sum()), t_cold=float(t_cold.sum()),
        t_cf=float(t_cf.sum()), t_comp=float(t_comp.sum()),
        n_hit=outcome["n_hit"], n_cold=outcome["n_cold"],
        n_cf=outcome["n_cf"], kept_fraction=outcome["kept"])


def _predict_profile(counts: DataflowCounts, llc_bytes: int, policy: str,
                     hw: SimConfig, params: ModelParams,
                     bypass_variant: str, gqa: bool, b_bits: int,
                     n_rounds: Optional[int] = None) -> Prediction:
    prof = counts.reuse_profile
    at, dbp, bypass = parse_model_policy(policy)
    if bypass and bypass_variant.startswith("fix"):
        at = True          # static gears run with at enabled (§VI-E)
    gears = _gear_candidates(bypass, bypass_variant, gqa, b_bits)
    if len(gears) > 1:
        # dynamic bypassing: emulate the per-slice feedback law (§IV-D)
        # instead of assuming the best-case gear — the controller raises
        # the gear until the eviction rate drops under its upper bound,
        # so it converges to the *smallest* such gear (and to max gear
        # when no gear tames the rate), even when that over-bypasses and
        # destroys inter-core reuse (the §IV-E failure the gqa variant
        # exists to avoid).
        from .policies import PolicyConfig
        ub = PolicyConfig().bypass_ub
        chosen = gears[-1]
        for gear in gears:
            rate = _profile_outcome(prof, llc_bytes, hw.llc_assoc, at, dbp,
                                    gear, b_bits)["evict_rate"]
            if rate <= ub:
                chosen = gear
                break
        gears = (chosen,)
    best: Optional[Prediction] = None
    for gear in gears:
        outcome = _profile_outcome(prof, llc_bytes, hw.llc_assoc, at, dbp,
                                   gear, b_bits)
        pred = _profile_prediction(prof, outcome, hw, params, n_rounds)
        if best is None or pred.cycles < best.cycles:
            best = pred
    return best


# ---------------------------------------------------------------------------
# Eq. 1–5
# ---------------------------------------------------------------------------
def predict(counts: DataflowCounts, llc_bytes: int, policy: str,
            hw: Optional[SimConfig] = None,
            params: Optional[ModelParams] = None,
            bypass_variant: str = "optimal",
            gqa: bool = False,
            b_bits: int = 3,
            n_rounds: Optional[int] = None,
            model: str = "profile") -> Prediction:
    """Predict cycles for one (dataflow, cache size, policy) point.

    ``model="profile"`` (default) evaluates the reuse-distance profile
    attached to ``counts`` and falls back to the closed forms when the
    producer skipped the profile lowering; ``model="closed"`` forces the
    original §V-C scalar step functions.
    """
    hw = hw or SimConfig()
    params = params or ModelParams()
    if model not in ("profile", "closed"):
        raise KeyError(f"unknown model {model!r}")
    if model == "profile" and counts.reuse_profile is not None:
        return _predict_profile(counts, llc_bytes, policy, hw, params,
                                bypass_variant, gqa, b_bits, n_rounds)

    pollution = 1.0
    if counts.n_batches > 1 and policy == "lru":
        pollution = 1.0 / counts.n_batches
    if counts.n_batches > 1 and "dbp" not in policy and policy != "lru":
        pollution = 1.0 / counts.n_batches

    f = kept_fraction(policy, counts.s_work_active, llc_bytes,
                      hw.llc_assoc, b_bits, bypass_variant, gqa, pollution)

    temporal_hits = f * counts.n_temporal_reuse
    intercore_hits = float(counts.n_intercore_reuse)
    lost_intercore = 0.0
    if (not gqa and counts.n_intercore_reuse
            and policy in ("bypass+dbp", "all", "lru+bypass", "at+bypass")):
        # blind bypassing in sharing dataflows loses the bypassed share of
        # inter-core reuses and pays extra DRAM fetches (paper §IV-E)
        if bypass_variant.startswith("fix"):
            gear_frac = int(bypass_variant[3:]) / (1 << b_bits)
        else:
            gear_frac = max(0.0, 1.0 - f)
        lost_intercore = gear_frac * intercore_hits
        intercore_hits -= lost_intercore

    n_hit = temporal_hits + intercore_hits
    n_cold = counts.n_kv_distinct + counts.n_bypass_lines
    n_cf = (counts.n_temporal_reuse - temporal_hits) + lost_intercore
    n_mem = counts.n_kv_accesses + counts.n_bypass_lines

    N, ipc = hw.n_cores, hw.ipc_mem
    v_llc = hw.v_llc
    bw = hw.dram_lines_per_cycle

    t_comp = counts.flops_total / (N * hw.core_flops_per_cycle)
    t_hit = max(n_hit / (N * ipc), n_hit / v_llc)
    bw_cold = params.theta1 * bw
    t_cold = max(n_cold / (N * ipc), n_cold / v_llc, n_cold / bw_cold)

    # Eq. 3: conflict-miss demand density over the instruction stream
    ipc_comp = hw.core_flops_per_cycle
    denom = n_mem / ipc + counts.flops_total / ipc_comp
    eta_cf = (n_cf / ipc) / denom if denom > 0 else 0.0
    v_cf_dmd = min(eta_cf * N * ipc, v_llc)
    bw_cf = float(np.clip(params.lam * v_cf_dmd,
                          params.theta2 * bw, params.theta3 * bw))
    t_cf = max(n_cf / (N * ipc), n_cf / v_llc, n_cf / bw_cf) if n_cf else 0.0

    cycles = t_hit + t_cold + max(t_comp, t_cf)
    if n_rounds:
        cycles += params.round_overhead * n_rounds

    return Prediction(cycles=cycles, t_hit=t_hit, t_cold=t_cold, t_cf=t_cf,
                      t_comp=t_comp, n_hit=n_hit, n_cold=n_cold, n_cf=n_cf,
                      kept_fraction=f)


# ---------------------------------------------------------------------------
# Calibration (§V-D: θ, λ fitted per hardware/policy combination)
# ---------------------------------------------------------------------------
def fit_params(points: Sequence[Tuple[DataflowCounts, int, str, str, bool,
                                      Optional[int], float]],
               hw: Optional[SimConfig] = None,
               model: str = "profile") -> ModelParams:
    """Fit (θ1, θ2, θ3, λ) to simulator measurements.

    ``points``: (counts, llc_bytes, policy, bypass_variant, gqa, n_rounds,
    simulated_cycles) tuples.  Coarse grid search + refinement on mean
    squared log error, mirroring the paper's empirical fitting.  ``model``
    selects the hit engine the constants are fitted for (the profile
    engine caches its θ-independent request aggregates, so the grid
    search only re-runs the cheap time aggregation).
    """
    hw = hw or SimConfig()

    def loss(p: ModelParams) -> float:
        err = 0.0
        for counts, llc, pol, variant, gqa, rounds, target in points:
            pred = predict(counts, llc, pol, hw, p, variant, gqa,
                           n_rounds=rounds, model=model).cycles
            err += (math.log(max(pred, 1.0)) - math.log(max(target, 1.0))) ** 2
        return err / max(len(points), 1)

    best = ModelParams()
    best_loss = loss(best)
    grid = product(
        (0.7, 0.8, 0.9, 1.0),          # theta1
        (0.1, 0.2, 0.3),               # theta2
        (0.45, 0.6, 0.75, 0.9),        # theta3
        (0.6, 0.8, 1.0, 1.25),         # lambda
    )
    for t1, t2, t3, lam in grid:
        if t2 >= t3:
            continue
        p = ModelParams(t1, t2, t3, lam)
        l = loss(p)
        if l < best_loss:
            best, best_loss = p, l
    # local refinement around the best point
    for _ in range(2):
        t1, t2, t3, lam = best.theta1, best.theta2, best.theta3, best.lam
        for d1, d2, d3, dl in product((-0.05, 0.0, 0.05), repeat=4):
            p = ModelParams(
                float(np.clip(t1 + d1, 0.3, 1.0)),
                float(np.clip(t2 + d2, 0.05, 0.5)),
                float(np.clip(t3 + d3, 0.2, 1.0)),
                float(np.clip(lam + dl, 0.2, 2.0)))
            if p.theta2 >= p.theta3:
                continue
            l = loss(p)
            if l < best_loss:
                best, best_loss = p, l
    return best


# ---------------------------------------------------------------------------
# Validation metrics (paper §VI-G1: R² = 0.997, Kendall τ = 0.934)
# ---------------------------------------------------------------------------
def r_squared(pred: np.ndarray, target: np.ndarray) -> float:
    target = np.asarray(target, dtype=float)
    pred = np.asarray(pred, dtype=float)
    ss_res = float(((target - pred) ** 2).sum())
    ss_tot = float(((target - target.mean()) ** 2).sum())
    return 1.0 - ss_res / ss_tot if ss_tot else 1.0


def kendall_tau(pred: np.ndarray, target: np.ndarray) -> float:
    pred = np.asarray(pred, dtype=float)
    target = np.asarray(target, dtype=float)
    n = pred.shape[0]
    if n < 2:
        return 1.0
    dp = np.sign(pred[:, None] - pred[None, :])
    dt = np.sign(target[:, None] - target[None, :])
    iu = np.triu_indices(n, k=1)
    s = dp[iu] * dt[iu]
    concordant = float((s > 0).sum())
    discordant = float((s < 0).sum())
    denom = n * (n - 1) / 2
    return (concordant - discordant) / denom

"""Cache-integrated analytical model (paper §V, Eq. 1–5).

Predicts execution time for a dataflow from closed-form request counts
(``traces.fa2_counts``) — no simulation in the loop.  The paper's
structure is kept exactly:

* Eq. 1: each request class is bottlenecked by the slowest of
  {core LSU issue, LLC throughput, DRAM bandwidth}.
* Eq. 2: ``t = t_hit + t_cold + max(t_comp, t_cf)`` — cold misses are
  bursty and exposed; conflict misses are dispersed and overlap with
  compute.
* Eq. 3–5: conflict-miss bandwidth from the demand rate ``v_cf,dmd`` with
  fitted constants θ1, θ2, θ3, λ (per hardware/policy family, §V-D).
* §V-C hit estimation: K/V streaming reuse → LRU hit rate is a step
  function of (reuse distance ≤ cache size); anti-thrashing keeps
  ``S_kept = S_work·M/2^B_BITS ≤ S_LLC·(A-1)/A``; *ideal* bypassing keeps
  exactly the cache size (and may use the whole cache, §VI-E3); inter-core
  reuses are captured by LLC+MSHR in a single ``v_LLC`` term.

The model "does not need to precisely model every variant … it is
acceptable as long as it provides a proxy or a bound to a properly-set
policy" (§V-A): dynamic bypassing is modeled by its upper bound, the
optimal static gear, exactly as the paper does.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from itertools import product
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .simulator import SimConfig
from .traces import DataflowCounts

MODEL_POLICIES = ("lru", "dbp", "at+dbp", "bypass+dbp", "all")
BYPASS_VARIANTS = ("fix1", "fix3", "optimal")


@dataclass(frozen=True)
class ModelParams:
    """Fitted constants of Eq. 4–5 (+ per-round scheduling overhead)."""

    theta1: float = 0.90      # cold-burst DRAM efficiency
    theta2: float = 0.25      # conflict-miss bandwidth floor (×BW)
    theta3: float = 0.65      # conflict-miss bandwidth ceiling (×BW)
    lam: float = 1.00         # demand-rate scale λ
    round_overhead: float = 8.0


@dataclass(frozen=True)
class Prediction:
    cycles: float
    t_hit: float
    t_cold: float
    t_cf: float
    t_comp: float
    n_hit: float
    n_cold: float
    n_cf: float
    kept_fraction: float


# ---------------------------------------------------------------------------
# §V-C: kept-fraction closed forms
# ---------------------------------------------------------------------------
def kept_fraction(policy: str, s_work: float, s_llc: float, assoc: int,
                  b_bits: int = 3, bypass_variant: str = "optimal",
                  gqa: bool = False, pollution: float = 1.0) -> float:
    """Fraction of the streaming working set whose reuses hit.

    ``pollution`` scales the effective cache size down (dead data from
    retired batches, §VI-F) — 1.0 with DBP, 1/n_batches without.
    """
    if s_work <= 0:
        return 1.0
    s_eff_at = s_llc * (assoc - 1) / assoc * pollution
    s_eff_full = s_llc * pollution
    tiers = 1 << b_bits

    def at_fraction(work: float, cap: float) -> float:
        if work <= cap:
            return 1.0
        m = int(cap / (work / tiers))
        return min(m, tiers) / tiers

    if policy == "lru":
        return 1.0 if s_work <= s_eff_at else 0.0
    if policy == "dbp":
        # clean separation between adjacent working sets → full cache usable
        return 1.0 if s_work <= s_eff_full else 0.0
    if policy == "at+dbp" or policy == "at":
        return at_fraction(s_work, s_eff_at)
    if policy in ("bypass+dbp", "lru+bypass", "at+bypass", "all"):
        if gqa:
            # conservative gqa_bypass pins nothing beyond LRU behavior
            # (paper Fig. 10 d–f: bypass+dbp ≈ 1.0 under inter-core sharing)
            extra = 1.0 if s_work <= s_eff_full else 0.0
            if policy == "all":
                return max(extra, at_fraction(s_work, s_eff_at))
            return extra
        if bypass_variant == "optimal" or policy == "all":
            return min(1.0, s_eff_full / s_work)
        gear = int(bypass_variant[3:])        # fix1 / fix3 …
        protected = (tiers - gear) / tiers
        s_prot = protected * s_work
        if s_prot <= s_eff_full:
            return protected
        # at (always on with static gears) keeps top tiers of the
        # protected stream
        return at_fraction(s_prot, s_eff_at) * protected
    raise KeyError(f"unknown model policy {policy!r}")


# ---------------------------------------------------------------------------
# Eq. 1–5
# ---------------------------------------------------------------------------
def predict(counts: DataflowCounts, llc_bytes: int, policy: str,
            hw: Optional[SimConfig] = None,
            params: Optional[ModelParams] = None,
            bypass_variant: str = "optimal",
            gqa: bool = False,
            b_bits: int = 3,
            n_rounds: Optional[int] = None) -> Prediction:
    hw = hw or SimConfig()
    params = params or ModelParams()

    pollution = 1.0
    if counts.n_batches > 1 and policy == "lru":
        pollution = 1.0 / counts.n_batches
    if counts.n_batches > 1 and "dbp" not in policy and policy != "lru":
        pollution = 1.0 / counts.n_batches

    f = kept_fraction(policy, counts.s_work_active, llc_bytes,
                      hw.llc_assoc, b_bits, bypass_variant, gqa, pollution)

    temporal_hits = f * counts.n_temporal_reuse
    intercore_hits = float(counts.n_intercore_reuse)
    lost_intercore = 0.0
    if (not gqa and counts.n_intercore_reuse
            and policy in ("bypass+dbp", "all", "lru+bypass", "at+bypass")):
        # blind bypassing in sharing dataflows loses the bypassed share of
        # inter-core reuses and pays extra DRAM fetches (paper §IV-E)
        if bypass_variant.startswith("fix"):
            gear_frac = int(bypass_variant[3:]) / (1 << b_bits)
        else:
            gear_frac = max(0.0, 1.0 - f)
        lost_intercore = gear_frac * intercore_hits
        intercore_hits -= lost_intercore

    n_hit = temporal_hits + intercore_hits
    n_cold = counts.n_kv_distinct + counts.n_bypass_lines
    n_cf = (counts.n_temporal_reuse - temporal_hits) + lost_intercore
    n_mem = counts.n_kv_accesses + counts.n_bypass_lines

    N, ipc = hw.n_cores, hw.ipc_mem
    v_llc = hw.v_llc
    bw = hw.dram_lines_per_cycle

    t_comp = counts.flops_total / (N * hw.core_flops_per_cycle)
    t_hit = max(n_hit / (N * ipc), n_hit / v_llc)
    bw_cold = params.theta1 * bw
    t_cold = max(n_cold / (N * ipc), n_cold / v_llc, n_cold / bw_cold)

    # Eq. 3: conflict-miss demand density over the instruction stream
    ipc_comp = hw.core_flops_per_cycle
    denom = n_mem / ipc + counts.flops_total / ipc_comp
    eta_cf = (n_cf / ipc) / denom if denom > 0 else 0.0
    v_cf_dmd = min(eta_cf * N * ipc, v_llc)
    bw_cf = float(np.clip(params.lam * v_cf_dmd,
                          params.theta2 * bw, params.theta3 * bw))
    t_cf = max(n_cf / (N * ipc), n_cf / v_llc, n_cf / bw_cf) if n_cf else 0.0

    cycles = t_hit + t_cold + max(t_comp, t_cf)
    if n_rounds:
        cycles += params.round_overhead * n_rounds

    return Prediction(cycles=cycles, t_hit=t_hit, t_cold=t_cold, t_cf=t_cf,
                      t_comp=t_comp, n_hit=n_hit, n_cold=n_cold, n_cf=n_cf,
                      kept_fraction=f)


# ---------------------------------------------------------------------------
# Calibration (§V-D: θ, λ fitted per hardware/policy combination)
# ---------------------------------------------------------------------------
def fit_params(points: Sequence[Tuple[DataflowCounts, int, str, str, bool,
                                      Optional[int], float]],
               hw: Optional[SimConfig] = None) -> ModelParams:
    """Fit (θ1, θ2, θ3, λ) to simulator measurements.

    ``points``: (counts, llc_bytes, policy, bypass_variant, gqa, n_rounds,
    simulated_cycles) tuples.  Coarse grid search + refinement on mean
    squared log error, mirroring the paper's empirical fitting.
    """
    hw = hw or SimConfig()

    def loss(p: ModelParams) -> float:
        err = 0.0
        for counts, llc, pol, variant, gqa, rounds, target in points:
            pred = predict(counts, llc, pol, hw, p, variant, gqa,
                           n_rounds=rounds).cycles
            err += (math.log(max(pred, 1.0)) - math.log(max(target, 1.0))) ** 2
        return err / max(len(points), 1)

    best = ModelParams()
    best_loss = loss(best)
    grid = product(
        (0.7, 0.8, 0.9, 1.0),          # theta1
        (0.1, 0.2, 0.3),               # theta2
        (0.45, 0.6, 0.75, 0.9),        # theta3
        (0.6, 0.8, 1.0, 1.25),         # lambda
    )
    for t1, t2, t3, lam in grid:
        if t2 >= t3:
            continue
        p = ModelParams(t1, t2, t3, lam)
        l = loss(p)
        if l < best_loss:
            best, best_loss = p, l
    # local refinement around the best point
    for _ in range(2):
        t1, t2, t3, lam = best.theta1, best.theta2, best.theta3, best.lam
        for d1, d2, d3, dl in product((-0.05, 0.0, 0.05), repeat=4):
            p = ModelParams(
                float(np.clip(t1 + d1, 0.3, 1.0)),
                float(np.clip(t2 + d2, 0.05, 0.5)),
                float(np.clip(t3 + d3, 0.2, 1.0)),
                float(np.clip(lam + dl, 0.2, 2.0)))
            if p.theta2 >= p.theta3:
                continue
            l = loss(p)
            if l < best_loss:
                best, best_loss = p, l
    return best


# ---------------------------------------------------------------------------
# Validation metrics (paper §VI-G1: R² = 0.997, Kendall τ = 0.934)
# ---------------------------------------------------------------------------
def r_squared(pred: np.ndarray, target: np.ndarray) -> float:
    target = np.asarray(target, dtype=float)
    pred = np.asarray(pred, dtype=float)
    ss_res = float(((target - pred) ** 2).sum())
    ss_tot = float(((target - target.mean()) ** 2).sum())
    return 1.0 - ss_res / ss_tot if ss_tot else 1.0


def kendall_tau(pred: np.ndarray, target: np.ndarray) -> float:
    pred = np.asarray(pred, dtype=float)
    target = np.asarray(target, dtype=float)
    n = pred.shape[0]
    if n < 2:
        return 1.0
    dp = np.sign(pred[:, None] - pred[None, :])
    dt = np.sign(target[:, None] - target[None, :])
    iu = np.triu_indices(n, k=1)
    s = dp[iu] * dt[iu]
    concordant = float((s > 0).sum())
    discordant = float((s < 0).sum())
    denom = n * (n - 1) / 2
    return (concordant - discordant) / denom

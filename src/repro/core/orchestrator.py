"""CacheOrchestrator — the TPU-native transfer of DCO (DESIGN.md §3).

On TPU there is no shared hardware LLC under policy control; the
capacity-constrained fast memory is VMEM and every placement decision is
made at trace/compile time.  The orchestrator therefore executes the
paper's *policies* as a planner:

* **anti-thrashing → pinned subset**: the same priority trick — score a
  KV tile by the low ``B_BITS`` bits of its tile index — selects a
  deterministic subset ``S_kept = S_work · M / 2^B_BITS ≤ budget`` that is
  kept VMEM-resident across the whole Q loop of a FlashAttention kernel.
* **dynamic bypassing → streamed remainder**: tiles below the chosen gear
  are re-fetched from HBM per Q block (the Pallas BlockSpec index_map
  re-walks them), sparing VMEM exactly like LLC bypass spares cache space.
  The gear is chosen *per shape* from the analytical model instead of a
  runtime eviction-rate loop (the information hardware infers from
  eviction rates is exact at trace time here).
* **dead-block prediction → buffer lifetime**: per-tensor ``nAcc`` from
  the dataflow tells the serve engine when a batch's KV pages retire
  (multi-batch scenario of §VI-F) so their slots are reused immediately.

The plan is consumed by ``repro.kernels.flash_attention`` (pinned/streamed
split) and by ``repro.serve`` (KV page retirement).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict
from typing import Tuple

import numpy as np

from .tmu import TensorMeta


@dataclass(frozen=True)
class TensorPlanEntry:
    """Residency decision for one tensor."""

    tensor_id: int
    pinned_tiles: Tuple[int, ...]     # tile indices kept resident
    streamed_tiles: Tuple[int, ...]   # tile indices re-fetched per use
    gear: int                         # chosen B_GEAR (tiles with prio<gear stream)
    n_acc: int                        # dataflow lifetime (for retirement)


@dataclass(frozen=True)
class OrchestrationPlan:
    entries: Dict[int, TensorPlanEntry]
    vmem_budget_bytes: int
    pinned_bytes: int
    b_bits: int

    @property
    def pinned_fraction(self) -> float:
        total = sum(len(e.pinned_tiles) + len(e.streamed_tiles)
                    for e in self.entries.values())
        pinned = sum(len(e.pinned_tiles) for e in self.entries.values())
        return pinned / total if total else 1.0


class CacheOrchestrator:
    """Plan VMEM residency for a set of registered tensors.

    Mirrors the TMU software interface: ``register`` tensors with their
    dataflow metadata, then ``plan`` against a VMEM budget.
    """

    def __init__(self, vmem_budget_bytes: int, b_bits: int = 3,
                 reserve_fraction: float = 1.0 / 8.0):
        """``reserve_fraction`` mirrors the paper's (A-1)/A term: a share
        of the budget is set aside for streaming double-buffers, just as
        ``at`` leaves one way per set for in-flight lines."""
        self.vmem_budget = vmem_budget_bytes
        self.b_bits = b_bits
        self.reserve_fraction = reserve_fraction
        self._tensors: Dict[int, TensorMeta] = {}

    def register(self, meta: TensorMeta) -> None:
        if meta.tensor_id in self._tensors:
            raise ValueError(f"tensor {meta.tensor_id} already registered")
        self._tensors[meta.tensor_id] = meta

    def register_many(self, metas) -> None:
        """Register a whole dataflow's tensors (e.g. the output of
        ``repro.dataflows.tmu_metadata``) in one call."""
        for meta in metas:
            self.register(meta)

    def clear(self, tensor_id: int) -> None:
        self._tensors.pop(tensor_id, None)

    # ------------------------------------------------------------------
    def plan(self) -> OrchestrationPlan:
        """Choose the pinned subset with the paper's S_kept rule.

        Tensors are ranked by reuse (``n_acc``) so the most-reused streams
        claim residency first; within a tensor, the priority score is the
        low ``B_BITS`` bits of the tile index and the gear is the largest
        value such that pinned bytes fit the budget — the compile-time
        equivalent of the self-adaptive mechanism.
        """
        usable = int(self.vmem_budget * (1.0 - self.reserve_fraction))
        tiers = 1 << self.b_bits
        entries: Dict[int, TensorPlanEntry] = {}
        pinned_bytes = 0

        order = sorted(self._tensors.values(),
                       key=lambda m: (-m.n_acc, m.tensor_id))
        for meta in order:
            tiles = np.arange(meta.num_tiles)
            prio = tiles & (tiers - 1)
            if meta.bypass_all or meta.n_acc <= 1:
                gear = tiers          # stream everything: no reuse to save
            else:
                remaining = usable - pinned_bytes
                # pin tiers from the top (highest priority) downwards
                gear = tiers
                for g in range(tiers, -1, -1):
                    n_pinned = int((prio >= g).sum())
                    if n_pinned * meta.tile_bytes <= remaining:
                        gear = g
                    else:
                        break
            keep = prio >= gear
            pinned = tuple(int(t) for t in tiles[keep])
            streamed = tuple(int(t) for t in tiles[~keep])
            pinned_bytes += len(pinned) * meta.tile_bytes
            entries[meta.tensor_id] = TensorPlanEntry(
                tensor_id=meta.tensor_id, pinned_tiles=pinned,
                streamed_tiles=streamed, gear=gear, n_acc=meta.n_acc)

        return OrchestrationPlan(entries=entries,
                                 vmem_budget_bytes=self.vmem_budget,
                                 pinned_bytes=pinned_bytes,
                                 b_bits=self.b_bits)

    # ------------------------------------------------------------------
    def plan_kv_split(self, seq_len: int, kv_tile_rows: int,
                      bytes_per_row: int) -> Tuple[int, int]:
        """Convenience for the flash-attention kernel: split a KV stream of
        ``seq_len`` rows into (pinned_rows, streamed_rows), pinned rows
        chosen as a contiguous prefix (TPU-friendly: one dense block)
        whose size matches the S_kept the tag-bit policy would keep."""
        usable = int(self.vmem_budget * (1.0 - self.reserve_fraction))
        total_rows = seq_len
        total_bytes = total_rows * bytes_per_row
        tiers = 1 << self.b_bits
        if total_bytes <= usable:
            return total_rows, 0
        tile_bytes = kv_tile_rows * bytes_per_row
        n_tiles = total_rows // kv_tile_rows
        m = min(int(usable / max(tile_bytes, 1) / max(n_tiles / tiers, 1e-9)),
                tiers)
        kept_tiles = n_tiles * m // tiers
        pinned_rows = kept_tiles * kv_tile_rows
        return pinned_rows, total_rows - pinned_rows

"""Shared last-level cache model (sliced, set-associative) for DCO.

The LLC is modeled at cache-line granularity with vectorized numpy state so
that paper-scale traces (hundreds of MB of traffic) simulate in seconds.
Bursts of *unique-set* line addresses are processed in one shot; the
simulator's bulk tile transfers are contiguous in the tiled address layout
so a tile burst touches consecutive sets, and :meth:`SharedLLC.access_burst`
internally splits bursts whose set indices would collide.

Replacement priority (paper §IV-A): dead blocks (TMU dead FIFO match) →
anti-thrashing lowest-``tag[B_BITS-1:0]``-tier → LRU tie-break.
Bypass (paper §IV-D): on a miss, incoming lines whose priority is below the
slice's ``B_GEAR`` are not allocated.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict
from typing import List
from typing import Optional
from typing import Tuple

import numpy as np

from .events import EV_BYPASS
from .events import EV_EVICT
from .events import EV_FILL
from .events import EV_HIT
from .events import EV_WB
from .policies import BYPASS_NONE
from .policies import GearController
from .policies import PolicyConfig
from .policies import make_controller
from .tmu import TMU

# Access outcome codes (returned per line).  The numeric values encode
# the outcome arithmetically: miss code = 1 + seen_before + 2*bypassed.
HIT = 0
COLD_MISS = 1
CONFLICT_MISS = 2
BYPASSED_COLD = 3
BYPASSED_CONFLICT = 4

_MISS_CODES = (COLD_MISS, CONFLICT_MISS, BYPASSED_COLD, BYPASSED_CONFLICT)

# sentinel for "invalid way" in the last_use / prio state arrays: larger
# than any real LRU stamp or priority, so victim selection needs no
# validity masking on its hot path
_BIG = np.int64(1) << 60


@dataclass(frozen=True)
class CacheGeometry:
    size_bytes: int
    line_bytes: int = 128
    assoc: int = 8
    n_slices: int = 32
    # XOR set-index hashing (standard in sliced LLCs): folds tag bits into
    # the set index so power-of-2 tensor strides don't alias onto the same
    # sets.  tag_of is unchanged (tag = full line//num_sets), so lookups
    # stay exact.
    hash_sets: bool = True

    @property
    def num_lines(self) -> int:
        return self.size_bytes // self.line_bytes

    @property
    def num_sets(self) -> int:
        return self.num_lines // self.assoc

    def __post_init__(self) -> None:
        if self.num_lines % self.assoc:
            raise ValueError("cache size must be a multiple of line*assoc")
        ns = self.num_sets
        if ns & (ns - 1):
            raise ValueError("number of sets must be a power of two")

    def set_of(self, line_addr: np.ndarray) -> np.ndarray:
        line = line_addr // self.line_bytes
        if self.hash_sets:
            # Fibonacci-fold the tag into the index: within one aligned
            # num_sets-line block the mapping stays a bijection (no
            # intra-tile collisions), while blocks at power-of-2 strides
            # land in decorrelated set bands.
            tag = line // self.num_sets
            line = line ^ (tag * 0x9E3779B1)
        return line % self.num_sets

    def tag_of(self, line_addr: np.ndarray) -> np.ndarray:
        return (line_addr // self.line_bytes) // self.num_sets

    def line_addr_of(self, set_idx: np.ndarray,
                     tags: np.ndarray) -> np.ndarray:
        """Inverse of ``(set_of, tag_of)``: reconstruct the byte address
        of a resident line from its (set, tag).  Exact because the
        Fibonacci fold XORs into a power-of-two index — used by the
        event layer to attribute victims back to tensors/tenants."""
        low = set_idx
        if self.hash_sets:
            low = (set_idx ^ (tags * 0x9E3779B1)) % self.num_sets
        return (tags * self.num_sets + low) * self.line_bytes

    def slice_of_set(self, set_idx: np.ndarray) -> np.ndarray:
        return set_idx % self.n_slices


@dataclass
class AccessPlan:
    """Precomputed burst structure for :meth:`SharedLLC.access_planned`.

    Holds what :meth:`SharedLLC.access_burst` would recompute on every
    call for a fixed (addresses, geometry) pair: the set index of every
    line and the same-set pass split.  ``passes`` is ``None`` when all
    sets in the burst are distinct (single-shot fast path); otherwise it
    lists, per pass, the ascending line indices whose set's k-th
    occurrence falls in that pass — byte-identical chunking to
    ``access_burst``.  Plans are geometry-specific but policy-independent,
    so a policy sweep computes them once (see
    ``CompiledTrace.plans_for``)."""

    line_addrs: np.ndarray
    sets: np.ndarray
    passes: Optional[List[np.ndarray]] = None
    tags: Optional[np.ndarray] = None


class SharedLLC:
    """Vectorized set-associative shared cache with DCO policies.

    ``tenant_map`` (multi-tenant composites, DESIGN.md §8.4) is the
    sorted ``(region_start_addrs, tenant_ids)`` pair from
    ``Trace.tenant_region_starts``: write-backs are attributed to the
    *victim line's* tenant (``tenant_wb``), and — with the opt-in
    ``policy.per_tenant_gears`` — the dynamic-bypass controller runs
    one feedback loop per tenant, each access consulting and charging
    its own tenant's gear.
    """

    def __init__(self, geom: CacheGeometry, policy: PolicyConfig,
                 tmu: Optional[TMU] = None,
                 tenant_map: Optional[Tuple[np.ndarray, np.ndarray]] = None):
        self.geom = geom
        self.policy = policy
        self.tmu = tmu
        S, A = geom.num_sets, geom.assoc
        self.tags = np.full((S, A), -1, dtype=np.int64)
        self.valid = np.zeros((S, A), dtype=bool)
        self.dirty = np.zeros((S, A), dtype=bool)
        # invariant: invalid ways hold _BIG in last_use/prio (and -1 in
        # tags), so lookup and victim selection skip validity masking
        self.last_use = np.full((S, A), _BIG, dtype=np.int64)
        self.prio = np.full((S, A), _BIG, dtype=np.int64)
        # owning tensor id per resident line (event attribution that
        # stays exact when a pooled allocator recycles addresses across
        # tensor generations); maintained only when callers thread tids
        self.owner = np.full((S, A), -1, dtype=np.int64)
        self._clock = 0  # monotone access counter for LRU
        # tenant attribution state: regions are huge and aligned, so the
        # byte-address region map projects exactly onto tag space
        # (tag = line // num_sets is monotone in the address)
        self.n_tenants = 1
        self._tenant_tag_starts: Optional[np.ndarray] = None
        self._tenant_ids: Optional[np.ndarray] = None
        self.tenant_wb: Optional[np.ndarray] = None
        if tenant_map is not None:
            starts, tens = tenant_map
            tag_bytes = geom.line_bytes * geom.num_sets
            if (starts % tag_bytes).any():
                # a region base inside a tag region would silently
                # misattribute every access near the boundary — the
                # composite's region alignment must cover one tag
                # (compose_time_sliced's REGION_ALIGN_BYTES does for
                # every suite geometry; huge LLCs need a larger one)
                raise ValueError(
                    f"tenant region bases must be multiples of the tag "
                    f"granularity num_sets*line_bytes={tag_bytes}; "
                    f"recompose with region_align_bytes>={tag_bytes}")
            self.n_tenants = int(tens.max()) + 1
            self._tenant_tag_starts = starts // tag_bytes
            self._tenant_ids = tens
            self.tenant_wb = np.zeros(self.n_tenants, dtype=np.int64)
        self.controller: Optional[GearController] = make_controller(
            geom.n_slices, policy, self.n_tenants)
        self._per_tenant_gears = (self.controller is not None
                                  and self.controller.n_tenants > 1)
        self.stats: Dict[str, int] = {
            "hits": 0, "cold_misses": 0, "conflict_misses": 0,
            "bypassed": 0, "evictions": 0, "dead_evictions": 0,
            "writebacks": 0,
        }
        self._prio_mask = (1 << policy.b_bits) - 1 if policy.b_bits else 0
        # opt-in event telemetry (repro.core.events.EventSink); every
        # emission site is guarded by `sink is not None` so the hot path
        # is untouched when tracing is off
        self.sink = None

    # ------------------------------------------------------------------
    def tenant_of_tags(self, tags: np.ndarray) -> np.ndarray:
        """Tenant index of each cache tag (requires a tenant map)."""
        idx = np.searchsorted(self._tenant_tag_starts, tags,
                              side="right") - 1
        return self._tenant_ids[np.maximum(idx, 0)]

    # ------------------------------------------------------------------
    def _priorities(self, tags: np.ndarray) -> np.ndarray:
        if self.tmu is not None:
            # TMU owns the bit slicing; mask form is identical but keeps a
            # single source of truth for B_BITS.
            mask = (1 << self.tmu.params.b_bits) - 1
            return tags & mask
        return tags & self._prio_mask

    def gear_of(self, slice_ids: np.ndarray,
                tenant_ids: Optional[np.ndarray] = None) -> np.ndarray:
        if self.controller is None:
            return np.zeros_like(slice_ids)
        return self.controller.gears_at(slice_ids, tenant_ids)

    # ------------------------------------------------------------------
    def access_burst(
        self,
        line_addrs: np.ndarray,
        *,
        seen_before: np.ndarray,
        is_write=False,
        bypass_eligible=True,
        force_bypass=False,
        cores=None,
        tids=None,
    ) -> np.ndarray:
        """Access a burst of line addresses; returns per-line outcome codes.

        ``seen_before``    bool per line: fetched from DRAM before (cold
                           vs conflict classification, paper §V-B).
        ``bypass_eligible`` gqa_bypass gating: only the slower core of a
                           sharing pair may bypass (simulator decides);
                           scalar or per-line bool array.
        ``force_bypass``   whole-tensor bypass (TMU ``bypass_all``), e.g.
                           Q/O tensors in FlashAttention; scalar or array.
        ``cores``          optional int64 array (issuing core per line),
                           only consulted for event-trace attribution
                           when a sink is attached.
        ``tids``           optional int64 array (owning tensor per line):
                           exact event attribution under address reuse —
                           accesses carry their tensor, and the per-way
                           ``owner`` state attributes evictions and
                           write-backs to the victim's tensor.

        Duplicate line addresses within one burst model MSHR behavior:
        the second occurrence of an *allocated* line hits (MSHR/LLC hit —
        the paper treats both classes at ``v_LLC``, §V-C), while duplicates
        of a *bypassed* line miss again (the paper's "bypassing blindly
        will miss some inter-core reuse opportunities", §IV-E).
        """
        line_addrs = np.asarray(line_addrs, dtype=np.int64)
        out = np.empty(line_addrs.shape[0], dtype=np.int64)
        sets = self.geom.set_of(line_addrs)
        n = line_addrs.shape[0]
        if n == 0:
            return out
        # fast path: all sets unique
        if np.unique(sets).shape[0] == n:
            out[:] = self._access_unique(line_addrs, sets, seen_before,
                                         is_write, bypass_eligible,
                                         force_bypass, cores=cores,
                                         tids=tids)
            return out
        # split into chunks with unique sets so state updates don't collide
        order = np.argsort(sets, kind="stable")
        sorted_sets = sets[order]
        # pass index: the k-th occurrence of a set goes into chunk k
        # (vectorized: position within the run of equal sorted sets)
        _, first_pos, counts = np.unique(sorted_sets, return_index=True,
                                         return_counts=True)
        run_start = np.repeat(first_pos, counts)
        pass_idx_sorted = np.arange(n) - run_start
        pass_idx = np.empty(n, dtype=np.int64)
        pass_idx[order] = pass_idx_sorted
        max_pass = int(pass_idx_sorted.max())
        for p in range(max_pass + 1):
            sel = np.nonzero(pass_idx == p)[0]
            out[sel] = self._access_unique(
                line_addrs[sel], sets[sel],
                _index(seen_before, sel), _index(is_write, sel),
                _index(bypass_eligible, sel), _index(force_bypass, sel),
                cores=None if cores is None else cores[sel],
                tids=None if tids is None else tids[sel])
        return out

    # ------------------------------------------------------------------
    def access_planned(
        self,
        plan: AccessPlan,
        *,
        seen_before: np.ndarray,
        is_write=False,
        bypass_eligible=True,
        force_bypass=False,
        cores=None,
        tids=None,
    ) -> np.ndarray:
        """:meth:`access_burst` with the set mapping and pass split taken
        from a precomputed :class:`AccessPlan` (same outcome codes and
        state transitions; the per-call ``argsort``/``unique`` work is
        hoisted out of the policy sweep's inner loop)."""
        n = plan.line_addrs.shape[0]
        out = np.empty(n, dtype=np.int64)
        if n == 0:
            return out
        tags = plan.tags
        if plan.passes is None:
            out[:] = self._access_unique(plan.line_addrs, plan.sets,
                                         seen_before, is_write,
                                         bypass_eligible, force_bypass,
                                         tags=tags, cores=cores,
                                         tids=tids)
            return out
        for sel in plan.passes:
            out[sel] = self._access_unique(
                plan.line_addrs[sel], plan.sets[sel],
                _index(seen_before, sel), _index(is_write, sel),
                _index(bypass_eligible, sel), _index(force_bypass, sel),
                tags=None if tags is None else tags[sel],
                cores=None if cores is None else cores[sel],
                tids=None if tids is None else tids[sel])
        return out

    # ------------------------------------------------------------------
    def _access_unique(self, line_addrs, sets, seen_before, is_write,
                       bypass_eligible, force_bypass,
                       tags=None, cores=None, tids=None) -> np.ndarray:
        n = line_addrs.shape[0]
        sink = self.sink
        if tags is None:
            tags = self.geom.tag_of(line_addrs)
        out = np.empty(n, dtype=np.int64)
        seen_before = _bool_vec(seen_before, n)
        is_write = _bool_vec(is_write, n)
        bypass_eligible = _bool_vec(bypass_eligible, n)
        force_bypass = _bool_vec(force_bypass, n)
        self._clock += 1
        now = self._clock

        # lookup: invalid ways hold tag -1 and real tags are >= 0, so a
        # tag match alone implies validity (no valid-mask gather)
        set_tags = self.tags[sets]            # [n, A]
        hit_mask_ways = set_tags == tags[:, None]
        hit = hit_mask_ways.any(axis=1)
        hit_way = np.argmax(hit_mask_ways, axis=1)
        n_hit = int(hit.sum())

        # --- hits: refresh LRU ------------------------------------------------
        if n_hit:
            hs, hw = sets[hit], hit_way[hit]
            self.last_use[hs, hw] = now
            if tids is not None:
                # a hit under address reuse means the recycled line is
                # adopted by its new tensor generation
                self.owner[hs, hw] = tids[hit]
            w = is_write[hit]
            if w.any():
                self.dirty[hs[w], hw[w]] = True
            out[hit] = HIT
            self.stats["hits"] += n_hit
            # hits feed the eviction-rate denominator of the gear feedback
            if self.controller is not None:
                self._record_controller(hs, np.zeros(n_hit, dtype=bool),
                                        tags[hit])
            if sink is not None:
                sink.emit_lines(EV_HIT, line_addrs[hit], sets=hs, ways=hw,
                                cores=None if cores is None
                                else cores[hit],
                                tensors=None if tids is None
                                else tids[hit])
            if n_hit == n:
                return out

        miss = ~hit
        m_sets = sets[miss]
        m_tags = tags[miss]
        m_seen = seen_before[miss]

        # --- bypass decision (before allocation, paper §IV-D) ----------------
        if self.policy.bypass != BYPASS_NONE:
            m_tenants = (self.tenant_of_tags(m_tags)
                         if self._per_tenant_gears else None)
            gears = self.gear_of(self.geom.slice_of_set(m_sets), m_tenants)
            bypass = ((self._priorities(m_tags) < gears)
                      & bypass_eligible[miss]) | force_bypass[miss]
        else:
            bypass = force_bypass[miss]

        # outcome code = 1 + seen + 2*bypassed (see the constants above)
        out[miss] = 1 + m_seen + 2 * bypass
        n_conf = int(m_seen.sum())
        self.stats["bypassed"] += int(bypass.sum())
        self.stats["cold_misses"] += (n - n_hit) - n_conf
        self.stats["conflict_misses"] += n_conf

        m_tids = None if tids is None else tids[miss]
        if sink is not None:
            m_addrs = line_addrs[miss]
            m_cores = None if cores is None else cores[miss]
            bp = np.nonzero(bypass)[0]
            if bp.shape[0]:
                sink.emit_lines(EV_BYPASS, m_addrs[bp], sets=m_sets[bp],
                                cores=None if m_cores is None
                                else m_cores[bp],
                                aux=m_seen[bp].astype(np.int64),
                                tensors=None if m_tids is None
                                else m_tids[bp])

        # --- allocation (alloc-on-fill; write-allocate) -----------------------
        alloc = ~bypass
        if alloc.any():
            a_sets = m_sets[alloc]
            a_tags = m_tags[alloc]
            way, evicted_valid, evicted_dead = self._select_victims(a_sets)
            # victim tags/owners must be read before the fill overwrites
            v_tags = self.tags[a_sets, way] if sink is not None else None
            v_owner = (self.owner[a_sets, way]
                       if sink is not None and tids is not None else None)
            # writeback accounting for dirty victims
            wb = self.dirty[a_sets, way] & evicted_valid
            self.stats["writebacks"] += int(wb.sum())
            if self.tenant_wb is not None and wb.any():
                # charge the write-back to the *victim's* tenant region
                victim_tenants = self.tenant_of_tags(
                    self.tags[a_sets[wb], way[wb]])
                self.tenant_wb += np.bincount(victim_tenants,
                                              minlength=self.n_tenants)
            self.stats["evictions"] += int(evicted_valid.sum())
            self.stats["dead_evictions"] += int(evicted_dead.sum())
            self.tags[a_sets, way] = a_tags
            self.valid[a_sets, way] = True
            self.dirty[a_sets, way] = is_write[miss][alloc]
            self.last_use[a_sets, way] = now
            self.prio[a_sets, way] = self._priorities(a_tags)
            if tids is not None:
                self.owner[a_sets, way] = m_tids[alloc]
            ev_full = np.zeros(m_sets.shape[0], dtype=bool)
            ev_full[alloc] = evicted_valid
            if sink is not None:
                geom = self.geom
                ev = np.nonzero(evicted_valid)[0]
                if ev.shape[0]:
                    sink.emit_lines(
                        EV_EVICT, geom.line_addr_of(a_sets[ev], v_tags[ev]),
                        sets=a_sets[ev], ways=way[ev],
                        aux=2 * v_tags[ev] + evicted_dead[ev],
                        tensors=None if v_owner is None else v_owner[ev])
                wbi = np.nonzero(wb)[0]
                if wbi.shape[0]:
                    sink.emit_lines(
                        EV_WB, geom.line_addr_of(a_sets[wbi], v_tags[wbi]),
                        sets=a_sets[wbi], ways=way[wbi], aux=v_tags[wbi],
                        tensors=None if v_owner is None else v_owner[wbi])
                sink.emit_lines(
                    EV_FILL, m_addrs[alloc], sets=a_sets, ways=way,
                    cores=None if m_cores is None else m_cores[alloc],
                    aux=2 * a_tags + m_seen[alloc],
                    tensors=None if m_tids is None else m_tids[alloc])
        else:
            ev_full = np.zeros(m_sets.shape[0], dtype=bool)

        if self.controller is not None:
            self._record_controller(m_sets, ev_full, m_tags)
        return out

    # ------------------------------------------------------------------
    def _select_victims(self, a_sets: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized victim choice: invalid → dead → anti-thrash tier → LRU.

        Relies on the state invariant that invalid ways hold ``_BIG`` in
        ``last_use``/``prio``, so the LRU and anti-thrashing tiers need
        no per-call validity masking.  Returns (way, evicted_valid,
        evicted_was_dead) per set.
        """
        set_valid = self.valid[a_sets]       # [n, A]
        set_lru = self.last_use[a_sets]      # invalid ways hold _BIG
        n, A = set_valid.shape

        # 1. invalid way available → fill it (no eviction)
        invalid_ways = ~set_valid
        has_invalid = invalid_ways.any(axis=1)
        invalid_way = np.argmax(invalid_ways, axis=1)

        # 2. dead-block prediction: victimize TMU-dead lines first (LRU among dead)
        if self.policy.dbp and self.tmu is not None and len(self.tmu.dead_fifo):
            fifo = np.asarray(self.tmu.dead_fifo.snapshot(), dtype=np.int64)
            p = self.tmu.params
            width = p.d_msb - p.d_lsb + 1
            dead_ids = (self.tags[a_sets] >> p.d_lsb) & ((1 << width) - 1)
            dead_ways = set_valid & np.isin(dead_ids, fifo)
            has_dead = dead_ways.any(axis=1)
            dead_lru = np.where(dead_ways, set_lru, _BIG)
            dead_way = np.argmin(dead_lru, axis=1)
        else:
            has_dead = None

        # 3. anti-thrashing: lowest-priority tier present, tie-break LRU
        # (invalid ways sit at prio _BIG, so they never define the tier
        # unless the whole set is invalid — where has_invalid wins anyway)
        if self.policy.at:
            set_prio = self.prio[a_sets]
            min_tier = set_prio.min(axis=1, keepdims=True)
            tier_ways = set_prio == min_tier
            tier_lru = np.where(tier_ways, set_lru, _BIG)
            fallback_way = np.argmin(tier_lru, axis=1)
        else:
            # 4. plain LRU (invalid ways at _BIG lose to any valid way)
            fallback_way = np.argmin(set_lru, axis=1)

        evicted_valid = ~has_invalid
        if has_dead is None:
            way = fallback_way
            evicted_dead = np.zeros(n, dtype=bool)
        else:
            way = np.where(has_dead, dead_way, fallback_way)
            evicted_dead = evicted_valid & has_dead
        way = np.where(has_invalid, invalid_way, way)
        return way, evicted_valid, evicted_dead

    # ------------------------------------------------------------------
    def _record_controller(self, sets: np.ndarray, evicted: np.ndarray,
                           tags: Optional[np.ndarray] = None) -> None:
        if self.controller is not None and sets.shape[0]:
            tenants = (self.tenant_of_tags(tags)
                       if self._per_tenant_gears and tags is not None
                       else None)
            self.controller.record(self.geom.slice_of_set(sets), evicted,
                                   tenants)

    def tick(self, now_cycles: float) -> None:
        if self.controller is not None:
            self.controller.tick(now_cycles)

    # ------------------------------------------------------------------
    def hit_rate(self) -> float:
        total = (self.stats["hits"] + self.stats["cold_misses"]
                 + self.stats["conflict_misses"])
        return self.stats["hits"] / total if total else 0.0

    def resident_bytes(self) -> int:
        return int(self.valid.sum()) * self.geom.line_bytes


def _index(x, sel):
    """Index ``x`` by ``sel`` if it is an array; pass scalars through."""
    arr = np.asarray(x)
    return arr[sel] if arr.ndim else x


def _bool_vec(x, n):
    """Per-line bool vector: pass bool arrays through, broadcast scalars."""
    a = np.asarray(x, dtype=bool)
    return a if a.ndim else np.broadcast_to(a, (n,))


def is_miss(codes: np.ndarray) -> np.ndarray:
    return codes != HIT


def goes_to_dram(codes: np.ndarray) -> np.ndarray:
    return np.isin(codes, _MISS_CODES)

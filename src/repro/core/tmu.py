"""Tensor Management Unit (TMU) — faithful functional model of DCO §IV-B.

The TMU is the liaison between software and the shared-LLC replacement
logic.  Software registers *tensor metadata* before an operator runs
(three "specialized instructions" in the paper: register / clear / set
parameters); at runtime the TMU maintains *live tile info* (per-tile
access counters ``accCnt``) and a bounded *dead-tile-identifier FIFO*.

Semantics implemented bit-exactly per the paper (Table I):

* ``nAcc``      expected number of accesses of each cache line of a tensor
                (known from the dataflow, e.g. #Q-tiles for a K tile).
* ``accCnt``    per-live-tile counter, incremented when the tile's **last
                cache line** (TLL) is accessed; when ``accCnt == nAcc`` the
                tile retires and ``tag[D_MSB:D_LSB]`` is pushed into the
                dead FIFO (depth-bounded; full ⇒ oldest entry dropped).
* dead check    a cache line is considered dead iff ``tag[D_MSB:D_LSB]``
                is present in the dead FIFO.
* priority      ``tag[B_BITS-1:0]`` — the *lowermost bits of the tag
                domain*, uniform across a tensor; shared by the
                anti-thrashing replacement tier and the bypass gear.

Hardware cost defaults follow Table III: 8 tensor metadata entries,
256 tile metadata entries, dead FIFO depth 16, 48-bit physical addresses.
"""

from __future__ import annotations

from collections import OrderedDict
from collections import deque
from dataclasses import dataclass
from typing import Deque
from typing import Dict
from typing import Optional
from typing import Tuple

import numpy as np

PHYS_ADDR_BITS = 48


@dataclass(frozen=True)
class TensorMeta:
    """Static operator metadata registered before execution (paper §IV-B).

    Addresses are byte addresses; ``tile_bytes`` must be a multiple of the
    cache line size so that every line belongs to exactly one tile.
    """

    tensor_id: int
    base_addr: int
    size_bytes: int
    tile_bytes: int
    n_acc: int                 # expected accesses of each cache line
    operand_id: int = 0        # e.g. 0=left, 1=right, 2=output
    bypass_all: bool = False   # bypass the whole tensor from LLC

    def __post_init__(self) -> None:
        if self.size_bytes <= 0 or self.tile_bytes <= 0:
            raise ValueError("tensor/tile sizes must be positive")
        if self.size_bytes % self.tile_bytes != 0:
            raise ValueError(
                f"tensor size {self.size_bytes} not a multiple of tile "
                f"size {self.tile_bytes}"
            )
        if self.base_addr < 0 or self.base_addr + self.size_bytes > (1 << PHYS_ADDR_BITS):
            raise ValueError("tensor does not fit in the physical address space")

    @property
    def end_addr(self) -> int:
        return self.base_addr + self.size_bytes

    @property
    def num_tiles(self) -> int:
        return self.size_bytes // self.tile_bytes

    def tile_of(self, addr: int) -> int:
        return (addr - self.base_addr) // self.tile_bytes

    def tile_last_line(self, tile_idx: int, line_bytes: int) -> int:
        """Byte address of the first byte of the tile's last cache line."""
        end = self.base_addr + (tile_idx + 1) * self.tile_bytes
        return end - line_bytes


@dataclass
class TMUParams:
    """Run-time configurable parameters (the paper's third instruction)."""

    d_lsb: int = 0
    d_msb: int = 11          # inclusive; tag[D_MSB:D_LSB] = 12-bit dead id
    b_bits: int = 3          # priority = tag[B_BITS-1:0] → 2**b_bits tiers

    def __post_init__(self) -> None:
        if not (0 <= self.d_lsb <= self.d_msb):
            raise ValueError("need 0 <= D_LSB <= D_MSB")
        if not (0 <= self.b_bits <= 8):
            raise ValueError("B_BITS out of supported range")

    def dead_id(self, tag: int) -> int:
        width = self.d_msb - self.d_lsb + 1
        return (tag >> self.d_lsb) & ((1 << width) - 1)

    def priority(self, tag: int) -> int:
        if self.b_bits == 0:
            return 0
        return tag & ((1 << self.b_bits) - 1)


class DeadFIFO:
    """Bounded FIFO of dead tile identifiers (tag[D_MSB:D_LSB] values).

    Lookup must complete within a clock cycle in hardware, hence the small
    depth (16 in Table III).  We keep an O(1) membership set alongside the
    FIFO order; duplicate pushes refresh nothing (hardware would simply
    hold two identical entries — membership semantics are identical).
    """

    def __init__(self, depth: int = 16):
        if depth <= 0:
            raise ValueError("FIFO depth must be positive")
        self.depth = depth
        self._fifo: Deque[int] = deque()
        self._counts: Dict[int, int] = {}

    def push(self, dead_id: int) -> Optional[int]:
        """Push an id; returns the evicted (dropped) id if the FIFO was full."""
        dropped: Optional[int] = None
        if len(self._fifo) == self.depth:
            dropped = self._fifo.popleft()
            c = self._counts[dropped] - 1
            if c:
                self._counts[dropped] = c
            else:
                del self._counts[dropped]
        self._fifo.append(dead_id)
        self._counts[dead_id] = self._counts.get(dead_id, 0) + 1
        return dropped

    def __contains__(self, dead_id: int) -> bool:
        return dead_id in self._counts

    def __len__(self) -> int:
        return len(self._fifo)

    def snapshot(self) -> Tuple[int, ...]:
        return tuple(self._fifo)

    def clear(self) -> None:
        self._fifo.clear()
        self._counts.clear()


class TMU:
    """Functional TMU: tensor metadata module + tile metadata module.

    The tile metadata module has bounded capacity (``tile_entries``).  Live
    tile entries are allocated lazily on first TLL access and evicted in
    LRU order when capacity is exceeded (the paper sizes it at 256 entries
    so that the set of tiles concurrently in flight fits; overflow merely
    loses a counter, i.e. a missed dead prediction — never a correctness
    issue).
    """

    def __init__(
        self,
        line_bytes: int = 128,
        tensor_entries: int = 8,
        tile_entries: int = 256,
        dead_fifo_depth: int = 16,
        params: Optional[TMUParams] = None,
    ):
        self.line_bytes = line_bytes
        self.tensor_entries = tensor_entries
        self.tile_entries = tile_entries
        self.params = params or TMUParams()
        self.dead_fifo = DeadFIFO(dead_fifo_depth)
        self._tensors: Dict[int, TensorMeta] = {}
        # live tile info: (tensor_id, tile_idx) -> accCnt, LRU-ordered
        self._live: "OrderedDict[Tuple[int, int], int]" = OrderedDict()
        # stats
        self.stats: Dict[str, int] = {
            "tll_accesses": 0,
            "tiles_retired": 0,
            "live_overflow_evictions": 0,
            "dead_fifo_drops": 0,
        }
        # opt-in event telemetry (repro.core.events.EventSink); None on
        # the hot path unless the simulator attached a sink
        self.sink = None

    # ------------------------------------------------------------------
    # The three specialized instructions (paper §IV-B)
    # ------------------------------------------------------------------
    def register(self, meta: TensorMeta) -> None:
        """Instruction 1: register tensor metadata."""
        if meta.tensor_id in self._tensors:
            raise ValueError(f"tensor {meta.tensor_id} already registered")
        if len(self._tensors) >= self.tensor_entries:
            raise RuntimeError(
                f"TMU tensor metadata full ({self.tensor_entries} entries); "
                "clear a tensor first"
            )
        if meta.tile_bytes % self.line_bytes != 0:
            raise ValueError("tile size must be a multiple of the line size")
        self._tensors[meta.tensor_id] = meta

    def register_many(self, metas) -> None:
        """Register a whole dataflow's tensor set (one ``register`` per
        entry, same capacity checks) — the batch form the simulator and
        the dataflow lowerings use."""
        for meta in metas:
            self.register(meta)

    def clear(self, tensor_id: int) -> None:
        """Instruction 2: clear a registration that is no longer needed."""
        self._tensors.pop(tensor_id, None)
        stale = [k for k in self._live if k[0] == tensor_id]
        for k in stale:
            del self._live[k]

    def set_params(self, params: TMUParams) -> None:
        """Instruction 3: set D_LSB / D_MSB / B_BITS."""
        self.params = params

    # ------------------------------------------------------------------
    # Runtime interface used by the LLC
    # ------------------------------------------------------------------
    def lookup_tensor(self, addr: int) -> Optional[TensorMeta]:
        for meta in self._tensors.values():
            if meta.base_addr <= addr < meta.end_addr:
                return meta
        return None

    def on_access(self, addr: int, tag: int) -> None:
        """LLC informs the TMU of an access.  If ``addr`` is a tile's last
        line (TLL), bump ``accCnt``; on reaching ``nAcc`` retire the tile
        into the dead FIFO."""
        meta = self.lookup_tensor(addr)
        if meta is None or meta.bypass_all:
            return
        tile_idx = meta.tile_of(addr)
        line_addr = addr - (addr % self.line_bytes)
        if line_addr != meta.tile_last_line(tile_idx, self.line_bytes):
            return
        self.stats["tll_accesses"] += 1
        key = (meta.tensor_id, tile_idx)
        cnt = self._live.get(key, 0) + 1
        if cnt >= meta.n_acc:
            # retire: move identifier from live tile info to dead ids
            self._live.pop(key, None)
            if self.dead_fifo.push(self.params.dead_id(tag)) is not None:
                self.stats["dead_fifo_drops"] += 1
            self.stats["tiles_retired"] += 1
            if self.sink is not None:
                self.sink.emit_retire([meta.tensor_id], [tile_idx])
        else:
            self._live[key] = cnt
            self._live.move_to_end(key)
            if len(self._live) > self.tile_entries:
                self._live.popitem(last=False)
                self.stats["live_overflow_evictions"] += 1

    def on_access_batch(self, tensor_ids, tile_idxs, tags, n_accs) -> None:
        """Batched :meth:`on_access` over a pre-resolved TLL feed.

        The caller (the compiled-trace simulator) guarantees every entry
        is the tile-last-line of a registered, non-``bypass_all`` tensor,
        so the per-call linear tensor lookup and the TLL address check are
        skipped and the dead-id bit slicing is done vectorized up front.
        State transitions (accCnt bumps, retirement order, dead-FIFO
        pushes, live-table LRU/overflow) are identical to issuing the
        calls one at a time in feed order.
        """
        tensor_ids = np.asarray(tensor_ids)
        n = tensor_ids.shape[0]
        if n == 0:
            return
        self.stats["tll_accesses"] += int(n)
        p = self.params
        width = p.d_msb - p.d_lsb + 1
        dead_ids = ((np.asarray(tags, dtype=np.int64) >> p.d_lsb)
                    & ((1 << width) - 1)).tolist()
        live = self._live
        fifo = self.dead_fifo
        sink = self.sink
        r_tids = [] if sink is not None else None
        r_tiles = [] if sink is not None else None
        retired = drops = overflow = 0
        for tid, tile, did, n_acc in zip(
                tensor_ids.tolist(), np.asarray(tile_idxs).tolist(),
                dead_ids, np.asarray(n_accs).tolist()):
            key = (tid, tile)
            cnt = live.get(key, 0) + 1
            if cnt >= n_acc:
                live.pop(key, None)
                if fifo.push(did) is not None:
                    drops += 1
                retired += 1
                if r_tids is not None:
                    r_tids.append(tid)
                    r_tiles.append(tile)
            else:
                live[key] = cnt
                live.move_to_end(key)
                if len(live) > self.tile_entries:
                    live.popitem(last=False)
                    overflow += 1
        self.stats["tiles_retired"] += retired
        self.stats["dead_fifo_drops"] += drops
        self.stats["live_overflow_evictions"] += overflow
        if sink is not None and r_tids:
            sink.emit_retire(r_tids, r_tiles)

    def is_dead(self, tag: int) -> bool:
        return self.params.dead_id(tag) in self.dead_fifo

    def priority(self, tag: int) -> int:
        return self.params.priority(tag)

    def acc_cnt(self, tensor_id: int, tile_idx: int) -> int:
        return self._live.get((tensor_id, tile_idx), 0)

    @property
    def live_tiles(self) -> int:
        return len(self._live)

    # ------------------------------------------------------------------
    # Structural cost estimate (paper Table II reports 64,438 µm² @15nm,
    # 2 GHz for the full TMU).  We provide a transparent bit-count model
    # so the configuration's storage cost is auditable; the paper's
    # synthesized figure remains the reference value.
    # ------------------------------------------------------------------
    def area_report(self) -> Dict[str, float]:
        tag_bits = PHYS_ADDR_BITS  # upper bound; real tag is addr minus index/offset
        tensor_entry_bits = (
            PHYS_ADDR_BITS          # base address
            + 32                    # size
            + 24                    # tile size
            + 16                    # nAcc
            + 2                     # operand id
            + 1                     # bypass flag
        )
        tile_entry_bits = 16 + 16 + 16   # tensor/tile key + accCnt
        dead_entry_bits = self.params.d_msb - self.params.d_lsb + 1
        bits = (
            self.tensor_entries * tensor_entry_bits
            + self.tile_entries * tile_entry_bits
            + self.dead_fifo.depth * dead_entry_bits
        )
        # NanGate15 ~0.2 µm²/bit for flop-based storage + ~60% logic overhead:
        um2 = bits * 0.2 * 1.6
        return {
            "storage_bits": float(bits),
            "estimated_um2": um2,
            "paper_reference_um2": 64438.0,
            "paper_reference_freq_ghz": 2.0,
            "tag_bits_assumed": float(tag_bits),
        }

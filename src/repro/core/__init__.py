"""DCO core: TMU, shared-LLC policies, cycle-level simulator, analytical
model, and the TPU-side cache orchestrator."""

from .analytical import (ModelParams, Prediction, fit_params,
                         gear_trajectory, kendall_tau, kept_fraction,
                         predict, predict_batch, r_squared)
from .cache import CacheGeometry, SharedLLC
from .events import (COLUMNS as EVENT_COLUMNS, KIND_NAMES as EVENT_KINDS,
                     SCHEMA_VERSION as EVENT_SCHEMA_VERSION, EventSink,
                     canonical_order, decode_event, stream_digest,
                     timeline_digest)
from .orchestrator import CacheOrchestrator, OrchestrationPlan
from .policies import PolicyConfig, named_policy
from .simulator import (SimConfig, SimResult, Simulator, run_policies,
                        run_policy)
from .tmu import TMU, DeadFIFO, TMUParams, TensorMeta
from .traces import (CompiledTrace, DataflowCounts, Step, Trace,
                     build_fa2_trace, build_matmul_trace, fa2_counts)
from .workloads import (PAPER_WORKLOADS, SPATIAL, TEMPORAL, AttnWorkload,
                        DecodeWorkload, MoEWorkload, PrefixShareWorkload,
                        SpecDecodeWorkload, SSDScanWorkload, get_workload)

__all__ = [
    "ModelParams", "Prediction", "fit_params", "gear_trajectory",
    "kendall_tau", "kept_fraction", "predict", "predict_batch",
    "r_squared",
    "CacheGeometry", "SharedLLC",
    "EVENT_COLUMNS", "EVENT_KINDS", "EVENT_SCHEMA_VERSION", "EventSink",
    "canonical_order", "decode_event", "stream_digest", "timeline_digest",
    "CacheOrchestrator", "OrchestrationPlan",
    "PolicyConfig", "named_policy",
    "SimConfig", "SimResult", "Simulator", "run_policies", "run_policy",
    "TMU", "DeadFIFO", "TMUParams", "TensorMeta",
    "CompiledTrace", "DataflowCounts", "Step", "Trace", "build_fa2_trace",
    "build_matmul_trace", "fa2_counts",
    "PAPER_WORKLOADS", "SPATIAL", "TEMPORAL", "AttnWorkload",
    "DecodeWorkload", "MoEWorkload", "PrefixShareWorkload",
    "SpecDecodeWorkload", "SSDScanWorkload", "get_workload",
]

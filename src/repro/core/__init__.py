"""DCO core: TMU, shared-LLC policies, cycle-level simulator, analytical
model, and the TPU-side cache orchestrator."""

from .analytical import ModelParams
from .analytical import Prediction
from .analytical import fit_params
from .analytical import gear_trajectory
from .analytical import kendall_tau
from .analytical import kept_fraction
from .analytical import predict
from .analytical import predict_batch
from .analytical import r_squared
from .cache import CacheGeometry
from .cache import SharedLLC
from .events import COLUMNS as EVENT_COLUMNS
from .events import EventSink
from .events import KIND_NAMES as EVENT_KINDS
from .events import SCHEMA_VERSION as EVENT_SCHEMA_VERSION
from .events import canonical_order
from .events import decode_event
from .events import stream_digest
from .events import timeline_digest
from .orchestrator import CacheOrchestrator
from .orchestrator import OrchestrationPlan
from .policies import PolicyConfig
from .policies import named_policy
from .simulator import SimConfig
from .simulator import SimResult
from .simulator import Simulator
from .simulator import run_policies
from .simulator import run_policy
from .tmu import DeadFIFO
from .tmu import TMU
from .tmu import TMUParams
from .tmu import TensorMeta
from .traces import CompiledTrace
from .traces import DataflowCounts
from .traces import Step
from .traces import Trace
from .traces import build_fa2_trace
from .traces import build_matmul_trace
from .traces import fa2_counts
from .workloads import AttnWorkload
from .workloads import DecodeWorkload
from .workloads import MoEWorkload
from .workloads import PAPER_WORKLOADS
from .workloads import PrefixShareWorkload
from .workloads import SPATIAL
from .workloads import SSDScanWorkload
from .workloads import SpecDecodeWorkload
from .workloads import TEMPORAL
from .workloads import get_workload

__all__ = [
    "ModelParams", "Prediction", "fit_params", "gear_trajectory",
    "kendall_tau", "kept_fraction", "predict", "predict_batch",
    "r_squared",
    "CacheGeometry", "SharedLLC",
    "EVENT_COLUMNS", "EVENT_KINDS", "EVENT_SCHEMA_VERSION", "EventSink",
    "canonical_order", "decode_event", "stream_digest", "timeline_digest",
    "CacheOrchestrator", "OrchestrationPlan",
    "PolicyConfig", "named_policy",
    "SimConfig", "SimResult", "Simulator", "run_policies", "run_policy",
    "TMU", "DeadFIFO", "TMUParams", "TensorMeta",
    "CompiledTrace", "DataflowCounts", "Step", "Trace", "build_fa2_trace",
    "build_matmul_trace", "fa2_counts",
    "PAPER_WORKLOADS", "SPATIAL", "TEMPORAL", "AttnWorkload",
    "DecodeWorkload", "MoEWorkload", "PrefixShareWorkload",
    "SpecDecodeWorkload", "SSDScanWorkload", "get_workload",
]

"""Trace-driven, burst-synchronous cycle-level simulator (paper §VI-B).

System model follows Table IV: 16 cores (1 vector/tile engine + private
SPM each), a 32-slice shared LLC (assoc 8, MSHR per slice), DDR5-3200
×16-channel-class main memory, 2 GHz.  Cores execute bulk tile transfers
and compute in lockstep *rounds* (one dataflow inner step per round); the
LLC is simulated at cache-line granularity with full replacement/bypass
state (see ``cache.py``), while time is accounted per round with the
paper's bottleneck/overlap semantics (Eq. 1–2):

    t_hit  = max(n_hit  / (N·ipc_mem),  n_hit  / v_LLC)
    t_cold = max(n_cold / (N·ipc_mem),  n_cold / v_LLC,  n'_cold / bw_cold)
    t_cf   = max(n_cf   / (N·ipc_mem),  n_cf   / v_LLC,  n'_cf   / bw_cf)
    t      = t_hit + t_cold + max(t_comp, t_cf)

Cold misses occur in bursts and saturate DRAM at sequential efficiency;
conflict/capacity misses are dispersed and overlap with compute.  The
difference from the analytical model (``analytical.py``) is that all
``n_*`` here come from the *simulated cache state* (real evictions, dead
blocks, per-slice gears), not from closed forms.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import cache as C
from .cache import CacheGeometry, SharedLLC
from .policies import PolicyConfig
from .tmu import TMU, TMUParams, TensorMeta
from .traces import Trace


@dataclass(frozen=True)
class SimConfig:
    """Hardware configuration (paper Table IV + DESIGN.md §7.3)."""

    n_cores: int = 16
    freq_ghz: float = 2.0
    line_bytes: int = 128
    llc_bytes: int = 4 * 2**20
    llc_assoc: int = 8
    llc_slices: int = 32
    ipc_mem: float = 1.0              # SPM<->LLC lines issued /cycle/core
    v_llc: float = 32.0               # LLC lines served /cycle (all slices)
    core_flops_per_cycle: float = 16384.0  # 64x128 MAC tile engine per core
    dram_bw_bytes_per_cycle: float = 204.8  # DDR5-3200 x16ch @2GHz
    dram_eff_seq: float = 0.90        # burst (cold) efficiency
    dram_eff_rand: float = 0.55       # dispersed (conflict) efficiency
    round_overhead_cycles: float = 8.0
    # TMU hardware parameters (Table III)
    tmu_tensor_entries: int = 4096    # functional-model capacity; the RTL
    tmu_tile_entries: int = 4096      # uses 8/256 with time-multiplexed
    dead_fifo_depth: int = 16         # registration per operator

    @property
    def dram_lines_per_cycle(self) -> float:
        return self.dram_bw_bytes_per_cycle / self.line_bytes


@dataclass
class SimResult:
    name: str
    policy: str
    cycles: float
    hits: int
    mshr_hits: int
    cold_misses: int
    conflict_misses: int
    bypassed: int
    dram_lines: int
    writebacks: int
    dead_evictions: int
    flops: float
    history: Dict[str, np.ndarray] = field(default_factory=dict)

    @property
    def accesses(self) -> int:
        return (self.hits + self.mshr_hits + self.cold_misses
                + self.conflict_misses)

    @property
    def hit_rate(self) -> float:
        """LLC + MSHR hits over all requests (the paper treats both hit
        classes in a single v_LLC term, §V-C)."""
        served = self.hits + self.mshr_hits
        return served / self.accesses if self.accesses else 0.0

    @property
    def time_ms(self) -> float:
        return self.cycles / 2.0e6  # 2 GHz

    def summary(self) -> str:
        return (f"{self.name:34s} {self.policy:24s} "
                f"cycles={self.cycles:12.0f} hit={self.hit_rate:6.3f} "
                f"dram_lines={self.dram_lines}")


class Simulator:
    """Run one trace under one policy."""

    def __init__(self, cfg: SimConfig, policy: PolicyConfig,
                 tmu_params: Optional[TMUParams] = None):
        self.cfg = cfg
        self.policy = policy
        self.tmu_params = tmu_params or TMUParams(b_bits=policy.b_bits)

    def run(self, trace: Trace, record_history: bool = True) -> SimResult:
        cfg = self.cfg
        geom = CacheGeometry(cfg.llc_bytes, cfg.line_bytes, cfg.llc_assoc,
                             cfg.llc_slices)
        tmu = TMU(line_bytes=cfg.line_bytes,
                  tensor_entries=cfg.tmu_tensor_entries,
                  tile_entries=cfg.tmu_tile_entries,
                  dead_fifo_depth=cfg.dead_fifo_depth,
                  params=self.tmu_params)
        for meta in trace.tensors.values():
            tmu.register(meta)
        llc = SharedLLC(geom, self.policy, tmu=tmu)

        # per-tensor "ever fetched" bitmaps for cold/conflict classification
        seen: Dict[int, np.ndarray] = {
            tid: np.zeros(m.size_bytes // cfg.line_bytes, dtype=bool)
            for tid, m in trace.tensors.items()
        }

        n_rounds = trace.n_rounds
        clock = 0.0
        total_mshr_hits = 0
        total_dram_lines = 0
        total_flops = 0.0
        hist_cycles: List[float] = []
        hist_hits: List[int] = []
        hist_acc: List[int] = []
        hist_gear: List[float] = []

        tensors = trace.tensors
        line_b = cfg.line_bytes

        for r in range(n_rounds):
            addrs_parts: List[np.ndarray] = []
            seen_parts: List[np.ndarray] = []
            force_parts: List[np.ndarray] = []
            elig_parts: List[np.ndarray] = []
            write_parts: List[np.ndarray] = []
            tll_calls: List[Tuple[int, int]] = []  # (tll_addr, tag)
            flops_round = 0.0

            contended = (llc.controller is not None
                         and bool(llc.controller.contended().any()))

            for c, steps in enumerate(trace.core_steps):
                if r >= len(steps):
                    continue
                step = steps[r]
                flops_round += step.flops
                # gqa_bypass: only non-leader ("slower") cores bypass, and
                # only when the LLC is contended (paper §IV-E).
                if self.policy.gqa_variant:
                    eligible = (not trace.core_is_leader[c]) and contended
                else:
                    eligible = True
                for (tid, tile), is_store in (
                        [(l, False) for l in step.loads]
                        + [(s, True) for s in step.stores]):
                    meta = tensors[tid]
                    lines = trace.tile_lines(tid, tile)
                    k = lines.shape[0]
                    idx0 = (lines[0] - meta.base_addr) // line_b
                    sv = seen[tid][idx0:idx0 + k]
                    addrs_parts.append(lines)
                    seen_parts.append(sv.copy())
                    sv[:] = True
                    force_parts.append(
                        np.full(k, meta.bypass_all, dtype=bool))
                    elig_parts.append(np.full(k, eligible, dtype=bool))
                    write_parts.append(np.full(k, is_store, dtype=bool))
                    if not is_store and not meta.bypass_all:
                        tll_addr = meta.tile_last_line(tile, line_b)
                        tll_calls.append(
                            (tll_addr, int(geom.tag_of(np.int64(tll_addr)))))

            if not addrs_parts:
                clock += cfg.round_overhead_cycles
                continue

            addrs = np.concatenate(addrs_parts)
            seen_b = np.concatenate(seen_parts)
            force_b = np.concatenate(force_parts)
            elig_b = np.concatenate(elig_parts)
            write_b = np.concatenate(write_parts)

            # MSHR merge: same-line requests issued in the same round are
            # merged into one in-flight fill — policy-independent, even for
            # bypassed lines (an MSHR entry exists for the duration of the
            # DRAM fetch whether or not the fill allocates).  Only the
            # first occurrence touches the cache state.
            _, first_idx = np.unique(addrs, return_index=True)
            n_dups = addrs.shape[0] - first_idx.shape[0]
            total_mshr_hits += n_dups

            wb_before = llc.stats["writebacks"]
            codes = llc.access_burst(addrs[first_idx],
                                     seen_before=seen_b[first_idx],
                                     is_write=write_b[first_idx],
                                     bypass_eligible=elig_b[first_idx],
                                     force_bypass=force_b[first_idx])

            for tll_addr, tag in tll_calls:
                tmu.on_access(tll_addr, tag)

            n_hit = int((codes == C.HIT).sum()) + n_dups
            cold = int(np.isin(codes, (C.COLD_MISS, C.BYPASSED_COLD)).sum())
            cf = int(np.isin(codes,
                             (C.CONFLICT_MISS, C.BYPASSED_CONFLICT)).sum())
            wb_round = llc.stats["writebacks"] - wb_before
            dram_cold = cold
            dram_cf = cf + wb_round
            total_dram_lines += dram_cold + dram_cf
            total_flops += flops_round

            t = self._round_time(n_hit, cold, cf, dram_cold, dram_cf,
                                 flops_round)
            clock += t
            llc.tick(clock)

            if record_history:
                hist_cycles.append(clock)
                hist_hits.append(n_hit)
                hist_acc.append(n_hit + cold + cf)
                if llc.controller is not None:
                    hist_gear.append(float(llc.controller.gear.mean()))

        history = {}
        if record_history:
            history = {
                "cycles": np.asarray(hist_cycles),
                "hits": np.asarray(hist_hits, dtype=np.int64),
                "accesses": np.asarray(hist_acc, dtype=np.int64),
            }
            if hist_gear:
                history["gear"] = np.asarray(hist_gear)

        return SimResult(
            name=trace.name, policy=self.policy.name, cycles=clock,
            hits=llc.stats["hits"], mshr_hits=total_mshr_hits,
            cold_misses=llc.stats["cold_misses"],
            conflict_misses=llc.stats["conflict_misses"],
            bypassed=llc.stats["bypassed"],
            dram_lines=total_dram_lines,
            writebacks=llc.stats["writebacks"],
            dead_evictions=llc.stats["dead_evictions"],
            flops=total_flops, history=history,
        )

    # ------------------------------------------------------------------
    def _round_time(self, n_hit: int, n_cold: int, n_cf: int,
                    dram_cold: int, dram_cf: int, flops: float) -> float:
        cfg = self.cfg
        issue = cfg.n_cores * cfg.ipc_mem
        bw = cfg.dram_lines_per_cycle
        t_hit = max(n_hit / issue, n_hit / cfg.v_llc) if n_hit else 0.0
        t_cold = max(n_cold / issue, n_cold / cfg.v_llc,
                     dram_cold / (cfg.dram_eff_seq * bw)) if n_cold else 0.0
        t_cf = max(n_cf / issue, n_cf / cfg.v_llc,
                   dram_cf / (cfg.dram_eff_rand * bw)) if (n_cf or dram_cf) \
            else 0.0
        t_comp = flops / (cfg.n_cores * cfg.core_flops_per_cycle)
        return t_hit + t_cold + max(t_comp, t_cf) + cfg.round_overhead_cycles


def run_policy(trace: Trace, policy: PolicyConfig,
               cfg: Optional[SimConfig] = None,
               record_history: bool = True) -> SimResult:
    return Simulator(cfg or SimConfig(), policy).run(
        trace, record_history=record_history)

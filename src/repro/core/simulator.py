"""Trace-driven, burst-synchronous cycle-level simulator (paper §VI-B).

System model follows Table IV: 16 cores (1 vector/tile engine + private
SPM each), a 32-slice shared LLC (assoc 8, MSHR per slice), DDR5-3200
×16-channel-class main memory, 2 GHz.  Cores execute bulk tile transfers
and compute in lockstep *rounds* (one dataflow inner step per round); the
LLC is simulated at cache-line granularity with full replacement/bypass
state (see ``cache.py``), while time is accounted per round with the
paper's bottleneck/overlap semantics (Eq. 1–2):

    t_hit  = max(n_hit  / (N·ipc_mem),  n_hit  / v_LLC)
    t_cold = max(n_cold / (N·ipc_mem),  n_cold / v_LLC,  n'_cold / bw_cold)
    t_cf   = max(n_cf   / (N·ipc_mem),  n_cf   / v_LLC,  n'_cf   / bw_cf)
    t      = t_hit + t_cold + max(t_comp, t_cf)

Cold misses occur in bursts and saturate DRAM at sequential efficiency;
conflict/capacity misses are dispersed and overlap with compute.  The
difference from the analytical model (``analytical.py``) is that all
``n_*`` here come from the *simulated cache state* (real evictions, dead
blocks, per-slice gears), not from closed forms.

Execution engines:

* the default **compiled** engine slices the flat round-indexed arrays of
  a :class:`~repro.core.traces.CompiledTrace` (built once per trace and
  shared across policies — see :func:`run_policies` for batch sweeps);
* the **step** engine re-walks the Python ``Step`` lists per round.  It
  is the original reference implementation, kept as the oracle for the
  compiled path (``tests/test_compiled_trace.py`` asserts bit-identical
  counters) — both engines produce byte-identical ``SimResult``\\ s.
"""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import field
from dataclasses import replace
from typing import Dict
from typing import Iterable
from typing import List
from typing import Optional
from typing import Tuple
from typing import Union

import numpy as np

from . import cache as C
from .cache import CacheGeometry
from .cache import SharedLLC
from .events import EV_MSHR
from .events import EventSink
from .policies import PolicyConfig
from .policies import named_policy
from .tmu import TMU
from .tmu import TMUParams
from .traces import Trace


@dataclass(frozen=True)
class SimConfig:
    """Hardware configuration (paper Table IV + DESIGN.md §7.3)."""

    n_cores: int = 16
    freq_ghz: float = 2.0
    line_bytes: int = 128
    llc_bytes: int = 4 * 2**20
    llc_assoc: int = 8
    llc_slices: int = 32
    ipc_mem: float = 1.0              # SPM<->LLC lines issued /cycle/core
    v_llc: float = 32.0               # LLC lines served /cycle (all slices)
    core_flops_per_cycle: float = 16384.0  # 64x128 MAC tile engine per core
    dram_bw_bytes_per_cycle: float = 204.8  # DDR5-3200 x16ch @2GHz
    dram_eff_seq: float = 0.90        # burst (cold) efficiency
    dram_eff_rand: float = 0.55       # dispersed (conflict) efficiency
    round_overhead_cycles: float = 8.0
    # TMU hardware parameters (Table III)
    tmu_tensor_entries: int = 4096    # functional-model capacity; the RTL
    tmu_tile_entries: int = 4096      # uses 8/256 with time-multiplexed
    dead_fifo_depth: int = 16         # registration per operator
    # opt-in structured event telemetry (repro.core.events): every run
    # collects the canonical per-round event stream into a fresh
    # EventSink attached to SimResult.events.  Off by default — the
    # emission sites are fully skipped (sweep_perf.py gates the
    # overhead-when-off at ~0%).
    trace_events: bool = False

    @property
    def dram_lines_per_cycle(self) -> float:
        return self.dram_bw_bytes_per_cycle / self.line_bytes


@dataclass
class SimResult:
    name: str
    policy: str
    cycles: float
    hits: int
    mshr_hits: int
    cold_misses: int
    conflict_misses: int
    bypassed: int
    dram_lines: int
    writebacks: int
    dead_evictions: int
    flops: float
    freq_ghz: float = 2.0
    history: Dict[str, np.ndarray] = field(default_factory=dict)
    #: per-tenant counter attribution on multi-tenant composite traces
    #: (DESIGN.md §8.4): tenant name → {hits, mshr_hits, cold_misses,
    #: conflict_misses, bypassed, writebacks}; each counter sums to the
    #: matching global field (conservation pinned by tests).  Empty on
    #: single-tenant traces.
    tenants: Dict[str, Dict[str, int]] = field(default_factory=dict)
    #: per-round metric series (recorded with ``record_history``):
    #: ``round`` (global index of each non-empty round) plus aligned
    #: ``hits``/``misses``/``bypassed``/``writebacks`` int64 series and,
    #: on multi-tenant traces, ``tenant_*`` (rounds, tenants) matrices.
    #: ``repro.core.events.timeline_digest`` hashes it deterministically
    #: (suite_bench records the digest per scenario).
    timeline: Dict[str, np.ndarray] = field(default_factory=dict)
    #: the run's EventSink when event tracing was on (SimConfig.
    #: trace_events or an explicit ``events=`` argument); None otherwise
    events: Optional[EventSink] = None

    @property
    def accesses(self) -> int:
        return (self.hits + self.mshr_hits + self.cold_misses
                + self.conflict_misses)

    @property
    def hit_rate(self) -> float:
        """LLC + MSHR hits over all requests (the paper treats both hit
        classes in a single v_LLC term, §V-C)."""
        served = self.hits + self.mshr_hits
        return served / self.accesses if self.accesses else 0.0

    @property
    def time_ms(self) -> float:
        return self.cycles / (self.freq_ghz * 1e6)

    def summary(self) -> str:
        return (f"{self.name:34s} {self.policy:24s} "
                f"cycles={self.cycles:12.0f} hit={self.hit_rate:6.3f} "
                f"dram_lines={self.dram_lines}")


class _RoundLedger:
    """Per-round accounting shared by both engines.

    One implementation of the outcome-class tallies, per-round
    write-back delta, Eq. 1–2 wall-clock advance, history recording, and
    per-tenant counter attribution — extracted so the compiled and step
    engines cannot drift apart (they are pinned bit-identical, including
    the per-tenant counters, by ``tests/test_compiled_trace.py``).

    Engine contract per non-empty round: ``begin_round()`` before the
    LLC access, then ``end_round(codes, addrs, dup_counts, flops)`` with
    the merged per-line outcome codes, the merged line addresses, and
    the number of MSHR-merged duplicates per line (plus the merged
    per-line owning-tensor ids when an event sink is attached, so MSHR
    events stay exactly attributed under address reuse).  Empty rounds
    call ``idle_round()``.
    """

    def __init__(self, sim: "Simulator", llc: SharedLLC, trace: Trace,
                 record_history: bool, sink: Optional[EventSink] = None):
        self.cfg = sim.cfg
        self.llc = llc
        self.record_history = record_history
        self.sink = sink
        self.clock = 0.0
        self.mshr_hits = 0
        self.dram_lines = 0
        self.flops = 0.0
        self.hist_cycles: List[float] = []
        self.hist_hits: List[int] = []
        self.hist_acc: List[int] = []
        self.hist_gear: List[float] = []
        self.hist_tgear: List[np.ndarray] = []
        # timeline series (one entry per non-empty round)
        self.tl_round: List[int] = []
        self.tl_miss: List[int] = []
        self.tl_byp: List[int] = []
        self.tl_wb: List[int] = []
        self.tl_t_rows: List[np.ndarray] = []   # (tenants, 4) per round
        self.tenant_names = trace.tenant_names
        regions = trace.tenant_region_starts()
        if regions is not None:
            self._t_starts, self._t_ids = regions
            n_t = trace.n_tenants
            self.t_hits = np.zeros(n_t, dtype=np.int64)
            self.t_mshr = np.zeros(n_t, dtype=np.int64)
            self.t_cold = np.zeros(n_t, dtype=np.int64)
            self.t_cf = np.zeros(n_t, dtype=np.int64)
            self.t_byp = np.zeros(n_t, dtype=np.int64)
        else:
            self._t_starts = None
        self._wb_before = 0
        self._t_wb_before: Optional[np.ndarray] = None
        # the ledger owns the global round index: it persists across
        # streaming segments, so event rounds stay monotone and segment
        # concatenation is bit-identical to a monolithic run
        self._r = -1

    # -- engine hooks ---------------------------------------------------
    def idle_round(self) -> None:
        self._r += 1
        self.clock += self.cfg.round_overhead_cycles

    def begin_round(self) -> None:
        self._r += 1
        if self.sink is not None:
            self.sink.begin_round(self._r)
        self._wb_before = self.llc.stats["writebacks"]
        if (self.record_history and self._t_starts is not None
                and self.llc.tenant_wb is not None):
            self._t_wb_before = self.llc.tenant_wb.copy()

    def end_round(self, codes: np.ndarray, addrs: np.ndarray,
                  dup_counts: np.ndarray, flops_round: float,
                  tids: Optional[np.ndarray] = None) -> None:
        if self.sink is not None:
            d = np.nonzero(dup_counts > 0)[0]
            if d.shape[0]:
                self.sink.emit_lines(EV_MSHR, addrs[d],
                                     aux=dup_counts[d].astype(np.int64),
                                     tensors=None if tids is None
                                     else tids[d])
        n_dups = int(dup_counts.sum())
        self.mshr_hits += n_dups
        n_hit = int((codes == C.HIT).sum()) + n_dups
        cold = int(((codes == C.COLD_MISS)
                    | (codes == C.BYPASSED_COLD)).sum())
        cf = int(((codes == C.CONFLICT_MISS)
                  | (codes == C.BYPASSED_CONFLICT)).sum())
        wb_round = self.llc.stats["writebacks"] - self._wb_before
        self.dram_lines += cold + cf + wb_round
        self.flops += flops_round

        if self._t_starts is not None:
            tens = self._t_ids[np.maximum(
                np.searchsorted(self._t_starts, addrs, side="right") - 1,
                0)]
            n_t = self.t_hits.shape[0]
            inc_hits = np.bincount(tens[codes == C.HIT], minlength=n_t)
            inc_mshr = np.bincount(tens, weights=dup_counts,
                                   minlength=n_t).astype(np.int64)
            inc_cold = np.bincount(
                tens[(codes == C.COLD_MISS)
                     | (codes == C.BYPASSED_COLD)], minlength=n_t)
            inc_cf = np.bincount(
                tens[(codes == C.CONFLICT_MISS)
                     | (codes == C.BYPASSED_CONFLICT)], minlength=n_t)
            inc_byp = np.bincount(
                tens[(codes == C.BYPASSED_COLD)
                     | (codes == C.BYPASSED_CONFLICT)], minlength=n_t)
            self.t_hits += inc_hits
            self.t_mshr += inc_mshr
            self.t_cold += inc_cold
            self.t_cf += inc_cf
            self.t_byp += inc_byp
            if self.record_history:
                t_wb = (self.llc.tenant_wb - self._t_wb_before
                        if self._t_wb_before is not None
                        else np.zeros(n_t, dtype=np.int64))
                self.tl_t_rows.append(np.stack(
                    [inc_hits + inc_mshr, inc_cold + inc_cf, inc_byp,
                     t_wb]))

        self.clock += self._round_time(n_hit, cold, cf, cold,
                                       cf + wb_round, flops_round)
        self.llc.tick(self.clock)

        if self.record_history:
            self.hist_cycles.append(self.clock)
            self.hist_hits.append(n_hit)
            self.hist_acc.append(n_hit + cold + cf)
            self.tl_round.append(self._r)
            self.tl_miss.append(cold + cf)
            self.tl_byp.append(int((codes >= C.BYPASSED_COLD).sum()))
            self.tl_wb.append(wb_round)
            ctl = self.llc.controller
            if ctl is not None:
                self.hist_gear.append(float(ctl.gear.mean()))
                if ctl.n_tenants > 1:
                    self.hist_tgear.append(ctl.gear.mean(axis=1))

    # -- result assembly ------------------------------------------------
    def result(self, trace: Trace, policy_name: str,
               freq_ghz: float) -> SimResult:
        llc = self.llc
        history: Dict[str, np.ndarray] = {}
        if self.record_history:
            history = {
                "cycles": np.asarray(self.hist_cycles),
                "hits": np.asarray(self.hist_hits, dtype=np.int64),
                "accesses": np.asarray(self.hist_acc, dtype=np.int64),
            }
            if self.hist_gear:
                history["gear"] = np.asarray(self.hist_gear)
            if self.hist_tgear:
                # (rounds, tenants) mean gear per tenant's feedback loop
                history["tenant_gear"] = np.asarray(self.hist_tgear)

        timeline: Dict[str, np.ndarray] = {}
        if self.record_history:
            timeline = {
                "round": np.asarray(self.tl_round, dtype=np.int64),
                "hits": np.asarray(self.hist_hits, dtype=np.int64),
                "misses": np.asarray(self.tl_miss, dtype=np.int64),
                "bypassed": np.asarray(self.tl_byp, dtype=np.int64),
                "writebacks": np.asarray(self.tl_wb, dtype=np.int64),
            }
            if self.hist_gear:
                timeline["gear"] = np.asarray(self.hist_gear)
            if self.tl_t_rows:
                # (rounds, tenants) series, split out of the per-round
                # (tenants, 4) stacks
                t = np.asarray(self.tl_t_rows, dtype=np.int64)
                timeline["tenant_hits"] = t[:, 0]
                timeline["tenant_misses"] = t[:, 1]
                timeline["tenant_bypassed"] = t[:, 2]
                timeline["tenant_writebacks"] = t[:, 3]

        tenants: Dict[str, Dict[str, int]] = {}
        if self._t_starts is not None:
            wb = llc.tenant_wb if llc.tenant_wb is not None else \
                np.zeros_like(self.t_hits)
            for i, name in enumerate(self.tenant_names):
                tenants[name] = {
                    "hits": int(self.t_hits[i]),
                    "mshr_hits": int(self.t_mshr[i]),
                    "cold_misses": int(self.t_cold[i]),
                    "conflict_misses": int(self.t_cf[i]),
                    "bypassed": int(self.t_byp[i]),
                    "writebacks": int(wb[i]),
                }

        return SimResult(
            name=trace.name, policy=policy_name, cycles=self.clock,
            hits=llc.stats["hits"], mshr_hits=self.mshr_hits,
            cold_misses=llc.stats["cold_misses"],
            conflict_misses=llc.stats["conflict_misses"],
            bypassed=llc.stats["bypassed"],
            dram_lines=self.dram_lines,
            writebacks=llc.stats["writebacks"],
            dead_evictions=llc.stats["dead_evictions"],
            flops=self.flops, freq_ghz=freq_ghz, history=history,
            tenants=tenants, timeline=timeline, events=self.sink,
        )

    # ------------------------------------------------------------------
    def _round_time(self, n_hit: int, n_cold: int, n_cf: int,
                    dram_cold: int, dram_cf: int, flops: float) -> float:
        cfg = self.cfg
        issue = cfg.n_cores * cfg.ipc_mem
        bw = cfg.dram_lines_per_cycle
        t_hit = max(n_hit / issue, n_hit / cfg.v_llc) if n_hit else 0.0
        t_cold = max(n_cold / issue, n_cold / cfg.v_llc,
                     dram_cold / (cfg.dram_eff_seq * bw)) if n_cold else 0.0
        t_cf = max(n_cf / issue, n_cf / cfg.v_llc,
                   dram_cf / (cfg.dram_eff_rand * bw)) if (n_cf or dram_cf) \
            else 0.0
        t_comp = flops / (cfg.n_cores * cfg.core_flops_per_cycle)
        return t_hit + t_cold + max(t_comp, t_cf) + cfg.round_overhead_cycles


class Simulator:
    """Run one trace under one policy."""

    def __init__(self, cfg: SimConfig, policy: PolicyConfig,
                 tmu_params: Optional[TMUParams] = None):
        self.cfg = cfg
        self.policy = policy
        self.tmu_params = tmu_params or TMUParams(b_bits=policy.b_bits)

    # ------------------------------------------------------------------
    def _fresh_state(self, trace: Trace,
                     sink: Optional[EventSink] = None
                     ) -> Tuple[CacheGeometry, TMU, SharedLLC]:
        cfg = self.cfg
        geom = CacheGeometry(cfg.llc_bytes, cfg.line_bytes, cfg.llc_assoc,
                             cfg.llc_slices)
        tmu = TMU(line_bytes=cfg.line_bytes,
                  tensor_entries=cfg.tmu_tensor_entries,
                  tile_entries=cfg.tmu_tile_entries,
                  dead_fifo_depth=cfg.dead_fifo_depth,
                  params=self.tmu_params)
        tmu.register_many(trace.tensors.values())
        llc = SharedLLC(geom, self.policy, tmu=tmu,
                        tenant_map=trace.tenant_region_starts())
        if sink is not None:
            sink.bind(trace, geom)
            llc.sink = sink
            tmu.sink = sink
            if llc.controller is not None:
                llc.controller.sink = sink
        return geom, tmu, llc

    def _resolve_sink(self,
                      events: Optional[EventSink]) -> Optional[EventSink]:
        if events is not None:
            return events
        return EventSink() if self.cfg.trace_events else None

    def run(self, trace: Trace, record_history: bool = True,
            *, engine: str = "compiled",
            chunk_lines: Optional[int] = None,
            events: Optional[EventSink] = None) -> SimResult:
        """Simulate ``trace`` under this simulator's policy.

        ``engine="compiled"`` (default) drives the cached
        :class:`~repro.core.traces.CompiledTrace`; ``engine="steps"``
        re-walks the Python step lists (reference oracle).
        ``chunk_lines`` switches the compiled engine to streaming mode:
        the trace is lowered in whole-round CSR segments of at most that
        many pre-merge line requests, fed incrementally to the same
        round loop — bit-identical counters, bounded lowering memory.
        ``events`` attaches an :class:`~repro.core.events.EventSink`
        (one per run) that collects the canonical event stream;
        ``SimConfig.trace_events=True`` creates one implicitly.  The
        sink comes back on ``SimResult.events``.
        """
        if self.cfg.line_bytes != trace.line_bytes:
            # traces bake line granularity into their addresses; a
            # mismatched cache-line size silently corrupts the seen
            # bitmaps (and used to IndexError deep in the round loop)
            raise ValueError(
                f"SimConfig.line_bytes={self.cfg.line_bytes} does not "
                f"match trace line_bytes={trace.line_bytes}")
        if engine == "compiled":
            return self._run_compiled(trace, record_history, chunk_lines,
                                      events)
        if engine == "steps":
            if chunk_lines is not None:
                raise ValueError("chunk_lines requires engine='compiled'")
            return self._run_steps(trace, record_history, events)
        raise ValueError(f"unknown engine {engine!r}")

    # ------------------------------------------------------------------
    # compiled engine: slice flat per-round arrays
    # ------------------------------------------------------------------
    def _run_compiled(self, trace: Trace, record_history: bool,
                      chunk_lines: Optional[int] = None,
                      events: Optional[EventSink] = None) -> SimResult:
        if chunk_lines is None:
            segments = (trace.compiled(self.cfg.line_bytes),)
        else:
            segments = trace.compiled_segments(self.cfg.line_bytes,
                                               chunk_lines)
        return self.run_segments(trace, segments, record_history,
                                 events=events)

    def run_segments(self, trace: Trace, segments,
                     record_history: bool = True, *,
                     events: Optional[EventSink] = None) -> SimResult:
        """Streaming entry point: consume :class:`CompiledTrace`
        segments incrementally against one persistent cache/TMU/ledger
        state.  Cache state, the global seen bitmap, and the gear
        controller all persist across segment boundaries, so the result
        is bit-identical to a monolithic run — this is the hook the
        serving-replay path (``repro.serve``) uses to drive traces too
        large to materialize up front.  An attached event sink persists
        the same way: the round index lives in the ledger, so segment-
        by-segment emission concatenates bit-identically to the
        monolithic event stream."""
        cfg = self.cfg
        sink = self._resolve_sink(events)
        geom, tmu, llc = self._fresh_state(trace, sink)
        gqa = self.policy.gqa_variant
        led = _RoundLedger(self, llc, trace, record_history, sink)
        seen = np.zeros(0, dtype=bool)
        for ct in segments:
            # the dense seen-bitmap layout is global across segments;
            # grow (never shrink) when a segment raises the high-water
            # mark — new lines start unseen, exactly like a monolithic
            # allocation would
            seen = _grow_seen(seen, ct.n_seen_lines)
            self._consume_segment(ct, geom, tmu, llc, led, seen, gqa)
        return led.result(trace, self.policy.name, cfg.freq_ghz)

    def run_stream(self, stream, *, name: str = "replay",
                   record_history: bool = True,
                   events: Optional[EventSink] = None) -> SimResult:
        """Consume an *open-ended* stream of
        :class:`~repro.dataflows.stream.ReplaySegment` items — segments
        whose tensor population changes over time (the serving-replay
        path, DESIGN.md §11).

        Unlike :meth:`run_segments`, which assumes one fixed trace with
        all tensors registered up front, each segment here carries its
        own TMU registrations (``new_tensors``, applied before the
        segment's rounds), retirements (``clear_tids``, applied after —
        the paper's second specialized instruction at request
        completion), and recycled seen-bitmap ranges (``seen_resets``,
        zeroed before, so a reused dense range observes cold misses
        exactly as a fresh monolithic allocation would).  Cache, gear,
        ledger, and dead-FIFO state persist across segments, so on a
        small seed the counters and the raw event stream are
        bit-identical to lowering the whole replay into one
        ``DataflowSpec`` and calling :meth:`run`.
        """
        cfg = self.cfg
        sink = self._resolve_sink(events)
        n_cores = cfg.n_cores
        header = Trace(name=name, tensors={},
                       core_steps=[[] for _ in range(n_cores)],
                       core_group=[-1] * n_cores,
                       core_is_leader=[True] * n_cores,
                       line_bytes=cfg.line_bytes)
        geom, tmu, llc = self._fresh_state(header, sink)
        gqa = self.policy.gqa_variant
        led = _RoundLedger(self, llc, header, record_history, sink)
        seen = np.zeros(0, dtype=bool)
        for seg in stream:
            seen = _grow_seen(seen, seg.n_seen_lines)
            for s0, s1 in seg.seen_resets:
                seen[s0:s1] = False
            if sink is not None and seg.clear_tids:
                # a pooled allocator may hand a retiring tensor's region
                # to a tensor declared in this same segment window, so
                # the retirements must leave the live-region map before
                # the new registrations are overlap-checked (the actual
                # TMU clear still happens after the segment's rounds)
                sink.release_tensors(seg.clear_tids)
            if seg.new_tensors:
                tmu.register_many(seg.new_tensors)
                if sink is not None:
                    sink.register_tensors(
                        seg.new_tensors,
                        retiring_tids=frozenset(seg.clear_tids))
            self._consume_segment(seg.ct, geom, tmu, llc, led, seen, gqa)
            for tid in seg.clear_tids:
                tmu.clear(tid)
        return led.result(header, self.policy.name, cfg.freq_ghz)

    def _consume_segment(self, ct, geom, tmu, llc, led, seen,
                         gqa) -> None:
        plans = ct.plans_for(geom)
        tll_tags = ct.tll_tags_for(geom)   # per-geometry, sweep-shared
        round_off = ct.round_off
        tll_off = ct.tll_off
        sink = led.sink
        for r in range(ct.n_rounds):
            a0, a1 = round_off[r], round_off[r + 1]
            if a0 == a1:
                led.idle_round()
                continue

            # contention only gates gqa eligibility; reading it has no
            # side effects, so non-gqa policies skip the check
            contended = (gqa and llc.controller is not None
                         and bool(llc.controller.contended().any()))
            sel = slice(a0, a1)
            dense = ct.u_dense[sel]
            seen_b = seen[dense]           # fancy indexing → fresh copy
            seen[dense] = True
            elig = (ct.u_nonleader[sel] & contended) if gqa else True

            led.begin_round()
            tids = ct.u_tid[sel] if sink is not None else None
            codes = llc.access_planned(plans[r],
                                       seen_before=seen_b,
                                       is_write=ct.u_write[sel],
                                       bypass_eligible=elig,
                                       force_bypass=ct.u_force[sel],
                                       cores=ct.u_core[sel]
                                       if sink is not None else None,
                                       tids=tids)
            t0, t1 = tll_off[r], tll_off[r + 1]
            if t1 > t0:
                tmu.on_access_batch(ct.tll_tids[t0:t1], ct.tll_tiles[t0:t1],
                                    tll_tags[t0:t1], ct.tll_nacc[t0:t1])
            led.end_round(codes, ct.u_addrs[sel], ct.u_dups[sel],
                          float(ct.flops_round[r]), tids=tids)

    # ------------------------------------------------------------------
    # step engine: reference implementation over Python Step lists
    # ------------------------------------------------------------------
    def _run_steps(self, trace: Trace, record_history: bool,
                   events: Optional[EventSink] = None) -> SimResult:
        cfg = self.cfg
        sink = self._resolve_sink(events)
        geom, tmu, llc = self._fresh_state(trace, sink)

        # per-tensor "ever fetched" bitmaps for cold/conflict classification
        seen: Dict[int, np.ndarray] = {
            tid: np.zeros(m.size_bytes // cfg.line_bytes, dtype=bool)
            for tid, m in trace.tensors.items()
        }

        n_rounds = trace.n_rounds
        led = _RoundLedger(self, llc, trace, record_history, sink)

        tensors = trace.tensors
        line_b = cfg.line_bytes

        for r in range(n_rounds):
            addrs_parts: List[np.ndarray] = []
            seen_parts: List[np.ndarray] = []
            force_parts: List[np.ndarray] = []
            elig_parts: List[np.ndarray] = []
            write_parts: List[np.ndarray] = []
            core_parts: List[np.ndarray] = []      # only when tracing
            tid_parts: List[np.ndarray] = []       # only when tracing
            # (tensor_id, tile, tag, n_acc) — resolved here, not by
            # address, so TLL accounting stays exact when a pooled
            # allocator recycles address ranges across tensors
            tll_calls: List[Tuple[int, int, int, int]] = []
            flops_round = 0.0

            contended = (llc.controller is not None
                         and bool(llc.controller.contended().any()))

            for c, steps in enumerate(trace.core_steps):
                if r >= len(steps):
                    continue
                step = steps[r]
                flops_round += step.flops
                # gqa_bypass: only non-leader ("slower") cores bypass, and
                # only when the LLC is contended (paper §IV-E).
                if self.policy.gqa_variant:
                    eligible = (not trace.core_is_leader[c]) and contended
                else:
                    eligible = True
                for (tid, tile), is_store in (
                        [(ld, False) for ld in step.loads]
                        + [(s, True) for s in step.stores]):
                    meta = tensors[tid]
                    lines = trace.tile_lines(tid, tile)
                    k = lines.shape[0]
                    idx0 = (lines[0] - meta.base_addr) // line_b
                    sv = seen[tid][idx0:idx0 + k]
                    addrs_parts.append(lines)
                    seen_parts.append(sv.copy())
                    sv[:] = True
                    force_parts.append(
                        np.full(k, meta.bypass_all, dtype=bool))
                    elig_parts.append(np.full(k, eligible, dtype=bool))
                    write_parts.append(np.full(k, is_store, dtype=bool))
                    if sink is not None:
                        core_parts.append(np.full(k, c, dtype=np.int64))
                        tid_parts.append(np.full(k, tid, dtype=np.int64))
                    if not is_store and not meta.bypass_all:
                        tll_addr = meta.tile_last_line(tile, line_b)
                        tll_calls.append(
                            (tid, tile,
                             int(geom.tag_of(np.int64(tll_addr))),
                             meta.n_acc))

            if not addrs_parts:
                led.idle_round()
                continue

            addrs = np.concatenate(addrs_parts)
            seen_b = np.concatenate(seen_parts)
            force_b = np.concatenate(force_parts)
            elig_b = np.concatenate(elig_parts)
            write_b = np.concatenate(write_parts)

            # MSHR merge: same-line requests issued in the same round are
            # merged into one in-flight fill — policy-independent, even for
            # bypassed lines (an MSHR entry exists for the duration of the
            # DRAM fetch whether or not the fill allocates).  Only the
            # first occurrence touches the cache state, but write intent is
            # OR-ed over the duplicates so a load+store merge still dirties
            # the line (writeback accounting).
            u_addrs, first_idx, inv, counts = np.unique(
                addrs, return_index=True, return_inverse=True,
                return_counts=True)
            write_m = np.bincount(inv, weights=write_b,
                                  minlength=first_idx.shape[0]) > 0

            led.begin_round()
            # first merged occurrence keeps its requester/owner,
            # matching the compiled lowering's u_core/u_tid
            tids_m = (np.concatenate(tid_parts)[first_idx]
                      if sink is not None else None)
            codes = llc.access_burst(
                addrs[first_idx],
                seen_before=seen_b[first_idx],
                is_write=write_m,
                bypass_eligible=elig_b[first_idx],
                force_bypass=force_b[first_idx],
                cores=np.concatenate(core_parts)[first_idx]
                if sink is not None else None,
                tids=tids_m)

            if tll_calls:
                t_tid, t_tile, t_tag, t_nacc = zip(*tll_calls)
                tmu.on_access_batch(
                    np.asarray(t_tid, dtype=np.int64),
                    np.asarray(t_tile, dtype=np.int64),
                    np.asarray(t_tag, dtype=np.int64),
                    np.asarray(t_nacc, dtype=np.int64))

            led.end_round(codes, u_addrs, counts - 1, flops_round,
                          tids=tids_m)

        return led.result(trace, self.policy.name, cfg.freq_ghz)


def _grow_seen(seen: np.ndarray, n_lines: int) -> np.ndarray:
    """Grow the dense seen bitmap to ``n_lines`` (new lines unseen)."""
    if n_lines <= seen.shape[0]:
        return seen
    grown = np.zeros(n_lines, dtype=bool)
    grown[:seen.shape[0]] = seen
    return grown


PolicyLike = Union[str, PolicyConfig]


def _resolve_policy(p: PolicyLike) -> PolicyConfig:
    return named_policy(p) if isinstance(p, str) else p


def run_policy(trace: Trace, policy: PolicyLike,
               cfg: Optional[SimConfig] = None,
               record_history: bool = True,
               engine: str = "compiled") -> SimResult:
    return Simulator(cfg or SimConfig(), _resolve_policy(policy)).run(
        trace, record_history=record_history, engine=engine)


def run_policies(trace: Trace, policies: Iterable[PolicyLike],
                 cfg: Optional[SimConfig] = None,
                 record_history: bool = False,
                 tmu_params: Optional[TMUParams] = None,
                 capacities: Optional[Iterable[int]] = None):
    """Batch policy sweep over one trace (the paper's figure workflow).

    The trace is lowered once (``trace.compiled``) and the lowering —
    plus the geometry-dependent access plans — is shared by every policy,
    so sweeping N policies costs one compile plus N fast vectorized runs
    instead of N Python trace walks.  Results come back in input order
    with counters bit-identical to individual :func:`run_policy` calls.

    ``capacities`` adds a capacity axis (the §VI capacity sweeps):
    ``cfg.llc_bytes`` is replaced by each entry and the return value
    becomes a nested list indexed ``[policy][capacity]``.  Plans are
    cached per :class:`~repro.core.cache.CacheGeometry` on the shared
    compiled trace, so the P×C sweep still compiles once and sorts each
    distinct geometry once.
    """
    cfg = cfg or SimConfig()
    trace.compiled(cfg.line_bytes)       # build once, shared by all runs
    pols = [_resolve_policy(p) for p in policies]
    if capacities is None:
        return [
            Simulator(cfg, p, tmu_params).run(
                trace, record_history=record_history)
            for p in pols
        ]
    caps = list(capacities)
    return [
        [Simulator(replace(cfg, llc_bytes=int(c)), p, tmu_params).run(
            trace, record_history=record_history)
         for c in caps]
        for p in pols
    ]

"""jit-callable wrapper for the SSD scan kernel."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernel import build_ssd_call


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 256,
             interpret: bool = False
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Kernelized SSD.  x (B,S,H,P); dt (B,S,H); A (H,); B/C (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if s % chunk:
        raise ValueError("sequence must be chunk-aligned")
    rep = h // g

    # flatten (B,S,H,P) → (B·H, S, P); broadcast groups to heads
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s, 1)
    af = jnp.broadcast_to(A[None], (b, h)).reshape(b * h, 1)
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    bf = Bh.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    cf = Ch.transpose(0, 2, 1, 3).reshape(b * h, s, n)

    call = build_ssd_call(bh=b * h, seq=s, p=p, n=n, chunk=chunk,
                          dtype=x.dtype, interpret=interpret)
    yf, state = call(xf, dtf, af, bf, cf)
    y = yf.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    # kernel state layout (N, P) → model layout (P, N)
    final = state.reshape(b, h, n, p).transpose(0, 1, 3, 2)
    return y, final

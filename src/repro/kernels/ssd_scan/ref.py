"""Pure-jnp oracle for the SSD scan kernel: delegates to the model's
chunked SSD implementation (itself validated against a sequential scan in
tests)."""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            B: jnp.ndarray, C: jnp.ndarray, chunk: int
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,H,P); dt (B,S,H); A (H,); B/C (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    return ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                       B.astype(jnp.float32), C.astype(jnp.float32), chunk)


def ssd_sequential_ref(x, dt, A, B, C):
    """O(S) sequential recurrence — ground truth for both implementations."""
    import jax
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)

    def step(state, inputs):
        xt, dtt, Bt, Ct = inputs
        dA = jnp.exp(dtt * A)                       # (b,h)
        upd = jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], Bt)
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3), final

"""Mamba2 SSD chunked-scan Pallas-TPU kernel.

Grid: (batch·heads, n_chunks) — the chunk axis is the innermost,
*sequential* TPU grid dimension, so the inter-chunk SSM state lives in
VMEM scratch across chunks and is never written back to HBM until the
final state output.  This is the orchestrator's dead-block insight applied
to SSM state: a chunk's running state has a known one-chunk lifetime and
therefore never claims HBM bandwidth (contrast a naive implementation
that materializes (n_chunks, P, N) states).

Per chunk (intra-chunk quadratic + state update):
    L[i,j]   = exp(cum_i - cum_j) (causal)        — (Q, Q)
    y_diag   = (C·Bᵀ ∘ L) (x·dt)                  — (Q, P)
    y_off    = C · state_in · exp(cum)            — (Q, P)
    state    = state_in·exp(total) + Bᵀ·(x·dt·decay_to_end)
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp


def ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
               state_ref, *, chunk: int, n_chunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0]                                       # (1,) — A for head
    dt = dt_ref[0].astype(jnp.float32)                 # (Q, 1)
    x = x_ref[0].astype(jnp.float32)                   # (Q, P)
    B = b_ref[0].astype(jnp.float32)                   # (Q, N)
    C = c_ref[0].astype(jnp.float32)                   # (Q, N)

    da = dt[:, 0] * a                                  # (Q,)
    cum = jnp.cumsum(da)                               # inclusive
    total = cum[-1]
    xd = x * dt                                        # (Q, P)

    # intra-chunk: causal decay matrix L
    seg = cum[:, None] - cum[None, :]                  # (Q, Q)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iota_i >= iota_j, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * L, xd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state, then state update
    state = state_ref[...]                             # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    decay_to_end = jnp.exp(total - cum)                # (Q,)
    new_state = state * jnp.exp(total) + jax.lax.dot_general(
        B, xd * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_ref[...] = new_state
    y_ref[0, ...] = y.astype(y_ref.dtype)

    @pl.when(c_idx == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, ...] = state_ref[...]


def build_ssd_call(*, bh: int, seq: int, p: int, n: int, chunk: int,
                   dtype, interpret: bool):
    n_chunks = seq // chunk
    grid = (bh, n_chunks)
    kernel = functools.partial(ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),   # x
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),   # dt
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),             # A
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),   # B
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),   # C
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),   # y
            pl.BlockSpec((1, n, p), lambda b, c: (b, 0, 0)),       # state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq, p), dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )

from .ops import ssd_scan
from .ref import ssd_ref
from .ref import ssd_sequential_ref

__all__ = ["ssd_scan", "ssd_ref", "ssd_sequential_ref"]

"""jit-callable wrapper for the decode-attention kernel."""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import build_decode_call


@functools.partial(jax.jit, static_argnames=("scale", "block_k",
                                              "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     cache_len: jnp.ndarray, *,
                     scale: Optional[float] = None,
                     block_k: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """q (B, H, D) single new token; k/v (B, S, G, D) KV cache;
    cache_len (B,) int32 valid lengths.  Returns (B, H, D)."""
    b, h, d = q.shape
    _, s, g, _ = k.shape
    if h % g:
        raise ValueError("n_heads must be divisible by n_kv_heads")
    if s % block_k:
        raise ValueError("cache length must be block-aligned")
    group = h // g
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    # (B, H, D) → (B·G, group, D): one GQA group per grid row
    qf = q.reshape(b, g, group, d).reshape(b * g, group, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * g, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * g, s, d)
    lens = jnp.repeat(cache_len.astype(jnp.int32), g)

    call = build_decode_call(bg=b * g, group=group, seq_k=s, head_dim=d,
                             scale=scale, block_k=block_k, dtype=q.dtype,
                             interpret=interpret)
    of = call(lens, qf, kf, vf)
    return of.reshape(b, g, group, d).reshape(b, h, d)

"""Pure-jnp oracle for decode attention (one token vs KV cache)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         cache_len: jnp.ndarray, *,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """q (B, H, D); k/v (B, S, G, D); cache_len (B,) valid prefix lengths.
    Returns (B, H, D)."""
    b, h, d = q.shape
    _, s, g, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, g, h // g, d).astype(jnp.float32)
    sc = jnp.einsum("bgqd,btgd->bgqt", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, None, None, :] < cache_len[:, None, None,
                                                           None]
    sc = jnp.where(valid, sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bgqt,btgd->bgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)

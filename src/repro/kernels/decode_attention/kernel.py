"""Single-token decode attention Pallas-TPU kernel.

One new query token attends over a long KV cache — the inference-decode
stress case the paper evaluates (decode_32k / long_500k shapes).  All the
query heads of one GQA group are processed together as the (sublane)
rows of a single MXU operand, so every fetched KV block is reused
``group`` times from VMEM — the kernel-level counterpart of the paper's
inter-core KV sharing captured by the shared LLC.

Grid: (batch·kv_heads, n_kv_blocks); online-softmax carry (m, l, acc) in
VMEM scratch across the sequential KV axis; KV blocks past ``cache_len``
(scalar-prefetched) are skipped — the dead-block analogue: retired slots
are never fetched.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

NEG_INF = -1e30


def decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, scale: float, block_k: int, n_kv: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[pl.program_id(0)]
    k_off = j * block_k

    @pl.when(k_off < cache_len)
    def _step():
        q = q_ref[0].astype(jnp.float32)           # (group, d)
        k = k_ref[0].astype(jnp.float32)           # (block_k, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_off + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < cache_len, s, NEG_INF)
        m_prev, l_prev = m_ref[:, 0], l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(j == n_kv - 1)
    def _finalize():
        l_sum = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l_sum[:, None]).astype(o_ref.dtype)


def build_decode_call(*, bg: int, group: int, seq_k: int, head_dim: int,
                      scale: float, block_k: int, dtype, interpret: bool):
    n_kv = seq_k // block_k
    grid = (bg, n_kv)
    kernel = functools.partial(decode_kernel, scale=scale,
                               block_k=block_k, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, group, head_dim), lambda b, j, lens: (b, 0, 0)),
                pl.BlockSpec((1, block_k, head_dim),
                             lambda b, j, lens: (b, j, 0)),
                pl.BlockSpec((1, block_k, head_dim),
                             lambda b, j, lens: (b, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, group, head_dim),
                                   lambda b, j, lens: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, head_dim), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bg, group, head_dim), dtype),
        interpret=interpret,
    )

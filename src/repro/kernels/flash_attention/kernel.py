"""FlashAttention-2 forward Pallas-TPU kernel with DCO KV orchestration.

TPU adaptation of the paper's policies (DESIGN.md §3):

* **anti-thrashing → pinned KV prefix**: ``k_pin``/``v_pin`` enter through
  BlockSpecs whose index_map is *constant*, so Mosaic keeps the same VMEM
  block across all grid steps (copy elided between consecutive identical
  indices) — the prefix is fetched from HBM exactly once per (batch,head)
  and reused by every Q block, exactly like the LLC keeping ``S_kept``.
* **bypass → streamed KV remainder**: ``k_str``/``v_str`` blocks are
  re-walked per Q block (index_map depends on the innermost grid axis),
  i.e. they never claim persistent VMEM — the cache-bypass analogue.
* The split point comes from ``CacheOrchestrator.plan_kv_split`` (the
  paper's ``S_kept = S_work·M/2^B_BITS ≤ budget·(A-1)/A`` rule).

Grid: (batch·heads, n_q_blocks, n_stream_blocks); the streamed axis is the
innermost (sequential) dimension, with online-softmax state in VMEM
scratch.  The pinned region is consumed by an in-kernel loop at the first
streamed step.

MXU alignment: block_q/block_k default to 128; head_dim is padded to a
multiple of 128 by ``ops.flash_attention`` when needed.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

NEG_INF = -1e30


def _attend(q, k, v, m_prev, l_prev, acc, *, scale, softcap, q_off, k_off,
            causal, block_q, block_k):
    """One online-softmax update with block-offset causal masking."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        q_pos = q_off + jax.lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_k), 0)
        k_pos = k_off + jax.lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_new = acc * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def flash_kernel(q_ref, kp_ref, vp_ref, ks_ref, vs_ref, o_ref,
                 m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, softcap,
                 block_q: int, block_k: int,
                 pinned_rows: int, n_stream: int):
    i = pl.program_id(1)
    j = pl.program_id(2)
    q_off = i * block_q

    @pl.when(j == 0)
    def _init():
        m = jnp.full((block_q, 1), NEG_INF, jnp.float32)
        l_i = jnp.zeros((block_q, 1), jnp.float32)
        acc = jnp.zeros_like(acc_ref)
        q = q_ref[0].astype(jnp.float32)

        # ---- pinned prefix (VMEM-resident across the whole grid) ----
        if pinned_rows:
            n_pin = pinned_rows // block_k

            def body(jj, carry):
                m_c, l_c, a_c = carry
                k = kp_ref[0, pl.dslice(jj * block_k, block_k)]
                v = vp_ref[0, pl.dslice(jj * block_k, block_k)]
                m2, l2, a2 = _attend(
                    q, k.astype(jnp.float32), v, m_c[:, 0], l_c[:, 0],
                    a_c, scale=scale, softcap=softcap, q_off=q_off,
                    k_off=jj * block_k, causal=causal,
                    block_q=block_q, block_k=block_k)
                return m2[:, None], l2[:, None], a2

            m, l_i, acc = jax.lax.fori_loop(0, n_pin, body, (m, l_i, acc))
        m_ref[...] = m
        l_ref[...] = l_i
        acc_ref[...] = acc

    # ---- streamed remainder (re-fetched per Q block: bypass class) ----
    if n_stream:
        k_off = pinned_rows + j * block_k

        def _stream():
            q = q_ref[0].astype(jnp.float32)
            m2, l2, a2 = _attend(
                q, ks_ref[0].astype(jnp.float32), vs_ref[0],
                m_ref[:, 0], l_ref[:, 0], acc_ref[...],
                scale=scale, softcap=softcap, q_off=q_off, k_off=k_off,
                causal=causal, block_q=block_q, block_k=block_k)
            m_ref[...] = m2[:, None]
            l_ref[...] = l2[:, None]
            acc_ref[...] = a2

        if causal:
            # skip fully-masked streamed blocks
            pl.when(k_off <= q_off + block_q - 1)(_stream)
        else:
            _stream()

    @pl.when(j == max(n_stream - 1, 0))
    def _finalize():
        l_sum = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l_sum[:, None]).astype(o_ref.dtype)


def build_flash_call(*, bh: int, n_heads: int, n_kv_heads: int,
                     seq_q: int, seq_k: int, head_dim: int,
                     scale: float, causal: bool, softcap,
                     pinned_rows: int, block_q: int, block_k: int,
                     dtype, interpret: bool):
    """Construct the pallas_call for given static shapes."""
    group = n_heads // n_kv_heads
    stream_rows = seq_k - pinned_rows
    n_q = seq_q // block_q
    n_stream = stream_rows // block_k
    grid = (bh, n_q, max(n_stream, 1))

    def kv_head(b):
        # flattened (batch*heads) index → (batch*kv_heads) index
        return (b // n_heads) * n_kv_heads + (b % n_heads) // group

    q_spec = pl.BlockSpec((1, block_q, head_dim),
                          lambda b, i, j: (b, i, 0))
    pin_spec = pl.BlockSpec((1, max(pinned_rows, block_k), head_dim),
                            lambda b, i, j: (kv_head(b), 0, 0))
    str_spec = pl.BlockSpec((1, block_k, head_dim),
                            lambda b, i, j: (kv_head(b), j, 0))
    o_spec = pl.BlockSpec((1, block_q, head_dim),
                          lambda b, i, j: (b, i, 0))

    kernel = functools.partial(
        flash_kernel, scale=scale, causal=causal, softcap=softcap,
        block_q=block_q, block_k=block_k, pinned_rows=pinned_rows,
        n_stream=n_stream)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, pin_spec, pin_spec, str_spec, str_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((bh, seq_q, head_dim), dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, head_dim), jnp.float32),  # acc
        ],
        interpret=interpret,
    )

"""jit-callable wrapper around the flash-attention Pallas kernel."""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import build_flash_call


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "softcap", "pinned_rows", "block_q", "block_k",
    "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    scale: Optional[float] = None,
                    softcap: Optional[float] = None,
                    pinned_rows: int = 0,
                    block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """FlashAttention-2 forward with DCO KV orchestration.

    q (B, Sq, H, D); k/v (B, Sk, G, D).  ``pinned_rows`` KV rows (a
    multiple of block_k, from ``CacheOrchestrator.plan_kv_split``) stay
    VMEM-resident across the Q loop; the rest stream per Q block.
    """
    b, sq, h, d = q.shape
    _, sk, g, _ = k.shape
    if h % g:
        raise ValueError("n_heads must be divisible by n_kv_heads")
    if sq % block_q or sk % block_k:
        raise ValueError("sequence lengths must be block-aligned")
    if pinned_rows % block_k or not 0 <= pinned_rows <= sk:
        raise ValueError("pinned_rows must be a block-aligned prefix")
    if causal and sq != sk:
        raise ValueError("causal masking assumes aligned q/k sequences; "
                         "use decode_attention for cached decoding")
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    # layout: flatten (B, S, H, D) → (B·H, S, D)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * g, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * g, sk, d)

    stream_rows = sk - pinned_rows
    if pinned_rows:
        k_pin, v_pin = kf[:, :pinned_rows], vf[:, :pinned_rows]
    else:  # dummy one-block operand (never read: kernel skips the loop)
        k_pin = jnp.zeros((b * g, block_k, d), kf.dtype)
        v_pin = jnp.zeros((b * g, block_k, d), vf.dtype)
    if stream_rows:
        k_str, v_str = kf[:, pinned_rows:], vf[:, pinned_rows:]
    else:
        k_str = jnp.zeros((b * g, block_k, d), kf.dtype)
        v_str = jnp.zeros((b * g, block_k, d), vf.dtype)

    call = build_flash_call(
        bh=b * h, n_heads=h, n_kv_heads=g, seq_q=sq, seq_k=sk,
        head_dim=d, scale=scale, causal=causal, softcap=softcap,
        pinned_rows=pinned_rows, block_q=block_q, block_k=block_k,
        dtype=q.dtype, interpret=interpret)
    of = call(qf, k_pin, v_pin, k_str, v_str)
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)

"""Pure-jnp oracle for the flash-attention kernel (no tiling, fp32)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True,
                  scale: Optional[float] = None,
                  softcap: Optional[float] = None) -> jnp.ndarray:
    """q (B, Sq, H, D); k/v (B, Sk, G, D); returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, g, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, g, h // g, d).astype(jnp.float32)
    s = jnp.einsum("bsgqd,btgd->bgqst", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqst,btgd->bsgqd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)

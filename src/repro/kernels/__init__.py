"""Pallas-TPU kernels for the performance-critical compute layers.

Each kernel package has kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit wrapper) and ref.py (pure-jnp oracle).  Kernels are
validated in interpret mode on CPU (tests/) and activate on real TPU via
the ``use_pallas`` flag in the serve/train configs.
"""

from .decode_attention import decode_attention
from .decode_attention import decode_attention_ref
from .flash_attention import attention_ref
from .flash_attention import flash_attention
from .ssd_scan import ssd_ref
from .ssd_scan import ssd_scan
from .ssd_scan import ssd_sequential_ref

__all__ = ["decode_attention", "decode_attention_ref", "attention_ref",
           "flash_attention", "ssd_ref", "ssd_scan", "ssd_sequential_ref"]

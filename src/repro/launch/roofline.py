"""Roofline-term derivation from compiled dry-run artifacts.

Target hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  Three terms per (arch × shape × mesh):

    compute    = HLO_FLOPs   / (chips × peak_FLOPs)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

``cost_analysis()`` yields per-partition FLOPs/bytes (SPMD compiles one
program), so per-chip terms divide by 1 and global numbers multiply by
``chips``; we record per-chip seconds (identical either way).
Collective bytes are parsed from the optimized HLO text: the summed
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""

from __future__ import annotations

from dataclasses import asdict
from dataclasses import dataclass
import json
import re
from typing import Dict
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(%?[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(",
                     re.MULTILINE)


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every dtype[shape] occurrence in a type string
    (handles tuple types)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Split HLO text into named computation bodies.

    A computation header is ``[ENTRY] %name (params…) -> type {`` — the
    parameter list may contain nested parens (tuple types), so we match
    only the name prefix and the trailing ``{`` + ``->``.
    """
    comps: Dict[str, list] = {}
    current = None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        m = re.match(r"\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
        if (m and stripped.endswith("{") and "->" in stripped
                and "=" not in stripped.split("(")[0]):
            current = m.group(2)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
                continue
            comps[current].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _group_size(line: str, default: int = 16) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_factor(kind: str, gs: int) -> float:
    """Per-chip wire bytes per operand byte (ring algorithms).

    all-gather operands are the LOCAL shard (received data ≈ (g-1)×shard);
    all-reduce operands are the full partial (reduce-scatter + all-gather
    phases ≈ 2·(g-1)/g×full); reduce-scatter / all-to-all move
    (g-1)/g×full; collective-permute moves the operand once.
    """
    if gs <= 1:
        return 0.0
    return {
        "all-gather": float(gs - 1),
        "all-reduce": 2.0 * (gs - 1) / gs,
        "reduce-scatter": (gs - 1) / gs,
        "all-to-all": (gs - 1) / gs,
        "collective-permute": 1.0,
    }[kind]


def _collectives_in(text: str, def_types: Dict[str, str]) -> Dict[str, int]:
    out = {k: 0 for k in _COLLECTIVES}
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"(%?[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+?)((\.\d+)?)\(",
                     stripped)
        if not m:
            continue
        op = m.group(3)
        base = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if base is None:
            continue
        paren = stripped[stripped.index("("):stripped.index(")") + 1]
        operands = re.findall(r"%([\w.\-]+)", paren)
        op_bytes = 0
        for name in operands:
            t = def_types.get(name)
            if t:
                op_bytes += _shape_bytes(t)
        if op_bytes == 0:
            op_bytes = _shape_bytes(m.group(2))
        gs = _group_size(line)
        out[base] += int(op_bytes * _wire_factor(base, gs))
    return out


def collective_bytes(hlo_text: str,
                     main_trips: Optional[list] = None,
                     nested_trip: int = 1) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text.

    Collectives inside while-loop bodies are multiplied by the loop trip
    count: ``main_trips`` lists the trip counts of the top-level layer
    scans in program order (XLA counts a loop body once); a while nested
    inside another body multiplies further by ``nested_trip``.
    """
    def_types: Dict[str, str] = {}
    for m in _DEF_RE.finditer(hlo_text):
        def_types[m.group(1).lstrip("%")] = m.group(2)

    comps = _split_computations(hlo_text)
    # find while ops: (parent_comp, body_name)
    whiles = []
    for cname, body in comps.items():
        for m in re.finditer(
                r"while\(.*?\).*?body=\s*%?([\w.\-]+)", body):
            whiles.append((cname, m.group(1)))
    body_parents = {b: p for p, b in whiles}

    def depth_chain(comp: str) -> int:
        d = 0
        while comp in body_parents:
            d += 1
            comp = body_parents[comp]
        return d

    # assign trip counts to top-level while bodies in program order
    top_bodies = [b for p, b in whiles if depth_chain(p) == 0]
    trips: Dict[str, int] = {}
    mt = list(main_trips or [])
    if mt and len(top_bodies) != len(mt):
        # loop simplifier may inline trip-1 scans: drop 1s first
        mt_eff = [t for t in mt if t != 1]
        mt = mt_eff if len(top_bodies) == len(mt_eff) else \
            [max(mt)] * len(top_bodies)
    for b, t in zip(top_bodies, mt or [1] * len(top_bodies)):
        trips[b] = t
    for p, b in whiles:
        if b not in trips:                       # nested
            trips[b] = trips.get(p, 1) * nested_trip

    out = {k: 0 for k in _COLLECTIVES}
    for cname, body in comps.items():
        mult = trips.get(cname, 1)
        found = _collectives_in(body, def_types)
        for k, v in found.items():
            out[k] += v * mult
    return out


@dataclass
class RooflineEntry:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int]
    peak_memory_bytes: Optional[float]
    model_flops_global: float
    model_bytes_global: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful
        (catches remat/redundancy waste)."""
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def useful_bytes_ratio(self) -> float:
        hlo_global = self.bytes_per_chip * self.chips
        return self.model_bytes_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of roofline achieved: time the dominant resource
        would need for the *algorithmically necessary* work (model FLOPs
        at peak compute, or model bytes at peak HBM bw — whichever is the
        binding floor) over the compiled dominant-term time."""
        tmax = max(self.t_compute, self.t_memory, self.t_collective)
        useful_c = self.model_flops_global / self.chips / PEAK_FLOPS
        useful_m = self.model_bytes_global / self.chips / HBM_BW
        useful = max(useful_c, useful_m)
        return useful / tmax if tmax > 0 else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 useful_bytes_ratio=self.useful_bytes_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for training;
    2·N·D for inference forward; decode counts one new token per seq."""
    n = param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence + attention over the cache
    tokens = shape.global_batch
    flops = 2.0 * n * tokens
    if cfg.n_heads:
        apps = cfg.n_layers if cfg.hybrid_period is None \
            else cfg.n_layers // cfg.hybrid_period
        flops += (4.0 * cfg.n_heads * cfg.head_dim * shape.seq_len
                  * apps * tokens)
    return flops


def model_bytes(cfg, shape) -> float:
    """Algorithmically necessary global HBM traffic for one step.

    train:   3 passes over params (fwd read, bwd read, update rw) in bf16
             + moment reads/writes (fp32 m+v r/w) + activations ≈ params-
             dominated at these batch sizes.
    prefill: params read once (weights stream past activations) + KV write.
    decode:  params read + FULL KV cache read (the binding term) + state.
    """
    n = param_count(cfg)
    if shape.kind == "train":
        return 3 * 2.0 * n + 4 * 4.0 * n          # bf16 passes + fp32 m,v
    if shape.kind == "prefill":
        kv_write = _kv_cache_bytes(cfg, shape)
        return 2.0 * n + kv_write
    return 2.0 * n + _kv_cache_bytes(cfg, shape) + _state_bytes(cfg, shape)


def _kv_cache_bytes(cfg, shape) -> float:
    if not cfg.n_heads:
        return 0.0
    apps = cfg.n_layers if cfg.hybrid_period is None \
        else cfg.n_layers // cfg.hybrid_period
    return (2.0 * apps * shape.global_batch * shape.seq_len
            * cfg.n_kv_heads * cfg.head_dim * 2.0)


def _state_bytes(cfg, shape) -> float:
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    di = cfg.ssm.expand * cfg.d_model
    h = di // cfg.ssm.head_dim
    per_l = h * cfg.ssm.head_dim * cfg.ssm.d_state * 4.0
    return 2.0 * cfg.n_layers * shape.global_batch * per_l   # read+write


def param_count(cfg, active_only: bool = False) -> float:
    """Approximate parameter count from the config (embedding included
    once; MoE counts only active experts when ``active_only``)."""
    d = cfg.d_model
    n = cfg.vocab * d * 2                       # embed + lm_head
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * d if cfg.n_heads else 0
    if cfg.family == "dense":
        n += cfg.n_layers * (attn + 3 * d * cfg.d_ff)
    elif cfg.family == "moe":
        nd = cfg.moe.first_dense
        n += nd * (attn + 3 * d * cfg.d_ff)
        e_active = cfg.moe.top_k if active_only else cfg.moe.n_experts
        per_e = 3 * d * cfg.moe.d_ff_expert
        shared = cfg.moe.n_shared * per_e
        n += (cfg.n_layers - nd) * (attn + e_active * per_e + shared)
    elif cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm.expand * d
        h = di // cfg.ssm.head_dim
        gn = cfg.ssm.n_groups * cfg.ssm.d_state
        per_l = d * (2 * di + 2 * gn + h) + di * d
        n += cfg.n_layers * per_l
        if cfg.family == "hybrid":
            n += attn + 3 * d * cfg.d_ff       # shared block (once)
    return float(n)


def write_report(entries, path: str) -> None:
    with open(path, "w") as f:
        json.dump([e.to_dict() for e in entries], f, indent=1)

"""Serving driver: batched requests through the ServeEngine.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --reduce \
        --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs import reduce_for_smoke
from repro.models import init_params
from repro.serve import Request
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduce:
        cfg = reduce_for_smoke(cfg)
    params = init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(2, cfg.vocab, size=plen).astype(np.int32)
        req = Request(uid=i, prompt=prompt, max_new_tokens=args.max_new)
        engine.add_request(req)
        reqs.append(req)

    t0 = time.time()
    steps = 0
    while any(not r.done for r in reqs):
        engine.step()
        steps += 1
    dt = time.time() - t0
    total_tokens = sum(len(r.tokens_out) for r in reqs)
    for r in reqs:
        print(f"req {r.uid}: prompt_len={len(r.prompt)} -> {r.tokens_out}")
    print(f"{args.requests} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s, {steps} engine steps, "
          f"slot reuse via dead-block retirement)")


if __name__ == "__main__":
    main()

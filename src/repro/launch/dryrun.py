import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- multi-pod dry-run: lower + compile every (arch × shape × mesh) cell ---
# The two lines above MUST run before any other import (jax locks the
# device count at first init).  See DESIGN.md §9 / EXPERIMENTS.md §Dry-run.

import argparse
from functools import partial
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES
from repro.configs import SHAPES
from repro.configs import cell_applicable
from repro.configs import get_arch
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import cache_logical_axes
from repro.models import decode_step
from repro.models import init_cache
from repro.models import init_params
from repro.models import prefill
from repro.sharding import logical_spec
from repro.sharding import use_mesh
from repro.train import AdamWConfig
from repro.train import init_train_state
from repro.train import make_train_step
from repro.train import opt_logical_axes
from repro.train import param_logical_axes


def shardings_for(axes_tree, struct_tree, mesh):
    """Logical-axes pytree + struct pytree → NamedSharding pytree
    (shape-aware: indivisible dims fall back to replication)."""
    def one(axes, struct):
        if axes is None or struct is None:
            return NamedSharding(mesh, P())
        spec = logical_spec(tuple(axes), mesh, shape=struct.shape)
        return NamedSharding(mesh, spec)

    def is_axes_leaf(x):
        # plain tuples are axis specs; NamedTuples (Cache) are containers
        return x is None or (isinstance(x, tuple)
                             and not hasattr(x, "_fields"))

    return jax.tree.map(one, axes_tree, struct_tree, is_leaf=is_axes_leaf)




def build_cell(cfg, shape, mesh):
    """Returns (fn, arg_structs, in_shardings) for one dry-run cell."""
    params_struct = jax.eval_shape(
        lambda: init_params(cfg, jax.random.key(0)))
    p_shard = shardings_for(param_logical_axes(cfg), params_struct, mesh)

    if shape.kind == "train":
        tokens = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                      jnp.int32)
        state_struct = jax.eval_shape(
            lambda: init_train_state(
                init_params(cfg, jax.random.key(0))))
        opt_ax = opt_logical_axes(cfg)
        state_shard = jax.tree.map(
            lambda s: None, state_struct,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        state_shard = type(state_struct)(
            params=p_shard,
            opt=type(state_struct.opt)(
                m=shardings_for(opt_ax, state_struct.opt.m, mesh),
                v=shardings_for(opt_ax, state_struct.opt.v, mesh),
                step=NamedSharding(mesh, P())))
        tok_shard = NamedSharding(
            mesh, logical_spec(("dp", None), mesh, tokens.shape))
        fn = make_train_step(cfg, AdamWConfig())
        return fn, (state_struct, tokens), (state_shard, tok_shard), \
            {"donate_argnums": (0,)}

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                      jnp.int32)
        tok_shard = NamedSharding(
            mesh, logical_spec(("dp", None), mesh, tokens.shape))
        fn = partial(prefill, cfg=cfg)
        return fn, (params_struct, tokens), (p_shard, tok_shard), {}

    # decode: one new token against a seq_len-deep cache
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    cache_struct = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cache_axes = cache_logical_axes(cfg)
    # Perf lever (§Perf iteration 1): when the KV head count divides the
    # model axis, shard KV HEADS over model instead of the sequence —
    # attention stays head-local and no per-layer cache resharding
    # (all-to-all) is needed.  Sequence sharding remains the fallback for
    # archs with few KV heads (and the long_500k batch-1 case).
    mdl = mesh.shape.get("model", 1)
    if (os.environ.get("REPRO_KV_HEAD_SHARD", "1") == "1"
            and cache_axes.k is not None and cfg.n_kv_heads % mdl == 0
            and shape.global_batch > 1):
        cache_axes = cache_axes._replace(
            k=(None, "dp", None, "tp", None),
            v=(None, "dp", None, "tp", None))
    cache_shard = shardings_for(cache_axes, cache_struct, mesh)
    tok_shard = NamedSharding(
        mesh, logical_spec(("dp", None), mesh, tokens.shape))
    fn = partial(decode_step, cfg=cfg)
    return fn, (params_struct, tokens, cache_struct), \
        (p_shard, tok_shard, cache_shard), {"donate_argnums": (2,)}


def loop_trips(cfg) -> list:
    """Top-level layer-scan trip counts in program order (for the
    while-body collective multiplier)."""
    if cfg.family == "moe":
        nd = cfg.moe.first_dense
        return ([nd, cfg.n_layers - nd] if nd else [cfg.n_layers])
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.hybrid_period
        tail = cfg.n_layers - groups * cfg.hybrid_period
        return [groups, tail] if tail else [groups]
    return [cfg.n_layers]


def nested_trip(cfg) -> int:
    return cfg.hybrid_period if cfg.family == "hybrid" else 1


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str, skip_existing: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_arch(arch_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch_name}__{shape_name}__{mesh_name}"
    out_path = os.path.join(out_dir, cell_id + ".json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    ok, why = cell_applicable(cfg, shape)
    record = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        record.update(status="skipped", reason=why)
        _dump(record, out_path)
        return record

    t0 = time.time()
    try:
        from repro.models import model as model_mod
        from repro.sharding import api as shard_api
        # Perf lever (§Perf): Megatron-SP residual stream (AG+RS per
        # block instead of AR) — opt-in for hillclimb variants.
        shard_api.ACT_SEQ[0] = os.environ.get("REPRO_SEQ_ACT", "0") == "1"
        mesh = make_production_mesh(multi_pod=multi_pod)
        with use_mesh(mesh):
            fn, structs, in_shardings, jit_kw = build_cell(cfg, shape,
                                                           mesh)
            jitted = jax.jit(fn, in_shardings=in_shardings, **jit_kw)

            # pass A (scanned): compile → memory analysis + collectives
            model_mod.UNROLL_SCANS[0] = False
            lowered = jitted.lower(*structs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            print(f"[{cell_id}] memory_analysis: {mem}")
            hlo = compiled.as_text()
            coll = rl.collective_bytes(hlo, main_trips=loop_trips(cfg),
                                       nested_trip=nested_trip(cfg))

            # pass B (unrolled lowering): true global FLOPs/bytes — XLA's
            # cost analysis counts while bodies once, so the scanned form
            # under-reports by ~n_layers× (EXPERIMENTS.md §Dry-run).
            # NB: a fresh jax.jit wrapper — the first one caches the
            # scanned trace.
            model_mod.UNROLL_SCANS[0] = True
            try:
                cost = jax.jit(fn, in_shardings=in_shardings, **jit_kw) \
                    .lower(*structs).cost_analysis()
            finally:
                model_mod.UNROLL_SCANS[0] = False
            print(f"[{cell_id}] cost_analysis(global): flops="
                  f"{cost.get('flops', 0):.3e} bytes="
                  f"{cost.get('bytes accessed', 0):.3e}")

        chips = mesh.size
        entry = rl.RooflineEntry(
            arch=arch_name, shape=shape_name, mesh=mesh_name, chips=chips,
            flops_per_chip=float(cost.get("flops", 0.0)) / chips,
            bytes_per_chip=float(cost.get("bytes accessed", 0.0)) / chips,
            coll_bytes_per_chip=float(sum(coll.values())),
            coll_breakdown=coll,
            peak_memory_bytes=getattr(mem, "temp_size_in_bytes", None),
            model_flops_global=rl.model_flops(cfg, shape),
            model_bytes_global=rl.model_bytes(cfg, shape),
        )
        record.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory_analysis=_mem_dict(mem),
            roofline=entry.to_dict(),
        )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    _dump(record, out_path)
    return record


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
    return {k: getattr(mem, k, None) for k in keys}


def _dump(record: dict, path: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    summary = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               skip_existing=not args.force)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    rf = rec["roofline"]
                    extra = (f" bottleneck={rf['bottleneck']}"
                             f" frac={rf['roofline_fraction']:.3f}"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"{rec['arch']:22s} {rec['shape']:12s} "
                      f"{rec['mesh']:8s} {status}{extra}", flush=True)
                summary.append(rec)
    n_ok = sum(r["status"] == "ok" for r in summary)
    n_skip = sum(r["status"] == "skipped" for r in summary)
    n_err = sum(r["status"] == "error" for r in summary)
    print(f"\ncells: {len(summary)}  ok: {n_ok}  skipped(documented): "
          f"{n_skip}  errors: {n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Training driver: synthetic-data LM training with checkpointing,
auto-resume, and straggler watchdog.

Single-host by default (CPU-runnable with reduced configs); on a real
cluster the same driver runs under ``jax.distributed`` with the
production mesh — see launch/dryrun.py for the mesh/sharding wiring.

Example (CPU, ~100M-param model, a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3.2-3b --reduce --d-model 512 --layers 12 \
        --steps 300 --batch 16 --seq 256
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.configs import reduce_for_smoke
from repro.data import SyntheticLM
from repro.models import init_params
from repro.train import AdamWConfig
from repro.train import StepTimer
from repro.train import StepWatchdog
from repro.train import init_train_state
from repro.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduce", action="store_true",
                    help="smoke-reduced config (CPU-sized)")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduce:
        cfg = reduce_for_smoke(cfg)
    changes = {}
    if args.d_model:
        changes.update(d_model=args.d_model,
                       d_ff=4 * args.d_model if cfg.d_ff else 0,
                       head_dim=args.d_model // max(cfg.n_heads, 1)
                       if cfg.n_heads else 0)
    if args.layers:
        changes["n_layers"] = args.layers
    if changes:
        cfg = dataclasses.replace(cfg, **changes)

    params = init_params(cfg, jax.random.key(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    state = init_train_state(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10),
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0,))
    data = SyntheticLM(cfg.vocab, args.seq, args.batch)

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        restored = mgr.restore_latest(state)
        if restored is not None:
            start_step, state = restored
            print(f"resumed from step {start_step}")

    watchdog = StepWatchdog(
        on_straggler=lambda s, d: print(
            f"[watchdog] step {s}: {d:.2f}s — straggler policy engaged "
            f"(log/alert; evict+elastic-restart on real cluster)"))

    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = jnp.asarray(data.batch(step))
        with StepTimer() as t:
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
        watchdog.record(step, t.elapsed)
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / t.elapsed
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"{t.elapsed * 1e3:.0f}ms {tok_s:.0f} tok/s")
        if mgr and step and step % args.ckpt_every == 0:
            mgr.save(step, state)
    if mgr:
        mgr.save(args.steps, state)
    print(f"done in {time.time() - t_start:.1f}s "
          f"(stragglers flagged: {len(watchdog.flagged_steps)})")


if __name__ == "__main__":
    main()

"""Differential event-stream comparison with first-divergence reports.

Three checks per (scenario, policy) cell, strongest first:

1. **engine agreement** — the step engine (reference oracle) and the
   compiled engine must produce byte-identical *canonical* event
   streams (``EventSink.canonical``: total order, engine-independent);
2. **streaming concatenation** — the compiled engine run segment-by-
   segment (``chunk_lines``) must produce a *raw* stream bit-identical
   to the monolithic compiled run (rounds are atomic, the round index
   is global, so not even reordering is tolerated);
3. **golden digest** — the canonical stream's SHA-256 must match the
   digest frozen under ``tests/golden/conformance_digests.json``
   (refreshed via ``scripts/conformance.py --update-golden``).

A failed check yields a :class:`Divergence`: the first differing event
with its round, the expected and actual rows decoded to text, and a
window of surrounding events from both streams — the debugging context
a bare ``assert digest == golden`` throws away.
"""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import field
import json
from pathlib import Path
from typing import Dict
from typing import Iterable
from typing import List
from typing import Optional
from typing import Tuple

import numpy as np

from repro.core import EventSink
from repro.core import Simulator
from repro.core.events import SCHEMA_VERSION
from repro.core.events import decode_event
from repro.core.events import stream_digest
from repro.core.policies import named_policy

#: default segment count the streaming check splits each trace into
_N_SEGMENTS = 7


# ---------------------------------------------------------------------------
# first-divergence reporting
# ---------------------------------------------------------------------------
@dataclass
class Divergence:
    """First point where two event streams disagree, with context."""

    index: int                      # row index in the canonical stream
    round: int                      # simulation round of the divergence
    expected: Optional[List[int]]   # raw row (None: stream ended early)
    actual: Optional[List[int]]
    expected_text: str
    actual_text: str
    context: List[str] = field(default_factory=list)

    def render(self) -> str:
        lines = [
            f"first divergence at event #{self.index} (round {self.round}):",
            f"  expected: {self.expected_text}",
            f"  actual:   {self.actual_text}",
            "  context (expected | actual):",
        ]
        lines.extend(f"    {c}" for c in self.context)
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "index": self.index, "round": self.round,
            "expected": self.expected, "actual": self.actual,
            "expected_text": self.expected_text,
            "actual_text": self.actual_text, "context": self.context,
        }


def first_divergence(expected: np.ndarray, actual: np.ndarray,
                     window: int = 3) -> Optional[Divergence]:
    """Locate the first differing row of two event matrices; ``None``
    when they are identical.  ``window`` rows of context on each side
    are decoded from both streams."""
    n_e, n_a = expected.shape[0], actual.shape[0]
    n = min(n_e, n_a)
    if n:
        neq = (expected[:n] != actual[:n]).any(axis=1)
        idx = int(np.argmax(neq)) if neq.any() else n
    else:
        idx = 0
    if idx == n and n_e == n_a:
        return None

    def row(mat, i):
        if i >= mat.shape[0]:
            return None, "<stream ended>"
        r = [int(x) for x in mat[i]]
        return r, decode_event(r)

    exp_row, exp_text = row(expected, idx)
    act_row, act_text = row(actual, idx)
    rnd = (exp_row or act_row or [-1])[0]
    context = []
    for i in range(max(0, idx - window), min(max(n_e, n_a), idx + window + 1)):
        _, et = row(expected, i)
        _, at = row(actual, i)
        marker = ">>" if i == idx else "  "
        context.append(f"{marker} #{i}: {et}  |  {at}")
    return Divergence(index=idx, round=rnd, expected=exp_row,
                      actual=act_row, expected_text=exp_text,
                      actual_text=act_text, context=context)


# ---------------------------------------------------------------------------
# per-cell comparison
# ---------------------------------------------------------------------------
@dataclass
class CompareResult:
    scenario: str
    policy: str
    n_events: int = 0
    digest: str = ""
    golden: Optional[str] = None
    #: None = cell passed; otherwise the failed check's name
    failure: Optional[str] = None   # engine|streaming|golden|missing-golden
    divergence: Optional[Divergence] = None
    seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return self.failure is None

    def to_dict(self) -> dict:
        d = {
            "scenario": self.scenario, "policy": self.policy,
            "n_events": self.n_events, "digest": self.digest,
            "golden": self.golden, "failure": self.failure,
            "seconds": round(self.seconds, 3),
        }
        if self.divergence is not None:
            d["divergence"] = self.divergence.to_dict()
        return d


def _build_case(key: str):
    from repro.dataflows import lower_to_trace
    from repro.dataflows.suite import suite_case
    case = suite_case(key)
    return case, lower_to_trace(case.spec)


def compare_scenario(key: str, policies: Iterable[str],
                     golden: Optional[Dict[str, str]] = None,
                     window: int = 3) -> List[CompareResult]:
    """Run the three conformance checks for one scenario across
    ``policies`` (the trace is lowered once and shared)."""
    import time
    case, trace = _build_case(key)
    results: List[CompareResult] = []
    for pol in policies:
        t0 = time.perf_counter()
        res = CompareResult(scenario=key, policy=pol)
        sim = Simulator(case.cfg, named_policy(pol, gqa=case.gqa))
        s_step, s_comp, s_seg = EventSink(), EventSink(), EventSink()
        sim.run(trace, record_history=False, engine="steps",
                events=s_step)
        sim.run(trace, record_history=False, engine="compiled",
                events=s_comp)
        ct = trace.compiled(case.cfg.line_bytes)
        chunk = max(1, int(ct.n_acc_round.sum()) // _N_SEGMENTS)
        sim.run(trace, record_history=False, engine="compiled",
                chunk_lines=chunk, events=s_seg)

        res.n_events = len(s_comp)
        canon = s_comp.canonical()
        res.digest = stream_digest(canon)

        div = first_divergence(s_step.canonical(), canon, window)
        if div is not None:
            res.failure = "engine"
            res.divergence = div
        else:
            # streaming must match the monolithic *raw* stream
            div = first_divergence(s_comp.matrix(), s_seg.matrix(), window)
            if div is not None:
                res.failure = "streaming"
                res.divergence = div
            elif golden is not None:
                cell = f"{key}/{pol}"
                want = golden.get(cell)
                if want is None:
                    res.failure = "missing-golden"
                elif want != res.digest:
                    res.failure = "golden"
                res.golden = want
        res.seconds = time.perf_counter() - t0
        results.append(res)
    return results


def run_matrix(entries: Iterable[Tuple[str, str]],
               golden: Optional[Dict[str, str]] = None,
               window: int = 3,
               progress=None) -> List[CompareResult]:
    """Run the conformance checks over matrix ``entries``, grouping by
    scenario so each trace is lowered and compiled once."""
    by_scenario: Dict[str, List[str]] = {}
    for key, pol in entries:
        by_scenario.setdefault(key, []).append(pol)
    results: List[CompareResult] = []
    for key, pols in by_scenario.items():
        cells = compare_scenario(key, pols, golden, window)
        results.extend(cells)
        if progress is not None:
            for c in cells:
                progress(c)
    return results


# ---------------------------------------------------------------------------
# golden digests
# ---------------------------------------------------------------------------
def golden_path() -> Path:
    return (Path(__file__).resolve().parents[3] / "tests" / "golden"
            / "conformance_digests.json")


def load_golden(path: Optional[Path] = None) -> Optional[Dict[str, str]]:
    """The frozen ``cell → digest`` map, or ``None`` when absent or
    written under a different event schema (a schema bump obsoletes
    every digest at once)."""
    path = path or golden_path()
    try:
        blob = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    if blob.get("schema_version") != SCHEMA_VERSION:
        return None
    return dict(blob.get("digests", {}))


def save_golden(digests: Dict[str, str],
                path: Optional[Path] = None) -> Path:
    path = path or golden_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    blob = {
        "schema_version": SCHEMA_VERSION,
        "digests": {k: digests[k] for k in sorted(digests)},
    }
    path.write_text(json.dumps(blob, indent=2) + "\n")
    return path

"""The conformance scenario×policy matrix.

The scenario axis is *derived from the suite registry*
(``repro.dataflows.suite``): registering a new scenario automatically
enrolls it in the conformance matrix — no second list to keep in sync.
The policy axis covers the three mechanism classes whose event streams
exercise distinct engine code paths:

* ``lru``     baseline replacement (fills/evictions/write-backs only)
* ``dbp``     dead-block prediction (TMU retirements drive victims)
* ``at+dbp``  anti-thrashing tiers composed with DBP
* ``all``     adds the dynamic bypass gear (gear-transition events);
              kept out of the default matrix axis only where noted

CI runs the smoke subset (one small, one paged, one multi-tenant
scenario — the three trace shapes with structurally different event
mixes); the full matrix backs the frozen goldens.
"""

from __future__ import annotations

from typing import Iterable
from typing import Iterator
from typing import Optional
from typing import Tuple

#: policy axis of the frozen golden matrix (ISSUE acceptance floor:
#: lru, dbp, at+dbp) plus the gear-exercising composite
CONFORMANCE_POLICIES: Tuple[str, ...] = ("lru", "dbp", "at+dbp", "all")

#: CI smoke subset: small dense, paged-decode, multi-tenant composed,
#: and generator-driven replay traces — the structurally distinct event
#: mixes (serve-replay adds mid-run tensor churn from the batching loop;
#: serve-replay-pooled additionally recycles addresses, so dense-id and
#: owner attribution must survive cross-generation address reuse)
SMOKE_SCENARIOS: Tuple[str, ...] = ("matmul", "decode-paged", "mt-spec-ssd",
                                    "serve-replay", "serve-replay-pooled")


def matrix_entries(smoke: bool = False,
                   scenarios: Optional[Iterable[str]] = None,
                   policies: Optional[Iterable[str]] = None,
                   ) -> Iterator[Tuple[str, str]]:
    """Yield ``(scenario_key, policy_name)`` pairs of the conformance
    matrix.  Default: every registered suite scenario × every
    conformance policy; ``smoke=True`` restricts scenarios to the CI
    subset; explicit ``scenarios``/``policies`` override either axis."""
    if scenarios is None:
        if smoke:
            scenarios = SMOKE_SCENARIOS
        else:
            from repro.dataflows.suite import registry_keys
            scenarios = registry_keys()
    if policies is None:
        policies = CONFORMANCE_POLICIES
    policies = tuple(policies)
    for key in scenarios:
        for pol in policies:
            yield key, pol

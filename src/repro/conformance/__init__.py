"""Differential conformance harness (DESIGN.md §10).

Pins the repo's execution engines against each other at the *event*
level: the step engine is the oracle, the compiled engine (monolithic
and streaming/chunked) must reproduce its canonical event stream byte
for byte on every registered suite scenario, and the resulting digests
are frozen as goldens under ``tests/golden/``.  On mismatch the harness
reports the first-divergence event with full context (round, expected
vs actual, surrounding window) rather than a bare assert — the RTL-
verification ``compare_traces`` idiom applied to the simulator stack.

Entry points: ``scripts/conformance.py`` (CI gate + ``--update-golden``
refresh) and ``scripts/trace_dump.py`` (render/export one run's
events); the scenario×policy matrix lives in :mod:`.matrix` and grows
automatically with ``repro.dataflows.suite``'s registry.
"""

from .compare import CompareResult
from .compare import Divergence
from .compare import compare_scenario
from .compare import first_divergence
from .compare import golden_path
from .compare import load_golden
from .compare import run_matrix
from .compare import save_golden
from .matrix import CONFORMANCE_POLICIES
from .matrix import SMOKE_SCENARIOS
from .matrix import matrix_entries

__all__ = [
    "CompareResult", "Divergence", "compare_scenario", "first_divergence",
    "golden_path", "load_golden", "run_matrix", "save_golden",
    "CONFORMANCE_POLICIES", "SMOKE_SCENARIOS", "matrix_entries",
]

from __future__ import annotations

from contextlib import contextmanager
import threading
from typing import Optional
from typing import Sequence
from typing import Tuple
from typing import Union

import jax
from jax.sharding import Mesh
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis → tuple of mesh axes (filtered by what the mesh provides)
LOGICAL_RULES = {
    "dp": ("pod", "data"),        # batch / data parallel
    "sp": ("data",),              # sequence parallel (long-context)
    "tp": ("model",),             # tensor parallel (heads / ffn / vocab)
    "tp_act": ("model",),         # activation d_model sharding (Megatron SP)
    "ep": ("model",),             # expert parallel
    "zero": ("data",),            # optimizer-state sharding (ZeRO-1)
    # KV-cache sequence axis: takes whatever of (data, model) the batch
    # axis left unused — decode_32k shards seq over model; long_500k
    # (batch 1) shards seq over data AND model.
    "kvseq": ("data", "model"),
    # Megatron-SP residual stream: sequence sharded over model between
    # blocks (enabled by ACT_SEQ) — per-layer comm becomes
    # all-gather(seq) + reduce-scatter(seq) instead of all-reduce.
    "act_seq": ("model",),
    None: (),
}

# Runtime switch (launch/dryrun §Perf): residual-stream layout.
ACT_SEQ = [False]


def act_axes():
    """Logical axes for the residual stream between blocks."""
    if ACT_SEQ[0]:
        return ("dp", "act_seq", None)
    return ("dp", None, "tp_act")

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Bind a mesh for logical-axis constraint resolution (and enter the
    jax mesh context so collectives/shard_map resolve axis names)."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def _resolve(axis: Union[str, None], mesh: Mesh) -> Optional[Tuple[str, ...]]:
    mesh_axes = set(mesh.axis_names)
    phys = tuple(a for a in LOGICAL_RULES.get(axis, ()) if a in mesh_axes)
    if not phys:
        return None
    return phys


def logical_spec(axes: Sequence[Union[str, None]],
                 mesh: Optional[Mesh] = None,
                 shape: Optional[Sequence[int]] = None) -> P:
    """Translate logical axes to a PartitionSpec for ``mesh``.

    With ``shape`` given, axes whose mesh extent does not divide the dim
    size are dropped (replicated) — e.g. batch=1 decode cells drop "dp".
    """
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    parts = []
    used: set = set()
    for i, ax in enumerate(axes):
        phys = _resolve(ax, mesh)
        if phys is None:
            parts.append(None)
            continue
        phys = tuple(p for p in phys if p not in used)
        if shape is not None and phys:
            # keep the largest prefix of mesh axes that divides the dim
            keep = []
            extent = 1
            for p in phys:
                if shape[i] % (extent * mesh.shape[p]) == 0:
                    keep.append(p)
                    extent *= mesh.shape[p]
                else:
                    break
            phys = tuple(keep)
        used.update(phys)
        if not phys:
            parts.append(None)
        else:
            parts.append(phys if len(phys) != 1 else phys[0])
    return P(*parts)


def named_sharding(axes: Sequence[Union[str, None]],
                   mesh: Optional[Mesh] = None,
                   shape: Optional[Sequence[int]] = None
                   ) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(axes, mesh, shape))


def constrain(x: jax.Array, axes: Sequence[Union[str, None]]) -> jax.Array:
    """Apply a logical sharding constraint if a mesh is bound (no-op
    otherwise, so single-device tests run unannotated)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_spec(axes, mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))

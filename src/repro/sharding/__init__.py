"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code annotates tensors with *logical* axes ("dp", "tp", …);
:func:`use_mesh` binds a physical mesh and the rules below translate the
hints into ``with_sharding_constraint`` calls.  Without a bound mesh every
hint is a no-op, so smoke tests run unchanged on one CPU device.
"""

from .api import ACT_SEQ
from .api import LOGICAL_RULES
from .api import act_axes
from .api import constrain
from .api import current_mesh
from .api import logical_spec
from .api import named_sharding
from .api import use_mesh

__all__ = ["ACT_SEQ", "LOGICAL_RULES", "act_axes", "constrain",
           "current_mesh", "logical_spec", "named_sharding", "use_mesh"]

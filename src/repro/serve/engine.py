"""Batched serving engine: continuous batching over a slotted KV pool.

The DCO mapping (DESIGN.md §3): each slot's KV region is a *tensor* with
dataflow-known lifetime.  When a sequence finishes, its slot is retired
immediately and reused by the next queued request — the serving-level
dead-block prediction (paper §VI-F: "data from completed batches becomes
dead and pollutes the cache"; here the pollution is reclaimed the moment
``accCnt == nAcc``, i.e. at EOS/max-tokens).  A TMU instance tracks the
slot lifetimes so the analogy is executable, not rhetorical.

The engine is deliberately synchronous and functional: ``step()`` runs one
batched decode for every active slot (padding inactive slots), so the
whole loop jit-compiles to a single ``decode_step`` of static shape.
"""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import field
from typing import Dict
from typing import List
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.core.tmu import TMU
from repro.core.tmu import TensorMeta
from repro.models import Cache
from repro.models import decode_step
from repro.models import init_cache
from repro.models import prefill

from .scheduler import ServeTruncation
from .scheduler import SlotScheduler


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    tokens_out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 4,
                 max_seq: int = 256, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache = init_cache(cfg, max_batch, max_seq)
        self.sched: SlotScheduler[Request] = SlotScheduler(max_batch)
        self.slot_pos = np.zeros(max_batch, dtype=np.int32)
        self.greedy = greedy
        # TMU tracking slot lifetimes (dead-block analogue)
        self._tmu = TMU(tensor_entries=max_batch * 2)
        self._slot_bytes = 1 << 20

        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, t, c, cfg))
        self._prefill = jax.jit(
            lambda p, t: prefill(p, t, cfg))

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        self.sched.add(req)

    def _admit(self) -> None:
        for slot, req in self.sched.admit():
            self._start(slot, req)

    def _start(self, slot: int, req: Request) -> None:
        prompt = jnp.asarray(req.prompt[None, :])
        logits, pcache = self._prefill(self.params, prompt)
        plen = req.prompt.shape[0]
        # splice this request's prefilled KV/state into the pooled cache
        self.cache = _splice(self.cache, pcache, slot, plen, self.max_seq)
        self.slot_pos[slot] = plen
        first = int(jnp.argmax(logits[0])) if self.greedy else int(
            jax.random.categorical(jax.random.key(req.uid), logits[0]))
        req.tokens_out.append(first)
        self._tmu.register(TensorMeta(
            tensor_id=req.uid, base_addr=slot * self._slot_bytes,
            size_bytes=self._slot_bytes, tile_bytes=self._slot_bytes,
            n_acc=req.max_new_tokens))

    def _retire(self, slot: int) -> None:
        req = self.sched.release(slot)
        req.done = True
        self._tmu.clear(req.uid)          # slot retires → space reusable
        self.slot_pos[slot] = 0

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One batched decode step; returns #active slots."""
        self._admit()
        active = self.sched.active_slots()
        if not active:
            return 0
        toks = np.zeros((self.max_batch, 1), dtype=np.int32)
        for i in active:
            toks[i, 0] = self.sched.slots[i].tokens_out[-1]
        # batched decode at the max position (positions are per-slot via
        # cache.pos; we use per-slot positions by patching pos before the
        # call — a single scalar pos requires aligned decoding, so the
        # engine decodes each distinct position group separately)
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(int(self.slot_pos[i]), []).append(i)
        for pos, slots in groups.items():
            cache = self.cache._replace(pos=jnp.asarray(pos, jnp.int32))
            logits, new_cache = self._decode(
                self.params, jnp.asarray(toks), cache)
            self.cache = _merge_slots(self.cache, new_cache, slots)
            for i in slots:
                req = self.sched.slots[i]
                nxt = int(jnp.argmax(logits[i, 0]))
                req.tokens_out.append(nxt)
                self.slot_pos[i] += 1
                self._tmu.on_access(
                    i * self._slot_bytes + self._slot_bytes - 128, 0)
                exhausted = len(req.tokens_out) >= req.max_new_tokens
                if exhausted or (req.eos_id is not None
                                 and nxt == req.eos_id):
                    self._retire(i)
        return len(active)

    def run_to_completion(self, max_steps: int = 1000) -> int:
        """Drive :meth:`step` until every request finishes; returns the
        number of steps taken.  Raises :class:`ServeTruncation` if the
        budget runs out with requests still active or queued (previously
        this exited silently, making truncated generations look
        finished)."""
        for n in range(max_steps):
            if self.step() == 0 and self.sched.drained:
                return n + 1
        if not self.sched.drained:
            raise ServeTruncation(max_steps, self.sched.n_active,
                                  self.sched.n_queued)
        return max_steps


# ---------------------------------------------------------------------------
def _splice(pool: Cache, one: Cache, slot: int, plen: int,
            max_seq: int) -> Cache:
    """Copy a single-sequence prefill cache into pool slot ``slot``."""
    def put_kv(pool_a, one_a):
        if pool_a is None:
            return None
        pad = max_seq - one_a.shape[2]
        padded = jnp.pad(one_a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return jax.lax.dynamic_update_slice_in_dim(pool_a, padded, slot,
                                                   axis=1)

    def put_state(pool_a, one_a):
        if pool_a is None:
            return None
        return jax.lax.dynamic_update_slice_in_dim(pool_a, one_a, slot,
                                                   axis=1)

    return Cache(
        k=put_kv(pool.k, one.k), v=put_kv(pool.v, one.v),
        conv_x=put_state(pool.conv_x, one.conv_x),
        conv_bc=put_state(pool.conv_bc, one.conv_bc),
        ssm=put_state(pool.ssm, one.ssm),
        pos=pool.pos)


def _merge_slots(old: Cache, new: Cache, slots: List[int]) -> Cache:
    """Keep updated cache rows only for ``slots`` (batch axis 1)."""
    sel = np.zeros(old.k.shape[1] if old.k is not None
                   else old.ssm.shape[1], dtype=bool)
    sel[slots] = True
    mask = jnp.asarray(sel)

    def pick(o, n, bdim=1):
        if o is None:
            return None
        shape = [1] * o.ndim
        shape[bdim] = o.shape[bdim]
        m = mask.reshape(shape)
        return jnp.where(m, n, o)

    return Cache(k=pick(old.k, new.k), v=pick(old.v, new.v),
                 conv_x=pick(old.conv_x, new.conv_x),
                 conv_bc=pick(old.conv_bc, new.conv_bc),
                 ssm=pick(old.ssm, new.ssm), pos=old.pos)

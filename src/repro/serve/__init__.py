"""Serving layer: the JAX engine plus the jax-free replay stack.

``Request``/``ServeEngine`` pull in the JAX model stack, so they are
resolved lazily (PEP 562): the traffic-scale replay modules
(:mod:`repro.serve.traffic`, :mod:`repro.serve.replay`,
:mod:`repro.serve.scheduler`) share this package but must stay
importable from suite/conformance worker processes that never touch JAX.
"""

from .scheduler import ServeTruncation
from .scheduler import SlotScheduler

__all__ = ["Request", "ServeEngine", "ServeTruncation", "SlotScheduler"]


def __getattr__(name):
    if name in ("Request", "ServeEngine"):
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

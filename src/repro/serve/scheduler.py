"""Continuous-batching slot scheduler (jax-free).

The admit/retire bookkeeping of :class:`~repro.serve.engine.ServeEngine`
— a fixed pool of KV slots, a FIFO queue, first-free-slot admission,
immediate slot reuse on retirement — extracted so the traffic-scale
replay driver (:mod:`repro.serve.replay`) shares the exact batching
decisions of the real serving loop without importing the JAX model
stack.  Slots hold arbitrary payloads; the scheduler knows nothing about
caches or tokens.
"""

from __future__ import annotations

from typing import Generic
from typing import List
from typing import Optional
from typing import Tuple
from typing import TypeVar

T = TypeVar("T")


class ServeTruncation(RuntimeError):
    """``run_to_completion`` exhausted its step budget with work left.

    Carries how much was still pending so callers can size budgets; the
    silent-return behaviour this replaces made truncated generations
    indistinguishable from finished ones.
    """

    def __init__(self, steps: int, active: int, queued: int):
        self.steps = steps
        self.active = active
        self.queued = queued
        super().__init__(
            f"serve loop truncated after {steps} steps with {active} "
            f"active slot(s) and {queued} queued request(s) remaining")


class SlotScheduler(Generic[T]):
    """First-free-slot continuous batching over ``max_batch`` slots."""

    def __init__(self, max_batch: int):
        if max_batch <= 0:
            raise ValueError("max_batch must be positive")
        self.max_batch = max_batch
        self.slots: List[Optional[T]] = [None] * max_batch
        self.queue: List[T] = []

    # -- queue ----------------------------------------------------------
    def add(self, item: T) -> None:
        self.queue.append(item)

    def admit(self) -> List[Tuple[int, T]]:
        """Fill free slots from the queue head; returns the new
        ``(slot, item)`` placements in admission order."""
        placed: List[Tuple[int, T]] = []
        for slot, occupant in enumerate(self.slots):
            if occupant is not None:
                continue
            if not self.queue:
                break
            item = self.queue.pop(0)
            self.slots[slot] = item
            placed.append((slot, item))
        return placed

    def release(self, slot: int) -> T:
        item = self.slots[slot]
        if item is None:
            raise ValueError(f"slot {slot} is not occupied")
        self.slots[slot] = None
        return item

    # -- views ----------------------------------------------------------
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    @property
    def n_active(self) -> int:
        return sum(1 for r in self.slots if r is not None)

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    @property
    def drained(self) -> bool:
        return self.n_active == 0 and not self.queue

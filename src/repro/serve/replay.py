"""Traffic-scale serving replay: arrivals → batching → cache simulation.

The end-to-end pipeline of DESIGN.md §11: a seeded
:class:`~repro.serve.traffic.RequestStream` feeds the continuous-
batching :class:`~repro.serve.scheduler.SlotScheduler` (the same
admit/retire discipline as the JAX ``ServeEngine``), and every slot
decision is *emitted* as one lockstep dataflow round — KV pages stored
during prefill, re-read every decode step, shared prompt prefixes
co-read by their group, Q/X/O traffic bypassed — through the emitter
protocol of :mod:`repro.dataflows.stream`.

With a :class:`~repro.dataflows.stream.StreamEmitter` the replay runs in
bounded memory end to end (``Simulator.run_stream`` consumes windows as
they flush); with a :class:`~repro.dataflows.stream.SpecEmitter` the
same driver produces one monolithic ``DataflowSpec`` for the suite /
model-validation / conformance paths and for the bit-identity property
(streamed counters and event stream == monolithic, small seeds).

On top of the cache counters, :func:`slo_metrics` derives serving SLOs
from the simulated clock: TTFT (arrival → first generated token,
queueing + prefill included) and TPOT (mean inter-token gap) as
p50/p95/p99 milliseconds.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict
from typing import Iterator
from typing import List
from typing import Optional

import numpy as np

from repro.core.policies import named_policy
from repro.core.simulator import SimConfig
from repro.core.simulator import SimResult
from repro.core.simulator import Simulator
from repro.dataflows.addr import BUMP
from repro.dataflows.addr import make_allocator
from repro.dataflows.stream import DEFAULT_CHUNK_LINES
from repro.dataflows.stream import ReplaySegment
from repro.dataflows.stream import SpecEmitter
from repro.dataflows.stream import StreamEmitter

from .scheduler import ServeTruncation
from .scheduler import SlotScheduler
from .traffic import ReplayRequest
from .traffic import RequestStream
from .traffic import TrafficConfig


@dataclass(frozen=True)
class ReplayConfig:
    """Shape of the emitted dataflow (pages are the KV paging unit)."""

    max_batch: int = 16
    n_cores: int = 16
    page_bytes: int = 2048
    prefill_pages_per_round: int = 4
    line_bytes: int = 128
    flops_per_byte: float = 2.0
    #: hard safety ceiling on replay rounds (None: unbounded)
    max_rounds: Optional[int] = None
    #: address-space strategy (repro.dataflows.addr): "bump" mints the
    #: historical monotone layout; "pooled" recycles retired KV regions
    #: from a fixed page pool so tag-derived TMU state (anti-thrashing
    #: tiers, dead ids) keeps covering the live working set at scale
    allocator: str = "bump"
    #: pooled-allocator pool size, in pages of ``page_bytes``.  A fixed
    #: config knob (not derived from the realized stream) so streamed
    #: and monolithic runs of one traffic seed share layouts exactly.
    pool_pages: int = 2048


@dataclass
class ReplayLog:
    """Per-request round indices for SLO derivation (indexed by uid)."""

    arrival: np.ndarray
    first_token: np.ndarray
    last_token: np.ndarray
    n_decode: np.ndarray

    @classmethod
    def empty(cls, n: int) -> "ReplayLog":
        return cls(arrival=np.zeros(n, dtype=np.int64),
                   first_token=np.full(n, -1, dtype=np.int64),
                   last_token=np.full(n, -1, dtype=np.int64),
                   n_decode=np.zeros(n, dtype=np.int64))


@dataclass
class _Active:
    """Per-slot replay state."""

    req: ReplayRequest
    kv: str
    io: str
    pfx: Optional[str]
    prefill_rounds: int
    pages_filled: int = 0
    decoded: int = 0
    io_tile: int = 0


class ReplayEngine:
    """Drives an emitter from the arrival stream; yields flushed
    segments (none for a :class:`SpecEmitter`)."""

    def __init__(self, stream: RequestStream, rcfg: ReplayConfig):
        self.stream = stream
        self.rcfg = rcfg
        self.log = ReplayLog.empty(stream.cfg.n_requests)
        self.rounds = 0

    # ------------------------------------------------------------------
    def _declare(self, emitter, req: ReplayRequest,
                 pfx_declared: set, pfx_refs: Dict[int, int]) -> _Active:
        rc = self.rcfg
        wave = req.uid // rc.max_batch
        pfx_name = None
        if req.prefix_id >= 0:
            pfx_name = f"pfx{req.prefix_id}"
            if req.prefix_id not in pfx_declared:
                info = self.stream.prefix_info(req.prefix_id)
                emitter.declare(
                    pfx_name,
                    size_bytes=self.stream.cfg.prefix_pages * rc.page_bytes,
                    tile_bytes=rc.page_bytes,
                    n_acc=info.total_decode_steps,
                    sharers=1,
                    epoch=(info.uid_min // rc.max_batch,
                           info.uid_max // rc.max_batch))
                pfx_declared.add(req.prefix_id)
                pfx_refs[req.prefix_id] = info.members
        kv = f"kv{req.uid}"
        emitter.declare(kv,
                        size_bytes=req.prefill_pages * rc.page_bytes,
                        tile_bytes=rc.page_bytes,
                        n_acc=req.decode_steps,
                        epoch=(wave, wave))
        prefill_rounds = -(-req.prefill_pages // rc.prefill_pages_per_round)
        io = f"io{req.uid}"
        emitter.declare(io,
                        size_bytes=(prefill_rounds + 2 * req.decode_steps)
                        * rc.line_bytes,
                        tile_bytes=rc.line_bytes,
                        n_acc=1, bypass=True, epoch=(wave, wave))
        return _Active(req=req, kv=kv, io=io, pfx=pfx_name,
                       prefill_rounds=prefill_rounds)

    # ------------------------------------------------------------------
    def drive(self, emitter) -> Iterator[ReplaySegment]:
        rc = self.rcfg
        n_prefix_pages = self.stream.cfg.prefix_pages
        sched: SlotScheduler[ReplayRequest] = SlotScheduler(rc.max_batch)
        state: List[Optional[_Active]] = [None] * rc.max_batch
        arrivals = iter(self.stream)
        pending = next(arrivals, None)
        pfx_declared: set = set()
        pfx_refs: Dict[int, int] = {}
        r = 0
        while pending is not None or not sched.drained:
            if rc.max_rounds is not None and r >= rc.max_rounds:
                raise ServeTruncation(
                    r, sched.n_active,
                    sched.n_queued + (1 if pending is not None else 0))
            while pending is not None and pending.arrival_round <= r:
                sched.add(pending)
                pending = next(arrivals, None)
            for slot, req in sched.admit():
                state[slot] = self._declare(emitter, req, pfx_declared,
                                            pfx_refs)
                self.log.arrival[req.uid] = req.arrival_round
                self.log.n_decode[req.uid] = req.decode_steps

            # one lockstep round: merge slots that map onto one core
            per_core: Dict[int, list] = {}
            for slot in sched.active_slots():
                st = state[slot]
                row = per_core.setdefault(slot % rc.n_cores,
                                          [[], [], 0.0])
                loads, stores = row[0], row[1]
                if st.pages_filled < st.req.prefill_pages:
                    k = min(rc.prefill_pages_per_round,
                            st.req.prefill_pages - st.pages_filled)
                    stores.extend((st.kv, st.pages_filled + j)
                                  for j in range(k))
                    loads.append((st.io, st.io_tile))
                    st.io_tile += 1
                    st.pages_filled += k
                    row[2] += k * rc.page_bytes * rc.flops_per_byte
                else:
                    loads.extend((st.kv, p)
                                 for p in range(st.req.prefill_pages))
                    pages = st.req.prefill_pages
                    if st.pfx is not None:
                        loads.extend((st.pfx, p)
                                     for p in range(n_prefix_pages))
                        pages += n_prefix_pages
                    loads.append((st.io, st.io_tile))
                    stores.append((st.io, st.io_tile + 1))
                    st.io_tile += 2
                    st.decoded += 1
                    if self.log.first_token[st.req.uid] < 0:
                        self.log.first_token[st.req.uid] = r
                    row[2] += pages * rc.page_bytes * rc.flops_per_byte

            seg = emitter.emit_round(
                [(core, loads, stores, flops)
                 for core, (loads, stores, flops)
                 in sorted(per_core.items())])
            if seg is not None:
                yield seg

            for slot in sched.active_slots():
                st = state[slot]
                if st.decoded >= st.req.decode_steps:
                    self.log.last_token[st.req.uid] = r
                    emitter.retire(st.kv)
                    emitter.retire(st.io)
                    if st.pfx is not None:
                        pid = st.req.prefix_id
                        pfx_refs[pid] -= 1
                        if pfx_refs[pid] == 0:
                            emitter.retire(st.pfx)
                    sched.release(slot)
                    state[slot] = None
            r += 1
        self.rounds = r
        final = emitter.finish()
        if final is not None:
            yield final


# ---------------------------------------------------------------------------
@dataclass
class ReplayResult:
    sim: SimResult
    log: ReplayLog
    slo: Dict[str, Dict[str, float]]
    rounds: int
    segments: int = 0
    peak_seen_lines: int = 0
    total_lines_declared: int = 0
    #: online verifier verdict (``run_replay(verify=True)``), else None
    diagnostics: Optional[object] = None


def slo_metrics(log: ReplayLog,
                res: SimResult) -> Dict[str, Dict[str, float]]:
    """TTFT/TPOT percentile milliseconds from the simulated clock.

    The per-round clock comes from ``history["cycles"]`` (recorded at
    non-empty rounds only; a request arriving inside an idle gap is
    anchored to the last non-empty round before it, an error of at most
    the idle rounds' fixed overhead).
    """
    tl = res.timeline.get("round")
    cyc = res.history.get("cycles")
    if tl is None or cyc is None or tl.size == 0:
        return {}
    done = log.last_token >= 0

    def clock_end(rounds: np.ndarray) -> np.ndarray:
        idx = np.searchsorted(tl, rounds, side="right") - 1
        return np.where(idx >= 0, cyc[np.maximum(idx, 0)], 0.0)

    scale = 1.0 / (res.freq_ghz * 1e6)
    ttft = (clock_end(log.first_token[done])
            - clock_end(log.arrival[done] - 1)) * scale
    gaps = np.maximum(log.n_decode[done] - 1, 1)
    tpot = (clock_end(log.last_token[done])
            - clock_end(log.first_token[done])) / gaps * scale

    def pct(a: np.ndarray) -> Dict[str, float]:
        return {"p50": float(np.percentile(a, 50)),
                "p95": float(np.percentile(a, 95)),
                "p99": float(np.percentile(a, 99)),
                "mean": float(a.mean())}

    return {"ttft_ms": pct(ttft), "tpot_ms": pct(tpot),
            "completed": {"n": float(done.sum())}}


# ---------------------------------------------------------------------------
def replay_spec(traffic: TrafficConfig,
                rcfg: Optional[ReplayConfig] = None):
    """Monolithic lowering: the whole replay as one ``DataflowSpec``
    (suite/conformance registration path).  Returns ``(spec, log)``."""
    rcfg = rcfg or ReplayConfig()
    eng = ReplayEngine(RequestStream(traffic), rcfg)
    emitter = SpecEmitter(_replay_name(traffic, rcfg), rcfg.n_cores,
                          line_bytes=rcfg.line_bytes,
                          allocator=_replay_allocator(rcfg))
    for _ in eng.drive(emitter):
        pass
    return emitter.build(), eng.log


def _replay_allocator(rcfg: ReplayConfig):
    """Fresh allocator for one replay run; ``None`` for bump, which
    keeps the emitters on their historical implicit-base path (layouts
    byte-identical to the pre-allocator pipeline)."""
    if rcfg.allocator == BUMP:
        return None
    return make_allocator(rcfg.allocator, page_bytes=rcfg.page_bytes,
                          pool_pages=rcfg.pool_pages)


def _replay_name(traffic: TrafficConfig, rcfg: ReplayConfig) -> str:
    name = (f"serve-replay-{traffic.process}"
            f"-n{traffic.n_requests}-s{traffic.seed}")
    if rcfg.allocator != BUMP:
        name += f"-{rcfg.allocator}"
    return name


def run_replay(traffic: TrafficConfig, policy,
               sim_cfg: Optional[SimConfig] = None,
               rcfg: Optional[ReplayConfig] = None, *,
               mode: str = "stream",
               chunk_lines: int = DEFAULT_CHUNK_LINES,
               record_history: bool = True,
               events=None, verify: bool = False) -> ReplayResult:
    """Run one replay under one policy.

    ``mode="stream"`` (default) is the bounded-memory path: generator →
    StreamEmitter windows → ``Simulator.run_stream``.  ``mode=
    "monolithic"`` materializes the whole spec/trace first (reference
    path; small seeds only — every tensor is TMU-registered up front).

    ``verify=True`` turns on the online verifier (DESIGN.md §12): in
    stream mode a :class:`~repro.dataflows.verify.StreamVerifier` audits
    every flushed segment in-line (bounded memory, same pass as the
    simulator); in monolithic mode the built spec goes through
    :func:`~repro.dataflows.verify.verify_spec`.  The resulting
    :class:`~repro.dataflows.verify.VerifyResult` lands on
    ``ReplayResult.diagnostics``; error-tier findings raise
    :class:`~repro.dataflows.verify.SpecVerifyError` before results are
    returned (a corrupt emission must not masquerade as a measurement).
    """
    cfg = sim_cfg or SimConfig()
    rcfg = rcfg or ReplayConfig(n_cores=cfg.n_cores,
                                line_bytes=cfg.line_bytes)
    if rcfg.n_cores != cfg.n_cores:
        raise ValueError("ReplayConfig.n_cores must match SimConfig")
    pol = named_policy(policy) if isinstance(policy, str) else policy
    eng = ReplayEngine(RequestStream(traffic), rcfg)
    name = _replay_name(traffic, rcfg)
    sim = Simulator(cfg, pol)
    diags = None
    if mode == "stream":
        emitter = StreamEmitter(name, rcfg.n_cores,
                                chunk_lines=chunk_lines,
                                line_bytes=rcfg.line_bytes,
                                allocator=_replay_allocator(rcfg))
        segs = eng.drive(emitter)
        verifier = None
        if verify:
            from repro.dataflows.verify import StreamVerifier
            verifier = StreamVerifier(name, line_bytes=rcfg.line_bytes,
                                      sim_cfg=cfg,
                                      allocator=rcfg.allocator)

            def audited(source=segs, v=verifier):
                for seg in source:
                    v.on_segment(seg)
                    yield seg

            segs = audited()
        res = sim.run_stream(segs, name=name,
                             record_history=record_history, events=events)
        if verifier is not None:
            diags = verifier.finish()
        segments = emitter.segments
        peak = emitter.peak_seen_lines
        total = emitter.total_lines_declared
    elif mode == "monolithic":
        from repro.dataflows import lower_to_trace
        emitter = SpecEmitter(name, rcfg.n_cores,
                              line_bytes=rcfg.line_bytes,
                              allocator=_replay_allocator(rcfg))
        for _ in eng.drive(emitter):
            pass
        spec = emitter.build()
        if verify:
            from repro.dataflows.verify import verify_spec
            diags = verify_spec(spec, sim_cfg=cfg)
        trace = lower_to_trace(spec)
        res = sim.run(trace, record_history=record_history, events=events)
        segments = 1
        peak = total = sum(m.size_bytes // rcfg.line_bytes
                           for m in trace.tensors.values())
    else:
        raise ValueError(f"unknown mode {mode!r}")
    if diags is not None and diags.has_errors:
        from repro.dataflows.verify import SpecVerifyError
        raise SpecVerifyError(diags)
    return ReplayResult(sim=res, log=eng.log,
                        slo=slo_metrics(eng.log, res),
                        rounds=eng.rounds, segments=segments,
                        peak_seen_lines=peak, total_lines_declared=total,
                        diagnostics=diags)

"""Seeded synthetic request-arrival generator for serving replay.

Produces the request population of DESIGN.md §11: Poisson or bursty
arrivals (rounds are the time unit — one lockstep simulator round per
serve-engine step), mixed prefill/decode lengths drawn from small
categorical mixes, and a prefix-sharing subpopulation (groups of
requests that read one shared prompt-prefix KV region, the paper's
inter-request reuse carrier).

Generation is cohort-buffered: requests are drawn ``cohort`` at a time
with vectorized numpy calls from one ``default_rng(seed)``, so a
million-request stream costs a few thousand RNG calls and O(cohort)
memory.  Prefix groups never span a cohort, so by the time a request is
yielded its whole group is known — the replay driver can declare the
shared-prefix tensor with its *exact* total read count
(:meth:`RequestStream.prefix_info`), which is what lets every tile
self-retire in the TMU (see ``repro.dataflows.stream``).

Re-iterating a :class:`RequestStream` re-seeds the generator, so two
passes over the same stream (e.g. the monolithic and streamed halves of
the bit-identity property) see identical requests.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict
from typing import Iterator
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class TrafficConfig:
    """Arrival process + request-shape mix (all rounds/pages units)."""

    n_requests: int
    seed: int = 0
    process: str = "poisson"               # "poisson" | "bursty"
    #: mean rounds between arrivals (poisson); ~0.7 keeps a 16-slot
    #: engine around 80% utilized with the default length mix
    mean_interarrival_rounds: float = 0.7
    #: bursty process: geometric burst sizes with this mean, separated
    #: by exponential gaps of this mean
    burst_mean_size: float = 8.0
    burst_gap_rounds: float = 12.0
    prefill_pages_choices: Tuple[int, ...] = (2, 4, 8)
    prefill_pages_weights: Tuple[float, ...] = (0.5, 0.3, 0.2)
    decode_steps_choices: Tuple[int, ...] = (4, 8, 16)
    decode_steps_weights: Tuple[float, ...] = (0.5, 0.3, 0.2)
    #: fraction of requests that share a prompt prefix with neighbours
    share_fraction: float = 0.3
    prefix_pages: int = 4
    prefix_group_size: int = 4
    #: vectorized generation window (groups never span a cohort)
    cohort: int = 1024

    def __post_init__(self) -> None:
        if self.n_requests <= 0:
            raise ValueError("n_requests must be positive")
        if self.process not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival process {self.process!r}")
        if not 0.0 <= self.share_fraction <= 1.0:
            raise ValueError("share_fraction must be in [0, 1]")
        if self.prefix_group_size < 2:
            raise ValueError("prefix_group_size must be >= 2")
        if self.cohort < self.prefix_group_size:
            raise ValueError("cohort must hold at least one prefix group")


@dataclass(frozen=True)
class ReplayRequest:
    uid: int
    arrival_round: int
    prefill_pages: int
    decode_steps: int
    prefix_id: int = -1                    # -1: no shared prefix


@dataclass(frozen=True)
class PrefixInfo:
    """Whole-group facts, available as soon as any member is yielded."""

    members: int
    total_decode_steps: int                # == per-line reads of the prefix
    uid_min: int
    uid_max: int


class RequestStream:
    """Deterministic, re-iterable stream of :class:`ReplayRequest`."""

    def __init__(self, cfg: TrafficConfig):
        self.cfg = cfg
        self._prefixes: Dict[int, PrefixInfo] = {}

    def prefix_info(self, prefix_id: int) -> PrefixInfo:
        return self._prefixes[prefix_id]

    def __iter__(self) -> Iterator[ReplayRequest]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        pp_choices = np.asarray(cfg.prefill_pages_choices)
        pp_w = np.asarray(cfg.prefill_pages_weights, dtype=np.float64)
        pp_w = pp_w / pp_w.sum()
        ds_choices = np.asarray(cfg.decode_steps_choices)
        ds_w = np.asarray(cfg.decode_steps_weights, dtype=np.float64)
        ds_w = ds_w / ds_w.sum()

        uid = 0
        clock = 0.0
        next_pid = 0
        remaining = cfg.n_requests
        while remaining:
            n = min(cfg.cohort, remaining)
            remaining -= n
            pp = rng.choice(pp_choices, size=n, p=pp_w)
            ds = rng.choice(ds_choices, size=n, p=ds_w)
            shared = rng.random(n) < cfg.share_fraction

            if cfg.process == "poisson":
                gaps = rng.exponential(cfg.mean_interarrival_rounds, n)
            else:
                # geometric bursts: each request opens a new burst with
                # probability 1/mean_size; only burst openers add a gap
                opener = rng.random(n) < 1.0 / cfg.burst_mean_size
                opener[0] = True
                gaps = np.where(opener,
                                rng.exponential(cfg.burst_gap_rounds, n),
                                0.0)
            arrivals = np.floor(clock + np.cumsum(gaps)).astype(np.int64)
            clock = float(clock + gaps.sum())

            # consecutive sharing requests chunk into groups; prefix
            # facts are recorded before any member is yielded (idempotent
            # overwrite, so re-iteration never double-counts)
            pid = np.full(n, -1, dtype=np.int64)
            sh_idx = np.nonzero(shared)[0]
            g = cfg.prefix_group_size
            for k in range(0, len(sh_idx) - len(sh_idx) % g, g):
                grp = sh_idx[k:k + g]
                pid[grp] = next_pid
                self._prefixes[next_pid] = PrefixInfo(
                    members=len(grp),
                    total_decode_steps=int(ds[grp].sum()),
                    uid_min=uid + int(grp[0]),
                    uid_max=uid + int(grp[-1]))
                next_pid += 1

            for i in range(n):
                yield ReplayRequest(
                    uid=uid + i, arrival_round=int(arrivals[i]),
                    prefill_pages=int(pp[i]), decode_steps=int(ds[i]),
                    prefix_id=int(pid[i]))
            uid += n

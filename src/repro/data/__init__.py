from .synthetic import SyntheticLM
from .synthetic import make_batch

__all__ = ["SyntheticLM", "make_batch"]

"""Deterministic synthetic LM data pipeline.

Tokens are generated from a counter-based PRNG keyed by (seed, step,
shard) so that (a) every restart reproduces the same stream (checkpoint
resume sees identical batches), and (b) each data-parallel host generates
only its own shard — no host ever materializes the global batch
(mandatory at global_batch 256 × seq 4k).

The generated stream is a Zipf-ish mixture with Markov structure rather
than uniform noise, so the training loss has real signal to descend.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def __post_init__(self) -> None:
        if self.global_batch % self.n_shards:
            raise ValueError("global_batch must divide evenly over shards")
        self.local_batch = self.global_batch // self.n_shards

    def _rng(self, step: int) -> np.random.Generator:
        seq = np.random.SeedSequence(
            entropy=self.seed, spawn_key=(step, self.shard))
        return np.random.Generator(np.random.PCG64(seq))

    def batch(self, step: int) -> np.ndarray:
        """(local_batch, seq_len) int32 tokens for this shard at `step`."""
        rng = self._rng(step)
        b, s, v = self.local_batch, self.seq_len, self.vocab
        # zipf-weighted unigram pool + first-order repetition structure
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        tok = (base - 1) % v
        rep = rng.random((b, s)) < 0.3
        shifted = np.roll(tok, 1, axis=1)
        tok = np.where(rep, shifted, tok)
        tok[:, 0] = 1                      # BOS
        return tok.astype(np.int32)

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch(vocab: int, batch: int, seq: int, step: int = 0,
               seed: int = 0) -> np.ndarray:
    return SyntheticLM(vocab, seq, batch, seed=seed).batch(step)

"""Multi-tenant composition of dataflow specs (DESIGN.md §8.4).

The paper's shared system-level cache is a *multi-core, multi-workload*
resource (§IV-D/E exist because heterogeneous dataflows contend for one
LLC), yet a single :class:`~repro.dataflows.ir.DataflowSpec` describes
one dataflow in isolation.  :func:`compose_time_sliced` builds the
serving-system view: N tenant specs time-sliced onto the same cores in
round-robin quanta, sharing one LLC.

The composite is itself a valid ``DataflowSpec``, so **all four
lowerings work unchanged** — the simulator trace executes the true
interleaving, ``lower_to_reuse_profile`` measures the *interleaved*
stack distances (tenant A's reuse window now contains tenant B's
traffic), the counts see the union working set, and the orchestrator
plans the union tensor set.  What composition adds on top:

* **tensor namespacing** — tenant ``i``'s tensors are renamed
  ``t{i}.<name>`` and declared tenant-major, so each tenant occupies one
  contiguous run of the shared address layout;
* **region alignment** — each tenant's block starts at a multiple of
  ``region_align_bytes`` (default 16 MB).  The TMU's dead-tile
  identifier is a ``tag``-domain slice (``tag[D_MSB:D_LSB]``, §IV-B)
  whose region granularity is ``num_sets · line_bytes · 2^D_LSB``;
  aligning tenant bases beyond that guarantees no dead-id region (and
  no ``tag[B_BITS-1:0]`` priority tier) ever straddles two tenants — a
  retirement in one tenant can never mark another tenant's lines dead;
* **tenant metadata** — ``tenant_of_tensor`` / ``tenant_names`` ride on
  the spec and are threaded through every lowering, so the simulator
  attributes hits/misses/write-backs per tenant region and the
  analytical model exposes per-tenant breakdowns (and can run one gear
  feedback loop per tenant, the per-slice mode).
"""

from __future__ import annotations

from typing import Dict
from typing import List
from typing import Optional
from typing import Sequence

from .ir import DataflowSpec
from .ir import StepSpec
from .ir import TensorSpec

#: default tenant-region alignment: covers the dead-id tag granularity
#: (num_sets · line_bytes · 2^D_LSB) for every geometry the suite sweeps
#: (up to 128 MB LLCs at 128-byte lines, assoc 8, D_LSB 0) and is a
#: multiple of the 2^B_BITS tier period, so each tenant's tier layout
#: starts at tier 0 exactly like its stand-alone spec.
REGION_ALIGN_BYTES = 1 << 24


def compose_time_sliced(tenants: Sequence[DataflowSpec],
                        quantum_rounds: int = 8,
                        name: Optional[str] = None,
                        region_align_bytes: int = REGION_ALIGN_BYTES,
                        ) -> DataflowSpec:
    """Interleave ``tenants`` round-robin onto one set of cores.

    The composite schedule takes ``quantum_rounds`` lockstep rounds from
    tenant 0, then ``quantum_rounds`` from tenant 1, … cycling until
    every tenant's schedule is exhausted (a tenant that finishes early
    simply drops out of the rotation — no idle quanta are inserted).
    Tenants narrower than the widest one leave the extra cores idle
    during their quanta.

    Core sharing-group annotations survive only when every tenant
    declares the identical layout (they are per-core *static* facts and
    the composite runs different tenants on the same core over time);
    otherwise the composite resets to ungrouped all-leader cores —
    compose gqa-dependent tenants only with matching group layouts.
    """
    if not tenants:
        raise ValueError("compose_time_sliced needs at least one tenant")
    if quantum_rounds < 1:
        raise ValueError("quantum_rounds must be >= 1")
    line_bytes = tenants[0].line_bytes
    if any(t.line_bytes != line_bytes for t in tenants):
        raise ValueError("tenants disagree on line_bytes")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        names = [f"{t.name}#{i}" for i, t in enumerate(tenants)]

    n_cores = max(t.n_cores for t in tenants)

    # --- tensor layer: tenant-major, namespaced --------------------------
    tensors: List[TensorSpec] = []
    tenant_of: Dict[str, int] = {}
    rename: List[Dict[str, str]] = []
    for i, sp in enumerate(tenants):
        m: Dict[str, str] = {}
        for t in sp.tensors:
            new = f"t{i}.{t.name}"
            m[t.name] = new
            tenant_of[new] = i
            tensors.append(TensorSpec(
                name=new, size_bytes=t.size_bytes, tile_bytes=t.tile_bytes,
                n_acc=t.n_acc, operand_id=t.operand_id, bypass=t.bypass,
                epoch0=t.epoch0, epoch1=t.epoch1, sharers=t.sharers))
        rename.append(m)

    # --- schedule layer: round-robin quanta ------------------------------
    def renamed(step: StepSpec, m: Dict[str, str]) -> StepSpec:
        return StepSpec(
            loads=tuple((m[n], tile) for n, tile in step.loads),
            stores=tuple((m[n], tile) for n, tile in step.stores),
            flops=step.flops)

    programs: List[List[StepSpec]] = [[] for _ in range(n_cores)]
    cursor = [0] * len(tenants)          # next round to take per tenant
    active = list(range(len(tenants)))
    while active:
        still: List[int] = []
        for i in active:
            sp = tenants[i]
            r0 = cursor[i]
            r1 = min(r0 + quantum_rounds, sp.n_rounds)
            cursor[i] = r1
            for r in range(r0, r1):
                for c in range(n_cores):
                    prog = sp.core_programs[c] if c < sp.n_cores else ()
                    programs[c].append(
                        renamed(prog[r], rename[i]) if r < len(prog)
                        else StepSpec())
            if r1 < sp.n_rounds:
                still.append(i)
        active = still

    # --- core annotations: only a unanimous layout survives ---------------
    def padded_groups(sp: DataflowSpec):
        pad = n_cores - sp.n_cores
        return (list(sp.core_group) + [-1] * pad,
                list(sp.core_is_leader) + [True] * pad)

    g0, l0 = padded_groups(tenants[0])
    if all(padded_groups(sp) == (g0, l0) for sp in tenants[1:]):
        core_group, core_is_leader = g0, l0
    else:
        core_group = [-1] * n_cores
        core_is_leader = [True] * n_cores

    spec = DataflowSpec(
        name=name or ("mt-" + "+".join(names)),
        tensors=tensors,
        core_programs=programs,
        core_group=core_group,
        core_is_leader=core_is_leader,
        line_bytes=line_bytes,
        tenant_of_tensor=tenant_of,
        tenant_names=names,
        tenant_region_align=region_align_bytes,
    )
    spec.validate()
    # composite specs feed registries/replay directly (no SpecBuilder on
    # this path), so run the same error-tier gate build() applies
    from .verify import assert_clean
    assert_clean(spec)
    return spec


def tenant_regions(spec: DataflowSpec) -> List[tuple]:
    """Per-tenant ``(name, base_addr, end_addr)`` of the shared layout —
    the address regions the simulator attributes counters by.  Regions
    are disjoint and each base is ``tenant_region_align``-aligned
    (round-trip pinned by tests)."""
    from .lower import assign_addresses

    if spec.tenant_of_tensor is None or spec.tenant_names is None:
        raise ValueError(f"{spec.name}: not a multi-tenant composite")
    metas = assign_addresses(spec)
    lo = [None] * len(spec.tenant_names)
    hi = [None] * len(spec.tenant_names)
    for tid, t in enumerate(spec.tensors):
        ten = spec.tenant_of_tensor[t.name]
        m = metas[tid]
        lo[ten] = m.base_addr if lo[ten] is None else min(lo[ten],
                                                          m.base_addr)
        hi[ten] = m.end_addr if hi[ten] is None else max(hi[ten],
                                                         m.end_addr)
    return [(n, lo[i], hi[i]) for i, n in enumerate(spec.tenant_names)]

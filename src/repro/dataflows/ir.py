"""Declarative dataflow IR (DESIGN.md §8).

One :class:`DataflowSpec` describes a dataflow at the level the DCO paper
reasons about (§III: "dataflow information available in the software
stack"), and every downstream consumer *derives* its view from that single
description instead of keeping hand-written twins in sync:

* ``lower_to_trace``  → the cycle simulator's :class:`~repro.core.traces.Trace`
* ``lower_to_counts`` → the analytical model's
  :class:`~repro.core.traces.DataflowCounts`
* ``lower_to_plan``   → the TPU orchestrator's
  :class:`~repro.core.orchestrator.OrchestrationPlan` / TMU metadata

The IR has two layers:

**Tensor layer** (fully declarative) — :class:`TensorSpec` records, per
tensor, what the paper's TMU instructions register (size, tile shape,
per-line expected *read* count ``n_acc``, operand id, whole-tensor bypass
hint) plus two placement facts the closed-form counts need and a trace
cannot express directly: the tensor's *liveness epoch range* (which
working-set generation it belongs to — batch index in the multi-batch
§VI-F scenario, retirement wave in decode, expert generation in MoE) and
its *sharer count* (how many cores co-stream it through the LLC —
1 for temporal placement, the group size for spatial placement §VI-C).

**Schedule layer** — per-core lists of :class:`StepSpec` (bulk tile
transfers + flops), one entry per lockstep round of the burst-synchronous
simulation (DESIGN.md §7.2).  Steps reference tensors *by name*; no
addresses exist at this level.  Address assignment happens once, inside
the lowerings, so every backend sees the same layout.

``n_acc`` counts *reads*: the TMU bumps ``accCnt`` on tile-last-line load
accesses only (stores never enter the TLL feed), so a tensor that is
produced and then consumed (e.g. an activation between fused ops) sets
``n_acc`` to its read count and retires when the last consumer has
streamed it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict
from typing import List
from typing import Optional
from typing import Sequence
from typing import Tuple

from repro.core.workloads import AttnWorkload

LINE_BYTES = 128

Access = Tuple[str, int]              # (tensor name, tile index)


@dataclass(frozen=True)
class TensorSpec:
    """One tensor of a dataflow, in TMU-registration form (paper §IV-B)
    plus the placement facts the counts lowering derives reuse from."""

    name: str
    size_bytes: int
    tile_bytes: int
    n_acc: int                  # expected reads of each cache line
    operand_id: int = 0
    bypass: bool = False        # whole-tensor LLC bypass (paper §V-C)
    epoch0: int = 0             # first working-set epoch this tensor is live
    epoch1: int = 0             # last epoch (inclusive)
    sharers: int = 1            # cores co-streaming it through the LLC
    base: Optional[int] = None  # explicit base address (pooled layouts);
    #                             None = the lowering's bump allocator

    def __post_init__(self) -> None:
        if self.size_bytes <= 0 or self.tile_bytes <= 0:
            raise ValueError(f"{self.name}: sizes must be positive")
        if self.size_bytes % self.tile_bytes:
            raise ValueError(
                f"{self.name}: size {self.size_bytes} not a multiple of "
                f"tile {self.tile_bytes}")
        if self.epoch1 < self.epoch0 or self.epoch0 < 0:
            raise ValueError(f"{self.name}: bad epoch range")
        if self.sharers < 1:
            raise ValueError(f"{self.name}: sharers must be >= 1")
        if self.base is not None and (self.base < 0
                                      or self.base % self.tile_bytes):
            raise ValueError(
                f"{self.name}: explicit base 0x{self.base:x} must be "
                f"tile-aligned and non-negative")

    @property
    def num_tiles(self) -> int:
        return self.size_bytes // self.tile_bytes

    @property
    def reuse_carrier(self) -> bool:
        """True for tensors whose lines the LLC can usefully retain (the
        paper's K/V class); bypass tensors are the bursty Q/O class."""
        return not self.bypass


@dataclass(frozen=True)
class StepSpec:
    """One lockstep round on one core: bulk tile transfers + compute."""

    loads: Tuple[Access, ...] = ()
    stores: Tuple[Access, ...] = ()
    flops: float = 0.0


@dataclass
class DataflowSpec:
    """A complete dataflow: tensor layer + per-core round schedule.

    ``tenant_of_tensor`` / ``tenant_names`` / ``tenant_region_align`` are
    set by :func:`~repro.dataflows.compose.compose_time_sliced` on
    multi-tenant composites: every tensor belongs to exactly one tenant,
    tenants occupy disjoint address regions (the shared allocator aligns
    each tenant's first tensor to ``tenant_region_align`` so no TMU
    dead-id tag region straddles two tenants), and all lowerings carry
    the mapping through so simulator counters, profile masses, and plans
    can be attributed per tenant.  ``None`` on ordinary single-tenant
    specs.
    """

    name: str
    tensors: List[TensorSpec]                 # declaration order = layout order
    core_programs: List[List[StepSpec]]
    core_group: List[int]
    core_is_leader: List[bool]
    line_bytes: int = LINE_BYTES
    workload: Optional[AttnWorkload] = None
    tenant_of_tensor: Optional[Dict[str, int]] = None
    tenant_names: Optional[List[str]] = None
    tenant_region_align: int = 0
    #: which address-space policy laid the spec out ("bump" | "pooled");
    #: the verifier conditions its DCO2xx layout rules on this tag
    #: (DESIGN.md §13) — monotone bases are a bump fact, not an IR fact
    allocator: str = "bump"

    @property
    def n_cores(self) -> int:
        return len(self.core_programs)

    @property
    def n_tenants(self) -> int:
        return len(self.tenant_names) if self.tenant_names else 1

    @property
    def n_rounds(self) -> int:
        return max((len(p) for p in self.core_programs), default=0)

    @property
    def n_epochs(self) -> int:
        return 1 + max((t.epoch1 for t in self.tensors), default=0)

    def tensor(self, name: str) -> TensorSpec:
        return self._by_name()[name]

    def _by_name(self) -> Dict[str, TensorSpec]:
        return {t.name: t for t in self.tensors}

    # ------------------------------------------------------------------
    def validate(self) -> None:
        """Structural well-formedness: unique names, resolvable references,
        in-range tile indices, consistent core/tenant annotations.

        The checks themselves live in the verifier's rule inventory
        (``repro.dataflows.verify``, codes DCO001–DCO008) so the CLI,
        the gates, and this fail-fast path agree on one rule set; this
        raises on the first structural error, spec name included.
        """
        from .verify import structural_diagnostics
        diags = structural_diagnostics(self)
        if diags:
            d = diags[0]
            more = f" (+{len(diags) - 1} more)" if len(diags) > 1 else ""
            raise ValueError(f"{d.format()}{more}")

    # ------------------------------------------------------------------
    def per_tensor_line_accesses(self) -> Dict[str, Tuple[int, int]]:
        """Closed-form (line_reads, line_writes) per tensor, from tile
        transfer counts × lines-per-tile — no trace expansion, no
        addresses.  The property tests pin these against trace-derived
        totals."""
        reads: Dict[str, int] = {t.name: 0 for t in self.tensors}
        writes: Dict[str, int] = {t.name: 0 for t in self.tensors}
        for prog in self.core_programs:
            for step in prog:
                for tname, _ in step.loads:
                    reads[tname] += 1
                for tname, _ in step.stores:
                    writes[tname] += 1
        out: Dict[str, Tuple[int, int]] = {}
        for t in self.tensors:
            lpt = t.tile_bytes // self.line_bytes
            out[t.name] = (reads[t.name] * lpt, writes[t.name] * lpt)
        return out

    def total_flops(self) -> float:
        return sum(step.flops for prog in self.core_programs
                   for step in prog)


class SpecBuilder:
    """Imperative construction helper for :class:`DataflowSpec`.

    Scenario builders declare tensors (declaration order fixes the address
    layout, like the TMU registration order fixes metadata slots) and emit
    per-core steps; ``build()`` validates and freezes the spec.
    """

    def __init__(self, name: str, n_cores: int,
                 line_bytes: int = LINE_BYTES,
                 workload: Optional[AttnWorkload] = None):
        self.name = name
        self.line_bytes = line_bytes
        self.workload = workload
        self.allocator = "bump"      # layout-policy tag for the built spec
        self._tensors: List[TensorSpec] = []
        self._programs: List[List[StepSpec]] = [[] for _ in range(n_cores)]
        self._core_group = [-1] * n_cores
        self._core_is_leader = [True] * n_cores

    @property
    def n_cores(self) -> int:
        return len(self._programs)

    def tensor(self, name: str, *, size_bytes: int, tile_bytes: int,
               n_acc: int, operand_id: int = 0, bypass: bool = False,
               epoch: int | Tuple[int, int] = 0, sharers: int = 1,
               base: Optional[int] = None) -> str:
        e0, e1 = (epoch, epoch) if isinstance(epoch, int) else epoch
        self._tensors.append(TensorSpec(
            name=name, size_bytes=size_bytes, tile_bytes=tile_bytes,
            n_acc=n_acc, operand_id=operand_id, bypass=bypass,
            epoch0=e0, epoch1=e1, sharers=sharers, base=base))
        return name

    def step(self, core: int, loads: Sequence[Access] = (),
             stores: Sequence[Access] = (), flops: float = 0.0) -> None:
        self._programs[core].append(StepSpec(
            loads=tuple(loads), stores=tuple(stores), flops=flops))

    def pad(self, core: int, n_rounds: int) -> None:
        """Idle rounds keeping ``core`` in lockstep with the others."""
        self._programs[core].extend(StepSpec() for _ in range(n_rounds))

    def pad_to_sync(self) -> None:
        """Barrier: pad every core to the longest program (op boundary)."""
        longest = max((len(p) for p in self._programs), default=0)
        for c in range(self.n_cores):
            self.pad(c, longest - len(self._programs[c]))

    def set_groups(self, core_group: Sequence[int],
                   core_is_leader: Sequence[bool]) -> None:
        self._core_group = list(core_group)
        self._core_is_leader = list(core_is_leader)

    def build(self, verify: bool = True) -> DataflowSpec:
        """Validate, gate, and freeze the spec.

        Beyond the structural ``validate()``, every built spec passes
        the verifier's error tier (annotation-vs-schedule consistency,
        layout invariants — DESIGN.md §12) so no inconsistent spec
        enters a registry or lowering; ``verify=False`` skips the gate
        for callers that deliberately construct defective specs (the
        injection harness goes through ``dataclasses.replace`` instead).
        """
        spec = DataflowSpec(
            name=self.name, tensors=list(self._tensors),
            core_programs=[list(p) for p in self._programs],
            core_group=list(self._core_group),
            core_is_leader=list(self._core_is_leader),
            line_bytes=self.line_bytes, workload=self.workload,
            allocator=self.allocator)
        spec.validate()
        if verify:
            from .verify import assert_clean
            assert_clean(spec)
        return spec

"""Declarative dataflow IR with shared lowerings (DESIGN.md §8).

One :class:`DataflowSpec` per scenario; ``lower_to_trace`` /
``lower_to_counts`` / ``lower_to_plan`` derive the simulator trace, the
analytical model's counts, and the orchestrator plan from that single
description.  The scenario registry (``build_suite``) is the canonical
entry point for sweeping every expressible dataflow.
"""

from .artifacts import (artifacts_enabled, cache_dir, spec_fingerprint,
                        try_spec_fingerprint)
from .compose import compose_time_sliced, tenant_regions
from .fa2 import fa2_spec, matmul_spec
from .ir import DataflowSpec, SpecBuilder, StepSpec, TensorSpec
from .lower import (assign_addresses, lower_to_counts, lower_to_plan,
                    lower_to_trace, tmu_metadata)
from .reuse import ReuseProfile, lower_to_reuse_profile
from .scenarios import (decode_paged_spec, mlp_chain_spec, moe_ffn_spec,
                        prefix_share_spec, spec_decode_spec, ssd_scan_spec,
                        transformer_layer_spec)
from .stream import (DEFAULT_CHUNK_LINES, ReplaySegment, SpecEmitter,
                     StreamEmitter)
from .suite import (SUITE_POLICIES, SuiteCase, build_suite, registry_keys,
                    suite_case)

__all__ = [
    "DataflowSpec", "SpecBuilder", "StepSpec", "TensorSpec",
    "compose_time_sliced", "tenant_regions",
    "assign_addresses", "lower_to_counts", "lower_to_plan",
    "lower_to_trace", "tmu_metadata",
    "ReuseProfile", "lower_to_reuse_profile",
    "artifacts_enabled", "cache_dir", "spec_fingerprint",
    "try_spec_fingerprint",
    "fa2_spec", "matmul_spec",
    "decode_paged_spec", "mlp_chain_spec", "moe_ffn_spec",
    "prefix_share_spec", "spec_decode_spec", "ssd_scan_spec",
    "transformer_layer_spec",
    "DEFAULT_CHUNK_LINES", "ReplaySegment", "SpecEmitter", "StreamEmitter",
    "SUITE_POLICIES", "SuiteCase", "build_suite", "registry_keys",
    "suite_case",
]

"""Declarative dataflow IR with shared lowerings (DESIGN.md §8).

One :class:`DataflowSpec` per scenario; ``lower_to_trace`` /
``lower_to_counts`` / ``lower_to_plan`` derive the simulator trace, the
analytical model's counts, and the orchestrator plan from that single
description.  The scenario registry (``build_suite``) is the canonical
entry point for sweeping every expressible dataflow.
"""

from .artifacts import artifacts_enabled
from .artifacts import cache_dir
from .artifacts import spec_fingerprint
from .artifacts import try_spec_fingerprint
from .compose import compose_time_sliced
from .compose import tenant_regions
from .fa2 import fa2_spec
from .fa2 import matmul_spec
from .ir import DataflowSpec
from .ir import SpecBuilder
from .ir import StepSpec
from .ir import TensorSpec
from .lower import assign_addresses
from .lower import lower_to_counts
from .lower import lower_to_plan
from .lower import lower_to_trace
from .lower import tmu_metadata
from .reuse import ReuseProfile
from .reuse import lower_to_reuse_profile
from .scenarios import decode_paged_spec
from .scenarios import mlp_chain_spec
from .scenarios import moe_ffn_spec
from .scenarios import prefix_share_spec
from .scenarios import spec_decode_spec
from .scenarios import ssd_scan_spec
from .scenarios import transformer_layer_spec
from .stream import DEFAULT_CHUNK_LINES
from .stream import ReplaySegment
from .stream import SpecEmitter
from .stream import StreamEmitter
from .suite import SUITE_POLICIES
from .suite import SuiteCase
from .suite import build_suite
from .suite import registry_keys
from .suite import suite_case
from .verify import Diagnostic
from .verify import SpecVerifyError
from .verify import StreamVerifier
from .verify import VerifyResult
from .verify import assert_clean
from .verify import cross_check_case
from .verify import predicted_retirements
from .verify import rules_inventory
from .verify import verify_metas
from .verify import verify_spec

__all__ = [
    "DataflowSpec", "SpecBuilder", "StepSpec", "TensorSpec",
    "compose_time_sliced", "tenant_regions",
    "assign_addresses", "lower_to_counts", "lower_to_plan",
    "lower_to_trace", "tmu_metadata",
    "ReuseProfile", "lower_to_reuse_profile",
    "artifacts_enabled", "cache_dir", "spec_fingerprint",
    "try_spec_fingerprint",
    "fa2_spec", "matmul_spec",
    "decode_paged_spec", "mlp_chain_spec", "moe_ffn_spec",
    "prefix_share_spec", "spec_decode_spec", "ssd_scan_spec",
    "transformer_layer_spec",
    "DEFAULT_CHUNK_LINES", "ReplaySegment", "SpecEmitter", "StreamEmitter",
    "SUITE_POLICIES", "SuiteCase", "build_suite", "registry_keys",
    "suite_case",
    "Diagnostic", "SpecVerifyError", "StreamVerifier", "VerifyResult",
    "assert_clean", "cross_check_case", "predicted_retirements",
    "rules_inventory", "verify_metas", "verify_spec",
]

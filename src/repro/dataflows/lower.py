"""Lowerings from the dataflow IR to the three backends (DESIGN.md §8.2).

All three consumers of dataflow knowledge — the trace-driven simulator,
the cache-integrated analytical model (§V), and the TPU-side orchestrator
— derive their inputs here from one :class:`~repro.dataflows.ir.DataflowSpec`.
Address assignment is shared: every lowering sees the same layout —
explicit per-tensor bases when an :mod:`~repro.dataflows.addr` allocator
already laid the spec out (the pooled replay path), otherwise the default
tile-aligned bump allocation in declaration order — so the simulator's
TMU metadata, the model's line counts, and the orchestrator's plan all
describe the same physical tensors.
"""

from __future__ import annotations

from typing import Dict
from typing import List

from repro.core.orchestrator import CacheOrchestrator
from repro.core.orchestrator import OrchestrationPlan
from repro.core.tmu import TensorMeta
from repro.core.traces import DataflowCounts
from repro.core.traces import Step
from repro.core.traces import Trace

from .addr import BumpAllocator
from .ir import DataflowSpec


def assign_addresses(spec: DataflowSpec) -> Dict[int, TensorMeta]:
    """Lay the spec's tensors out in physical address space.

    Declaration order is allocation order and the tensor id is the
    declaration index — the single source of truth for the layout every
    lowering (and the TMU) observes.  On a multi-tenant composite
    (``spec.tenant_of_tensor``) each tenant's first tensor is aligned to
    ``spec.tenant_region_align``, so tenants occupy disjoint address
    regions and no TMU dead-id tag region straddles two tenants
    (DESIGN.md §8.4).

    Tensors may instead carry *explicit* bases (``TensorSpec.base``, set
    by an emitter that already ran an :class:`~repro.dataflows.addr`
    allocator — the pooled replay path): all-or-nothing per spec, and
    the bases are used verbatim so every lowering reproduces the
    emitter's layout.  Specs without explicit bases go through the
    default :class:`~repro.dataflows.addr.BumpAllocator`, bit-identical
    to the historical in-lowering bump allocator.
    """
    n_explicit = sum(1 for t in spec.tensors if t.base is not None)
    if n_explicit and n_explicit != len(spec.tensors):
        raise ValueError(
            f"{spec.name}: explicit tensor bases are all-or-nothing "
            f"({n_explicit}/{len(spec.tensors)} set)")
    metas: Dict[int, TensorMeta] = {}
    if n_explicit:
        for tid, t in enumerate(spec.tensors):
            metas[tid] = TensorMeta(
                tensor_id=tid, base_addr=t.base, size_bytes=t.size_bytes,
                tile_bytes=t.tile_bytes, n_acc=t.n_acc,
                operand_id=t.operand_id, bypass_all=t.bypass)
        return metas
    alloc = BumpAllocator()
    tenant_of = spec.tenant_of_tensor
    region_align = spec.tenant_region_align
    prev_tenant = None
    for tid, t in enumerate(spec.tensors):
        align = t.tile_bytes
        if tenant_of is not None and region_align:
            tenant = tenant_of[t.name]
            if tenant != prev_tenant:
                align = max(align, region_align)
            prev_tenant = tenant
        region = alloc.alloc(t.size_bytes, t.tile_bytes, align=align)
        metas[tid] = TensorMeta(
            tensor_id=tid, base_addr=region.base, size_bytes=t.size_bytes,
            tile_bytes=t.tile_bytes, n_acc=t.n_acc,
            operand_id=t.operand_id, bypass_all=t.bypass)
    return metas


def tmu_metadata(spec: DataflowSpec) -> List[TensorMeta]:
    """The spec's tensors as TMU registration records (paper §IV-B)."""
    return list(assign_addresses(spec).values())


# ---------------------------------------------------------------------------
def lower_to_trace(spec: DataflowSpec) -> Trace:
    """Expand the spec's round schedule into a simulator :class:`Trace`."""
    metas = assign_addresses(spec)
    tid_of = {t.name: i for i, t in enumerate(spec.tensors)}
    core_steps: List[List[Step]] = []
    for prog in spec.core_programs:
        steps: List[Step] = []
        for s in prog:
            steps.append(Step(
                loads=[(tid_of[n], tile) for n, tile in s.loads],
                stores=[(tid_of[n], tile) for n, tile in s.stores],
                flops=s.flops))
        core_steps.append(steps)
    tenant_of = None
    if spec.tenant_of_tensor is not None:
        tenant_of = {tid_of[n]: ten
                     for n, ten in spec.tenant_of_tensor.items()}
    from .artifacts import artifacts_enabled, try_spec_fingerprint
    return Trace(name=spec.name, tensors=metas, core_steps=core_steps,
                 core_group=list(spec.core_group),
                 core_is_leader=list(spec.core_is_leader),
                 line_bytes=spec.line_bytes, workload=spec.workload,
                 tenant_of_tensor=tenant_of,
                 tenant_names=(list(spec.tenant_names)
                               if spec.tenant_names else None),
                 fingerprint=(try_spec_fingerprint(spec)
                              if artifacts_enabled() else None))


# ---------------------------------------------------------------------------
def lower_to_counts(spec: DataflowSpec,
                    with_profile: bool = True) -> DataflowCounts:
    """Derive the analytical model's request counts (§V, Eq. 1–3) from the
    spec — closed-form per tensor (tile transfer counts × lines per tile,
    placement annotations for sharing), no trace expansion and no
    addresses.

    Class assignment follows §V-B/§V-C: non-bypass tensors are the
    reuse-carrier (K/V) class — their first line touches are cold misses
    and repeat touches split into temporal and inter-core reuse via the
    declared ``sharers`` — while ``bypass`` tensors are the bursty
    always-DRAM (Q/O) class.

    ``with_profile`` (default) also runs the reuse-distance lowering
    (DESIGN.md §5) and attaches the resulting
    :class:`~repro.dataflows.reuse.ReuseProfile` so the analytical
    model's default ``model="profile"`` path has its input; pass
    ``False`` to skip the schedule walk when only the scalar counts are
    needed (e.g. very long-context closed-form sweeps).
    """
    per_tensor = spec.per_tensor_line_accesses()
    n_kv_accesses = 0.0
    n_kv_distinct = 0
    n_bypass = 0
    intercore = 0.0
    for t in spec.tensors:
        reads, writes = per_tensor[t.name]
        acc = reads + writes
        if t.bypass:
            n_bypass += acc
            continue
        n_kv_accesses += acc
        n_kv_distinct += t.size_bytes // spec.line_bytes
        if t.sharers > 1:
            intercore += acc * (t.sharers - 1) / t.sharers

    live_bytes = [0] * spec.n_epochs
    for t in spec.tensors:
        if t.bypass:
            continue
        for e in range(t.epoch0, t.epoch1 + 1):
            live_bytes[e] += t.size_bytes
    s_active = max(live_bytes) if live_bytes else 0
    s_total = live_bytes[0] if live_bytes else 0

    profile = None
    if with_profile:
        from . import artifacts
        from .reuse import lower_to_reuse_profile
        fp = (artifacts.try_spec_fingerprint(spec)
              if artifacts.artifacts_enabled() else None)
        if fp is not None:
            profile = artifacts.load_reuse_profile(fp)
        if profile is None:
            profile = lower_to_reuse_profile(spec)
            if fp is not None:
                artifacts.store_reuse_profile(fp, profile)

    return DataflowCounts(
        name=spec.name, line_bytes=spec.line_bytes,
        n_kv_accesses=int(round(n_kv_accesses)),
        n_kv_distinct=int(n_kv_distinct),
        n_bypass_lines=int(n_bypass),
        n_intercore_reuse=int(round(intercore)),
        s_work_active=int(s_active),
        s_work_total=int(s_total),
        flops_total=float(spec.total_flops()),
        n_batches=spec.n_epochs,
        n_rounds=int(spec.n_rounds),
        reuse_profile=profile,
    )


# ---------------------------------------------------------------------------
def lower_to_plan(spec: DataflowSpec, vmem_budget_bytes: int, *,
                  b_bits: int = 3,
                  reserve_fraction: float = 1.0 / 8.0) -> OrchestrationPlan:
    """Plan VMEM residency for the spec's tensors (DESIGN.md §3).

    Registers the shared address layout with a
    :class:`~repro.core.orchestrator.CacheOrchestrator` and runs the
    S_kept planner — the compile-time transfer of the paper's
    anti-thrashing + bypass gear selection.
    """
    orch = CacheOrchestrator(vmem_budget_bytes, b_bits=b_bits,
                             reserve_fraction=reserve_fraction)
    orch.register_many(tmu_metadata(spec))
    return orch.plan()

"""The scenario suite: every dataflow the stack can express, one registry.

Each :class:`SuiteCase` bundles a spec builder with the cache
configuration that puts it in the regime the paper studies (working set
vs. LLC capacity) and the policy-variant flag (gqa bypass for spatially
shared dataflows, §IV-E).  ``benchmarks/suite_bench.py`` sweeps the fig-4
policy set across this registry and cross-validates the simulator against
the analytical model; tests and future scenario PRs extend the registry
rather than writing new one-off builders.

The registry is **lazy**: ``_REGISTRY`` maps each key to a builder thunk
and specs are only constructed when a case is actually requested —
``suite_case(key)`` builds exactly one case (CI smoke used to pay the
full ~10× suite build cost per single-scenario invocation), while
``build_suite()`` materializes all of them in registration order exactly
as before.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable
from typing import Dict
from typing import List
from typing import Tuple

from repro.core.simulator import SimConfig
from repro.core.workloads import AttnWorkload
from repro.core.workloads import DecodeWorkload
from repro.core.workloads import MoEWorkload
from repro.core.workloads import PrefixShareWorkload
from repro.core.workloads import SSDScanWorkload
from repro.core.workloads import SpecDecodeWorkload
from repro.core.workloads import get_workload

from .compose import compose_time_sliced
from .fa2 import fa2_spec
from .fa2 import matmul_spec
from .ir import DataflowSpec
from .scenarios import decode_paged_spec
from .scenarios import mlp_chain_spec
from .scenarios import moe_ffn_spec
from .scenarios import prefix_share_spec
from .scenarios import spec_decode_spec
from .scenarios import ssd_scan_spec
from .scenarios import transformer_layer_spec

MB = 2 ** 20

#: the fig-4 policy set plus the DBP-bearing variants the new scenarios
#: exercise (fig-8 style)
SUITE_POLICIES: Tuple[str, ...] = ("lru", "at", "at+bypass", "at+dbp",
                                   "all")


@dataclass
class SuiteCase:
    key: str
    spec: DataflowSpec
    cfg: SimConfig
    gqa: bool = False
    #: scenarios where dead-block prediction must beat plain LRU
    expect_dbp_win: bool = False

    @property
    def fingerprint(self) -> str:
        """Deterministic content hash of this case's spec — the
        registry-level handle into the artifact cache
        (``repro.dataflows.artifacts``)."""
        from .artifacts import spec_fingerprint
        return spec_fingerprint(self.spec)


# ---------------------------------------------------------------------------
# Case builders (lazy: invoked per requested case, not at import / lookup)
# ---------------------------------------------------------------------------
def _fa2_temporal(full: bool, n_cores: int) -> SuiteCase:
    seq = 2048 if full else 1024
    wl = get_workload("gemma3-27b", seq_len=seq)
    return SuiteCase(
        "fa2-temporal", fa2_spec(wl, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=(4 if full else 2) * MB))


def _fa2_spatial(full: bool, n_cores: int) -> SuiteCase:
    seq = 2048 if full else 1024
    wl = get_workload("qwen3-8b", seq_len=seq)
    return SuiteCase(
        "fa2-spatial", fa2_spec(wl, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=(2 if full else 1) * MB),
        gqa=True)


def _matmul(full: bool, n_cores: int) -> SuiteCase:
    dim = 2048 if full else 1024
    return SuiteCase(
        "matmul", matmul_spec(dim, dim, dim, tile=128, n_cores=n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=1 * MB))


def _decode_paged(full: bool, n_cores: int) -> SuiteCase:
    dec = DecodeWorkload(seq_len=4096 if full else 2048)
    return SuiteCase(
        "decode-paged", decode_paged_spec(dec, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=4 * MB),
        expect_dbp_win=True)


def _moe_ffn(full: bool, n_cores: int) -> SuiteCase:
    moe = MoEWorkload(n_steps=12 if full else 8)
    return SuiteCase(
        "moe-ffn", moe_ffn_spec(moe, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=2 * MB),
        expect_dbp_win=True)


def _spec_decode(full: bool, n_cores: int) -> SuiteCase:
    spd = SpecDecodeWorkload(target_len=1024 if full else 512)
    return SuiteCase(
        "spec-decode", spec_decode_spec(spd, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=(8 if full else 4) * MB),
        expect_dbp_win=True)


def _mlp_chain(full: bool, n_cores: int) -> SuiteCase:
    return SuiteCase(
        "mlp-chain",
        mlp_chain_spec(m=2048 if full else 1024, n_cores=n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=1 * MB))


def _transformer_layer(full: bool, n_cores: int) -> SuiteCase:
    seq = 2048 if full else 1024
    wl = AttnWorkload("tl-8h", n_q_heads=8, n_kv_heads=4, head_dim=128,
                      seq_len=seq, group_alloc="temporal")
    return SuiteCase(
        "transformer-layer", transformer_layer_spec(wl, d_ff=1024,
                                                    n_cores=n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=2 * MB))


def _ssd_scan(full: bool, n_cores: int) -> SuiteCase:
    # one state generation is n_seqs × n_heads × P × N = 1.5 MB and
    # head slabs retire incrementally (a read slab dies as the matching
    # new slab is stored), so the live stack peaks at ~1 generation
    # (12288 lines): under a 2 MB LLC the live states fit once the
    # consumed slabs retire, while LRU drags them as MRU dead mass and
    # thrashes — the recurring chunk-cadence DBP win
    ssd = SSDScanWorkload(n_chunks=8 if full else 6)
    return SuiteCase(
        "ssd-scan", ssd_scan_spec(ssd, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=2 * MB),
        expect_dbp_win=True)


def _prefix_share(full: bool, n_cores: int) -> SuiteCase:
    # shared prefix 0.5 MB + 2 MB of private suffixes over a 1 MB LLC:
    # the private streams thrash while the co-streamed prefix is the
    # inter-core reuse blind bypassing would destroy (gqa variant on)
    pfx = PrefixShareWorkload(prefix_len=4096 if full else 2048)
    return SuiteCase(
        "prefix-share", prefix_share_spec(pfx, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=1 * MB),
        gqa=True)


# --- multi-tenant mixes (DESIGN.md §8.4) -----------------------------------
def _mt_prefill_decode(full: bool, n_cores: int) -> SuiteCase:
    # the classic serving mix: a compute-heavy prefill tenant (FA2 over
    # one attention unit) time-sliced against a decode tenant whose
    # paged KV pollutes the shared LLC as sequences finish — DBP retires
    # the dead pages of *both* tenants' regions, and the prefill
    # tenant's KV reuse must survive the decode tenant's thrash
    seq = 1024 if full else 512
    wl = AttnWorkload("prefill", n_q_heads=16, n_kv_heads=8, head_dim=128,
                      seq_len=seq, group_alloc="temporal")
    dec = DecodeWorkload(seq_len=2048 if full else 1024,
                         n_steps=6, retire_step=3)
    spec = compose_time_sliced(
        [fa2_spec(wl, n_cores), decode_paged_spec(dec, n_cores)],
        quantum_rounds=16, name="mt-prefill-decode")
    return SuiteCase(
        "mt-prefill-decode", spec,
        SimConfig(n_cores=n_cores, llc_bytes=(4 if full else 2) * MB),
        expect_dbp_win=True)


def _mt_spec_ssd(full: bool, n_cores: int) -> SuiteCase:
    # two DBP-heavy epoch structures colliding on one LLC: speculative
    # decoding's per-cycle draft windows and the SSD scan's chunk-state
    # generations retire at *different* cadences, so the dead-mass mix
    # the shared cache carries is never aligned with either tenant's
    # epoch boundary — the recurring pollution pattern per tenant
    spd = SpecDecodeWorkload(target_len=512 if full else 256,
                             draft_len=128, n_verify=3)
    ssd = SSDScanWorkload(n_chunks=6 if full else 5, n_heads=4)
    spec = compose_time_sliced(
        [spec_decode_spec(spd, n_cores), ssd_scan_spec(ssd, n_cores)],
        quantum_rounds=16, name="mt-spec-ssd")
    return SuiteCase(
        "mt-spec-ssd", spec,
        SimConfig(n_cores=n_cores, llc_bytes=2 * MB),
        expect_dbp_win=True)


def _serve_replay(full: bool, n_cores: int) -> SuiteCase:
    # the §VI-F regime end to end: bursty arrivals through the
    # continuous-batching scheduler, so completed requests' KV pages sit
    # dead in the LLC while their slots refill — the at-tier protects
    # live KV against the bypassed Q/O stream and DBP reclaims the dead
    # pages at retirement cadence (at+dbp ≈ 1.25×/1.14× over LRU under
    # a 128 KB LLC that holds roughly the live working set)
    from repro.serve.replay import ReplayConfig, replay_spec
    from repro.serve.traffic import TrafficConfig
    traffic = TrafficConfig(n_requests=128 if full else 96, seed=7,
                            process="bursty")
    spec, _ = replay_spec(traffic, ReplayConfig(n_cores=n_cores))
    return SuiteCase(
        "serve-replay", spec,
        SimConfig(n_cores=n_cores, llc_bytes=128 * 1024),
        expect_dbp_win=True)


def _serve_replay_pooled(full: bool, n_cores: int) -> SuiteCase:
    # same traffic as serve-replay, but KV pages come from the fixed
    # page pool instead of the monotone bump stream: retired requests'
    # regions are recycled, so `tag[B_BITS-1:0]` tiers stay correlated
    # with liveness at serving scale (the at-tier recovery the pooled
    # allocator exists for — DESIGN.md §13)
    from repro.serve.replay import ReplayConfig, replay_spec
    from repro.serve.traffic import TrafficConfig
    traffic = TrafficConfig(n_requests=128 if full else 96, seed=7,
                            process="bursty")
    spec, _ = replay_spec(traffic, ReplayConfig(n_cores=n_cores,
                                                allocator="pooled"))
    return SuiteCase(
        "serve-replay-pooled", spec,
        SimConfig(n_cores=n_cores, llc_bytes=128 * 1024),
        expect_dbp_win=True)


#: key → builder thunk, in suite order; ``build_suite`` materializes all
#: of them, ``suite_case`` exactly one
_REGISTRY: Dict[str, Callable[[bool, int], SuiteCase]] = {
    "fa2-temporal": _fa2_temporal,
    "fa2-spatial": _fa2_spatial,
    "matmul": _matmul,
    "decode-paged": _decode_paged,
    "moe-ffn": _moe_ffn,
    "spec-decode": _spec_decode,
    "mlp-chain": _mlp_chain,
    "transformer-layer": _transformer_layer,
    "ssd-scan": _ssd_scan,
    "prefix-share": _prefix_share,
    "mt-prefill-decode": _mt_prefill_decode,
    "mt-spec-ssd": _mt_spec_ssd,
    "serve-replay": _serve_replay,
    "serve-replay-pooled": _serve_replay_pooled,
}


def registry_keys() -> List[str]:
    """Registered scenario keys, in suite order (no spec is built)."""
    return list(_REGISTRY)


def _gated(case: SuiteCase) -> SuiteCase:
    """Registry gate: no case leaves the registry carrying error-tier
    diagnostics (DESIGN.md §12).  Runs against the case's own sim
    config so the layout rules see the geometry the case simulates."""
    from .verify import assert_clean
    assert_clean(case.spec, sim_cfg=case.cfg)
    return case


def build_suite(full: bool = False, n_cores: int = 16) -> List[SuiteCase]:
    """Instantiate the whole suite (reduced grid by default, paper-scale
    shapes with ``full=True``)."""
    return [_gated(build(full, n_cores)) for build in _REGISTRY.values()]


def suite_case(key: str, full: bool = False,
               n_cores: int = 16, *, gate: bool = True) -> SuiteCase:
    """Build exactly one registered case (lazy: no other spec is
    constructed — the CI smoke path).

    ``gate=False`` skips the error-tier verification gate — for the lint
    CLI, which wants the full diagnostic list rather than the first
    error as an exception.
    """
    build = _REGISTRY.get(key)
    if build is None:
        raise KeyError(f"unknown suite scenario {key!r}; have "
                       f"{list(_REGISTRY)}")
    case = build(full, n_cores)
    return _gated(case) if gate else case

"""The scenario suite: every dataflow the stack can express, one registry.

Each :class:`SuiteCase` bundles a spec builder with the cache
configuration that puts it in the regime the paper studies (working set
vs. LLC capacity) and the policy-variant flag (gqa bypass for spatially
shared dataflows, §IV-E).  ``benchmarks/suite_bench.py`` sweeps the fig-4
policy set across this registry and cross-validates the simulator against
the analytical model; tests and future scenario PRs extend the registry
rather than writing new one-off builders.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from repro.core.simulator import SimConfig
from repro.core.workloads import (AttnWorkload, DecodeWorkload, MoEWorkload,
                                  PrefixShareWorkload, SpecDecodeWorkload,
                                  SSDScanWorkload, get_workload)

from .fa2 import fa2_spec, matmul_spec
from .ir import DataflowSpec
from .scenarios import (decode_paged_spec, mlp_chain_spec, moe_ffn_spec,
                        prefix_share_spec, spec_decode_spec,
                        ssd_scan_spec, transformer_layer_spec)

MB = 2 ** 20

#: the fig-4 policy set plus the DBP-bearing variants the new scenarios
#: exercise (fig-8 style)
SUITE_POLICIES: Tuple[str, ...] = ("lru", "at", "at+bypass", "at+dbp",
                                   "all")


@dataclass
class SuiteCase:
    key: str
    spec: DataflowSpec
    cfg: SimConfig
    gqa: bool = False
    #: scenarios where dead-block prediction must beat plain LRU
    expect_dbp_win: bool = False


def build_suite(full: bool = False, n_cores: int = 16) -> List[SuiteCase]:
    """Instantiate the whole suite (reduced grid by default, paper-scale
    shapes with ``full=True``)."""
    seq = 2048 if full else 1024
    cases: List[SuiteCase] = []

    # LLC sizes put each case in the paper's contended regime (working
    # set a small multiple of capacity) at the default reduced shapes
    wl_t = get_workload("gemma3-27b", seq_len=seq)
    cases.append(SuiteCase(
        "fa2-temporal", fa2_spec(wl_t, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=(4 if full else 2) * MB)))

    wl_s = get_workload("qwen3-8b", seq_len=seq)
    cases.append(SuiteCase(
        "fa2-spatial", fa2_spec(wl_s, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=(2 if full else 1) * MB),
        gqa=True))

    dim = 2048 if full else 1024
    cases.append(SuiteCase(
        "matmul", matmul_spec(dim, dim, dim, tile=128, n_cores=n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=1 * MB)))

    dec = DecodeWorkload(seq_len=4096 if full else 2048)
    cases.append(SuiteCase(
        "decode-paged", decode_paged_spec(dec, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=4 * MB),
        expect_dbp_win=True))

    moe = MoEWorkload(n_steps=12 if full else 8)
    cases.append(SuiteCase(
        "moe-ffn", moe_ffn_spec(moe, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=2 * MB),
        expect_dbp_win=True))

    spd = SpecDecodeWorkload(target_len=1024 if full else 512)
    cases.append(SuiteCase(
        "spec-decode", spec_decode_spec(spd, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=(8 if full else 4) * MB),
        expect_dbp_win=True))

    cases.append(SuiteCase(
        "mlp-chain",
        mlp_chain_spec(m=2048 if full else 1024, n_cores=n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=1 * MB)))

    wl_l = AttnWorkload("tl-8h", n_q_heads=8, n_kv_heads=4, head_dim=128,
                        seq_len=seq, group_alloc="temporal")
    cases.append(SuiteCase(
        "transformer-layer", transformer_layer_spec(wl_l, d_ff=1024,
                                                    n_cores=n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=2 * MB)))

    # one state generation is n_seqs × n_heads × P × N = 1.5 MB and
    # head slabs retire incrementally (a read slab dies as the matching
    # new slab is stored), so the live stack peaks at ~1 generation
    # (12288 lines): under a 2 MB LLC the live states fit once the
    # consumed slabs retire, while LRU drags them as MRU dead mass and
    # thrashes — the recurring chunk-cadence DBP win
    ssd = SSDScanWorkload(n_chunks=8 if full else 6)
    cases.append(SuiteCase(
        "ssd-scan", ssd_scan_spec(ssd, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=2 * MB),
        expect_dbp_win=True))

    # shared prefix 0.5 MB + 2 MB of private suffixes over a 1 MB LLC:
    # the private streams thrash while the co-streamed prefix is the
    # inter-core reuse blind bypassing would destroy (gqa variant on)
    pfx = PrefixShareWorkload(prefix_len=4096 if full else 2048)
    cases.append(SuiteCase(
        "prefix-share", prefix_share_spec(pfx, n_cores),
        SimConfig(n_cores=n_cores, llc_bytes=1 * MB),
        gqa=True))
    return cases


def suite_case(key: str, full: bool = False,
               n_cores: int = 16) -> SuiteCase:
    cases = build_suite(full=full, n_cores=n_cores)
    for case in cases:
        if case.key == key:
            return case
    raise KeyError(f"unknown suite scenario {key!r}; have "
                   f"{[c.key for c in cases]}")

"""Pluggable address-space layer (DESIGN.md §13).

Address assignment used to be an *implicit invariant* — "bases are
monotone, bump-allocated from ``1 << 30``" — replicated in the lowering
(`lower.assign_addresses`), both stream emitters, the event sink's
registration check, and the verifier's DCO211 rule.  PR 8's serving
replay showed why that matters: a bump allocator mints fresh addresses
forever, so the anti-thrashing ``tag[B_BITS-1:0]`` tiers decay with
replay length (at+dbp 1.25× at 96 requests → 0.67× at 1000).  Real
paged-KV servers recycle pages from a fixed pool (vLLM-style), which
keeps the tag map stationary.

This module makes the policy explicit: an :class:`AddressAllocator`
hands out :class:`Region`\\ s and (optionally) takes them back.  Two
implementations:

* :class:`BumpAllocator` — today's behavior, bit-identical to the
  historical ``lower._Allocator`` / ``StreamEmitter`` arithmetic
  (tile-aligned bump from ``1 << 30``; ``free`` is a no-op).  The
  pinned default: every existing spec, golden digest, and frozen
  oracle lays out byte-identically.
* :class:`PooledPageAllocator` — a fixed page pool with a sorted,
  coalescing free list.  ``free`` returns a region's pages
  immediately; ``alloc`` recycles first-fit at the lowest address.
  Deterministic: allocator state is a pure function of the
  alloc/free call sequence, so the monolithic and streaming replay
  emitters (which see the same declare/retire sequence from
  ``ReplayEngine.drive``) produce identical layouts.

Allocator contract for callers: ``free`` may only be called once the
region's final access round has been emitted (the replay engine retires
a request *after* its last decode round), so a recycled region's new
tensor is never accessed in the same round as its predecessor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict
from typing import List
from typing import Optional
from typing import Tuple

#: shared default base — away from address 0 so tag bits are
#: non-degenerate (matches the historical ``lower._Allocator``)
DEFAULT_BASE = 1 << 30

#: allocator registry names (``DataflowSpec.allocator`` tags)
BUMP = "bump"
POOLED = "pooled"
ALLOCATOR_NAMES = (BUMP, POOLED)


@dataclass(frozen=True)
class Region:
    """One allocated address range, as handed out by an allocator."""

    base: int
    size_bytes: int

    @property
    def end(self) -> int:
        return self.base + self.size_bytes


class AddressAllocator:
    """Protocol for address-space policies.

    ``name`` is the registry tag recorded on specs the allocator laid
    out (``DataflowSpec.allocator``); ``monotone`` states whether bases
    ascend in allocation order (the fact DCO211 checks — a
    BumpAllocator property, not an IR property).
    """

    name: str = "abstract"
    monotone: bool = False

    def alloc(self, size_bytes: int, tile_bytes: int, *,
              align: Optional[int] = None) -> Region:
        raise NotImplementedError

    def free(self, region: Region) -> None:
        raise NotImplementedError

    def stats(self) -> Dict[str, int]:
        return {}


class BumpAllocator(AddressAllocator):
    """Monotone bump allocation, tile-aligned, from ``base``.

    Bit-identical to the historical arithmetic: the aligned base is
    ``ceil(next / align) * align`` and ``next`` advances past the
    allocation.  ``free`` is a no-op — addresses are never reused, which
    is exactly the PR 8 tier-decay regime."""

    name = BUMP
    monotone = True

    def __init__(self, base: int = DEFAULT_BASE):
        self._base = base
        self._next = base

    def alloc(self, size_bytes: int, tile_bytes: int, *,
              align: Optional[int] = None) -> Region:
        if size_bytes <= 0 or tile_bytes <= 0:
            raise ValueError("alloc: sizes must be positive")
        a = align if align is not None else tile_bytes
        base = (self._next + a - 1) // a * a
        self._next = base + size_bytes
        return Region(base=base, size_bytes=size_bytes)

    def free(self, region: Region) -> None:  # noqa: ARG002 - by contract
        """No-op: bump allocation never reuses addresses."""

    def stats(self) -> Dict[str, int]:
        return {"allocated_bytes": self._next - self._base}


class PooledPageAllocator(AddressAllocator):
    """Fixed page pool with free-list recycling (vLLM-style).

    The pool is ``pool_pages`` pages of ``page_bytes`` starting at
    ``base``.  Allocations are rounded up to whole pages and placed
    first-fit at the lowest free address; frees return pages to a
    sorted, coalescing interval list immediately.  If no free interval
    fits, the pool grows deterministically past its configured top
    (``overflow_allocs`` counts these — a sizing signal, not an error),
    and overflowed pages recycle like any others once freed.

    ``free`` is idempotent-safe: freeing a region whose pages are
    already entirely free is a no-op; a *partial* overlap with the free
    list (a region that was never handed out, or a double free racing a
    reallocation) raises.
    """

    name = POOLED
    monotone = False

    def __init__(self, page_bytes: int = 2048, pool_pages: int = 2048,
                 base: int = DEFAULT_BASE):
        if page_bytes <= 0 or pool_pages <= 0:
            raise ValueError("pooled allocator: page/pool sizes "
                             "must be positive")
        if base % page_bytes:
            raise ValueError("pooled allocator: base must be "
                             "page-aligned")
        self.page_bytes = page_bytes
        self.pool_pages = pool_pages
        self._base = base
        self._pool_end = base + pool_pages * page_bytes
        self._top = self._pool_end          # grows only on overflow
        #: sorted, disjoint, coalesced free intervals [start, end)
        self._free: List[Tuple[int, int]] = [(base, self._pool_end)]
        self.overflow_allocs = 0
        self.n_allocs = 0
        self.n_frees = 0

    def _span(self, size_bytes: int) -> int:
        p = self.page_bytes
        return (size_bytes + p - 1) // p * p

    def alloc(self, size_bytes: int, tile_bytes: int, *,
              align: Optional[int] = None) -> Region:
        if size_bytes <= 0 or tile_bytes <= 0:
            raise ValueError("alloc: sizes must be positive")
        a = align if align is not None else tile_bytes
        if self.page_bytes % a:
            raise ValueError(
                f"pooled allocator: alignment {a} does not divide the "
                f"page size {self.page_bytes} (page-aligned bases could "
                f"violate it)")
        span = self._span(size_bytes)
        self.n_allocs += 1
        for i, (start, end) in enumerate(self._free):
            if end - start >= span:
                if end - start == span:
                    del self._free[i]
                else:
                    self._free[i] = (start + span, end)
                return Region(base=start, size_bytes=size_bytes)
        base = self._top
        self._top += span
        self.overflow_allocs += 1
        return Region(base=base, size_bytes=size_bytes)

    def free(self, region: Region) -> None:
        start = region.base
        end = start + self._span(region.size_bytes)
        if start % self.page_bytes or start < self._base or end > self._top:
            raise ValueError(
                f"free: region [0x{start:x}, 0x{end:x}) was never "
                f"handed out by this pool")
        self.n_frees += 1
        # locate the insertion point in the sorted interval list
        lo = 0
        hi = len(self._free)
        while lo < hi:
            mid = (lo + hi) // 2
            if self._free[mid][0] < start:
                lo = mid + 1
            else:
                hi = mid
        # idempotent no-op: the pages are already entirely free
        if lo > 0 and self._free[lo - 1][1] >= end:
            return
        if lo < len(self._free) and self._free[lo][0] == start \
                and self._free[lo][1] >= end:
            return
        # partial overlap with a free interval is a real double free
        if lo > 0 and self._free[lo - 1][1] > start:
            raise ValueError(
                f"free: [0x{start:x}, 0x{end:x}) partially overlaps the "
                f"free interval [0x{self._free[lo - 1][0]:x}, "
                f"0x{self._free[lo - 1][1]:x})")
        if lo < len(self._free) and self._free[lo][0] < end:
            raise ValueError(
                f"free: [0x{start:x}, 0x{end:x}) partially overlaps the "
                f"free interval [0x{self._free[lo][0]:x}, "
                f"0x{self._free[lo][1]:x})")
        # insert, coalescing with both neighbors
        if lo > 0 and self._free[lo - 1][1] == start:
            start = self._free[lo - 1][0]
            del self._free[lo - 1]
            lo -= 1
        if lo < len(self._free) and self._free[lo][0] == end:
            end = self._free[lo][1]
            del self._free[lo]
        self._free.insert(lo, (start, end))

    # ------------------------------------------------------------------
    def free_pages(self) -> int:
        return sum(e - s for s, e in self._free) // self.page_bytes

    def high_water_pages(self) -> int:
        """Peak footprint in pages, counting overflow growth."""
        return (self._top - self._base) // self.page_bytes

    def stats(self) -> Dict[str, int]:
        return {"pool_pages": self.pool_pages,
                "page_bytes": self.page_bytes,
                "n_allocs": self.n_allocs,
                "n_frees": self.n_frees,
                "overflow_allocs": self.overflow_allocs,
                "high_water_pages": self.high_water_pages(),
                "free_pages": self.free_pages()}


def make_allocator(name: str, *, page_bytes: int = 2048,
                   pool_pages: int = 2048,
                   base: int = DEFAULT_BASE) -> AddressAllocator:
    """Factory keyed by the registry tag (``ReplayConfig.allocator``)."""
    if name == BUMP:
        return BumpAllocator(base=base)
    if name == POOLED:
        return PooledPageAllocator(page_bytes=page_bytes,
                                   pool_pages=pool_pages, base=base)
    raise ValueError(f"unknown allocator {name!r} "
                     f"(expected one of {ALLOCATOR_NAMES})")

"""FlashAttention-2 GQA and tiled-MatMul dataflows, expressed on the IR.

These re-express the original hand-written trace builders (paper §VI-C
group allocations, Fig. 2(a) matmul) as :class:`DataflowSpec` builders.
``tests/test_dataflow_ir.py`` pins them bit-identical — tensor layout,
step schedules, simulator counters, and analytical counts — to the frozen
pre-refactor implementations in ``tests/_reference_builders.py``; the
public ``repro.core`` entry points (``build_fa2_trace`` etc.) are thin
wrappers over these specs.
"""

from __future__ import annotations

from typing import Dict
from typing import List
from typing import Tuple

from repro.core.workloads import AttnWorkload
from repro.core.workloads import TEMPORAL

from .ir import DataflowSpec
from .ir import SpecBuilder


def _kv_extent(wl: AttnWorkload, q_tile: int) -> int:
    if not wl.causal:
        return wl.n_kv_tiles
    return min(q_tile + 1, wl.n_kv_tiles)


def _decl_kv(b: SpecBuilder, wl: AttnWorkload, batch: int, head: int,
             n_acc: int, sharers: int) -> Tuple[str, str]:
    size = wl.seq_len * wl.head_dim * wl.dtype_bytes
    names = []
    for kind in ("K", "V"):
        names.append(b.tensor(
            f"{kind}.b{batch}.g{head}", size_bytes=size,
            tile_bytes=wl.kv_tile_bytes, n_acc=n_acc, operand_id=1,
            epoch=batch, sharers=sharers))
    return names[0], names[1]


def _decl_qo(b: SpecBuilder, wl: AttnWorkload, kind: str, batch: int,
             head: int, operand_id: int) -> str:
    size = wl.seq_len * wl.head_dim * wl.dtype_bytes
    return b.tensor(f"{kind}.b{batch}.h{head}", size_bytes=size,
                    tile_bytes=wl.q_tile_bytes, n_acc=1,
                    operand_id=operand_id, bypass=True, epoch=batch)


def fa2_spec(wl: AttnWorkload, n_cores: int = 16) -> DataflowSpec:
    """FlashAttention-2 GQA dataflow (temporal or spatial group
    allocation, §VI-C), optionally multi-batch (§VI-F)."""
    if wl.group_alloc == TEMPORAL:
        return _fa2_temporal_spec(wl, n_cores)
    return _fa2_spatial_spec(wl, n_cores)


def _fa2_temporal_spec(wl: AttnWorkload, n_cores: int) -> DataflowSpec:
    """Group dimension entirely in the time domain: each KV-head group is
    owned by one core; assigned groups interleave at Q-tile granularity so
    every live head's K/V stream stays concurrent (the long-reuse-distance
    thrashing regime); batches are strictly sequential (§VI-F)."""
    b = SpecBuilder(f"{wl.name}-temporal", n_cores, workload=wl)
    n_acc = wl.n_q_tiles
    per_core: List[List[Tuple[int, int]]] = [[] for _ in range(n_cores)]
    for bt in range(wl.n_batches):
        for g in range(wl.n_kv_heads):
            per_core[g % n_cores].append((bt, g))

    for c in range(n_cores):
        items = []
        for (bt, g) in per_core[c]:
            kv = _decl_kv(b, wl, bt, g, n_acc, sharers=1)
            q_names, o_names = [], []
            for m in range(wl.group_size):
                h = g * wl.group_size + m
                q_names.append(_decl_qo(b, wl, "Q", bt, h, operand_id=0))
                o_names.append(_decl_qo(b, wl, "O", bt, h, operand_id=2))
            items.append((bt, kv, q_names, o_names))

        half = wl.flops_per_inner_step() * wl.group_size / 2
        for bt in range(wl.n_batches):
            batch_items = [it for it in items if it[0] == bt]
            for i in range(wl.n_q_tiles):
                for (_, kv, q_names, o_names) in batch_items:
                    b.step(c, loads=[(q, i) for q in q_names])
                    for j in range(_kv_extent(wl, i)):
                        # FA2 inner iteration: K tile → QK^T, V tile → PV
                        b.step(c, loads=[(kv[0], j)], flops=half)
                        b.step(c, loads=[(kv[1], j)], flops=half)
                    b.step(c, stores=[(o, i) for o in o_names])
    return b.build()


def _fa2_spatial_spec(wl: AttnWorkload, n_cores: int) -> DataflowSpec:
    """Group dimension (partially) across cores: group members stream the
    same K/V in lockstep (same-round requests merge in the MSHRs) except
    the last rank, which lags one round so its reuses ride LLC *storage*
    — the population blind bypassing destroys (§IV-E)."""
    b = SpecBuilder(f"{wl.name}-spatial", n_cores, workload=wl)
    gs = wl.group_size
    sharers = min(gs, n_cores)
    # every group member reads each K/V tile once per Q tile; when the
    # group is wider than the machine the extra members run in later
    # waves on the same cores, so reads scale with gs, not sharers
    # (declaring n_acc from sharers understated it 'gs/n_cores'-fold and
    # retired tiles with readers remaining — caught by DCO101)
    n_acc = wl.n_q_tiles * gs
    n_waves = (wl.n_q_heads + n_cores - 1) // n_cores
    b.set_groups(
        [c // gs if gs <= n_cores else 0 for c in range(n_cores)],
        [(c % gs != gs - 1) if gs <= n_cores else (c != n_cores - 1)
         for c in range(n_cores)])

    kv_names: Dict[Tuple[int, int], Tuple[str, str]] = {}
    for bt in range(wl.n_batches):
        for g in range(wl.n_kv_heads):
            kv_names[(bt, g)] = _decl_kv(b, wl, bt, g, n_acc, sharers)
    qo_names: Dict[Tuple[int, int], Tuple[str, str]] = {}
    for bt in range(wl.n_batches):
        for h in range(wl.n_q_heads):
            qo_names[(bt, h)] = (_decl_qo(b, wl, "Q", bt, h, operand_id=0),
                                 _decl_qo(b, wl, "O", bt, h, operand_id=2))

    half = wl.flops_per_inner_step() / 2
    for bt in range(wl.n_batches):
        for i in range(wl.n_q_tiles):
            kv_hi = _kv_extent(wl, i)
            for w in range(n_waves):
                for c in range(n_cores):
                    h = w * n_cores + c
                    if h >= wl.n_q_heads:
                        b.pad(c, 2 * kv_hi + 2)   # idle wave slot, lockstep
                        continue
                    kv = kv_names[(bt, h // gs)]
                    q, o = qo_names[(bt, h)]
                    rank = (h % gs) if gs <= n_cores else c
                    last_rank = (gs - 1) if gs <= n_cores else (n_cores - 1)
                    lag = 1 if rank == last_rank else 0
                    b.step(c, loads=[(q, i)])
                    for jj in range(kv_hi):
                        j = (jj - lag) % kv_hi
                        b.step(c, loads=[(kv[0], j)], flops=half)
                        b.step(c, loads=[(kv[1], j)], flops=half)
                    b.step(c, stores=[(o, i)])
    return b.build()


# ---------------------------------------------------------------------------
def matmul_spec(m: int, n: int, k: int, tile: int = 128,
                n_cores: int = 16, dtype_bytes: int = 1) -> DataflowSpec:
    """C[M,N] = A[M,K] @ B[K,N] of Fig. 2(a), C-tiles round-robin over
    cores; nAcc registered at the dataflow level as the paper does."""
    if m % tile or n % tile or k % tile:
        raise ValueError("dims must be tile-aligned")
    mt, nt, kt = m // tile, n // tile, k // tile
    tile_bytes = tile * tile * dtype_bytes
    b = SpecBuilder(f"matmul-{m}x{n}x{k}", n_cores)
    A = b.tensor("A", size_bytes=mt * kt * tile_bytes,
                 tile_bytes=tile_bytes, n_acc=nt, operand_id=0)
    B = b.tensor("B", size_bytes=kt * nt * tile_bytes,
                 tile_bytes=tile_bytes, n_acc=mt, operand_id=1)
    C = b.tensor("C", size_bytes=mt * nt * tile_bytes,
                 tile_bytes=tile_bytes, n_acc=1, operand_id=2, bypass=True)
    emit_matmul_rounds(b, A, B, C, mt, kt, nt,
                       2.0 * tile * tile * tile)
    return b.build()


def emit_matmul_rounds(b: SpecBuilder, A: str, B_: str, C: str,
                       mt: int, kt: int, nt: int, flops: float) -> None:
    """Emit one tiled matmul's rounds (C-tiles round-robin over cores) —
    shared by ``matmul_spec`` and the multi-op scenario builders."""
    for idx, (i, j) in enumerate((i, j) for i in range(mt)
                                 for j in range(nt)):
        core = idx % b.n_cores
        for kk in range(kt):
            b.step(core, loads=[(A, i * kt + kk), (B_, kk * nt + j)],
                   flops=flops)
        b.step(core, stores=[(C, i * nt + j)])

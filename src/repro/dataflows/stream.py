"""Generator-driven chunked lowering for serving replay (DESIGN.md §11).

The suite path materializes a whole :class:`~repro.dataflows.ir.DataflowSpec`,
lowers it to a :class:`~repro.core.traces.Trace`, and compiles that — all
O(total rounds) memory.  Traffic-scale replay (10⁵–10⁶ requests) cannot
afford any of those materializations, so this module provides the
streaming twin: an *emitter* interface that the serve engine's
admit/retire loop drives round by round, producing
:class:`ReplaySegment` windows (a :class:`~repro.core.traces.CompiledTrace`
plus incremental TMU registrations/retirements and seen-bitmap recycling
directives) that :meth:`repro.core.simulator.Simulator.run_stream`
consumes with bounded memory.

Two emitters implement the same protocol so the replay driver is written
once and the bit-identity property (streamed == monolithic) is testable:

* :class:`SpecEmitter` accumulates everything into one ``DataflowSpec``
  — the reference path, feeding the ordinary suite lowerings (trace,
  counts, reuse profile) for small seeds;
* :class:`StreamEmitter` buffers at most ``chunk_lines`` pre-merge line
  requests of rounds, then flushes a window ``CompiledTrace`` built via
  ``CompiledTrace.build(..., dense_map=...)``.

Bit-identity rests on three invariants:

1. **Addresses** — both emitters drive the *same*
   :class:`~repro.dataflows.addr.AddressAllocator` policy over the same
   declare/retire call sequence; allocator state is a pure function of
   that sequence, so the layouts agree by construction.  The default
   :class:`~repro.dataflows.addr.BumpAllocator` reproduces
   :func:`repro.dataflows.lower.assign_addresses` bit-exactly
   (tile-aligned from ``1 << 30``, declaration order); a
   :class:`~repro.dataflows.addr.PooledPageAllocator` recycles retired
   regions identically on both paths (the monolithic emitter bakes the
   resulting bases into the spec via ``TensorSpec.base`` so every
   lowering reproduces them).  Tensor ids are declaration indices.
   Identical addresses ⇒ identical set/tag mapping, MSHR merges, and
   eviction interleaving.  Allocator contract: ``retire`` is only
   called after the round holding the tensor's final access has been
   emitted, so a recycled region's new tensor is never co-accessed
   with its predecessor in one round.
2. **Seen-bitmap recycling** — the monolithic layout gives every tensor
   its own dense range forever; the stream recycles a retired tensor's
   range through a size-keyed free list, but only after a *flush
   boundary* (a quarantine holds ranges freed mid-window), and each
   recycled range is zeroed (``seen_resets``) before the window that
   reuses it.  A fresh tensor therefore observes exactly the cold
   misses it would have observed with a private range, while the bitmap
   stays O(live working set) instead of O(every tensor ever declared).
3. **Exact nAcc** — the replay driver declares true access counts, so
   every tile self-retires from the TMU's live table before ``clear``
   is issued; the incremental register/clear calls are then invisible
   to the simulated cache state (the compiled engine never consults
   tensor metadata on the access path).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict
from typing import List
from typing import Optional
from typing import Sequence
from typing import Tuple

from repro.core.tmu import TensorMeta
from repro.core.traces import CompiledTrace
from repro.core.traces import Step
from repro.core.traces import Trace

from .addr import AddressAllocator
from .addr import BumpAllocator
from .addr import Region
from .ir import DataflowSpec
from .ir import LINE_BYTES
from .ir import SpecBuilder

#: default flush budget: pre-merge line requests buffered per window
DEFAULT_CHUNK_LINES = 1 << 18

#: one core's contribution to a round: (core, loads, stores, flops) with
#: loads/stores as sequences of (tensor_name, tile_index)
RoundStep = Tuple[int, Sequence[Tuple[str, int]],
                  Sequence[Tuple[str, int]], float]


@dataclass
class ReplaySegment:
    """One flushed window of the streamed lowering.

    ``Simulator.run_stream`` applies the fields in order: grow the seen
    bitmap to ``n_seen_lines``, zero the ``seen_resets`` ranges, register
    ``new_tensors`` with the TMU (+ event sink), consume ``ct``'s rounds,
    then clear ``clear_tids``.
    """

    ct: CompiledTrace
    new_tensors: List[TensorMeta]
    seen_resets: List[Tuple[int, int]]     # [start, stop) dense-line ranges
    clear_tids: List[int]
    n_seen_lines: int


class SpecEmitter:
    """Reference emitter: accumulate the whole replay into one spec.

    Keeps every tensor and every round, so it is only usable for small
    seeds — exactly the regime where the bit-identity property and the
    suite/conformance registrations need a monolithic
    :class:`DataflowSpec` with reuse-profile epochs intact.
    """

    def __init__(self, name: str, n_cores: int,
                 line_bytes: int = LINE_BYTES,
                 allocator: Optional[AddressAllocator] = None):
        self._b = SpecBuilder(name, n_cores, line_bytes=line_bytes)
        self._n_cores = n_cores
        # with no allocator the spec keeps implicit bases and the
        # lowering's default bump allocation lays it out (the historical
        # byte-identical path); an explicit allocator is run here and
        # its bases are baked into the spec (``TensorSpec.base``)
        self.allocator = allocator
        self._regions: Dict[str, Region] = {}
        if allocator is not None:
            self._b.allocator = allocator.name
        self.rounds = 0

    def declare(self, name: str, *, size_bytes: int, tile_bytes: int,
                n_acc: int, bypass: bool = False, sharers: int = 1,
                epoch: Tuple[int, int] = (0, 0)) -> None:
        base = None
        if self.allocator is not None:
            region = self.allocator.alloc(size_bytes, tile_bytes)
            self._regions[name] = region
            base = region.base
        self._b.tensor(name, size_bytes=size_bytes, tile_bytes=tile_bytes,
                       n_acc=n_acc, bypass=bypass, sharers=sharers,
                       epoch=epoch, base=base)

    def emit_round(self, steps: Sequence[RoundStep]
                   ) -> Optional[ReplaySegment]:
        present = set()
        for core, loads, stores, flops in steps:
            self._b.step(core, loads=list(loads), stores=list(stores),
                         flops=flops)
            present.add(core)
        for core in range(self._n_cores):
            if core not in present:
                self._b.pad(core, 1)
        self.rounds += 1
        return None

    def retire(self, name: str) -> None:
        """Return the tensor's region to the allocator (immediately: the
        driver only retires after the final access round is emitted, so
        a recycled region is never co-accessed with its predecessor).
        Without an explicit allocator this is a no-op — the monolithic
        bump layout never recycles."""
        region = self._regions.pop(name, None)
        if region is not None and self.allocator is not None:
            self.allocator.free(region)

    def finish(self) -> Optional[ReplaySegment]:
        return None

    def build(self) -> DataflowSpec:
        return self._b.build()


@dataclass
class _LiveTensor:
    tid: int
    meta: TensorMeta
    dense_off: int
    n_lines: int
    region: Region


class StreamEmitter:
    """Chunked emitter: flush ``CompiledTrace`` windows on the fly.

    Peak memory is the window buffer (≤ ``chunk_lines`` pre-merge line
    requests of Python ``Step`` rows plus one compiled window) plus the
    recycled seen bitmap (``peak_seen_lines`` lines, O(live working
    set)) — independent of total round count.
    """

    def __init__(self, name: str, n_cores: int, *,
                 chunk_lines: int = DEFAULT_CHUNK_LINES,
                 line_bytes: int = LINE_BYTES,
                 allocator: Optional[AddressAllocator] = None):
        if chunk_lines <= 0:
            raise ValueError("chunk_lines must be positive")
        self.name = name
        self.n_cores = n_cores
        self.chunk_lines = chunk_lines
        self.line_bytes = line_bytes
        # the address-space policy (module docstring, invariant 1);
        # the default BumpAllocator reproduces the monolithic lowering's
        # layout bit-exactly
        self.allocator = allocator if allocator is not None \
            else BumpAllocator()
        self._next_tid = 0
        self._live: Dict[str, _LiveTensor] = {}
        # window state -------------------------------------------------
        self._buf: List[List[Step]] = [[] for _ in range(n_cores)]
        self._buf_lines = 0
        self._window_metas: Dict[int, TensorMeta] = {}   # live + retired
        self._window_dense: Dict[int, int] = {}
        self._new: List[TensorMeta] = []
        self._clears: List[int] = []
        self._resets: List[Tuple[int, int]] = []
        # dense seen-bitmap allocator (invariant 2) --------------------
        self._free: Dict[int, List[int]] = {}
        self._quarantine: List[Tuple[int, int]] = []     # (n_lines, off)
        self._dense_top = 0
        # observability ------------------------------------------------
        self.rounds = 0
        self.segments = 0
        self.peak_seen_lines = 0
        self.total_lines_declared = 0

    # -- protocol -------------------------------------------------------
    def declare(self, name: str, *, size_bytes: int, tile_bytes: int,
                n_acc: int, bypass: bool = False, sharers: int = 1,
                epoch: Tuple[int, int] = (0, 0)) -> None:
        if name in self._live:
            raise ValueError(f"tensor {name!r} already live")
        if size_bytes <= 0 or tile_bytes <= 0:
            raise ValueError(
                f"{self.name}: tensor {name!r} sizes must be positive "
                f"(size={size_bytes}, tile={tile_bytes})")
        if size_bytes % tile_bytes:
            raise ValueError(
                f"{self.name}: tensor {name!r} size {size_bytes} not a "
                f"multiple of tile {tile_bytes}")
        if tile_bytes % self.line_bytes:
            raise ValueError(
                f"{self.name}: tensor {name!r} tile {tile_bytes} not a "
                f"multiple of line {self.line_bytes}")
        region = self.allocator.alloc(size_bytes, tile_bytes)
        base = region.base
        tid = self._next_tid
        self._next_tid += 1
        n_lines = size_bytes // self.line_bytes
        bucket = self._free.get(n_lines)
        if bucket:
            off = bucket.pop()
            self._resets.append((off, off + n_lines))
        else:
            off = self._dense_top
            self._dense_top += n_lines
            self.peak_seen_lines = max(self.peak_seen_lines,
                                       self._dense_top)
        meta = TensorMeta(tensor_id=tid, base_addr=base,
                          size_bytes=size_bytes, tile_bytes=tile_bytes,
                          n_acc=n_acc, bypass_all=bypass)
        lt = _LiveTensor(tid=tid, meta=meta, dense_off=off,
                         n_lines=n_lines, region=region)
        self._live[name] = lt
        self._window_metas[tid] = meta
        self._window_dense[tid] = off
        self._new.append(meta)
        self.total_lines_declared += n_lines

    def emit_round(self, steps: Sequence[RoundStep]
                   ) -> Optional[ReplaySegment]:
        lb = self.line_bytes
        present = set()
        for core, loads, stores, flops in steps:
            l_ids = []
            for nm, tile in loads:
                lt = self._live[nm]
                l_ids.append((lt.tid, tile))
                self._buf_lines += lt.meta.tile_bytes // lb
            s_ids = []
            for nm, tile in stores:
                lt = self._live[nm]
                s_ids.append((lt.tid, tile))
                self._buf_lines += lt.meta.tile_bytes // lb
            self._buf[core].append(Step(loads=l_ids, stores=s_ids,
                                        flops=flops))
            present.add(core)
        for core in range(self.n_cores):
            if core not in present:
                self._buf[core].append(Step())
        self.rounds += 1
        if self._buf_lines >= self.chunk_lines:
            return self._flush()
        return None

    def retire(self, name: str) -> None:
        """Mark a tensor finished: its TMU entry is cleared after the
        window holding its final rounds, its seen range becomes
        recyclable at the next flush boundary (never within the window
        that still references it), and its address region returns to
        the allocator immediately (safe by the retire-after-last-access
        contract; a no-op under bump allocation)."""
        lt = self._live.pop(name)
        self._clears.append(lt.tid)
        self._quarantine.append((lt.n_lines, lt.dense_off))
        self.allocator.free(lt.region)

    def finish(self) -> Optional[ReplaySegment]:
        """Flush whatever remains (possibly a round-less trailer that
        only carries clears)."""
        if (self.rounds and any(self._buf)) or self._new or self._clears:
            return self._flush()
        return None

    # -- internals ------------------------------------------------------
    def _flush(self) -> ReplaySegment:
        trace = Trace(
            name=f"{self.name}@{self.segments}",
            tensors=dict(self._window_metas),
            core_steps=[list(b) for b in self._buf],
            core_group=[-1] * self.n_cores,
            core_is_leader=[True] * self.n_cores,
            line_bytes=self.line_bytes)
        ct = CompiledTrace.build(trace, self.line_bytes,
                                 dense_map=dict(self._window_dense),
                                 n_seen_lines=self._dense_top)
        seg = ReplaySegment(ct=ct, new_tensors=self._new,
                            seen_resets=self._resets,
                            clear_tids=self._clears,
                            n_seen_lines=self._dense_top)
        # reset the window; retired tensors leave the meta tables and
        # their quarantined ranges become recyclable
        for tid in self._clears:
            del self._window_metas[tid]
            del self._window_dense[tid]
        for n_lines, off in self._quarantine:
            self._free.setdefault(n_lines, []).append(off)
        self._quarantine = []
        self._buf = [[] for _ in range(self.n_cores)]
        self._buf_lines = 0
        self._new = []
        self._clears = []
        self._resets = []
        self.segments += 1
        return seg

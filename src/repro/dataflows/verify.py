"""Static verifier over the dataflow IR (DESIGN.md §12).

Every DCO policy decision — dead-block prediction, bypass,
anti-thrashing tiers — trusts the annotations the compiler hands the TMU
(``n_acc``, epoch ranges, sharer counts, bypass hints) and the address
layout the lowerings derive.  Nothing on the simulation path verifies
either: a stale ``n_acc`` silently becomes a premature retirement, and a
bump allocator that mints fresh addresses forever silently aliases
``tag[B_BITS-1:0]`` priority tiers across tensor generations (the PR 8
at+dbp decay, 1.25× → 0.67×).

This module is the missing check: :func:`verify_spec` walks a
:class:`~repro.dataflows.ir.DataflowSpec` once and emits structured
:class:`Diagnostic` records — stable ``DCOxxx`` codes, severity
error/warn/info, tensor/core/round location — instead of asserts.  The
rule inventory (:data:`RULES`) is the single place an assumption is
written down next to the lowering or policy that consumes it.

Severity calibration is empirical: a rule is error-tier only if every
registered suite scenario satisfies it exactly (so a violation is a real
defect, not a modeling choice).  The registry's measured behaviour:

* per-tile load counts equal ``n_acc`` exactly on every scenario —
  ``DCO101``/``DCO102`` are errors;
* declared ``sharers`` legitimately *understate* cross-core touches
  (temporal-reuse accounting on matmul/mlp-chain/…) — only the
  over-declared direction (``DCO110``) is an error, the forfeited
  same-round merge is an info lint (``DCO303``);
* tensors with disjoint epoch ranges legitimately overlap in time under
  continuous batching (serve-replay waves) — ``DCO120`` is a warning;
* tier/dead-id aliasing across generations is *present* in the registry
  (spec-decode, mt-spec-ssd, serve-replay — the PR 8 decay exhibit) —
  ``DCO201``/``DCO202`` are warnings that document it.

Three consumers: ``SpecBuilder.build()`` / ``suite_case()`` gate the
error tier on every spec entering the registry (:func:`assert_clean`),
:class:`StreamVerifier` is the opt-in online mode for the streaming
replay (``run_replay(..., verify=True)``), and ``scripts/spec_lint.py``
sweeps the registry from the command line.  :func:`cross_check_case`
closes the loop against ground truth: the analyzer's predicted TMU
retirement counts must match the simulator's measured ``RETIRE`` events.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from dataclasses import field
from typing import Dict
from typing import List
from typing import Optional
from typing import Sequence
from typing import TYPE_CHECKING
from typing import Tuple

from repro.core.tmu import TMUParams
from repro.core.tmu import TensorMeta

from .ir import DataflowSpec

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (suite -> verify)
    from .stream import ReplaySegment
    from .suite import SuiteCase

ERROR = "error"
WARN = "warn"
INFO = "info"

_SEV_ORDER = {ERROR: 0, WARN: 1, INFO: 2}

#: runaway guard on per-rule diagnostics per spec — high enough that
#: every per-tensor diagnostic of the registry (and any injected one)
#: survives; rendering layers summarize, the result stays complete
MAX_DIAGS_PER_RULE = 4096


@dataclass(frozen=True)
class Rule:
    """One entry of the rule inventory: the assumption a code checks and
    the lowering/policy that consumes the assumption (DESIGN.md §12)."""

    code: str
    severity: str
    title: str
    assumption: str
    consumer: str
    #: which address-space strategies the rule applies to: "any", or a
    #: specific allocator name ("bump" for the monotone-base invariant —
    #: a recycling allocator legitimately re-issues lower addresses)
    allocator: str = "any"


#: the rule inventory — single source of truth for codes, severities, and
#: the assumption → consumer mapping (rendered by ``spec_lint.py --rules``
#: and documented in DESIGN.md §12)
RULES: Dict[str, Rule] = {r.code: r for r in [
    # -- DCO0xx: structural well-formedness (folded from the historical
    #    DataflowSpec.validate asserts; validate() now raises on these) --
    Rule("DCO001", ERROR, "duplicate tensor names",
         "tensor names are unique (name = identity for schedule refs)",
         "every lowering; TMU metadata slots"),
    Rule("DCO002", ERROR, "core annotation length mismatch",
         "core_group/core_is_leader cover every core program",
         "lower_to_trace; gqa bypass grouping"),
    Rule("DCO003", ERROR, "unknown tensor reference",
         "schedule steps reference declared tensors only",
         "every lowering"),
    Rule("DCO004", ERROR, "tile index out of range",
         "every (tensor, tile) access lies inside the tensor",
         "lower_to_trace; TMU tile table"),
    Rule("DCO005", ERROR, "invalid tenant mapping",
         "tenant map covers every tensor with a valid tenant id",
         "per-tenant attribution (simulator counters, profile masses)"),
    Rule("DCO006", ERROR, "tenant declarations not contiguous",
         "each tenant is one contiguous run of the declaration order",
         "shared allocator region map; tenant_region_starts"),
    Rule("DCO007", ERROR, "non-positive n_acc",
         "n_acc >= 1 (the TMU retires at accCnt >= nAcc; 0 retires on "
         "first touch)",
         "TMU retirement; reuse-profile dead/live split"),
    Rule("DCO008", ERROR, "tile not a multiple of the line size",
         "every cache line belongs to exactly one tile",
         "TLL tile-last-line resolution; dead-id tag math"),
    # -- DCO1xx: annotation consistency vs the schedule ------------------
    Rule("DCO101", ERROR, "n_acc understated",
         "declared n_acc >= actual per-tile read count (else the tile "
         "retires while readers remain: guaranteed dead-block mispredict)",
         "TMU retirement -> DBP dead-FIFO; reuse-profile dead split"),
    Rule("DCO102", ERROR, "n_acc overstated",
         "some loaded tile reaches the declared n_acc (else no tile "
         "ever retires: dead lines are never predicted dead)",
         "TMU retirement -> DBP; analytical dead-mass terms"),
    Rule("DCO104", WARN, "n_acc overstated on boundary tiles",
         "per-tensor n_acc matches the per-tile read count everywhere; "
         "a shortfall on a strict subset (e.g. the causal-mask boundary) "
         "is conservative — those tiles never retire, but nothing "
         "retires early",
         "DBP coverage (unretired boundary tiles stay LRU-managed)"),
    Rule("DCO103", INFO, "store-only tensor",
         "a written-never-read tensor has no TLL feed, so n_acc is "
         "unverifiable and its lines leave the LLC only by eviction",
         "TMU (no retirement); write-back dirty-lifetime model"),
    Rule("DCO110", ERROR, "sharers exceed observed cores",
         "declared sharers <= cores that ever touch the tensor (the "
         "counts lowering credits inter-core reuse that cannot occur)",
         "lower_to_counts inter-core split; profile sharer transform"),
    Rule("DCO120", WARN, "epoch-disjoint tensors concurrently live",
         "tensors with disjoint epoch ranges are not accessed in "
         "overlapping round windows (epoch = the liveness generation "
         "the capacity model stacks)",
         "lower_to_counts s_active; analytical live-stack peak"),
    # -- DCO2xx: layout hazards ------------------------------------------
    Rule("DCO201", WARN, "dead-id region mixes epoch generations",
         "no tag[D_MSB:D_LSB] dead-id region spans tensors of different "
         "epoch ranges (a retirement in one generation marks another "
         "generation's lines dead)",
         "DBP dead-FIFO is_dead match"),
    Rule("DCO202", WARN, "priority-tier aliasing across generations",
         "tag[B_BITS-1:0] tiers keep their liveness correlation: "
         "disjoint-epoch tensors do not reuse the same tier values "
         "(the PR 8 bump-allocator at+dbp decay)",
         "anti-thrashing tier protection (at)"),
    Rule("DCO210", ERROR, "tensor address regions overlap",
         "assigned [base, end) ranges are disjoint among concurrently-"
         "live tensors (a recycling allocator may reuse a range only "
         "after its previous owner's last access)",
         "every address-level consumer; event attribution"),
    Rule("DCO211", ERROR, "base addresses not monotone",
         "declaration order = ascending base order (bump allocation)",
         "EventSink.register_tensors; StreamEmitter recycling",
         allocator="bump"),
    Rule("DCO212", ERROR, "tenant region misaligned",
         "each tenant's first tensor is aligned to tenant_region_align "
         "so no dead-id tag region straddles two tenants",
         "per-tenant event attribution; dead-id isolation (§8.4)"),
    # -- DCO3xx: policy-contradiction lints ------------------------------
    Rule("DCO301", WARN, "bypass tensor with derived reuse",
         "bypass-hinted tensors are single-touch streams (re-reads or "
         "same-round co-streams through DRAM forfeit LLC reuse)",
         "bypass policy (§V-C); gqa_bypass sharing protection"),
    Rule("DCO302", WARN, "shared tensor declared single-read",
         "n_acc == 1 with sharers > 1 is contradictory: the first "
         "sharer's read retires the tile before the others stream it",
         "TMU retirement vs counts-lowering inter-core reuse"),
    Rule("DCO303", INFO, "same-round co-stream wider than sharers",
         "declared sharers cover the same-round co-stream width (an "
         "understated count forfeits MSHR-merge credit in the model)",
         "lower_to_counts inter-core split; MSHR merge accounting"),
]}

#: codes whose violation invalidates the spec (gate tier for
#: SpecBuilder.build / suite_case / compose)
ERROR_CODES: Tuple[str, ...] = tuple(
    code for code, r in RULES.items() if r.severity == ERROR)


@dataclass(frozen=True)
class Diagnostic:
    """One finding: a stable code, a severity, a location, a message."""

    code: str
    severity: str
    spec: str
    message: str
    tensor: Optional[str] = None
    core: Optional[int] = None
    round: Optional[int] = None

    def format(self) -> str:
        loc = [self.spec]
        if self.tensor is not None:
            loc.append(self.tensor)
        if self.core is not None:
            loc.append(f"core {self.core}")
        if self.round is not None:
            loc.append(f"round {self.round}")
        return (f"{self.code} [{self.severity}] "
                f"{'/'.join(str(x) for x in loc)}: {self.message}")

    def to_dict(self) -> Dict[str, object]:
        return {"code": self.code, "severity": self.severity,
                "spec": self.spec, "tensor": self.tensor,
                "core": self.core, "round": self.round,
                "message": self.message}


@dataclass
class VerifyResult:
    """All diagnostics of one verification pass, error tier first."""

    spec_name: str
    diagnostics: List[Diagnostic] = field(default_factory=list)
    #: uncapped per-rule fire counts (``diagnostics`` stores at most
    #: MAX_DIAGS_PER_RULE per code; gates that compare counts across
    #: allocators — the replay-scale DCO202 check — need the real total)
    rule_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARN]

    @property
    def has_errors(self) -> bool:
        return any(d.severity == ERROR for d in self.diagnostics)

    def codes(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.diagnostics:
            out[d.code] = out.get(d.code, 0) + 1
        return out

    def count(self, code: str) -> int:
        """Uncapped fire count for ``code`` (falls back to the stored-
        diagnostic tally when the pass predates the counter)."""
        if code in self.rule_counts:
            return self.rule_counts[code]
        return sum(1 for d in self.diagnostics if d.code == code)

    def by_code(self, code: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.code == code]

    def located(self, code: str, tensor: str) -> bool:
        """True if ``code`` fired at ``tensor`` (the injection-detection
        predicate: a corruption is caught when its expected code appears
        at the corrupted tensor)."""
        return any(d.code == code and d.tensor == tensor
                   for d in self.diagnostics)

    def sort(self) -> None:
        self.diagnostics.sort(
            key=lambda d: (_SEV_ORDER[d.severity], d.code,
                           d.tensor or "", d.round or -1))

    def summary(self) -> str:
        n_e = len(self.errors)
        n_w = len(self.warnings)
        n_i = len(self.diagnostics) - n_e - n_w
        codes = ",".join(f"{c}x{n}" for c, n in sorted(self.codes().items()))
        return (f"{self.spec_name}: {n_e} error(s), {n_w} warning(s), "
                f"{n_i} info ({codes or 'clean'})")

    def to_dict(self) -> Dict[str, object]:
        return {"spec": self.spec_name,
                "counts": {"error": len(self.errors),
                           "warn": len(self.warnings),
                           "info": (len(self.diagnostics)
                                    - len(self.errors)
                                    - len(self.warnings))},
                "codes": self.codes(),
                "diagnostics": [d.to_dict() for d in self.diagnostics]}


class SpecVerifyError(ValueError):
    """Raised by :func:`assert_clean` when error-tier rules fire; carries
    the full :class:`VerifyResult` for callers that want the details."""

    def __init__(self, result: VerifyResult):
        self.result = result
        errs = result.errors
        head = "; ".join(d.format() for d in errs[:3])
        more = f" (+{len(errs) - 3} more)" if len(errs) > 3 else ""
        super().__init__(
            f"spec {result.spec_name!r} failed verification with "
            f"{len(errs)} error(s): {head}{more}")


class _Emitter:
    """Per-rule capped diagnostic collector."""

    def __init__(self, spec_name: str):
        self.spec_name = spec_name
        self.diags: List[Diagnostic] = []
        self._per_rule: Dict[str, int] = defaultdict(int)

    def emit(self, code: str, message: str, *, tensor: Optional[str] = None,
             core: Optional[int] = None,
             round: Optional[int] = None) -> None:
        n = self._per_rule[code]
        self._per_rule[code] = n + 1
        if n >= MAX_DIAGS_PER_RULE:
            return
        if n == MAX_DIAGS_PER_RULE - 1:
            message += f" [further {code} diagnostics suppressed]"
        self.diags.append(Diagnostic(
            code=code, severity=RULES[code].severity, spec=self.spec_name,
            message=message, tensor=tensor, core=core, round=round))


# ---------------------------------------------------------------------------
# schedule-derived facts (one walk, shared by the rule families)
# ---------------------------------------------------------------------------
@dataclass
class _ScheduleFacts:
    loads: Dict[Tuple[str, int], int]           # (tensor, tile) -> reads
    first_round: Dict[str, int]
    last_round: Dict[str, int]
    cores: Dict[str, set]
    co_width: Dict[str, int]        # max cores loading one tile same round
    loaded: set
    stored: set


def _walk_schedule(spec: DataflowSpec) -> _ScheduleFacts:
    loads: Dict[Tuple[str, int], int] = defaultdict(int)
    first: Dict[str, int] = {}
    last: Dict[str, int] = {}
    cores: Dict[str, set] = defaultdict(set)
    co_width: Dict[str, int] = defaultdict(int)
    loaded: set = set()
    stored: set = set()
    round_cores: Dict[Tuple[int, str, int], set] = defaultdict(set)
    for c, prog in enumerate(spec.core_programs):
        for r, step in enumerate(prog):
            for name, tile in step.loads:
                loads[(name, tile)] += 1
                loaded.add(name)
                round_cores[(r, name, tile)].add(c)
                if name not in first:
                    first[name] = r
                first[name] = min(first[name], r)
                last[name] = max(last.get(name, r), r)
                cores[name].add(c)
            for name, tile in step.stores:
                stored.add(name)
                if name not in first:
                    first[name] = r
                first[name] = min(first[name], r)
                last[name] = max(last.get(name, r), r)
                cores[name].add(c)
    for (_, name, _), cs in round_cores.items():
        co_width[name] = max(co_width[name], len(cs))
    return _ScheduleFacts(loads=dict(loads), first_round=first,
                          last_round=last, cores=dict(cores),
                          co_width=dict(co_width), loaded=loaded,
                          stored=stored)


# ---------------------------------------------------------------------------
# rule families
# ---------------------------------------------------------------------------
def structural_diagnostics(spec: DataflowSpec) -> List[Diagnostic]:
    """DCO001–DCO008 — the one rule inventory behind
    ``DataflowSpec.validate()`` (which raises on the first of these)."""
    em = _Emitter(spec.name)
    names = [t.name for t in spec.tensors]
    dup = sorted({n for n in names if names.count(n) > 1})
    if dup:
        em.emit("DCO001", f"duplicate tensor names {dup}")
    if not (len(spec.core_group) == len(spec.core_is_leader)
            == spec.n_cores):
        em.emit("DCO002", "core annotation length mismatch")
    by = {t.name: t for t in spec.tensors}
    for c, prog in enumerate(spec.core_programs):
        for r, step in enumerate(prog):
            for name, tile in (*step.loads, *step.stores):
                t = by.get(name)
                if t is None:
                    em.emit("DCO003",
                            f"references unknown tensor {name!r}",
                            core=c, round=r)
                elif not (0 <= tile < t.num_tiles):
                    em.emit("DCO004",
                            f"tile {tile} out of range for {name!r} "
                            f"({t.num_tiles} tiles)",
                            tensor=name, core=c, round=r)
    if spec.tenant_of_tensor is not None:
        if spec.tenant_names is None:
            em.emit("DCO005", "tenant map without tenant names")
        else:
            n_t = len(spec.tenant_names)
            runs: List[int] = []
            for t in spec.tensors:
                tid = spec.tenant_of_tensor.get(t.name)
                if tid is None or not (0 <= tid < n_t):
                    em.emit("DCO005",
                            "no valid tenant assignment", tensor=t.name)
                    continue
                if not runs or runs[-1] != tid:
                    runs.append(tid)
            if len(runs) != len(set(runs)):
                em.emit("DCO006",
                        f"tenant declarations must be contiguous "
                        f"(tenant-major tensor order), got run "
                        f"sequence {runs}")
    for t in spec.tensors:
        if t.n_acc < 1:
            em.emit("DCO007", f"n_acc={t.n_acc} (must be >= 1)",
                    tensor=t.name)
        if t.tile_bytes % spec.line_bytes:
            em.emit("DCO008",
                    f"tile_bytes={t.tile_bytes} not a multiple of "
                    f"line_bytes={spec.line_bytes}", tensor=t.name)
    return em.diags


def _annotation_rules(spec: DataflowSpec, facts: _ScheduleFacts,
                      em: _Emitter, errors_only: bool) -> None:
    for t in spec.tensors:
        if t.bypass or t.name not in facts.loaded:
            if (not errors_only and not t.bypass
                    and t.name in facts.stored
                    and t.name not in facts.loaded):
                em.emit("DCO103",
                        f"written but never read (n_acc={t.n_acc} "
                        f"unverifiable; lines retire only by eviction)",
                        tensor=t.name)
            continue
        # n_acc vs per-tile read counts (only tiles the schedule reads;
        # a partially-read tensor reports per-tile, capped)
        under = over = exact = 0
        worst: Optional[Tuple[int, int]] = None
        for tile in range(t.num_tiles):
            n = facts.loads.get((t.name, tile), 0)
            if n == 0:
                continue
            if n > t.n_acc:
                under += 1
                if worst is None or n > worst[1]:
                    worst = (tile, n)
            elif n < t.n_acc:
                over += 1
                if worst is None or n < worst[1]:
                    worst = (tile, n)
            else:
                exact += 1
        if under:
            em.emit("DCO101",
                    f"n_acc={t.n_acc} understated: {under} tile(s) read "
                    f"more often (e.g. tile {worst[0]}: {worst[1]} reads) "
                    f"— tiles retire while readers remain",
                    tensor=t.name)
        elif over and not exact:
            # unsatisfiable anywhere: the tensor can never retire
            em.emit("DCO102",
                    f"n_acc={t.n_acc} overstated: {over} tile(s) read "
                    f"fewer times (e.g. tile {worst[0]}: {worst[1]} reads)"
                    f" — tiles never retire, dead lines never predicted",
                    tensor=t.name)
        elif over:
            # conservative boundary shortfall (e.g. a causal mask's last
            # tile): nothing retires early, so not gate-worthy
            em.emit("DCO104",
                    f"n_acc={t.n_acc} reached by {exact} tile(s) but "
                    f"{over} boundary tile(s) fall short (e.g. tile "
                    f"{worst[0]}: {worst[1]} reads): those never retire",
                    tensor=t.name)
    for t in spec.tensors:
        seen = len(facts.cores.get(t.name, ()))
        if seen and t.sharers > seen:
            em.emit("DCO110",
                    f"sharers={t.sharers} but only {seen} core(s) ever "
                    f"touch the tensor", tensor=t.name)
        if errors_only:
            continue
        width = facts.co_width.get(t.name, 0)
        if not t.bypass and width > t.sharers:
            em.emit("DCO303",
                    f"co-streamed by {width} cores in one round but "
                    f"sharers={t.sharers}: inter-core (MSHR-merge) reuse "
                    f"is forfeited in the counts lowering",
                    tensor=t.name)
        if not t.bypass and t.n_acc == 1 and t.sharers > 1:
            em.emit("DCO302",
                    f"n_acc=1 with sharers={t.sharers}: the first "
                    f"sharer's read retires the tile", tensor=t.name)
        if t.bypass:
            n_tiles_multi = sum(
                1 for tile in range(t.num_tiles)
                if facts.loads.get((t.name, tile), 0) > 1)
            if n_tiles_multi:
                em.emit("DCO301",
                        f"bypass-hinted but {n_tiles_multi} tile(s) are "
                        f"read more than once: temporal reuse goes to "
                        f"DRAM", tensor=t.name)
            elif width > 1:
                em.emit("DCO301",
                        f"bypass-hinted but co-streamed by {width} cores "
                        f"in one round: the shared stream pays DRAM per "
                        f"core (the gqa_bypass hazard, §IV-E)",
                        tensor=t.name)


def _epoch_rules(spec: DataflowSpec, facts: _ScheduleFacts,
                 em: _Emitter) -> None:
    """DCO120: pairwise liveness of epoch-disjoint tensors (warn — the
    continuous-batching waves of serve-replay legitimately overlap)."""
    rows = [(t, facts.first_round.get(t.name), facts.last_round.get(t.name))
            for t in spec.tensors]
    rows = [(t, f, last) for t, f, last in rows if f is not None]
    # sweep in first-round order; only tensors whose windows overlap can
    # conflict, so the inner loop stops at the first non-overlapping start
    rows.sort(key=lambda x: x[1])
    per_tensor: Dict[str, Tuple[int, str]] = {}
    for i, (ti, fi, li) in enumerate(rows):
        for tj, fj, lj in rows[i + 1:]:
            if fj > li:
                break
            if ti.epoch1 < tj.epoch0 or tj.epoch1 < ti.epoch0:
                for a, b in ((ti, tj), (tj, ti)):
                    n, ex = per_tensor.get(a.name, (0, b.name))
                    per_tensor[a.name] = (n + 1, ex)
    for t in spec.tensors:
        hit = per_tensor.get(t.name)
        if hit:
            n, ex = hit
            em.emit("DCO120",
                    f"epochs [{t.epoch0},{t.epoch1}] declared disjoint "
                    f"from {n} tensor(s) it is concurrently live with "
                    f"(e.g. {ex!r}): the capacity model retires it early",
                    tensor=t.name,
                    round=facts.last_round.get(t.name))


def _layout_rules(spec: DataflowSpec, metas: Sequence[TensorMeta],
                  em: _Emitter, errors_only: bool, num_sets: int,
                  params: TMUParams,
                  facts: Optional[_ScheduleFacts] = None) -> None:
    if spec.allocator != "bump":
        # recycling allocator: declaration order no longer implies
        # address order and ranges may legitimately recur across
        # generations — the layout tier switches to liveness-window
        # semantics (DCO211 does not apply at all)
        _meta_rules_pooled(spec, metas, em, facts)
        if not errors_only:
            _generation_rules_pooled(spec, metas, em, num_sets, params)
        return
    _meta_rules(spec, metas, em)
    if errors_only:
        return
    # -- generation aliasing (DCO201/DCO202): tag-space collisions
    #    between tensors of different / disjoint epoch generations ------
    line = spec.line_bytes
    infos = []
    for m, t in zip(metas, spec.tensors):
        if t.bypass:
            continue
        tag0 = (m.base_addr // line) // num_sets
        tag1 = ((m.base_addr + m.size_bytes - 1) // line) // num_sets
        infos.append((t, tag0, tag1))
    # dead-id regions: granularity 2**d_lsb tags; region id wraps at the
    # dead-id width, so two generations collide when region ids match
    dead_regions: Dict[int, set] = defaultdict(set)
    region_names: Dict[int, List[str]] = defaultdict(list)
    for t, tag0, tag1 in infos:
        r0 = params.dead_id(tag0)
        span = (tag1 >> params.d_lsb) - (tag0 >> params.d_lsb)
        width = params.d_msb - params.d_lsb + 1
        for k in range(min(span + 1, 1 << width)):
            rid = (r0 + k) & ((1 << width) - 1)
            dead_regions[rid].add((t.epoch0, t.epoch1))
            if len(region_names[rid]) < 4:
                region_names[rid].append(t.name)
    mixed = {rid for rid, gens in dead_regions.items() if len(gens) > 1}
    flagged: set = set()
    for rid in sorted(mixed):
        for name in region_names[rid]:
            if name in flagged:
                continue
            flagged.add(name)
            others = [n for n in region_names[rid] if n != name]
            em.emit("DCO201",
                    f"dead-id region {rid} spans epoch generations "
                    f"{sorted(dead_regions[rid])} (with {others}): a "
                    f"retirement in one generation marks the other's "
                    f"lines dead", tensor=name)
    # priority tiers: tag[B_BITS-1:0]; flag each tensor that shares a
    # tier value with a disjoint-epoch tensor (the PR 8 decay signature)
    n_tiers = 1 << params.b_bits
    tier_sets = []
    for t, tag0, tag1 in infos:
        if tag1 - tag0 + 1 >= n_tiers:
            tiers = (1 << n_tiers) - 1
        else:
            tiers = 0
            for tag in range(tag0, tag1 + 1):
                tiers |= 1 << (tag & (n_tiers - 1))
        tier_sets.append((t, tiers))
    reported: Dict[str, Tuple[int, str]] = {}
    for i, (ti, si) in enumerate(tier_sets):
        for tj, sj in tier_sets[i + 1:]:
            if not (si & sj):
                continue
            if ti.epoch1 < tj.epoch0 or tj.epoch1 < ti.epoch0:
                for a, b in ((ti, tj), (tj, ti)):
                    n, ex = reported.get(a.name, (0, b.name))
                    reported[a.name] = (n + 1, ex)
    for t in spec.tensors:
        hit = reported.get(t.name)
        if hit:
            n, ex = hit
            em.emit("DCO202",
                    f"tag[{params.b_bits - 1}:0] tier values recur in "
                    f"{n} disjoint-epoch tensor(s) (e.g. {ex!r}): the "
                    f"at tier protection decays across generations "
                    f"(epochs [{t.epoch0},{t.epoch1}])",
                    tensor=t.name)


def _meta_rules(spec: DataflowSpec, metas: Sequence[TensorMeta],
                em: _Emitter) -> None:
    """DCO210/DCO211/DCO212 — pure layout facts, reusable against any
    meta list (the streaming emitters replicate the allocator)."""
    names = [t.name for t in spec.tensors]
    prev_base = None
    prev_name = None
    max_end = None
    max_name = None
    for m, name in zip(metas, names):
        if prev_base is not None:
            if m.base_addr <= prev_base:
                em.emit("DCO211",
                        f"base 0x{m.base_addr:x} not above predecessor "
                        f"{prev_name!r} (0x{prev_base:x}): breaks the "
                        f"bump-allocation invariant EventSink."
                        f"register_tensors and the stream emitters "
                        f"assume", tensor=name)
            if m.base_addr < max_end:
                em.emit("DCO210",
                        f"[0x{m.base_addr:x}, 0x"
                        f"{m.base_addr + m.size_bytes:x}) overlaps "
                        f"{max_name!r} ending at 0x{max_end:x}",
                        tensor=name)
        prev_base, prev_name = m.base_addr, name
        end = m.base_addr + m.size_bytes
        if max_end is None or end > max_end:
            max_end, max_name = end, name
    _tenant_align_rules(spec, metas, em)


def _tenant_align_rules(spec: DataflowSpec, metas: Sequence[TensorMeta],
                        em: _Emitter) -> None:
    """DCO212 — allocator-independent tenant-boundary alignment."""
    if spec.tenant_of_tensor is not None and spec.tenant_region_align:
        align = spec.tenant_region_align
        prev_tenant = None
        for m, t in zip(metas, spec.tensors):
            tenant = spec.tenant_of_tensor.get(t.name)
            if tenant != prev_tenant and m.base_addr % align:
                em.emit("DCO212",
                        f"tenant {tenant} region starts at "
                        f"0x{m.base_addr:x}, not {align}-byte aligned: "
                        f"a dead-id tag region straddles two tenants",
                        tensor=t.name)
            prev_tenant = tenant


def _meta_rules_pooled(spec: DataflowSpec, metas: Sequence[TensorMeta],
                       em: _Emitter,
                       facts: Optional[_ScheduleFacts]) -> None:
    """DCO210/DCO212 under a recycling allocator.

    Two tensors may occupy the same ``[base, end)`` range when the
    region was recycled between generations; the hazard is overlap
    while both are *live*, so the check intersects address ranges with
    schedule round windows.  Tensors the schedule never touches have no
    window and cannot conflict."""
    fr = facts.first_round if facts is not None else {}
    lr = facts.last_round if facts is not None else {}
    rows = []
    for m, t in zip(metas, spec.tensors):
        f = fr.get(t.name)
        if f is None:
            continue
        rows.append((m.base_addr, m.base_addr + m.size_bytes,
                     f, lr[t.name], t.name))
    rows.sort()
    for i, (b0, e0, f0, l0, n0) in enumerate(rows):
        for b1, e1, f1, l1, n1 in rows[i + 1:]:
            if b1 >= e0:
                break              # base-sorted: nothing later overlaps
            if not (l0 < f1 or l1 < f0):
                em.emit("DCO210",
                        f"[0x{b1:x}, 0x{e1:x}) overlaps {n0!r} "
                        f"([0x{b0:x}, 0x{e0:x})) while both are live "
                        f"(rounds [{f1},{l1}] vs [{f0},{l0}]): the "
                        f"allocator recycled a region before its "
                        f"previous owner's last access", tensor=n1,
                        round=max(f0, f1))
    _tenant_align_rules(spec, metas, em)


def _generation_rules_pooled(spec: DataflowSpec,
                             metas: Sequence[TensorMeta], em: _Emitter,
                             num_sets: int, params: TMUParams) -> None:
    """DCO201/DCO202 as *pool-coverage* metrics under recycling.

    With address reuse, a tier (or dead-id) collision is a fresh
    aliasing event only when a tensor claims a previously-unused tag
    block whose tier / dead-id value is already taken — a recycled
    block inherits its own history rather than aliasing someone else's.
    Once the pool's tag blocks are all warmed up no tensor can fire
    again, so both counts are bounded by the pool footprint and stay
    flat in request count.  A bump layout fails this signature: fresh
    addresses forever mean fresh tag blocks forever, and the counts
    grow with every retired generation (the PR 8 at+dbp decay) — the
    gap is the replay gate's acceptance metric."""
    line = spec.line_bytes
    n_tiers = 1 << params.b_bits
    width = params.d_msb - params.d_lsb + 1
    used_tags: set = set()
    used_tiers: set = set()
    used_rids: set = set()
    for m, t in zip(metas, spec.tensors):
        if t.bypass:
            continue
        tag0 = (m.base_addr // line) // num_sets
        tag1 = ((m.base_addr + m.size_bytes - 1) // line) // num_sets
        new_tags = [tag for tag in range(tag0, tag1 + 1)
                    if tag not in used_tags]
        if not new_tags:
            continue
        new_tiers = {tag & (n_tiers - 1) for tag in new_tags}
        new_rids = {(tag >> params.d_lsb) & ((1 << width) - 1)
                    for tag in new_tags}
        rid_hits = sorted(new_rids & used_rids)
        if rid_hits:
            em.emit("DCO201",
                    f"claims {len(new_tags)} fresh tag block(s) whose "
                    f"dead-id value(s) {rid_hits[:4]} are already in "
                    f"use: a retirement there marks another "
                    f"generation's lines dead", tensor=t.name)
        tier_hits = sorted(new_tiers & used_tiers)
        if tier_hits:
            em.emit("DCO202",
                    f"claims fresh tag block(s) on already-used "
                    f"tag[{params.b_bits - 1}:0] tier value(s) "
                    f"{tier_hits}: at tier protection dilutes as the "
                    f"address footprint grows", tensor=t.name)
        used_tags.update(new_tags)
        used_tiers |= new_tiers
        used_rids |= new_rids


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def _num_sets(llc_bytes: int, line_bytes: int, assoc: int) -> int:
    return max(1, (llc_bytes // line_bytes) // assoc)


def verify_spec(spec: DataflowSpec, *, sim_cfg=None,
                params: Optional[TMUParams] = None,
                errors_only: bool = False) -> VerifyResult:
    """Run the rule inventory over one spec.

    ``sim_cfg`` supplies the cache geometry the tag-space rules
    (DCO201/DCO202) are evaluated under — pass the suite case's
    ``SimConfig`` to lint the layout against the geometry it actually
    runs on (defaults to the stock geometry).  ``errors_only`` restricts
    to the gate tier (the cheap path ``SpecBuilder.build`` runs).
    """
    params = params or TMUParams()
    res = VerifyResult(spec.name)
    res.diagnostics.extend(structural_diagnostics(spec))
    if res.has_errors:
        # the schedule walk / layout need a structurally sound spec
        res.sort()
        return res
    from .lower import assign_addresses
    if sim_cfg is None:
        num_sets = _num_sets(4 * 2 ** 20, spec.line_bytes, 8)
    else:
        num_sets = _num_sets(sim_cfg.llc_bytes, sim_cfg.line_bytes,
                             sim_cfg.llc_assoc)
    facts = _walk_schedule(spec)
    em = _Emitter(spec.name)
    _annotation_rules(spec, facts, em, errors_only)
    if not errors_only:
        _epoch_rules(spec, facts, em)
    metas = list(assign_addresses(spec).values())
    _layout_rules(spec, metas, em, errors_only, num_sets, params,
                  facts=facts)
    res.diagnostics.extend(em.diags)
    for code, n in em._per_rule.items():
        res.rule_counts[code] = res.rule_counts.get(code, 0) + n
    res.sort()
    return res


def verify_metas(spec: DataflowSpec, metas: Sequence[TensorMeta],
                 ) -> VerifyResult:
    """Layout-only verification of an explicit meta list (the injection
    harness corrupts base addresses at this level; the spec only carries
    names/tenants for location and alignment context)."""
    em = _Emitter(spec.name)
    _meta_rules(spec, metas, em)
    res = VerifyResult(spec.name, em.diags)
    res.sort()
    return res


def assert_clean(spec: DataflowSpec, *, sim_cfg=None) -> None:
    """Gate: raise :class:`SpecVerifyError` if any error-tier rule fires
    (annotation-vs-schedule consistency plus layout invariants — the
    structural tier is already covered by ``spec.validate()``)."""
    res = verify_spec(spec, sim_cfg=sim_cfg, errors_only=True)
    if res.has_errors:
        raise SpecVerifyError(res)


# ---------------------------------------------------------------------------
# online (streaming) mode
# ---------------------------------------------------------------------------
class StreamVerifier:
    """Opt-in online verification of emitted :class:`ReplaySegment`
    windows (``run_replay(..., verify=True)``).

    The streaming path has no monolithic spec, so the verifier rebuilds
    the analyzer's facts incrementally: bases must ascend and stay
    disjoint (DCO210/DCO211) as tensors are declared, per-tile TLL read
    counts are accumulated from each window's compiled feed, and at
    ``clear`` time the observed counts are checked against the declared
    ``n_acc`` (DCO101/DCO102).  Generation aliasing (DCO202) is tracked
    as tier values of *new* tensors colliding with tiers of already
    *retired* ones — the bump allocator's PR 8 decay, observed live.

    ``allocator="pooled"`` switches the layout tier to the recycling
    semantics of :func:`_meta_rules_pooled` / :func:`
    _generation_rules_pooled`, evaluated incrementally: DCO210 checks
    each declaration against the *live* region set (a region retiring
    in the same window is a legitimate hand-off, mirroring ``EventSink.
    register_tensors``), DCO211 does not apply, and DCO201/DCO202 fire
    only when a declaration claims previously-unused tag blocks on
    already-used dead-id / tier values.  Declaration order equals the
    monolithic spec's, so the streamed counts match ``verify_spec`` on
    the same replay.
    """

    def __init__(self, name: str, *, line_bytes: int = 128, sim_cfg=None,
                 params: Optional[TMUParams] = None,
                 allocator: str = "bump"):
        self.params = params or TMUParams()
        self.line_bytes = line_bytes
        self.allocator = allocator
        if sim_cfg is None:
            self.num_sets = _num_sets(4 * 2 ** 20, line_bytes, 8)
        else:
            self.num_sets = _num_sets(sim_cfg.llc_bytes,
                                      sim_cfg.line_bytes,
                                      sim_cfg.llc_assoc)
        self._em = _Emitter(name)
        self._prev_base: Optional[int] = None
        self._prev_end: Optional[int] = None
        self._prev_tid: Optional[int] = None
        self._meta: Dict[int, TensorMeta] = {}
        self._tier_bits: Dict[int, int] = {}
        self._retired_tiers = 0
        self._counts: Dict[Tuple[int, int], int] = defaultdict(int)
        # pooled-mode state: live [base, end) per tid + pool coverage
        self._live_regions: Dict[int, Tuple[int, int]] = {}
        self._used_tags: set = set()
        self._used_tiers: set = set()
        self._used_rids: set = set()
        self.segments = 0

    def _tag_range(self, meta: TensorMeta) -> Tuple[int, int]:
        tag0 = (meta.base_addr // self.line_bytes) // self.num_sets
        tag1 = ((meta.base_addr + meta.size_bytes - 1)
                // self.line_bytes) // self.num_sets
        return tag0, tag1

    def _tiers_of(self, meta: TensorMeta) -> int:
        n_tiers = 1 << self.params.b_bits
        tag0, tag1 = self._tag_range(meta)
        if tag1 - tag0 + 1 >= n_tiers:
            return (1 << n_tiers) - 1
        bits = 0
        for tag in range(tag0, tag1 + 1):
            bits |= 1 << (tag & (n_tiers - 1))
        return bits

    def _on_declared_bump(self, meta: TensorMeta, name: str) -> None:
        em = self._em
        if self._prev_base is not None:
            if meta.base_addr <= self._prev_base:
                em.emit("DCO211",
                        f"base 0x{meta.base_addr:x} not above "
                        f"predecessor t{self._prev_tid} "
                        f"(0x{self._prev_base:x})", tensor=name)
            if meta.base_addr < self._prev_end:
                em.emit("DCO210",
                        f"[0x{meta.base_addr:x}, ...) overlaps "
                        f"t{self._prev_tid} ending at "
                        f"0x{self._prev_end:x}", tensor=name)
        self._prev_base = meta.base_addr
        self._prev_end = meta.base_addr + meta.size_bytes
        self._prev_tid = meta.tensor_id
        if not meta.bypass_all:
            tiers = self._tiers_of(meta)
            self._tier_bits[meta.tensor_id] = tiers
            if tiers & self._retired_tiers:
                em.emit("DCO202",
                        f"tier values recur from already-retired "
                        f"generations (bump allocation never reuses "
                        f"addresses, so tag[{self.params.b_bits - 1}"
                        f":0] wrapped)", tensor=name)

    def _on_declared_pooled(self, meta: TensorMeta, name: str,
                            retiring: set) -> None:
        em = self._em
        tid = meta.tensor_id
        base, end = meta.base_addr, meta.base_addr + meta.size_bytes
        for lt, (ls, le) in self._live_regions.items():
            if lt == tid or lt in retiring:
                continue
            if base < le and ls < end:
                em.emit("DCO210",
                        f"[0x{base:x}, 0x{end:x}) overlaps the live "
                        f"region [0x{ls:x}, 0x{le:x}) of t{lt}: the "
                        f"allocator recycled a region still in use",
                        tensor=name)
                break
        self._live_regions[tid] = (base, end)
        if meta.bypass_all:
            return
        p = self.params
        n_tiers = 1 << p.b_bits
        width = p.d_msb - p.d_lsb + 1
        tag0, tag1 = self._tag_range(meta)
        new_tags = [tag for tag in range(tag0, tag1 + 1)
                    if tag not in self._used_tags]
        if not new_tags:
            return
        new_tiers = {tag & (n_tiers - 1) for tag in new_tags}
        new_rids = {(tag >> p.d_lsb) & ((1 << width) - 1)
                    for tag in new_tags}
        rid_hits = sorted(new_rids & self._used_rids)
        if rid_hits:
            em.emit("DCO201",
                    f"claims {len(new_tags)} fresh tag block(s) whose "
                    f"dead-id value(s) {rid_hits[:4]} are already in "
                    f"use: a retirement there marks another "
                    f"generation's lines dead", tensor=name)
        tier_hits = sorted(new_tiers & self._used_tiers)
        if tier_hits:
            em.emit("DCO202",
                    f"claims fresh tag block(s) on already-used "
                    f"tag[{p.b_bits - 1}:0] tier value(s) "
                    f"{tier_hits}: at tier protection dilutes as the "
                    f"address footprint grows", tensor=name)
        self._used_tags.update(new_tags)
        self._used_tiers |= new_tiers
        self._used_rids |= new_rids

    def on_segment(self, seg: "ReplaySegment") -> None:
        em = self._em
        pooled = self.allocator != "bump"
        retiring = set(seg.clear_tids) if pooled else ()
        for meta in seg.new_tensors:
            tid = meta.tensor_id
            name = f"t{tid}"
            if pooled:
                self._on_declared_pooled(meta, name, retiring)
            else:
                self._on_declared_bump(meta, name)
            self._meta[tid] = meta
        ct = seg.ct
        for tid, tile in zip(ct.tll_tids.tolist(), ct.tll_tiles.tolist()):
            self._counts[(tid, tile)] += 1
        for tid in seg.clear_tids:
            self._live_regions.pop(tid, None)
            meta = self._meta.pop(tid, None)
            if meta is None or meta.bypass_all:
                continue
            self._retired_tiers |= self._tier_bits.pop(tid, 0)
            n_tiles = meta.size_bytes // meta.tile_bytes
            under = over = exact = 0
            for tile in range(n_tiles):
                n = self._counts.pop((tid, tile), 0)
                if n > meta.n_acc:
                    under += 1
                elif n == meta.n_acc:
                    exact += 1
                elif n > 0:
                    over += 1
            if under:
                em.emit("DCO101",
                        f"n_acc={meta.n_acc} understated: {under} "
                        f"tile(s) read more often before clear",
                        tensor=f"t{tid}")
            if over and not exact:
                em.emit("DCO102",
                        f"n_acc={meta.n_acc} overstated: {over} tile(s) "
                        f"cleared before reaching it (never retired)",
                        tensor=f"t{tid}")
            elif over:
                em.emit("DCO104",
                        f"n_acc={meta.n_acc} reached by {exact} tile(s) "
                        f"but {over} cleared short of it (never retired)",
                        tensor=f"t{tid}")
        self.segments += 1

    def finish(self) -> VerifyResult:
        res = VerifyResult(self._em.spec_name, list(self._em.diags),
                          rule_counts=dict(self._em._per_rule))
        res.sort()
        return res


# ---------------------------------------------------------------------------
# ground-truth cross-check (analyzer verdicts vs simulator-measured TMU)
# ---------------------------------------------------------------------------
def predicted_retirements(spec: DataflowSpec) -> Dict[str, int]:
    """The analyzer's retirement prediction per tensor: the TMU bumps one
    accCnt per TLL feed entry (one per load of a non-bypass tile, not
    MSHR-merged) and retires each time the counter reaches ``n_acc``
    (counter pops and re-accumulates), so a tile retires
    ``floor(reads / n_acc)`` times."""
    facts = _walk_schedule(spec)
    out: Dict[str, int] = {}
    for t in spec.tensors:
        if t.bypass:
            continue
        total = 0
        for tile in range(t.num_tiles):
            total += facts.loads.get((t.name, tile), 0) // t.n_acc
        out[t.name] = total
    return out


def predicted_excess_retirements(spec: DataflowSpec) -> int:
    """Tiles retiring more than once = the measurable premature-
    retirement signal an understated ``n_acc`` produces (a clean spec
    predicts zero: every read tile retires exactly once, at its last
    read)."""
    facts = _walk_schedule(spec)
    total = 0
    for t in spec.tensors:
        if t.bypass:
            continue
        for tile in range(t.num_tiles):
            total += max(0, facts.loads.get((t.name, tile), 0)
                         // t.n_acc - 1)
    return total


def cross_check_case(case: "SuiteCase",
                     policies: Sequence[str] = ("lru", "dbp", "at+dbp"),
                     ) -> Dict[str, object]:
    """Run one suite case with events on and compare measured truth to
    the analyzer's verdicts.

    Checks, per policy: total TMU ``RETIRE`` events == the analyzer's
    predicted retirement count; per-tensor retirement counts match; and
    (spec predicted clean) measured excess retirements (a tile retiring
    more than once) == 0.  Retirements are policy-independent (the TLL
    feed is derived from the trace), so agreement across the policy set
    also pins that invariance.
    """
    import numpy as np

    from repro.core.events import EV_RETIRE
    from repro.core.events import EventSink
    from repro.core.policies import named_policy
    from repro.core.simulator import Simulator

    from .lower import lower_to_trace

    spec = case.spec
    predicted = predicted_retirements(spec)
    predicted_total = sum(predicted.values())
    predicted_excess = predicted_excess_retirements(spec)
    verdict = verify_spec(spec, sim_cfg=case.cfg)
    predicted_clean = not any(
        d.code in ("DCO101", "DCO102") for d in verdict.diagnostics)
    trace = lower_to_trace(spec)
    name_of = {i: t.name for i, t in enumerate(spec.tensors)}
    rows: List[Dict[str, object]] = []
    agree = True
    for pol in policies:
        sink = EventSink()
        sim = Simulator(case.cfg, named_policy(pol, gqa=case.gqa))
        sim.run(trace, record_history=False, events=sink)
        mat = sink.matrix()
        ret = mat[mat[:, 6] == EV_RETIRE]
        measured_total = int(ret.shape[0])
        measured: Dict[str, int] = {}
        excess = 0
        if measured_total:
            pair = ret[:, 3] * (2 ** 32) + ret[:, 7]
            _, tile_counts = np.unique(pair, return_counts=True)
            excess = int(np.maximum(tile_counts - 1, 0).sum())
            tids, counts = np.unique(ret[:, 3], return_counts=True)
            measured = {name_of[int(t)]: int(c)
                        for t, c in zip(tids, counts)}
        mismatches = sorted(
            n for n in set(predicted) | set(measured)
            if predicted.get(n, 0) != measured.get(n, 0))
        ok = (measured_total == predicted_total and not mismatches
              and (excess == 0 if predicted_clean
                   else excess == predicted_excess))
        agree &= ok
        rows.append({"policy": pol, "ok": ok,
                     "measured_retirements": measured_total,
                     "measured_excess": excess,
                     "per_tensor_mismatches": mismatches[:8]})
    return {"scenario": case.key, "agree": agree,
            "predicted_retirements": predicted_total,
            "predicted_excess": predicted_excess,
            "predicted_clean": predicted_clean,
            "policies": rows}


def rules_inventory() -> List[Dict[str, str]]:
    """The rule table as plain dicts (CLI/report rendering)."""
    return [{"code": r.code, "severity": r.severity, "title": r.title,
             "assumption": r.assumption, "consumer": r.consumer,
             "allocator": r.allocator}
            for r in RULES.values()]

"""Corruption injection for the static verifier (DESIGN.md §12).

The ground-truth side of the analyzer's contract: for every corruption
class a stale compiler could hand the TMU — wrong ``n_acc``, shifted
epoch ranges, inflated sharer counts, broken base addresses — this
module produces a corrupted twin of a known-good spec together with the
diagnostic code the analyzer *must* raise against it.  The injection
tests assert 100% detection (the expected code fires, located at the
corrupted tensor) and zero regression (the clean spec carries no such
diagnostic at that tensor), which doubles as the labeled-defect
substrate the ROADMAP's learned-predictor item needs.

Spec-level corruptions go through ``dataclasses.replace`` so every
corrupted spec is still structurally valid — the defect is *semantic*,
exactly the class ``DataflowSpec.validate()`` cannot see.  Base-address
corruptions operate on the assigned :class:`~repro.core.tmu.TensorMeta`
layout (specs carry no addresses) and are checked by
:func:`~repro.dataflows.verify.verify_metas`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
import random
from typing import Dict
from typing import List
from typing import Optional
from typing import Sequence
from typing import Tuple

from repro.core.tmu import TensorMeta

from .ir import DataflowSpec
from .verify import _walk_schedule

#: corruption classes applied to the spec's annotations
SPEC_KINDS: Tuple[str, ...] = ("nacc_under", "nacc_over", "sharers_over",
                               "epoch_shift")
#: corruption classes applied to the assigned address layout
LAYOUT_KINDS: Tuple[str, ...] = ("base_overlap", "base_nonmonotone")

#: corruption class -> the diagnostic code the analyzer must raise
EXPECTED_CODE: Dict[str, str] = {
    "nacc_under": "DCO101",
    "nacc_over": "DCO102",
    "sharers_over": "DCO110",
    "epoch_shift": "DCO120",
    "base_overlap": "DCO210",
    "base_nonmonotone": "DCO211",
}


@dataclass(frozen=True)
class Injection:
    """One applied corruption: where, what, and the code that must fire."""

    kind: str
    tensor: str
    expected_code: str
    description: str


def _replace_tensor(spec: DataflowSpec, name: str,
                    **changes) -> DataflowSpec:
    tensors = [dataclasses.replace(t, **changes) if t.name == name else t
               for t in spec.tensors]
    return dataclasses.replace(spec, tensors=tensors)


def eligible_tensors(spec: DataflowSpec, kind: str,
                     avoid: Sequence[str] = ()) -> List[str]:
    """Tensors on which ``kind`` produces a *guaranteed-detectable*
    corruption (e.g. halving ``n_acc=1`` changes nothing; a tensor that
    overlaps nobody in time cannot exhibit an epoch conflict)."""
    facts = _walk_schedule(spec)
    avoid_set = set(avoid)
    out: List[str] = []
    if kind in LAYOUT_KINDS:
        # any non-first tensor (base_overlap additionally needs a
        # predecessor wider than one line to slide into while keeping
        # bases ascending)
        return [t.name for i, t in enumerate(spec.tensors)
                if i > 0 and t.name not in avoid_set
                and (kind != "base_overlap"
                     or spec.tensors[i - 1].size_bytes
                     > spec.line_bytes)]
    for t in spec.tensors:
        if t.name in avoid_set:
            continue
        if kind == "nacc_under":
            if t.bypass or t.name not in facts.loaded:
                continue
            m = min(facts.loads.get((t.name, k), 0) or 10 ** 9
                    for k in range(t.num_tiles))
            if m >= 2 and t.n_acc >= 2:
                out.append(t.name)
        elif kind == "nacc_over":
            if not t.bypass and t.name in facts.loaded:
                out.append(t.name)
        elif kind == "sharers_over":
            if t.name in facts.cores:
                out.append(t.name)
        elif kind == "epoch_shift":
            f = facts.first_round.get(t.name)
            if f is None:
                continue
            last = facts.last_round[t.name]
            if any(o.name != t.name
                   and facts.first_round.get(o.name) is not None
                   and facts.first_round[o.name] <= last
                   and facts.last_round[o.name] >= f
                   for o in spec.tensors):
                out.append(t.name)
        else:
            raise KeyError(f"unknown corruption kind {kind!r}")
    return out


def inject_spec(spec: DataflowSpec, kind: str, rng: random.Random,
                avoid: Sequence[str] = (),
                ) -> Optional[Tuple[DataflowSpec, Injection]]:
    """Apply one spec-level corruption of class ``kind`` to a randomly
    chosen eligible tensor (``None`` if the spec offers no eligible
    target).  ``avoid`` excludes tensors already carrying the expected
    code in the clean run, so detection is attributable."""
    if kind not in SPEC_KINDS:
        raise KeyError(f"not a spec-level corruption kind: {kind!r}")
    names = eligible_tensors(spec, kind, avoid)
    if not names:
        return None
    name = rng.choice(names)
    t = spec.tensor(name)
    facts = _walk_schedule(spec)
    if kind == "nacc_under":
        m = min(facts.loads.get((name, k), 0) or 10 ** 9
                for k in range(t.num_tiles))
        new = max(1, m // 2)
        corrupted = _replace_tensor(spec, name, n_acc=new)
        desc = f"n_acc {t.n_acc} -> {new} (tiles read >= {m} times)"
    elif kind == "nacc_over":
        peak = max(facts.loads.get((name, k), 0)
                   for k in range(t.num_tiles))
        new = peak + 3
        corrupted = _replace_tensor(spec, name, n_acc=new)
        desc = f"n_acc {t.n_acc} -> {new} (tiles read <= {peak} times)"
    elif kind == "sharers_over":
        seen = len(facts.cores[name])
        new = seen + 1
        corrupted = _replace_tensor(spec, name, sharers=new)
        desc = f"sharers {t.sharers} -> {new} ({seen} cores observed)"
    else:  # epoch_shift
        horizon = 1 + max(x.epoch1 for x in spec.tensors)
        corrupted = _replace_tensor(spec, name, epoch0=horizon,
                                    epoch1=horizon)
        desc = (f"epochs [{t.epoch0},{t.epoch1}] -> "
                f"[{horizon},{horizon}] (stale generation tag)")
    return corrupted, Injection(kind=kind, tensor=name,
                                expected_code=EXPECTED_CODE[kind],
                                description=desc)


def inject_layout(spec: DataflowSpec, metas: Sequence[TensorMeta],
                  kind: str, rng: random.Random,
                  ) -> Tuple[List[TensorMeta], Injection]:
    """Apply one base-address corruption to an assigned layout.

    ``base_overlap`` slides a tensor's base back inside its
    predecessor's region while keeping bases ascending (isolates
    DCO210); ``base_nonmonotone`` rewinds a base below its predecessor
    (the invariant ``EventSink.register_tensors`` and the stream
    emitters' recycling rest on — DCO211)."""
    if kind not in LAYOUT_KINDS:
        raise KeyError(f"not a layout corruption kind: {kind!r}")
    names = eligible_tensors(spec, kind)
    if not names:
        raise ValueError(f"{spec.name}: no eligible tensor for {kind}")
    name = rng.choice(names)
    idx = [t.name for t in spec.tensors].index(name)
    out = list(metas)
    prev = metas[idx - 1]
    if kind == "base_overlap":
        new_base = max(prev.base_addr + spec.line_bytes,
                       prev.base_addr + prev.size_bytes
                       - spec.line_bytes)
        desc = (f"base 0x{metas[idx].base_addr:x} -> 0x{new_base:x} "
                f"(inside {prev.tensor_id}'s region)")
    else:
        new_base = prev.base_addr
        desc = (f"base 0x{metas[idx].base_addr:x} -> 0x{new_base:x} "
                f"(= predecessor base; monotone bump broken)")
    out[idx] = dataclasses.replace(metas[idx], base_addr=new_base)
    return out, Injection(kind=kind, tensor=name,
                          expected_code=EXPECTED_CODE[kind],
                          description=desc)

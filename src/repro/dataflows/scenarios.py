"""New dataflow scenarios expressed on the IR (DESIGN.md §8.3).

Four workload classes beyond the seed's FA2/matmul pair, each exercising
a capability the IR provides and a paper mechanism end to end:

* :func:`decode_paged_spec` — decode attention over paged KV with
  staggered sequence completion (§VI-F generalized to serving): dead
  pages pollute the LLC until DBP retires them.
* :func:`moe_ffn_spec` — MoE expert-FFN with skewed routing: hot expert
  weights are co-streamed by several cores through the LLC (inter-core
  sharing, cf. the MoE cache-management line of work in PAPERS.md),
  while cold experts finish early and go dead.
* :func:`mlp_chain_spec` — a 3-matmul MLP chain whose intermediate
  activations are produced by one op and consumed by the next through
  LLC storage (inter-op reuse a single-op builder cannot express).
* :func:`transformer_layer_spec` — a fused attention+FFN layer: the
  attention outputs, bypass-class in stand-alone FA2, become reuse
  carriers read back by the FFN matmuls — cross-op dataflow knowledge is
  exactly what the TMU registration interface exists to convey.
* :func:`spec_decode_spec` — speculative decoding: per-round draft-model
  KV with a short known lifetime (its own liveness epoch, dead at
  verification) interleaved with persistent target-model KV — the
  §VI-F retirement pattern at speculation-round cadence.
* :func:`ssd_scan_spec` — Mamba2 SSD chunked scan: per-chunk running
  states materialized by stores and consumed exactly once by the next
  chunk's recurrence (``nAcc`` ends at the next chunk's
  materialization) — dead-block prediction and dirty-lifetime write-back
  on an attention-free architecture.
* :func:`prefix_share_spec` — prefix-cache sharing: one common prompt
  prefix's KV co-streamed by every request (high ``sharers``; MSHR
  merges plus a lagging rank riding LLC storage) over thrashing
  per-request private suffixes — the gqa_bypass protection scenario.
"""

from __future__ import annotations

from typing import List
from typing import Tuple

from repro.core.workloads import AttnWorkload
from repro.core.workloads import DecodeWorkload
from repro.core.workloads import MoEWorkload
from repro.core.workloads import PrefixShareWorkload
from repro.core.workloads import SSDScanWorkload
from repro.core.workloads import SpecDecodeWorkload
from repro.core.workloads import TEMPORAL

from .fa2 import _kv_extent
from .fa2 import emit_matmul_rounds
from .ir import DataflowSpec
from .ir import SpecBuilder


# ---------------------------------------------------------------------------
# Decode attention with paged KV (multi-batch DBP retirement, §VI-F)
# ---------------------------------------------------------------------------
def decode_paged_spec(wl: DecodeWorkload, n_cores: int = 16) -> DataflowSpec:
    b = SpecBuilder(wl.name, n_cores)
    # KV first, contiguously: one sequence's K+V spans exactly one run of
    # tag space, so tile priorities (tag low bits) and dead ids fall out
    # per sequence just as §IV-B intends.
    kv: List = []
    for s in range(wl.n_seqs):
        alive = wl.steps_alive(s)
        epoch = (0, 0) if s < wl.n_short else (0, 1)
        pair = []
        for kind in ("K", "V"):
            pair.append(b.tensor(
                f"{kind}.s{s}", size_bytes=wl.n_pages * wl.page_bytes,
                tile_bytes=wl.page_bytes, n_acc=alive, operand_id=1,
                epoch=epoch))
        kv.append(tuple(pair))
    # per-sequence decode-token streams (Q in, logit/output out): one line
    # per step, always-bypass (the bursty Q/O class)
    q_bytes = wl.head_dim * wl.n_kv_heads * wl.dtype_bytes
    qo = []
    for s in range(wl.n_seqs):
        alive = wl.steps_alive(s)
        q = b.tensor(f"Q.s{s}", size_bytes=alive * q_bytes,
                     tile_bytes=q_bytes, n_acc=1, operand_id=0,
                     bypass=True, epoch=(0, 0) if s < wl.n_short else (0, 1))
        o = b.tensor(f"O.s{s}", size_bytes=alive * q_bytes,
                     tile_bytes=q_bytes, n_acc=1, operand_id=2,
                     bypass=True, epoch=(0, 0) if s < wl.n_short else (0, 1))
        qo.append((q, o))

    half = 2.0 * wl.page_rows * wl.head_dim * wl.n_kv_heads
    for t in range(wl.n_steps):
        for s in range(wl.n_seqs):
            if t >= wl.steps_alive(s):
                continue
            c = s % n_cores
            b.step(c, loads=[(qo[s][0], t)])
            for p in range(wl.n_pages):
                b.step(c, loads=[(kv[s][0], p)], flops=half)
                b.step(c, loads=[(kv[s][1], p)], flops=half)
            b.step(c, stores=[(qo[s][1], t)])
        # cores whose sequences all finished idle in lockstep
        b.pad_to_sync()
    return b.build()


# ---------------------------------------------------------------------------
# MoE expert-FFN with skewed expert routing
# ---------------------------------------------------------------------------
def moe_ffn_spec(wl: MoEWorkload, n_cores: int = 16) -> DataflowSpec:
    if n_cores % wl.n_hot:
        raise ValueError("n_cores must be a multiple of n_hot")
    if wl.n_cold != n_cores - wl.n_hot:
        raise ValueError("need n_cold == n_cores - n_hot (one warm-phase "
                         "core per cold expert)")
    b = SpecBuilder(wl.name, n_cores)
    share = n_cores // wl.n_hot          # cores per hot expert, steady state
    hot_uses = wl.warm_steps + (wl.n_steps - wl.warm_steps) * share
    n_tiles = wl.expert_bytes // wl.tile_bytes

    experts = []
    for e in range(wl.n_experts):
        hot = e < wl.n_hot
        experts.append(b.tensor(
            f"W.e{e}", size_bytes=wl.expert_bytes,
            tile_bytes=wl.tile_bytes, operand_id=1,
            n_acc=hot_uses if hot else wl.warm_steps,
            epoch=(0, 1) if hot else (0, 0),
            sharers=share if hot else 1))
    acts = []
    for c in range(n_cores):
        x = b.tensor(f"X.c{c}", size_bytes=wl.n_steps * wl.act_tile_bytes,
                     tile_bytes=wl.act_tile_bytes, n_acc=1, operand_id=0,
                     bypass=True, epoch=(0, 1))
        y = b.tensor(f"Y.c{c}", size_bytes=wl.n_steps * wl.act_tile_bytes,
                     tile_bytes=wl.act_tile_bytes, n_acc=1, operand_id=2,
                     bypass=True, epoch=(0, 1))
        acts.append((x, y))

    # steady-state sharing groups: ranks of one hot expert; rank 0 leads,
    # later ranks lag `rank` tiles so their reuses ride LLC storage
    b.set_groups([c % wl.n_hot for c in range(n_cores)],
                 [c // wl.n_hot == 0 for c in range(n_cores)])

    tile_flops = wl.flops_per_use / n_tiles
    for s in range(wl.n_steps):
        for c in range(n_cores):
            if s < wl.warm_steps:
                # skewed warm phase: core c serves expert c (the first
                # n_hot cores route hot, the rest one cold expert each)
                e = c
                lag = 0
            else:
                e = c % wl.n_hot
                lag = c // wl.n_hot
            b.step(c, loads=[(acts[c][0], s)])
            for tt in range(n_tiles):
                b.step(c, loads=[(experts[e], (tt - lag) % n_tiles)],
                       flops=tile_flops)
            b.step(c, stores=[(acts[c][1], s)])
    return b.build()


# ---------------------------------------------------------------------------
# 3-matmul MLP chain with inter-op activation reuse
# ---------------------------------------------------------------------------
def _emit_matmul(b: SpecBuilder, A: str, B_: str, C: str,
                 mt: int, kt: int, nt: int, flops: float) -> None:
    """One chained matmul op: shared emission plus a lockstep barrier
    (pad_to_sync) so the next op starts aligned."""
    emit_matmul_rounds(b, A, B_, C, mt, kt, nt, flops)
    b.pad_to_sync()


def mlp_chain_spec(m: int = 1024, dims: tuple = (512, 512, 512, 512),
                   tile: int = 128, n_cores: int = 16,
                   dtype_bytes: int = 1) -> DataflowSpec:
    """Y = act(act(X@W1)@W2)@W3: the intermediate activations H1/H2 are
    written by one op and read back by the next — their ``nAcc`` is the
    *consumer's* read count, dataflow knowledge that spans op boundaries.
    """
    d0, d1, d2, d3 = dims
    for d in (m, *dims):
        if d % tile:
            raise ValueError("dims must be tile-aligned")
    mt = m // tile
    t0, t1, t2, t3 = (d // tile for d in dims)
    tb = tile * tile * dtype_bytes
    b = SpecBuilder(f"mlp-chain-{m}x{'x'.join(str(d) for d in dims)}",
                    n_cores)

    X = b.tensor("X", size_bytes=mt * t0 * tb, tile_bytes=tb,
                 n_acc=t1, operand_id=0)
    W1 = b.tensor("W1", size_bytes=t0 * t1 * tb, tile_bytes=tb,
                  n_acc=mt, operand_id=1)
    W2 = b.tensor("W2", size_bytes=t1 * t2 * tb, tile_bytes=tb,
                  n_acc=mt, operand_id=1)
    W3 = b.tensor("W3", size_bytes=t2 * t3 * tb, tile_bytes=tb,
                  n_acc=mt, operand_id=1)
    H1 = b.tensor("H1", size_bytes=mt * t1 * tb, tile_bytes=tb,
                  n_acc=t2, operand_id=2)     # read back by op 2
    H2 = b.tensor("H2", size_bytes=mt * t2 * tb, tile_bytes=tb,
                  n_acc=t3, operand_id=2)     # read back by op 3
    Y = b.tensor("Y", size_bytes=mt * t3 * tb, tile_bytes=tb,
                 n_acc=1, operand_id=2, bypass=True)

    flops = 2.0 * tile * tile * tile
    _emit_matmul(b, X, W1, H1, mt, t0, t1, flops)
    _emit_matmul(b, H1, W2, H2, mt, t1, t2, flops)
    _emit_matmul(b, H2, W3, Y, mt, t2, t3, flops)
    return b.build()


# ---------------------------------------------------------------------------
# Fused attention + FFN transformer layer
# ---------------------------------------------------------------------------
def transformer_layer_spec(wl: AttnWorkload, d_ff: int = 1024,
                           n_cores: int = 16) -> DataflowSpec:
    """One transformer layer as a single dataflow: FA2 attention (temporal
    group allocation) whose per-head outputs feed an FFN up/down pair.

    Stand-alone FA2 marks O bypass-all (§V-C); fused, each O tile is read
    ``d_ff/tile`` times by the up-projection, so O becomes a reuse
    carrier with a cross-op ``nAcc`` — the fusion changes the optimal
    cache treatment of the same tensor, which is precisely the dataflow
    information the paper's software interface carries to hardware.
    """
    if wl.group_alloc != TEMPORAL:
        raise ValueError("fused layer uses temporal group allocation")
    if wl.n_batches != 1:
        raise ValueError("single-batch layer only")
    tile = wl.q_block
    if wl.head_dim != tile or d_ff % tile:
        raise ValueError("head_dim must equal q_block; d_ff tile-aligned")
    d_model = wl.n_q_heads * wl.head_dim
    mt, ht = wl.n_q_tiles, wl.n_q_heads
    ft, dt = d_ff // tile, d_model // tile
    tb = tile * tile * wl.dtype_bytes
    b = SpecBuilder(f"{wl.name}-layer", n_cores, workload=wl)

    # --- attention tensors (declaration order mirrors fa2_spec) ---------
    per_core: List[List[int]] = [[] for _ in range(n_cores)]
    for g in range(wl.n_kv_heads):
        per_core[g % n_cores].append(g)
    kv_size = wl.seq_len * wl.head_dim * wl.dtype_bytes
    items: List[tuple] = []
    o_of_head = {}
    for c in range(n_cores):
        for g in per_core[c]:
            kv = tuple(b.tensor(
                f"{kind}.g{g}", size_bytes=kv_size,
                tile_bytes=wl.kv_tile_bytes, n_acc=wl.n_q_tiles,
                operand_id=1) for kind in ("K", "V"))
            q_names, o_names = [], []
            for m_ in range(wl.group_size):
                h = g * wl.group_size + m_
                q_names.append(b.tensor(
                    f"Q.h{h}", size_bytes=kv_size,
                    tile_bytes=wl.q_tile_bytes, n_acc=1, bypass=True))
                # fused: O is consumed by the FFN up-projection
                o = b.tensor(f"O.h{h}", size_bytes=kv_size,
                             tile_bytes=wl.q_tile_bytes, n_acc=ft,
                             operand_id=2)
                o_names.append(o)
                o_of_head[h] = o
            items.append((c, kv, q_names, o_names))

    # --- FFN tensors ----------------------------------------------------
    W_up = b.tensor("W_up", size_bytes=dt * ft * tb, tile_bytes=tb,
                    n_acc=mt, operand_id=1)
    W_dn = b.tensor("W_dn", size_bytes=ft * dt * tb, tile_bytes=tb,
                    n_acc=mt, operand_id=1)
    H = b.tensor("H", size_bytes=mt * ft * tb, tile_bytes=tb,
                 n_acc=dt, operand_id=2)
    Y = b.tensor("Y", size_bytes=mt * dt * tb, tile_bytes=tb,
                 n_acc=1, operand_id=2, bypass=True)

    # --- attention rounds (fa2 temporal schedule: a core's assigned
    # groups interleave at Q-tile granularity, keeping every group's K/V
    # stream live concurrently) ------------------------------------------
    half = wl.flops_per_inner_step() * wl.group_size / 2
    for c in range(n_cores):
        for i in range(wl.n_q_tiles):
            for (_, kv, q_names, o_names) in (it for it in items
                                              if it[0] == c):
                b.step(c, loads=[(q, i) for q in q_names])
                for j in range(_kv_extent(wl, i)):
                    b.step(c, loads=[(kv[0], j)], flops=half)
                    b.step(c, loads=[(kv[1], j)], flops=half)
                b.step(c, stores=[(o, i) for o in o_names])
    b.pad_to_sync()

    # --- FFN rounds: H[m, f] = X @ W_up with X tiles read straight from
    # the per-head O tensors (k-block k is head k's output column) -------
    flops = 2.0 * tile * tile * tile
    for idx, (i, j) in enumerate((i, j) for i in range(mt)
                                 for j in range(ft)):
        core = idx % n_cores
        for k in range(ht):
            b.step(core, loads=[(o_of_head[k], i), (W_up, k * ft + j)],
                   flops=flops)
        b.step(core, stores=[(H, i * ft + j)])
    b.pad_to_sync()
    _emit_matmul(b, H, W_dn, Y, mt, ft, dt, flops)
    return b.build()


# ---------------------------------------------------------------------------
# Speculative decoding: short-lived draft KV epochs + persistent target KV
# ---------------------------------------------------------------------------
def spec_decode_spec(wl: SpecDecodeWorkload,
                     n_cores: int = 16) -> DataflowSpec:
    """Draft/verify cycles over paged KV (ROADMAP scenario candidate).

    Per verification cycle ``r`` and sequence: the draft model streams
    its speculation-window KV ``gamma`` times (one autoregressive pass
    per proposed token), then the target model verifies the batch in one
    pass over its full history *plus* the speculation window (its
    attention over the draft suffix reads the draft-layout KV once
    rather than recomputing it), so the dying window's last touches
    interleave with the persistent target stream.  Draft tensors of
    round ``r`` live in epoch ``r`` only and declare
    ``nAcc = gamma + 1`` — the TMU retires the whole window on exactly
    that verification read.  Under DBP the retired window frees its
    capacity immediately; under LRU it is the *most recently used* dead
    mass sitting on top of the target stream's reuse window, which is
    precisely the §VI-F pollution pattern recurring every cycle.
    """
    if wl.n_seqs % n_cores:
        raise ValueError("n_seqs must be a multiple of n_cores")
    b = SpecBuilder(wl.name, n_cores)

    # persistent target KV, declared first: one contiguous run of tag
    # space per sequence (dead-id / priority granularity, §IV-B)
    target: List[tuple] = []
    for s in range(wl.n_seqs):
        target.append(tuple(b.tensor(
            f"T{kind}.s{s}", size_bytes=wl.n_target_pages * wl.page_bytes,
            tile_bytes=wl.page_bytes, n_acc=wl.n_verify, operand_id=1,
            epoch=(0, wl.n_verify - 1)) for kind in ("K", "V")))
    # per-round draft KV: its own epoch, dies at verification
    draft: List[List[tuple]] = []
    for s in range(wl.n_seqs):
        gens = []
        for r in range(wl.n_verify):
            gens.append(tuple(b.tensor(
                f"D{kind}.s{s}.r{r}",
                size_bytes=wl.n_draft_pages * wl.page_bytes,
                tile_bytes=wl.page_bytes, n_acc=wl.gamma + 1, operand_id=1,
                epoch=(r, r)) for kind in ("K", "V")))
        draft.append(gens)
    # bursty token streams (Q in, accepted-token logits out)
    qo = []
    for s in range(wl.n_seqs):
        tokens = wl.n_verify * (wl.gamma + 1)
        q = b.tensor(f"Q.s{s}", size_bytes=tokens * wl.token_bytes,
                     tile_bytes=wl.token_bytes, n_acc=1, operand_id=0,
                     bypass=True, epoch=(0, wl.n_verify - 1))
        o = b.tensor(f"O.s{s}", size_bytes=wl.n_verify * wl.token_bytes,
                     tile_bytes=wl.token_bytes, n_acc=1, operand_id=2,
                     bypass=True, epoch=(0, wl.n_verify - 1))
        qo.append((q, o))

    half = 2.0 * wl.page_rows * wl.head_dim * wl.n_kv_heads
    for r in range(wl.n_verify):
        for s in range(wl.n_seqs):
            c = s % n_cores
            dk, dv = draft[s][r]
            # draft phase: gamma autoregressive passes over the window
            for t in range(wl.gamma):
                b.step(c, loads=[(qo[s][0], r * (wl.gamma + 1) + t)])
                for p in range(wl.n_draft_pages):
                    b.step(c, loads=[(dk, p)], flops=half)
                    b.step(c, loads=[(dv, p)], flops=half)
            # verify phase: one pass over the full target history with
            # the speculation window's pages interleaved (the target's
            # attention over the draft suffix reads them once more —
            # their last access, so retirement lands mid-stream)
            tk, tv = target[s]
            b.step(c, loads=[(qo[s][0], r * (wl.gamma + 1) + wl.gamma)])
            stride = max(wl.n_target_pages // wl.n_draft_pages, 1)
            d_idx = 0
            for p in range(wl.n_target_pages):
                b.step(c, loads=[(tk, p)], flops=half * wl.gamma)
                b.step(c, loads=[(tv, p)], flops=half * wl.gamma)
                if p % stride == stride - 1 and d_idx < wl.n_draft_pages:
                    b.step(c, loads=[(dk, d_idx)], flops=half)
                    b.step(c, loads=[(dv, d_idx)], flops=half)
                    d_idx += 1
            # windows larger than the target history (n_draft_pages >
            # n_target_pages) finish their verify reads here so every
            # draft page still reaches nAcc = gamma + 1 and retires
            while d_idx < wl.n_draft_pages:
                b.step(c, loads=[(dk, d_idx)], flops=half)
                b.step(c, loads=[(dv, d_idx)], flops=half)
                d_idx += 1
            b.step(c, stores=[(qo[s][1], r)])
        b.pad_to_sync()
    return b.build()


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan: running states die at the next chunk
# ---------------------------------------------------------------------------
def ssd_scan_spec(wl: SSDScanWorkload, n_cores: int = 16) -> DataflowSpec:
    """Chunked SSD scan (``models/ssm.py::ssd_chunked``) on the IR.

    Per chunk ``c`` and sequence: stream the chunk's x/B/C input block
    (bypass class), then head by head read the previous chunk's running
    state (its single ``nAcc`` read — the TMU retires it mid-chunk) and
    store this chunk's freshly materialized state (a *dirty* fill whose
    lifetime runs to the next chunk's recurrence).  The final chunk's
    state is drained once at the end, as ``ssd_chunked`` returns it.
    Read-prev/store-next interleave at head granularity, so under LRU
    the dead previous generation is the *most recently used* mass
    sitting on top of the live one — the recurring §VI-F pollution
    pattern DBP clears at chunk cadence.

    All running states are declared first, **chunk-major at head-slab
    granularity**: tensor ``S.c{c}.h{h}`` holds every sequence's head-h
    tile of chunk c (tile index = sequence).  The TMU's dead identifier
    is a tag-domain slice (``tag[D_MSB:D_LSB]``, §IV-B), so the unit
    that must never straddle a dead-id region is the unit that dies
    *atomically* — and a head slab is exactly that: every core's
    recurrence reads its sequence's tile in the same lockstep round, so
    the whole slab retires at that round's TLL feed and the dead-id
    region it fills flips dead with no live residue.  (A sequence-major
    layout interleaves generations inside one region and DBP would
    victimize still-unread states — the layout is part of the dataflow
    knowledge the software side owes the hardware, cf. `decode_paged_spec`.)
    """
    if wl.n_seqs % n_cores:
        raise ValueError("n_seqs must be a multiple of n_cores")
    b = SpecBuilder(wl.name, n_cores)

    last = wl.n_chunks - 1
    states: List[List[str]] = []
    for c in range(wl.n_chunks):
        states.append([b.tensor(
            f"S.c{c}.h{h}", size_bytes=wl.head_slab_bytes,
            tile_bytes=wl.head_state_bytes, n_acc=1, operand_id=2,
            epoch=(c, min(c + 1, last)))
            for h in range(wl.n_heads)])
    io: List[Tuple[str, str]] = []
    for c in range(wl.n_chunks):
        io.append((
            b.tensor(f"X.c{c}", size_bytes=wl.n_seqs * wl.chunk_in_bytes,
                     tile_bytes=wl.chunk_in_bytes, n_acc=1, operand_id=0,
                     bypass=True, epoch=(c, c)),
            b.tensor(f"Y.c{c}", size_bytes=wl.n_seqs * wl.chunk_out_bytes,
                     tile_bytes=wl.chunk_out_bytes, n_acc=1, operand_id=2,
                     bypass=True, epoch=(c, c))))

    intra_h = wl.intra_flops / wl.n_heads
    inter_h = wl.inter_flops / wl.n_heads
    for c in range(wl.n_chunks):
        for s in range(wl.n_seqs):
            core = s % n_cores
            b.step(core, loads=[(io[c][0], s)])
            for h in range(wl.n_heads):
                if c > 0:
                    # inter-chunk recurrence: the consuming read of the
                    # previous chunk's state (reaches nAcc, retires)
                    b.step(core, loads=[(states[c - 1][h], s)],
                           flops=inter_h)
                b.step(core, stores=[(states[c][h], s)], flops=intra_h)
            b.step(core, stores=[(io[c][1], s)])
        b.pad_to_sync()
    # drain the final state (ssd_chunked returns it): its nAcc read
    for s in range(wl.n_seqs):
        core = s % n_cores
        for h in range(wl.n_heads):
            b.step(core, loads=[(states[last][h], s)])
    b.pad_to_sync()
    return b.build()


# ---------------------------------------------------------------------------
# Prefix-cache sharing: one shared prompt prefix, private suffixes
# ---------------------------------------------------------------------------
def prefix_share_spec(wl: PrefixShareWorkload,
                      n_cores: int = 16) -> DataflowSpec:
    """Decode over a shared prompt prefix plus per-request suffixes.

    All ranks stream the shared prefix KV in lockstep — same-round
    same-page requests merge in the MSHRs (distance-0 inter-core mass),
    while the last rank lags one page so its prefix reuses ride LLC
    *storage*, the population blind bypassing destroys (§IV-E).  The
    per-request suffix KV is private and collectively oversubscribes the
    LLC, supplying the contention that would make a blind controller
    ramp its gear into the shared stream; the suite runs this case under
    the conservative ``gqa_bypass`` variant (only the lagging non-leader
    rank may bypass, and only under measured contention).
    """
    if wl.n_reqs % n_cores:
        raise ValueError("n_reqs must be a multiple of n_cores")
    b = SpecBuilder(wl.name, n_cores)

    # one sharing group spanning all cores; the last rank is the lagging
    # non-leader (the only rank gqa_bypass lets bypass, cf. fa2 spatial)
    b.set_groups([0] * n_cores,
                 [c != n_cores - 1 for c in range(n_cores)])

    pre = tuple(b.tensor(
        f"{kind}pre", size_bytes=wl.n_prefix_pages * wl.page_bytes,
        tile_bytes=wl.page_bytes, n_acc=wl.n_reqs * wl.n_steps,
        operand_id=1, sharers=min(wl.n_reqs, n_cores))
        for kind in ("K", "V"))
    suf: List[tuple] = []
    qo: List[tuple] = []
    for s in range(wl.n_reqs):
        suf.append(tuple(b.tensor(
            f"{kind}suf.s{s}", size_bytes=wl.n_suffix_pages * wl.page_bytes,
            tile_bytes=wl.page_bytes, n_acc=wl.n_steps, operand_id=1)
            for kind in ("K", "V")))
        q = b.tensor(f"Q.s{s}", size_bytes=wl.n_steps * wl.token_bytes,
                     tile_bytes=wl.token_bytes, n_acc=1, operand_id=0,
                     bypass=True)
        o = b.tensor(f"O.s{s}", size_bytes=wl.n_steps * wl.token_bytes,
                     tile_bytes=wl.token_bytes, n_acc=1, operand_id=2,
                     bypass=True)
        qo.append((q, o))

    half = 2.0 * wl.page_rows * wl.head_dim * wl.n_kv_heads
    for t in range(wl.n_steps):
        for s in range(wl.n_reqs):
            c = s % n_cores
            lag = 1 if c == n_cores - 1 else 0
            b.step(c, loads=[(qo[s][0], t)])
            for p in range(wl.n_prefix_pages):
                pp = (p - lag) % wl.n_prefix_pages
                b.step(c, loads=[(pre[0], pp)], flops=half)
                b.step(c, loads=[(pre[1], pp)], flops=half)
            for p in range(wl.n_suffix_pages):
                b.step(c, loads=[(suf[s][0], p)], flops=half)
                b.step(c, loads=[(suf[s][1], p)], flops=half)
            b.step(c, stores=[(qo[s][1], t)])
        b.pad_to_sync()
    return b.build()

"""Reuse-distance profile lowering (DESIGN.md §5/§8.2 — fourth lowering).

``lower_to_reuse_profile(spec)`` walks a :class:`DataflowSpec`'s per-core
round schedule **once** and emits a :class:`ReuseProfile`: for every
repeat access to a reuse-carrier (non-bypass) tile, the *stack distance*
in cache lines since the previous access to the same tile, measured at
round granularity over the burst-synchronous global interleaving
(DESIGN.md §7.2).  The profile is what the analytical model's
``model="profile"`` path evaluates policies against
(`core/analytical.py`): an access hits iff its policy-transformed
distance fits the effective capacity — one evaluation rule for every
replacement/bypass mechanism instead of per-policy closed forms.

Four facts of the schedule that scalar working-set models collapse are
kept explicit:

* **sharer-awareness** — cores are interleaved in the exact lockstep
  order the simulator executes, so inter-core co-streaming shows up as
  short distances (the lagging rank of a sharing group) or as
  distance-0 MSHR merges (same-round same-tile requests), exactly the
  population blind bypassing destroys (paper §IV-E);
* **epoch-awareness** — each distance is split into *live* mass and
  *dead* mass.  A tile is dead once its load count reaches the declared
  ``n_acc`` (the TMU's retirement rule, paper §IV-B); dead tiles of
  retired working-set generations contribute pollution that DBP removes
  (``d_live``) and every other policy suffers (``d_live + d_dead``);
* **priority tiers** — each entry records its tile's first line address,
  so the model can recover the hardware's ``tag[B_BITS-1:0]`` priority
  tier for any cache geometry (anti-thrashing protection and bypass
  gears partition reuse mass by exactly these bits);
* **dirty lifetimes** — entries carry store flags and per-tile chain
  indices, and the tile table carries cold-store flags and tail
  distances, so the model can propagate P(dirty) along each tile's
  access sequence and price write-backs by when a dirtied tile actually
  ages past capacity (the §V-B dirty-eviction traffic term).

The walk is O(accesses · log accesses) at *tile* granularity (two
Fenwick trees over the access sequence), so paper-scale suite specs
profile in milliseconds — cheap enough to thread through
``lower_to_counts`` by default.
"""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import field
from typing import Dict
from typing import List
from typing import Optional
from typing import Tuple

import numpy as np

from .ir import DataflowSpec


class _Fenwick:
    """Prefix-sum tree over access positions (weights = lines)."""

    __slots__ = ("n", "t")

    def __init__(self, n: int):
        self.n = n
        self.t = [0] * (n + 1)

    def add(self, i: int, v: int) -> None:
        i += 1
        while i <= self.n:
            self.t[i] += v
            i += i & -i

    def prefix(self, i: int) -> int:
        """Sum of weights at positions [0, i]."""
        i += 1
        s = 0
        while i > 0:
            s += self.t[i]
            i -= i & -i
        return s

    def range(self, a: int, b: int) -> int:
        """Sum of weights at positions [a, b] (inclusive); 0 if empty."""
        if b < a:
            return 0
        return self.prefix(b) - (self.prefix(a - 1) if a > 0 else 0)


@dataclass
class ReuseProfile:
    """Round-granularity reuse-distance profile of one dataflow.

    **Reuse entries** (one per repeat access to a reuse-carrier tile;
    parallel arrays):

    * ``e_round``     lockstep round of the access
    * ``e_tensor``    tensor index (declaration order)
    * ``e_line``      first line index of the tile (absolute, for
                      geometry-exact ``tag[B_BITS-1:0]`` tier recovery)
    * ``e_mass``      lines in the tile (the entry's request mass)
    * ``e_dlive``     live stack distance in lines (distinct
                      still-live mass touched since the previous access)
    * ``e_ddead``     dead mass in the same window (TMU-retired tiles —
                      the pollution DBP removes)
    * ``e_intercore`` previous access was issued by another core
    * ``e_mshr``      same-round merge (distance 0, MSHR hit)
    * ``e_store``     the access is a store (dirties the line —
                      write-allocate; input to the dirty-lifetime model)
    * ``e_tile``      index into the distinct-tile table below, so the
                      model can chain a tile's accesses (dirty-bit
                      propagation needs the access *sequence* per tile,
                      not just marginal distances)
    * ``e_prev_round`` round of the tile's previous access — the gear
                      trajectory needs it to know whether the line's
                      last fill was *allocated* (bypass decisions are
                      made at fill time, so a tier bypassed now may
                      still be resident from a lower-gear window)

    **Per-round traffic** that is not reuse, kept per tenant (second
    axis; single-tenant specs have one column): ``cold_rt`` (first
    touches of reuse carriers), ``byp_cold_rt`` / ``byp_rep_rt``
    (whole-tensor-bypass Q/O traffic, first touch vs repeat).  The
    tenant-summed views remain available as ``cold_round`` /
    ``byp_cold_round`` / ``byp_rep_round``; ``flops_round`` stays
    global.  (Write-back volume is not a per-round tally here: the
    model derives it from the dirty-lifetime facts below.)

    **Tenant attribution** (multi-tenant composites, DESIGN.md §8.4):
    ``tenant_names`` and ``tenant_of_tensor`` (tensor index → tenant)
    plus ``t_tensor`` (tile → tensor index) let every mass above be
    keyed by tenant — ``e_tenant`` / ``t_tenant`` are the derived
    per-entry / per-tile tenant indices the model's per-slice gear mode
    evaluates against.

    **Footprint** facts for tier partitioning: the distinct tile table
    (``t_line``/``t_mass``/``t_dies``) and ``max_live_lines`` — the peak
    concurrently-live stack mass (the profile-derived active working
    set).

    **Dirty-lifetime** facts (DESIGN.md §5, the write-back model): per
    tile, whether its *first* touch was a store (``t_cold_store`` —
    produced-then-consumed tensors allocate dirty), the round of its
    last access (``t_last_round``), and the tile's *tail* stack distance
    ``t_tail_dlive``/``t_tail_ddead`` — distinct live/dead mass touched
    between the tile's final access and the end of the schedule.  A tile
    still dirty at its last access writes back iff that forward distance
    ages it past capacity (the same distance-vs-capacity rule hits are
    evaluated under); distances from a store to the tile's next access
    are already the reuse entries themselves (``e_store`` marks them).
    """

    name: str
    line_bytes: int
    n_rounds: int
    tensor_names: List[str]
    e_round: np.ndarray
    e_tensor: np.ndarray
    e_line: np.ndarray
    e_mass: np.ndarray
    e_dlive: np.ndarray
    e_ddead: np.ndarray
    e_intercore: np.ndarray
    e_mshr: np.ndarray
    e_store: np.ndarray
    e_tile: np.ndarray
    e_prev_round: np.ndarray
    cold_rt: np.ndarray                # (n_rounds, n_tenants)
    byp_cold_rt: np.ndarray            # (n_rounds, n_tenants)
    byp_rep_rt: np.ndarray             # (n_rounds, n_tenants)
    flops_round: np.ndarray
    t_line: np.ndarray
    t_mass: np.ndarray
    t_tensor: np.ndarray               # tile → tensor index
    t_dies: np.ndarray                 # tile reaches n_acc (TMU-retired)
    t_cold_store: np.ndarray           # first touch was a store (dirty fill)
    t_cold_round: np.ndarray           # round of the tile's first touch
    t_last_round: np.ndarray           # round of the tile's final access
    t_tail_dlive: np.ndarray           # live mass after the final access
    t_tail_ddead: np.ndarray           # dead mass after the final access
    max_live_lines: int
    tenant_names: List[str] = field(default_factory=lambda: ["t0"])
    tenant_of_tensor: np.ndarray = field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))
    _eval_cache: Dict[tuple, dict] = field(default_factory=dict,
                                           init=False, repr=False,
                                           compare=False)

    # ------------------------------------------------------------------
    @property
    def n_entries(self) -> int:
        return int(self.e_mass.shape[0])

    @property
    def n_tenants(self) -> int:
        return len(self.tenant_names)

    @property
    def cold_round(self) -> np.ndarray:
        return self.cold_rt.sum(axis=1)

    @property
    def byp_cold_round(self) -> np.ndarray:
        return self.byp_cold_rt.sum(axis=1)

    @property
    def byp_rep_round(self) -> np.ndarray:
        return self.byp_rep_rt.sum(axis=1)

    @property
    def e_tenant(self) -> np.ndarray:
        return self.tenant_of_tensor[self.e_tensor]

    @property
    def t_tenant(self) -> np.ndarray:
        return self.tenant_of_tensor[self.t_tensor]

    def total_reuse_mass(self) -> int:
        """Total repeat-access mass in lines — pinned equal to
        ``DataflowCounts.n_temporal_reuse + n_intercore_reuse``."""
        return int(self.e_mass.sum())

    def intercore_reuse_mass(self) -> int:
        return int(self.e_mass[self.e_intercore].sum())

    def footprint_lines(self) -> int:
        """Distinct reuse-carrier lines ever touched
        (== ``DataflowCounts.n_kv_distinct``)."""
        return int(self.t_mass.sum())

    def histogram(self, tensor: Optional[str] = None,
                  dbp: bool = False) -> Dict[int, int]:
        """Reuse-distance histogram ``{distance_lines: mass_lines}``.

        ``dbp=True`` buckets by the live distance only (retired-epoch
        pollution removed); default is the full LRU stack distance
        ``d_live + d_dead``.  Restrict to one tensor by name.
        """
        d = self.e_dlive if dbp else self.e_dlive + self.e_ddead
        mass = self.e_mass
        if tensor is not None:
            sel = self.e_tensor == self.tensor_names.index(tensor)
            d, mass = d[sel], mass[sel]
        out: Dict[int, int] = {}
        for dist, m in zip(d.tolist(), mass.tolist()):
            out[dist] = out.get(dist, 0) + m
        return out


# ---------------------------------------------------------------------------
def lower_to_reuse_profile(spec: DataflowSpec) -> ReuseProfile:
    """Derive the :class:`ReuseProfile` from one schedule walk.

    Accesses are visited in the simulator's global order (round-major,
    core order within a round, loads before stores within a step).
    Same-round repeat accesses to a tile merge MSHR-style into
    distance-0 entries; otherwise the distance is the distinct tile mass
    (in lines) touched since the tile's previous access, split into live
    and TMU-dead components by two Fenwick trees over the sequence.
    """
    from .lower import assign_addresses      # lazy: lower.py imports us

    metas = assign_addresses(spec)
    lb = spec.line_bytes
    n_rounds = spec.n_rounds

    lines_per_tile = [t.tile_bytes // lb for t in spec.tensors]
    start_line = [metas[i].base_addr // lb for i in range(len(spec.tensors))]
    n_acc = [t.n_acc for t in spec.tensors]
    is_bypass = [t.bypass for t in spec.tensors]
    if spec.tenant_of_tensor is not None and spec.tenant_names:
        tenant_names = list(spec.tenant_names)
        tn_of = [spec.tenant_of_tensor[t.name] for t in spec.tensors]
    else:
        tenant_names = [spec.name]
        tn_of = [0] * len(spec.tensors)
    n_ten = len(tenant_names)

    # ---- pass 1: flatten the schedule into the global access sequence
    # (reuse carriers only; bypass traffic is tallied per round directly)
    seq_round: List[int] = []
    seq_core: List[int] = []
    seq_tid: List[int] = []
    seq_tile: List[int] = []
    seq_store: List[bool] = []
    cold_rt = np.zeros((n_rounds, n_ten), dtype=np.int64)
    byp_cold_rt = np.zeros((n_rounds, n_ten), dtype=np.int64)
    byp_rep_rt = np.zeros((n_rounds, n_ten), dtype=np.int64)
    flops_round = np.zeros(n_rounds, dtype=np.float64)
    byp_seen: set = set()
    tid_of = {t.name: i for i, t in enumerate(spec.tensors)}

    for r in range(n_rounds):
        for c, prog in enumerate(spec.core_programs):
            if r >= len(prog):
                continue
            step = prog[r]
            flops_round[r] += step.flops
            for (tname, tile), is_store in (
                    [(ld, False) for ld in step.loads]
                    + [(s, True) for s in step.stores]):
                tid = tid_of[tname]
                if is_bypass[tid]:
                    key = (tid, tile)
                    if key in byp_seen:
                        byp_rep_rt[r, tn_of[tid]] += lines_per_tile[tid]
                    else:
                        byp_seen.add(key)
                        byp_cold_rt[r, tn_of[tid]] += lines_per_tile[tid]
                    continue
                seq_round.append(r)
                seq_core.append(c)
                seq_tid.append(tid)
                seq_tile.append(tile)
                seq_store.append(is_store)

    # ---- pass 2: weighted stack distances over the sequence
    P = len(seq_round)
    live = _Fenwick(P)
    dead = _Fenwick(P)
    # per-tile state: [position, core, round, in_dead_tree, load_count]
    state: Dict[Tuple[int, int], list] = {}
    tile_info: Dict[Tuple[int, int], Tuple[int, int]] = {}  # key → (line, mass)
    tile_idx: Dict[Tuple[int, int], int] = {}               # key → table index
    tile_died: set = set()
    cold_store: List[bool] = []        # per table index: first touch a store
    cold_rnd: List[int] = []           # per table index: first-touch round
    live_total = 0
    max_live = 0

    e_round: List[int] = []
    e_tensor: List[int] = []
    e_line: List[int] = []
    e_mass: List[int] = []
    e_dlive: List[int] = []
    e_ddead: List[int] = []
    e_intercore: List[bool] = []
    e_mshr: List[bool] = []
    e_store: List[bool] = []
    e_tile: List[int] = []
    e_prev_round: List[int] = []

    for i in range(P):
        r, c = seq_round[i], seq_core[i]
        tid, tile = seq_tid[i], seq_tile[i]
        is_store = seq_store[i]
        key = (tid, tile)
        mass = lines_per_tile[tid]
        line = start_line[tid] + tile * mass

        st = state.get(key)
        if st is not None and st[2] == r:
            # same-round duplicate: merges in the MSHRs — an in-flight
            # fill exists whatever the policy, so this is always a hit
            e_round.append(r)
            e_tensor.append(tid)
            e_line.append(line)
            e_mass.append(mass)
            e_dlive.append(0)
            e_ddead.append(0)
            e_intercore.append(c != st[1])
            e_mshr.append(True)
            e_store.append(is_store)
            e_tile.append(tile_idx[key])
            e_prev_round.append(st[2])
            if not is_store:
                st[4] += 1
                if st[4] >= n_acc[tid] and not st[3]:
                    # the merged load still bumps accCnt: move the
                    # tile's stack weight into the dead tree in place
                    live.add(st[0], -mass)
                    dead.add(st[0], mass)
                    st[3] = True
                    live_total -= mass
                    tile_died.add(key)
            continue

        if st is not None:
            p = st[0]
            d_live = live.range(p + 1, i - 1)
            d_dead = dead.range(p + 1, i - 1)
            e_round.append(r)
            e_tensor.append(tid)
            e_line.append(line)
            e_mass.append(mass)
            e_dlive.append(d_live)
            e_ddead.append(d_dead)
            e_intercore.append(c != st[1])
            e_mshr.append(False)
            e_store.append(is_store)
            e_tile.append(tile_idx[key])
            e_prev_round.append(st[2])
            (dead if st[3] else live).add(p, -mass)
            if not st[3]:
                live_total -= mass
        else:
            cold_rt[r, tn_of[tid]] += mass
            tile_idx[key] = len(tile_info)
            tile_info[key] = (line, mass)
            cold_store.append(is_store)
            cold_rnd.append(r)

        cnt = (st[4] if st is not None else 0) + (0 if is_store else 1)
        dies = cnt >= n_acc[tid]
        (dead if dies else live).add(i, mass)
        if dies:
            tile_died.add(key)
        else:
            live_total += mass
            if live_total > max_live:
                max_live = live_total
        state[key] = [i, c, r, dies, cnt]

    keys = list(tile_info)
    # tail distances: distinct live/dead mass touched after each tile's
    # final access (its remaining window to survive to end-of-schedule —
    # the dirty-lifetime model's eviction rule for still-dirty tiles)
    n_t = len(keys)
    tail_dlive = np.zeros(n_t, dtype=np.int64)
    tail_ddead = np.zeros(n_t, dtype=np.int64)
    last_round = np.zeros(n_t, dtype=np.int64)
    for key, st in state.items():
        idx = tile_idx[key]
        tail_dlive[idx] = live.range(st[0] + 1, P - 1)
        tail_ddead[idx] = dead.range(st[0] + 1, P - 1)
        last_round[idx] = st[2]
    return ReuseProfile(
        name=spec.name, line_bytes=lb, n_rounds=n_rounds,
        tensor_names=[t.name for t in spec.tensors],
        e_round=np.asarray(e_round, dtype=np.int64),
        e_tensor=np.asarray(e_tensor, dtype=np.int64),
        e_line=np.asarray(e_line, dtype=np.int64),
        e_mass=np.asarray(e_mass, dtype=np.int64),
        e_dlive=np.asarray(e_dlive, dtype=np.int64),
        e_ddead=np.asarray(e_ddead, dtype=np.int64),
        e_intercore=np.asarray(e_intercore, dtype=bool),
        e_mshr=np.asarray(e_mshr, dtype=bool),
        e_store=np.asarray(e_store, dtype=bool),
        e_tile=np.asarray(e_tile, dtype=np.int64),
        e_prev_round=np.asarray(e_prev_round, dtype=np.int64),
        cold_rt=cold_rt, byp_cold_rt=byp_cold_rt,
        byp_rep_rt=byp_rep_rt, flops_round=flops_round,
        t_line=np.asarray([tile_info[k][0] for k in keys], dtype=np.int64),
        t_mass=np.asarray([tile_info[k][1] for k in keys], dtype=np.int64),
        t_tensor=np.asarray([k[0] for k in keys], dtype=np.int64),
        t_dies=np.asarray([k in tile_died for k in keys], dtype=bool),
        t_cold_store=np.asarray(cold_store, dtype=bool),
        t_cold_round=np.asarray(cold_rnd, dtype=np.int64),
        t_last_round=last_round,
        t_tail_dlive=tail_dlive, t_tail_ddead=tail_ddead,
        max_live_lines=int(max_live),
        tenant_names=tenant_names,
        tenant_of_tensor=np.asarray(tn_of, dtype=np.int64),
    )

"""Content-addressed artifact cache for the lowering pipeline.

Lowered traces, reuse-distance profiles, and geometry plans are pure
functions of a :class:`~repro.dataflows.ir.DataflowSpec`, yet every
process that needs one (suite_bench, the CI smoke loop, scripts/
suite_gate.py re-runs, tests) used to recompute it from scratch.  This
module gives each spec a **deterministic content fingerprint** and keys
the lowered artifacts by it on disk, so the second consumer of a spec —
in this process, another process, or another session — loads arrays
instead of re-walking schedules.

Keying scheme (DESIGN.md §8.5):

* ``spec_fingerprint(spec)`` — SHA-256 over a canonical byte
  serialization of the spec *content*: dataclass fields in declaration
  order, dict items sorted by key, floats via ``repr`` (exact for IEEE
  doubles), numpy arrays via dtype + shape + raw bytes.  No Python
  ``hash()``, no ``id()``, no dict iteration order — two fresh
  interpreters agree on the fingerprint and any field edit changes it
  (pinned by tests/test_artifacts.py).
* the on-disk key additionally folds in a **code-version salt** (hash
  of the lowering sources) so editing ``lower.py``/``reuse.py``/
  ``traces.py`` invalidates every cached artifact instead of serving
  stale lowerings;
* artifact kinds carry their own parameters in the key — the compiled
  trace by ``line_bytes``, plans by ``(num_sets, hash_sets)``.

Writes are atomic (temp file + ``os.replace``) so concurrent suite
workers never observe a torn artifact; unreadable or truncated files
are treated as misses and rebuilt.  Set ``REPRO_ARTIFACTS=0`` to
disable the cache, ``REPRO_ARTIFACT_DIR`` to relocate it (default:
``<repo>/.cache/artifacts``).
"""

from __future__ import annotations

from dataclasses import fields
from dataclasses import is_dataclass
import hashlib
import json
import os
from pathlib import Path
import tempfile
from typing import Dict
from typing import Optional

import numpy as np

_FORMAT_VERSION = "3"     # 3: compiled traces grew the u_tid column

#: lowering sources whose bytes salt the on-disk key: an edit to any of
#: them must invalidate cached artifacts (the fingerprint itself stays a
#: pure content hash)
_VERSIONED_SOURCES = ("ir.py", "lower.py", "addr.py", "reuse.py",
                      "compose.py", "../core/traces.py")


# ---------------------------------------------------------------------------
# deterministic content fingerprint
# ---------------------------------------------------------------------------
def _fold(h, obj) -> None:
    """Fold one value into the hash with an unambiguous type-tagged
    encoding (length-prefixed strings, declaration-ordered dataclass
    fields, key-sorted dicts)."""
    if obj is None:
        h.update(b"N;")
    elif obj is True:
        h.update(b"T;")
    elif obj is False:
        h.update(b"F;")
    elif isinstance(obj, (int, np.integer)):
        h.update(b"i%d;" % int(obj))
    elif isinstance(obj, (float, np.floating)):
        # repr round-trips IEEE doubles exactly and is platform-stable
        h.update(b"f" + repr(float(obj)).encode() + b";")
    elif isinstance(obj, str):
        b = obj.encode("utf-8")
        h.update(b"s%d:" % len(b) + b)
    elif isinstance(obj, bytes):
        h.update(b"b%d:" % len(obj) + obj)
    elif isinstance(obj, np.ndarray):
        a = np.ascontiguousarray(obj)
        h.update(b"a" + str(a.dtype).encode() + b"|"
                 + repr(a.shape).encode() + b"|")
        h.update(a.tobytes())
    elif is_dataclass(obj) and not isinstance(obj, type):
        h.update(b"D" + type(obj).__name__.encode() + b"{")
        for f in fields(obj):
            if f.name.startswith("_"):
                continue             # caches et al. are not content
            h.update(f.name.encode() + b"=")
            _fold(h, getattr(obj, f.name))
        h.update(b"}")
    elif isinstance(obj, (list, tuple)):
        h.update(b"[" if isinstance(obj, list) else b"(")
        for x in obj:
            _fold(h, x)
        h.update(b"]" if isinstance(obj, list) else b")")
    elif isinstance(obj, dict):
        h.update(b"{")
        for k in sorted(obj, key=lambda k: (type(k).__name__, str(k))):
            _fold(h, k)
            h.update(b":")
            _fold(h, obj[k])
        h.update(b"}")
    else:
        raise TypeError(
            f"cannot canonically serialize {type(obj).__name__} for the "
            f"spec fingerprint")


def spec_fingerprint(spec) -> str:
    """Deterministic SHA-256 content hash of a :class:`DataflowSpec`.

    Stable across processes and sessions; memoized on the spec object
    (specs are frozen after ``SpecBuilder.build``)."""
    cached = spec.__dict__.get("_dco_fingerprint")
    if cached is not None:
        return cached
    h = hashlib.sha256()
    _fold(h, spec)
    fp = h.hexdigest()
    spec.__dict__["_dco_fingerprint"] = fp
    return fp


def try_spec_fingerprint(spec) -> Optional[str]:
    """Like :func:`spec_fingerprint` but ``None`` when the spec carries
    content outside the canonical-serialization domain (exotic workload
    objects, or no ``__dict__`` to memoize on) — the lowerings then
    simply skip the artifact cache."""
    try:
        return spec_fingerprint(spec)
    except (TypeError, AttributeError):
        return None


# ---------------------------------------------------------------------------
# on-disk store
# ---------------------------------------------------------------------------
def artifacts_enabled() -> bool:
    return os.environ.get("REPRO_ARTIFACTS", "1") != "0"


def cache_dir() -> Path:
    env = os.environ.get("REPRO_ARTIFACT_DIR")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / ".cache" / "artifacts"


_code_version_cache: Optional[str] = None


def code_version() -> str:
    """Hash of the lowering sources — the artifact-key salt."""
    global _code_version_cache
    if _code_version_cache is None:
        h = hashlib.sha256()
        h.update(_FORMAT_VERSION.encode())
        here = Path(__file__).resolve().parent
        for rel in _VERSIONED_SOURCES:
            try:
                h.update((here / rel).read_bytes())
            except OSError:
                h.update(b"?")
        _code_version_cache = h.hexdigest()[:16]
    return _code_version_cache


def _path(kind: str, key: str) -> Path:
    return cache_dir() / f"{kind}-{key}-{code_version()}.npz"


def load_arrays(kind: str, key: str) -> Optional[Dict[str, np.ndarray]]:
    """Load one artifact; ``None`` on miss, disabled cache, or a
    corrupt/unreadable file (callers rebuild and re-store)."""
    if not artifacts_enabled():
        return None
    path = _path(kind, key)
    try:
        with np.load(path, allow_pickle=False) as z:
            return {k: z[k] for k in z.files}
    except Exception:
        return None


def store_arrays(kind: str, key: str,
                 arrays: Dict[str, np.ndarray]) -> None:
    """Atomically persist one artifact (temp file + rename), so pooled
    suite workers racing on the same key never see a torn file."""
    if not artifacts_enabled():
        return
    path = _path(kind, key)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    except OSError:
        pass                          # cache is best-effort, never fatal


def _json_blob(obj) -> np.ndarray:
    return np.frombuffer(json.dumps(obj).encode("utf-8"), dtype=np.uint8)


def _json_unblob(arr: np.ndarray):
    return json.loads(bytes(arr.tobytes()).decode("utf-8"))


# ---------------------------------------------------------------------------
# typed artifact adapters
# ---------------------------------------------------------------------------
_CT_ARRAYS = ("u_addrs", "u_dense", "u_write", "u_force", "u_nonleader",
              "u_core", "u_tid", "u_dups", "round_off", "n_acc_round",
              "flops_round", "tll_addrs", "tll_tids", "tll_tiles",
              "tll_nacc", "tll_off")


def compiled_trace_key(fingerprint: str, line_bytes: int) -> str:
    return f"{fingerprint}-lb{line_bytes}"


def store_compiled_trace(key: str, ct) -> None:
    arrays = {name: getattr(ct, name) for name in _CT_ARRAYS}
    arrays["scalars"] = np.asarray(
        [ct.line_bytes, ct.n_rounds, ct.n_seen_lines], dtype=np.int64)
    store_arrays("trace", key, arrays)


def load_compiled_trace(key: str):
    z = load_arrays("trace", key)
    if z is None or "scalars" not in z:
        return None
    from repro.core.traces import CompiledTrace
    lb, n_rounds, n_seen = (int(x) for x in z["scalars"])
    return CompiledTrace(lb, n_rounds, n_seen,
                         *(z[name] for name in _CT_ARRAYS))


_PROF_ARRAYS = ("e_round", "e_tensor", "e_line", "e_mass", "e_dlive",
                "e_ddead", "e_intercore", "e_mshr", "e_store", "e_tile",
                "e_prev_round", "cold_rt", "byp_cold_rt", "byp_rep_rt",
                "flops_round", "t_line", "t_mass", "t_tensor", "t_dies",
                "t_cold_store", "t_cold_round", "t_last_round",
                "t_tail_dlive", "t_tail_ddead", "tenant_of_tensor")


def store_reuse_profile(key: str, prof) -> None:
    arrays = {name: getattr(prof, name) for name in _PROF_ARRAYS}
    arrays["meta"] = _json_blob({
        "name": prof.name, "line_bytes": prof.line_bytes,
        "n_rounds": prof.n_rounds, "tensor_names": prof.tensor_names,
        "max_live_lines": prof.max_live_lines,
        "tenant_names": prof.tenant_names,
    })
    store_arrays("profile", key, arrays)


def load_reuse_profile(key: str):
    z = load_arrays("profile", key)
    if z is None or "meta" not in z:
        return None
    from .reuse import ReuseProfile
    meta = _json_unblob(z["meta"])
    return ReuseProfile(
        name=meta["name"], line_bytes=meta["line_bytes"],
        n_rounds=meta["n_rounds"], tensor_names=list(meta["tensor_names"]),
        max_live_lines=meta["max_live_lines"],
        tenant_names=list(meta["tenant_names"]),
        **{name: z[name] for name in _PROF_ARRAYS})


def plan_key(trace_key: str, num_sets: int, hash_sets: bool) -> str:
    return f"{trace_key}-s{num_sets}-h{int(hash_sets)}"


def store_plan_pass_idx(key: str, pass_idx: np.ndarray) -> None:
    store_arrays("plan", key, {"pass_idx": pass_idx})


def load_plan_pass_idx(key: str) -> Optional[np.ndarray]:
    z = load_arrays("plan", key)
    if z is None or "pass_idx" not in z:
        return None
    return z["pass_idx"]

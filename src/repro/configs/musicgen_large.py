"""MusicGen-Large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L, d_model 2048, 32 heads (MHA), d_ff 8192,
vocab 2048 (one EnCodec codebook; the audio frontend — EnCodec encoder and
the codebook delay pattern — is a stub per spec: ``input_specs`` provides
precomputed frame token ids).
"""
from repro.configs import ArchConfig
from repro.configs import DENSE

ARCH = ArchConfig(
    name="musicgen-large", family=DENSE,
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, act="gelu", rope_theta=10_000.0,
    tie_embeddings=False, modality_stub="audio",
)

"""Llama-3.2-3B — small dense Llama3.

[hf:meta-llama/Llama-3.2-3B; unverified]  28L, d_model 3072, 24H GQA kv=8,
head_dim 128, d_ff 8192, vocab 128256, rope theta 500k.
"""
from repro.configs import ArchConfig
from repro.configs import DENSE

ARCH = ArchConfig(
    name="llama3.2-3b", family=DENSE,
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=128256, rope_theta=500_000.0,
)

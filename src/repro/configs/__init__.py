"""Architecture configs (one module per assigned arch) + shape grid.

``get_arch(name)`` returns the full published config; ``reduce_for_smoke``
shrinks it to a CPU-runnable size with the same structure (family, GQA
ratio, MoE top-k, SSD chunking all preserved).
"""

from __future__ import annotations

from dataclasses import dataclass
from dataclasses import replace
import importlib
from typing import Dict
from typing import Optional
from typing import Tuple

DENSE, MOE, SSM, HYBRID = "dense", "moe", "ssm", "hybrid"


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    first_dense: int = 0          # leading layers with dense FFN


@dataclass(frozen=True)
class SSMSpec:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    n_groups: int = 1


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"
    rope_theta: float = 1e4
    mrope_sections: Optional[Tuple[int, int, int]] = None
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    window: Optional[int] = None
    local_global_period: Optional[int] = None  # every Nth layer is global
    attn_scale: Optional[float] = None
    qk_norm: bool = False
    gemma_norm: bool = False
    tie_embeddings: bool = True
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    hybrid_period: Optional[int] = None        # shared attn every N ssm layers
    sub_quadratic: bool = False                # supports long_500k
    modality_stub: Optional[str] = None        # "audio" | "vision" frontends

    @property
    def attention_free(self) -> bool:
        return self.family == SSM


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_NAMES = [
    "musicgen_large", "zamba2_7b", "mamba2_2p7b", "qwen2_vl_7b",
    "gemma2_27b", "llama3p2_3b", "mistral_nemo_12b", "gemma_7b",
    "deepseek_moe_16b", "moonshot_v1_16b_a3b",
]

_ALIASES = {
    "musicgen-large": "musicgen_large",
    "zamba2-7b": "zamba2_7b",
    "mamba2-2.7b": "mamba2_2p7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "gemma2-27b": "gemma2_27b",
    "llama3.2-3b": "llama3p2_3b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "gemma-7b": "gemma_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
}


def get_arch(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name)
    if mod_name not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.ARCH


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is (arch × shape) runnable? Returns (ok, reason-if-skipped).

    Per spec: long_500k needs sub-quadratic context handling — skipped for
    pure full-attention archs (documented in DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k requires sub-quadratic"
    return True, ""


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Shrink to a single-CPU testable size preserving the family shape."""
    changes = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 64) if cfg.window else None,
    )
    if cfg.moe:
        # capacity_factor = E/k → capacity ≥ tokens: no drops, so prefill
        # vs full-forward equivalence is exact in the smoke tests
        changes["moe"] = replace(cfg.moe, n_experts=8, top_k=2,
                                 d_ff_expert=64,
                                 n_shared=min(cfg.moe.n_shared, 1),
                                 first_dense=min(cfg.moe.first_dense, 1),
                                 capacity_factor=4.0)
    if cfg.ssm:
        changes["ssm"] = replace(cfg.ssm, d_state=16, head_dim=32, chunk=32)
    if cfg.hybrid_period:
        changes["n_layers"] = 4
        changes["hybrid_period"] = 2
    if cfg.n_kv_heads == cfg.n_heads:        # preserve MHA
        changes["n_kv_heads"] = changes["n_heads"]
    if cfg.mrope_sections:
        changes["mrope_sections"] = (8, 12, 12)   # sums to head_dim/2 = 32
    return replace(cfg, **changes)

"""Gemma-7B — dense, GeGLU, head_dim 256.

[arXiv:2403.08295; hf]  28L, d_model 3072, 16H (kv=16: MHA on 7b; MQA is
the 2b variant), head_dim 256, d_ff 24576, vocab 256000, GeGLU.
"""
from repro.configs import ArchConfig
from repro.configs import DENSE

ARCH = ArchConfig(
    name="gemma-7b", family=DENSE,
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, act="gelu", gemma_norm=True,
)

"""Qwen2-VL-7B — VLM backbone with M-RoPE (dynamic resolution frontend
stubbed).

[arXiv:2409.12191; hf]  28L, d_model 3584, 28H GQA kv=4, d_ff 18944,
vocab 152064.  M-RoPE splits rotary frequencies into temporal/height/width
sections (16, 24, 24 half-dims).  The vision tower is a stub per spec:
``input_specs`` provides token ids + 3-plane position ids.
"""
from repro.configs import ArchConfig
from repro.configs import DENSE

ARCH = ArchConfig(
    name="qwen2-vl-7b", family=DENSE,
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, rope_theta=1e6,
    mrope_sections=(16, 24, 24), tie_embeddings=False,
    modality_stub="vision",
)

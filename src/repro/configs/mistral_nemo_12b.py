"""Mistral-Nemo-12B — dense, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf]  40L, d_model 5120, 32H GQA
kv=8, head_dim 128, d_ff 14336, vocab 131072, rope theta 1e6.
"""
from repro.configs import ArchConfig
from repro.configs import DENSE

ARCH = ArchConfig(
    name="mistral-nemo-12b", family=DENSE,
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1e6, tie_embeddings=False,
)

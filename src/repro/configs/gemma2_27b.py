"""Gemma2-27B — dense, local+global alternating attention, logit softcap.

[arXiv:2408.00118; hf]  46L, d_model 4608, 32H GQA kv=16, head_dim 128,
d_ff 36864, vocab 256000; sliding window 4096 on local layers (every other
layer global), attention softcap 50.0, final-logit softcap 30.0,
query scale (d_model/n_heads)^-0.5 = 144^-0.5.
"""
from repro.configs import ArchConfig
from repro.configs import DENSE

ARCH = ArchConfig(
    name="gemma2-27b", family=DENSE,
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000, act="gelu",
    attn_softcap=50.0, final_softcap=30.0,
    window=4096, local_global_period=2,
    attn_scale=144.0 ** -0.5, gemma_norm=True,
)

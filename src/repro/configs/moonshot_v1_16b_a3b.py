"""Moonlight-16B-A3B (moonshot-v1-16b-a3b) — MoE 64e top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L, d_model 2048, 16H GQA kv=16,
head_dim 128, expert d_ff 1408, 2 shared experts, vocab 163840, first
layer dense.
"""
from repro.configs import ArchConfig
from repro.configs import MOE
from repro.configs import MoESpec

ARCH = ArchConfig(
    name="moonshot-v1-16b-a3b", family=MOE,
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=11264, vocab=163840,
    moe=MoESpec(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                first_dense=1),
)

"""Mamba2-2.7B — pure SSM (SSD / state-space duality), attention-free.

[arXiv:2405.21060; unverified]  64L, d_model 2560, d_state 128,
expand 2 → d_inner 5120, head_dim 64 → 80 SSD heads.  DCO-applicability:
attention-free → the paper's KV-cache bypass/anti-thrash policies do not
apply (DESIGN.md §4); the SSD chunk-state lifetime still maps to the
dead-block insight.
"""
from repro.configs import ArchConfig
from repro.configs import SSM
from repro.configs import SSMSpec

ARCH = ArchConfig(
    name="mamba2-2.7b", family=SSM,
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280,
    ssm=SSMSpec(d_state=128, expand=2, head_dim=64, chunk=256),
    sub_quadratic=True,
)

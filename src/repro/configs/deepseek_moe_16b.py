"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed, top-6.

[arXiv:2401.06066; hf]  28L, d_model 2048, 16H MHA kv=16, head_dim 128,
expert d_ff 1408, vocab 102400; layer 0 uses a dense FFN (intermediate
10944 in the published model — we use 8*1408=11264-class width via
cfg.d_ff=10944).
"""
from repro.configs import ArchConfig
from repro.configs import MOE
from repro.configs import MoESpec

ARCH = ArchConfig(
    name="deepseek-moe-16b", family=MOE,
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab=102400,
    moe=MoESpec(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                first_dense=1),
)

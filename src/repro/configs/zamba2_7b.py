"""Zamba2-7B — hybrid: Mamba2 backbone + shared attention block.

[arXiv:2411.15242; unverified]  81 Mamba2 layers (d_model 3584, ssm_state
64) with a single weight-shared transformer block (32H MHA kv=32, d_ff
14336) applied every ``hybrid_period`` Mamba layers.  Deviation noted in
DESIGN.md: the published model alternates two shared blocks with LoRA
projectors; we implement one shared block every 6 layers.
"""
from repro.configs import ArchConfig
from repro.configs import HYBRID
from repro.configs import SSMSpec

ARCH = ArchConfig(
    name="zamba2-7b", family=HYBRID,
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, act="gelu",
    ssm=SSMSpec(d_state=64, expand=2, head_dim=64, chunk=256),
    hybrid_period=6, sub_quadratic=True,
)

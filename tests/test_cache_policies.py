"""Unit + property tests for the shared LLC and DCO policies."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given
from hypothesis import settings
from hypothesis import strategies as st

from repro.core.cache import BYPASSED_COLD
from repro.core.cache import COLD_MISS
from repro.core.cache import CONFLICT_MISS
from repro.core.cache import CacheGeometry
from repro.core.cache import HIT
from repro.core.cache import SharedLLC
from repro.core.policies import named_policy
from repro.core.tmu import TMU
from repro.core.tmu import TMUParams
from repro.core.tmu import TensorMeta

GEOM = CacheGeometry(64 * 1024, line_bytes=128, assoc=4, n_slices=4)


def mk_llc(policy="lru", tmu=None, geom=GEOM, **kw):
    return SharedLLC(geom, named_policy(policy, **kw), tmu=tmu)


def addrs(lines):
    return np.asarray(lines, dtype=np.int64) * 128


def test_geometry_set_hash_is_bijective_per_block():
    g = CacheGeometry(64 * 1024, 128, 4, 4)
    ns = g.num_sets
    lines = np.arange(ns, dtype=np.int64) * 128 + 7 * ns * 128
    sets = g.set_of(lines)
    assert np.unique(sets).shape[0] == ns    # bijection within a block


def test_cold_then_hit():
    llc = mk_llc()
    a = addrs(range(16))
    seen = np.zeros(16, dtype=bool)
    codes = llc.access_burst(a, seen_before=seen)
    assert (codes == COLD_MISS).all()
    codes = llc.access_burst(a, seen_before=np.ones(16, dtype=bool))
    assert (codes == HIT).all()
    assert llc.hit_rate() == 0.5


def test_force_bypass_never_allocates():
    llc = mk_llc()
    a = addrs(range(8))
    codes = llc.access_burst(a, seen_before=np.zeros(8, bool),
                             force_bypass=True)
    assert (codes == BYPASSED_COLD).all()
    codes = llc.access_burst(a, seen_before=np.ones(8, bool),
                             force_bypass=True)
    assert (codes != HIT).all()
    assert llc.resident_bytes() == 0


def test_lru_evicts_oldest():
    geom = CacheGeometry(4 * 128 * 2, 128, 4, 1)   # 2 sets, 4 ways
    llc = SharedLLC(geom, named_policy("lru"))
    # 5 lines mapping to the same set → evicts the first
    lines = [geom_line_for_set(geom, 0, k) for k in range(5)]
    for ln in lines:
        llc.access_burst(addrs([ln]), seen_before=np.zeros(1, bool))
    # first line should be gone
    code = llc.access_burst(addrs([lines[0]]),
                            seen_before=np.ones(1, bool))
    assert code[0] == CONFLICT_MISS
    # others (2..4) still resident
    for ln in lines[2:]:
        code = llc.access_burst(addrs([ln]), seen_before=np.ones(1, bool))
        assert code[0] == HIT


def geom_line_for_set(geom, set_idx, k):
    """Find the k-th line number mapping to set_idx (scan; small geoms)."""
    found = 0
    ln = 0
    while True:
        if int(geom.set_of(np.int64(ln * 128))) == set_idx:
            if found == k:
                return ln
            found += 1
        ln += 1


def test_anti_thrash_evicts_lowest_priority_tier():
    geom = CacheGeometry(2 * 128 * 4, 128, 4, 1, hash_sets=False)  # 2 sets
    llc = SharedLLC(geom, named_policy("at", b_bits=3))
    ns = geom.num_sets
    # fill one set with tags of priorities 5, 6, 7, 4 (same set: stride ns)
    prios = [5, 6, 7, 4]
    lines = [p * ns for p in prios]             # tag == p
    for ln in lines:
        llc.access_burst(addrs([ln]), seen_before=np.zeros(1, bool))
    # insert a new line in the same set: victim must be the prio-4 line
    new = 9 * ns + 0                             # tag 9 → prio 1
    llc.access_burst(addrs([new]), seen_before=np.zeros(1, bool))
    code = llc.access_burst(addrs([4 * ns]), seen_before=np.ones(1, bool))
    assert code[0] == CONFLICT_MISS              # prio-4 was evicted
    for p in (5, 6, 7):
        code = llc.access_burst(addrs([p * ns]),
                                seen_before=np.ones(1, bool))
        assert code[0] == HIT


def test_dbp_victimizes_dead_lines_first():
    geom = CacheGeometry(2 * 128 * 4, 128, 4, 1, hash_sets=False)
    tmu = TMU(line_bytes=128, params=TMUParams(d_lsb=0, d_msb=11, b_bits=3))
    llc = SharedLLC(geom, named_policy("dbp"), tmu=tmu)
    ns = geom.num_sets
    # register a tensor covering the line with tag 6 (one-tile tensor)
    base = 6 * ns * 128
    meta = TensorMeta(0, base_addr=base, size_bytes=128, tile_bytes=128,
                      n_acc=1)
    tmu.register(meta)
    # fill set 0 with tags 5, 6, 7, 8; mark tag-6 line dead via TLL access
    for tag in (5, 6, 7, 8):
        llc.access_burst(addrs([tag * ns]), seen_before=np.zeros(1, bool))
    tmu.on_access(base, 6)
    assert tmu.is_dead(6)
    # new fill: victim must be the dead tag-6 line, not LRU (tag 5)
    llc.access_burst(addrs([9 * ns]), seen_before=np.zeros(1, bool))
    assert llc.access_burst(addrs([5 * ns]),
                            seen_before=np.ones(1, bool))[0] == HIT
    assert llc.access_burst(addrs([6 * ns]),
                            seen_before=np.ones(1, bool))[0] == CONFLICT_MISS
    assert llc.stats["dead_evictions"] == 1


def test_static_bypass_gear_filters_low_priority():
    geom = CacheGeometry(2 * 128 * 4, 128, 4, 1, hash_sets=False)
    llc = SharedLLC(geom, named_policy("fix4", b_bits=3))
    ns = geom.num_sets
    lo = 2 * ns      # tag 2 → prio 2 < gear 4 → bypass
    hi = 6 * ns      # tag 6 → prio 6 ≥ gear 4 → allocate
    llc.access_burst(addrs([lo, hi]), seen_before=np.zeros(2, bool))
    codes = llc.access_burst(addrs([lo, hi]), seen_before=np.ones(2, bool))
    assert codes[0] != HIT and codes[1] == HIT


def test_bypass_eligibility_gates_gqa_variant():
    geom = CacheGeometry(2 * 128 * 4, 128, 4, 1, hash_sets=False)
    llc = SharedLLC(geom, named_policy("fix4", b_bits=3, gqa=True))
    ns = geom.num_sets
    lo = 2 * ns
    # not eligible (leader core) → allocated despite low priority
    llc.access_burst(addrs([lo]), seen_before=np.zeros(1, bool),
                     bypass_eligible=False)
    assert llc.access_burst(addrs([lo]),
                            seen_before=np.ones(1, bool))[0] == HIT


def test_duplicate_sets_within_burst_are_split_correctly():
    geom = CacheGeometry(2 * 128 * 4, 128, 4, 1, hash_sets=False)
    llc = SharedLLC(geom, named_policy("lru"))
    ns = geom.num_sets
    # two lines in the same set in one burst: both must be processed
    a = addrs([1 * ns, 3 * ns])
    codes = llc.access_burst(a, seen_before=np.zeros(2, bool))
    assert (codes == COLD_MISS).all()
    codes = llc.access_burst(a, seen_before=np.ones(2, bool))
    assert (codes == HIT).all()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=2000), min_size=1,
                max_size=300))
def test_property_stats_conservation(lines):
    """hits + cold + conflict == total accesses, and cold misses equal the
    number of distinct lines on first touch (with a policy-free cache)."""
    llc = mk_llc("lru")
    seen = set()
    total = 0
    for chunk_start in range(0, len(lines), 50):
        chunk = lines[chunk_start:chunk_start + 50]
        # dedupe within chunk (simulator-level MSHR contract)
        chunk = list(dict.fromkeys(chunk))
        sb = np.array([ln in seen for ln in chunk], dtype=bool)
        llc.access_burst(addrs(chunk), seen_before=sb)
        seen.update(chunk)
        total += len(chunk)
    s = llc.stats
    assert s["hits"] + s["cold_misses"] + s["conflict_misses"] == total
    assert s["cold_misses"] == len(seen) >= 1


def test_gear_window_advances_in_whole_multiples():
    """A late tick must not stretch the next feedback window: the window
    start advances by whole ``window_cycles`` multiples, never snaps to
    ``now_cycles`` (the drift skewed every subsequent eviction *rate*)."""
    from repro.core.policies import GearController

    cfg = named_policy("at+bypass", window_cycles=100)
    gc = GearController(1, cfg)
    gc.record(np.zeros(50, dtype=np.int64), np.ones(50, dtype=bool))
    gc.tick(150.0)                     # closes the [0, 100) window late
    assert gc._window_start == 100.0   # not 150.0
    assert gc.gear[0] == 1             # rate 1.0 > ub → gear up
    # the next window closes at 200, unaffected by the 50-cycle overshoot
    gc.record(np.zeros(10, dtype=np.int64), np.ones(10, dtype=bool))
    gc.tick(199.0)
    assert gc._window_start == 100.0 and gc.gear[0] == 1
    gc.tick(205.0)
    assert gc._window_start == 200.0 and gc.gear[0] == 2
    # a very late tick skips whole windows, landing on a boundary
    gc.tick(565.0)
    assert gc._window_start == 500.0


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=8))
def test_property_gear_zero_equals_at(gear):
    """B_GEAR=0 bypasses nothing → static bypass degenerates to plain at
    (paper Fig. 7: 'B_GEAR = 0 … degenerates to ordinary at')."""
    rng = np.random.default_rng(0)
    lines = rng.integers(0, 4096, size=600)
    def run(policy):
        llc = mk_llc(policy)
        seen = set()
        for i in range(0, 600, 40):
            chunk = list(dict.fromkeys(lines[i:i + 40].tolist()))
            sb = np.array([ln in seen for ln in chunk], dtype=bool)
            llc.access_burst(addrs(chunk), seen_before=sb)
            seen.update(chunk)
        return llc.stats["hits"]
    if gear == 0:
        assert run("fix0") == run("at")

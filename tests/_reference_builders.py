"""Frozen pre-refactor trace builders and closed-form counts (PR-1 state).

This is a verbatim copy of the hand-written ``build_fa2_trace`` /
``build_matmul_trace`` / ``fa2_counts`` implementations as they existed
before the dataflow IR landed.  It exists ONLY as the reference oracle for
``tests/test_dataflow_ir.py``: the IR-based re-expressions must reproduce
these outputs bit-identically (tensor metadata, step schedules, simulator
counters, and counts).  Do not "fix" or modernize this file — divergence
from it is the signal the equivalence tests exist to catch.
"""

from __future__ import annotations

from typing import Dict
from typing import List
from typing import Tuple

from repro.core.tmu import TensorMeta
from repro.core.traces import DataflowCounts
from repro.core.traces import LINE_BYTES
from repro.core.traces import Step
from repro.core.traces import Trace
from repro.core.workloads import AttnWorkload
from repro.core.workloads import TEMPORAL


class _Allocator:
    def __init__(self, base: int = 1 << 30):
        self._next = base

    def alloc(self, size: int, align: int) -> int:
        a = (self._next + align - 1) // align * align
        self._next = a + size
        return a


def build_fa2_trace_ref(wl: AttnWorkload, n_cores: int = 16) -> Trace:
    if wl.group_alloc == TEMPORAL:
        return _fa2_temporal(wl, n_cores)
    return _fa2_spatial(wl, n_cores)


def _mk_kv_tensors(wl, alloc, tensors, next_id, batch, kv_head, n_acc):
    size = wl.seq_len * wl.head_dim * wl.dtype_bytes
    ids = []
    for _ in ("K", "V"):
        base = alloc.alloc(size, wl.kv_tile_bytes)
        tensors[next_id] = TensorMeta(
            tensor_id=next_id, base_addr=base, size_bytes=size,
            tile_bytes=wl.kv_tile_bytes, n_acc=n_acc, operand_id=1)
        ids.append(next_id)
        next_id += 1
    return ids, next_id


def _mk_qo_tensor(wl, alloc, tensors, next_id, operand_id):
    size = wl.seq_len * wl.head_dim * wl.dtype_bytes
    base = alloc.alloc(size, wl.q_tile_bytes)
    tensors[next_id] = TensorMeta(
        tensor_id=next_id, base_addr=base, size_bytes=size,
        tile_bytes=wl.q_tile_bytes, n_acc=1, operand_id=operand_id,
        bypass_all=True)
    return next_id, next_id + 1


def _fa2_temporal(wl: AttnWorkload, n_cores: int) -> Trace:
    alloc = _Allocator()
    tensors: Dict[int, TensorMeta] = {}
    next_id = 0
    steps: List[List[Step]] = [[] for _ in range(n_cores)]

    n_acc = wl.n_q_tiles
    per_core: List[List[Tuple[int, int]]] = [[] for _ in range(n_cores)]
    for b in range(wl.n_batches):
        for g in range(wl.n_kv_heads):
            per_core[g % n_cores].append((b, g))

    for c in range(n_cores):
        items = []
        for (b, g) in per_core[c]:
            kv_ids, next_id = _mk_kv_tensors(wl, alloc, tensors, next_id,
                                             b, g, n_acc)
            q_ids, o_ids = [], []
            for _ in range(wl.group_size):
                qid, next_id = _mk_qo_tensor(wl, alloc, tensors, next_id, 0)
                oid, next_id = _mk_qo_tensor(wl, alloc, tensors, next_id, 2)
                q_ids.append(qid)
                o_ids.append(oid)
            items.append((b, kv_ids, q_ids, o_ids))

        half = wl.flops_per_inner_step() * wl.group_size / 2
        for b in range(wl.n_batches):
            batch_items = [it for it in items if it[0] == b]
            for i in range(wl.n_q_tiles):
                for (_, kv_ids, q_ids, o_ids) in batch_items:
                    steps[c].append(Step(
                        loads=[(qid, i) for qid in q_ids], flops=0.0))
                    kv_hi = _kv_extent(wl, i)
                    for j in range(kv_hi):
                        steps[c].append(Step(loads=[(kv_ids[0], j)],
                                             flops=half))
                        steps[c].append(Step(loads=[(kv_ids[1], j)],
                                             flops=half))
                    steps[c].append(Step(
                        stores=[(oid, i) for oid in o_ids], flops=0.0))

    return Trace(name=f"{wl.name}-temporal", tensors=tensors,
                 core_steps=steps, core_group=[-1] * n_cores,
                 core_is_leader=[True] * n_cores, workload=wl)


def _fa2_spatial(wl: AttnWorkload, n_cores: int) -> Trace:
    alloc = _Allocator()
    tensors: Dict[int, TensorMeta] = {}
    next_id = 0
    steps: List[List[Step]] = [[] for _ in range(n_cores)]
    gs = wl.group_size

    n_acc = wl.n_q_tiles * min(gs, n_cores)

    n_waves = (wl.n_q_heads + n_cores - 1) // n_cores
    kv_cache_ids: Dict[Tuple[int, int], List[int]] = {}
    core_group = [c // gs if gs <= n_cores else 0 for c in range(n_cores)]
    core_is_leader = [(c % gs != gs - 1) if gs <= n_cores
                      else (c != n_cores - 1) for c in range(n_cores)]

    for b in range(wl.n_batches):
        for g in range(wl.n_kv_heads):
            kv_cache_ids[(b, g)], next_id = _mk_kv_tensors(
                wl, alloc, tensors, next_id, b, g, n_acc)

    qo_ids: Dict[Tuple[int, int], Tuple[int, int]] = {}
    for b in range(wl.n_batches):
        for h in range(wl.n_q_heads):
            qid, next_id = _mk_qo_tensor(wl, alloc, tensors, next_id, 0)
            oid, next_id = _mk_qo_tensor(wl, alloc, tensors, next_id, 2)
            qo_ids[(b, h)] = (qid, oid)

    half = wl.flops_per_inner_step() / 2
    for b in range(wl.n_batches):
        for i in range(wl.n_q_tiles):
            kv_hi = _kv_extent(wl, i)
            for w in range(n_waves):
                for c in range(n_cores):
                    h = w * n_cores + c
                    if h >= wl.n_q_heads:
                        steps[c].extend(Step() for _ in range(2 * kv_hi + 2))
                        continue
                    g = h // gs
                    kv_ids = kv_cache_ids[(b, g)]
                    qid, oid = qo_ids[(b, h)]
                    rank = (h % gs) if gs <= n_cores else c
                    last_rank = (gs - 1) if gs <= n_cores else (n_cores - 1)
                    lag = 1 if rank == last_rank else 0
                    steps[c].append(Step(loads=[(qid, i)], flops=0.0))
                    for jj in range(kv_hi):
                        j = (jj - lag) % kv_hi
                        steps[c].append(Step(loads=[(kv_ids[0], j)],
                                             flops=half))
                        steps[c].append(Step(loads=[(kv_ids[1], j)],
                                             flops=half))
                    steps[c].append(Step(stores=[(oid, i)], flops=0.0))

    return Trace(name=f"{wl.name}-spatial", tensors=tensors,
                 core_steps=steps, core_group=core_group,
                 core_is_leader=core_is_leader, workload=wl)


def _kv_extent(wl: AttnWorkload, q_tile: int) -> int:
    if not wl.causal:
        return wl.n_kv_tiles
    return min(q_tile + 1, wl.n_kv_tiles)


def build_matmul_trace_ref(m: int, n: int, k: int, tile: int = 128,
                           n_cores: int = 16, dtype_bytes: int = 1) -> Trace:
    if m % tile or n % tile or k % tile:
        raise ValueError("dims must be tile-aligned")
    mt, nt, kt = m // tile, n // tile, k // tile
    tile_bytes = tile * tile * dtype_bytes
    alloc = _Allocator()
    tensors: Dict[int, TensorMeta] = {}

    def mk(tid, rows_t, cols_t, n_acc, operand_id, bypass=False):
        size = rows_t * cols_t * tile_bytes
        base = alloc.alloc(size, tile_bytes)
        tensors[tid] = TensorMeta(tensor_id=tid, base_addr=base,
                                  size_bytes=size, tile_bytes=tile_bytes,
                                  n_acc=n_acc, operand_id=operand_id,
                                  bypass_all=bypass)

    A, B, C = 0, 1, 2
    mk(A, mt, kt, n_acc=nt, operand_id=0)
    mk(B, kt, nt, n_acc=mt, operand_id=1)
    mk(C, mt, nt, n_acc=1, operand_id=2, bypass=True)

    steps: List[List[Step]] = [[] for _ in range(n_cores)]
    flops = 2.0 * tile * tile * tile
    c_tiles = [(i, j) for i in range(mt) for j in range(nt)]
    for idx, (i, j) in enumerate(c_tiles):
        core = idx % n_cores
        for kk in range(kt):
            steps[core].append(Step(
                loads=[(A, i * kt + kk), (B, kk * nt + j)], flops=flops))
        steps[core].append(Step(stores=[(C, i * nt + j)]))

    return Trace(name=f"matmul-{m}x{n}x{k}", tensors=tensors,
                 core_steps=steps, core_group=[-1] * n_cores,
                 core_is_leader=[True] * n_cores)


def fa2_counts_ref(wl: AttnWorkload, n_cores: int = 16) -> DataflowCounts:
    kv_lines_head = 2 * wl.seq_len * wl.head_dim * wl.dtype_bytes // LINE_BYTES
    kv_distinct = kv_lines_head * wl.n_kv_heads * wl.n_batches
    gs = wl.group_size

    if wl.causal:
        pass_frac = (wl.n_q_tiles + 1) / (2 * wl.n_q_tiles)
    else:
        pass_frac = 1.0

    active_groups = wl.n_kv_heads
    if wl.group_alloc == TEMPORAL:
        accesses = kv_distinct * wl.n_q_tiles * pass_frac
        intercore = 0
        items_per_core = -(-wl.n_kv_heads * wl.n_batches // n_cores)
        n_rounds = items_per_core * wl.n_q_tiles * (2 * wl.n_kv_tiles + 2)
    else:
        accesses = kv_distinct * wl.n_q_tiles * min(gs, n_cores) * pass_frac
        intercore = accesses * (min(gs, n_cores) - 1) / min(gs, n_cores)
        n_waves = -(-wl.n_q_heads // n_cores)
        n_rounds = (wl.n_batches * n_waves * wl.n_q_tiles
                    * (2 * wl.n_kv_tiles + 2))

    s_active = active_groups * 2 * wl.seq_len * wl.head_dim * wl.dtype_bytes
    qo_lines = (2 * wl.seq_len * wl.head_dim * wl.dtype_bytes // LINE_BYTES
                ) * wl.n_q_heads * wl.n_batches
    flops = (wl.flops_per_inner_step() * wl.n_q_tiles * wl.n_kv_tiles
             * pass_frac * wl.n_q_heads * wl.n_batches)

    return DataflowCounts(
        name=f"{wl.name}-{wl.group_alloc}", line_bytes=LINE_BYTES,
        n_kv_accesses=int(round(accesses)),
        n_kv_distinct=int(kv_distinct),
        n_bypass_lines=int(qo_lines),
        n_intercore_reuse=int(round(intercore)),
        s_work_active=int(s_active),
        s_work_total=int(kv_distinct * LINE_BYTES // max(wl.n_batches, 1)),
        flops_total=float(flops),
        n_batches=wl.n_batches,
        n_rounds=int(n_rounds),
    )

"""Dataflow IR: bit-identical equivalence with the pre-refactor builders,
counts/trace consistency, the four new scenarios through both engines and
the analytical model, plan lowering, and the suite registry."""

from _reference_builders import build_fa2_trace_ref
from _reference_builders import build_matmul_trace_ref
from _reference_builders import fa2_counts_ref
import numpy as np
import pytest

from repro.core import DecodeWorkload
from repro.core import MoEWorkload
from repro.core import SimConfig
from repro.core import SpecDecodeWorkload
from repro.core import build_fa2_trace
from repro.core import build_matmul_trace
from repro.core import fa2_counts
from repro.core import named_policy
from repro.core import predict
from repro.core import run_policies
from repro.core import run_policy
from repro.core.workloads import AttnWorkload
from repro.core.workloads import SPATIAL
from repro.core.workloads import TEMPORAL
from repro.core.workloads import get_workload
from repro.dataflows import SUITE_POLICIES
from repro.dataflows import build_suite
from repro.dataflows import decode_paged_spec
from repro.dataflows import fa2_spec
from repro.dataflows import lower_to_counts
from repro.dataflows import lower_to_plan
from repro.dataflows import lower_to_trace
from repro.dataflows import matmul_spec
from repro.dataflows import mlp_chain_spec
from repro.dataflows import moe_ffn_spec
from repro.dataflows import spec_decode_spec
from repro.dataflows import suite_case
from repro.dataflows import tmu_metadata
from repro.dataflows import transformer_layer_spec
from repro.dataflows.ir import SpecBuilder

TINY_T = AttnWorkload("tiny-t", 8, 4, 128, 1024, group_alloc=TEMPORAL)
TINY_S = AttnWorkload("tiny-s", 16, 4, 128, 1024, group_alloc=SPATIAL)
TINY_MB = AttnWorkload("tiny-mb", 4, 4, 128, 1024, group_alloc=TEMPORAL,
                       n_batches=2)
CFG4 = SimConfig(n_cores=4, llc_bytes=512 * 1024, llc_slices=8)

COUNTERS = ("cycles", "hits", "mshr_hits", "cold_misses",
            "conflict_misses", "bypassed", "dram_lines", "writebacks",
            "dead_evictions", "flops")

MINI_DECODE = DecodeWorkload(n_seqs=8, seq_len=1024, n_steps=4,
                             retire_step=2, n_short=4)
MINI_MOE = MoEWorkload(n_experts=8, n_hot=4, d_model=256, d_ff=256,
                       tile_bytes=8192, n_steps=6, warm_steps=2)
MOE_CFG = SimConfig(n_cores=8, llc_bytes=256 * 1024, llc_slices=8)
MINI_SPECDEC = SpecDecodeWorkload(n_seqs=4, target_len=384, draft_len=128,
                                  gamma=2, n_verify=3)


def assert_traces_identical(ref, got):
    assert got.name == ref.name
    assert got.core_group == ref.core_group
    assert got.core_is_leader == ref.core_is_leader
    assert set(got.tensors) == set(ref.tensors)
    for tid in ref.tensors:
        assert got.tensors[tid] == ref.tensors[tid], f"tensor {tid}"
    for c, (sr, sg) in enumerate(zip(ref.core_steps, got.core_steps)):
        assert sr == sg, f"core {c} schedule differs"


def trace_line_accesses(trace):
    """Per-tensor (line_reads, line_writes) by walking the trace steps —
    the trace-derived side of the counts pin."""
    out = {tid: [0, 0] for tid in trace.tensors}
    for steps in trace.core_steps:
        for step in steps:
            for tid, _ in step.loads:
                out[tid][0] += trace.tensors[tid].tile_bytes // trace.line_bytes
            for tid, _ in step.stores:
                out[tid][1] += trace.tensors[tid].tile_bytes // trace.line_bytes
    return {tid: tuple(v) for tid, v in out.items()}


# ---------------------------------------------------------------------------
# Pin: IR-lowered FA2/matmul traces are bit-identical to the pre-refactor
# hand-written builders (frozen in tests/_reference_builders.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("wl,n_cores", [
    (TINY_T, 4), (TINY_S, 4), (TINY_MB, 4),
    (get_workload("gemma3-27b"), 16),
    (get_workload("qwen3-8b"), 16),
    (get_workload("llama3-70b"), 16),
    (AttnWorkload("causal-t", 8, 4, 128, 1024, group_alloc=TEMPORAL,
                  causal=True), 4),
    (AttnWorkload("causal-s", 8, 4, 128, 1024, group_alloc=SPATIAL,
                  causal=True), 4),
])
def test_fa2_trace_bit_identical_to_reference(wl, n_cores):
    assert_traces_identical(build_fa2_trace_ref(wl, n_cores),
                            build_fa2_trace(wl, n_cores))


def test_matmul_trace_bit_identical_to_reference():
    assert_traces_identical(
        build_matmul_trace_ref(512, 512, 512, tile=128, n_cores=4),
        build_matmul_trace(512, 512, 512, tile=128, n_cores=4))
    with pytest.raises(ValueError, match="tile-aligned"):
        build_matmul_trace(500, 512, 512)


@pytest.mark.parametrize("wl,n_cores", [
    (TINY_T, 4), (TINY_S, 4), (TINY_MB, 4),
    (get_workload("gemma3-27b"), 16),
    (get_workload("qwen3-8b"), 16),
    (get_workload("llama3-405b"), 16),
])
def test_fa2_counts_bit_identical_to_reference(wl, n_cores):
    """On every shape where the old closed-form was consistent with its
    own trace, the IR-derived counts reproduce it field for field."""
    assert fa2_counts(wl, n_cores) == fa2_counts_ref(wl, n_cores)


@pytest.mark.parametrize("wl,n_cores", [
    # shapes where the old hand-kept formula had drifted from its own
    # trace: causal extents, group_size > n_cores, uneven multi-batch
    (AttnWorkload("causal-t", 8, 4, 128, 1024, group_alloc=TEMPORAL,
                  causal=True), 4),
    (get_workload("llama3-405b"), 4),
    (TINY_MB, 16),
])
def test_fa2_counts_now_match_trace_where_formula_drifted(wl, n_cores):
    trace = build_fa2_trace(wl, n_cores)
    counts = fa2_counts(wl, n_cores)
    ct = trace.compiled()
    assert counts.n_rounds == trace.n_rounds
    assert (counts.n_kv_accesses + counts.n_bypass_lines
            == int(ct.n_acc_round.sum()))


def test_fa2_sim_counters_identical_to_reference():
    ref = run_policy(build_fa2_trace_ref(TINY_T, 4), named_policy("all"),
                     CFG4)
    got = run_policy(build_fa2_trace(TINY_T, 4), named_policy("all"), CFG4)
    for f in COUNTERS:
        assert getattr(ref, f) == getattr(got, f), f


# ---------------------------------------------------------------------------
# Counts lowering ≡ trace-derived totals (all scenarios)
# ---------------------------------------------------------------------------
def _all_specs():
    return [
        fa2_spec(TINY_T, 4), fa2_spec(TINY_S, 4), fa2_spec(TINY_MB, 4),
        matmul_spec(512, 512, 512, n_cores=4),
        decode_paged_spec(MINI_DECODE, 4),
        moe_ffn_spec(MINI_MOE, 8),
        mlp_chain_spec(m=512, dims=(256, 256, 256, 256), n_cores=4),
        transformer_layer_spec(AttnWorkload("tl", 4, 2, 128, 512),
                               d_ff=512, n_cores=4),
        spec_decode_spec(MINI_SPECDEC, 4),
    ]


@pytest.mark.parametrize("spec", _all_specs(), ids=lambda s: s.name)
def test_counts_lowering_matches_trace(spec):
    trace = lower_to_trace(spec)
    counts = lower_to_counts(spec)
    ct = trace.compiled()
    # totals
    assert counts.n_rounds == trace.n_rounds
    assert (counts.n_kv_accesses + counts.n_bypass_lines
            == int(ct.n_acc_round.sum()))
    assert float(ct.flops_round.sum()) == counts.flops_total
    # class assignment partitions the tensor set: every byte is counted
    # exactly once as either reuse-carrier (n_kv_distinct) or bypass
    bypass_bytes = sum(m.size_bytes for m in trace.tensors.values()
                      if m.bypass_all)
    assert (trace.total_bytes_touched()
            == counts.n_kv_distinct * trace.line_bytes + bypass_bytes)
    # per-tensor access counts: closed form vs trace walk
    name_of = {i: t.name for i, t in enumerate(spec.tensors)}
    from_trace = {name_of[tid]: v
                  for tid, v in trace_line_accesses(trace).items()}
    assert from_trace == spec.per_tensor_line_accesses()
    # derived invariants
    assert counts.n_kv_accesses >= counts.n_kv_distinct
    assert counts.n_temporal_reuse >= 0
    assert counts.n_intercore_reuse >= 0


# ---------------------------------------------------------------------------
# New scenarios: both engines bit-identical, DBP machinery exercised,
# analytical model runs
# ---------------------------------------------------------------------------
SCENARIOS = {
    "decode-paged": (lambda: decode_paged_spec(MINI_DECODE, 4), CFG4),
    "moe-ffn": (lambda: moe_ffn_spec(MINI_MOE, 8), MOE_CFG),
    "mlp-chain": (lambda: mlp_chain_spec(m=512, dims=(256, 256, 256, 256),
                                         n_cores=4),
                  SimConfig(n_cores=4, llc_bytes=256 * 1024, llc_slices=8)),
    "transformer-layer": (
        lambda: transformer_layer_spec(AttnWorkload("tl", 4, 2, 128, 512),
                                       d_ff=512, n_cores=4), CFG4),
    "spec-decode": (lambda: spec_decode_spec(MINI_SPECDEC, 4), CFG4),
}


@pytest.mark.parametrize("key", sorted(SCENARIOS))
@pytest.mark.parametrize("policy", ["lru", "at+dbp", "all"])
def test_scenario_engines_bit_identical(key, policy):
    build, cfg = SCENARIOS[key]
    trace = lower_to_trace(build())
    pol = named_policy(policy)
    ref = run_policy(trace, pol, cfg, engine="steps")
    got = run_policy(trace, pol, cfg, engine="compiled")
    for f in COUNTERS:
        assert getattr(ref, f) == getattr(got, f), f
    for k in ref.history:
        np.testing.assert_array_equal(ref.history[k], got.history[k])


@pytest.mark.parametrize("key", sorted(SCENARIOS))
def test_scenario_analytical_model_runs(key):
    build, cfg = SCENARIOS[key]
    counts = lower_to_counts(build())
    for policy in ("lru", "at", "at+dbp", "all"):
        pred = predict(counts, cfg.llc_bytes, policy, cfg,
                       n_rounds=counts.n_rounds)
        assert pred.cycles > 0
        assert 0.0 <= pred.kept_fraction <= 1.0


@pytest.mark.parametrize("key,build,cfg", [
    ("decode", lambda: decode_paged_spec(MINI_DECODE, 4), CFG4),
    ("moe", lambda: moe_ffn_spec(MINI_MOE, 8), MOE_CFG),
    ("specdec", lambda: spec_decode_spec(MINI_SPECDEC, 4), CFG4),
])
def test_dbp_beats_lru_on_retirement_scenarios(key, build, cfg):
    """The acceptance property of §VI-F transplanted to the new
    scenarios: with dead data polluting the LLC, the DBP-bearing policy
    must measurably beat plain LRU (and the trace must actually retire
    tiles into the dead FIFO)."""
    trace = lower_to_trace(build())
    pols = ("lru", "at+dbp")
    lru, dbp = run_policies(trace, [named_policy(p) for p in pols], cfg)
    assert dbp.dead_evictions > 0
    assert lru.cycles / dbp.cycles > 1.05, \
        f"{key}: dbp speedup only {lru.cycles / dbp.cycles:.3f}x"


def test_decode_retirement_counts():
    """Short sequences retire exactly their page tiles (K and V) into the
    TMU; long sequences retire at the very end of the run."""
    spec = decode_paged_spec(MINI_DECODE, 4)
    trace = lower_to_trace(spec)
    res = run_policy(trace, named_policy("at+dbp"), CFG4)
    assert res.dead_evictions > 0
    # every KV tile is eventually retired: n_seqs * 2 tensors * n_pages
    from repro.core.simulator import Simulator
    sim = Simulator(CFG4, named_policy("at+dbp"))
    geom, tmu, llc = sim._fresh_state(trace)
    ct = trace.compiled()
    tmu.on_access_batch(ct.tll_tids, ct.tll_tiles, ct.tll_tags_for(geom),
                        ct.tll_nacc)
    expected = MINI_DECODE.n_seqs * 2 * MINI_DECODE.n_pages
    assert tmu.stats["tiles_retired"] == expected


# ---------------------------------------------------------------------------
# Plan lowering
# ---------------------------------------------------------------------------
def test_lower_to_plan_budget_and_partition():
    spec = moe_ffn_spec(MINI_MOE, 8)
    budget = 512 * 1024
    plan = lower_to_plan(spec, budget)
    usable = int(budget * (1 - 1.0 / 8.0))
    assert plan.pinned_bytes <= usable
    metas = {m.tensor_id: m for m in tmu_metadata(spec)}
    for tid, entry in plan.entries.items():
        got = sorted(entry.pinned_tiles + entry.streamed_tiles)
        assert got == list(range(metas[tid].num_tiles))
    # the most-reused (hot expert) tensors claim residency first
    hot_ids = [i for i, t in enumerate(spec.tensors)
               if t.name.startswith("W.e") and t.sharers > 1]
    assert any(plan.entries[i].pinned_tiles for i in hot_ids)
    # bypass activations are never pinned
    act_ids = [i for i, t in enumerate(spec.tensors) if t.bypass]
    assert all(not plan.entries[i].pinned_tiles for i in act_ids)


def test_tmu_metadata_registers_into_tmu():
    from repro.core import TMU
    spec = mlp_chain_spec(m=512, dims=(256, 256, 256, 256), n_cores=4)
    tmu = TMU(tensor_entries=64)
    tmu.register_many(tmu_metadata(spec))
    meta = tmu_metadata(spec)[0]
    assert tmu.lookup_tensor(meta.base_addr) == meta


# ---------------------------------------------------------------------------
# IR validation and builder helpers
# ---------------------------------------------------------------------------
def test_spec_validation_rejects_bad_references():
    b = SpecBuilder("bad", 1)
    b.tensor("T", size_bytes=1024, tile_bytes=256, n_acc=1)
    b.step(0, loads=[("nope", 0)])
    with pytest.raises(ValueError, match="unknown tensor"):
        b.build()
    b2 = SpecBuilder("bad2", 1)
    b2.tensor("T", size_bytes=1024, tile_bytes=256, n_acc=1)
    b2.step(0, loads=[("T", 4)])
    with pytest.raises(ValueError, match="out of range"):
        b2.build()
    b3 = SpecBuilder("bad3", 1)
    b3.tensor("T", size_bytes=1024, tile_bytes=256, n_acc=1)
    b3.tensor("T", size_bytes=1024, tile_bytes=256, n_acc=1)
    with pytest.raises(ValueError, match="duplicate"):
        b3.build()


def test_transformer_layer_interleaves_groups_like_fa2_temporal():
    """A core owning several KV groups must interleave them at Q-tile
    granularity (fa2 temporal semantics: all owned streams concurrently
    live), not run one group to completion before the next."""
    wl = AttnWorkload("tli", 8, 8, 128, 512)     # 8 KV groups on 4 cores
    spec = transformer_layer_spec(wl, d_ff=512, n_cores=4)
    first_pass = 2 * (2 * wl.n_kv_tiles + 2)     # one Q tile × both groups
    seen = {name for step in spec.core_programs[0][:first_pass]
            for name, _ in step.loads if name.startswith("K.")}
    assert seen == {"K.g0", "K.g4"}


def test_moe_spec_rejects_core_expert_mismatch():
    # n_cold == 0 with more cores than experts must error, not index
    # past the expert list during the warm phase
    with pytest.raises(ValueError, match="n_cold"):
        moe_ffn_spec(MoEWorkload(n_experts=8, n_hot=8, d_model=256,
                                 d_ff=256, tile_bytes=8192), n_cores=16)
    # all-hot routing is fine when every core maps to an expert
    spec = moe_ffn_spec(MoEWorkload(n_experts=8, n_hot=8, d_model=256,
                                    d_ff=256, tile_bytes=8192), n_cores=8)
    assert spec.n_cores == 8


def test_pad_to_sync_aligns_cores():
    b = SpecBuilder("sync", 3)
    b.tensor("T", size_bytes=1024, tile_bytes=256, n_acc=1)
    b.step(0, loads=[("T", 0)])
    b.step(0, loads=[("T", 1)])
    b.step(2, loads=[("T", 2)])
    b.pad_to_sync()
    spec = b.build()
    assert [len(p) for p in spec.core_programs] == [2, 2, 2]


# ---------------------------------------------------------------------------
# Suite registry
# ---------------------------------------------------------------------------
def test_suite_registry_complete_and_unique():
    cases = build_suite()
    keys = [c.key for c in cases]
    assert len(set(keys)) == len(keys)
    for expected in ("fa2-temporal", "fa2-spatial", "matmul",
                     "decode-paged", "moe-ffn", "spec-decode",
                     "mlp-chain", "transformer-layer",
                     "ssd-scan", "prefix-share"):
        assert expected in keys
    # the speculative-decoding case exists to demonstrate the recurring
    # two-epoch DBP win — keep it flagged for the suite_bench emit line
    assert next(c for c in cases if c.key == "spec-decode").expect_dbp_win
    # ssd-scan exists for the chunk-state retirement win (gated in CI);
    # prefix-share runs under the conservative gqa_bypass variant
    assert next(c for c in cases if c.key == "ssd-scan").expect_dbp_win
    assert next(c for c in cases if c.key == "prefix-share").gqa
    assert "lru" in SUITE_POLICIES and "at+dbp" in SUITE_POLICIES
    with pytest.raises(KeyError, match="unknown suite scenario"):
        suite_case("not-a-scenario")

"""Tests for the logical-axis sharding rules."""

import jax
from jax.sharding import PartitionSpec as P
import pytest

from repro.compat import abstract_mesh
from repro.sharding import act_axes
from repro.sharding import constrain
from repro.sharding import logical_spec
from repro.sharding import use_mesh
from repro.sharding.api import ACT_SEQ


@pytest.fixture
def mesh():
    # AbstractMesh: real axis sizes without needing 256 devices
    return abstract_mesh((16, 16), ("data", "model"))


def test_no_mesh_is_noop():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert constrain(x, ("dp", "tp")) is x


def test_logical_spec_basic(mesh):
    spec = logical_spec(("dp", None, "tp"), mesh)
    assert spec == P("data", None, "model")


def test_divisibility_filter(mesh):
    # dim size 3 cannot shard over data(16) → dropped; 64 can shard 16-way
    spec = logical_spec(("dp", "tp"), mesh, shape=(3, 64))
    assert spec == P(None, "model")


def test_axis_used_once(mesh):
    # "dp" consumes data; "sp" (data) must then resolve to nothing
    spec = logical_spec(("dp", "sp"), mesh)
    assert spec == P("data", None)


def test_kvseq_takes_leftover_axes(mesh):
    # batch=1: dp dropped by divisibility → kvseq gets data AND model
    spec = logical_spec(("dp", "kvseq"), mesh, shape=(1, 512))
    assert spec == P(None, ("data", "model"))
    # batch shardable: data consumed by dp → kvseq falls back to model
    spec = logical_spec(("dp", "kvseq"), mesh, shape=(32, 512))
    assert spec == P("data", "model")


def test_act_axes_flag():
    try:
        ACT_SEQ[0] = False
        assert act_axes() == ("dp", None, "tp_act")
        ACT_SEQ[0] = True
        assert act_axes() == ("dp", "act_seq", None)
    finally:
        ACT_SEQ[0] = False


def test_multipod_spec():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = logical_spec(("dp", None, "tp"), mesh)
    assert spec == P(("pod", "data"), None, "model")


def test_use_mesh_binds_and_restores():
    from repro.sharding import current_mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert current_mesh() is None
    with use_mesh(mesh):
        assert current_mesh() is mesh
    assert current_mesh() is None

"""Event-trace telemetry layer (repro.core.events, DESIGN.md §10).

Pins the tentpole contracts: engine agreement on the canonical stream,
bit-identical streaming concatenation, event↔counter conservation,
zero emission when disabled, timeline series/digests, and the
(set, tag) → address inversion the victim attribution rides on.
"""

import numpy as np
import pytest

from repro.core import EventSink
from repro.core import SimConfig
from repro.core import Simulator
from repro.core import named_policy
from repro.core import run_policy
from repro.core import timeline_digest
from repro.core.cache import CacheGeometry
from repro.core.events import COLUMNS
from repro.core.events import EV_BYPASS
from repro.core.events import EV_EVICT
from repro.core.events import EV_FILL
from repro.core.events import EV_GEAR
from repro.core.events import EV_HIT
from repro.core.events import EV_MSHR
from repro.core.events import EV_RETIRE
from repro.core.events import EV_WB
from repro.core.events import SCHEMA_VERSION
from repro.core.events import canonical_order
from repro.core.events import decode_event
from repro.core.events import stream_digest
from repro.core.traces import build_fa2_trace
from repro.core.traces import build_matmul_trace
from repro.core.workloads import AttnWorkload
from repro.core.workloads import SPATIAL
from repro.core.workloads import TEMPORAL

CFG = SimConfig(llc_bytes=256 * 1024, llc_slices=8)
TINY_T = AttnWorkload("tiny-t", n_q_heads=8, n_kv_heads=4, head_dim=128,
                      seq_len=512, group_alloc=TEMPORAL)
TINY_S = AttnWorkload("tiny-s", n_q_heads=8, n_kv_heads=4, head_dim=128,
                      seq_len=512, group_alloc=SPATIAL)

POLICIES = ["lru", "dbp", "at+dbp", "all"]


def _run(trace, policy, engine, gqa=False, chunk_lines=None, cfg=CFG):
    sink = EventSink()
    sim = Simulator(cfg, named_policy(policy, gqa=gqa))
    res = sim.run(trace, record_history=False, engine=engine,
                  chunk_lines=chunk_lines, events=sink)
    return sink, res


# ---------------------------------------------------------------------------
# engine agreement + streaming concatenation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_step_and_compiled_agree_canonical(policy):
    trace = build_fa2_trace(TINY_T, n_cores=8)
    s_step, r_step = _run(trace, policy, "steps")
    s_comp, r_comp = _run(trace, policy, "compiled")
    assert np.array_equal(s_step.canonical(), s_comp.canonical())
    assert s_step.digest() == s_comp.digest()
    assert r_step.hits == r_comp.hits


def test_gqa_spatial_agreement():
    trace = build_fa2_trace(TINY_S, n_cores=8)
    s_step, _ = _run(trace, "all", "steps", gqa=True)
    s_comp, _ = _run(trace, "all", "compiled", gqa=True)
    assert s_step.digest() == s_comp.digest()


def test_mshr_merges_agree_across_engines():
    # cores sharing B tiles in the same round produce MSHR merges
    trace = build_matmul_trace(512, 512, 512, n_cores=8)
    s_step, r_step = _run(trace, "all", "steps")
    s_comp, r_comp = _run(trace, "all", "compiled")
    assert r_comp.mshr_hits > 0
    assert s_comp.counts_by_kind()["MSHR"] > 0
    assert s_step.digest() == s_comp.digest()


@pytest.mark.parametrize("chunk_lines", [64, 600, 10**9])
def test_streaming_concatenates_bit_identical(chunk_lines):
    trace = build_matmul_trace(512, 512, 512, n_cores=4)
    s_mono, _ = _run(trace, "at+dbp", "compiled")
    s_seg, _ = _run(trace, "at+dbp", "compiled", chunk_lines=chunk_lines)
    # raw emission order, not just canonical: segments must concatenate
    assert np.array_equal(s_mono.matrix(), s_seg.matrix())
    assert s_mono.digest() == s_seg.digest()


# ---------------------------------------------------------------------------
# event ↔ SimResult counter conservation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_event_counts_conserve_to_counters(policy):
    trace = build_fa2_trace(TINY_T, n_cores=8)
    sink, res = _run(trace, policy, "compiled")
    m = sink.matrix()
    kinds, aux = m[:, 6], m[:, 7]
    assert int((kinds == EV_HIT).sum()) == res.hits
    assert int(aux[kinds == EV_MSHR].sum()) == res.mshr_hits
    assert int((kinds == EV_BYPASS).sum()) == res.bypassed
    assert int((kinds == EV_WB).sum()) == res.writebacks
    # every miss either fills or bypasses
    assert (int((kinds == EV_FILL).sum()) + int((kinds == EV_BYPASS).sum())
            == res.cold_misses + res.conflict_misses)
    # EVICT aux LSB carries the dead verdict
    assert (int((aux[kinds == EV_EVICT] & 1).sum())
            == res.dead_evictions)
    # FILL aux LSB = seen (conflict): recounts the allocated conflicts
    fills_seen = int((aux[kinds == EV_FILL] & 1).sum())
    byp_seen = int((aux[kinds == EV_BYPASS]).sum())
    assert fills_seen + byp_seen == res.conflict_misses


def test_retire_events_present_under_dbp():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    sink, _ = _run(trace, "dbp", "compiled")
    assert sink.counts_by_kind()["RETIRE"] > 0


def test_gear_events_only_with_dynamic_bypass():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    s_lru, _ = _run(trace, "lru", "compiled")
    s_all, _ = _run(trace, "all", "compiled")
    assert s_lru.counts_by_kind()["GEAR"] == 0
    assert s_all.counts_by_kind()["GEAR"] > 0
    # gear rows carry slice in the set column and new gear in aux
    m = s_all.matrix()
    gear_rows = m[m[:, 6] == EV_GEAR]
    assert (gear_rows[:, 4] >= 0).all()
    assert (gear_rows[:, 7] >= 0).all()


# ---------------------------------------------------------------------------
# disabled by default / opt-in paths
# ---------------------------------------------------------------------------
def test_no_events_unless_requested():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    res = run_policy(trace, "at+dbp", CFG, record_history=False)
    assert res.events is None


def test_trace_events_config_flag():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    cfg = SimConfig(llc_bytes=256 * 1024, llc_slices=8,
                    trace_events=True)
    r1 = run_policy(trace, "at+dbp", cfg, record_history=False)
    r2 = run_policy(trace, "at+dbp", cfg, record_history=False)
    assert r1.events is not None and len(r1.events) > 0
    # determinism: same run → same digest
    assert r1.events.digest() == r2.events.digest()


def test_results_unchanged_by_tracing():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    plain = run_policy(trace, "all", CFG, record_history=False)
    sink, traced = _run(trace, "all", "compiled")
    for f in ("cycles", "hits", "mshr_hits", "cold_misses",
              "conflict_misses", "bypassed", "writebacks",
              "dead_evictions", "dram_lines"):
        assert getattr(plain, f) == getattr(traced, f), f


# ---------------------------------------------------------------------------
# timeline view
# ---------------------------------------------------------------------------
def test_timeline_series_sum_to_counters():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    res = run_policy(trace, "all", CFG, record_history=True)
    tl = res.timeline
    for key in ("round", "hits", "misses", "bypassed", "writebacks"):
        assert key in tl
    assert int(tl["hits"].sum()) == res.hits + res.mshr_hits
    assert int(tl["misses"].sum()) == res.cold_misses + res.conflict_misses
    assert int(tl["bypassed"].sum()) == res.bypassed
    assert int(tl["writebacks"].sum()) == res.writebacks
    assert (np.diff(tl["round"]) > 0).all()      # strictly monotone


def test_timeline_matches_across_engines_and_digest():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    sim = Simulator(CFG, named_policy("at+dbp"))
    r_step = sim.run(trace, record_history=True, engine="steps")
    r_comp = sim.run(trace, record_history=True, engine="compiled")
    d_step = timeline_digest(r_step.timeline)
    d_comp = timeline_digest(r_comp.timeline)
    assert d_step == d_comp
    # digest is content-sensitive
    mutated = dict(r_comp.timeline)
    mutated["hits"] = mutated["hits"] + 1
    assert timeline_digest(mutated) != d_comp


def test_timeline_off_without_history():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    res = run_policy(trace, "lru", CFG, record_history=False)
    assert res.timeline == {}


# ---------------------------------------------------------------------------
# canonical order, digest domain, decoding, export
# ---------------------------------------------------------------------------
def test_canonical_order_is_permutation_invariant():
    trace = build_fa2_trace(TINY_T, n_cores=8)
    sink, _ = _run(trace, "at+dbp", "compiled")
    m = sink.matrix()
    rng = np.random.default_rng(7)
    shuffled = m[rng.permutation(m.shape[0])]
    assert np.array_equal(canonical_order(shuffled), sink.canonical())


def test_digest_includes_schema_version():
    empty = np.empty((0, len(COLUMNS)), dtype=np.int64)
    d = stream_digest(empty)
    assert isinstance(d, str) and len(d) == 64
    # digest domain is versioned: a different payload changes it
    one = np.zeros((1, len(COLUMNS)), dtype=np.int64)
    assert stream_digest(one) != d


def test_decode_event_names_every_kind():
    rows = {
        "FILL": [3, 1, 0, 2, 5, 4, EV_FILL, 2 * 77 + 1],
        "HIT": [3, 1, 0, 2, 5, 4, EV_HIT, 0],
        "MSHR": [3, -1, 0, 2, 5, -1, EV_MSHR, 3],
        "BYPASS": [3, 1, 0, 2, 5, -1, EV_BYPASS, 1],
        "EVICT": [3, -1, 0, 2, 5, 4, EV_EVICT, 2 * 99],
        "WB": [3, -1, 0, 2, 5, 4, EV_WB, 99],
        "GEAR": [3, -1, 1, -1, 6, -1, EV_GEAR, 2],
        "RETIRE": [3, -1, 0, 7, -1, -1, EV_RETIRE, 11],
    }
    for name, row in rows.items():
        text = decode_event(row)
        assert name in text and "round=3" in text


def test_npz_export_roundtrip(tmp_path):
    trace = build_fa2_trace(TINY_T, n_cores=8)
    sink, _ = _run(trace, "dbp", "compiled")
    path = tmp_path / "events.npz"
    sink.to_npz(path)
    with np.load(path) as z:
        assert int(z["schema_version"][0]) == SCHEMA_VERSION
        m = sink.matrix()
        for i, name in enumerate(COLUMNS):
            assert np.array_equal(z[name], m[:, i])


# ---------------------------------------------------------------------------
# (set, tag) → line address inversion (victim attribution)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hash_sets", [True, False])
def test_line_addr_of_inverts_set_mapping(hash_sets):
    geom = CacheGeometry(256 * 1024, 128, 8, 8, hash_sets=hash_sets)
    rng = np.random.default_rng(3)
    addrs = (rng.integers(0, 1 << 32, size=4096) // 128) * 128
    sets = geom.set_of(addrs)
    tags = geom.tag_of(addrs)
    assert np.array_equal(geom.line_addr_of(sets, tags), addrs)


# ---------------------------------------------------------------------------
# live-region registration (allocator-aware overlap check)
# ---------------------------------------------------------------------------
def test_register_tensors_rejects_live_overlap_with_names():
    """A mid-stream registration colliding with a still-live region is
    an allocator bug; the error names the offender, its base, and the
    live region it collides with."""
    from repro.core.tmu import TensorMeta

    def meta(tid, base, size):
        return TensorMeta(tensor_id=tid, base_addr=base, size_bytes=size,
                          tile_bytes=size, n_acc=1)

    sink = EventSink()
    sink.register_tensors([meta(1, 0x10000, 0x800)])
    with pytest.raises(ValueError) as exc:
        sink.register_tensors([meta(2, 0x10400, 0x800)])
    msg = str(exc.value)
    assert "tensor 2" in msg and "0x10400" in msg
    assert "[0x10000, 0x10800)" in msg and "tensor 1" in msg

    # released regions may be recycled...
    sink.release_tensors([1])
    sink.register_tensors([meta(3, 0x10000, 0x800)])
    # ...and a same-segment retirement exempts its region in-window
    with pytest.raises(ValueError):
        sink.register_tensors([meta(4, 0x10000, 0x800)])
    sink.register_tensors([meta(4, 0x10000, 0x800)],
                          retiring_tids=frozenset({3}))

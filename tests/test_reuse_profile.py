"""Reuse-profile engine: lowering invariants, a trace-measured stack
distance cross-check, the policy-transform model, and the accuracy win
over the closed forms (DESIGN.md §5)."""

import numpy as np
import pytest

from repro.core import SimConfig
from repro.core import fit_params
from repro.core import gear_trajectory
from repro.core import named_policy
from repro.core import predict
from repro.core import run_policies
from repro.core import run_policy
from repro.core.workloads import AttnWorkload
from repro.core.workloads import DecodeWorkload
from repro.core.workloads import PrefixShareWorkload
from repro.core.workloads import SPATIAL
from repro.core.workloads import SSDScanWorkload
from repro.core.workloads import SpecDecodeWorkload
from repro.core.workloads import TEMPORAL
from repro.dataflows import decode_paged_spec
from repro.dataflows import fa2_spec
from repro.dataflows import lower_to_counts
from repro.dataflows import lower_to_reuse_profile
from repro.dataflows import lower_to_trace
from repro.dataflows import matmul_spec
from repro.dataflows import mlp_chain_spec
from repro.dataflows import prefix_share_spec
from repro.dataflows import spec_decode_spec
from repro.dataflows import ssd_scan_spec

TINY_T = AttnWorkload("tiny-t", 8, 4, 128, 1024, group_alloc=TEMPORAL)
TINY_S = AttnWorkload("tiny-s", 16, 4, 128, 1024, group_alloc=SPATIAL)
TINY_MB = AttnWorkload("tiny-mb", 4, 4, 128, 1024, group_alloc=TEMPORAL,
                       n_batches=2)
MINI_DECODE = DecodeWorkload(n_seqs=8, seq_len=1024, n_steps=4,
                             retire_step=2, n_short=4)
MINI_SPECDEC = SpecDecodeWorkload(n_seqs=4, target_len=256, draft_len=128,
                                  gamma=2, n_verify=2)
MINI_SSD = SSDScanWorkload(n_seqs=4, n_chunks=4, n_heads=4, d_head=64,
                           d_state=64, chunk_len=32)
MINI_PFX = PrefixShareWorkload(n_reqs=4, prefix_len=512, suffix_len=256,
                               n_steps=2)


# ---------------------------------------------------------------------------
# Lowering invariants against the closed-form counts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", [
    fa2_spec(TINY_T, 4), fa2_spec(TINY_S, 4), fa2_spec(TINY_MB, 4),
    matmul_spec(512, 512, 512, n_cores=4),
    decode_paged_spec(MINI_DECODE, 4),
    spec_decode_spec(MINI_SPECDEC, 4),
    ssd_scan_spec(MINI_SSD, 4),
    prefix_share_spec(MINI_PFX, 4),
], ids=lambda s: s.name)
def test_profile_mass_identities(spec):
    counts = lower_to_counts(spec)
    prof = counts.reuse_profile
    assert prof is not None
    # total reuse mass == temporal + inter-core reuse of the counts
    assert (prof.total_reuse_mass()
            == counts.n_temporal_reuse + counts.n_intercore_reuse)
    # cold mass == distinct reuse-carrier lines; bypass traffic matches
    assert int(prof.cold_round.sum()) == counts.n_kv_distinct
    assert prof.footprint_lines() == counts.n_kv_distinct
    assert (int(prof.byp_cold_round.sum() + prof.byp_rep_round.sum())
            == counts.n_bypass_lines)
    assert float(prof.flops_round.sum()) == counts.flops_total
    assert prof.n_rounds == counts.n_rounds
    # distances are well-formed
    assert (prof.e_dlive >= 0).all() and (prof.e_ddead >= 0).all()
    assert (prof.e_mass > 0).all()
    assert prof.e_dlive[prof.e_mshr].sum() == 0


# ---------------------------------------------------------------------------
# Cross-check: an independent LRU-stack walk over the lowered *trace*
# measures the same distances the profile derives from the schedule
# ---------------------------------------------------------------------------
def _measure_trace_distances(trace, dbp=False):
    """Tile-granular move-to-front stack walk (O(n²) oracle).

    ``dbp=True`` removes a tile from the stack the moment its load count
    reaches ``n_acc`` (TMU retirement) — measured distances then equal
    the profile's live component.
    """
    hist = {}
    stack = []                        # most recent first: (tid, tile)
    mass = {tid: m.tile_bytes // trace.line_bytes
            for tid, m in trace.tensors.items()}
    loads = {}
    for r in range(trace.n_rounds):
        this_round = {}
        for c, steps in enumerate(trace.core_steps):
            if r >= len(steps):
                continue
            step = steps[r]
            for (tid, tile), is_store in (
                    [(ld, False) for ld in step.loads]
                    + [(s, True) for s in step.stores]):
                if trace.tensors[tid].bypass_all:
                    continue
                key = (tid, tile)
                if not is_store:
                    loads[key] = loads.get(key, 0) + 1
                if key in this_round:
                    hist[0] = hist.get(0, 0) + mass[tid]
                    continue
                this_round[key] = True
                if key in stack:
                    d = sum(mass[k[0]] for k in
                            stack[:stack.index(key)])
                    hist[d] = hist.get(d, 0) + mass[tid]
                    stack.remove(key)
                retired = dbp and loads.get(key, 0) >= \
                    trace.tensors[tid].n_acc
                if not retired:
                    stack.insert(0, key)
    return hist


@pytest.mark.parametrize("dbp", [False, True], ids=["lru", "dbp"])
def test_trace_measured_distances_match_profile(dbp):
    """Simulator-trace-observed stack distances land in exactly the
    profile's histogram buckets (full distance without DBP, live
    distance with)."""
    spec = decode_paged_spec(MINI_DECODE, 4)
    prof = lower_to_reuse_profile(spec)
    trace = lower_to_trace(spec)
    measured = _measure_trace_distances(trace, dbp=dbp)
    assert measured == prof.histogram(dbp=dbp)


def test_epoch_aware_dead_mass():
    """Retired-generation lines show up as dead pollution, not reuse:
    the multi-batch dataflow carries dead mass in its distances and DBP
    strictly shortens them; the speculative-decoding draft windows all
    retire."""
    prof_mb = lower_to_reuse_profile(fa2_spec(TINY_MB, 4))
    assert int(prof_mb.e_ddead.sum()) > 0
    full = sum(d * m for d, m in prof_mb.histogram().items())
    live = sum(d * m for d, m in prof_mb.histogram(dbp=True).items())
    assert live < full

    spec = spec_decode_spec(MINI_SPECDEC, 4)
    prof = lower_to_reuse_profile(spec)
    # every reuse-carrier tile eventually reaches its nAcc (accurate
    # lifetimes), and the persistent target stream carries the retired
    # draft windows as dead pollution in its reuse windows
    assert prof.t_dies.all()
    t_sel = np.array([prof.tensor_names[t].startswith(("TK", "TV"))
                      for t in prof.e_tensor])
    assert int(prof.e_ddead[t_sel].sum()) > 0


# ---------------------------------------------------------------------------
# Profile model: transforms and orderings
# ---------------------------------------------------------------------------
def test_profile_model_monotone_in_cache_size():
    counts = lower_to_counts(fa2_spec(TINY_T, 4))
    hw = SimConfig(n_cores=4)
    fracs = [predict(counts, s * 2**20, "at+dbp", hw,
                     model="profile").kept_fraction
             for s in (1, 2, 4, 16)]
    assert all(a <= b + 1e-12 for a, b in zip(fracs, fracs[1:]))
    assert fracs[-1] == pytest.approx(1.0)


def test_profile_model_mechanism_orderings():
    """DBP never hurts (dead mass leaves the stack); anti-thrashing
    never loses to LRU in the thrashing regime."""
    counts = lower_to_counts(fa2_spec(TINY_T, 16))
    hw = SimConfig(n_cores=16)
    llc = 512 * 1024
    lru = predict(counts, llc, "lru", hw, model="profile")
    at = predict(counts, llc, "at+dbp", hw, model="profile")
    dbp = predict(counts, llc, "dbp", hw, model="profile")
    assert dbp.n_hit >= lru.n_hit
    assert at.n_hit >= lru.n_hit
    assert lru.cycles >= at.cycles


def test_profile_mshr_mass_always_hits():
    """Same-round co-streaming merges in the MSHRs under every policy —
    even full static bypass cannot lose that mass."""
    counts = lower_to_counts(fa2_spec(TINY_S, 4))
    prof = counts.reuse_profile
    mshr_mass = int(prof.e_mass[prof.e_mshr].sum())
    assert mshr_mass > 0
    hw = SimConfig(n_cores=4)
    pred = predict(counts, 256 * 1024, "bypass+dbp", hw,
                   bypass_variant="fix8", model="profile")
    assert pred.n_hit >= mshr_mass


def test_closed_fallback_without_profile():
    """model="profile" on counts lowered without a profile falls back to
    the closed forms bit-for-bit."""
    spec = fa2_spec(TINY_T, 4)
    bare = lower_to_counts(spec, with_profile=False)
    assert bare.reuse_profile is None
    hw = SimConfig(n_cores=4)
    a = predict(bare, 2**20, "at+dbp", hw, model="profile")
    b = predict(bare, 2**20, "at+dbp", hw, model="closed")
    assert a == b


def test_counts_equality_ignores_profile():
    spec = fa2_spec(TINY_T, 4)
    assert lower_to_counts(spec) == lower_to_counts(spec,
                                                    with_profile=False)


# ---------------------------------------------------------------------------
# Dirty-lifetime write-back model + gear-transient emulation (the PR-4
# blind spots: ROADMAP "write-back modeling" / "dynamic-gear transients")
# ---------------------------------------------------------------------------
def test_dirty_lifetime_profile_fields():
    """Structural invariants of the new dirty-lifetime facts."""
    prof = lower_to_reuse_profile(ssd_scan_spec(MINI_SSD, 4))
    # running states are produced by stores: dirty cold fills exist
    assert prof.t_cold_store.any()
    assert prof.e_store.shape == prof.e_mass.shape
    assert (prof.t_tail_dlive >= 0).all() and (prof.t_tail_ddead >= 0).all()
    assert (prof.t_last_round >= prof.t_cold_round).all()
    # every reuse entry's previous access precedes (or shares) its round
    assert (prof.e_prev_round <= prof.e_round).all()
    assert (prof.e_tile >= 0).all()
    assert prof.e_tile.max() < prof.t_mass.shape[0]


@pytest.mark.parametrize("spec,llc_kb", [
    (ssd_scan_spec(MINI_SSD, 4), 128),
    (mlp_chain_spec(m=512, dims=(256, 256, 256, 256), n_cores=4), 128),
    (prefix_share_spec(MINI_PFX, 4), 128),
    (spec_decode_spec(MINI_SPECDEC, 4), 128),
], ids=["ssd-scan", "mlp-chain", "prefix-share", "spec-decode"])
@pytest.mark.parametrize("pol", ["lru", "at", "at+dbp"])
def test_writeback_volume_matches_simulator(spec, llc_kb, pol):
    """The dirty-lifetime model's predicted write-back volume tracks the
    simulator's dirty-eviction count — per scenario and per policy,
    including the DBP case the old reuse-miss-fraction scaling got wrong
    (retired dirty tiles still write back when the dead FIFO evicts
    them)."""
    counts = lower_to_counts(spec)
    trace = lower_to_trace(spec)
    hw = SimConfig(n_cores=4, llc_bytes=llc_kb * 1024, llc_slices=8)
    res = run_policy(trace, named_policy(pol), hw, record_history=False)
    pred = predict(counts, hw.llc_bytes, pol, hw, n_rounds=counts.n_rounds)
    if res.writebacks == 0:
        # scenarios with no (evicted) dirty reuse carriers must not
        # invent write-back traffic
        assert pred.n_wb <= 0.02 * counts.n_kv_distinct
    else:
        rel = abs(pred.n_wb - res.writebacks) / res.writebacks
        assert rel <= 0.35, (pred.n_wb, res.writebacks)


def test_closed_model_carries_no_writeback_term():
    counts = lower_to_counts(ssd_scan_spec(MINI_SSD, 4))
    hw = SimConfig(n_cores=4)
    assert predict(counts, 2**20, "lru", hw, model="closed").n_wb == 0.0


@pytest.mark.parametrize("spec,llc_kb", [
    (fa2_spec(TINY_T, 4), 512),
    (mlp_chain_spec(m=512, dims=(256, 256, 256, 256), n_cores=4), 128),
    (prefix_share_spec(MINI_PFX, 4), 128),
], ids=["fa2", "mlp-chain", "prefix-share"])
def test_gear_trajectory_matches_history(spec, llc_kb):
    """The window-by-window §IV-D emulation reproduces the simulator's
    recorded gear trajectory: same ramp (mean absolute gear gap under a
    step) and a final gear within one step of the per-slice mean."""
    counts = lower_to_counts(spec)
    trace = lower_to_trace(spec)
    hw = SimConfig(n_cores=4, llc_bytes=llc_kb * 1024, llc_slices=8)
    res = run_policy(trace, named_policy("at+bypass"), hw,
                     record_history=True)
    g = gear_trajectory(counts, hw.llc_bytes, "at+bypass", hw)
    prof = counts.reuse_profile
    assert g.shape == (prof.n_rounds,)
    # history records only non-empty rounds; align the emulation to them
    req = (np.bincount(prof.e_round, minlength=prof.n_rounds)
           + prof.cold_round + prof.byp_cold_round + prof.byp_rep_round)
    emu = g[np.nonzero(req)[0]]
    sim = res.history["gear"]
    assert emu.shape[0] == sim.shape[0]
    assert abs(float(emu[-1]) - float(sim[-1])) <= 1.0
    assert np.abs(emu - sim).mean() <= 0.75


def test_gear_trajectory_requires_bypass_policy():
    counts = lower_to_counts(fa2_spec(TINY_T, 4))
    with pytest.raises(ValueError, match="does not bypass"):
        gear_trajectory(counts, 2**20, "lru")


def test_ssd_scan_dbp_win():
    """The scenario's reason to exist: retired chunk states are MRU dead
    mass under LRU; DBP frees them and keeps the live generation
    resident (sim-level pin of the suite-gated win)."""
    trace = lower_to_trace(ssd_scan_spec(MINI_SSD, 4))
    hw = SimConfig(n_cores=4, llc_bytes=64 * 1024, llc_slices=8)
    lru = run_policy(trace, named_policy("lru"), hw, record_history=False)
    dbp = run_policy(trace, named_policy("at+dbp"), hw,
                     record_history=False)
    assert dbp.hits + dbp.mshr_hits > lru.hits + lru.mshr_hits
    assert lru.cycles / dbp.cycles > 1.15


def test_prefix_share_intercore_mass():
    """The shared prefix shows up as the §IV-E population: same-round
    MSHR merges plus lagged-rank inter-core reuse riding LLC storage."""
    prof = lower_to_reuse_profile(prefix_share_spec(MINI_PFX, 4))
    assert int(prof.e_mass[prof.e_mshr].sum()) > 0
    assert int(prof.e_mass[prof.e_intercore].sum()) > 0
    # private suffixes are single-core streams: their entries carry no
    # inter-core mass
    suf = np.array([prof.tensor_names[t].startswith(("Ksuf", "Vsuf"))
                    for t in prof.e_tensor])
    assert not prof.e_intercore[suf].any()


# ---------------------------------------------------------------------------
# The refactor's reason to exist: the profile engine out-predicts the
# closed forms on the scenarios the ROADMAP called out (matmul-style
# weight-stationary reuse)
# ---------------------------------------------------------------------------
def test_profile_model_beats_closed_on_matmul_class():
    policies = ("lru", "at", "at+dbp", "all")
    hw = SimConfig(n_cores=4, llc_bytes=256 * 1024, llc_slices=8)
    pts = []
    for spec in (matmul_spec(512, 512, 512, n_cores=4),
                 mlp_chain_spec(m=512, dims=(256, 256, 256, 256),
                                n_cores=4)):
        trace = lower_to_trace(spec)
        counts = lower_to_counts(spec)
        for pol, res in zip(policies, run_policies(
                trace, [named_policy(p) for p in policies], hw)):
            pts.append((counts, hw.llc_bytes, pol, "optimal", False,
                        counts.n_rounds, res.cycles))

    errs = {}
    for model in ("closed", "profile"):
        params = fit_params(pts, hw, model=model)
        errs[model] = np.mean([
            abs(predict(c, sz, p, hw, params, v, g, n_rounds=r,
                        model=model).cycles - t) / t
            for (c, sz, p, v, g, r, t) in pts])
    assert errs["profile"] < errs["closed"], errs
    assert errs["profile"] < 0.25, errs

"""Content-addressed artifact cache (repro.dataflows.artifacts):
fingerprint determinism across processes, sensitivity to every content
field, and bit-identical round-trips of the cached lowerings."""

import os
import subprocess
import sys

import numpy as np

from repro.core import SimConfig
from repro.core import named_policy
from repro.core import run_policy
from repro.core.workloads import AttnWorkload
from repro.core.workloads import TEMPORAL
from repro.dataflows import artifacts
from repro.dataflows import artifacts_enabled
from repro.dataflows import fa2_spec
from repro.dataflows import lower_to_counts
from repro.dataflows import lower_to_trace
from repro.dataflows import matmul_spec
from repro.dataflows import registry_keys
from repro.dataflows import spec_fingerprint
from repro.dataflows import suite_case
from repro.dataflows import try_spec_fingerprint

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _wl():
    return AttnWorkload("fp", n_q_heads=8, n_kv_heads=4, head_dim=128,
                        seq_len=512, group_alloc=TEMPORAL)


# ---------------------------------------------------------------------------
# fingerprint determinism
# ---------------------------------------------------------------------------
_SUBPROC = """
import sys
sys.path.insert(0, {src!r})
from repro.core.workloads import AttnWorkload, TEMPORAL
from repro.dataflows import fa2_spec, spec_fingerprint
wl = AttnWorkload("fp", n_q_heads=8, n_kv_heads=4, head_dim=128,
                  seq_len=512, group_alloc=TEMPORAL)
print(spec_fingerprint(fa2_spec(wl, 4)))
"""


def test_fingerprint_stable_across_fresh_processes():
    """Two cold interpreters agree — no Python hash(), no dict-order or
    id() leakage (PYTHONHASHSEED varies per process by default)."""
    outs = [
        subprocess.run([sys.executable, "-c", _SUBPROC.format(src=SRC)],
                       capture_output=True, text=True, check=True,
                       env={**os.environ, "PYTHONHASHSEED": seed})
        .stdout.strip()
        for seed in ("0", "12345")
    ]
    assert outs[0] == outs[1]
    assert outs[0] == spec_fingerprint(fa2_spec(_wl(), 4))


def test_fingerprint_changes_on_any_field_edit():
    base = spec_fingerprint(fa2_spec(_wl(), 4))
    # a different core count, sequence length, or tile size must rekey
    assert spec_fingerprint(fa2_spec(_wl(), 8)) != base
    wl2 = AttnWorkload("fp", n_q_heads=8, n_kv_heads=4, head_dim=128,
                       seq_len=1024, group_alloc=TEMPORAL)
    assert spec_fingerprint(fa2_spec(wl2, 4)) != base
    assert (spec_fingerprint(matmul_spec(256, 256, 256, tile=128,
                                         n_cores=4))
            != spec_fingerprint(matmul_spec(256, 256, 512, tile=128,
                                            n_cores=4)))


def test_fingerprint_memoized_and_try_variant():
    spec = fa2_spec(_wl(), 4)
    assert spec_fingerprint(spec) == spec_fingerprint(spec)
    assert try_spec_fingerprint(spec) == spec_fingerprint(spec)
    assert try_spec_fingerprint(object()) is None


def test_registry_fingerprints_distinct():
    """Every registered scenario hashes to its own key — the registry-
    level handle into the artifact store."""
    fps = [suite_case(k).fingerprint for k in registry_keys()]
    assert len(set(fps)) == len(fps)


# ---------------------------------------------------------------------------
# on-disk round-trips
# ---------------------------------------------------------------------------
def _sim(trace):
    return run_policy(trace, named_policy("at+dbp"),
                      SimConfig(llc_bytes=256 * 1024, llc_slices=8))


def test_artifact_roundtrip_bit_identical(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_ARTIFACT_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_ARTIFACTS", raising=False)
    assert artifacts_enabled()

    spec = fa2_spec(_wl(), 4)
    cold = _sim(lower_to_trace(spec))
    counts_cold = lower_to_counts(spec)
    stored = list(tmp_path.glob("*.npz"))
    kinds = {p.name.split("-")[0] for p in stored}
    assert {"trace", "profile"} <= kinds

    warm = _sim(lower_to_trace(spec))          # second lowering: cache hit
    counts_warm = lower_to_counts(spec)
    for f in ("cycles", "hits", "mshr_hits", "cold_misses",
              "conflict_misses", "bypassed", "writebacks", "dram_lines"):
        assert getattr(cold, f) == getattr(warm, f), f
    pc, pw = counts_cold.reuse_profile, counts_warm.reuse_profile
    for name in artifacts._PROF_ARRAYS:
        np.testing.assert_array_equal(getattr(pc, name), getattr(pw, name))
    assert pc.tensor_names == pw.tensor_names
    assert pc.n_rounds == pw.n_rounds


def test_artifacts_disable_and_corruption(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_ARTIFACT_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_ARTIFACTS", "0")
    assert not artifacts_enabled()
    spec = fa2_spec(_wl(), 4)
    trace = lower_to_trace(spec)
    assert trace.fingerprint is None           # lowering skips the cache
    _sim(trace)
    assert list(tmp_path.glob("*.npz")) == []

    monkeypatch.setenv("REPRO_ARTIFACTS", "1")
    ref = _sim(lower_to_trace(spec))
    files = list(tmp_path.glob("*.npz"))
    assert files
    for p in files:                            # torn/corrupt file == miss
        p.write_bytes(b"not an npz")
    got = _sim(lower_to_trace(spec))
    assert got.cycles == ref.cycles and got.hits == ref.hits


def test_code_version_salts_the_key():
    key = artifacts.compiled_trace_key("deadbeef", 128)
    assert key == "deadbeef-lb128"
    path = artifacts._path("trace", key)
    assert artifacts.code_version() in path.name


def test_store_load_plan_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_ARTIFACT_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_ARTIFACTS", raising=False)
    idx = np.arange(17, dtype=np.int64)[::-1].copy()
    key = artifacts.plan_key("k", 2048, True)
    artifacts.store_plan_pass_idx(key, idx)
    got = artifacts.load_plan_pass_idx(key)
    np.testing.assert_array_equal(got, idx)
    assert artifacts.load_plan_pass_idx(artifacts.plan_key("k", 1024,
                                                           True)) is None

"""End-to-end behaviour tests for the DCO system: the paper's policy
pipeline, its analytical projection, and the TPU-side orchestration must
agree with each other."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CacheOrchestrator
from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import fa2_counts
from repro.core import named_policy
from repro.core import predict
from repro.core import run_policy
from repro.core.workloads import AttnWorkload
from repro.core.workloads import TEMPORAL
from repro.kernels import attention_ref
from repro.kernels import flash_attention


WL = AttnWorkload("sys-t", n_q_heads=8, n_kv_heads=8, head_dim=128,
                  seq_len=1024, group_alloc=TEMPORAL)
CFG = SimConfig(llc_bytes=1 * 2**20, llc_slices=8)


def test_end_to_end_policy_ordering_matches_model():
    """Simulator and analytical model must agree on the policy ranking
    for a thrashing workload (the paper's central claim chain)."""
    trace = build_fa2_trace(WL)
    counts = fa2_counts(WL)
    sim = {}
    for pol in ("lru", "at", "all"):
        sim[pol] = run_policy(trace, named_policy(pol), CFG,
                              record_history=False).cycles
    assert sim["lru"] > sim["at"] > sim["all"] * 0.999

    model = {p: predict(counts, CFG.llc_bytes, m,
                        n_rounds=counts.n_rounds).cycles
             for p, m in (("lru", "lru"), ("at", "at+dbp"), ("all", "all"))}
    assert model["lru"] >= model["at"] >= model["all"]


def test_end_to_end_orchestrated_kernel_consistency():
    """The orchestrator's S_kept plan must (a) respect the VMEM budget,
    (b) shrink with the budget (self-adaptive), and (c) produce a kernel
    split that matches the unorchestrated oracle numerically."""
    seq, d, g = 512, 128, 2
    bytes_per_row = 2 * d * 2
    pins = []
    for budget in (64 * 1024, 128 * 1024, 4 * 2**20):
        orch = CacheOrchestrator(vmem_budget_bytes=budget)
        pinned, streamed = orch.plan_kv_split(seq, 128, bytes_per_row)
        assert pinned + streamed == seq and pinned % 128 == 0
        if pinned * bytes_per_row:
            assert pinned * bytes_per_row <= budget
        pins.append(pinned)
    assert pins[0] <= pins[1] <= pins[2] == seq   # monotone in budget

    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, seq, 4, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, seq, g, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, seq, g, d), jnp.bfloat16)
    ref = attention_ref(q, k, v, causal=True)
    for pinned in sorted(set(pins)):
        out = flash_attention(q, k, v, causal=True, pinned_rows=pinned,
                              interpret=True)
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.astype(np.float32), rtol=2e-2,
                                   atol=2e-2)


def test_end_to_end_serving_retires_slots():
    """Dead-block behaviour at the serving layer: a finished request's
    slot is reused by the next queued request."""
    from repro.configs import get_arch, reduce_for_smoke
    from repro.models import init_params
    from repro.serve import Request, ServeEngine

    cfg = reduce_for_smoke(get_arch("llama3.2-3b"))
    params = init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(
        2, cfg.vocab, size=5).astype(np.int32), max_new_tokens=3)
        for i in range(3)]
    for r in reqs:
        engine.add_request(r)
    engine.run_to_completion()
    assert all(r.done for r in reqs)          # 3 requests through 1 slot
    assert engine._tmu.live_tiles == 0        # all slot lifetimes retired

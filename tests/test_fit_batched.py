"""θ-batched calibration and batched prediction (DESIGN.md §8.5): the
vectorized ``fit_params`` grid search and ``predict_batch`` must be
bit-identical to the sequential reference scan / per-policy ``predict``
calls they replace — same floats, same tie-breaks, same NaN handling."""

import pytest

from repro.core import ModelParams
from repro.core import SimConfig
from repro.core import named_policy
from repro.core import predict
from repro.core import predict_batch
from repro.core import run_policies
from repro.core.analytical import _fit_params_reference
from repro.core.analytical import fit_params
from repro.dataflows import SUITE_POLICIES
from repro.dataflows import lower_to_counts
from repro.dataflows import lower_to_trace
from repro.dataflows import suite_case

#: a dynamic-gear scenario, a pure-streaming one, and a DBP one — the
#: three fit regimes (static, dynamic replay, closed fallback) are all on
CASE_KEYS = ("matmul", "decode-paged", "moe-ffn")


@pytest.fixture(scope="module")
def fit_fixture():
    cases = [suite_case(k) for k in CASE_KEYS]
    hw = cases[0].cfg
    points, per_case = [], {}
    for case in cases:
        counts = lower_to_counts(case.spec)
        results = run_policies(
            lower_to_trace(case.spec),
            [named_policy(p, gqa=case.gqa) for p in SUITE_POLICIES],
            case.cfg)
        per_case[case.key] = (case, counts)
        for pol, res in zip(SUITE_POLICIES, results):
            points.append((counts, case.cfg.llc_bytes, pol, "optimal",
                           case.gqa, counts.n_rounds, res.cycles))
    return hw, points, per_case


@pytest.mark.parametrize("model", ["closed", "profile"])
def test_fit_params_bit_identical_to_reference(fit_fixture, model):
    hw, points, _ = fit_fixture
    ref = _fit_params_reference(points, hw, model=model)
    got = fit_params(points, hw, model=model)
    assert (got.theta1, got.theta2, got.theta3, got.lam,
            got.round_overhead) == (ref.theta1, ref.theta2, ref.theta3,
                                    ref.lam, ref.round_overhead)


def test_fit_params_deterministic_and_loso_shares_cache(fit_fixture):
    """Refitting (the LOSO loop's access pattern: overlapping point
    subsets, same candidate grids) reuses the per-point caches and stays
    exactly reproducible."""
    hw, points, _ = fit_fixture
    full = fit_params(points, hw, model="profile")
    assert fit_params(points, hw, model="profile") == full
    subset = points[:-len(SUITE_POLICIES)]       # leave one scenario out
    loso = fit_params(subset, hw, model="profile")
    assert loso == _fit_params_reference(subset, hw, model="profile")


def test_fit_params_empty_points_returns_default():
    assert fit_params([], SimConfig(), model="profile") == ModelParams()
    assert (_fit_params_reference([], SimConfig(), model="profile")
            == ModelParams())


@pytest.mark.parametrize("model", ["profile", "closed"])
def test_predict_batch_matches_predict(fit_fixture, model):
    hw, points, per_case = fit_fixture
    params = fit_params(points, hw, model=model)
    for case, counts in per_case.values():
        singles = [predict(counts, case.cfg.llc_bytes, p, hw, params,
                           "optimal", case.gqa, n_rounds=counts.n_rounds,
                           model=model)
                   for p in SUITE_POLICIES]
        batched = predict_batch(counts, case.cfg.llc_bytes,
                                SUITE_POLICIES, hw, params, "optimal",
                                case.gqa, n_rounds=counts.n_rounds,
                                model=model)
        assert batched == singles          # full Prediction equality


def test_predict_batch_rejects_unknown_model(fit_fixture):
    hw, _, per_case = fit_fixture
    case, counts = next(iter(per_case.values()))
    with pytest.raises(KeyError):
        predict_batch(counts, case.cfg.llc_bytes, ["lru"], hw,
                      model="quantum")

"""Substrate tests: optimizer, train step, data, checkpoint fault
tolerance, gradient compression, watchdog, serve engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.compat import shard_map
from repro.configs import get_arch
from repro.configs import reduce_for_smoke
from repro.data import SyntheticLM
from repro.data import make_batch
from repro.models import decode_step
from repro.models import init_params
from repro.models import prefill
from repro.serve import Request
from repro.serve import ServeEngine
from repro.train import AdamWConfig
from repro.train import StepWatchdog
from repro.train import compressed_psum_mean
from repro.train import init_error_feedback
from repro.train import init_train_state
from repro.train import lr_schedule
from repro.train import make_train_step
from repro.train import opt_logical_axes
from repro.train import param_logical_axes

CFG = reduce_for_smoke(get_arch("llama3.2-3b"))


# ---------------------------------------------------------------------------
# optimizer / train loop
# ---------------------------------------------------------------------------
def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)


@pytest.mark.slow
def test_train_loss_decreases():
    params = init_params(CFG, jax.random.key(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(
        CFG, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)))
    data = SyntheticLM(CFG.vocab, seq_len=64, global_batch=8)
    losses = []
    for i in range(20):
        state, metrics = step(state, jnp.asarray(data.batch(i)))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    params = init_params(CFG, jax.random.key(0))
    tokens = jnp.asarray(make_batch(CFG.vocab, 8, 32))
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1, m1 = jax.jit(make_train_step(CFG, opt, microbatches=1))(
        init_train_state(params), tokens)
    s2, m2 = jax.jit(make_train_step(CFG, opt, microbatches=4))(
        init_train_state(params), tokens)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(a.astype(np.float32),
                                   b.astype(np.float32), rtol=2e-2,
                                   atol=2e-3)


def test_param_axes_structure_matches_params():
    for name in ("llama3.2-3b", "deepseek-moe-16b", "mamba2-2.7b",
                 "zamba2-7b"):
        cfg = reduce_for_smoke(get_arch(name))
        params = init_params(cfg, jax.random.key(0))
        axes = param_logical_axes(cfg)
        pl = jax.tree_util.tree_structure(params)
        al = jax.tree_util.tree_structure(
            axes, is_leaf=lambda x: isinstance(x, tuple))
        assert pl == al, f"{name}: axes tree != params tree"
        # every axes tuple has the same rank as its param
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(axes,
                                 is_leaf=lambda x: isinstance(x, tuple))
        for p, a in zip(flat_p, flat_a):
            assert p.ndim == len(a), f"{name}: rank mismatch {p.shape} {a}"
        # ZeRO axes add 'zero' only on unsharded leading dims
        zaxes = jax.tree.leaves(opt_logical_axes(cfg),
                                is_leaf=lambda x: isinstance(x, tuple))
        for a, z in zip(flat_a, zaxes):
            assert len(a) == len(z)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_compressed_psum_single_shard_roundtrip():
    """On a 1-device axis the compressed mean must equal g up to int8
    quantization error, and error feedback must capture the residual."""
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)}
    e = init_error_feedback(g)

    def f(g, e):
        return compressed_psum_mean(g, e, "pod")

    from jax.sharding import PartitionSpec as P
    out, err = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False))(g, e)
    q_err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    assert q_err.max() <= (1.0 / 127.0) + 1e-6
    np.testing.assert_allclose(np.asarray(err["w"]),
                               np.asarray(g["w"] - out["w"]), atol=1e-6)


def test_compressed_psum_error_feedback_converges():
    """Repeatedly syncing the same gradient with error feedback must
    average out the quantization bias (sum of dequantized ≈ sum of true)."""
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray([[0.003, -0.7], [0.31, 0.02]])}
    e = init_error_feedback(g)
    from jax.sharding import PartitionSpec as P
    f = jax.jit(shard_map(
        lambda g, e: compressed_psum_mean(g, e, "pod"), mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False))
    total = jnp.zeros_like(g["w"])
    for _ in range(50):
        out, e = f(g, e)
        total = total + out["w"]
    np.testing.assert_allclose(np.asarray(total) / 50,
                               np.asarray(g["w"]), atol=2e-3)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_data_deterministic_and_sharded():
    d1 = SyntheticLM(1000, 128, 16, seed=7, n_shards=4, shard=2)
    d2 = SyntheticLM(1000, 128, 16, seed=7, n_shards=4, shard=2)
    np.testing.assert_array_equal(d1.batch(5), d2.batch(5))
    assert d1.batch(5).shape == (4, 128)
    d3 = SyntheticLM(1000, 128, 16, seed=7, n_shards=4, shard=3)
    assert not np.array_equal(d1.batch(5), d3.batch(5))
    assert (d1.batch(0) < 1000).all() and (d1.batch(0) >= 0).all()


# ---------------------------------------------------------------------------
# checkpointing / fault tolerance
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_pruning(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.float32)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, tree))
    assert mgr._steps() == [2, 3]            # pruned to keep_n
    step, restored = mgr.restore_latest(tree)
    assert step == 3
    np.testing.assert_allclose(np.asarray(restored["b"]["c"]),
                               3 * np.ones(4))


def test_checkpoint_survives_corruption(tmp_path):
    """Corrupting the newest checkpoint must fall back to the previous
    valid one (node-failure torn-write scenario)."""
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    tree = {"w": jnp.ones((8,), jnp.float32)}
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda x: x * 2, tree))
    # corrupt step 2's arrays
    npz = os.path.join(str(tmp_path), "step_00000002", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(30)
        f.write(b"\x00" * 64)
    step, restored = mgr.restore_latest(tree)
    assert step == 1
    np.testing.assert_allclose(np.asarray(restored["w"]), np.ones(8))


@pytest.mark.slow
def test_checkpoint_resume_training(tmp_path):
    """Kill-and-resume: state restored from disk continues bit-exactly."""
    params = init_params(CFG, jax.random.key(0))
    state = init_train_state(params)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    step_fn = jax.jit(make_train_step(CFG, opt))
    data = SyntheticLM(CFG.vocab, 32, 4)
    mgr = CheckpointManager(str(tmp_path))
    for i in range(3):
        state, _ = step_fn(state, jnp.asarray(data.batch(i)))
    mgr.save(3, state)
    state_a = state
    for i in range(3, 5):
        state_a, _ = step_fn(state_a, jnp.asarray(data.batch(i)))
    # simulated preemption: fresh process restores and replays
    step0, state_b = mgr.restore_latest(init_train_state(params))
    assert step0 == 3
    for i in range(3, 5):
        state_b, _ = step_fn(state_b, jnp.asarray(data.batch(i)))
    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------
def test_watchdog_flags_stragglers():
    evicted = []
    wd = StepWatchdog(threshold=3.0, evict_after=2,
                      on_straggler=lambda s, d: evicted.append(s))
    for s in range(10):
        assert not wd.record(s, 1.0)
    assert wd.record(10, 10.0)
    assert wd.record(11, 12.0)
    assert evicted == [11]
    assert not wd.record(12, 1.0)      # recovery resets the streak


# ---------------------------------------------------------------------------
# serve engine
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_serve_engine_batched_matches_single():
    cfg = CFG
    params = init_params(cfg, jax.random.key(0))

    def reference_decode(prompt, n):
        logits, cache = jax.jit(lambda p, t: prefill(p, t, cfg))(
            params, jnp.asarray(prompt[None]))
        # pad cache seq to engine max_seq
        pad = 64 - cache.k.shape[2]
        cache = cache._replace(
            k=jnp.pad(cache.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            v=jnp.pad(cache.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))))
        out = [int(jnp.argmax(logits[0]))]
        dec = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
        for _ in range(n - 1):
            lg, cache = dec(params, jnp.asarray([[out[-1]]]), cache)
            out.append(int(jnp.argmax(lg[0, 0])))
        return out

    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=L).astype(np.int32)
               for L in (7, 13, 10)]
    engine = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.add_request(r)
    engine.run_to_completion()
    for r, p in zip(reqs, prompts):
        assert r.done and len(r.tokens_out) == 5
        assert r.tokens_out == reference_decode(p, 5), \
            f"request {r.uid} diverged"

"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode on
CPU), sweeping shapes and dtypes as required."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.orchestrator import CacheOrchestrator
from repro.kernels import attention_ref
from repro.kernels import decode_attention
from repro.kernels import decode_attention_ref
from repro.kernels import flash_attention
from repro.kernels import ssd_ref
from repro.kernels import ssd_scan
from repro.kernels import ssd_sequential_ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # (B, Sq, Sk, H, G, D, causal, softcap, pinned, dtype)
    (1, 256, 256, 4, 4, 128, True, None, 0, jnp.float32),
    (2, 256, 256, 8, 2, 128, True, None, 0, jnp.bfloat16),
    (1, 128, 512, 4, 1, 128, False, None, 0, jnp.float32),
    (1, 256, 256, 4, 2, 128, True, 50.0, 0, jnp.float32),
    (2, 256, 256, 4, 2, 64, True, None, 128, jnp.float32),   # pinned prefix
    (1, 384, 384, 2, 2, 128, True, None, 256, jnp.bfloat16),  # mostly pinned
    (1, 256, 256, 4, 4, 128, True, None, 256, jnp.float32),  # fully pinned
]


@pytest.mark.parametrize(
    "b,sq,sk,h,g,d,causal,softcap,pinned,dtype", FLASH_CASES)
def test_flash_attention_matches_ref(b, sq, sk, h, g, d, causal, softcap,
                                     pinned, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = rand(ks[0], (b, sq, h, d), dtype)
    k = rand(ks[1], (b, sk, g, d), dtype)
    v = rand(ks[2], (b, sk, g, d), dtype)
    out = flash_attention(q, k, v, causal=causal, softcap=softcap,
                          pinned_rows=pinned, block_q=128, block_k=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), rtol=tol, atol=tol)


def test_flash_attention_orchestrator_split_is_valid():
    """The CacheOrchestrator's S_kept split must be block-aligned and fit
    the budget, and the kernel must accept it."""
    orch = CacheOrchestrator(vmem_budget_bytes=256 * 1024, b_bits=3)
    seq = 1024
    bytes_per_row = 2 * 128 * 2          # K+V rows, bf16, d=128
    pinned, streamed = orch.plan_kv_split(seq, 128, bytes_per_row)
    assert pinned + streamed == seq
    assert pinned % 128 == 0
    assert pinned * bytes_per_row <= 256 * 1024
    ks = jax.random.split(jax.random.key(1), 3)
    q = rand(ks[0], (1, seq, 2, 128), jnp.bfloat16)
    k = rand(ks[1], (1, seq, 2, 128), jnp.bfloat16)
    v = rand(ks[2], (1, seq, 2, 128), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, pinned_rows=pinned,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), rtol=2e-2, atol=2e-2)


def test_flash_attention_pinned_equivalence():
    """Pinned split is a pure execution-schedule change: results must be
    bit-consistent across split points (same fp32 accumulation order up to
    reassociation tolerance)."""
    ks = jax.random.split(jax.random.key(2), 3)
    q = rand(ks[0], (1, 256, 2, 128), jnp.float32)
    k = rand(ks[1], (1, 256, 2, 128), jnp.float32)
    v = rand(ks[2], (1, 256, 2, 128), jnp.float32)
    outs = [flash_attention(q, k, v, causal=True, pinned_rows=p,
                            interpret=True) for p in (0, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------
DECODE_CASES = [
    # (B, S, H, G, D, dtype)
    (1, 512, 4, 4, 128, jnp.float32),
    (2, 1024, 8, 2, 128, jnp.bfloat16),
    (2, 512, 4, 1, 64, jnp.float32),
    (1, 2048, 16, 4, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,g,d,dtype", DECODE_CASES)
def test_decode_attention_matches_ref(b, s, h, g, d, dtype):
    ks = jax.random.split(jax.random.key(3), 4)
    q = rand(ks[0], (b, h, d), dtype)
    k = rand(ks[1], (b, s, g, d), dtype)
    v = rand(ks[2], (b, s, g, d), dtype)
    lens = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, k, v, lens, block_k=256, interpret=True)
    ref = decode_attention_ref(q, k, v, lens)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), rtol=tol, atol=tol)


def test_decode_attention_dead_blocks_never_counted():
    """Slots past cache_len must not affect the result (retired data)."""
    ks = jax.random.split(jax.random.key(4), 3)
    q = rand(ks[0], (1, 4, 64), jnp.float32)
    k = rand(ks[1], (1, 512, 2, 64), jnp.float32)
    v = rand(ks[2], (1, 512, 2, 64), jnp.float32)
    lens = jnp.array([300], jnp.int32)
    out1 = decode_attention(q, k, v, lens, interpret=True, block_k=256)
    # poison the dead region
    k2 = k.at[:, 300:].set(1e4)
    v2 = v.at[:, 300:].set(-1e4)
    out2 = decode_attention(q, k2, v2, lens, interpret=True, block_k=256)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
SSD_CASES = [
    # (B, S, H, G, P, N, chunk, dtype)
    (1, 128, 2, 1, 64, 32, 32, jnp.float32),
    (2, 256, 4, 1, 32, 64, 64, jnp.float32),
    (1, 256, 4, 2, 64, 32, 64, jnp.bfloat16),
    (1, 512, 2, 1, 64, 128, 128, jnp.float32),
]


def _ssd_inputs(key, b, s, h, g, p, n, dtype):
    ks = jax.random.split(key, 4)
    x = rand(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32)) * 0.1
    A = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=-1.0, maxval=1.0))
    B = rand(ks[3], (b, s, g, n), dtype)
    C = rand(jax.random.key(99), (b, s, g, n), dtype)
    return x, dt, A, B, C


@pytest.mark.parametrize("b,s,h,g,p,n,chunk,dtype", SSD_CASES)
@pytest.mark.slow
def test_ssd_kernel_matches_chunked_ref(b, s, h, g, p, n, chunk, dtype):
    x, dt, A, B, C = _ssd_inputs(jax.random.key(5), b, s, h, g, p, n, dtype)
    y, state = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_ref, state_ref = ssd_ref(x, dt, A, B, C, chunk)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(y.astype(np.float32),
                               y_ref.astype(np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(state, state_ref, rtol=tol, atol=tol)


@pytest.mark.slow
def test_ssd_chunked_matches_sequential():
    """The chunked SSD algorithm (model path) vs O(S) recurrence."""
    x, dt, A, B, C = _ssd_inputs(jax.random.key(6), 2, 128, 2, 1, 32, 16,
                                 jnp.float32)
    y_c, st_c = ssd_ref(x, dt, A, B, C, chunk=32)
    y_s, st_s = ssd_sequential_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y_c, y_s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_c, st_s.transpose(0, 1, 2, 3), rtol=1e-4,
                               atol=1e-4)

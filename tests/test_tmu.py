"""Unit tests for the TMU functional model (paper §IV-B, Table I/III)."""

import pytest

from repro.core.tmu import DeadFIFO
from repro.core.tmu import TMU
from repro.core.tmu import TMUParams
from repro.core.tmu import TensorMeta


def test_dead_fifo_bounded_and_fifo_order():
    f = DeadFIFO(depth=4)
    for i in range(4):
        assert f.push(i) is None
    assert len(f) == 4
    # full: pushing drops the oldest
    dropped = f.push(99)
    assert dropped == 0
    assert 0 not in f and 99 in f and 1 in f


def test_dead_fifo_duplicate_membership():
    f = DeadFIFO(depth=3)
    f.push(7)
    f.push(7)
    f.push(1)
    assert 7 in f
    assert f.push(2) is not None     # drops first 7
    assert 7 in f                    # second copy still present
    assert f.push(3) is not None     # drops second 7
    assert 7 not in f


def test_tensor_meta_validation():
    with pytest.raises(ValueError):
        TensorMeta(0, base_addr=0, size_bytes=1000, tile_bytes=300, n_acc=1)
    m = TensorMeta(0, base_addr=4096, size_bytes=4096, tile_bytes=1024,
                   n_acc=3)
    assert m.num_tiles == 4
    assert m.tile_of(4096 + 1500) == 1
    assert m.tile_last_line(0, 128) == 4096 + 1024 - 128


def test_tmu_register_capacity():
    tmu = TMU(tensor_entries=2)
    tmu.register(TensorMeta(0, 0, 1024, 1024, 1))
    tmu.register(TensorMeta(1, 1024, 1024, 1024, 1))
    with pytest.raises(RuntimeError):
        tmu.register(TensorMeta(2, 2048, 1024, 1024, 1))
    tmu.clear(0)
    tmu.register(TensorMeta(2, 2048, 1024, 1024, 1))


def test_tile_retires_after_nacc_tll_accesses():
    """accCnt increments on tile-last-line access; at nAcc the tile's
    tag[D_MSB:D_LSB] enters the dead FIFO."""
    params = TMUParams(d_lsb=0, d_msb=11, b_bits=3)
    tmu = TMU(line_bytes=128, params=params)
    meta = TensorMeta(0, base_addr=0, size_bytes=2048, tile_bytes=1024,
                      n_acc=2)
    tmu.register(meta)
    tll = meta.tile_last_line(0, 128)
    tag = 0x123
    # non-TLL access: no effect
    tmu.on_access(0, tag)
    assert tmu.acc_cnt(0, 0) == 0
    tmu.on_access(tll, tag)
    assert tmu.acc_cnt(0, 0) == 1
    assert not tmu.is_dead(tag)
    tmu.on_access(tll, tag)
    assert tmu.acc_cnt(0, 0) == 0           # retired
    assert tmu.is_dead(tag)
    assert tmu.stats["tiles_retired"] == 1


def test_bypass_all_tensor_not_tracked():
    tmu = TMU()
    meta = TensorMeta(0, 0, 1024, 1024, n_acc=1, bypass_all=True)
    tmu.register(meta)
    tmu.on_access(meta.tile_last_line(0, 128), 0x5)
    assert tmu.stats["tll_accesses"] == 0


def test_priority_and_dead_id_bit_slicing():
    p = TMUParams(d_lsb=2, d_msb=5, b_bits=3)
    tag = 0b110101100
    assert p.priority(tag) == 0b100
    assert p.dead_id(tag) == (tag >> 2) & 0xF


def test_live_table_overflow_is_lossy_not_fatal():
    tmu = TMU(tile_entries=2)
    meta = TensorMeta(0, 0, 4096, 1024, n_acc=5)
    tmu.register(meta)
    for t in range(4):
        tmu.on_access(meta.tile_last_line(t, 128), t)
    assert tmu.live_tiles == 2
    assert tmu.stats["live_overflow_evictions"] == 2


def test_area_report_within_order_of_magnitude_of_paper():
    tmu = TMU(tensor_entries=8, tile_entries=256, dead_fifo_depth=16)
    rep = tmu.area_report()
    # the paper's synthesized TMU is 64,438 µm²; a bit-count estimate of
    # the Table-III configuration should land within ~10x
    assert 3_000 < rep["estimated_um2"] < 650_000

"""Multi-tenant time-slicing (DESIGN.md §8.4): composition invariants,
per-tenant simulator attribution and conservation, per-slice gear
control in both engines and in the analytical emulation."""

import numpy as np
import pytest

from repro.core import SimConfig
from repro.core import gear_trajectory
from repro.core import named_policy
from repro.core import predict
from repro.core import run_policy
from repro.core.workloads import AttnWorkload
from repro.core.workloads import DecodeWorkload
from repro.core.workloads import SSDScanWorkload
from repro.core.workloads import SpecDecodeWorkload
from repro.core.workloads import TEMPORAL
from repro.dataflows import compose_time_sliced
from repro.dataflows import decode_paged_spec
from repro.dataflows import fa2_spec
from repro.dataflows import lower_to_counts
from repro.dataflows import lower_to_plan
from repro.dataflows import lower_to_reuse_profile
from repro.dataflows import lower_to_trace
from repro.dataflows import spec_decode_spec
from repro.dataflows import ssd_scan_spec
from repro.dataflows import suite_case
from repro.dataflows import tenant_regions
from repro.dataflows.compose import REGION_ALIGN_BYTES

PF = AttnWorkload("pf", 8, 4, 128, 512, group_alloc=TEMPORAL)
DEC = DecodeWorkload(n_seqs=8, seq_len=512, n_steps=3, retire_step=2,
                     n_short=4)
SPD = SpecDecodeWorkload(n_seqs=4, target_len=256, draft_len=128, gamma=2,
                        n_verify=2)
SSD = SSDScanWorkload(n_seqs=4, n_chunks=4, n_heads=4, d_head=64,
                      d_state=64, chunk_len=32)
HW = SimConfig(n_cores=4, llc_bytes=512 * 1024, llc_slices=8)


def _mix(quantum=8):
    return compose_time_sliced(
        [fa2_spec(PF, 4), decode_paged_spec(DEC, 4)],
        quantum_rounds=quantum)


# ---------------------------------------------------------------------------
# Composition invariants
# ---------------------------------------------------------------------------
def test_composite_is_valid_and_conserves_schedule():
    a, b = fa2_spec(PF, 4), decode_paged_spec(DEC, 4)
    comp = _mix()
    comp.validate()
    assert comp.n_tenants == 2
    assert comp.n_rounds == a.n_rounds + b.n_rounds
    # per-tensor access totals are exactly the tenants' own totals
    per = comp.per_tensor_line_accesses()
    for i, sp in enumerate((a, b)):
        own = sp.per_tensor_line_accesses()
        for name, tot in own.items():
            assert per[f"t{i}.{name}"] == tot
    assert comp.total_flops() == a.total_flops() + b.total_flops()


def test_tenant_regions_disjoint_and_aligned():
    comp = _mix()
    regions = tenant_regions(comp)
    assert [n for n, _, _ in regions] == comp.tenant_names
    for _, base, end in regions:
        assert base % REGION_ALIGN_BYTES == 0
        assert end > base
    for (_, _, e0), (_, b1, _) in zip(regions, regions[1:]):
        assert e0 <= b1                       # disjoint, ascending
    # round-trip: every tensor's addresses fall inside its tenant's region
    from repro.dataflows import assign_addresses
    metas = assign_addresses(comp)
    for tid, t in enumerate(comp.tensors):
        ten = comp.tenant_of_tensor[t.name]
        _, base, end = regions[ten]
        assert base <= metas[tid].base_addr
        assert metas[tid].end_addr <= end


def test_all_four_lowerings_work_on_composite():
    comp = _mix()
    trace = lower_to_trace(comp)
    counts = lower_to_counts(comp)
    prof = lower_to_reuse_profile(comp)
    plan = lower_to_plan(comp, 1 << 20)
    assert trace.n_tenants == 2 and trace.tenant_region_starts() is not None
    assert counts.reuse_profile is not None
    assert prof.n_tenants == 2
    # profile mass identities hold on the composite exactly as on any
    # spec (the §V-C scalars stay marginals of the interleaved profile)
    assert (prof.total_reuse_mass()
            == counts.n_temporal_reuse + counts.n_intercore_reuse)
    assert prof.footprint_lines() == counts.n_kv_distinct
    # the plan covers the namespaced union tensor set
    assert len(plan.entries) == len(comp.tensors)


def test_composite_profile_masses_recount_per_tenant():
    """The interleaving-aware recount: per-tenant masses of the
    composite profile sum to the composite totals, and each tenant's
    cold mass equals its stand-alone footprint (interleaving moves
    reuse distances, never cold mass)."""
    a, b = fa2_spec(PF, 4), decode_paged_spec(DEC, 4)
    comp = _mix()
    prof = lower_to_reuse_profile(comp)
    e_ten = prof.e_tenant
    per_t = [int(prof.e_mass[e_ten == i].sum()) for i in range(2)]
    assert sum(per_t) == prof.total_reuse_mass()
    cold_t = prof.cold_rt.sum(axis=0)
    assert int(cold_t.sum()) == int(prof.cold_round.sum())
    for i, sp in enumerate((a, b)):
        own = lower_to_reuse_profile(sp)
        assert int(cold_t[i]) == own.footprint_lines()
        # reuse mass is invariant under interleaving too: the same
        # accesses repeat, only their distances change
        assert per_t[i] == own.total_reuse_mass()


def test_compose_rejects_bad_inputs():
    with pytest.raises(ValueError, match="at least one"):
        compose_time_sliced([])
    with pytest.raises(ValueError, match="quantum"):
        compose_time_sliced([fa2_spec(PF, 4)], quantum_rounds=0)


# ---------------------------------------------------------------------------
# Simulator: per-tenant attribution + conservation
# ---------------------------------------------------------------------------
TENANT_KEYS = ("hits", "mshr_hits", "cold_misses", "conflict_misses",
               "bypassed", "writebacks")


def assert_tenant_conservation(res):
    assert res.tenants
    for key in TENANT_KEYS:
        total = sum(t[key] for t in res.tenants.values())
        assert total == getattr(res, key), key


@pytest.mark.parametrize("pol", ["lru", "at+dbp", "at+bypass", "all"])
def test_per_tenant_counters_conserve(pol):
    trace = lower_to_trace(_mix())
    res = run_policy(trace, named_policy(pol), HW, record_history=False)
    assert_tenant_conservation(res)
    # both tenants actually produce traffic
    assert all(t["hits"] + t["cold_misses"] > 0
               for t in res.tenants.values())


def test_single_tenant_trace_has_no_tenant_counters():
    res = run_policy(lower_to_trace(fa2_spec(PF, 4)), named_policy("lru"),
                     HW, record_history=False)
    assert res.tenants == {}


# ---------------------------------------------------------------------------
# Per-slice gear control: simulator and analytical emulation
# ---------------------------------------------------------------------------
def test_per_tenant_gears_diverge_and_match_model():
    """One feedback loop per tenant: the simulator's opt-in per-tenant
    controller lets the tenants' gears diverge, and the per-slice
    trajectory emulation reproduces each tenant's trajectory against
    ``history["tenant_gear"]`` (final gear ±1, bounded mean gap)."""
    comp = _mix()
    trace = lower_to_trace(comp)
    counts = lower_to_counts(comp)
    pol = named_policy("at+bypass", per_tenant_gears=True)
    res = run_policy(trace, pol, HW, record_history=True)
    sim = res.history["tenant_gear"]
    assert sim.shape[1] == 2

    g = gear_trajectory(counts, HW.llc_bytes, "at+bypass", HW,
                        per_tenant=True)
    prof = counts.reuse_profile
    assert g.shape == (prof.n_rounds, 2)
    req = (np.bincount(prof.e_round, minlength=prof.n_rounds)
           + prof.cold_round + prof.byp_cold_round + prof.byp_rep_round)
    emu = g[np.nonzero(req)[0]]
    assert emu.shape[0] == sim.shape[0]
    for i in range(2):
        assert abs(float(emu[-1, i]) - float(sim[-1, i])) <= 1.0
        assert np.abs(emu[:, i] - sim[:, i]).mean() <= 1.0


def test_per_tenant_gear_requires_composite():
    counts = lower_to_counts(fa2_spec(PF, 4))
    with pytest.raises(ValueError, match="multi-tenant"):
        gear_trajectory(counts, HW.llc_bytes, "at+bypass", HW,
                        per_tenant=True)


def test_global_controller_unchanged_by_flag_on_single_tenant():
    """per_tenant_gears on a single-tenant trace is bit-identical to
    the global controller (the flag only engages with a tenant map)."""
    trace = lower_to_trace(fa2_spec(PF, 4))
    a = run_policy(trace, named_policy("at+bypass"), HW)
    b = run_policy(trace, named_policy("at+bypass",
                                       per_tenant_gears=True), HW)
    assert a.cycles == b.cycles and a.hits == b.hits
    np.testing.assert_array_equal(a.history["gear"], b.history["gear"])


# ---------------------------------------------------------------------------
# Analytical model: per-tenant breakdowns
# ---------------------------------------------------------------------------
def test_prediction_tenant_breakdowns_conserve():
    comp = compose_time_sliced(
        [spec_decode_spec(SPD, 4), ssd_scan_spec(SSD, 4)],
        quantum_rounds=8)
    counts = lower_to_counts(comp)
    for pol in ("lru", "at+dbp", "at+bypass"):
        pred = predict(counts, HW.llc_bytes, pol, HW,
                       n_rounds=counts.n_rounds)
        assert pred.n_hit_tenant is not None
        assert sum(pred.n_hit_tenant) == pytest.approx(pred.n_hit)
        assert sum(pred.n_miss_tenant) == pytest.approx(
            pred.n_cold + pred.n_cf)
        assert sum(pred.n_wb_tenant) == pytest.approx(pred.n_wb)


def test_single_tenant_prediction_has_no_breakdowns():
    counts = lower_to_counts(fa2_spec(PF, 4))
    pred = predict(counts, HW.llc_bytes, "lru", HW)
    assert pred.n_hit_tenant is None


# ---------------------------------------------------------------------------
# Suite mixes: registered and in the contended regime
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("key", ["mt-prefill-decode", "mt-spec-ssd"])
def test_suite_mixes_registered(key):
    case = suite_case(key, n_cores=4)
    assert case.spec.n_tenants == 2
    assert case.expect_dbp_win


def test_mt_mix_dbp_win_mini():
    """The mixes' reason to exist at miniature scale: dead pages /
    retired windows of both tenants pollute the shared LLC under LRU;
    DBP clears each tenant's region."""
    comp = compose_time_sliced(
        [spec_decode_spec(SPD, 4), ssd_scan_spec(SSD, 4)],
        quantum_rounds=8)
    trace = lower_to_trace(comp)
    hw = SimConfig(n_cores=4, llc_bytes=128 * 1024, llc_slices=8)
    lru = run_policy(trace, named_policy("lru"), hw, record_history=False)
    dbp = run_policy(trace, named_policy("at+dbp"), hw,
                     record_history=False)
    assert dbp.hits + dbp.mshr_hits > lru.hits + lru.mshr_hits
    assert lru.cycles > dbp.cycles
    assert_tenant_conservation(dbp)

"""scripts/suite_gate.py budget plumbing (--sps-budget /
REPRO_SPS_BUDGET) and the pinned at-row saturation-residue ceilings."""

import json
import os
from pathlib import Path
import subprocess
import sys

REPO = Path(__file__).resolve().parents[1]
GATE = REPO / "scripts" / "suite_gate.py"


def _report(tmp_path, sps=3.0, rows=None):
    path = tmp_path / "suite_bench.json"
    path.write_text(json.dumps({
        "model_rel_err_by_scenario": {"profile": {"matmul": 0.05},
                                      "closed": {"matmul": 0.05}},
        "dbp_win_scenarios": [],
        "rows": rows or {},
        "perf": {"seconds_per_scenario": sps, "case_seconds": {}},
    }))
    return path


def _gate(report, *flags, env=None):
    e = dict(os.environ)
    e.pop("REPRO_SPS_BUDGET", None)
    if env:
        e.update(env)
    return subprocess.run(
        [sys.executable, str(GATE), str(report), *flags],
        capture_output=True, text=True, cwd=REPO, env=e)


def test_default_budget_passes(tmp_path):
    proc = _gate(_report(tmp_path))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "suite gate OK" in proc.stdout


def test_flag_tightens_budget(tmp_path):
    proc = _gate(_report(tmp_path), "--sps-budget", "1.0")
    assert proc.returncode != 0
    assert "throughput regressed" in proc.stderr + proc.stdout


def test_env_tightens_budget(tmp_path):
    proc = _gate(_report(tmp_path), env={"REPRO_SPS_BUDGET": "1.0"})
    assert proc.returncode != 0
    assert "throughput regressed" in proc.stderr + proc.stdout


def test_flag_overrides_env(tmp_path):
    proc = _gate(_report(tmp_path), "--sps-budget", "10.0",
                 env={"REPRO_SPS_BUDGET": "1.0"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


# --- pinned at-row saturation residue (over-protection, carried PR 5) ------
def test_at_residue_within_ceiling_passes(tmp_path):
    rows = {"moe-ffn-at": {"model_rel_err_profile": 0.17},
            "decode-paged-at": {"model_rel_err_profile": 0.10}}
    proc = _gate(_report(tmp_path, rows=rows))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_at_residue_over_ceiling_fails(tmp_path):
    rows = {"moe-ffn-at": {"model_rel_err_profile": 0.25}}
    proc = _gate(_report(tmp_path, rows=rows))
    assert proc.returncode != 0
    assert "residue ceiling" in proc.stderr + proc.stdout


def test_at_residue_absent_row_tolerated(tmp_path):
    # a smoke report without the pinned scenarios must not trip the check
    rows = {"matmul-at": {"model_rel_err_profile": 0.9}}
    proc = _gate(_report(tmp_path, rows=rows))
    assert proc.returncode == 0, proc.stdout + proc.stderr

"""Tests for the analytical model (paper §V) and its validation metrics."""

import numpy as np
import pytest

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import fa2_counts
from repro.core import fit_params
from repro.core import kendall_tau
from repro.core import kept_fraction
from repro.core import named_policy
from repro.core import predict
from repro.core import r_squared
from repro.core import run_policy
from repro.core.analytical import ModelParams
from repro.core.workloads import AttnWorkload
from repro.core.workloads import SPATIAL
from repro.core.workloads import TEMPORAL

WL = AttnWorkload("tiny-t", n_q_heads=8, n_kv_heads=4, head_dim=128,
                  seq_len=1024, group_alloc=TEMPORAL)


def test_kept_fraction_lru_step_function():
    # LRU: all-or-nothing (paper §V-C)
    assert kept_fraction("lru", s_work=1000, s_llc=8000, assoc=8) == 1.0
    assert kept_fraction("lru", s_work=9000, s_llc=8000, assoc=8) == 0.0


def test_kept_fraction_at_skept_formula():
    # S_kept = S_work * M / 2^B <= S_LLC * (A-1)/A
    f = kept_fraction("at+dbp", s_work=8 * 2**20, s_llc=4 * 2**20, assoc=8,
                      b_bits=3)
    # S_eff = 3.5MB; tier = 1MB → M = 3 → f = 3/8
    assert f == pytest.approx(3 / 8)


def test_kept_fraction_optimal_bypass_uses_whole_cache():
    f_b = kept_fraction("bypass+dbp", s_work=8 * 2**20, s_llc=4 * 2**20,
                        assoc=8)
    f_at = kept_fraction("at+dbp", s_work=8 * 2**20, s_llc=4 * 2**20,
                         assoc=8)
    assert f_b > f_at                      # paper §VI-E3
    assert f_b == pytest.approx(0.5)


def test_kept_fraction_gqa_bypass_conservative():
    # under inter-core sharing the gqa variant pins nothing extra
    f = kept_fraction("bypass+dbp", s_work=8 * 2**20, s_llc=4 * 2**20,
                      assoc=8, gqa=True)
    assert f == 0.0
    f_all = kept_fraction("all", s_work=8 * 2**20, s_llc=4 * 2**20,
                          assoc=8, gqa=True)
    assert f_all == pytest.approx(3 / 8)   # falls back to at


def test_predict_kept_fraction_monotone_in_cache_size():
    """Bigger cache → larger kept fraction; thrashing end slower than the
    fits end.  (Total time is NOT strictly monotone by construction:
    Eq. 2 serializes t_hit while conflict misses overlap with compute.)"""
    counts = fa2_counts(WL, n_cores=4)
    hw = SimConfig(n_cores=4)
    preds = [predict(counts, s * 2**20, "at+dbp", hw)
             for s in (1, 2, 4, 16)]
    fracs = [p.kept_fraction for p in preds]
    assert all(a <= b for a, b in zip(fracs, fracs[1:]))
    assert fracs[-1] == 1.0


def test_predict_policy_ordering_under_thrash():
    # 16-core configuration (paper Table IV) → memory-bound regime, where
    # the policy ordering lru ≥ at ≥ optimal-bypass must hold
    counts = fa2_counts(WL, n_cores=16)
    hw = SimConfig(n_cores=16)
    llc = 512 * 1024
    lru = predict(counts, llc, "lru", hw).cycles
    at = predict(counts, llc, "at+dbp", hw).cycles
    opt = predict(counts, llc, "all", hw).cycles
    assert lru >= at >= opt


def test_metrics_perfect_and_degraded():
    x = np.array([1.0, 2.0, 3.0, 4.0])
    assert r_squared(x, x) == pytest.approx(1.0)
    assert kendall_tau(x, x) == pytest.approx(1.0)
    assert kendall_tau(-x, x) == pytest.approx(-1.0)
    assert abs(kendall_tau(np.array([1.0, 3.0, 2.0, 4.0]), x)) < 1.0


def test_kendall_tau_tie_adjusted():
    """τ-b: tied prediction pairs shrink the denominator instead of
    silently counting as disagreement (the paper's §VI-G1 τ = 0.934 is
    a τ-b figure).  One tied pair among n=4: 5 concordant pairs, none
    discordant → τ-b = 5/sqrt(5·6), NOT the τ-a value 5/6."""
    target = np.array([1.0, 2.0, 3.0, 4.0])
    pred = np.array([1.0, 1.0, 2.0, 3.0])
    assert kendall_tau(pred, target) == pytest.approx(
        5.0 / np.sqrt(5.0 * 6.0))
    # two independent ties, one in each input
    assert kendall_tau(np.array([1.0, 1.0, 2.0, 3.0]),
                       np.array([1.0, 2.0, 3.0, 3.0])) == pytest.approx(
        4.0 / np.sqrt(5.0 * 5.0))
    # a constant input carries no rank information; two constants agree
    assert kendall_tau(np.ones(4), target) == 0.0
    assert kendall_tau(np.ones(4), np.ones(4)) == 1.0
    # monotone agreement with ties must not be biased below 1-equivalent
    assert kendall_tau(pred, target) > (5.0 - 0.0) / 6.0


def test_closed_pollution_single_branch():
    """Behavior pin for the collapsed pollution condition: the two
    former ``n_batches > 1`` branches reduce to one ``"dbp" not in
    policy`` check — every policy either hit engine resolves must see
    exactly the pollution the original dual-branch logic assigned
    (including "all", whose closed §V-C treatment keeps the polluted
    stack)."""
    from repro.core.analytical import _KNOWN_POLICIES
    counts = fa2_counts(WL.with_batches(2), n_cores=4)
    assert counts.n_batches == 2 and counts.reuse_profile is None
    hw = SimConfig(n_cores=4)
    llc = 2 * 2**20
    for policy in _KNOWN_POLICIES:
        # the original two-branch logic, verbatim
        pollution = 1.0
        if counts.n_batches > 1 and policy == "lru":
            pollution = 1.0 / counts.n_batches
        if counts.n_batches > 1 and "dbp" not in policy and policy != "lru":
            pollution = 1.0 / counts.n_batches
        expected = kept_fraction(policy, counts.s_work_active, llc,
                                 hw.llc_assoc, 3, "optimal", False,
                                 pollution)
        got = predict(counts, llc, policy, hw, model="closed")
        assert got.kept_fraction == pytest.approx(expected), policy


def test_model_validates_against_simulator():
    """Mini Fig-9: fit θ on a few sim points, check rank preservation."""
    hw = SimConfig(n_cores=4, llc_slices=8)
    pts = []
    for wl in (WL, AttnWorkload("tiny-s", 16, 4, 128, 1024,
                                group_alloc=SPATIAL)):
        tr = build_fa2_trace(wl, n_cores=4)
        counts = fa2_counts(wl, n_cores=4)
        gqa = wl.group_alloc == SPATIAL
        for llc in (512 * 1024, 1 * 2**20, 2 * 2**20):
            cfg = SimConfig(n_cores=4, llc_bytes=llc, llc_slices=8)
            for pol, sim_pol in (("lru", "lru"), ("at+dbp", "at"),
                                 ("all", "all")):
                res = run_policy(tr, named_policy(sim_pol, gqa=gqa), cfg,
                                 record_history=False)
                pts.append((counts, llc, pol, "optimal", gqa,
                            counts.n_rounds, res.cycles))
    params = fit_params(pts, hw)
    pred = np.array([predict(c, sz, p, hw, params, v, g, n_rounds=r).cycles
                     for (c, sz, p, v, g, r, _) in pts])
    target = np.array([t for *_, t in pts])
    r2 = r_squared(pred, target)
    tau = kendall_tau(pred, target)
    assert r2 > 0.80, f"R²={r2}"
    assert tau > 0.65, f"tau={tau}"


def test_fit_params_improves_loss():
    hw = SimConfig(n_cores=4, llc_slices=8)
    tr = build_fa2_trace(WL, n_cores=4)
    counts = fa2_counts(WL, n_cores=4)
    cfg = SimConfig(n_cores=4, llc_bytes=1 * 2**20, llc_slices=8)
    res = run_policy(tr, named_policy("lru"), cfg, record_history=False)
    pts = [(counts, 1 * 2**20, "lru", "optimal", False, counts.n_rounds,
            res.cycles)]
    fitted = fit_params(pts, hw)
    default_err = abs(predict(counts, 1 * 2**20, "lru", hw,
                              ModelParams(),
                              n_rounds=counts.n_rounds).cycles - res.cycles)
    fitted_err = abs(predict(counts, 1 * 2**20, "lru", hw, fitted,
                             n_rounds=counts.n_rounds).cycles - res.cycles)
    assert fitted_err <= default_err + 1e-6

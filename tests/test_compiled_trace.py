"""Compiled-trace IR: equivalence with the reference step engine, the
batched ``run_policies`` sweep API, and regression tests for the
simulator/cache fixes that rode along (MSHR write-intent merge, scalar
``seen_before`` broadcast, ``freq_ghz``-aware wall time)."""

import numpy as np
import pytest

from repro.core import SimConfig
from repro.core import SimResult
from repro.core import Simulator
from repro.core import Trace
from repro.core import build_fa2_trace
from repro.core import build_matmul_trace
from repro.core import named_policy
from repro.core import run_policies
from repro.core import run_policy
from repro.core.cache import COLD_MISS
from repro.core.cache import CONFLICT_MISS
from repro.core.cache import CacheGeometry
from repro.core.cache import SharedLLC
from repro.core.tmu import TMU
from repro.core.tmu import TMUParams
from repro.core.tmu import TensorMeta
from repro.core.traces import Step
from repro.core.workloads import AttnWorkload
from repro.core.workloads import SPATIAL
from repro.core.workloads import TEMPORAL

TINY_TEMPORAL = AttnWorkload("tiny-t", n_q_heads=8, n_kv_heads=4,
                             head_dim=128, seq_len=1024,
                             group_alloc=TEMPORAL)
TINY_SPATIAL = AttnWorkload("tiny-s", n_q_heads=16, n_kv_heads=4,
                            head_dim=128, seq_len=1024,
                            group_alloc=SPATIAL)
CFG = SimConfig(llc_bytes=512 * 1024, llc_slices=8)

COUNTERS = ("cycles", "hits", "mshr_hits", "cold_misses",
            "conflict_misses", "bypassed", "dram_lines", "writebacks",
            "dead_evictions", "flops")


def assert_results_equal(a: SimResult, b: SimResult) -> None:
    for f in COUNTERS:
        assert getattr(a, f) == getattr(b, f), f
    assert set(a.history) == set(b.history)
    for k in a.history:
        np.testing.assert_array_equal(a.history[k], b.history[k])


# ---------------------------------------------------------------------------
# engine equivalence: the compiled path must reproduce the step engine
# bit-for-bit on every trace shape and policy family
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy,gqa", [
    ("lru", False), ("at", False), ("at+dbp", False),
    ("at+bypass", False), ("all", False), ("fix4", True),
])
@pytest.mark.parametrize("trace_kind", ["matmul", "temporal", "spatial"])
def test_engines_bit_identical(trace_kind, policy, gqa):
    if trace_kind == "matmul":
        trace = build_matmul_trace(512, 512, 512, tile=128, n_cores=4)
    elif trace_kind == "temporal":
        trace = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    else:
        trace = build_fa2_trace(TINY_SPATIAL, n_cores=4)
    pol = named_policy(policy, gqa=gqa)
    ref = run_policy(trace, pol, CFG, engine="steps")
    got = run_policy(trace, pol, CFG, engine="compiled")
    assert_results_equal(ref, got)


@pytest.mark.parametrize("policy,per_tenant", [
    ("lru", False), ("at+dbp", False), ("at+bypass", False),
    ("at+bypass", True), ("all", True),
])
def test_engines_bit_identical_on_composite(policy, per_tenant):
    """Multi-tenant composites: the shared round ledger keeps both
    engines bit-identical including the per-tenant counter attribution
    and the (opt-in) per-tenant gear controller."""
    from repro.core.workloads import DecodeWorkload
    from repro.dataflows import (compose_time_sliced, decode_paged_spec,
                                 fa2_spec, lower_to_trace)
    wl = AttnWorkload("pf", 8, 4, 128, 512, group_alloc=TEMPORAL)
    dec = DecodeWorkload(n_seqs=8, seq_len=512, n_steps=3, retire_step=2,
                         n_short=4)
    trace = lower_to_trace(compose_time_sliced(
        [fa2_spec(wl, 4), decode_paged_spec(dec, 4)], quantum_rounds=8))
    pol = named_policy(policy, per_tenant_gears=per_tenant)
    ref = run_policy(trace, pol, CFG, engine="steps")
    got = run_policy(trace, pol, CFG, engine="compiled")
    assert_results_equal(ref, got)
    assert got.tenants and got.tenants == ref.tenants
    for f in ("hits", "mshr_hits", "cold_misses", "conflict_misses",
              "bypassed", "writebacks"):
        assert sum(t[f] for t in got.tenants.values()) == getattr(got, f)


def test_multibatch_dbp_equivalence():
    wl = AttnWorkload("tiny-mb", n_q_heads=4, n_kv_heads=4, head_dim=128,
                      seq_len=1024, group_alloc=TEMPORAL, n_batches=2)
    trace = build_fa2_trace(wl, n_cores=4)
    pol = named_policy("all")
    ref = run_policy(trace, pol, CFG, engine="steps")
    got = run_policy(trace, pol, CFG, engine="compiled")
    assert got.dead_evictions > 0      # the DBP path actually exercised
    assert_results_equal(ref, got)


# ---------------------------------------------------------------------------
# run_policies sweep API
# ---------------------------------------------------------------------------
def test_run_policies_matches_sequential():
    trace = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    pols = ["lru", "at", "at+dbp", "at+bypass", "all"]
    batch = run_policies(trace, pols, CFG, record_history=True)
    assert [r.policy for r in batch] == \
        [named_policy(p).name for p in pols]
    for p, got in zip(pols, batch):
        ref = run_policy(trace, named_policy(p), CFG)
        assert_results_equal(ref, got)


def test_run_policies_accepts_policy_configs():
    trace = build_matmul_trace(256, 256, 256, tile=128, n_cores=4)
    res = run_policies(trace, [named_policy("at", b_bits=4)], CFG)
    assert res[0].policy == "at"


def test_compiled_lowering_cached_on_trace():
    trace = build_matmul_trace(256, 256, 256, tile=128, n_cores=4)
    ct = trace.compiled(CFG.line_bytes)
    assert trace.compiled(CFG.line_bytes) is ct
    # plans are cached per geometry and shared across policies
    geom = CacheGeometry(CFG.llc_bytes, CFG.line_bytes, CFG.llc_assoc,
                         CFG.llc_slices)
    assert ct.plans_for(geom) is ct.plans_for(geom)
    other = CacheGeometry(2 * CFG.llc_bytes, CFG.line_bytes,
                          CFG.llc_assoc, CFG.llc_slices)
    assert ct.plans_for(other) is not ct.plans_for(geom)


def test_compiled_trace_structure():
    trace = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    ct = trace.compiled()
    assert ct.n_rounds == trace.n_rounds
    assert ct.round_off.shape == (ct.n_rounds + 1,)
    assert ct.round_off[-1] == ct.u_addrs.shape[0]
    # per-round line addresses are unique and ascending (merged MSHR view)
    for r in range(min(ct.n_rounds, 32)):
        a = ct.u_addrs[ct.round_off[r]:ct.round_off[r + 1]]
        assert (np.diff(a) > 0).all()
    # pre-merge counts can only exceed the merged ones
    assert (ct.n_acc_round >= np.diff(ct.round_off)).all()


# ---------------------------------------------------------------------------
# regression: MSHR merge must OR write intent across duplicates
# ---------------------------------------------------------------------------
def _one_tile_tensor(tid: int, base: int) -> TensorMeta:
    return TensorMeta(tensor_id=tid, base_addr=base, size_bytes=256,
                      tile_bytes=256, n_acc=1)


def _load_store_merge_trace() -> Trace:
    """Core 0 loads tile (tensor 0) while core 1 stores it in the same
    round; later rounds stream enough other tensors through a tiny cache
    to evict tensor 0's (dirty!) lines."""
    tensors = {i: _one_tile_tensor(i, (1 << 30) + 256 * i)
               for i in range(9)}
    core0 = [Step(loads=[(0, 0)])] + [Step(loads=[(i, 0)])
                                      for i in range(1, 9)]
    core1 = [Step(stores=[(0, 0)])]
    return Trace(name="load-store-merge", tensors=tensors,
                 core_steps=[core0, core1], core_group=[-1, -1],
                 core_is_leader=[True, True])


@pytest.mark.parametrize("engine", ["steps", "compiled"])
def test_mshr_merge_keeps_write_intent(engine):
    trace = _load_store_merge_trace()
    cfg = SimConfig(llc_bytes=1024, llc_assoc=2, llc_slices=4)
    res = run_policy(trace, named_policy("lru"), cfg, engine=engine)
    # the load+store merge is one MSHR hit, and the merged fill must be
    # dirty: evicting it later has to cost a writeback
    assert res.mshr_hits == 2
    assert res.writebacks > 0


def test_mismatched_line_bytes_rejected():
    trace = build_matmul_trace(256, 256, 256, tile=128, n_cores=4)
    with pytest.raises(ValueError, match="line_bytes"):
        run_policy(trace, named_policy("lru"), SimConfig(line_bytes=256))


# ---------------------------------------------------------------------------
# regression: scalar seen_before must broadcast like the other flags
# ---------------------------------------------------------------------------
def test_access_burst_scalar_seen_before():
    geom = CacheGeometry(64 * 1024, 128, 4, 4)
    a = np.arange(16, dtype=np.int64) * 128
    llc = SharedLLC(geom, named_policy("lru"))
    codes = llc.access_burst(a, seen_before=False)
    assert (codes == COLD_MISS).all()
    llc2 = SharedLLC(geom, named_policy("lru"))
    codes = llc2.access_burst(a, seen_before=True)
    assert (codes == CONFLICT_MISS).all()


# ---------------------------------------------------------------------------
# regression: SimResult wall time must honour SimConfig.freq_ghz
# ---------------------------------------------------------------------------
def test_time_ms_uses_config_frequency():
    trace = build_matmul_trace(256, 256, 256, tile=128, n_cores=4)
    res2 = run_policy(trace, named_policy("lru"), SimConfig(freq_ghz=2.0),
                      record_history=False)
    res1 = run_policy(trace, named_policy("lru"), SimConfig(freq_ghz=1.0),
                      record_history=False)
    assert res1.cycles == res2.cycles          # cycles are freq-agnostic
    assert res1.time_ms == pytest.approx(2 * res2.time_ms)
    assert res2.time_ms == pytest.approx(res2.cycles / 2.0e6)


# ---------------------------------------------------------------------------
# TMU batch interface
# ---------------------------------------------------------------------------
def test_tmu_on_access_batch_matches_sequential():
    params = TMUParams(d_lsb=0, d_msb=11, b_bits=3)
    metas = [TensorMeta(tensor_id=i, base_addr=(1 << 30) + i * 1024,
                        size_bytes=1024, tile_bytes=256, n_acc=3)
             for i in range(4)]
    seq_tmu = TMU(line_bytes=128, dead_fifo_depth=4, tile_entries=6,
                  params=params)
    bat_tmu = TMU(line_bytes=128, dead_fifo_depth=4, tile_entries=6,
                  params=params)
    for m in metas:
        seq_tmu.register(m)
        bat_tmu.register(m)

    rng = np.random.default_rng(0)
    tids = rng.integers(0, 4, size=200)
    tiles = rng.integers(0, 4, size=200)
    addrs = np.array([metas[t].tile_last_line(ti, 128)
                      for t, ti in zip(tids, tiles)], dtype=np.int64)
    tags = (addrs // 128) // 64
    naccs = np.full(200, 3, dtype=np.int64)

    for a, tg in zip(addrs, tags):
        seq_tmu.on_access(int(a), int(tg))
    bat_tmu.on_access_batch(tids, tiles, tags, naccs)

    assert seq_tmu.stats == bat_tmu.stats
    assert seq_tmu.dead_fifo.snapshot() == bat_tmu.dead_fifo.snapshot()
    assert seq_tmu._live == bat_tmu._live
    assert list(seq_tmu._live) == list(bat_tmu._live)   # LRU order too


# ---------------------------------------------------------------------------
# streaming (chunked) compilation: fixed-budget whole-round CSR segments
# fed incrementally must be bit-identical to the monolithic lowering
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk_lines", [1, 7, 333, 1 << 20])
@pytest.mark.parametrize("policy", ["lru", "at+bypass", "all"])
def test_chunked_compile_bit_identical(policy, chunk_lines):
    pol = named_policy(policy)
    mono = run_policy(build_fa2_trace(TINY_TEMPORAL, n_cores=4), pol, CFG)
    # fresh trace: segments take the per-range build path (no cached
    # full lowering to slice)
    chunked = Simulator(CFG, pol).run(build_fa2_trace(TINY_TEMPORAL,
                                                      n_cores=4),
                                      chunk_lines=chunk_lines)
    assert_results_equal(mono, chunked)


def test_chunked_compile_slices_cached_lowering():
    """With the full lowering already cached, segments are sliced views
    of it — same counters, no rebuild."""
    trace = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    pol = named_policy("at+dbp")
    mono = Simulator(CFG, pol).run(trace)      # populates trace.compiled
    chunked = Simulator(CFG, pol).run(trace, chunk_lines=257)
    assert_results_equal(mono, chunked)


@pytest.mark.parametrize("chunk_lines", [1, 2, 3])
def test_chunked_split_around_mshr_merge_round(chunk_lines):
    """Chunk budgets small enough that every boundary candidate falls
    next to the load+store merge round: rounds are atomic in the
    segmenter, so the MSHR write-intent merge and the later dirty
    write-back survive any chunk size."""
    cfg = SimConfig(llc_bytes=1024, llc_assoc=2, llc_slices=4)
    mono = run_policy(_load_store_merge_trace(), named_policy("lru"), cfg)
    chunked = Simulator(cfg, named_policy("lru")).run(
        _load_store_merge_trace(), chunk_lines=chunk_lines)
    assert chunked.mshr_hits == 2 and chunked.writebacks > 0
    assert_results_equal(mono, chunked)


def test_chunked_compile_validation():
    trace = build_matmul_trace(256, 256, 256, tile=128, n_cores=4)
    with pytest.raises(ValueError, match="chunk_lines"):
        list(trace.compiled_segments(128, 0))
    with pytest.raises(ValueError, match="chunk_lines"):
        Simulator(SimConfig(), named_policy("lru")).run(
            trace, engine="steps", chunk_lines=64)


# ---------------------------------------------------------------------------
# run_policies capacity axis: [policy][capacity] nested sweep
# ---------------------------------------------------------------------------
def test_run_policies_capacity_axis():
    trace = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    pols = ["lru", "at+dbp"]
    caps = [256 * 1024, 512 * 1024]
    nested = run_policies(trace, pols, CFG, record_history=True,
                          capacities=caps)
    assert len(nested) == len(pols)
    assert all(len(per_pol) == len(caps) for per_pol in nested)
    for p, per_pol in zip(pols, nested):
        for c, got in zip(caps, per_pol):
            ref = run_policy(trace, named_policy(p),
                             SimConfig(llc_bytes=c,
                                       llc_slices=CFG.llc_slices))
            assert_results_equal(ref, got)

"""Differential conformance harness (repro.conformance, DESIGN.md §10).

Covers the first-divergence report (an injected divergence must come
back with round + event context, not a bare assert), golden digest
round-tripping and schema invalidation, matrix growth from the suite
registry, and one end-to-end cell through scripts/conformance.py.
"""

import json
from pathlib import Path
import subprocess
import sys

import pytest

from repro.conformance import CONFORMANCE_POLICIES
from repro.conformance import SMOKE_SCENARIOS
from repro.conformance import compare_scenario
from repro.conformance import first_divergence
from repro.conformance import load_golden
from repro.conformance import matrix_entries
from repro.conformance import save_golden
from repro.core import EventSink
from repro.core import SimConfig
from repro.core import Simulator
from repro.core import named_policy
from repro.core.events import SCHEMA_VERSION
from repro.core.traces import build_matmul_trace

REPO = Path(__file__).resolve().parents[1]


def _tiny_stream():
    trace = build_matmul_trace(256, 256, 256, n_cores=4)
    sink = EventSink()
    sim = Simulator(SimConfig(llc_bytes=128 * 1024, llc_slices=8),
                    named_policy("at+dbp"))
    sim.run(trace, record_history=False, events=sink)
    return sink.canonical()


# ---------------------------------------------------------------------------
# first-divergence reporting
# ---------------------------------------------------------------------------
def test_identical_streams_have_no_divergence():
    m = _tiny_stream()
    assert first_divergence(m, m) is None
    assert first_divergence(m.copy(), m.copy()) is None


def test_injected_divergence_reports_round_and_context():
    expected = _tiny_stream()
    actual = expected.copy()
    idx = expected.shape[0] // 2
    actual[idx, 7] += 1                     # flip one event's aux
    div = first_divergence(expected, actual, window=2)
    assert div is not None
    assert div.index == idx
    assert div.round == int(expected[idx, 0])
    assert div.expected == [int(x) for x in expected[idx]]
    assert div.actual == [int(x) for x in actual[idx]]
    text = div.render()
    # a real report, not a bare assert: names the round, shows both
    # events decoded, and carries surrounding context lines
    assert "first divergence" in text
    assert f"round {div.round}" in text
    assert div.expected_text in text and div.actual_text in text
    assert len(div.context) == 5            # idx±2
    assert sum(c.startswith(">>") for c in div.context) == 1
    # round-trips to JSON for the CI artifact
    blob = json.dumps(div.to_dict())
    assert str(div.round) in blob


def test_divergence_on_truncated_stream():
    expected = _tiny_stream()
    actual = expected[:-3]
    div = first_divergence(expected, actual)
    assert div is not None
    assert div.index == expected.shape[0] - 3
    assert div.actual is None
    assert "<stream ended>" in div.actual_text


def test_divergence_window_clamps_at_edges():
    expected = _tiny_stream()[:4]
    actual = expected.copy()
    actual[0, 7] += 1
    div = first_divergence(expected, actual, window=3)
    assert div.index == 0
    assert len(div.context) == 4            # 0..3, clamped at the start


# ---------------------------------------------------------------------------
# golden digests
# ---------------------------------------------------------------------------
def test_golden_roundtrip(tmp_path):
    path = tmp_path / "golden.json"
    digests = {"b/x": "2" * 64, "a/y": "1" * 64}
    save_golden(digests, path)
    blob = json.loads(path.read_text())
    assert blob["schema_version"] == SCHEMA_VERSION
    assert list(blob["digests"]) == ["a/y", "b/x"]     # key-sorted
    assert load_golden(path) == digests


def test_golden_rejects_stale_schema(tmp_path):
    path = tmp_path / "golden.json"
    path.write_text(json.dumps({"schema_version": SCHEMA_VERSION + 1,
                                "digests": {"a/b": "0" * 64}}))
    assert load_golden(path) is None
    assert load_golden(tmp_path / "missing.json") is None


def test_frozen_goldens_cover_the_full_matrix():
    golden = load_golden()
    assert golden is not None, "tests/golden/conformance_digests.json " \
        "missing or stale — run scripts/conformance.py --update-golden"
    cells = {f"{k}/{p}" for k, p in matrix_entries()}
    assert cells <= set(golden)


# ---------------------------------------------------------------------------
# matrix growth
# ---------------------------------------------------------------------------
def test_matrix_grows_with_suite_registry():
    from repro.dataflows.suite import registry_keys
    entries = list(matrix_entries())
    keys = registry_keys()
    assert {k for k, _ in entries} == set(keys)
    assert len(entries) == len(keys) * len(CONFORMANCE_POLICIES)
    assert set(SMOKE_SCENARIOS) <= set(keys)
    smoke = list(matrix_entries(smoke=True))
    assert {k for k, _ in smoke} == set(SMOKE_SCENARIOS)
    # explicit axes override both defaults
    assert list(matrix_entries(scenarios=["matmul"],
                               policies=["lru"])) == [("matmul", "lru")]


# ---------------------------------------------------------------------------
# end-to-end cells
# ---------------------------------------------------------------------------
def test_compare_scenario_cell_passes_against_frozen_golden():
    golden = load_golden()
    res, = compare_scenario("matmul", ("lru",), golden=golden)
    assert res.ok and res.failure is None
    assert res.n_events > 0 and len(res.digest) == 64
    if golden is not None:
        assert res.golden == res.digest


def test_compare_scenario_flags_corrupted_golden():
    res, = compare_scenario("matmul", ("lru",),
                            golden={"matmul/lru": "f" * 64})
    assert res.failure == "golden"
    res, = compare_scenario("matmul", ("lru",), golden={})
    assert res.failure == "missing-golden"


@pytest.mark.slow
def test_conformance_script_single_cell(tmp_path):
    report = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "conformance.py"),
         "--scenario", "matmul", "--policy", "lru",
         "--report", str(report)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    blob = json.loads(report.read_text())
    assert blob["failures"] == 0
    assert blob["cells"][0]["scenario"] == "matmul"

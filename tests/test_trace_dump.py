"""scripts/trace_dump.py: window render, npz export round-trip, and
bad-args exit codes (shipped in PR 7 without dedicated tests)."""

from pathlib import Path
import subprocess
import sys

import numpy as np

from repro.core.events import COLUMNS
from repro.core.events import SCHEMA_VERSION

REPO = Path(__file__).resolve().parents[1]
DUMP = REPO / "scripts" / "trace_dump.py"


def _dump(*args):
    return subprocess.run([sys.executable, str(DUMP), *args],
                          capture_output=True, text=True, cwd=REPO)


def test_head_render():
    proc = _dump("matmul", "--policy", "at+dbp", "--head", "5")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    lines = proc.stdout.splitlines()
    header = [ln for ln in lines if ln.startswith("# matmul")]
    assert header and "events, digest" in header[0]
    events = [ln for ln in lines if not ln.startswith("#")]
    assert len(events) == 5


def test_round_window_render():
    proc = _dump("matmul", "--round", "4", "--window", "1")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "# rounds 3..5:" in proc.stdout
    # every printed event sits inside the requested window
    for ln in proc.stdout.splitlines():
        if ln.startswith("#"):
            continue
        assert ln.startswith(("round=3", "round=4", "round=5")), ln


def test_npz_export_round_trip(tmp_path):
    out = tmp_path / "events.npz"
    proc = _dump("matmul", "--npz", str(out))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert out.exists()
    data = np.load(out)
    assert set(COLUMNS) <= set(data.files)
    assert data["schema_version"][0] == SCHEMA_VERSION
    n = data["round"].shape[0]
    assert n > 0
    assert all(data[c].shape[0] == n for c in COLUMNS)
    # the header's event count is the exported row count
    head = proc.stdout.splitlines()[0]
    assert f"{n} events" in head


def test_unknown_scenario_exits_2():
    proc = _dump("no-such-scenario")
    assert proc.returncode == 2
    assert "unknown suite scenario" in proc.stderr


def test_unknown_policy_exits_2():
    proc = _dump("matmul", "--policy", "no-such-policy")
    assert proc.returncode == 2
    assert "unknown policy" in proc.stderr


def test_bad_engine_exits_2():
    proc = _dump("matmul", "--engine", "warp")
    assert proc.returncode == 2          # argparse choices

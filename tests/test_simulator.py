"""Integration tests: trace generation + cycle-level simulation.

Uses reduced workloads (small seq/heads) so the suite stays fast; the
paper-scale runs live in benchmarks/.
"""

import numpy as np
import pytest

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import build_matmul_trace
from repro.core import fa2_counts
from repro.core import named_policy
from repro.core import run_policy
from repro.core.workloads import AttnWorkload
from repro.core.workloads import SPATIAL
from repro.core.workloads import TEMPORAL

TINY_TEMPORAL = AttnWorkload("tiny-t", n_q_heads=8, n_kv_heads=4,
                             head_dim=128, seq_len=1024,
                             group_alloc=TEMPORAL)
TINY_SPATIAL = AttnWorkload("tiny-s", n_q_heads=16, n_kv_heads=4,
                            head_dim=128, seq_len=1024,
                            group_alloc=SPATIAL)
CFG = SimConfig(llc_bytes=1 * 2**20, llc_slices=8)


def test_trace_structure_temporal():
    tr = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    assert tr.n_cores == 4
    # all cores have identical step counts (lockstep)
    lens = {len(s) for s in tr.core_steps}
    assert len(lens) == 1
    # K/V tensors registered with nAcc = n_q_tiles
    kv = [m for m in tr.tensors.values() if not m.bypass_all]
    assert all(m.n_acc == TINY_TEMPORAL.n_q_tiles for m in kv)
    assert len(kv) == 2 * TINY_TEMPORAL.n_kv_heads
    # Q/O tensors always bypass (paper §V-C)
    qo = [m for m in tr.tensors.values() if m.bypass_all]
    assert len(qo) == 2 * TINY_TEMPORAL.n_q_heads


def test_trace_structure_spatial():
    tr = build_fa2_trace(TINY_SPATIAL, n_cores=4)
    kv = [m for m in tr.tensors.values() if not m.bypass_all]
    # spatial: each line touched by every group member per q-tile pass
    assert all(m.n_acc == TINY_SPATIAL.n_q_tiles * 4 for m in kv)
    # exactly one lagging (non-leader) core per group
    assert sum(not ldr for ldr in tr.core_is_leader) == 1  # gs=4, 4 cores=1 group


def test_counts_match_trace_totals():
    tr = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    counts = fa2_counts(TINY_TEMPORAL, n_cores=4)
    kv_lines = sum(m.size_bytes // 128 for m in tr.tensors.values()
                   if not m.bypass_all)
    assert counts.n_kv_distinct == kv_lines
    # simulate and compare request totals
    res = run_policy(tr, named_policy("lru"), CFG, record_history=False)
    assert res.accesses == counts.n_kv_accesses + counts.n_bypass_lines
    assert res.flops == pytest.approx(counts.flops_total, rel=1e-6)
    assert tr.n_rounds == counts.n_rounds


def test_lru_thrashes_when_working_set_exceeds_cache():
    wl = TINY_TEMPORAL
    tr = build_fa2_trace(wl, n_cores=4)
    counts = fa2_counts(wl, n_cores=4)
    small = SimConfig(llc_bytes=256 * 1024, llc_slices=8)
    res = run_policy(tr, named_policy("lru"), small, record_history=False)
    assert counts.s_work_active > small.llc_bytes
    assert res.hit_rate < 0.05          # classic LRU thrashing (paper §III-C)


def test_at_beats_lru_under_thrashing():
    tr = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    small = SimConfig(llc_bytes=512 * 1024, llc_slices=8)
    lru = run_policy(tr, named_policy("lru"), small, record_history=False)
    at = run_policy(tr, named_policy("at"), small, record_history=False)
    assert at.hit_rate > lru.hit_rate + 0.05
    assert at.cycles < lru.cycles


def test_policies_converge_when_cache_fits():
    tr = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    big = SimConfig(llc_bytes=8 * 2**20, llc_slices=8)
    lru = run_policy(tr, named_policy("lru"), big, record_history=False)
    at = run_policy(tr, named_policy("at"), big, record_history=False)
    assert at.cycles == pytest.approx(lru.cycles, rel=0.02)


def test_dynamic_bypass_near_best_static():
    """Paper §VI-E1: dynamic bypassing within a few % of the best static
    gear."""
    tr = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    cfg = SimConfig(llc_bytes=512 * 1024, llc_slices=8)
    static = [run_policy(tr, named_policy(f"fix{g}"), cfg,
                         record_history=False).cycles for g in range(9)]
    dyn = run_policy(tr, named_policy("at+bypass"), cfg,
                     record_history=False).cycles
    assert dyn <= min(static) * 1.10


def test_spatial_blind_bypass_loses_intercore_reuse():
    """Paper §IV-E: bypassing blindly misses inter-core reuses and adds
    DRAM traffic; the gqa variant avoids this."""
    tr = build_fa2_trace(TINY_SPATIAL, n_cores=4)
    cfg = SimConfig(llc_bytes=256 * 1024, llc_slices=8, n_cores=4)
    blind = run_policy(tr, named_policy("fix6"), cfg, record_history=False)
    gqa = run_policy(tr, named_policy("fix6", gqa=True), cfg,
                     record_history=False)
    assert blind.dram_lines > gqa.dram_lines
    assert blind.cycles > gqa.cycles


def test_dbp_helps_multibatch():
    """Paper §VI-F: DBP clears retired batches' data; at+bypass+dbp ≥
    at+bypass in the 2-batch scenario at moderate cache size."""
    wl = AttnWorkload("tiny-mb", n_q_heads=4, n_kv_heads=4, head_dim=128,
                      seq_len=1024, group_alloc=TEMPORAL, n_batches=2)
    tr = build_fa2_trace(wl, n_cores=4)
    cfg = SimConfig(llc_bytes=1 * 2**20, llc_slices=8, n_cores=4)
    base = run_policy(tr, named_policy("at+bypass"), cfg,
                      record_history=False)
    dbp = run_policy(tr, named_policy("all"), cfg, record_history=False)
    assert dbp.dead_evictions > 0
    assert dbp.cycles <= base.cycles * 1.02


def test_matmul_trace_runs():
    tr = build_matmul_trace(512, 512, 512, tile=128, n_cores=4)
    res = run_policy(tr, named_policy("lru"), CFG, record_history=False)
    assert res.accesses > 0
    assert res.flops == pytest.approx(2 * 512**3, rel=1e-6)


def test_history_monotone_and_hit_rate_consistent():
    tr = build_fa2_trace(TINY_TEMPORAL, n_cores=4)
    res = run_policy(tr, named_policy("at"), CFG, record_history=True)
    cyc = res.history["cycles"]
    assert (np.diff(cyc) > 0).all()
    assert res.history["hits"].sum() == res.hits + res.mshr_hits

"""Traffic-scale serving replay (DESIGN.md §11): generator determinism,
streamed-vs-monolithic bit-identity, bounded-window memory, SLO metrics,
and the serve-loop truncation contract."""

import numpy as np
import pytest

from repro.core.events import EventSink
from repro.core.simulator import SimConfig
from repro.serve.replay import ReplayConfig
from repro.serve.replay import replay_spec
from repro.serve.replay import run_replay
from repro.serve.scheduler import ServeTruncation
from repro.serve.scheduler import SlotScheduler
from repro.serve.traffic import RequestStream
from repro.serve.traffic import TrafficConfig

# Hypothesis widens the seed coverage where installed (CI); the
# parametrized variants below keep the invariants exercised without it.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CFG = SimConfig(llc_bytes=128 * 1024)


def _counters(res):
    s = res.sim
    return (s.cycles, s.hits, s.mshr_hits, s.cold_misses,
            s.conflict_misses, s.bypassed, s.dram_lines, s.writebacks,
            s.dead_evictions, s.flops)


# ---------------------------------------------------------------------------
# Generator determinism
# ---------------------------------------------------------------------------
def _check_generator_deterministic(seed, process):
    cfg = TrafficConfig(n_requests=200, seed=seed, process=process)
    stream = RequestStream(cfg)
    first = list(stream)
    again = list(stream)                       # re-iteration re-seeds
    fresh = list(RequestStream(TrafficConfig(n_requests=200, seed=seed,
                                             process=process)))
    assert first == again == fresh
    arr = np.array([r.arrival_round for r in first])
    assert (np.diff(arr) >= 0).all()           # arrivals are ordered
    assert all(r.uid == i for i, r in enumerate(first))


@pytest.mark.parametrize("seed,process",
                         [(0, "poisson"), (42, "bursty"),
                          (2**31 - 1, "bursty")])
def test_generator_deterministic_under_seed(seed, process):
    """Two iterations of the same RequestStream — and a fresh stream
    built from an equal config — yield identical request populations."""
    _check_generator_deterministic(seed, process)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           process=st.sampled_from(["poisson", "bursty"]))
    def test_generator_deterministic_property(seed, process):
        _check_generator_deterministic(seed, process)


def test_generator_prefix_populations():
    cfg = TrafficConfig(n_requests=400, seed=3, share_fraction=0.5)
    stream = RequestStream(cfg)
    reqs = list(stream)
    shared = [r for r in reqs if r.prefix_id >= 0]
    assert 0 < len(shared) < len(reqs)
    for pid in {r.prefix_id for r in shared}:
        info = stream.prefix_info(pid)
        members = [r for r in shared if r.prefix_id == pid]
        assert len(members) == info.members
        assert info.total_decode_steps == sum(r.decode_steps
                                              for r in members)
        assert info.uid_min == min(r.uid for r in members)
        assert info.uid_max == max(r.uid for r in members)


# ---------------------------------------------------------------------------
# Streamed replay ≡ monolithic replay (bit-identical)
# ---------------------------------------------------------------------------
def _check_stream_bit_identical(seed, process, policy):
    """The chunked emit→compile→run_stream pipeline must reproduce the
    monolithic spec→lower→run pipeline bit for bit: every counter and
    the canonical event-stream digest (chunk boundaries are invisible)."""
    traffic = TrafficConfig(n_requests=40, seed=seed, process=process)
    mono_sink, str_sink = EventSink(), EventSink()
    mono = run_replay(traffic, policy, CFG, mode="monolithic",
                      events=mono_sink)
    streamed = run_replay(traffic, policy, CFG, mode="stream",
                          chunk_lines=256, events=str_sink)
    assert streamed.segments > 1               # actually chunked
    assert _counters(streamed) == _counters(mono)
    assert streamed.rounds == mono.rounds
    assert str_sink.digest() == mono_sink.digest()
    np.testing.assert_array_equal(streamed.log.first_token,
                                  mono.log.first_token)
    np.testing.assert_array_equal(streamed.log.last_token,
                                  mono.log.last_token)


@pytest.mark.parametrize("seed,process,policy",
                         [(1, "poisson", "lru"), (7, "bursty", "all"),
                          (23, "bursty", "lru"), (5, "poisson", "all")])
def test_streamed_replay_bit_identical_to_monolithic(seed, process,
                                                     policy):
    _check_stream_bit_identical(seed, process, policy)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 1000),
           process=st.sampled_from(["poisson", "bursty"]),
           policy=st.sampled_from(["lru", "all"]))
    def test_streamed_replay_bit_identical_property(seed, process,
                                                    policy):
        _check_stream_bit_identical(seed, process, policy)


@pytest.mark.parametrize("policy", ["lru", "at+dbp", "all"])
def test_pooled_streamed_replay_bit_identical_to_monolithic(policy):
    """The bit-identity property must survive address recycling: both
    emitters see the identical declare/retire sequence from the replay
    engine, so the pooled allocator hands out identical layouts and the
    chunked pipeline reproduces the monolithic one exactly."""
    traffic = TrafficConfig(n_requests=40, seed=7, process="bursty")
    rcfg = ReplayConfig(allocator="pooled")
    mono_sink, str_sink = EventSink(), EventSink()
    mono = run_replay(traffic, policy, CFG, rcfg, mode="monolithic",
                      events=mono_sink)
    streamed = run_replay(traffic, policy, CFG, rcfg, mode="stream",
                          chunk_lines=256, events=str_sink)
    assert streamed.segments > 1
    assert _counters(streamed) == _counters(mono)
    assert str_sink.digest() == mono_sink.digest()


def test_pooled_replay_address_footprint_bounded():
    """Bump mints fresh addresses forever; the pooled replay's address
    span stays within the configured pool (no overflow at this scale),
    so tag-derived TMU state keeps covering the live working set."""
    traffic = TrafficConfig(n_requests=200, seed=11, process="bursty")
    rcfg = ReplayConfig(allocator="pooled")
    bump_spec, _ = replay_spec(traffic, ReplayConfig())
    pooled_spec, _ = replay_spec(traffic, rcfg)
    assert pooled_spec.allocator == "pooled"
    assert bump_spec.allocator == "bump"
    # bump layouts stay implicit (the historical lowering assigns them);
    # pooled layouts are baked in and live inside the configured pool
    assert all(t.base is None for t in bump_spec.tensors)
    assert all(t.base is not None for t in pooled_spec.tensors)
    span = (max(t.base + t.size_bytes for t in pooled_spec.tensors)
            - min(t.base for t in pooled_spec.tensors))
    assert span <= rcfg.pool_pages * rcfg.page_bytes
    # lifetime footprint exceeds the span — regions were recycled
    assert sum(t.size_bytes for t in pooled_spec.tensors) > span


def test_streamed_replay_memory_bounded():
    """Seen-bitmap recycling keeps the dense window a fraction of the
    lifetime footprint — the property that makes 10⁵–10⁶-request
    replays feasible."""
    traffic = TrafficConfig(n_requests=300, seed=11, process="bursty")
    res = run_replay(traffic, "all", CFG, chunk_lines=4096)
    assert res.segments > 1
    assert res.peak_seen_lines < 0.5 * res.total_lines_declared
    assert res.slo["completed"]["n"] == 300


def test_replay_slo_metrics_sane():
    traffic = TrafficConfig(n_requests=120, seed=5)
    res = run_replay(traffic, "at+dbp", CFG)
    for metric in ("ttft_ms", "tpot_ms"):
        pct = res.slo[metric]
        assert 0.0 < pct["p50"] <= pct["p95"] <= pct["p99"]
        assert pct["mean"] > 0.0
    assert res.slo["completed"]["n"] == 120


def test_replay_spec_round_trip_and_policy_spread():
    """The monolithic replay spec is a well-formed DataflowSpec and the
    full mechanism stack beats LRU on the bursty serving mix (the
    suite-registry contract for the serve-replay scenario)."""
    traffic = TrafficConfig(n_requests=96, seed=7, process="bursty")
    spec, log = replay_spec(traffic)
    assert spec.n_rounds > 0 and len(spec.tensors) > 0
    assert (log.first_token >= log.arrival).all()
    assert (log.last_token >= log.first_token).all()
    lru = run_replay(traffic, "lru", CFG, record_history=False)
    atdbp = run_replay(traffic, "at+dbp", CFG, record_history=False)
    assert lru.sim.cycles / atdbp.sim.cycles > 1.1


# ---------------------------------------------------------------------------
# Truncation contract (scheduler + engines)
# ---------------------------------------------------------------------------
def test_replay_max_rounds_truncation():
    traffic = TrafficConfig(n_requests=64, seed=0)
    with pytest.raises(ServeTruncation) as exc:
        run_replay(traffic, "lru", CFG, rcfg=ReplayConfig(max_rounds=5))
    assert "truncated after 5 steps" in str(exc.value)
    assert exc.value.steps == 5
    assert exc.value.active + exc.value.queued > 0


def test_slot_scheduler_contract():
    sched = SlotScheduler(2)
    for item in "abc":
        sched.add(item)
    admitted = sched.admit()
    assert [s for s, _ in admitted] == [0, 1]
    assert sched.n_active == 2 and sched.n_queued == 1
    assert not sched.drained
    sched.release(0)
    assert sched.admit() == [(0, "c")]
    for slot in list(sched.active_slots()):
        sched.release(slot)
    assert sched.drained and sched.admit() == []


def test_serve_engine_truncation_raises():
    """ServeEngine.run_to_completion must not silently truncate: work
    left after max_steps raises ServeTruncation naming the remainder."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_arch, reduce_for_smoke
    from repro.models import init_params
    from repro.serve import Request, ServeEngine

    cfg = reduce_for_smoke(get_arch("llama3.2-3b"))
    params = init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    rng = np.random.default_rng(1)
    for i in range(2):
        engine.add_request(Request(
            uid=i, prompt=rng.integers(2, cfg.vocab, size=5)
            .astype(np.int32), max_new_tokens=3))
    with pytest.raises(ServeTruncation) as exc:
        engine.run_to_completion(max_steps=2)
    assert exc.value.steps == 2
    assert exc.value.active + exc.value.queued > 0

    n = engine.run_to_completion()             # resumes and drains
    assert n >= 1 and engine.sched.drained

"""Per-architecture smoke tests: reduced config, one forward + decode step
on CPU, asserting output shapes and finiteness (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES
from repro.configs import get_arch
from repro.configs import reduce_for_smoke
from repro.models import decode_step
from repro.models import forward
from repro.models import init_cache
from repro.models import init_params
from repro.models import lm_loss
from repro.models import prefill

B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return tokens


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch(request):
    cfg = reduce_for_smoke(get_arch(request.param))
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def test_forward_shapes_and_finite(arch):
    cfg, params = arch
    tokens = _batch(cfg, jax.random.key(1))
    logits = jax.jit(lambda p, t: forward(p, t, cfg))(params, tokens)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_loss_finite_and_positive(arch):
    cfg, params = arch
    tokens = _batch(cfg, jax.random.key(2))
    loss = jax.jit(lambda p, t: lm_loss(forward(p, t, cfg), t))(
        params, tokens)
    assert np.isfinite(float(loss)) and float(loss) > 0


@pytest.mark.slow
def test_train_grad_step_no_nans(arch):
    cfg, params = arch
    tokens = _batch(cfg, jax.random.key(3))

    def loss_fn(p):
        return lm_loss(forward(p, tokens, cfg), tokens)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all())


@pytest.mark.slow
def test_prefill_then_decode_matches_forward(arch):
    """Decode with a prefilled cache must reproduce full-forward logits."""
    cfg, params = arch
    tokens = _batch(cfg, jax.random.key(4))
    full = jax.jit(lambda p, t: forward(p, t, cfg, remat=False))(
        params, tokens)

    logits_p, cache = jax.jit(
        lambda p, t: prefill(p, t[:, :-1], cfg))(params, tokens)
    # grow attention cache to S (prefill sized it to S-1)
    if cache.k is not None:
        pad = [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)]
        cache = cache._replace(k=jnp.pad(cache.k, pad),
                               v=jnp.pad(cache.v, pad))
    logits_d, cache2 = jax.jit(
        lambda p, t, c: decode_step(p, t, c, cfg))(
        params, tokens[:, -1:], cache)

    a = logits_p.astype(np.float32)               # pos S-2 from prefill
    b = full[:, -2].astype(np.float32)
    np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)
    c = logits_d[:, 0].astype(np.float32)         # pos S-1 from decode
    d = full[:, -1].astype(np.float32)
    np.testing.assert_allclose(c, d, rtol=3e-2, atol=3e-2)
    assert int(cache2.pos) == S


def test_decode_cache_shapes(arch):
    cfg, params = arch
    cache = init_cache(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))(
        params, tok, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    jax.tree.map(lambda a, b: None if a is None else
                 np.testing.assert_equal(a.shape, b.shape), cache, new)

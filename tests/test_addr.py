"""Address-space layer (DESIGN.md §13): bump bit-identity, pooled
free-list invariants (no live overlap, idempotent-safe frees,
deterministic recycling), and the replay-level DCO210/DCO202 contract."""

import numpy as np
import pytest

from repro.dataflows.addr import ALLOCATOR_NAMES
from repro.dataflows.addr import BumpAllocator
from repro.dataflows.addr import DEFAULT_BASE
from repro.dataflows.addr import PooledPageAllocator
from repro.dataflows.addr import Region
from repro.dataflows.addr import make_allocator

# Hypothesis widens the sequence coverage where installed (CI); the
# seeded variants below keep the invariants exercised without it.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

PAGE = 2048


# ---------------------------------------------------------------------------
# BumpAllocator: the pinned historical arithmetic
# ---------------------------------------------------------------------------
def test_bump_allocator_matches_historical_arithmetic():
    al = BumpAllocator()
    r1 = al.alloc(5000, 1024)
    r2 = al.alloc(300, 256)
    r3 = al.alloc(100, 256, align=4096)
    assert r1.base == DEFAULT_BASE                  # base is tile-aligned
    next1 = r1.base + 5000
    assert r2.base == (next1 + 255) // 256 * 256    # ceil to tile
    next2 = r2.base + 300
    assert r3.base == (next2 + 4095) // 4096 * 4096
    assert al.monotone and r1.base < r2.base < r3.base
    al.free(r1)                                     # no-op, never reused
    assert al.alloc(64, 64).base > r3.base


def test_make_allocator_registry():
    assert make_allocator("bump").name == "bump"
    assert make_allocator("pooled").name == "pooled"
    assert set(ALLOCATOR_NAMES) == {"bump", "pooled"}
    with pytest.raises(ValueError, match="unknown allocator"):
        make_allocator("slab")


# ---------------------------------------------------------------------------
# PooledPageAllocator: live-overlap freedom over random sequences
# ---------------------------------------------------------------------------
def _drive_random_sequence(seed, n_ops=400, pool_pages=64):
    """Random alloc/free workload; returns the realized (op, base, size)
    trace while asserting the no-live-overlap invariant at every step."""
    rng = np.random.default_rng(seed)
    al = PooledPageAllocator(page_bytes=PAGE, pool_pages=pool_pages)
    live = {}                                        # id -> Region
    trace = []
    for i in range(n_ops):
        if live and rng.random() < 0.45:
            key = list(live)[int(rng.integers(len(live)))]
            reg = live.pop(key)
            al.free(reg)
            trace.append(("free", reg.base, reg.size_bytes))
        else:
            size = int(rng.integers(1, 8 * PAGE))
            reg = al.alloc(size, PAGE)
            span = (size + PAGE - 1) // PAGE * PAGE
            for other in live.values():
                o_span = ((other.size_bytes + PAGE - 1) // PAGE * PAGE)
                assert (reg.base + span <= other.base
                        or other.base + o_span <= reg.base), (
                    f"op {i}: pooled alloc [{reg.base:#x}, "
                    f"{reg.base + span:#x}) overlaps live "
                    f"[{other.base:#x}, {other.base + o_span:#x})")
            live[i] = reg
            trace.append(("alloc", reg.base, reg.size_bytes))
    return trace, al


@pytest.mark.parametrize("seed", [0, 7, 123, 99991])
def test_pooled_never_overlaps_live_regions(seed):
    _drive_random_sequence(seed)


@pytest.mark.parametrize("seed", [3, 17, 4242])
def test_pooled_sequence_seed_deterministic(seed):
    """Re-driving the identical op sequence reproduces the identical
    region sequence — allocator state is a pure function of the call
    sequence (mirrors RequestStream's determinism contract, and is what
    makes streamed and monolithic replay layouts agree)."""
    first, al1 = _drive_random_sequence(seed)
    again, al2 = _drive_random_sequence(seed)
    assert first == again
    assert al1.stats() == al2.stats()


def test_pooled_recycles_at_lowest_address():
    al = PooledPageAllocator(page_bytes=PAGE, pool_pages=16)
    a = al.alloc(PAGE, PAGE)
    b = al.alloc(PAGE, PAGE)
    c = al.alloc(PAGE, PAGE)
    assert (a.base, b.base, c.base) == (
        DEFAULT_BASE, DEFAULT_BASE + PAGE, DEFAULT_BASE + 2 * PAGE)
    al.free(a)
    al.free(c)
    # first-fit at the lowest free address: a's slot, not c's
    assert al.alloc(PAGE, PAGE).base == a.base
    assert al.alloc(PAGE, PAGE).base == c.base
    assert al.overflow_allocs == 0


def test_pooled_overflow_grows_then_recycles():
    al = PooledPageAllocator(page_bytes=PAGE, pool_pages=2)
    a = al.alloc(2 * PAGE, PAGE)                    # drains the pool
    b = al.alloc(PAGE, PAGE)                        # overflow growth
    assert b.base == a.base + 2 * PAGE
    assert al.overflow_allocs == 1
    al.free(b)                                      # overflow pages pool
    assert al.alloc(PAGE, PAGE).base == b.base
    assert al.high_water_pages() == 3


def test_pooled_free_idempotent_and_partial_overlap_raises():
    al = PooledPageAllocator(page_bytes=PAGE, pool_pages=8)
    a = al.alloc(3 * PAGE, PAGE)
    al.free(a)
    al.free(a)                                      # idempotent no-op
    assert al.free_pages() == 8
    b = al.alloc(2 * PAGE, PAGE)
    # b occupies a's first two pages; re-freeing a now straddles the
    # live b and the free tail — a real double free racing reallocation
    with pytest.raises(ValueError, match="partially overlaps"):
        al.free(a)
    with pytest.raises(ValueError, match="never handed out"):
        al.free(Region(base=DEFAULT_BASE - PAGE, size_bytes=PAGE))
    with pytest.raises(ValueError, match="never handed out"):
        al.free(Region(base=b.base + 1, size_bytes=PAGE))


def test_pooled_alignment_must_divide_page():
    al = PooledPageAllocator(page_bytes=PAGE, pool_pages=8)
    al.alloc(PAGE, 512)                             # 512 divides 2048
    with pytest.raises(ValueError, match="does not divide"):
        al.alloc(PAGE, 3000)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           pool_pages=st.sampled_from([4, 16, 64, 256]))
    def test_pooled_invariants_property(seed, pool_pages):
        first, al1 = _drive_random_sequence(seed, n_ops=200,
                                            pool_pages=pool_pages)
        again, al2 = _drive_random_sequence(seed, n_ops=200,
                                            pool_pages=pool_pages)
        assert first == again
        assert al1.stats() == al2.stats()


# ---------------------------------------------------------------------------
# Replay-level contract: pooled recycling is DCO210-clean and keeps the
# DCO202 tier-aliasing count flat where bump's grows
# ---------------------------------------------------------------------------
def _replay_diags(n_requests, allocator):
    from repro.core.simulator import SimConfig
    from repro.serve.replay import ReplayConfig
    from repro.serve.replay import run_replay
    from repro.serve.traffic import TrafficConfig
    traffic = TrafficConfig(n_requests=n_requests, seed=0)
    res = run_replay(traffic, "lru", SimConfig(llc_bytes=128 * 1024),
                     ReplayConfig(allocator=allocator), verify=True)
    return res.diagnostics


def test_pooled_replay_recycles_without_overlap_diagnostics():
    """Driven by a real request stream, the pooled replay re-hands-out
    retired KV regions (bounded address footprint) with zero DCO210
    overlap findings, and its DCO202 count stays flat while bump's
    grows with replay length — the ROADMAP acceptance metric."""
    pooled_small = _replay_diags(96, "pooled")
    pooled_large = _replay_diags(600, "pooled")
    bump_small = _replay_diags(96, "bump")
    bump_large = _replay_diags(600, "bump")
    assert pooled_small.count("DCO210") == 0
    assert pooled_large.count("DCO210") == 0
    assert bump_large.count("DCO202") > bump_small.count("DCO202")
    assert (pooled_large.count("DCO202")
            <= pooled_small.count("DCO202") + 8)

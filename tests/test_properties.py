"""Hypothesis property tests on system invariants (beyond the per-module
properties in test_cache_policies)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given
from hypothesis import settings
from hypothesis import strategies as st

from repro.core import kept_fraction
from repro.core import predict
from repro.core.orchestrator import CacheOrchestrator
from repro.core.tmu import TMU
from repro.core.tmu import TMUParams
from repro.core.tmu import TensorMeta
from repro.core.traces import fa2_counts
from repro.core.workloads import AttnWorkload
from repro.core.workloads import DecodeWorkload
from repro.core.workloads import MoEWorkload
from repro.core.workloads import PrefixShareWorkload
from repro.core.workloads import SPATIAL
from repro.core.workloads import SSDScanWorkload
from repro.core.workloads import SpecDecodeWorkload
from repro.core.workloads import TEMPORAL
from repro.dataflows import compose_time_sliced
from repro.dataflows import decode_paged_spec
from repro.dataflows import fa2_spec
from repro.dataflows import lower_to_counts
from repro.dataflows import lower_to_trace
from repro.dataflows import matmul_spec
from repro.dataflows import mlp_chain_spec
from repro.dataflows import moe_ffn_spec
from repro.dataflows import prefix_share_spec
from repro.dataflows import spec_decode_spec
from repro.dataflows import ssd_scan_spec
from repro.dataflows import tenant_regions
from repro.launch.roofline import _shape_bytes
from repro.launch.roofline import _wire_factor
from repro.launch.roofline import param_count


# ---------------------------------------------------------------------------
# Orchestrator invariants
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(budget_kb=st.integers(16, 8192),
       seq=st.sampled_from([256, 512, 1024, 4096]),
       head_dim=st.sampled_from([64, 128, 256]))
def test_plan_kv_split_invariants(budget_kb, seq, head_dim):
    """The S_kept split always: partitions the sequence, stays
    block-aligned, fits the usable budget, and grows with the budget."""
    orch = CacheOrchestrator(vmem_budget_bytes=budget_kb * 1024)
    bpr = 2 * head_dim * 2
    pinned, streamed = orch.plan_kv_split(seq, 128, bpr)
    assert pinned + streamed == seq
    assert pinned % 128 == 0 and pinned >= 0 and streamed >= 0
    if streamed:        # not everything fits → pinned obeys the budget
        assert pinned * bpr <= budget_kb * 1024
    bigger = CacheOrchestrator(vmem_budget_bytes=2 * budget_kb * 1024)
    p2, _ = bigger.plan_kv_split(seq, 128, bpr)
    assert p2 >= pinned


@settings(max_examples=40, deadline=None)
@given(n_tensors=st.integers(1, 6),
       tiles=st.integers(1, 32),
       budget_tiles=st.integers(1, 64))
def test_orchestrator_plan_budget_and_partition(n_tensors, tiles,
                                                budget_tiles):
    tile_bytes = 16 * 1024
    orch = CacheOrchestrator(vmem_budget_bytes=budget_tiles * tile_bytes,
                             reserve_fraction=0.125)
    for t in range(n_tensors):
        orch.register(TensorMeta(t, base_addr=t * tiles * tile_bytes,
                                 size_bytes=tiles * tile_bytes,
                                 tile_bytes=tile_bytes, n_acc=4))
    plan = orch.plan()
    usable = int(orch.vmem_budget * (1 - orch.reserve_fraction))
    assert plan.pinned_bytes <= usable
    for e in plan.entries.values():
        got = sorted(e.pinned_tiles + e.streamed_tiles)
        assert got == list(range(tiles))       # exact partition


# ---------------------------------------------------------------------------
# Analytical model invariants
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(s_work=st.integers(1, 64), s_llc=st.integers(1, 64),
       b_bits=st.integers(1, 4))
def test_kept_fraction_bounds_and_policy_dominance(s_work, s_llc, b_bits):
    MB = 2 ** 20
    args = dict(s_work=s_work * MB, s_llc=s_llc * MB, assoc=8,
                b_bits=b_bits)
    for pol in ("lru", "dbp", "at+dbp", "bypass+dbp", "all"):
        f = kept_fraction(pol, **args)
        assert 0.0 <= f <= 1.0
    # optimal bypass dominates anti-thrashing (whole cache vs (A-1)/A)
    assert kept_fraction("all", **args) >= kept_fraction("at+dbp", **args)


@settings(max_examples=20, deadline=None)
@given(seq=st.sampled_from([1024, 2048, 4096]),
       kv=st.sampled_from([4, 8, 16]),
       alloc=st.sampled_from([TEMPORAL, SPATIAL]))
def test_prediction_positive_and_counts_consistent(seq, kv, alloc):
    wl = AttnWorkload("prop", n_q_heads=32, n_kv_heads=kv, head_dim=128,
                      seq_len=seq, group_alloc=alloc)
    counts = fa2_counts(wl)
    assert counts.n_kv_accesses >= counts.n_kv_distinct
    assert counts.n_temporal_reuse >= 0
    assert counts.n_intercore_reuse >= 0
    pred = predict(counts, 4 * 2 ** 20, "all", gqa=(alloc == SPATIAL),
                   n_rounds=counts.n_rounds)
    assert pred.cycles > 0
    assert pred.n_hit + pred.n_cold + pred.n_cf > 0


# ---------------------------------------------------------------------------
# Dataflow IR invariant: for every spec the suite can produce, the
# trace lowering and the closed-form counts lowering agree on totals
# (bytes touched, line accesses, flops, rounds) — one description, no
# hand-synced twins.
# ---------------------------------------------------------------------------
def _random_spec(draw, kinds=("fa2", "matmul", "decode", "moe", "mlp",
                              "specdec", "ssd", "prefix", "compose")):
    kind = draw(st.sampled_from(kinds))
    n_cores = draw(st.sampled_from([2, 4]))
    if kind == "compose":
        base = tuple(k for k in kinds if k != "compose")
        n_tenants = draw(st.integers(2, 3))
        tenants = [_random_spec(draw, kinds=base)
                   for _ in range(n_tenants)]
        return compose_time_sliced(
            tenants, quantum_rounds=draw(st.sampled_from([2, 8, 32])))
    if kind == "fa2":
        kv = draw(st.sampled_from([1, 2, 4]))
        gs = draw(st.sampled_from([1, 2, 4]))
        wl = AttnWorkload(
            "prop", n_q_heads=kv * gs, n_kv_heads=kv, head_dim=128,
            seq_len=draw(st.sampled_from([256, 512])),
            group_alloc=draw(st.sampled_from([TEMPORAL, SPATIAL])),
            n_batches=draw(st.sampled_from([1, 2])),
            causal=draw(st.booleans()))
        return fa2_spec(wl, n_cores)
    if kind == "matmul":
        dims = [128 * draw(st.integers(1, 3)) for _ in range(3)]
        return matmul_spec(*dims, tile=128, n_cores=n_cores)
    if kind == "decode":
        n_seqs = 2 * n_cores
        wl = DecodeWorkload(
            n_seqs=n_seqs, seq_len=draw(st.sampled_from([256, 512])),
            n_steps=3, retire_step=draw(st.sampled_from([1, 2])),
            n_short=draw(st.integers(0, n_seqs)))
        return decode_paged_spec(wl, n_cores)
    if kind == "moe":
        hot = n_cores // 2
        wl = MoEWorkload(n_experts=n_cores, n_hot=hot, d_model=128,
                         d_ff=128, tile_bytes=4096, n_steps=3,
                         warm_steps=draw(st.sampled_from([1, 2])))
        return moe_ffn_spec(wl, n_cores)
    if kind == "specdec":
        wl = SpecDecodeWorkload(
            n_seqs=n_cores * draw(st.sampled_from([1, 2])),
            target_len=draw(st.sampled_from([256, 512])),
            draft_len=draw(st.sampled_from([128, 256])),
            gamma=draw(st.integers(1, 3)),
            n_verify=draw(st.integers(1, 3)))
        return spec_decode_spec(wl, n_cores)
    if kind == "ssd":
        wl = SSDScanWorkload(
            n_seqs=n_cores * draw(st.sampled_from([1, 2])),
            n_chunks=draw(st.integers(2, 4)),
            n_heads=draw(st.sampled_from([2, 4])),
            d_head=64, d_state=64,
            chunk_len=draw(st.sampled_from([16, 32])))
        return ssd_scan_spec(wl, n_cores)
    if kind == "prefix":
        wl = PrefixShareWorkload(
            n_reqs=n_cores * draw(st.sampled_from([1, 2])),
            prefix_len=draw(st.sampled_from([256, 512])),
            suffix_len=draw(st.sampled_from([128, 256])),
            n_steps=draw(st.integers(1, 2)))
        return prefix_share_spec(wl, n_cores)
    dims = tuple(128 * draw(st.integers(1, 2)) for _ in range(4))
    return mlp_chain_spec(m=256, dims=dims, tile=128, n_cores=n_cores)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_ir_trace_totals_equal_closed_form_counts(data):
    spec = _random_spec(data.draw)
    trace = lower_to_trace(spec)
    counts = lower_to_counts(spec)
    ct = trace.compiled()
    assert counts.n_rounds == trace.n_rounds
    assert (counts.n_kv_accesses + counts.n_bypass_lines
            == int(ct.n_acc_round.sum()))
    assert float(ct.flops_round.sum()) == counts.flops_total
    # class assignment partitions the tensor set (reuse vs bypass bytes)
    bypass_bytes = sum(m.size_bytes for m in trace.tensors.values()
                       if m.bypass_all)
    assert (trace.total_bytes_touched()
            == counts.n_kv_distinct * trace.line_bytes + bypass_bytes)
    # per-tensor closed-form accesses match a literal trace walk
    per = spec.per_tensor_line_accesses()
    walked = {t.name: [0, 0] for t in spec.tensors}
    names = [t.name for t in spec.tensors]
    for steps in trace.core_steps:
        for step in steps:
            for tid, _ in step.loads:
                walked[names[tid]][0] += \
                    trace.tensors[tid].tile_bytes // trace.line_bytes
            for tid, _ in step.stores:
                walked[names[tid]][1] += \
                    trace.tensors[tid].tile_bytes // trace.line_bytes
    assert per == {k: tuple(v) for k, v in walked.items()}


# ---------------------------------------------------------------------------
# Reuse-profile invariant: for every spec the suite can produce, the
# profile lowering's total reuse mass equals the closed-form counts'
# temporal + inter-core reuse (and cold / bypass / flops totals agree) —
# the §V-C scalars are marginals of the reuse-distance histogram.
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_profile_reuse_mass_equals_closed_form_counts(data):
    spec = _random_spec(data.draw)
    counts = lower_to_counts(spec)
    prof = counts.reuse_profile
    assert (prof.total_reuse_mass()
            == counts.n_temporal_reuse + counts.n_intercore_reuse)
    assert prof.footprint_lines() == counts.n_kv_distinct
    assert (int(prof.byp_cold_round.sum() + prof.byp_rep_round.sum())
            == counts.n_bypass_lines)
    assert float(prof.flops_round.sum()) == counts.flops_total
    # live+dead split partitions every distance; MSHR mass is distance 0
    assert (prof.e_dlive >= 0).all() and (prof.e_ddead >= 0).all()
    assert int((prof.e_dlive + prof.e_ddead)[prof.e_mshr].sum()) == 0


# ---------------------------------------------------------------------------
# Multi-tenant invariants (DESIGN.md §8.4): for random 2–3-tenant
# composites, per-tenant simulator counters sum to the global stats,
# the composite reuse profile's per-tenant masses recount to the
# totals, and tenant address regions round-trip without overlap.
# ---------------------------------------------------------------------------
def _random_composite(draw):
    base = ("fa2", "matmul", "decode", "moe", "mlp", "specdec", "ssd",
            "prefix")
    tenants = [_random_spec(draw, kinds=base)
               for _ in range(draw(st.integers(2, 3)))]
    return compose_time_sliced(
        tenants, quantum_rounds=draw(st.sampled_from([2, 8, 32])))


@settings(max_examples=12, deadline=None)
@given(data=st.data())
def test_composite_tenant_conservation(data):
    from repro.core import SimConfig, named_policy, run_policy

    spec = _random_composite(data.draw)
    # regions: disjoint, aligned, and covering every tensor
    regions = tenant_regions(spec)
    for (_, _, e0), (_, b1, _) in zip(regions, regions[1:]):
        assert e0 <= b1
    for _, base, _ in regions:
        assert base % spec.tenant_region_align == 0

    counts = lower_to_counts(spec)
    prof = counts.reuse_profile
    n_t = spec.n_tenants
    # interleaving-aware recount: per-tenant profile masses sum to the
    # composite totals (and bypass/cold masses partition likewise)
    e_ten = prof.e_tenant
    assert (sum(int(prof.e_mass[e_ten == i].sum()) for i in range(n_t))
            == prof.total_reuse_mass())
    assert int(prof.cold_rt.sum()) == counts.n_kv_distinct
    assert (int(prof.byp_cold_rt.sum() + prof.byp_rep_rt.sum())
            == counts.n_bypass_lines)

    pol = data.draw(st.sampled_from(["lru", "at+dbp", "at+bypass"]))
    per_tenant = data.draw(st.booleans())
    hw = SimConfig(n_cores=spec.n_cores, llc_bytes=256 * 1024,
                   llc_slices=8)
    res = run_policy(lower_to_trace(spec),
                     named_policy(pol, per_tenant_gears=per_tenant), hw,
                     record_history=False)
    assert set(res.tenants) == set(spec.tenant_names)
    for key in ("hits", "mshr_hits", "cold_misses", "conflict_misses",
                "bypassed", "writebacks"):
        assert (sum(t[key] for t in res.tenants.values())
                == getattr(res, key)), key


# ---------------------------------------------------------------------------
# Event layer extension of the §8.4 attribution invariant: slicing the
# event stream by its tenant column must recount every per-tenant
# SimResult counter exactly (and hence the globals) — telemetry and
# accounting attribute to the same owner.
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_composite_event_stream_tenant_conservation(data):
    from repro.core import EventSink, SimConfig, Simulator, named_policy
    from repro.core.events import (EV_BYPASS, EV_FILL, EV_HIT, EV_MSHR,
                                   EV_WB)

    spec = _random_composite(data.draw)
    pol = data.draw(st.sampled_from(["lru", "at+dbp", "all"]))
    hw = SimConfig(n_cores=spec.n_cores, llc_bytes=256 * 1024,
                   llc_slices=8)
    sink = EventSink()
    res = Simulator(hw, named_policy(pol)).run(
        lower_to_trace(spec), record_history=False, events=sink)
    m = sink.matrix()
    kinds, ten, aux = m[:, 6], m[:, 2], m[:, 7]
    for i, name in enumerate(spec.tenant_names):
        t = res.tenants[name]
        sel = ten == i
        assert int((kinds[sel] == EV_HIT).sum()) == t["hits"], name
        assert (int(aux[sel & (kinds == EV_MSHR)].sum())
                == t["mshr_hits"]), name
        assert int((kinds[sel] == EV_BYPASS).sum()) == t["bypassed"], name
        assert int((kinds[sel] == EV_WB).sum()) == t["writebacks"], name
        # every one of the tenant's misses either fills or bypasses
        assert (int((kinds[sel] == EV_FILL).sum())
                + int((kinds[sel] == EV_BYPASS).sum())
                == t["cold_misses"] + t["conflict_misses"]), name
    # the tenant slices partition the globals (no orphaned events)
    assert int((kinds == EV_HIT).sum()) == res.hits
    assert int((kinds == EV_WB).sum()) == res.writebacks


# ---------------------------------------------------------------------------
# TMU invariant: retirement count never exceeds TLL accesses / nAcc
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(n_acc=st.integers(1, 5), accesses=st.integers(0, 40))
def test_tmu_retirement_rate(n_acc, accesses):
    tmu = TMU(params=TMUParams(b_bits=3))
    meta = TensorMeta(0, 0, 8 * 1024, 1024, n_acc=n_acc)
    tmu.register(meta)
    for i in range(accesses):
        tile = i % meta.num_tiles
        tmu.on_access(meta.tile_last_line(tile, 128), tile)
    assert tmu.stats["tiles_retired"] <= max(accesses // n_acc,
                                             meta.num_tiles)
    assert tmu.stats["tll_accesses"] == accesses


# ---------------------------------------------------------------------------
# Roofline helpers
# ---------------------------------------------------------------------------
def test_shape_bytes_parses_tuples():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("(bf16[4,4], s32[2])") == 32 + 8
    assert _shape_bytes("token[]") == 0


@settings(max_examples=30, deadline=None)
@given(gs=st.integers(2, 64))
def test_wire_factors_ordering(gs):
    """all-reduce must cost exactly 2× reduce-scatter; all-gather of a
    shard equals reduce-scatter of the full tensor."""
    ar = _wire_factor("all-reduce", gs)
    rs = _wire_factor("reduce-scatter", gs)
    ag = _wire_factor("all-gather", gs)
    assert ar == pytest.approx(2 * rs)
    # AG factor applies to the shard (1/gs of full): shard*(gs-1) ==
    # full*(gs-1)/gs
    assert ag / gs == pytest.approx(rs * (gs / (gs - 1)) * (gs - 1) / gs)


def test_param_counts_in_published_ballpark():
    """Config-derived parameter counts should land near the published
    model sizes (loose ±40% band — embeddings/frontends differ)."""
    from repro.configs import get_arch
    expected = {
        "llama3.2-3b": 3.2e9, "mistral-nemo-12b": 12e9,
        "gemma2-27b": 27e9, "gemma-7b": 8.5e9,
        "deepseek-moe-16b": 16e9,
        # moonshot-v1-16b-a3b omitted: the assigned pool config
        # (48L × 64 experts × d_ff 1408) computes to ~28B — we implement
        # the assignment as specified, not the hf card.
        "mamba2-2.7b": 2.7e9, "zamba2-7b": 7e9,
    }
    for name, n in expected.items():
        got = param_count(get_arch(name))
        assert 0.6 * n < got < 1.5 * n, f"{name}: {got / 1e9:.2f}B vs {n}"


# ---------------------------------------------------------------------------
# Streaming compilation invariant: for every spec the suite can produce
# and any chunk budget, the segment-fed compiled engine is bit-identical
# to the monolithic lowering — counters, per-tenant attribution, and the
# recorded gear history (boundaries that would split an MSHR-merge round
# cannot exist: rounds are atomic in the segmenter).
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_chunked_compile_matches_monolithic(data):
    from repro.core import SimConfig, Simulator, named_policy

    spec = _random_spec(data.draw)
    trace = lower_to_trace(spec)
    pol = named_policy(
        data.draw(st.sampled_from(["lru", "at+dbp", "at+bypass", "all"])))
    hw = SimConfig(n_cores=spec.n_cores, llc_bytes=256 * 1024,
                   llc_slices=8)
    mono = Simulator(hw, pol).run(trace)
    chunk = data.draw(st.sampled_from([1, 3, 17, 257, 4096, 1 << 20]))
    chunked = Simulator(hw, pol).run(lower_to_trace(spec),
                                     chunk_lines=chunk)
    for key in ("cycles", "hits", "mshr_hits", "cold_misses",
                "conflict_misses", "bypassed", "dram_lines", "writebacks",
                "dead_evictions", "flops"):
        assert getattr(mono, key) == getattr(chunked, key), key
    assert mono.tenants == chunked.tenants
    assert set(mono.history) == set(chunked.history)
    for k in mono.history:
        np.testing.assert_array_equal(mono.history[k], chunked.history[k])


# ---------------------------------------------------------------------------
# Static-verifier soundness (DESIGN.md §12): every spec the suite's
# builders can produce is error-free under the full rule inventory — no
# false positives on known-good specs, for any draw.
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_random_specs_carry_no_error_tier_diagnostics(data):
    from repro.dataflows import verify_spec

    spec = _random_spec(data.draw)
    res = verify_spec(spec)
    assert not res.has_errors, res.summary()

"""The static verifier (DESIGN.md §12): rule inventory, registry
cleanliness, corruption-injection detection, the PR 8 tag-aliasing
repro, the streaming online mode, and the ground-truth cross-check."""

import dataclasses
from pathlib import Path
import random
import subprocess
import sys

import pytest

from repro.core.simulator import SimConfig
from repro.dataflows import SpecBuilder
from repro.dataflows import assign_addresses
from repro.dataflows import verify_metas
from repro.dataflows import verify_spec
from repro.dataflows.inject import EXPECTED_CODE
from repro.dataflows.inject import LAYOUT_KINDS
from repro.dataflows.inject import SPEC_KINDS
from repro.dataflows.inject import eligible_tensors
from repro.dataflows.inject import inject_layout
from repro.dataflows.inject import inject_spec
from repro.dataflows.ir import DataflowSpec
from repro.dataflows.ir import StepSpec
from repro.dataflows.ir import TensorSpec
from repro.dataflows.suite import registry_keys
from repro.dataflows.suite import suite_case
from repro.dataflows.verify import ERROR_CODES
from repro.dataflows.verify import RULES
from repro.dataflows.verify import SpecVerifyError
from repro.dataflows.verify import StreamVerifier
from repro.dataflows.verify import cross_check_case
from repro.dataflows.verify import predicted_retirements
from repro.dataflows.verify import rules_inventory
from repro.dataflows.verify import structural_diagnostics

REPO = Path(__file__).resolve().parents[1]
LINT = REPO / "scripts" / "spec_lint.py"


# ---------------------------------------------------------------------------
# rule inventory
# ---------------------------------------------------------------------------
def test_rules_inventory_well_formed():
    inv = rules_inventory()
    codes = [r["code"] for r in inv]
    assert len(codes) == len(set(codes))
    assert all(r["severity"] in ("error", "warn", "info") for r in inv)
    assert all(r["assumption"] and r["consumer"] for r in inv)
    # every injection class maps to a registered code
    assert set(EXPECTED_CODE.values()) <= set(codes)
    assert set(ERROR_CODES) == {c for c, r in RULES.items()
                                if r.severity == "error"}


# ---------------------------------------------------------------------------
# no false positives: every registered scenario is error-free
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("key", registry_keys())
def test_registry_scenarios_error_free(key):
    case = suite_case(key, gate=False)
    res = verify_spec(case.spec, sim_cfg=case.cfg)
    assert not res.has_errors, res.summary()


# ---------------------------------------------------------------------------
# corruption injection: 100% detection by the correct code
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("key", registry_keys())
def test_injected_corruptions_all_detected(key):
    case = suite_case(key, gate=False)
    clean = verify_spec(case.spec, sim_cfg=case.cfg)
    rng = random.Random(key)          # str seeding is process-stable
    n_hit = 0
    for kind in SPEC_KINDS:
        code = EXPECTED_CODE[kind]
        # attribute detection to the corrupted tensor: skip tensors that
        # already carry the expected code in the clean run
        avoid = sorted({d.tensor for d in clean.by_code(code)
                        if d.tensor})
        got = inject_spec(case.spec, kind, rng, avoid=avoid)
        if got is None:          # no eligible tensor (e.g. all n_acc=1)
            assert not eligible_tensors(case.spec, kind, avoid)
            continue
        corrupted, inj = got
        assert not clean.located(code, inj.tensor), inj
        res = verify_spec(corrupted, sim_cfg=case.cfg)
        assert res.located(code, inj.tensor), (
            f"{key}/{kind}: {inj.description} not caught "
            f"({res.summary()})")
        n_hit += 1
    assert n_hit >= 3            # every scenario offers most classes


@pytest.mark.parametrize("key", ["matmul", "ssd-scan", "mt-spec-ssd"])
@pytest.mark.parametrize("kind", LAYOUT_KINDS)
def test_injected_layout_corruptions_detected(key, kind):
    case = suite_case(key, gate=False)
    metas = [m for _, m in sorted(assign_addresses(case.spec).items())]
    assert not verify_metas(case.spec, metas).has_errors
    rng = random.Random(11)
    bad, inj = inject_layout(case.spec, metas, kind, rng)
    res = verify_metas(case.spec, bad)
    assert res.located(inj.expected_code, inj.tensor), inj


# ---------------------------------------------------------------------------
# the PR 8 decay, minimally: two bump-allocated generations whose
# tag[B_BITS-1:0] tier values alias
# ---------------------------------------------------------------------------
def test_tag_tier_aliasing_fires_on_minimal_generation_repro():
    # 128 KB LLC at 128 B lines, assoc 8 -> 128 sets, so one tag covers
    # 16 KB and the 2^3 tier values wrap every 128 KB: each 128 KB
    # generation covers ALL tier values and the next generation (bump
    # allocation, disjoint epoch) reuses every one of them.
    tile = 16 * 1024
    b = SpecBuilder("pr8-decay", n_cores=1)
    for gen in range(2):
        b.tensor(f"kv{gen}", size_bytes=128 * 1024, tile_bytes=tile,
                 n_acc=1, epoch=(gen, gen))
    for gen in range(2):
        for t in range(8):
            b.step(0, loads=[(f"kv{gen}", t)])
    spec = b.build()
    res = verify_spec(spec, sim_cfg=SimConfig(n_cores=1,
                                              llc_bytes=128 * 1024))
    assert res.located("DCO202", "kv0")
    assert res.located("DCO202", "kv1")
    assert not res.has_errors
    # same layout, same-epoch generations: no aliasing to report
    b2 = SpecBuilder("pr8-clean", n_cores=1)
    for gen in range(2):
        b2.tensor(f"kv{gen}", size_bytes=128 * 1024, tile_bytes=tile,
                  n_acc=1)
    for gen in range(2):
        for t in range(8):
            b2.step(0, loads=[(f"kv{gen}", t)])
    res2 = verify_spec(b2.build(), sim_cfg=SimConfig(n_cores=1,
                                                     llc_bytes=128 * 1024))
    assert not res2.by_code("DCO202")


# ---------------------------------------------------------------------------
# structural tier + gates
# ---------------------------------------------------------------------------
def _raw_spec(tensors, programs):
    n = len(programs)
    return DataflowSpec(name="raw", tensors=tensors,
                        core_programs=programs, core_group=[-1] * n,
                        core_is_leader=[True] * n)


def test_structural_codes_fire():
    t = TensorSpec(name="a", size_bytes=256, tile_bytes=128, n_acc=1)
    dup = _raw_spec([t, t], [[StepSpec(loads=(("a", 0),))]])
    assert "DCO001" in {d.code for d in structural_diagnostics(dup)}
    ghost = _raw_spec([t], [[StepSpec(loads=(("b", 0),))]])
    assert "DCO003" in {d.code for d in structural_diagnostics(ghost)}
    oob = _raw_spec([t], [[StepSpec(loads=(("a", 2),))]])
    assert "DCO004" in {d.code for d in structural_diagnostics(oob)}
    with pytest.raises(ValueError, match="DCO003.*raw"):
        ghost.validate()


def test_build_gate_rejects_inconsistent_annotations():
    b = SpecBuilder("gated", n_cores=1)
    b.tensor("x", size_bytes=256, tile_bytes=128, n_acc=7)
    b.step(0, loads=[("x", 0), ("x", 1)])
    with pytest.raises(SpecVerifyError) as ei:
        b.build()
    assert any(d.code == "DCO102" for d in ei.value.result.errors)
    spec = b.build(verify=False)     # escape hatch for injection paths
    assert spec.tensor("x").n_acc == 7


# ---------------------------------------------------------------------------
# ground-truth cross-check: predictions == measured TMU RETIRE events
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("key", ["matmul", "decode-paged", "ssd-scan"])
def test_cross_check_agrees_with_simulated_retirements(key):
    case = suite_case(key, gate=False)
    cc = cross_check_case(case)
    assert cc["agree"], cc
    assert cc["predicted_retirements"] > 0
    assert cc["predicted_excess"] == 0
    for row in cc["policies"]:
        assert row["measured_retirements"] == cc["predicted_retirements"]
        assert row["measured_excess"] == 0


def test_cross_check_catches_understated_nacc():
    case = suite_case("matmul", gate=False)
    rng = random.Random(5)
    corrupted, inj = inject_spec(case.spec, "nacc_under", rng)
    bad_case = dataclasses.replace(case, spec=corrupted)
    cc = cross_check_case(bad_case, policies=("lru",))
    # the analyzer now predicts the premature retirements the simulator
    # actually produces -> still in agreement, but flagged not-clean
    assert not cc["predicted_clean"]
    assert cc["predicted_excess"] > 0
    assert cc["agree"], cc
    # predictions themselves shifted against the clean spec
    assert (sum(predicted_retirements(corrupted).values())
            > sum(predicted_retirements(case.spec).values()))


# ---------------------------------------------------------------------------
# streaming online mode
# ---------------------------------------------------------------------------
def _replay_segments(n_requests=24, seed=3, chunk_lines=2048):
    from repro.dataflows.stream import StreamEmitter
    from repro.serve.replay import ReplayConfig, ReplayEngine
    from repro.serve.traffic import RequestStream, TrafficConfig

    rcfg = ReplayConfig()
    eng = ReplayEngine(
        RequestStream(TrafficConfig(n_requests=n_requests, seed=seed)),
        rcfg)
    em = StreamEmitter("stream-verify", rcfg.n_cores,
                       chunk_lines=chunk_lines)
    return list(eng.drive(em))


def test_stream_verifier_clean_on_replay_emission():
    v = StreamVerifier("stream-verify")
    for seg in _replay_segments():
        v.on_segment(seg)
    res = v.finish()
    assert not res.has_errors, res.summary()
    assert v.segments > 1


def test_stream_verifier_catches_corrupted_segments():
    segs = _replay_segments()
    # corrupt the 2nd declared tensor's base (bump invariant) and a
    # later tensor's n_acc (overstated -> cleared before retiring)
    seen = 0
    nacc_tid = None
    for seg in segs:
        for i, meta in enumerate(seg.new_tensors):
            seen += 1
            if seen == 2:
                seg.new_tensors[i] = dataclasses.replace(
                    meta, base_addr=meta.base_addr // 2)
            elif seen == 3 and not meta.bypass_all:
                nacc_tid = meta.tensor_id
                seg.new_tensors[i] = dataclasses.replace(
                    meta, n_acc=meta.n_acc + 64)
    v = StreamVerifier("stream-verify")
    for seg in segs:
        v.on_segment(seg)
    res = v.finish()
    assert res.has_errors
    codes = set(res.codes())
    assert "DCO211" in codes
    if nacc_tid is not None:
        assert res.located("DCO102", f"t{nacc_tid}")


def test_run_replay_verify_flag_end_to_end():
    from repro.serve.replay import run_replay
    from repro.serve.traffic import TrafficConfig

    t = TrafficConfig(n_requests=16, seed=2)
    r = run_replay(t, "lru", SimConfig(), verify=True)
    assert r.diagnostics is not None
    assert not r.diagnostics.has_errors
    r2 = run_replay(t, "lru", SimConfig())
    assert r2.diagnostics is None
    # auditing the segment stream must not perturb the measurement
    assert (r2.sim.hits, r2.sim.cold_misses, r2.sim.conflict_misses,
            r2.sim.cycles) == (r.sim.hits, r.sim.cold_misses,
                               r.sim.conflict_misses, r.sim.cycles)


# ---------------------------------------------------------------------------
# the CLI
# ---------------------------------------------------------------------------
def _lint(*args):
    return subprocess.run([sys.executable, str(LINT), *args],
                          capture_output=True, text=True, cwd=REPO)


def test_spec_lint_cli_passes_on_clean_scenario(tmp_path):
    report = tmp_path / "lint.json"
    proc = _lint("matmul", "--json", str(report))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "spec lint OK" in proc.stdout
    import json
    data = json.loads(report.read_text())
    assert data["n_errors"] == 0
    assert "matmul" in data["scenarios"]


def test_spec_lint_cli_usage_errors():
    assert _lint().returncode == 2
    assert _lint("no-such-scenario").returncode == 2


def test_spec_lint_cli_rules_inventory():
    proc = _lint("--rules")
    assert proc.returncode == 0
    for r in rules_inventory():
        assert r["code"] in proc.stdout

"""Roofline table: aggregates the dry-run reports (launch/dryrun) into
the EXPERIMENTS.md §Roofline table, and prints per-cell terms."""

from __future__ import annotations

import glob
import json
import os

from .common import Timer
from .common import emit
from .common import save

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def run(full: bool = False) -> dict:
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    rows = []
    with Timer() as t:
        for path in files:
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") != "ok":
                rows.append({"cell": os.path.basename(path)[:-5],
                             "status": rec.get("status"),
                             "reason": rec.get("reason",
                                               rec.get("error", ""))[:100]})
                continue
            r = rec["roofline"]
            rows.append({
                "cell": os.path.basename(path)[:-5],
                "status": "ok",
                "t_compute_s": r["t_compute"],
                "t_memory_s": r["t_memory"],
                "t_collective_s": r["t_collective"],
                "bottleneck": r["bottleneck"],
                "useful_flops_ratio": r["useful_flops_ratio"],
                "roofline_fraction": r["roofline_fraction"],
            })
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        derived = (f"cells={len(ok)};worst={worst['cell']}"
                   f"({worst['roofline_fraction']:.3f})")
    else:
        derived = "no_dryrun_reports(run launch/dryrun first)"
    emit("roofline", t.elapsed_us, derived)
    save("roofline", rows)
    return {"rows": rows}

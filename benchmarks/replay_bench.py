"""Traffic-scale serving-replay benchmark (DESIGN.md §11).

Sweeps the cache policies over one seeded arrival trace driven end to
end through the streaming pipeline (generator → continuous batching →
incremental lowering → ``Simulator.run_stream``), and records what the
paper-level claims need side by side:

* serving SLOs — TTFT / TPOT p50/p95/p99 milliseconds from the
  simulated clock, per policy;
* cache effectiveness — hit rate, cycles, speedup vs LRU;
* replay cost — rounds/sec wall throughput, peak RSS, and the
  peak-vs-total seen-bitmap ratio that demonstrates bounded-window
  memory (``scripts/replay_gate.py`` gates both in CI);
* the at-tier decay-and-recovery curve — per-policy speedup vs replay
  length (96/1k/5k requests) under both the bump and the pooled page
  allocator, with the verifier's DCO202 tier-aliasing count per cell
  (flat under pooled, growing under bump — also CI-gated).

Default grid is a 2·10⁴-request Poisson trace; ``--full`` scales to
10⁵ requests.  ``--smoke`` (standalone CLI) is the ≈5·10³-request CI
budget check.
"""

from __future__ import annotations

import resource
import time

from .common import emit
from .common import save

#: policy axis: baseline, the dead-block predictor the serving claim
#: (§VI-F) rests on, and the at-composed variant.  DBP wins at every
#: replay length (~1.1–1.2× over LRU); under the bump allocator the
#: *at* tier decays with replay length because its address-tag tiers
#: lose their meaning as the replay mints fresh addresses forever
#: (1.25× at 96 requests → <1× beyond a few hundred), while the pooled
#: page allocator recycles retired KV regions and keeps the tiers
#: live — the decay-and-recovery curve below records both.
REPLAY_POLICIES = ("lru", "dbp", "at+dbp")

#: decay-and-recovery curve axes: replay lengths spanning the regime
#: where the bump at-tier collapses (96 → 5k requests), under both
#: address-space strategies (repro.dataflows.addr)
CURVE_LENGTHS = (96, 1000, 5000)
CURVE_ALLOCATORS = ("bump", "pooled")

#: the contested regime the paper studies: the LLC holds roughly the
#: live KV working set of a full batch, so completed requests' dead
#: pages actually displace live reuse (matches the suite scenario)
LLC_BYTES = 128 * 1024
N_DEFAULT = 20_000
N_FULL = 100_000
N_SMOKE = 5_000


def _curve(lengths=CURVE_LENGTHS, *, process: str = "poisson",
           seed: int = 0, policies=REPLAY_POLICIES):
    """Per-policy speedup vs replay length under both allocators, plus
    the DCO202 tier-aliasing count from a verified baseline run per
    cell (the count is a property of the emitted address stream, so one
    verified run covers the cell).  Returns the list of cells that
    lands in the report's ``curve`` section and drives the allocator
    gates in ``scripts/replay_gate.py``."""
    from repro.core.simulator import SimConfig
    from repro.serve.replay import ReplayConfig
    from repro.serve.replay import run_replay
    from repro.serve.traffic import TrafficConfig

    cfg = SimConfig(llc_bytes=LLC_BYTES)
    cells = []
    for n in lengths:
        traffic = TrafficConfig(n_requests=n, seed=seed, process=process)
        for alloc in CURVE_ALLOCATORS:
            rcfg = ReplayConfig(n_cores=cfg.n_cores, allocator=alloc)
            rows = {}
            base_cycles = None
            dco202 = None
            wall_s = 0.0
            for i, pol in enumerate(policies):
                t0 = time.perf_counter()
                res = run_replay(traffic, pol, cfg, rcfg, mode="stream",
                                 verify=(i == 0))
                wall_s += time.perf_counter() - t0
                if base_cycles is None:
                    base_cycles = res.sim.cycles
                if res.diagnostics is not None:
                    dco202 = res.diagnostics.count("DCO202")
                rows[pol] = {
                    "cycles": res.sim.cycles,
                    "hit_rate": res.sim.hit_rate,
                    "speedup_vs_lru": base_cycles / res.sim.cycles,
                }
            cell = {"n_requests": n, "allocator": alloc,
                    "dco202": dco202, "wall_s": wall_s, "rows": rows}
            cells.append(cell)
            derived = ";".join(
                f"{pol}_vs_lru={rows[pol]['speedup_vs_lru']:.3f}"
                for pol in policies if pol != "lru")
            emit(f"replay_curve[{alloc}]@{n}", wall_s * 1e6,
                 f"{derived};dco202={dco202}",
                 n_requests=n, allocator=alloc, dco202=dco202,
                 **{f"speedup_{pol}": rows[pol]["speedup_vs_lru"]
                    for pol in policies})
    return cells


def _bench(n_requests: int, *, process: str = "poisson", seed: int = 0,
           policies=REPLAY_POLICIES, curve=None):
    from repro.core.simulator import SimConfig
    from repro.serve.replay import run_replay
    from repro.serve.traffic import TrafficConfig

    traffic = TrafficConfig(n_requests=n_requests, seed=seed,
                            process=process)
    cfg = SimConfig(llc_bytes=LLC_BYTES)
    table = {}
    base_cycles = None
    for pol in policies:
        t0 = time.perf_counter()
        res = run_replay(traffic, pol, cfg, mode="stream")
        wall_s = time.perf_counter() - t0
        if base_cycles is None:
            base_cycles = res.sim.cycles
        rounds_per_s = res.rounds / wall_s
        maxrss_mb = (resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                     / 1024.0)
        row = {
            "policy": pol,
            "cycles": res.sim.cycles,
            "hit_rate": res.sim.hit_rate,
            "speedup_vs_lru": base_cycles / res.sim.cycles,
            "rounds": res.rounds,
            "segments": res.segments,
            "wall_s": wall_s,
            "rounds_per_s": rounds_per_s,
            "maxrss_mb": maxrss_mb,
            "peak_seen_lines": res.peak_seen_lines,
            "total_lines_declared": res.total_lines_declared,
            "slo": res.slo,
        }
        table[pol] = row
        ttft = res.slo.get("ttft_ms", {})
        emit(f"replay_bench[{pol}]", wall_s * 1e6,
             f"rounds_per_s={rounds_per_s:.0f};"
             f"hit={res.sim.hit_rate:.3f};"
             f"ttft_p95_ms={ttft.get('p95', float('nan')):.3f};"
             f"peak_seen_frac="
             f"{res.peak_seen_lines / max(res.total_lines_declared, 1):.3f}",
             n_requests=n_requests, rounds=res.rounds,
             rounds_per_s=rounds_per_s, maxrss_mb=maxrss_mb,
             peak_seen_lines=res.peak_seen_lines,
             total_lines_declared=res.total_lines_declared)
    save("replay_bench", {
        "n_requests": n_requests,
        "process": process,
        "seed": seed,
        "llc_bytes": LLC_BYTES,
        "completed": int(table[policies[0]]["slo"]
                         .get("completed", {}).get("n", 0)),
        "rows": table,
        "curve": curve,
    })
    return table


def run(full: bool = False) -> None:
    """Harness entry point (``benchmarks.run``)."""
    curve = _curve()
    _bench(N_FULL if full else N_DEFAULT, curve=curve)


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help=f"{N_FULL} requests (default {N_DEFAULT})")
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI budget check: {N_SMOKE} requests, "
                         f"single policy")
    ap.add_argument("--n", type=int, default=None,
                    help="explicit request count")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.smoke:
        # CI budget check: one 5k-policy run plus the two-allocator
        # decay/recovery curve the replay gate asserts on (dbp dropped
        # from the curve — the gates read lru and at+dbp only)
        curve = _curve(policies=("lru", "at+dbp"))
        _bench(args.n or N_SMOKE, policies=("dbp",), curve=curve)
    else:
        curve = _curve()
        _bench(args.n or (N_FULL if args.full else N_DEFAULT),
               curve=curve)


if __name__ == "__main__":
    main()

"""Traffic-scale serving-replay benchmark (DESIGN.md §11).

Sweeps the cache policies over one seeded arrival trace driven end to
end through the streaming pipeline (generator → continuous batching →
incremental lowering → ``Simulator.run_stream``), and records what the
paper-level claims need side by side:

* serving SLOs — TTFT / TPOT p50/p95/p99 milliseconds from the
  simulated clock, per policy;
* cache effectiveness — hit rate, cycles, speedup vs LRU;
* replay cost — rounds/sec wall throughput, peak RSS, and the
  peak-vs-total seen-bitmap ratio that demonstrates bounded-window
  memory (``scripts/replay_gate.py`` gates both in CI).

Default grid is a 2·10⁴-request Poisson trace; ``--full`` scales to
10⁵ requests.  ``--smoke`` (standalone CLI) is the ≈5·10³-request CI
budget check.
"""

from __future__ import annotations

import resource
import time

from .common import emit
from .common import save

#: policy axis: baseline, the dead-block predictor the serving claim
#: (§VI-F) rests on, and the at-composed variant.  DBP wins at every
#: replay length (~1.1–1.2× over LRU); the *at* tier decays with
#: replay length because its address-tag tiers lose their meaning
#: under the replay's ever-growing bump allocator (1.25× at 96
#: requests → <1× beyond a few hundred) — see the ROADMAP note on
#: paged address-pool reuse.
REPLAY_POLICIES = ("lru", "dbp", "at+dbp")

#: the contested regime the paper studies: the LLC holds roughly the
#: live KV working set of a full batch, so completed requests' dead
#: pages actually displace live reuse (matches the suite scenario)
LLC_BYTES = 128 * 1024
N_DEFAULT = 20_000
N_FULL = 100_000
N_SMOKE = 5_000


def _bench(n_requests: int, *, process: str = "poisson", seed: int = 0,
           policies=REPLAY_POLICIES):
    from repro.core.simulator import SimConfig
    from repro.serve.replay import run_replay
    from repro.serve.traffic import TrafficConfig

    traffic = TrafficConfig(n_requests=n_requests, seed=seed,
                            process=process)
    cfg = SimConfig(llc_bytes=LLC_BYTES)
    table = {}
    base_cycles = None
    for pol in policies:
        t0 = time.perf_counter()
        res = run_replay(traffic, pol, cfg, mode="stream")
        wall_s = time.perf_counter() - t0
        if base_cycles is None:
            base_cycles = res.sim.cycles
        rounds_per_s = res.rounds / wall_s
        maxrss_mb = (resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                     / 1024.0)
        row = {
            "policy": pol,
            "cycles": res.sim.cycles,
            "hit_rate": res.sim.hit_rate,
            "speedup_vs_lru": base_cycles / res.sim.cycles,
            "rounds": res.rounds,
            "segments": res.segments,
            "wall_s": wall_s,
            "rounds_per_s": rounds_per_s,
            "maxrss_mb": maxrss_mb,
            "peak_seen_lines": res.peak_seen_lines,
            "total_lines_declared": res.total_lines_declared,
            "slo": res.slo,
        }
        table[pol] = row
        ttft = res.slo.get("ttft_ms", {})
        emit(f"replay_bench[{pol}]", wall_s * 1e6,
             f"rounds_per_s={rounds_per_s:.0f};"
             f"hit={res.sim.hit_rate:.3f};"
             f"ttft_p95_ms={ttft.get('p95', float('nan')):.3f};"
             f"peak_seen_frac="
             f"{res.peak_seen_lines / max(res.total_lines_declared, 1):.3f}",
             n_requests=n_requests, rounds=res.rounds,
             rounds_per_s=rounds_per_s, maxrss_mb=maxrss_mb,
             peak_seen_lines=res.peak_seen_lines,
             total_lines_declared=res.total_lines_declared)
    save("replay_bench", {
        "n_requests": n_requests,
        "process": process,
        "seed": seed,
        "llc_bytes": LLC_BYTES,
        "completed": int(table[policies[0]]["slo"]
                         .get("completed", {}).get("n", 0)),
        "rows": table,
    })
    return table


def run(full: bool = False) -> None:
    """Harness entry point (``benchmarks.run``)."""
    _bench(N_FULL if full else N_DEFAULT)


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help=f"{N_FULL} requests (default {N_DEFAULT})")
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI budget check: {N_SMOKE} requests, "
                         f"single policy")
    ap.add_argument("--n", type=int, default=None,
                    help="explicit request count")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.smoke:
        _bench(args.n or N_SMOKE, policies=("dbp",))
    else:
        _bench(args.n or (N_FULL if args.full else N_DEFAULT))


if __name__ == "__main__":
    main()

"""Fig. 3: cache hit rate over time — LRU vs anti-thrashing (Gemma3-27B,
2K sequence, 4MB LLC)."""

from __future__ import annotations

import numpy as np

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import get_workload

from .common import Timer
from .common import emit
from .common import policy_sweep
from .common import save


def run(full: bool = False) -> dict:
    wl = get_workload("gemma3-27b", seq_len=2048)
    trace = build_fa2_trace(wl)
    cfg = SimConfig(llc_bytes=4 * 2 ** 20)
    curves = {}
    with Timer() as t:
        sweep = policy_sweep(trace, ("lru", "at"), cfg,
                             record_history=True)
        for pol, res in sweep.items():
            h = res.history
            # windowed hit rate over time (64 buckets)
            edges = np.linspace(0, h["cycles"][-1], 65)
            idx = np.searchsorted(h["cycles"], edges)
            rate, ts = [], []
            for a, b in zip(idx[:-1], idx[1:]):
                if b > a:
                    acc = h["accesses"][a:b].sum()
                    rate.append(float(h["hits"][a:b].sum() / max(acc, 1)))
                    ts.append(float(edges[1:][len(rate) - 1]))
            curves[pol] = {"t_cycles": ts, "hit_rate": rate,
                           "overall": res.hit_rate}
    adv = np.mean(curves["at"]["hit_rate"]) - np.mean(
        curves["lru"]["hit_rate"])
    emit("fig3_hitrate", t.elapsed_us,
         f"at_minus_lru_hit={adv:.3f};at={curves['at']['overall']:.3f};"
         f"lru={curves['lru']['overall']:.3f}")
    save("fig3_hitrate", curves)
    return curves

"""Table II/III: TMU hardware cost — structural bit-count estimate vs the
paper's synthesized 64,438 µm² @ 2 GHz (15nm), plus a functional
throughput microbenchmark of the dead-FIFO + priority path."""

from __future__ import annotations

from repro.core.tmu import TMU
from repro.core.tmu import TMUParams
from repro.core.tmu import TensorMeta

from .common import Timer
from .common import emit
from .common import save


def run(full: bool = False) -> dict:
    tmu = TMU(tensor_entries=8, tile_entries=256, dead_fifo_depth=16,
              params=TMUParams(d_lsb=0, d_msb=11, b_bits=3))
    rep = tmu.area_report()

    # functional microbench: TLL updates + dead lookups per second
    meta = TensorMeta(0, base_addr=0, size_bytes=1 << 20,
                      tile_bytes=16 * 1024, n_acc=4)
    tmu.register(meta)
    n = 20000 if not full else 200000
    with Timer() as t:
        for i in range(n):
            tile = i % meta.num_tiles
            tmu.on_access(meta.tile_last_line(tile, 128), tile)
            tmu.is_dead(tile)
    rate = n / (t.elapsed_us / 1e6)
    payload = {"area": rep, "functional_ops_per_s": rate,
               "config": {"tensor_entries": 8, "tile_entries": 256,
                          "dead_fifo_depth": 16, "paddr_bits": 48}}
    emit("table2_tmu", t.elapsed_us,
         f"est_area_um2={rep['estimated_um2']:.0f}"
         f"(paper {rep['paper_reference_um2']:.0f});"
         f"model_ops_per_s={rate:.2e}")
    save("table2_tmu", payload)
    return payload

"""Fig. 6: dynamic bypassing vs static gears across capacities
(Gemma3-27B temporal, normalized against fix1).

Paper: no static gear wins everywhere; dynamic tracks the best.
"""

from __future__ import annotations

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import get_workload

from .common import MB
from .common import Timer
from .common import emit
from .common import policy_sweep
from .common import save


def run(full: bool = False) -> dict:
    seq = 4096 if full else 2048
    wl = get_workload("gemma3-27b", seq_len=seq)
    trace = build_fa2_trace(wl)       # compiled once for the whole grid
    sizes = (1, 2, 4, 8)
    policies = ("fix1", "fix2", "fix3", "at+bypass")
    table = {}
    with Timer() as t:
        for mb in sizes:
            cfg = SimConfig(llc_bytes=mb * MB)
            sweep = policy_sweep(trace, policies, cfg)
            ref = sweep[policies[0]].cycles
            for pol, res in sweep.items():
                table[f"{mb}MB-{pol}"] = {
                    "cycles": res.cycles,
                    "norm_vs_fix1": res.cycles / ref,
                }
    # dynamic should be within a few % of the best policy at every size
    worst_gap = 0.0
    for mb in sizes:
        best = min(table[f"{mb}MB-{p}"]["cycles"] for p in policies)
        dyn = table[f"{mb}MB-at+bypass"]["cycles"]
        worst_gap = max(worst_gap, dyn / best - 1.0)
    emit("fig6_bypass", t.elapsed_us,
         f"dynamic_worst_gap_vs_best_static={worst_gap * 100:.1f}%")
    save("fig6_bypass", table)
    return table

"""Scenario-suite sweep: every dataflow the IR expresses × the fig-4
policy set, cross-validated against the analytical model (§V-D/§VI-G).

For each :class:`~repro.dataflows.SuiteCase` the spec is lowered once and
swept under ``SUITE_POLICIES`` via the batched ``run_policies`` API; the
same spec is lowered to counts (with the reuse-distance profile attached)
and fed to ``predict`` under **both** hit engines side by side —
``model="profile"`` (the IR-derived reuse-distance histogram, DESIGN.md
§5) and ``model="closed"`` (the §V-C scalar step functions) — each with
its own θ/λ calibration on the suite's simulator points.  Because
fitting on the very points you report error for flatters the model, a
**leave-one-scenario-out** column re-fits θ/λ with the row's scenario
held out and reports the honest out-of-sample error next to the
train-fit one.

The saved table reports, per scenario × policy: simulated cycles, hit
rate, speedup over LRU, and per engine the predicted cycles plus
train-fit and LOSO relative errors — plus the DBP-vs-LRU speedups the
decode / MoE / speculative-decoding scenarios exist to demonstrate.

Run a single scenario (CI smoke): ``python -m benchmarks.suite_bench
--scenario decode-paged``  (LOSO needs ≥ 2 scenarios and is skipped).
"""

from __future__ import annotations

import numpy as np

from repro.core import fit_params, named_policy, predict, run_policies
from repro.dataflows import (SUITE_POLICIES, build_suite, lower_to_counts,
                             lower_to_trace, suite_case)

from .common import Timer, emit, save

MODELS = ("closed", "profile")


def _sweep_case(case, table, fit_points):
    trace = lower_to_trace(case.spec)
    counts = lower_to_counts(case.spec)
    results = run_policies(
        trace, [named_policy(p, gqa=case.gqa) for p in SUITE_POLICIES],
        case.cfg)
    base = results[SUITE_POLICIES.index("lru")].cycles
    for pol, res in zip(SUITE_POLICIES, results):
        row = {
            "scenario": case.key,
            "policy": pol,
            "cycles": res.cycles,
            "hit_rate": res.hit_rate,
            "speedup_vs_lru": base / res.cycles,
            "dead_evictions": res.dead_evictions,
            "writebacks": res.writebacks,
        }
        if res.tenants:
            # per-tenant attribution columns (multi-tenant mixes,
            # DESIGN.md §8.4); conservation vs the global counters is
            # CI-gated by scripts/suite_gate.py
            row["tenants"] = res.tenants
        table[f"{case.key}-{pol}"] = row
        fit_points.append((f"{case.key}-{pol}",
                           (counts, case.cfg.llc_bytes, pol, "optimal",
                            case.gqa, counts.n_rounds, res.cycles)))
    return counts


def _record_errors(table, fit_points, hw, params, model, col):
    """Predict every row under ``params``/``model`` and append the
    ``model_cycles_*`` / ``model_rel_err_*`` columns; returns per-scenario
    mean errors."""
    errs = {}
    for row_key, (counts, llc, pol, variant, gqa, rounds, target) \
            in fit_points:
        row = table[row_key]
        pred = predict(counts, llc, pol, hw, params, variant, gqa,
                       n_rounds=rounds, model=model)
        row[f"model_cycles_{col}"] = pred.cycles
        row[f"model_rel_err_{col}"] = abs(pred.cycles - target) / target
        if model == "profile" and not col.startswith("loso"):
            # dirty-lifetime term: predicted write-back line volume next
            # to the simulator's (closed forms carry no such term)
            row["model_writebacks"] = pred.n_wb
            if pred.n_miss_tenant is not None:
                row["model_tenant_misses"] = list(pred.n_miss_tenant)
                row["model_tenant_writebacks"] = list(pred.n_wb_tenant)
        errs.setdefault(row["scenario"], []).append(
            row[f"model_rel_err_{col}"])
    return {k: float(np.mean(v)) for k, v in errs.items()}


def _validate(cases, table, fit_points):
    """§V-D calibration under both hit engines, plus the honest
    leave-one-scenario-out refits."""
    hw = cases[0].cfg
    errs, fitted = {}, {}
    for model in MODELS:
        params = fit_params([p for _, p in fit_points], hw, model=model)
        fitted[model] = params
        errs[model] = _record_errors(table, fit_points, hw, params, model,
                                     model)
        if len(cases) < 2:
            continue
        loso_errs = {}
        for case in cases:
            train = [p for k, p in fit_points
                     if table[k]["scenario"] != case.key]
            test = [(k, p) for k, p in fit_points
                    if table[k]["scenario"] == case.key]
            loso = fit_params(train, hw, model=model)
            loso_errs.update(
                _record_errors(table, test, hw, loso, model,
                               f"loso_{model}"))
        errs[f"loso_{model}"] = loso_errs
    return errs, fitted


def run(full: bool = False, scenario: str | None = None) -> dict:
    table: dict = {}
    fit_points: list = []
    with Timer() as t:
        if scenario is not None:
            cases = [suite_case(scenario, full=full)]
        else:
            cases = build_suite(full=full)
        for case in cases:
            _sweep_case(case, table, fit_points)
        errs, fitted = _validate(cases, table, fit_points)

    parts = []
    for key in ("profile", "closed", "loso_profile"):
        if key in errs:
            mean = float(np.mean(list(errs[key].values())))
            parts.append(f"model_err_mean_{key}={mean:.3f}")
    for case in cases:
        if case.expect_dbp_win:
            dbp = table[f"{case.key}-at+dbp"]["speedup_vs_lru"]
            parts.append(f"{case.key}_dbp_vs_lru={dbp:.2f}x")
    emit("suite_bench", t.elapsed_us, ";".join(parts))
    save("suite_bench", {
        "rows": table,
        "dbp_win_scenarios": [c.key for c in cases if c.expect_dbp_win],
        "model_rel_err_by_scenario": errs,
        "fitted_params": {
            model: {"theta1": p.theta1, "theta2": p.theta2,
                    "theta3": p.theta3, "lam": p.lam}
            for model, p in fitted.items()},
    })
    return table


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scenario", default=None,
                    help="run a single suite scenario (smoke mode)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, scenario=args.scenario)

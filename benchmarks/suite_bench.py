"""Scenario-suite sweep: every dataflow the IR expresses × the fig-4
policy set, cross-validated against the analytical model (§V-D/§VI-G).

For each :class:`~repro.dataflows.SuiteCase` the spec is lowered once and
swept under ``SUITE_POLICIES`` via the batched ``run_policies`` API; the
same spec is lowered to counts (with the reuse-distance profile attached)
and fed to the model under **both** hit engines side by side —
``model="profile"`` (the IR-derived reuse-distance histogram, DESIGN.md
§5) and ``model="closed"`` (the §V-C scalar step functions) — each with
its own θ/λ calibration on the suite's simulator points.  Because
fitting on the very points you report error for flatters the model, a
**leave-one-scenario-out** column re-fits θ/λ with the row's scenario
held out and reports the honest out-of-sample error next to the
train-fit one.

The suite is the fast path (DESIGN.md §8.5): independent cases run in a
process pool (``REPRO_SUITE_SERIAL=1`` forces in-process sweeps), each
worker leans on the content-addressed artifact cache for its lowerings,
the calibration is the θ-batched ``fit_params`` (bit-identical to the
sequential scan), and every prediction row comes from ``predict_batch``
over the scenario's shared reuse histogram.  Per-case seconds and
suite-seconds-per-scenario are recorded in the emitted row and the saved
report; scripts/suite_gate.py gates the per-scenario budget.

The saved table reports, per scenario × policy: simulated cycles, hit
rate, speedup over LRU, and per engine the predicted cycles plus
train-fit and LOSO relative errors — plus the DBP-vs-LRU speedups the
decode / MoE / speculative-decoding scenarios exist to demonstrate.

Run a single scenario (CI smoke — still through the pool driver):
``python -m benchmarks.suite_bench --scenario decode-paged``
(LOSO needs ≥ 2 scenarios and is skipped).
"""

from __future__ import annotations

from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass
from dataclasses import field
import os
import time

import numpy as np

from repro.core import fit_params
from repro.core import named_policy
from repro.core import predict_batch
from repro.core import run_policies
from repro.core import timeline_digest
from repro.dataflows import SUITE_POLICIES
from repro.dataflows import lower_to_counts
from repro.dataflows import lower_to_trace
from repro.dataflows import registry_keys
from repro.dataflows import suite_case

from .common import Timer
from .common import emit
from .common import save

MODELS = ("closed", "profile")


@dataclass
class _CaseResult:
    """One scenario's sweep output — everything the parent process needs
    (rows, calibration points, timing); the trace and spec stay in the
    worker."""
    key: str
    cfg: object
    expect_dbp_win: bool
    rows: dict = field(default_factory=dict)
    fit_points: list = field(default_factory=list)
    seconds: float = 0.0


def _sweep_case(case, table, fit_points):
    trace = lower_to_trace(case.spec)
    counts = lower_to_counts(case.spec)
    results = run_policies(
        trace, [named_policy(p, gqa=case.gqa) for p in SUITE_POLICIES],
        case.cfg, record_history=True)
    base = results[SUITE_POLICIES.index("lru")].cycles
    for pol, res in zip(SUITE_POLICIES, results):
        row = {
            "scenario": case.key,
            "policy": pol,
            "cycles": res.cycles,
            "hit_rate": res.hit_rate,
            "speedup_vs_lru": base / res.cycles,
            "dead_evictions": res.dead_evictions,
            "writebacks": res.writebacks,
            # per-round series fingerprint (DESIGN.md §10): engines and
            # reruns must reproduce the timeline bit-for-bit
            "timeline_digest": timeline_digest(res.timeline),
        }
        if res.tenants:
            # per-tenant attribution columns (multi-tenant mixes,
            # DESIGN.md §8.4); conservation vs the global counters is
            # CI-gated by scripts/suite_gate.py
            row["tenants"] = res.tenants
        table[f"{case.key}-{pol}"] = row
        fit_points.append((f"{case.key}-{pol}",
                           (counts, case.cfg.llc_bytes, pol, "optimal",
                            case.gqa, counts.n_rounds, res.cycles)))
    return counts


def _case_worker(args) -> _CaseResult:
    """Build and sweep exactly one registered scenario (the process-pool
    unit of work)."""
    key, full = args
    t0 = time.perf_counter()
    case = suite_case(key, full=full)
    out = _CaseResult(key, case.cfg, case.expect_dbp_win)
    _sweep_case(case, out.rows, out.fit_points)
    for _, (counts, *_rest) in out.fit_points:
        prof = counts.reuse_profile
        if prof is not None:
            # derived per-policy caches are rebuilt by the parent's
            # calibration — don't ship them across the pipe
            prof._eval_cache.clear()
    out.seconds = time.perf_counter() - t0
    return out


def _run_cases(keys, full):
    """Sweep the cases through a process pool (registry order preserved);
    ``REPRO_SUITE_SERIAL=1`` — or any pool failure — falls back to
    in-process sweeps."""
    tasks = [(k, full) for k in keys]
    if os.environ.get("REPRO_SUITE_SERIAL") == "1":
        return [_case_worker(t) for t in tasks]
    try:
        import multiprocessing
        ctx = multiprocessing.get_context("fork")
        workers = min(len(tasks), os.cpu_count() or 1)
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=ctx) as pool:
            return list(pool.map(_case_worker, tasks))
    except Exception:
        # the pool is an optimization, never a correctness dependency
        return [_case_worker(t) for t in tasks]


def _record_errors(table, fit_points, hw, params, model, col):
    """Predict every row under ``params``/``model`` and append the
    ``model_cycles_*`` / ``model_rel_err_*`` columns; returns per-scenario
    mean errors.  Rows sharing one scenario's counts evaluate in a single
    ``predict_batch`` call over the whole policy set."""
    errs = {}
    i = 0
    while i < len(fit_points):
        counts, llc, _, variant, gqa, rounds, _ = fit_points[i][1]
        j = i
        pols = []
        while j < len(fit_points):
            c2, l2, p2, v2, g2, r2, _ = fit_points[j][1]
            if (c2 is not counts or l2 != llc or v2 != variant
                    or g2 != gqa or r2 != rounds):
                break
            pols.append(p2)
            j += 1
        preds = predict_batch(counts, llc, pols, hw, params, variant, gqa,
                              n_rounds=rounds, model=model)
        for (row_key, pt), pred in zip(fit_points[i:j], preds):
            target = pt[6]
            row = table[row_key]
            row[f"model_cycles_{col}"] = pred.cycles
            row[f"model_rel_err_{col}"] = abs(pred.cycles - target) / target
            if model == "profile" and not col.startswith("loso"):
                # dirty-lifetime term: predicted write-back line volume
                # next to the simulator's (closed forms carry no such
                # term)
                row["model_writebacks"] = pred.n_wb
                if pred.n_miss_tenant is not None:
                    row["model_tenant_misses"] = list(pred.n_miss_tenant)
                    row["model_tenant_writebacks"] = list(pred.n_wb_tenant)
            errs.setdefault(row["scenario"], []).append(
                row[f"model_rel_err_{col}"])
        i = j
    return {k: float(np.mean(v)) for k, v in errs.items()}


def _validate(results, table, fit_points):
    """§V-D calibration under both hit engines, plus the honest
    leave-one-scenario-out refits."""
    hw = results[0].cfg
    errs, fitted = {}, {}
    for model in MODELS:
        params = fit_params([p for _, p in fit_points], hw, model=model)
        fitted[model] = params
        errs[model] = _record_errors(table, fit_points, hw, params, model,
                                     model)
        if len(results) < 2:
            continue
        loso_errs = {}
        for res in results:
            train = [p for k, p in fit_points
                     if table[k]["scenario"] != res.key]
            test = [(k, p) for k, p in fit_points
                    if table[k]["scenario"] == res.key]
            loso = fit_params(train, hw, model=model)
            loso_errs.update(
                _record_errors(table, test, hw, loso, model,
                               f"loso_{model}"))
        errs[f"loso_{model}"] = loso_errs
    return errs, fitted


def run(full: bool = False, scenario: str | None = None) -> dict:
    table: dict = {}
    fit_points: list = []
    with Timer() as t:
        if scenario is not None:
            if scenario not in registry_keys():
                suite_case(scenario)   # raises the canonical KeyError
            keys = [scenario]
        else:
            keys = registry_keys()
        results = _run_cases(keys, full)
        for res in results:
            table.update(res.rows)
            fit_points.extend(res.fit_points)
        t0 = time.perf_counter()
        errs, fitted = _validate(results, table, fit_points)
        validate_seconds = time.perf_counter() - t0

    parts = []
    for key in ("profile", "closed", "loso_profile"):
        if key in errs:
            mean = float(np.mean(list(errs[key].values())))
            parts.append(f"model_err_mean_{key}={mean:.3f}")
    for res in results:
        if res.expect_dbp_win:
            dbp = table[f"{res.key}-at+dbp"]["speedup_vs_lru"]
            parts.append(f"{res.key}_dbp_vs_lru={dbp:.2f}x")
    total_seconds = t.elapsed_us / 1e6
    seconds_per_scenario = total_seconds / max(len(results), 1)
    emit("suite_bench", t.elapsed_us, ";".join(parts),
         scenarios=len(results),
         seconds_per_scenario=round(seconds_per_scenario, 3))
    save("suite_bench", {
        "rows": table,
        "dbp_win_scenarios": [r.key for r in results if r.expect_dbp_win],
        "registry_keys": registry_keys(),
        "model_rel_err_by_scenario": errs,
        "fitted_params": {
            model: {"theta1": p.theta1, "theta2": p.theta2,
                    "theta3": p.theta3, "lam": p.lam}
            for model, p in fitted.items()},
        "perf": {
            "total_seconds": total_seconds,
            "seconds_per_scenario": seconds_per_scenario,
            "validate_seconds": validate_seconds,
            "case_seconds": {r.key: r.seconds for r in results},
        },
    })
    return table


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scenario", default=None,
                    help="run a single suite scenario (smoke mode)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, scenario=args.scenario)

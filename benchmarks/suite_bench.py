"""Scenario-suite sweep: every dataflow the IR expresses × the fig-4
policy set, cross-validated against the analytical model (§V-D/§VI-G).

For each :class:`~repro.dataflows.SuiteCase` the spec is lowered once and
swept under ``SUITE_POLICIES`` via the batched ``run_policies`` API; the
same spec is lowered to closed-form counts and fed to ``predict`` with
θ/λ fitted on the suite's own simulator points (the paper's per-hardware
calibration).  The saved table reports, per scenario × policy: simulated
cycles, hit rate, speedup over LRU, model-predicted cycles, and relative
model error — plus the DBP-vs-LRU speedups the decode and MoE scenarios
exist to demonstrate.

Run a single scenario (CI smoke): ``python -m benchmarks.suite_bench
--scenario decode-paged``.
"""

from __future__ import annotations

import numpy as np

from repro.core import fit_params, named_policy, predict, run_policies
from repro.dataflows import (SUITE_POLICIES, build_suite, lower_to_counts,
                             lower_to_trace, suite_case)

from .common import Timer, emit, save


def _sweep_case(case, table, fit_points):
    trace = lower_to_trace(case.spec)
    counts = lower_to_counts(case.spec)
    results = run_policies(
        trace, [named_policy(p, gqa=case.gqa) for p in SUITE_POLICIES],
        case.cfg)
    base = results[SUITE_POLICIES.index("lru")].cycles
    for pol, res in zip(SUITE_POLICIES, results):
        table[f"{case.key}-{pol}"] = {
            "scenario": case.key,
            "policy": pol,
            "cycles": res.cycles,
            "hit_rate": res.hit_rate,
            "speedup_vs_lru": base / res.cycles,
            "dead_evictions": res.dead_evictions,
        }
        fit_points.append((f"{case.key}-{pol}",
                           (counts, case.cfg.llc_bytes, pol, "optimal",
                            case.gqa, counts.n_rounds, res.cycles)))
    return counts


def _validate(cases, table, fit_points):
    """Fit θ/λ on the suite's own points, then record per-row model
    cycles and relative error (the §V-D calibration loop)."""
    hw = cases[0].cfg
    params = fit_params([p for _, p in fit_points], hw)
    errs = {}
    for row_key, (counts, llc, pol, variant, gqa, rounds, target) \
            in fit_points:
        pred = predict(counts, llc, pol, hw, params, variant, gqa,
                       n_rounds=rounds).cycles
        row = table[row_key]
        row["model_cycles"] = pred
        row["model_rel_err"] = abs(pred - target) / target
        errs.setdefault(row["scenario"], []).append(row["model_rel_err"])
    return {k: float(np.mean(v)) for k, v in errs.items()}, params


def run(full: bool = False, scenario: str | None = None) -> dict:
    table: dict = {}
    fit_points: list = []
    with Timer() as t:
        if scenario is not None:
            cases = [suite_case(scenario, full=full)]
        else:
            cases = build_suite(full=full)
        for case in cases:
            _sweep_case(case, table, fit_points)
        errs, params = _validate(cases, table, fit_points)

    parts = [f"model_err_mean={float(np.mean(list(errs.values()))):.3f}"]
    for case in cases:
        if case.expect_dbp_win:
            dbp = table[f"{case.key}-at+dbp"]["speedup_vs_lru"]
            parts.append(f"{case.key}_dbp_vs_lru={dbp:.2f}x")
    emit("suite_bench", t.elapsed_us, ";".join(parts))
    save("suite_bench", {
        "rows": table,
        "model_rel_err_by_scenario": errs,
        "fitted_params": {
            "theta1": params.theta1, "theta2": params.theta2,
            "theta3": params.theta3, "lam": params.lam},
    })
    return table


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scenario", default=None,
                    help="run a single suite scenario (smoke mode)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, scenario=args.scenario)

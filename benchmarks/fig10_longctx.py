"""Fig. 10: long-context projection via the analytical model.

Workloads at 64K/128K/256K with 16/32/64MB LLCs; speedups over LRU for
at+dbp / bypass+dbp / all.  Paper: Gemma3 peaks ≈1.30× (bypass-led);
Llama3-class spatial cases are at-led (≈1.12×), gqa-bypass ≈ 1.0."""

from __future__ import annotations

import json
import os

from repro.core import fa2_counts
from repro.core import get_workload
from repro.core import predict
from repro.core.analytical import ModelParams

from .common import MB
from .common import Timer
from .common import emit
from .common import save


def _fitted_params() -> ModelParams:
    path = os.path.join(os.path.dirname(__file__), "..", "reports",
                        "benchmarks", "fig9_validation.json")
    if os.path.exists(path):
        with open(path) as f:
            p = json.load(f)["fitted_params"]
        return ModelParams(theta1=p["theta1"], theta2=p["theta2"],
                           theta3=p["theta3"], lam=p["lambda"])
    return ModelParams()


def run(full: bool = False) -> dict:
    params = _fitted_params()
    models = ["gemma3-27b", "llama3-70b"]
    if full:
        models += ["llama3-405b", "qwen3-8b"]
    seqs = [65536, 131072, 262144]
    sizes = [16, 32, 64]
    policies = ["at+dbp", "bypass+dbp", "all"]
    table = {}
    with Timer() as t:
        for m in models:
            for seq in seqs:
                wl = get_workload(m, seq_len=seq)
                gqa = wl.group_alloc == "spatial"
                counts = fa2_counts(wl)
                for mb in sizes:
                    llc = mb * MB
                    lru = predict(counts, llc, "lru", params=params,
                                  gqa=gqa, n_rounds=counts.n_rounds).cycles
                    for pol in policies:
                        pr = predict(counts, llc, pol, params=params,
                                     gqa=gqa,
                                     n_rounds=counts.n_rounds)
                        key = f"{m}-{seq // 1024}K-{mb}MB-{pol}"
                        table[key] = {
                            "speedup_vs_lru": lru / pr.cycles,
                            "kept_fraction": pr.kept_fraction,
                        }
    g = max(v["speedup_vs_lru"] for k, v in table.items()
            if k.startswith("gemma3") and "-all" in k)
    ll = max(v["speedup_vs_lru"] for k, v in table.items()
             if k.startswith("llama3-70b") and "-all" in k)
    lb = max(v["speedup_vs_lru"] for k, v in table.items()
             if k.startswith("llama3-70b") and "bypass+dbp" in k)
    emit("fig10_longctx", t.elapsed_us,
         f"gemma_peak_all={g:.2f}x(paper~1.30);"
         f"llama70b_peak_all={ll:.2f}x(paper~1.12);"
         f"llama70b_gqa_bypass={lb:.2f}x(paper~1.0)")
    save("fig10_longctx", table)
    return table

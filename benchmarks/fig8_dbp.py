"""Fig. 8: dead-block prediction in the multi-batch scenario
(Gemma3-27B, 2 batches): at+bypass vs at+bypass+dbp.

Paper: DBP gives 1.07×/1.19× at 4/8MB; marginal at very small caches;
LRU best when everything fits (16MB)."""

from __future__ import annotations

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import get_workload

from .common import MB
from .common import Timer
from .common import emit
from .common import policy_sweep
from .common import save


def run(full: bool = False) -> dict:
    seq = 4096 if full else 2048
    wl = get_workload("gemma3-27b", seq_len=seq, n_batches=2)
    trace = build_fa2_trace(wl)       # compiled once for the whole grid
    sizes = (2, 4, 8, 16)
    table = {}
    with Timer() as t:
        for mb in sizes:
            cfg = SimConfig(llc_bytes=mb * MB)
            sweep = policy_sweep(trace, ("at+bypass", "all", "lru"), cfg)
            base, dbp, lru = (sweep["at+bypass"], sweep["all"],
                              sweep["lru"])
            table[f"{mb}MB"] = {
                "at+bypass": base.cycles, "all": dbp.cycles,
                "lru": lru.cycles,
                "dbp_speedup": base.cycles / dbp.cycles,
                "dead_evictions": dbp.dead_evictions,
            }
    mid = {k: v["dbp_speedup"] for k, v in table.items()}
    emit("fig8_dbp", t.elapsed_us,
         ";".join(f"{k}={v:.3f}x" for k, v in mid.items()))
    save("fig8_dbp", table)
    return table

"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes full tables to
reports/benchmarks/.  ``--full`` sweeps the paper's complete grids;
``--only NAME`` runs a single benchmark (unknown names are an error, not
a silent no-op); ``--json [TAG]`` additionally writes the emitted rows to
``reports/benchmarks/BENCH_<TAG>.json`` so the bench trajectory can be
tracked across PRs.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from . import common
from . import fig10_longctx
from . import fig3_hitrate
from . import fig4_policies
from . import fig5_bbits
from . import fig6_bypass
from . import fig7_gear
from . import fig8_dbp
from . import fig9_validation
from . import replay_bench
from . import roofline_bench
from . import suite_bench
from . import sweep_perf
from . import table2_tmu

BENCHMARKS = {
    "table2_tmu": table2_tmu.run,
    "fig3_hitrate": fig3_hitrate.run,
    "fig4_policies": fig4_policies.run,
    "fig5_bbits": fig5_bbits.run,
    "fig6_bypass": fig6_bypass.run,
    "fig7_gear": fig7_gear.run,
    "fig8_dbp": fig8_dbp.run,
    "fig9_validation": fig9_validation.run,
    "fig10_longctx": fig10_longctx.run,
    "roofline": roofline_bench.run,
    "sweep_perf": sweep_perf.run,
    "suite_bench": suite_bench.run,
    "replay_bench": replay_bench.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow)")
    ap.add_argument("--only", default=None,
                    help="run a subset of benchmarks by name "
                         "(comma-separated)")
    ap.add_argument("--json", nargs="?", const="latest", default=None,
                    metavar="TAG",
                    help="also write the emitted rows to "
                         "reports/benchmarks/BENCH_<TAG>.json")
    args = ap.parse_args(argv)

    only = None
    if args.only is not None:
        only = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in only if n not in BENCHMARKS]
        if unknown:
            raise SystemExit(
                f"unknown benchmark(s) {unknown}; available: "
                f"{', '.join(sorted(BENCHMARKS))}")

    print("name,us_per_call,derived")
    failed = []
    for name, fn in BENCHMARKS.items():
        if only is not None and name not in only:
            continue
        try:
            fn(full=args.full)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.json is not None:
        from repro.dataflows import registry_keys
        path = common.save_rows(args.json, full=args.full, failed=failed,
                                scenario_count=len(registry_keys()),
                                registry_keys=registry_keys())
        print(f"# rows written to {path}", file=sys.stderr)
    if failed:
        raise SystemExit(f"failed: {failed}")


if __name__ == "__main__":
    main()

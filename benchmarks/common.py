"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints a ``name,us_per_call,derived`` CSV row (harness
contract) and writes its full table to ``reports/benchmarks/<name>.json``.
``--full`` sweeps the paper's complete grids; the default is a reduced
grid sized for CI-class runtime.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict
from typing import Iterable
from typing import List

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "benchmarks")

MB = 2 ** 20

#: every ``emit`` call of the current process, in order — the harness's
#: ``--json`` mode serializes these as the machine-readable run record
ROWS: List[Dict[str, object]] = []


def policy_sweep(trace, policies: Iterable[str], cfg,
                 record_history: bool = False, gqa: bool = False) -> Dict:
    """Run one trace under many policies via the batched
    ``run_policies`` API (single compiled-trace build shared by every
    policy — the figure scripts' standard path).  Returns
    ``{policy_name: SimResult}`` keyed by the input names."""
    from repro.core import named_policy, run_policies

    names = list(policies)
    results = run_policies(
        trace, [named_policy(p, gqa=gqa) for p in names], cfg,
        record_history=record_history)
    return dict(zip(names, results))


def emit(name: str, us_per_call: float, derived: str, **extra) -> None:
    """Print one harness CSV row and buffer it for ``--json``; keyword
    extras (e.g. ``scenarios=12, seconds_per_scenario=...``) become
    additional machine-readable fields on the JSON row without touching
    the CSV contract."""
    row: Dict[str, object] = {"name": name,
                              "us_per_call": round(us_per_call, 1),
                              "derived": derived}
    row.update(extra)
    ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")


def save_rows(tag: str, **meta) -> str:
    """Write all rows emitted since the last call to ``BENCH_<tag>.json``
    (the cross-PR benchmark trajectory record) and reset the buffer."""
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.normpath(os.path.join(REPORT_DIR, f"BENCH_{tag}.json"))
    rows, ROWS[:] = list(ROWS), []
    with open(path, "w") as f:
        json.dump({"rows": rows, "meta": meta, "unix_time": time.time()},
                  f, indent=1, default=float)
    return path


def save(name: str, payload) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed_us = (time.perf_counter() - self.t0) * 1e6

"""Fig. 7: static gear sweep vs dynamic (near-optimality), plus the
gqa_bypass ablation under inter-core sharing.

(a) Gemma3-27B temporal, 2MB; (b) Qwen3-8B spatial, 1MB —
with and without the gqa variant (blind bypassing degrades, §IV-E).
"""

from __future__ import annotations

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import get_workload
from repro.core import named_policy
from repro.core import run_policy

from .common import MB
from .common import Timer
from .common import emit
from .common import save


def run(full: bool = False) -> dict:
    table = {}
    with Timer() as t:
        # (a) temporal
        wl = get_workload("gemma3-27b", seq_len=2048)
        trace = build_fa2_trace(wl)
        cfg = SimConfig(llc_bytes=(4 if full else 2) * MB)
        lru = run_policy(trace, named_policy("lru"), cfg,
                         record_history=False)
        for g in range(0, 9):
            res = run_policy(trace, named_policy(f"fix{g}"), cfg,
                             record_history=False)
            table[f"temporal-gear{g}"] = lru.cycles / res.cycles
        dyn = run_policy(trace, named_policy("at+bypass"), cfg,
                         record_history=False)
        table["temporal-dynamic"] = lru.cycles / dyn.cycles

        # (b) spatial ± gqa variant
        wl = get_workload("qwen3-8b", seq_len=2048)
        trace = build_fa2_trace(wl)
        cfg = SimConfig(llc_bytes=1 * MB)
        lru = run_policy(trace, named_policy("lru"), cfg,
                         record_history=False)
        gears = range(0, 9) if full else (0, 2, 4, 6, 8)
        for g in gears:
            blind = run_policy(trace, named_policy(f"fix{g}"), cfg,
                               record_history=False)
            gqa = run_policy(trace, named_policy(f"fix{g}", gqa=True), cfg,
                             record_history=False)
            table[f"spatial-gear{g}-blind"] = lru.cycles / blind.cycles
            table[f"spatial-gear{g}-gqa"] = lru.cycles / gqa.cycles
        dyn = run_policy(trace, named_policy("at+bypass", gqa=True), cfg,
                         record_history=False)
        table["spatial-dynamic-gqa"] = lru.cycles / dyn.cycles

    best_static = max(v for k, v in table.items()
                      if k.startswith("temporal-gear"))
    gap = table["temporal-dynamic"] / best_static - 1.0
    blind_worst = min(v for k, v in table.items() if "blind" in k)
    emit("fig7_gear", t.elapsed_us,
         f"dynamic_vs_best_static={gap * 100:+.1f}%(paper within 3%);"
         f"blind_bypass_worst={blind_worst:.2f}x(degrades<1)")
    save("fig7_gear", table)
    return table

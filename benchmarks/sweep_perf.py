"""Policy-sweep throughput: batched ``run_policies`` (compiled-trace IR)
vs sequential per-policy step-walks on the fig-4 policy set.

This is the simulator's own scaling benchmark (not a paper figure): the
paper's figures all sweep many policies over one dataflow trace, so the
sweep wall time bounds how far the grids in §VI can be pushed.  The two
paths must agree bit-exactly; the derived metric is the speedup.

Note the baseline here is the *current* step engine, which already
carries the shared LLC-model optimizations of this tree; against the
original seed's ``run_policy`` (pre-optimization cache model + per-policy
Python walk) the batched path measures ~5-7× on this workload.
"""

from __future__ import annotations

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import get_workload
from repro.core import named_policy
from repro.core import run_policies
from repro.core import run_policy

from .common import Timer
from .common import emit
from .common import save

POLICIES = ("lru", "at", "at+dbp", "at+bypass", "all")


def run(full: bool = False) -> dict:
    seq = 4096 if full else 2048
    wl = get_workload("gemma3-27b", seq_len=seq)
    cfg = SimConfig(llc_bytes=4 * 2 ** 20)

    trace = build_fa2_trace(wl)
    with Timer() as t_steps:
        ref = [run_policy(trace, named_policy(p), cfg,
                          record_history=False, engine="steps")
               for p in POLICIES]

    trace = build_fa2_trace(wl)       # fresh trace: include compile cost
    with Timer() as t_batch:
        batch = run_policies(trace, POLICIES, cfg)

    for a, b in zip(ref, batch):
        same = (a.cycles == b.cycles and a.hits == b.hits
                and a.cold_misses == b.cold_misses
                and a.conflict_misses == b.conflict_misses
                and a.bypassed == b.bypassed
                and a.dram_lines == b.dram_lines)
        if not same:
            raise AssertionError(f"engines diverged on {a.policy}")

    speedup = t_steps.elapsed_us / t_batch.elapsed_us
    table = {
        "steps_us": t_steps.elapsed_us,
        "batch_us": t_batch.elapsed_us,
        "speedup": speedup,
        "policies": list(POLICIES),
        "n_policies": len(POLICIES),
    }
    emit("sweep_perf", t_batch.elapsed_us,
         f"speedup_vs_step_engine={speedup:.2f}x;bit_identical=yes")

    # suite throughput probe: two representative scenarios through the
    # process-pool suite driver (the exact path suite_bench takes) — the
    # cross-PR record of suite-seconds-per-scenario that
    # scripts/suite_gate.py budgets on the full report
    from .suite_bench import _run_cases
    probe = ("matmul", "decode-paged")
    with Timer() as t_suite:
        results = _run_cases(list(probe), full=False)
    suite_sps = t_suite.elapsed_us / 1e6 / max(len(results), 1)
    table["suite_probe"] = {
        "scenarios": list(probe),
        "seconds_per_scenario": suite_sps,
        "case_seconds": {r.key: r.seconds for r in results},
    }
    emit("sweep_perf_suite", t_suite.elapsed_us,
         f"suite_seconds_per_scenario={suite_sps:.2f}",
         seconds_per_scenario=round(suite_sps, 3))

    # event-telemetry probe (DESIGN.md §10): emission must be zero-cost
    # when disabled.  A/B the default config against an explicitly
    # disabled one — the pair only diverges if the default path ever
    # starts paying for telemetry (e.g. `trace_events` flipping on, or
    # emission escaping its `sink is not None` guards) — and record the
    # enabled path's cost for the cross-PR record.
    probe_wl = get_workload("gemma3-27b", seq_len=512)
    probe_trace = build_fa2_trace(probe_wl)
    probe_trace.compiled(cfg.line_bytes)     # compile outside the timers

    def _best_us(run_cfg, repeats=5):
        times, res = [], None
        for _ in range(repeats):
            with Timer() as t:
                res = run_policy(probe_trace, "at+dbp", run_cfg,
                                 record_history=False)
            times.append(t.elapsed_us)
        return min(times), res

    cfg_default = SimConfig(llc_bytes=4 * 2 ** 20)
    cfg_off = SimConfig(llc_bytes=4 * 2 ** 20, trace_events=False)
    cfg_on = SimConfig(llc_bytes=4 * 2 ** 20, trace_events=True)
    default_us, res_default = _best_us(cfg_default)
    off_us, _ = _best_us(cfg_off)
    on_us, res_on = _best_us(cfg_on)
    if res_default.events is not None:
        raise AssertionError("default config emits events — telemetry "
                             "must be opt-in")
    overhead_off = default_us / off_us - 1.0
    overhead_on = on_us / off_us - 1.0
    # "~0%": a 10% margin absorbs timer noise on a shared CI core
    if overhead_off > 0.10:
        raise AssertionError(
            f"event telemetry costs {overhead_off:+.1%} with tracing "
            f"disabled (default {default_us:.0f}us vs off "
            f"{off_us:.0f}us) — the disabled path must be free")
    table["events_probe"] = {
        "default_us": default_us,
        "off_us": off_us,
        "on_us": on_us,
        "overhead_off": overhead_off,
        "overhead_on": overhead_on,
        "n_events_on": len(res_on.events),
    }
    emit("sweep_perf_events", on_us,
         f"events_overhead_off={overhead_off:+.1%};"
         f"events_overhead_on={overhead_on:+.1%}")
    save("sweep_perf", table)
    return table

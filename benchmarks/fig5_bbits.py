"""Fig. 5: anti-thrashing B_BITS sweep × capacity (Gemma3-27B temporal).

Paper: 3 bits is a stable choice across capacities.
"""

from __future__ import annotations

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import get_workload
from repro.core import named_policy
from repro.core import run_policy

from .common import MB
from .common import Timer
from .common import emit
from .common import save


def run(full: bool = False) -> dict:
    seq = 4096 if full else 2048          # paper uses 4K here
    wl = get_workload("gemma3-27b", seq_len=seq)
    trace = build_fa2_trace(wl)
    sizes = (1, 2, 4) if not full else (1, 2, 4, 8)
    table = {}
    with Timer() as t:
        for mb in sizes:
            cfg = SimConfig(llc_bytes=mb * MB)
            lru = run_policy(trace, named_policy("lru"), cfg,
                             record_history=False)
            for bits in (1, 2, 3, 4):
                res = run_policy(trace, named_policy("at", b_bits=bits),
                                 cfg, record_history=False)
                table[f"{mb}MB-B{bits}"] = {
                    "cycles": res.cycles,
                    "speedup_vs_lru": lru.cycles / res.cycles,
                }
    best3 = min(table[k]["speedup_vs_lru"] for k in table if "-B3" in k)
    emit("fig5_bbits", t.elapsed_us,
         f"worst_case_3bit_speedup={best3:.2f}x(stable>=1 expected)")
    save("fig5_bbits", table)
    return table

"""Fig. 4: execution time per replacement policy × LLC capacity.

(a/b) Gemma3-27B temporal 2K/4K; (c/d) Qwen3-8B spatial 2K/4K.
Default grid runs the 2K rows; ``--full`` adds 4K.
"""

from __future__ import annotations

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import get_workload

from .common import MB
from .common import Timer
from .common import emit
from .common import policy_sweep
from .common import save

POLICIES = ("lru", "at", "lru+bypass", "at+bypass")


def run(full: bool = False) -> dict:
    cases = [("gemma3-27b", 2048), ("qwen3-8b", 2048)]
    if full:
        cases += [("gemma3-27b", 4096), ("qwen3-8b", 4096)]
    sizes = (1, 2, 4, 8)
    table = {}
    with Timer() as t:
        for model, seq in cases:
            wl = get_workload(model, seq_len=seq)
            gqa = wl.group_alloc == "spatial"
            # one trace (and one compiled lowering) for the whole
            # capacity × policy grid of this case
            trace = build_fa2_trace(wl)
            for mb in sizes:
                cfg = SimConfig(llc_bytes=mb * MB)
                sweep = policy_sweep(trace, POLICIES, cfg, gqa=gqa)
                base = sweep[POLICIES[0]].cycles
                for pol, res in sweep.items():
                    table[f"{model}-{seq // 1024}K-{mb}MB-{pol}"] = {
                        "cycles": res.cycles,
                        "speedup_vs_lru": base / res.cycles,
                        "hit_rate": res.hit_rate,
                    }
    g4 = table["gemma3-27b-2K-4MB-at"]["speedup_vs_lru"]
    q4 = table["qwen3-8b-2K-2MB-at"]["speedup_vs_lru"]
    emit("fig4_policies", t.elapsed_us,
         f"gemma2K_4MB_at={g4:.2f}x(paper 1.51x);"
         f"qwen2K_2MB_at={q4:.2f}x")
    save("fig4_policies", table)
    return table

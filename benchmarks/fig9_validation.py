"""Fig. 9: analytical-model validation against the cycle-level simulator.

Grid (reduced by default; ``--full`` approaches the paper's 486 points):
workloads × seq × LLC × policies; fit θ/λ on the grid, report R² and
Kendall τ (paper: R²=0.997, τ=0.934)."""

from __future__ import annotations

import numpy as np

from repro.core import SimConfig
from repro.core import build_fa2_trace
from repro.core import fa2_counts
from repro.core import fit_params
from repro.core import get_workload
from repro.core import kendall_tau
from repro.core import named_policy
from repro.core import predict
from repro.core import r_squared
from repro.core import run_policy

from .common import MB
from .common import Timer
from .common import emit
from .common import save

# (model-policy, simulator-policy, bypass-variant)
POLICY_MAP = [
    ("lru", "lru", "optimal"),
    ("dbp", "dbp", "optimal"),
    ("at+dbp", "at+dbp", "optimal"),
    ("bypass+dbp", "bypass+dbp", "optimal"),
    ("all", "all", "optimal"),
]


def run(full: bool = False) -> dict:
    models = ["gemma3-27b", "qwen3-8b"]
    seqs = [1024, 2048]
    sizes = [1, 2, 4]
    if full:
        models += ["llama3-70b"]
        seqs += [4096]
    pts = []
    with Timer() as t:
        for m in models:
            for seq in seqs:
                wl = get_workload(m, seq_len=seq)
                gqa = wl.group_alloc == "spatial"
                trace = build_fa2_trace(wl)
                counts = fa2_counts(wl)
                for mb in sizes:
                    cfg = SimConfig(llc_bytes=mb * MB)
                    for mpol, spol, var in POLICY_MAP:
                        res = run_policy(trace,
                                         named_policy(spol, gqa=gqa),
                                         cfg, record_history=False)
                        pts.append((counts, mb * MB, mpol, var, gqa,
                                    counts.n_rounds, res.cycles))
        params = fit_params(pts)
        pred = np.array([predict(c, sz, p, params=params,
                                 bypass_variant=v, gqa=g,
                                 n_rounds=r).cycles
                         for (c, sz, p, v, g, r, _) in pts])
        target = np.array([x[-1] for x in pts])
        r2 = r_squared(pred, target)
        tau = kendall_tau(pred, target)
    payload = {
        "n_points": len(pts),
        "r_squared": r2, "kendall_tau": tau,
        "paper_reference": {"r_squared": 0.997, "kendall_tau": 0.934},
        "fitted_params": {"theta1": params.theta1, "theta2": params.theta2,
                          "theta3": params.theta3, "lambda": params.lam},
        "points": [{"name": c.name, "llc": sz, "policy": p,
                    "sim_cycles": tc, "pred_cycles": float(pc)}
                   for (c, sz, p, v, g, r, tc), pc in zip(pts, pred)],
    }
    emit("fig9_validation", t.elapsed_us,
         f"R2={r2:.3f}(paper 0.997);tau={tau:.3f}(paper 0.934);"
         f"n={len(pts)}")
    save("fig9_validation", payload)
    return payload

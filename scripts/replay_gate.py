#!/usr/bin/env python
"""CI gate over the most recent ``replay_bench`` report.

Asserts, on whatever request count the report covers (the ≈5k-request
CI smoke or a full 10⁵-request sweep), per swept policy:

* the replay finished inside a wall budget (``--budget-seconds`` /
  ``REPRO_REPLAY_BUDGET``) — streaming-throughput regressions fail CI
  instead of silently inflating the smoke step;
* peak memory stayed bounded by the chunk window: the streaming
  engine's seen-bitmap high-water mark must stay under
  ``--max-peak-fraction`` of the total dense lines declared over the
  replay's lifetime (bitmap recycling is the mechanism that makes
  10⁵–10⁶-request replays feasible; a leak shows up here long before
  RSS does);
* the window compiler actually chunked (≥ 2 segments — a replay that
  silently fell back to one monolithic window is not testing the
  streaming path);
* every generated request completed (the continuous-batching loop
  drained), and the TTFT/TPOT SLO percentiles are present and ordered;
* the allocator decay/recovery curve (both-allocator 96→5k smoke):
  the bump allocator's DCO202 tier-aliasing count *grows* with replay
  length while the pooled allocator's stays flat, and pooled
  allocation recovers the at-tier — at+dbp vs lru ≥ 1.0× at the
  1000-request point where the bump baseline had decayed to ~0.67×.

Run it immediately after a ``benchmarks.replay_bench`` invocation —
the benchmark always writes ``reports/benchmarks/replay_bench.json``.
"""

import argparse
import json
import os
import sys

#: default wall budget per policy for the CI smoke replay (measured
#: ~5.5 s for 5k requests on one CI core; generous 6x headroom)
DEFAULT_BUDGET_SECONDS = 30.0
#: seen-bitmap high-water mark as a fraction of total lines declared
#: (measured ~0.09 at 5k requests; the ratio shrinks as replays grow,
#: so the ceiling only loosens relative to the measurement)
DEFAULT_MAX_PEAK_FRACTION = 0.5
#: absolute slack on the pooled allocator's DCO202 count between the
#: shortest and the longest curve length (measured flat — 9 at 96
#: requests, 9 at 5k — vs bump's 0 → ~4.9k; the count may wobble by a
#: few warmup aliases but must not scale with replay length)
DEFAULT_DCO202_SLACK = 16
#: at-tier recovery floor: pooled at+dbp vs lru at the >=1k-request
#: points (bump baseline decayed to ~0.67x; pooled measured ~1.19x)
DEFAULT_AT_TIER_FLOOR = 1.0

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("report", nargs="?",
                default="reports/benchmarks/replay_bench.json",
                help="replay_bench JSON report to gate")
ap.add_argument("--budget-seconds", type=float,
                default=float(os.environ.get(
                    "REPRO_REPLAY_BUDGET", DEFAULT_BUDGET_SECONDS)),
                help="wall budget per swept policy (default: "
                     "$REPRO_REPLAY_BUDGET or %(default)s)")
ap.add_argument("--max-peak-fraction", type=float,
                default=DEFAULT_MAX_PEAK_FRACTION,
                help="seen-bitmap peak / total declared lines ceiling "
                     "(default %(default)s)")
ap.add_argument("--dco202-slack", type=int, default=DEFAULT_DCO202_SLACK,
                help="allowed pooled DCO202 growth, shortest to longest "
                     "curve length (default %(default)s)")
ap.add_argument("--at-tier-floor", type=float,
                default=DEFAULT_AT_TIER_FLOOR,
                help="pooled at+dbp vs lru floor at >=1k requests "
                     "(default %(default)s)")
args = ap.parse_args()

with open(args.report) as f:
    report = json.load(f)

n_requests = report["n_requests"]
completed = report.get("completed")
if completed != n_requests:
    sys.exit(f"replay did not drain: {completed} of {n_requests} "
             f"requests completed")

for pol, row in report["rows"].items():
    wall = float(row["wall_s"])
    if wall > args.budget_seconds:
        sys.exit(f"{pol}: replay wall time {wall:.2f} s exceeds the "
                 f"{args.budget_seconds} s budget "
                 f"({row['rounds_per_s']:.0f} rounds/s)")
    peak = int(row["peak_seen_lines"])
    total = int(row["total_lines_declared"])
    frac = peak / max(total, 1)
    if frac > args.max_peak_fraction:
        sys.exit(f"{pol}: seen-bitmap peak {peak} lines is "
                 f"{frac:.3f} of the {total} declared — exceeds the "
                 f"{args.max_peak_fraction} bounded-window ceiling "
                 f"(bitmap recycling leak?)")
    if int(row["segments"]) < 2:
        sys.exit(f"{pol}: replay compiled {row['segments']} segment(s) "
                 f"— the streaming path did not chunk")
    for metric in ("ttft_ms", "tpot_ms"):
        pct = row["slo"].get(metric)
        if not pct:
            sys.exit(f"{pol}: SLO metric {metric} missing from report")
        if not (0.0 < pct["p50"] <= pct["p95"] <= pct["p99"]):
            sys.exit(f"{pol}: {metric} percentiles malformed: {pct}")

# --- allocator decay/recovery curve -----------------------------------
curve = report.get("curve")
if not curve:
    sys.exit("report has no allocator curve — re-run "
             "benchmarks.replay_bench (it sweeps 96/1k/5k requests "
             "under both allocators)")
cells = {(c["n_requests"], c["allocator"]): c for c in curve}
lengths = sorted({c["n_requests"] for c in curve})
for alloc in ("bump", "pooled"):
    missing = [n for n in lengths if (n, alloc) not in cells]
    if missing:
        sys.exit(f"curve is missing {alloc} cells at {missing}")
lo, hi = lengths[0], lengths[-1]

bump_lo = int(cells[(lo, "bump")]["dco202"])
bump_hi = int(cells[(hi, "bump")]["dco202"])
pooled_lo = int(cells[(lo, "pooled")]["dco202"])
pooled_hi = int(cells[(hi, "pooled")]["dco202"])
if bump_hi <= bump_lo:
    sys.exit(f"bump DCO202 count did not grow with replay length "
             f"({bump_lo} at {lo} requests -> {bump_hi} at {hi}) — the "
             f"decay baseline the pooled allocator is measured against "
             f"has disappeared; re-check the verifier wiring")
if pooled_hi > pooled_lo + args.dco202_slack:
    sys.exit(f"pooled DCO202 count grew with replay length ({pooled_lo} "
             f"at {lo} requests -> {pooled_hi} at {hi}, slack "
             f"{args.dco202_slack}) — page recycling is no longer "
             f"keeping tag tiers correlated with liveness")

at_points = [(n, cells[(n, "pooled")]["rows"].get("at+dbp"))
             for n in lengths if n >= 1000]
for n, row in at_points:
    if row is None:
        sys.exit(f"curve pooled cell at {n} requests has no at+dbp row "
                 f"— the at-tier recovery gate needs it")
    sp = float(row["speedup_vs_lru"])
    if sp < args.at_tier_floor:
        sys.exit(f"pooled at+dbp vs lru is {sp:.3f}x at {n} requests — "
                 f"below the {args.at_tier_floor}x at-tier recovery "
                 f"floor (bump baseline decays to ~0.67x here)")

polys = list(report["rows"])
print(f"replay gate OK: {n_requests} requests drained over {polys}; "
      f"all within {args.budget_seconds} s and "
      f"peak-seen <= {args.max_peak_fraction} of declared; "
      f"DCO202 bump {bump_lo}->{bump_hi} vs pooled {pooled_lo}->"
      f"{pooled_hi} over {lo}->{hi} requests; pooled at+dbp "
      + ", ".join(f"{float(r['speedup_vs_lru']):.2f}x@{n}"
                  for n, r in at_points))

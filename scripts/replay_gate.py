#!/usr/bin/env python
"""CI gate over the most recent ``replay_bench`` report.

Asserts, on whatever request count the report covers (the ≈5k-request
CI smoke or a full 10⁵-request sweep), per swept policy:

* the replay finished inside a wall budget (``--budget-seconds`` /
  ``REPRO_REPLAY_BUDGET``) — streaming-throughput regressions fail CI
  instead of silently inflating the smoke step;
* peak memory stayed bounded by the chunk window: the streaming
  engine's seen-bitmap high-water mark must stay under
  ``--max-peak-fraction`` of the total dense lines declared over the
  replay's lifetime (bitmap recycling is the mechanism that makes
  10⁵–10⁶-request replays feasible; a leak shows up here long before
  RSS does);
* the window compiler actually chunked (≥ 2 segments — a replay that
  silently fell back to one monolithic window is not testing the
  streaming path);
* every generated request completed (the continuous-batching loop
  drained), and the TTFT/TPOT SLO percentiles are present and ordered.

Run it immediately after a ``benchmarks.replay_bench`` invocation —
the benchmark always writes ``reports/benchmarks/replay_bench.json``.
"""

import argparse
import json
import os
import sys

#: default wall budget per policy for the CI smoke replay (measured
#: ~5.5 s for 5k requests on one CI core; generous 6x headroom)
DEFAULT_BUDGET_SECONDS = 30.0
#: seen-bitmap high-water mark as a fraction of total lines declared
#: (measured ~0.09 at 5k requests; the ratio shrinks as replays grow,
#: so the ceiling only loosens relative to the measurement)
DEFAULT_MAX_PEAK_FRACTION = 0.5

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("report", nargs="?",
                default="reports/benchmarks/replay_bench.json",
                help="replay_bench JSON report to gate")
ap.add_argument("--budget-seconds", type=float,
                default=float(os.environ.get(
                    "REPRO_REPLAY_BUDGET", DEFAULT_BUDGET_SECONDS)),
                help="wall budget per swept policy (default: "
                     "$REPRO_REPLAY_BUDGET or %(default)s)")
ap.add_argument("--max-peak-fraction", type=float,
                default=DEFAULT_MAX_PEAK_FRACTION,
                help="seen-bitmap peak / total declared lines ceiling "
                     "(default %(default)s)")
args = ap.parse_args()

with open(args.report) as f:
    report = json.load(f)

n_requests = report["n_requests"]
completed = report.get("completed")
if completed != n_requests:
    sys.exit(f"replay did not drain: {completed} of {n_requests} "
             f"requests completed")

for pol, row in report["rows"].items():
    wall = float(row["wall_s"])
    if wall > args.budget_seconds:
        sys.exit(f"{pol}: replay wall time {wall:.2f} s exceeds the "
                 f"{args.budget_seconds} s budget "
                 f"({row['rounds_per_s']:.0f} rounds/s)")
    peak = int(row["peak_seen_lines"])
    total = int(row["total_lines_declared"])
    frac = peak / max(total, 1)
    if frac > args.max_peak_fraction:
        sys.exit(f"{pol}: seen-bitmap peak {peak} lines is "
                 f"{frac:.3f} of the {total} declared — exceeds the "
                 f"{args.max_peak_fraction} bounded-window ceiling "
                 f"(bitmap recycling leak?)")
    if int(row["segments"]) < 2:
        sys.exit(f"{pol}: replay compiled {row['segments']} segment(s) "
                 f"— the streaming path did not chunk")
    for metric in ("ttft_ms", "tpot_ms"):
        pct = row["slo"].get(metric)
        if not pct:
            sys.exit(f"{pol}: SLO metric {metric} missing from report")
        if not (0.0 < pct["p50"] <= pct["p95"] <= pct["p99"]):
            sys.exit(f"{pol}: {metric} percentiles malformed: {pct}")

polys = list(report["rows"])
print(f"replay gate OK: {n_requests} requests drained over {polys}; "
      f"all within {args.budget_seconds} s and "
      f"peak-seen <= {args.max_peak_fraction} of declared")

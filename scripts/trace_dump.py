#!/usr/bin/env python
"""Render or export one run's event trace (DESIGN.md §10).

Runs a suite scenario under one policy with event telemetry on and
either prints a window of decoded events or exports the raw columns as
npz for offline analysis (the training substrate for learned-predictor
work).

    PYTHONPATH=src python scripts/trace_dump.py matmul --policy at+dbp
    PYTHONPATH=src python scripts/trace_dump.py decode-paged \
        --round 40 --window 2            # all events of rounds 38..42
    PYTHONPATH=src python scripts/trace_dump.py mt-spec-ssd \
        --npz /tmp/events.npz            # export flat columns
"""

from __future__ import annotations

import argparse
from pathlib import Path
import sys

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import EventSink
from repro.core import Simulator
from repro.core.events import decode_event
from repro.core.policies import named_policy


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("scenario", help="suite scenario key "
                    "(see repro.dataflows.suite.registry_keys)")
    ap.add_argument("--policy", default="at+dbp")
    ap.add_argument("--engine", default="compiled",
                    choices=("compiled", "steps"))
    ap.add_argument("--head", type=int, default=40,
                    help="print the first N events (default 40)")
    ap.add_argument("--round", type=int, default=None,
                    help="print events of this round instead of --head")
    ap.add_argument("--window", type=int, default=0,
                    help="with --round: also include +/- this many rounds")
    ap.add_argument("--canonical", action="store_true",
                    help="print in canonical order instead of emission "
                         "order")
    ap.add_argument("--npz", type=Path, default=None,
                    help="export the raw event columns to this npz file")
    args = ap.parse_args(argv)

    from repro.dataflows import lower_to_trace
    from repro.dataflows.suite import suite_case
    try:
        case = suite_case(args.scenario)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    try:
        policy = named_policy(args.policy, gqa=case.gqa)
    except (KeyError, ValueError):
        print(f"error: unknown policy {args.policy!r}", file=sys.stderr)
        return 2
    trace = lower_to_trace(case.spec)
    sink = EventSink()
    sim = Simulator(case.cfg, policy)
    res = sim.run(trace, record_history=False, engine=args.engine,
                  events=sink)

    print(f"# {args.scenario} / {res.policy} ({args.engine}): "
          f"{len(sink)} events, digest {sink.digest()}")
    for kind, count in sink.counts_by_kind().items():
        if count:
            print(f"#   {kind:7s} {count}")

    if args.npz is not None:
        sink.to_npz(args.npz)
        print(f"# exported to {args.npz}")
        return 0

    mat = sink.canonical() if args.canonical else sink.matrix()
    if args.round is not None:
        lo, hi = args.round - args.window, args.round + args.window
        sel = (mat[:, 0] >= lo) & (mat[:, 0] <= hi)
        rows = mat[sel]
        print(f"# rounds {lo}..{hi}: {rows.shape[0]} events")
    else:
        rows = mat[: args.head]
    for row in rows:
        print(decode_event(row))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

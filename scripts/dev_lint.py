#!/usr/bin/env python
"""Offline approximation of the repo's ruff gate (pyproject.toml).

CI runs real ruff; this tool reproduces the subset of its verdicts that
matter for keeping the tree clean from environments without network
access (where ruff cannot be installed):

* **import order/format** — the exact canonical form of the configured
  isort profile (force-single-line, case-sensitive ASCII, sections
  future/stdlib/third-party/first-party/local, one blank line between
  sections); ``--fix`` rewrites import blocks in place,
* **F401** unused imports (``__all__`` counts as use; ``--fix`` does
  not remove them — they are reported for manual review),
* **E401** multiple imports on one line, **E402** late module imports
  (with the pyproject per-file ignores), **E711/E712** ``==`` against
  None/True/False, **E722** bare except, **E731** lambda assignment,
  **E741** ambiguous single-letter names (l/O/I), **E701/E702**
  compound statements.

    python scripts/dev_lint.py            # check src/tests/scripts/benchmarks
    python scripts/dev_lint.py --fix      # rewrite import blocks in place

Import blocks containing interior comments are never rewritten (a
comment would have to move with its statement); they are reported so
the imports can be reordered by hand.
"""

from __future__ import annotations

import argparse
import ast
from pathlib import Path
import sys

REPO = Path(__file__).resolve().parents[1]
ROOTS = ("src", "tests", "scripts", "benchmarks")
FIRST_PARTY = ("repro", "benchmarks")
E402_IGNORED = ("scripts", "tests", "benchmarks")

STDLIB = getattr(sys, "stdlib_module_names", frozenset())


def _section(node: ast.stmt) -> int:
    if isinstance(node, ast.ImportFrom):
        if node.level:
            return 4
        mod = node.module or ""
    else:
        mod = node.names[0].name
    top = mod.split(".")[0]
    if top == "__future__":
        return 0
    if top in STDLIB:
        return 1
    if top in FIRST_PARTY:
        return 3
    return 2


def _single_lines(node: ast.stmt):
    """Explode one import statement into (sort_key, rendered_line)."""
    if isinstance(node, ast.Import):
        for a in node.names:
            line = f"import {a.name}" + (f" as {a.asname}" if a.asname
                                         else "")
            yield (_section(node), a.name, 0, "", a.asname or ""), line
    else:
        dots = "." * node.level
        mod = f"{dots}{node.module or ''}"
        # relative imports sort furthest-to-closest, then by module name
        mkey = (f"\x00{255 - node.level:03d}.{node.module or ''}"
                if node.level else node.module or "")
        for a in node.names:
            line = f"from {mod} import {a.name}" + (
                f" as {a.asname}" if a.asname else "")
            yield (_section(node), mkey, 1, a.name, a.asname or ""), line


def _render_block(nodes) -> str:
    entries = sorted(e for n in nodes for e in _single_lines(n))
    out, prev_sec = [], None
    for (sec, *_), line in entries:
        if prev_sec is not None and sec != prev_sec:
            out.append("")
        out.append(line)
        prev_sec = sec
    return "\n".join(out)


def _import_blocks(tree: ast.Module, lines):
    """Contiguous top-level import runs (blank lines allowed inside,
    any other statement or comment line ends the block)."""
    blocks, cur, end = [], [], None
    for node in tree.body:
        is_imp = isinstance(node, (ast.Import, ast.ImportFrom))
        if is_imp and cur:
            gap = range(end, node.lineno - 1)   # 0-based between lines
            clean = all(not lines[i].strip()
                        or lines[i].lstrip().startswith("#")
                        for i in gap)
            has_comment = any(lines[i].lstrip().startswith("#")
                              for i in gap)
            if clean and not has_comment:
                cur.append(node)
                end = node.end_lineno
                continue
        if cur:
            blocks.append(cur)
            cur = []
        if is_imp:
            cur = [node]
            end = node.end_lineno
    if cur:
        blocks.append(cur)
    return blocks


def _has_interior_comment(lines, lo, hi) -> bool:
    return any(lines[i].lstrip().startswith("#") for i in range(lo, hi))


class _Checker(ast.NodeVisitor):
    def __init__(self, rel: str, src: str, tree: ast.Module):
        self.rel = rel
        self.problems: list[str] = []
        self.tree = tree
        self.src = src

    def err(self, node, code, msg):
        self.problems.append(f"{self.rel}:{node.lineno}: {code} {msg}")

    # E711/E712/E721/F632 -----------------------------------------------
    def visit_Compare(self, node: ast.Compare):
        for op, right in zip(node.ops, node.comparators):
            if isinstance(op, (ast.Eq, ast.NotEq)):
                if isinstance(right, ast.Constant):
                    if right.value is None:
                        self.err(node, "E711", "comparison to None "
                                 "(use 'is None')")
                    elif right.value is True or right.value is False:
                        self.err(node, "E712", "comparison to "
                                 f"{right.value} (use 'is')")
                if (isinstance(right, ast.Call)
                        and isinstance(right.func, ast.Name)
                        and right.func.id == "type"):
                    self.err(node, "E721", "type comparison with == "
                             "(use isinstance)")
            elif isinstance(op, (ast.Is, ast.IsNot)):
                if (isinstance(right, ast.Constant)
                        and isinstance(right.value, (str, int, float,
                                                     bytes, tuple))
                        and right.value is not True
                        and right.value is not False
                        and right.value is not None):
                    self.err(node, "F632", "'is' comparison with a "
                             "literal (use ==)")
        self.generic_visit(node)

    # E713/E714 ---------------------------------------------------------
    def visit_UnaryOp(self, node: ast.UnaryOp):
        if isinstance(node.op, ast.Not) and isinstance(node.operand,
                                                       ast.Compare):
            cmp = node.operand
            if len(cmp.ops) == 1:
                if isinstance(cmp.ops[0], ast.In):
                    self.err(node, "E713", "use 'not in' for membership")
                elif isinstance(cmp.ops[0], ast.Is):
                    self.err(node, "E714", "use 'is not' for identity")
        self.generic_visit(node)

    # F541 --------------------------------------------------------------
    def visit_JoinedStr(self, node: ast.JoinedStr):
        if not any(isinstance(v, ast.FormattedValue)
                   for v in node.values):
            self.err(node, "F541", "f-string without placeholders")
        self.generic_visit(node)

    def visit_FormattedValue(self, node: ast.FormattedValue):
        # a format spec is itself a JoinedStr with no placeholders —
        # visiting it would false-positive F541 on every ':.3f'
        self.visit(node.value)

    # E722 --------------------------------------------------------------
    def visit_ExceptHandler(self, node):
        if node.type is None:
            self.err(node, "E722", "bare except")
        self.generic_visit(node)

    # E731 --------------------------------------------------------------
    def visit_Assign(self, node: ast.Assign):
        if isinstance(node.value, ast.Lambda):
            self.err(node, "E731", "lambda assignment (use def)")
        self._ambiguous_targets(node.targets, node)
        self.generic_visit(node)

    # E741 --------------------------------------------------------------
    AMBIGUOUS = {"l", "O", "I"}

    def _ambiguous_targets(self, targets, node):
        for t in targets:
            for n in ast.walk(t):
                if (isinstance(n, ast.Name) and n.id in self.AMBIGUOUS
                        and isinstance(n.ctx, ast.Store)):
                    self.err(node, "E741", f"ambiguous name {n.id!r}")

    def visit_For(self, node):
        self._ambiguous_targets([node.target], node)
        self.generic_visit(node)

    def visit_comprehension_targets(self, gens, node):
        self._ambiguous_targets([g.target for g in gens], node)

    def visit_ListComp(self, node):
        self.visit_comprehension_targets(node.generators, node)
        self.generic_visit(node)

    visit_SetComp = visit_ListComp
    visit_GeneratorExp = visit_ListComp

    def visit_DictComp(self, node):
        self.visit_comprehension_targets(node.generators, node)
        self.generic_visit(node)

    def _check_args(self, node):
        a = node.args
        for arg in (a.posonlyargs + a.args + a.kwonlyargs
                    + ([a.vararg] if a.vararg else [])
                    + ([a.kwarg] if a.kwarg else [])):
            if arg.arg in self.AMBIGUOUS:
                self.err(node, "E741", f"ambiguous arg {arg.arg!r}")

    def visit_FunctionDef(self, node):
        self._check_args(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    # E401 --------------------------------------------------------------
    def visit_Import(self, node):
        if len(node.names) > 1:
            self.err(node, "E401", "multiple imports on one line")
        self.generic_visit(node)


def _f401(rel: str, tree: ast.Module, problems: list) -> None:
    bound: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = a.asname or a.name.split(".")[0]
                bound.setdefault(name, node.lineno)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                bound.setdefault(a.asname or a.name, node.lineno)
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif (isinstance(node, ast.Constant)
              and isinstance(node.value, str)):
            used.add(node.value)          # __all__ entries / doc refs
    for name, lineno in sorted(bound.items(), key=lambda kv: kv[1]):
        if name not in used:
            problems.append(f"{rel}:{lineno}: F401 {name!r} imported "
                            f"but unused")


def _f841(rel: str, tree: ast.Module, problems: list) -> None:
    """Unused local variables (simple assignments only; tuple-unpacking
    and underscore-prefixed names are exempt, matching ruff defaults)."""

    def walk_scope(node, skip_nested=True):
        """Yield nodes of one function scope, not descending into
        nested function/class scopes (for assignment attribution)."""
        stack = list(ast.iter_child_nodes(node))
        while stack:
            n = stack.pop()
            yield n
            if skip_nested and isinstance(
                    n, (ast.FunctionDef, ast.AsyncFunctionDef,
                        ast.ClassDef, ast.Lambda)):
                continue
            stack.extend(ast.iter_child_nodes(n))

    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        declared = set()
        assigns: dict = {}
        for n in walk_scope(fn):
            if isinstance(n, (ast.Global, ast.Nonlocal)):
                declared.update(n.names)
            elif isinstance(n, ast.Assign):
                for t in n.targets:
                    if isinstance(t, ast.Name):
                        assigns.setdefault(t.id, n.lineno)
            elif isinstance(n, ast.AnnAssign) and n.value is not None:
                if isinstance(n.target, ast.Name):
                    assigns.setdefault(n.target.id, n.lineno)
            elif isinstance(n, ast.ExceptHandler) and n.name:
                assigns.setdefault(n.name, n.lineno)
        loads = {n.id for n in ast.walk(fn)
                 if isinstance(n, ast.Name)
                 and not isinstance(n.ctx, ast.Store)}
        for name, lineno in sorted(assigns.items(), key=lambda kv: kv[1]):
            if (name not in loads and name not in declared
                    and not name.startswith("_")):
                problems.append(f"{rel}:{lineno}: F841 local variable "
                                f"{name!r} assigned but never used")


def _e402(rel: str, tree: ast.Module, problems: list) -> None:
    if any(rel.startswith(p + "/") for p in E402_IGNORED):
        return
    code_seen = False
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            if code_seen:
                problems.append(f"{rel}:{node.lineno}: E402 module "
                                f"import not at top of file")
        elif isinstance(node, ast.Expr) and isinstance(node.value,
                                                       ast.Constant):
            continue                      # docstring
        elif (isinstance(node, (ast.If, ast.Try, ast.Assign))
              and not code_seen):
            # ruff tolerates guards/dunder assignments before imports
            continue
        else:
            code_seen = True


def _e701_702(rel: str, src: str, problems: list) -> None:
    import io
    import tokenize
    depth = 0
    for tok in tokenize.generate_tokens(io.StringIO(src).readline):
        if tok.type == tokenize.OP:
            if tok.string in "([{":
                depth += 1
            elif tok.string in ")]}":
                depth -= 1
            elif tok.string == ";" and depth == 0:
                problems.append(f"{rel}:{tok.start[0]}: E702 statement "
                                f"ends with a semicolon")


def process(path: Path, fix: bool) -> list:
    rel = path.relative_to(REPO).as_posix()
    src = path.read_text()
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [f"{rel}:{e.lineno}: E999 {e.msg}"]
    problems: list = []
    lines = src.splitlines()

    chk = _Checker(rel, src, tree)
    chk.visit(tree)
    problems += chk.problems
    _f401(rel, tree, problems)
    _f841(rel, tree, problems)
    _e402(rel, tree, problems)
    _e701_702(rel, src, problems)

    # import-block canonical form ---------------------------------------
    changed = False
    for block in reversed(_import_blocks(tree, lines)):
        lo = block[0].lineno - 1
        hi = block[-1].end_lineno
        if _has_interior_comment(lines, lo, hi):
            got = "\n".join(lines[lo:hi])
            want = _render_block(block)
            if got != want:
                problems.append(
                    f"{rel}:{lo + 1}: I001 import block needs "
                    f"reordering but carries comments — fix by hand")
            continue
        want = _render_block(block)
        got = "\n".join(lines[lo:hi])
        if got != want:
            if fix:
                lines[lo:hi] = want.split("\n")
                changed = True
            else:
                problems.append(f"{rel}:{lo + 1}: I001 import block not "
                                f"in canonical form")
    if changed:
        path.write_text("\n".join(lines) + ("\n" if src.endswith("\n")
                                            else ""))
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files or directories (default: the repo roots)")
    ap.add_argument("--fix", action="store_true",
                    help="rewrite import blocks in place")
    args = ap.parse_args(argv)

    targets = [p.resolve() for p in args.paths] or [REPO / r for r in ROOTS]
    files = []
    for t in targets:
        files.extend(sorted(t.rglob("*.py")) if t.is_dir() else [t])
    all_problems = []
    for f in files:
        if "reports" in f.parts or "__pycache__" in f.parts:
            continue
        all_problems += process(f, args.fix)
    for p in all_problems:
        print(p)
    print(f"# {len(files)} files, {len(all_problems)} problem(s)")
    return 1 if all_problems else 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""CI gate over the most recent ``suite_bench`` report.

Asserts, on whatever scenario set the report covers (single smoke
scenario or the full suite):

* the reuse-profile model's mean relative error is no worse than the
  closed-form model's (the PR-3 accuracy win is regression-gated);
* every DBP-win scenario in the report still beats plain LRU under
  ``at+dbp`` (speedup > 1.0), and every scenario this gate *expects* to
  be a DBP win (``EXPECTED_DBP_WINS``) is still flagged as one when it
  appears in the report — deregistering ``expect_dbp_win`` on a
  scenario cannot silently disable its gate;
* the ``ssd-scan`` DBP win clears a regression margin
  (``SSD_SCAN_MIN_DBP``): the chunk-state retirement pattern is the
  scenario's reason to exist.

Run it immediately after each ``benchmarks.suite_bench`` invocation —
the benchmark always writes ``reports/benchmarks/suite_bench.json``, so
a later run overwrites an earlier scenario's numbers.
"""

import json
import sys

import numpy as np

#: scenarios whose at+dbp-vs-lru win is part of their contract
EXPECTED_DBP_WINS = ("decode-paged", "moe-ffn", "spec-decode", "ssd-scan")
#: regression margin for the ssd-scan chunk-state win (measured 1.24x)
SSD_SCAN_MIN_DBP = 1.10

path = sys.argv[1] if len(sys.argv) > 1 else \
    "reports/benchmarks/suite_bench.json"
with open(path) as f:
    report = json.load(f)

errs = report["model_rel_err_by_scenario"]
prof = float(np.mean(list(errs["profile"].values())))
closed = float(np.mean(list(errs["closed"].values())))
scenarios = sorted(errs["profile"])
# On a single-scenario smoke the 4-parameter closed fit can memorize its
# own 5 points, so "profile <= closed" alone would be vacuous there; an
# absolute floor keeps the gate meaningful in both directions.  Explicit
# exits, not asserts: python -O must not strip the gate.
ABS_OK = 0.15
if prof > max(closed, ABS_OK):
    sys.exit(f"reuse-profile model regressed on {scenarios}: mean rel "
             f"err {prof:.3f} > closed-form {closed:.3f} (and > {ABS_OK})")

flagged = report.get("dbp_win_scenarios", [])
for key in scenarios:
    if key in EXPECTED_DBP_WINS and key not in flagged:
        sys.exit(f"{key}: expected DBP-win scenario is no longer flagged "
                 f"expect_dbp_win in the suite registry")
for key in flagged:
    dbp = report["rows"][f"{key}-at+dbp"]["speedup_vs_lru"]
    if not dbp > 1.0:
        sys.exit(f"{key}: DBP win over LRU lost ({dbp:.3f}x)")
    if key == "ssd-scan" and dbp < SSD_SCAN_MIN_DBP:
        sys.exit(f"ssd-scan: chunk-state DBP win regressed "
                 f"({dbp:.3f}x < {SSD_SCAN_MIN_DBP}x)")

print(f"suite gate OK on {scenarios}: profile {prof:.3f} <= "
      f"max(closed {closed:.3f}, {ABS_OK}); dbp wins {flagged}")

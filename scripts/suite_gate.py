#!/usr/bin/env python
"""CI gate over the most recent ``suite_bench`` report.

Asserts, on whatever scenario set the report covers (single smoke
scenario or the full suite):

* the reuse-profile model's mean relative error is no worse than the
  closed-form model's (the PR-3 accuracy win is regression-gated);
* every DBP-win scenario in the report still beats plain LRU under
  ``at+dbp`` (speedup > 1.0), and every scenario this gate *expects* to
  be a DBP win (``EXPECTED_DBP_WINS``) is still flagged as one when it
  appears in the report — deregistering ``expect_dbp_win`` on a
  scenario cannot silently disable its gate;
* the ``ssd-scan`` DBP win clears a regression margin
  (``SSD_SCAN_MIN_DBP``): the chunk-state retirement pattern is the
  scenario's reason to exist;
* the ``mt-spec-ssd`` multi-tenant mix clears its own margin
  (``MT_SPEC_SSD_MIN_DBP``), and every multi-tenant row's per-tenant
  counters conserve exactly against the global ones (the attribution
  contract of DESIGN.md §8.4).

Run it immediately after each ``benchmarks.suite_bench`` invocation —
the benchmark always writes ``reports/benchmarks/suite_bench.json``, so
a later run overwrites an earlier scenario's numbers.
"""

import argparse
import json
import os
import sys

import numpy as np

#: scenarios whose at+dbp-vs-lru win is part of their contract
EXPECTED_DBP_WINS = ("decode-paged", "moe-ffn", "spec-decode", "ssd-scan",
                     "mt-prefill-decode", "mt-spec-ssd")
#: regression margin for the ssd-scan chunk-state win (measured 1.24x)
SSD_SCAN_MIN_DBP = 1.10
#: regression margin for the multi-tenant spec+ssd mix (measured 1.12x)
MT_SPEC_SSD_MIN_DBP = 1.05
#: model-accuracy residue pinned (carried from PR 5): the stratified
#: standing-occupancy band over-protects marginal tiers fed by live
#: re-touch, so the profile model's ``at``-row error saturates around
#: 0.10–0.17 on these scenarios.  The ceilings hold the residue where
#: it was measured — the open ROADMAP model item may shrink it, but no
#: change may silently widen it.
AT_RESIDUE_CEILINGS = {"moe-ffn": 0.22, "decode-paged": 0.22}
#: default wall budget per scenario for the pooled suite driver
#: (measured ~1.2 s per scenario on one CI core; the pre-streaming sweep
#: was ~20 s per scenario) — gated whenever the report carries a perf
#: record; tune per-runner with --sps-budget / REPRO_SPS_BUDGET
DEFAULT_SECONDS_PER_SCENARIO = 6.0

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("report", nargs="?",
                default="reports/benchmarks/suite_bench.json",
                help="suite_bench JSON report to gate")
ap.add_argument("--sps-budget", type=float,
                default=float(os.environ.get(
                    "REPRO_SPS_BUDGET", DEFAULT_SECONDS_PER_SCENARIO)),
                help="seconds-per-scenario wall budget (default: "
                     "$REPRO_SPS_BUDGET or %(default)s)")
args = ap.parse_args()
MAX_SECONDS_PER_SCENARIO = args.sps_budget

with open(args.report) as f:
    report = json.load(f)

errs = report["model_rel_err_by_scenario"]
prof = float(np.mean(list(errs["profile"].values())))
closed = float(np.mean(list(errs["closed"].values())))
scenarios = sorted(errs["profile"])
# On a single-scenario smoke the 4-parameter closed fit can memorize its
# own 5 points, so "profile <= closed" alone would be vacuous there; an
# absolute floor keeps the gate meaningful in both directions.  Explicit
# exits, not asserts: python -O must not strip the gate.
ABS_OK = 0.15
if prof > max(closed, ABS_OK):
    sys.exit(f"reuse-profile model regressed on {scenarios}: mean rel "
             f"err {prof:.3f} > closed-form {closed:.3f} (and > {ABS_OK})")

flagged = report.get("dbp_win_scenarios", [])
for key in scenarios:
    if key in EXPECTED_DBP_WINS and key not in flagged:
        sys.exit(f"{key}: expected DBP-win scenario is no longer flagged "
                 f"expect_dbp_win in the suite registry")
for key in flagged:
    dbp = report["rows"][f"{key}-at+dbp"]["speedup_vs_lru"]
    if not dbp > 1.0:
        sys.exit(f"{key}: DBP win over LRU lost ({dbp:.3f}x)")
    if key == "ssd-scan" and dbp < SSD_SCAN_MIN_DBP:
        sys.exit(f"ssd-scan: chunk-state DBP win regressed "
                 f"({dbp:.3f}x < {SSD_SCAN_MIN_DBP}x)")
    if key == "mt-spec-ssd" and dbp < MT_SPEC_SSD_MIN_DBP:
        sys.exit(f"mt-spec-ssd: multi-tenant DBP win regressed "
                 f"({dbp:.3f}x < {MT_SPEC_SSD_MIN_DBP}x)")

# at-row saturation residue: ceilings per scenario (see above)
for key, ceiling in AT_RESIDUE_CEILINGS.items():
    row = report["rows"].get(f"{key}-at")
    if row is None:
        continue
    err = row.get("model_rel_err_profile")
    if err is not None and err > ceiling:
        sys.exit(f"{key}: profile-model at-row error {err:.3f} exceeds "
                 f"the pinned residue ceiling {ceiling} — the "
                 f"over-protection residue widened")

# per-tenant conservation: every multi-tenant row's tenant counters
# must sum exactly to the global simulator counters it reports
n_tenant_rows = 0
for row_key, row in report["rows"].items():
    tenants = row.get("tenants")
    if not tenants:
        continue
    n_tenant_rows += 1
    wb = sum(t["writebacks"] for t in tenants.values())
    if wb != row["writebacks"]:
        sys.exit(f"{row_key}: per-tenant write-backs {wb} != global "
                 f"{row['writebacks']} (attribution broken)")
    served = sum(t["hits"] + t["mshr_hits"] for t in tenants.values())
    total = sum(t["hits"] + t["mshr_hits"] + t["cold_misses"]
                + t["conflict_misses"] for t in tenants.values())
    if total and abs(served / total - row["hit_rate"]) > 1e-9:
        sys.exit(f"{row_key}: per-tenant hit mass does not reproduce "
                 f"the row's hit rate")

# suite throughput: the sweep must stay the fast path (DESIGN.md §8.5)
perf = report.get("perf")
sps = None
if perf is not None:
    sps = float(perf["seconds_per_scenario"])
    if sps > MAX_SECONDS_PER_SCENARIO:
        sys.exit(f"suite throughput regressed: {sps:.2f} s per scenario "
                 f"> {MAX_SECONDS_PER_SCENARIO} s budget "
                 f"(case seconds: {perf.get('case_seconds')})")

print(f"suite gate OK on {scenarios}: profile {prof:.3f} <= "
      f"max(closed {closed:.3f}, {ABS_OK}); dbp wins {flagged}; "
      f"{n_tenant_rows} multi-tenant rows conserve"
      + (f"; {sps:.2f} s/scenario" if sps is not None else ""))

#!/usr/bin/env python
"""Lint registered dataflow specs with the static verifier (DESIGN.md §12).

Runs the full rule inventory (``repro.dataflows.verify``) over one or
more suite scenarios and reports structured diagnostics; exits non-zero
when any error-tier rule fires, so CI can gate on it.

    PYTHONPATH=src python scripts/spec_lint.py --all
    PYTHONPATH=src python scripts/spec_lint.py matmul ssd-scan -v
    PYTHONPATH=src python scripts/spec_lint.py --all --json report.json
    PYTHONPATH=src python scripts/spec_lint.py --all --cross-check
    PYTHONPATH=src python scripts/spec_lint.py --rules

``--cross-check`` additionally runs each scenario in the simulator with
event telemetry on and compares the analyzer's predicted TMU retirement
counts against measured ``RETIRE`` events per policy (the ground-truth
contract: a predicted-clean spec must retire exactly as the annotations
say, under every policy).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
import sys

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.dataflows.suite import registry_keys
from repro.dataflows.suite import suite_case
from repro.dataflows.verify import cross_check_case
from repro.dataflows.verify import rules_inventory
from repro.dataflows.verify import verify_spec

EXIT_OK = 0
EXIT_ERRORS = 1
EXIT_USAGE = 2


def _print_rules() -> None:
    for r in rules_inventory():
        print(f"{r['code']} [{r['severity']:5s}] "
              f"[alloc:{r['allocator']:6s}] {r['title']}")
        print(f"    assumes:  {r['assumption']}")
        print(f"    consumer: {r['consumer']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("scenarios", nargs="*",
                    help="suite scenario keys (see --all for the sweep)")
    ap.add_argument("--all", action="store_true",
                    help="lint every registered scenario")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes instead of the reduced grid")
    ap.add_argument("--json", type=Path, default=None,
                    help="write the full diagnostic report to this file")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule inventory and exit")
    ap.add_argument("--cross-check", action="store_true",
                    help="also compare predicted retirements against "
                         "simulator-measured TMU RETIRE events")
    ap.add_argument("--policies", default="lru,dbp,at+dbp",
                    help="policy set for --cross-check "
                         "(comma-separated, default %(default)s)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every diagnostic, not just summaries")
    args = ap.parse_args(argv)

    if args.rules:
        _print_rules()
        if args.json is not None:
            args.json.parent.mkdir(parents=True, exist_ok=True)
            args.json.write_text(json.dumps(
                {"rules": rules_inventory()}, indent=2, sort_keys=True))
            print(f"# rule inventory written to {args.json}")
        return EXIT_OK

    keys = registry_keys() if args.all else args.scenarios
    if not keys:
        print("error: no scenarios given (use --all or name scenarios)",
              file=sys.stderr)
        return EXIT_USAGE
    known = set(registry_keys())
    bad = [k for k in keys if k not in known]
    if bad:
        print(f"error: unknown scenario(s) {bad}; have "
              f"{sorted(known)}", file=sys.stderr)
        return EXIT_USAGE

    policies = tuple(p for p in args.policies.split(",") if p)
    report = {"scenarios": {}, "n_errors": 0, "cross_check": {}}
    failed = False
    for key in keys:
        case = suite_case(key, full=args.full, gate=False)
        res = verify_spec(case.spec, sim_cfg=case.cfg)
        report["scenarios"][key] = res.to_dict()
        report["n_errors"] += len(res.errors)
        print(res.summary())
        shown = res.diagnostics if args.verbose else res.errors
        for d in shown:
            print(f"  {d.format()}")
        if res.has_errors:
            failed = True
        if args.cross_check:
            cc = cross_check_case(case, policies=policies)
            report["cross_check"][key] = cc
            if cc["agree"]:
                print(f"  cross-check OK: {cc['predicted_retirements']} "
                      f"retirements agree across {list(policies)}")
            else:
                failed = True
                print(f"  cross-check FAILED: {json.dumps(cc['policies'])}",
                      file=sys.stderr)

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(report, indent=2, sort_keys=True))
        print(f"# report written to {args.json}")

    if failed:
        print(f"spec lint: FAILED ({report['n_errors']} error-tier "
              f"diagnostic(s))", file=sys.stderr)
        return EXIT_ERRORS
    print(f"spec lint OK on {list(keys)}")
    return EXIT_OK


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""Differential conformance gate: step vs compiled vs streaming engines.

Runs the scenario×policy conformance matrix (``repro.conformance``) and
verifies, per cell: (1) step and compiled engines agree on the canonical
event stream, (2) the streaming/chunked compiled run concatenates
bit-identically to the monolithic one, (3) the canonical digest matches
the golden frozen under ``tests/golden/conformance_digests.json``.

Any failure prints the first-divergence event with round + surrounding
context and exits 1; ``--report`` additionally writes the full failure
report as JSON (CI uploads it as an artifact).

    PYTHONPATH=src python scripts/conformance.py                # full matrix
    PYTHONPATH=src python scripts/conformance.py --smoke        # CI subset
    PYTHONPATH=src python scripts/conformance.py --update-golden
    PYTHONPATH=src python scripts/conformance.py \
        --scenario matmul --policy lru dbp
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
import sys

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.conformance import golden_path
from repro.conformance import load_golden
from repro.conformance import matrix_entries
from repro.conformance import run_matrix
from repro.conformance import save_golden


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run only the CI smoke scenario subset")
    ap.add_argument("--scenario", nargs="*", default=None,
                    help="restrict the scenario axis")
    ap.add_argument("--policy", nargs="*", default=None,
                    help="restrict the policy axis")
    ap.add_argument("--update-golden", action="store_true",
                    help="refresh tests/golden/conformance_digests.json "
                         "from this run instead of diffing against it")
    ap.add_argument("--report", type=Path, default=None,
                    help="write the JSON failure/summary report here")
    ap.add_argument("--window", type=int, default=3,
                    help="context events around a divergence (default 3)")
    args = ap.parse_args(argv)

    golden = None
    if not args.update_golden:
        golden = load_golden()
        if golden is None:
            print(f"warning: no golden digests at {golden_path()} (or "
                  f"stale schema) — engine/streaming checks only; run "
                  f"--update-golden to freeze them", file=sys.stderr)

    entries = list(matrix_entries(smoke=args.smoke,
                                  scenarios=args.scenario,
                                  policies=args.policy))

    def progress(cell):
        status = "ok" if cell.ok else f"FAIL[{cell.failure}]"
        print(f"  {cell.scenario:20s} {cell.policy:8s} "
              f"{cell.n_events:9d} events  {cell.seconds:6.1f}s  {status}",
              flush=True)

    print(f"conformance matrix: {len(entries)} cells", flush=True)
    results = run_matrix(entries, golden=golden, window=args.window)
    for cell in results:
        progress(cell)

    failures = [r for r in results if not r.ok]

    if args.update_golden:
        # merge into the existing file so partial-matrix runs don't drop
        # digests of cells they did not execute
        merged = load_golden() or {}
        for r in results:
            if r.failure in (None, "golden", "missing-golden"):
                merged[f"{r.scenario}/{r.policy}"] = r.digest
        path = save_golden(merged)
        print(f"froze {len(merged)} golden digests to {path}")
        failures = [r for r in failures
                    if r.failure not in ("golden", "missing-golden")]

    if args.report is not None:
        args.report.parent.mkdir(parents=True, exist_ok=True)
        args.report.write_text(json.dumps({
            "cells": [r.to_dict() for r in results],
            "failures": len(failures),
        }, indent=2) + "\n")
        print(f"report written to {args.report}")

    if failures:
        print(f"\n{len(failures)} conformance failure(s):")
        for r in failures:
            print(f"\n== {r.scenario}/{r.policy}: {r.failure}")
            if r.divergence is not None:
                print(r.divergence.render())
            elif r.failure == "golden":
                print(f"  digest   {r.digest}\n  golden   {r.golden}")
            elif r.failure == "missing-golden":
                print(f"  digest {r.digest} has no frozen golden — run "
                      f"--update-golden")
        return 1
    print(f"\nall {len(results)} cells conform")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env bash
# Tier-1 verify wrapper (ROADMAP.md): fast default run with timing report.
#
#   scripts/tier1.sh            # default: skips @slow tests (pytest.ini)
#   scripts/tier1.sh -m ""      # full run including @slow tests
#
# Extra arguments are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q --durations=10 "$@"

"""End-to-end training driver example: ~100M-param model, few hundred
steps, with checkpoint/resume and the straggler watchdog.

This wraps the production driver (repro.launch.train).  ~100M params on
CPU takes a while; pass --fast for a 10M-param run.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--fast]
"""

import sys

from repro.launch.train import main

fast = "--fast" in sys.argv
if fast:
    sys.argv = [sys.argv[0], "--arch", "llama3.2-3b", "--reduce",
                "--steps", "60", "--batch", "8", "--seq", "128",
                "--ckpt-dir", "/tmp/repro_tiny_ckpt", "--ckpt-every", "25"]
else:
    # ~100M params: d_model 512, 12 layers, vocab 128256
    sys.argv = [sys.argv[0], "--arch", "llama3.2-3b", "--reduce",
                "--d-model", "512", "--layers", "12",
                "--steps", "300", "--batch", "16", "--seq", "256",
                "--ckpt-dir", "/tmp/repro_100m_ckpt", "--ckpt-every", "100"]
main()

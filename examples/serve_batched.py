"""Batched serving example: continuous batching with slot retirement
(the serving-level dead-block prediction).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import sys

from repro.launch.serve import main

sys.argv = [sys.argv[0], "--arch", "gemma-7b", "--requests", "6",
            "--max-new", "8", "--max-batch", "3", "--max-seq", "96"]
main()

"""Quickstart: the three layers of the DCO reproduction in one script.

1. paper core — simulate the DCO policies on a GQA FlashAttention trace,
2. model zoo  — train a tiny assigned-arch model a few steps,
3. TPU side   — plan VMEM residency with the CacheOrchestrator and run
   the DCO-orchestrated flash-attention kernel (interpret mode on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CacheOrchestrator, SimConfig, build_fa2_trace,
                        get_workload, named_policy, run_policy)
from repro.configs import get_arch, reduce_for_smoke
from repro.data import SyntheticLM
from repro.kernels import attention_ref, flash_attention
from repro.models import init_params
from repro.train import AdamWConfig, init_train_state, make_train_step

# ---- 1. the paper's cache policies under thrashing -----------------------
print("=== DCO policies on Gemma3-27B attention (2K ctx, 4MB LLC) ===")
wl = get_workload("gemma3-27b", seq_len=2048)
trace = build_fa2_trace(wl)
cfg = SimConfig(llc_bytes=4 * 2**20)
lru = run_policy(trace, named_policy("lru"), cfg, record_history=False)
for pol in ("at", "at+bypass", "all"):
    res = run_policy(trace, named_policy(pol), cfg, record_history=False)
    print(f"  {pol:10s}: {lru.cycles / res.cycles:.2f}x over LRU "
          f"(hit {res.hit_rate:.2f} vs {lru.hit_rate:.2f})")

# ---- 2. train a tiny assigned architecture -------------------------------
print("=== Train a reduced llama3.2-3b for 30 steps ===")
arch = reduce_for_smoke(get_arch("llama3.2-3b"))
params = init_params(arch, jax.random.key(0))
state = init_train_state(params)
step = jax.jit(make_train_step(arch, AdamWConfig(lr=3e-3, warmup_steps=3,
                                                 total_steps=30)))
data = SyntheticLM(arch.vocab, 64, 8)
for i in range(30):
    state, m = step(state, jnp.asarray(data.batch(i)))
    if i % 10 == 0 or i == 29:
        print(f"  step {i:2d} loss={float(m['loss']):.3f}")

# ---- 3. the TPU transfer: orchestrated flash attention -------------------
print("=== CacheOrchestrator → pinned/streamed KV split ===")
orch = CacheOrchestrator(vmem_budget_bytes=256 * 1024, b_bits=3)
seq, d = 1024, 128
pinned, streamed = orch.plan_kv_split(seq, 128, bytes_per_row=2 * d * 2)
print(f"  VMEM budget 256KB → pin {pinned} KV rows (anti-thrashing), "
      f"stream {streamed} (bypass)")
k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
q = jax.random.normal(k1, (1, seq, 4, d), jnp.bfloat16)
k = jax.random.normal(k2, (1, seq, 2, d), jnp.bfloat16)
v = jax.random.normal(k3, (1, seq, 2, d), jnp.bfloat16)
out = flash_attention(q, k, v, causal=True, pinned_rows=pinned,
                      interpret=True)
ref = attention_ref(q, k, v, causal=True)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                            - ref.astype(jnp.float32))))
print(f"  kernel vs oracle max |err| = {err:.2e}  (interpret mode)")
print("done.")
